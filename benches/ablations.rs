//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **RU packing** — literal one-packet-per-psum repetitive unicast vs
//!    the packed 4-payloads-per-flit reading (brackets the paper's
//!    baseline; EXPERIMENTS.md "Methodology notes").
//! 2. **PE grouping** (§4.4) — column vs row grouping of the n PEs
//!    behind a router.
//! 3. **δ as fault tolerance** (§4.1) — a node whose upstream initiator
//!    is disabled still delivers after its timeout expires.

use noc_dnn::config::{Collection, PeGrouping, SimConfig};
use noc_dnn::coordinator::experiment::{latency_improvement, Experiment};
use noc_dnn::dataflow::os::OsMapping;
use noc_dnn::models::alexnet;
use noc_dnn::noc::network::Network;
use noc_dnn::noc::Coord;
use noc_dnn::util::bench::{bench_args, time_it, BenchReport};

fn main() {
    let args = bench_args();
    let mut report = BenchReport::new("ablations", args.quick);
    let layer = &alexnet::conv_layers()[2];

    // ---- 1) RU packing ----
    println!("== ablation: RU baseline reading (8x8, trace-driven, AlexNet conv3) ==");
    for n in [1usize, 4, 8] {
        let mut cfg = SimConfig::table1_8x8(n);
        cfg.trace_driven = true;
        let gather = Experiment::proposed(cfg.clone()).run_layer(layer);
        let literal = Experiment::baseline_ru(cfg.clone()).run_layer(layer);
        cfg.ru_pack_payloads = true;
        let packed = Experiment::baseline_ru(cfg).run_layer(layer);
        let vs_literal = latency_improvement(&literal, &gather);
        let vs_packed = latency_improvement(&packed, &gather);
        println!("  n={n}: improvement vs literal RU {vs_literal:.2}x, vs packed RU {vs_packed:.2}x");
        report.add(BenchReport::point(
            &[("name", "ru_packing")],
            &[("n", n as f64), ("vs_literal_ru", vs_literal), ("vs_packed_ru", vs_packed)],
        ));
    }
    println!("  (the paper's reported 1.0-1.84x sits between the two readings)");

    // ---- 2) PE grouping ----
    println!("\n== ablation: PE grouping (§4.4), 8x8 n=4 ==");
    for grouping in [PeGrouping::Column, PeGrouping::Row] {
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.pe_grouping = grouping;
        let m = OsMapping::new(&cfg, layer);
        let rep = Experiment::proposed(cfg).run_layer(layer);
        println!(
            "  {:<6} rounds={} row_bus={}w col_bus={}w total={} cycles",
            grouping.label(),
            m.rounds,
            m.row_stream_words,
            m.col_stream_words,
            rep.run.total_cycles
        );
        report.add(BenchReport::point(
            &[("name", "pe_grouping"), ("grouping", grouping.label())],
            &[("rounds", m.rounds as f64), ("total_cycles", rep.run.total_cycles as f64)],
        ));
    }

    // ---- 3) δ as a fault-tolerance bound (§4.1) ----
    println!("\n== ablation: timeout bounds the wait when no packet ever comes ==");
    let cfg = SimConfig::table1_8x8(1);
    let mut net = Network::new(&cfg, Collection::Gather);
    // Only a non-initiator node has payloads: no initiator packet will
    // ever pass, so delivery relies entirely on the δ expiry.
    net.post_result(0, Coord::new(5, 0), 1);
    let ok = net.run_until(|n| n.payloads_delivered >= 1, 100_000);
    assert!(ok, "orphan payload must still be delivered");
    println!(
        "  orphan payload delivered at cycle {} (delta={} + transit), packets={}",
        net.cycle,
        cfg.delta,
        net.stats.packets_injected
    );
    assert!(net.cycle as i64 >= cfg.delta as i64, "must have waited out delta");

    report.add(BenchReport::point(
        &[("name", "delta_fault_tolerance")],
        &[("orphan_delivery_cycle", net.cycle as f64), ("delta", cfg.delta as f64)],
    ));

    let t = time_it(3, || {
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.trace_driven = true;
        Experiment::proposed(cfg).run_layer(layer)
    });
    println!("\nbench: one trace-driven layer experiment {t}");
    report.add(BenchReport::point(
        &[("name", "layer_experiment")],
        &[("median_ns", t.median_ns as f64)],
    ));

    if let Some(path) = &args.json {
        report.write(path).expect("failed to write bench JSON");
    }
}
