//! Bench + regeneration of Fig. 12: effect of the timeout δ on the
//! single-row collection scenario (latency and power, 1/2/4/8 PEs/router).
//!
//! Prints the paper's series (normalized vs the δ<κ point) and times the
//! underlying simulation.

use noc_dnn::coordinator::{report, sweep};
use noc_dnn::util::bench::time_it;

fn main() {
    let factors = [0u64, 1, 3, 5, 7, 9, 11];
    for mesh in [8usize, 16] {
        let series = sweep::fig12(mesh, &factors);
        println!("Fig. 12 ({mesh}x{mesh}) — normalized runtime latency & power vs delta:");
        print!("{}", report::fig12_text(&series));
        // Paper's qualitative claims, asserted on every regeneration:
        for s in &series {
            let base = &s.points[0];
            let plateau = s.points.last().unwrap();
            assert!(
                plateau.energy_j <= base.energy_j,
                "power must improve with large delta (n={})",
                s.pes_per_router
            );
            if s.pes_per_router >= 4 {
                assert!(
                    plateau.latency_cycles <= base.latency_cycles,
                    "latency must improve for heavily loaded rows (n={})",
                    s.pes_per_router
                );
            }
        }
        println!();
    }

    let t = time_it(5, || sweep::fig12(8, &factors));
    println!("bench: fig12 sweep (8x8, 7 deltas x 4 n) {t}");
}
