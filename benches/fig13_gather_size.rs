//! Bench + regeneration of Fig. 13: one large gather packet vs two
//! smaller gather packets, 8×8 and 16×16, 1/2/4/8 PEs/router, normalized
//! against repetitive unicast.

use noc_dnn::coordinator::{report, sweep};
use noc_dnn::models::alexnet;
use noc_dnn::util::bench::time_it;

fn main() {
    let layer = &alexnet::conv_layers()[2];
    for mesh in [8usize, 16] {
        let rows = sweep::fig13(mesh, layer);
        println!("Fig. 13 ({mesh}x{mesh}, workload AlexNet {}):", layer.name);
        print!("{}", report::fig13_text(&rows));
        for r in &rows {
            // Paper §5.2: one large packet is at least as good for
            // latency as two smaller packets.
            assert!(
                r.get("one_pkt_lat_impr").unwrap() >= r.get("two_pkt_lat_impr").unwrap() * 0.98,
                "one-packet latency should not lose to two-packet (n={})",
                r.pes_per_router
            );
        }
        println!();
    }

    let t = time_it(3, || sweep::fig13(8, layer));
    println!("bench: fig13 study (8x8, 4 n, 3 configs each) {t}");
}
