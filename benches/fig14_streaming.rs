//! Bench + regeneration of Fig. 14: runtime improvement of gather with
//! two-way / one-way streaming over the gather-only architecture [27],
//! per conv layer of AlexNet and VGG-16.

use noc_dnn::coordinator::{report, sweep};
use noc_dnn::util::bench::time_it;

fn main() {
    let rows = sweep::fig14(8, 1);
    println!("Fig. 14 (8x8 mesh, n=1):");
    print!("{}", report::fig14_text(&rows));

    let avg2 = rows.iter().filter_map(|r| r.get("two_way_improvement")).sum::<f64>()
        / rows.len() as f64;
    let avg1 = rows.iter().filter_map(|r| r.get("one_way_improvement")).sum::<f64>()
        / rows.len() as f64;
    // Paper: two-way 1.71x, one-way 1.48x on average; the qualitative
    // ordering (both > 1, two-way > one-way) must hold.
    assert!(avg2 > 1.0, "two-way must beat gather-only (avg {avg2})");
    assert!(avg1 > 1.0, "one-way must beat gather-only (avg {avg1})");
    assert!(avg2 > avg1, "two-way must beat one-way for OS dataflow");
    println!("\npaper: 1.71x (two-way) / 1.48x (one-way); ours: {avg2:.2}x / {avg1:.2}x");

    let t = time_it(1, || sweep::fig14(8, 1));
    println!("bench: fig14 (18 layers x 3 architectures) {t}");
}
