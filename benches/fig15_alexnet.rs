//! Bench + regeneration of Fig. 15: AlexNet — total runtime latency and
//! network power improvement of gather over repetitive unicast, on 8×8
//! and 16×16 meshes for 1/2/4/8 PEs/router (two-way streaming fabric).

use noc_dnn::coordinator::{report, sweep};
use noc_dnn::models::Network;
use noc_dnn::util::bench::time_it;

fn main() {
    let model = Network::alexnet();
    let points = sweep::fig_model(&model, &[8, 16], &[1, 2, 4, 8]);
    println!("Fig. 15 — AlexNet, gather vs RU:");
    print!("{}", report::fig_model_text(&points));

    // Paper's qualitative claims:
    for mesh in [8usize, 16] {
        let at = |n: usize| {
            let v: Vec<f64> = points
                .iter()
                .filter(|p| p.mesh == mesh && p.pes_per_router == n)
                .filter_map(|p| p.get("latency_improvement"))
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        // Improvement grows with PEs/router (§5.3).
        assert!(at(8) > at(1), "mesh {mesh}: improvement must grow with n");
        // Gather is at worst marginally behind RU in the uncongested n=1
        // regime (§5.2 reports a slight increase there).
        assert!(at(1) > 0.9, "mesh {mesh}: n=1 should be near parity");
    }
    let avg16: f64 = points
        .iter()
        .filter(|p| p.mesh == 16 && p.pes_per_router == 8)
        .filter_map(|p| p.get("latency_improvement"))
        .sum::<f64>()
        / model.len() as f64;
    println!("\npaper headline: up to 1.8x latency; ours at 16x16/n=8: {avg16:.2}x");

    let t = time_it(1, || sweep::fig_model(&model, &[8], &[4]));
    println!("bench: fig15 slice (5 layers, 8x8, n=4) {t}");
}
