//! Bench + regeneration of Fig. 16: VGG-16 — total runtime latency and
//! network power improvement of gather over repetitive unicast, on 8×8
//! and 16×16 meshes for 1/2/4/8 PEs/router (two-way streaming fabric).

use noc_dnn::coordinator::{report, sweep};
use noc_dnn::models::Network;
use noc_dnn::util::bench::time_it;

fn main() {
    let model = Network::vgg16();
    let points = sweep::fig_model(&model, &[8, 16], &[1, 2, 4, 8]);
    println!("Fig. 16 — VGG-16, gather vs RU:");
    print!("{}", report::fig_model_text(&points));

    let avg = |mesh: usize, n: usize| {
        let v: Vec<f64> = points
            .iter()
            .filter(|p| p.mesh == mesh && p.pes_per_router == n)
            .filter_map(|p| p.get("latency_improvement"))
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    // Paper: improvement grows with n; 16x16 offers more improvement than
    // 8x8 at high n (up to 1.84x).
    assert!(avg(8, 8) > avg(8, 1), "8x8: improvement must grow with n");
    assert!(avg(16, 8) > avg(16, 1), "16x16: improvement must grow with n");
    assert!(avg(16, 8) > avg(8, 8) * 0.95, "16x16 should be at least on par at n=8");
    println!(
        "\npaper headline: up to 1.84x (16x16); ours: 8x8/n=8 {:.2}x, 16x16/n=8 {:.2}x",
        avg(8, 8),
        avg(16, 8)
    );

    let head = Network::new("vgg16-head", model.layers[..2].to_vec());
    let t = time_it(1, || sweep::fig_model(&head, &[8], &[4]));
    println!("bench: fig16 slice (2 layers, 8x8, n=4) {t}");
}
