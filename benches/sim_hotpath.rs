//! Simulator hot-path microbenchmarks (the §Perf targets of DESIGN.md).
//!
//! Every workload runs on **both** cycle kernels — the event-driven
//! production core (`noc::network::Network`) and the frozen pre-refactor
//! reference (`noc::reference::ReferenceNetwork`) — so each run reports a
//! true before/after speedup on the same machine, and cross-checks that
//! the two kernels produce identical cycle/hop counts while it measures.
//!
//! Workloads:
//! * **saturate** — every node posts `rounds` rounds of payloads up
//!   front; the mesh runs congested. The active-router set degenerates
//!   toward "all routers", so this bounds the bookkeeping overhead.
//! * **sparse** — one row collects per burst with long idle gaps; the
//!   drain-tail / gather-window regime where the active set and the
//!   calendar fast-forward dominate.
//! * **layer** — end-to-end AlexNet conv3 through the round driver (what
//!   every paper-figure point costs).
//!
//! * **big-mesh-probes-off / big-mesh-probes-on** — the saturating
//!   workload on a 32×32 fabric, event kernel only, with the per-link
//!   observability probes (`SimConfig::probes`) off and on. Distinct
//!   point names keep the two regimes as separate regression-gate keys;
//!   the run also asserts the probed kernel's cycle/hop counts are
//!   bit-identical to the unprobed one (probes are observation-only).
//!
//! * **big-mesh-compact-w{1,2,4,8}** — the saturating workload on a
//!   64×64 fabric under the intra-layer parallel kernel
//!   (`SimConfig::intra_workers`), event kernel only, measuring the
//!   compact-flit data layout (32-byte interned flit descriptors +
//!   enum-dispatched `Fabric` routing). One point name — one
//!   regression-gate key — per worker count, and every parallel run is
//!   asserted bit-identical to the workers=1 run it is compared to. The
//!   keys are distinct from the retired `big-mesh-workers-w{N}` points
//!   so the layout change lands as new baseline entries rather than a
//!   same-key delta against the wide-flit numbers.
//!
//! * **serving-knee** — the serving event loop (`serving::serve`) on a
//!   synthetic 8-layer service profile at 0.9× capacity: Poisson
//!   arrivals, batching, the multi-pass fabric interleaver and the
//!   latency histogram, with no network simulation underneath — a pure
//!   measure of the serving subsystem's calendar loop. Tagged
//!   `kernel=event` so the regression gate covers it once baselined.
//!
//! `--quick` runs the reduced CI matrix; `--json PATH` writes the
//! machine-readable report (`BENCH_sim_hotpath.json`) that
//! `scripts/check_bench_regression.py` gates against the committed
//! baseline.

use noc_dnn::config::{Collection, SimConfig};
use noc_dnn::coordinator::Experiment;
use noc_dnn::models::alexnet;
use noc_dnn::noc::network::Network;
use noc_dnn::noc::reference::{ReferenceNetwork, SimKernel};
use noc_dnn::noc::Coord;
use noc_dnn::serving::{serve, ArrivalKind, LayerCost, ServiceProfile, ServingConfig};
use noc_dnn::util::bench::{bench_args, fmt_ns, time_it, BenchReport, Timing};

const SATURATE_ROUNDS: u64 = 16;
const SPARSE_BURSTS: u64 = 8;
/// Idle gap between sparse bursts (cycles) — long enough that the mesh
/// fully drains and the clock fast-forwards between bursts.
const SPARSE_GAP: u64 = 2_000;

/// Saturating workload: every node posts `rounds` rounds of payloads.
fn saturate<K: SimKernel>(mut net: K, cfg: &SimConfig, rounds: u64) -> (u64, u64) {
    for r in 0..rounds {
        for y in 0..cfg.mesh_rows {
            for x in 0..cfg.mesh_cols {
                net.post_result(
                    r * 10 + 1,
                    Coord::new(x as u16, y as u16),
                    cfg.pes_per_router as u32,
                );
            }
        }
    }
    let total = rounds * (cfg.mesh_rows * cfg.mesh_cols * cfg.pes_per_router) as u64;
    let ok = net.run_until_delivered(total, 10_000_000);
    assert!(ok, "saturation run stalled");
    (net.stats().flit_hops, net.cycle())
}

/// Drain-heavy workload: one row collects per burst while the rest of
/// the mesh idles, with quiescent gaps between bursts.
fn sparse<K: SimKernel>(mut net: K, cfg: &SimConfig, bursts: u64) -> (u64, u64) {
    let mut posted = 0u64;
    for b in 0..bursts {
        let y = (b as usize) % cfg.mesh_rows;
        for x in 0..cfg.mesh_cols {
            net.post_result(
                b * SPARSE_GAP + 1,
                Coord::new(x as u16, y as u16),
                cfg.pes_per_router as u32,
            );
            posted += cfg.pes_per_router as u64;
        }
    }
    let ok = net.run_until_delivered(posted, 50_000_000);
    assert!(ok, "sparse run stalled");
    (net.stats().flit_hops, net.cycle())
}

struct Measured {
    hops: u64,
    cycles: u64,
    t: Timing,
}

impl Measured {
    fn cycles_per_sec(&self) -> f64 {
        self.cycles as f64 / (self.t.median_ns as f64 / 1e9)
    }

    fn hops_per_sec(&self) -> f64 {
        self.hops as f64 / (self.t.median_ns as f64 / 1e9)
    }
}

fn measure<K: SimKernel>(
    reps: usize,
    make: impl Fn() -> K,
    run: impl Fn(K) -> (u64, u64),
) -> Measured {
    // The workloads are deterministic, so the (hops, cycles) of the last
    // timed rep represent every rep — no extra untimed run needed
    // (time_it already does one warm-up internally).
    let mut last = (0u64, 0u64);
    let t = time_it(reps, || {
        last = run(make());
        last
    });
    Measured { hops: last.0, cycles: last.1, t }
}

#[allow(clippy::too_many_arguments)]
fn record(
    report: &mut BenchReport,
    workload: &str,
    kernel: &str,
    mesh: usize,
    n: usize,
    coll: Collection,
    m: &Measured,
) {
    report.add(BenchReport::point(
        &[("name", workload), ("kernel", kernel), ("collection", coll.label())],
        &[
            ("mesh", mesh as f64),
            ("n", n as f64),
            ("cycles", m.cycles as f64),
            ("flit_hops", m.hops as f64),
            ("median_ns", m.t.median_ns as f64),
            ("cycles_per_sec", m.cycles_per_sec()),
            ("hops_per_sec", m.hops_per_sec()),
        ],
    ));
}

fn main() {
    let args = bench_args();
    let reps = if args.quick { 2 } else { 5 };
    let matrix: &[(usize, usize)] =
        if args.quick { &[(16, 8)] } else { &[(8, 4), (16, 4), (16, 8)] };
    let mut report = BenchReport::new("sim_hotpath", args.quick);

    for &(mesh, n) in matrix {
        let cfg = SimConfig::table1(mesh, n);
        for coll in [Collection::Gather, Collection::RepetitiveUnicast] {
            for (workload, run_ev, run_rf) in [
                (
                    "saturate",
                    measure(reps, || Network::new(&cfg, coll), |k| {
                        saturate(k, &cfg, SATURATE_ROUNDS)
                    }),
                    measure(reps, || ReferenceNetwork::new(&cfg, coll), |k| {
                        saturate(k, &cfg, SATURATE_ROUNDS)
                    }),
                ),
                (
                    "sparse",
                    measure(reps, || Network::new(&cfg, coll), |k| {
                        sparse(k, &cfg, SPARSE_BURSTS)
                    }),
                    measure(reps, || ReferenceNetwork::new(&cfg, coll), |k| {
                        sparse(k, &cfg, SPARSE_BURSTS)
                    }),
                ),
            ] {
                // The bench doubles as a coarse equivalence check; the
                // real suite is tests/golden_kernel.rs.
                assert_eq!(
                    (run_ev.hops, run_ev.cycles),
                    (run_rf.hops, run_rf.cycles),
                    "{workload} {mesh}x{mesh} n={n} {}: kernels diverged",
                    coll.label()
                );
                let speedup = run_rf.t.median_ns as f64 / run_ev.t.median_ns as f64;
                println!(
                    "{mesh:>2}x{mesh} n={n} {:<6} {workload:<8} event {:>9} | reference {:>9} \
                     | {:>5.1}M cyc/s vs {:>5.1}M | speedup {speedup:>5.2}x",
                    coll.label(),
                    fmt_ns(run_ev.t.median_ns),
                    fmt_ns(run_rf.t.median_ns),
                    run_ev.cycles_per_sec() / 1e6,
                    run_rf.cycles_per_sec() / 1e6,
                );
                record(&mut report, workload, "event", mesh, n, coll, &run_ev);
                record(&mut report, workload, "reference", mesh, n, coll, &run_rf);
                report.add(BenchReport::point(
                    &[("name", "speedup"), ("workload", workload), ("collection", coll.label())],
                    &[("mesh", mesh as f64), ("n", n as f64), ("event_over_reference", speedup)],
                ));
            }
        }
    }

    // Big-mesh probe overhead: 32x32, event kernel only (the frozen
    // reference is mesh-only and would dominate the wall clock at this
    // size), saturating workload with the per-link probes off then on.
    {
        let big_mesh = 32usize;
        let big_n = 2usize;
        let rounds = if args.quick { 2 } else { 4 };
        let coll = Collection::Gather;
        let mut cfg_off = SimConfig::table1(big_mesh, big_n);
        cfg_off.probes = false;
        let mut cfg_on = cfg_off.clone();
        cfg_on.probes = true;
        let off = measure(reps, || Network::new(&cfg_off, coll), |k| {
            saturate(k, &cfg_off, rounds)
        });
        let on = measure(reps, || Network::new(&cfg_on, coll), |k| {
            saturate(k, &cfg_on, rounds)
        });
        // Probes must observe without perturbing: same cycles, same hops.
        assert_eq!(
            (off.hops, off.cycles),
            (on.hops, on.cycles),
            "32x32 probes-on run diverged from its probes-off twin"
        );
        let overhead = on.t.median_ns as f64 / off.t.median_ns as f64;
        println!(
            "{big_mesh}x{big_mesh} n={big_n} gather saturate probes off {:>9} | on {:>9} \
             | probe overhead {overhead:>5.2}x",
            fmt_ns(off.t.median_ns),
            fmt_ns(on.t.median_ns),
        );
        record(&mut report, "big-mesh-probes-off", "event", big_mesh, big_n, coll, &off);
        record(&mut report, "big-mesh-probes-on", "event", big_mesh, big_n, coll, &on);
    }

    // Intra-layer parallel kernel on the compact-flit layout: 64x64
    // saturating gather, event kernel only, at 1/2/4/8 band workers.
    // Distinct point names per worker count keep each point a separate
    // regression-gate key, and every parallel run is asserted
    // bit-identical to the workers=1 baseline while it is being timed.
    {
        let big_mesh = 64usize;
        let big_n = 2usize;
        let rounds = if args.quick { 1 } else { 2 };
        let coll = Collection::Gather;
        let mut baseline: Option<Measured> = None;
        for workers in [1usize, 2, 4, 8] {
            let mut cfg = SimConfig::table1(big_mesh, big_n);
            cfg.probes = false;
            cfg.intra_workers = workers;
            let m = measure(reps, || Network::new(&cfg, coll), |k| {
                saturate(k, &cfg, rounds)
            });
            if let Some(base) = &baseline {
                assert_eq!(
                    (m.hops, m.cycles),
                    (base.hops, base.cycles),
                    "64x64 workers={workers} run diverged from the sequential kernel"
                );
                let speedup = base.t.median_ns as f64 / m.t.median_ns as f64;
                println!(
                    "{big_mesh}x{big_mesh} n={big_n} gather saturate workers {workers} {:>9} \
                     | vs workers 1 {:>9} | speedup {speedup:>5.2}x",
                    fmt_ns(m.t.median_ns),
                    fmt_ns(base.t.median_ns),
                );
            } else {
                println!(
                    "{big_mesh}x{big_mesh} n={big_n} gather saturate workers {workers} {:>9}",
                    fmt_ns(m.t.median_ns),
                );
            }
            record(
                &mut report,
                &format!("big-mesh-compact-w{workers}"),
                "event",
                big_mesh,
                big_n,
                coll,
                &m,
            );
            if workers == 1 {
                baseline = Some(m);
            }
        }
    }

    // Serving event loop near the knee: a synthetic 8-layer profile (no
    // network simulation underneath) served at 0.9x its serial-fabric
    // capacity — batching, the pass interleaver and the histogram are
    // the entire cost. cycles_per_sec here is simulated serving cycles
    // per wall-second, the same axis the gate already checks.
    {
        let profile = ServiceProfile::synthetic(
            "bench",
            (0..8u64)
                .map(|i| LayerCost {
                    name: format!("l{i}"),
                    setup_cycles: 40,
                    per_image_cycles: 220 + 13 * i,
                    reload_cycles: 60,
                })
                .collect(),
        );
        let cfg = ServingConfig {
            arrival: ArrivalKind::Poisson,
            rate_per_mcycle: profile.capacity_per_mcycle(4) * 0.9,
            batch: 4,
            queue_cap: 32,
            max_inflight: 2,
            duration: if args.quick { 20_000_000 } else { 80_000_000 },
            seed: 7,
            ..ServingConfig::default()
        };
        let mut last = (0u64, 0u64);
        let t = time_it(reps, || {
            let rep = serve(&profile, &cfg).expect("bench serving config is valid");
            assert_eq!(rep.conservation_violations, 0, "serving bench lost requests");
            last = (rep.total_cycles, rep.completed);
            last
        });
        let cyc_per_sec = last.0 as f64 / (t.median_ns as f64 / 1e9);
        println!(
            "serving-knee (8 synthetic layers, 0.9x capacity, {}M cycles): {t} \
             | {:>5.1}M cyc/s | {} requests",
            cfg.duration / 1_000_000,
            cyc_per_sec / 1e6,
            last.1,
        );
        report.add(BenchReport::point(
            &[("name", "serving-knee"), ("kernel", "event"), ("collection", "synthetic")],
            &[
                ("duration_cycles", cfg.duration as f64),
                ("cycles", last.0 as f64),
                ("completed", last.1 as f64),
                ("median_ns", t.median_ns as f64),
                ("cycles_per_sec", cyc_per_sec),
            ],
        ));
    }

    // End-to-end layer simulation timing (what every figure point costs).
    let layer = &alexnet::conv_layers()[2];
    let mut cfg = SimConfig::table1_16x16(8);
    cfg.trace_driven = true;
    for coll in [Collection::Gather, Collection::RepetitiveUnicast] {
        let exp = match coll {
            Collection::Gather => Experiment::proposed(cfg.clone()),
            _ => Experiment::baseline_ru(cfg.clone()),
        };
        let t = time_it(reps, || exp.run_layer(layer));
        let label = format!("{},", coll.label());
        println!("layer sim (16x16, n=8, {label:<6} AlexNet conv3): {t}");
        report.add(BenchReport::point(
            &[("name", "layer"), ("kernel", "event"), ("collection", coll.label())],
            &[
                ("mesh", 16.0),
                ("n", 8.0),
                ("median_ns", t.median_ns as f64),
                ("ns_per_layer", t.median_ns as f64),
            ],
        ));
    }

    if let Some(path) = &args.json {
        report.write(path).expect("failed to write bench JSON");
    }
}
