//! Simulator hot-path microbenchmarks (the §Perf targets of DESIGN.md):
//! flit throughput of the cycle loop under saturating collection traffic,
//! plus end-to-end layer-simulation timing.

use noc_dnn::config::{Collection, SimConfig};
use noc_dnn::coordinator::Experiment;
use noc_dnn::models::alexnet;
use noc_dnn::noc::network::Network;
use noc_dnn::noc::Coord;
use noc_dnn::util::bench::{fmt_ns, time_it};

/// Saturating workload: every node posts `rounds` rounds of payloads.
fn saturate(cfg: &SimConfig, collection: Collection, rounds: u64) -> (u64, u64) {
    let mut net = Network::new(cfg, collection);
    for r in 0..rounds {
        for y in 0..cfg.mesh_rows {
            for x in 0..cfg.mesh_cols {
                net.post_result(
                    r * 10 + 1,
                    Coord::new(x as u16, y as u16),
                    cfg.pes_per_router as u32,
                );
            }
        }
    }
    let total = rounds * (cfg.mesh_rows * cfg.mesh_cols * cfg.pes_per_router) as u64;
    let ok = net.run_until(|n| n.payloads_delivered >= total, 10_000_000);
    assert!(ok, "saturation run stalled");
    (net.stats.flit_hops, net.cycle)
}

fn main() {
    for (mesh, n) in [(8usize, 4usize), (16, 4), (16, 8)] {
        let cfg = SimConfig::table1(mesh, n);
        for coll in [Collection::Gather, Collection::RepetitiveUnicast] {
            let (hops, cycles) = saturate(&cfg, coll, 16);
            let t = time_it(5, || saturate(&cfg, coll, 16));
            let hops_per_sec = hops as f64 / (t.median_ns as f64 / 1e9);
            let cyc_per_sec = cycles as f64 / (t.median_ns as f64 / 1e9);
            println!(
                "{mesh:>2}x{mesh} n={n} {:<7} {hops:>7} flit-hops / {cycles:>6} cycles in {:>9}  -> {:>5.1}M hops/s, {:>5.1}M cycles/s",
                match coll { Collection::Gather => "gather", _ => "RU" },
                fmt_ns(t.median_ns),
                hops_per_sec / 1e6,
                cyc_per_sec / 1e6,
            );
        }
    }

    // End-to-end layer simulation timing (what every figure point costs).
    let layer = &alexnet::conv_layers()[2];
    let mut cfg = SimConfig::table1_16x16(8);
    cfg.trace_driven = true;
    let t = time_it(5, || Experiment::proposed(cfg.clone()).run_layer(layer));
    println!("\nlayer sim (16x16, n=8, gather, AlexNet conv3): {t}");
    let t = time_it(5, || Experiment::baseline_ru(cfg.clone()).run_layer(layer));
    println!("layer sim (16x16, n=8, RU,     AlexNet conv3): {t}");
}
