//! Regeneration of the §5.4 hardware-overhead comparison (the paper's
//! only table of synthesis results): baseline vs gather-supported router
//! power and area at 45 nm / 1 GHz.

use noc_dnn::power::area::overhead_report;
use noc_dnn::power::router::{RouterArea, RouterEnergy};
use noc_dnn::util::bench::time_it;

fn main() {
    let r = overhead_report(1.0e9);
    println!("§5.4 hardware overhead (Table-1 router, 45 nm, 1 GHz):");
    println!("  power: {:.2} mW -> {:.2} mW  (+{:.1}%)", r.baseline_power_mw, r.proposed_power_mw, r.power_overhead_pct);
    println!("  area:  {:.0} um^2 -> {:.0} um^2  (+{:.1}%)", r.baseline_area_um2, r.proposed_area_um2, r.area_overhead_pct);
    println!("  paper: 26.3 mW -> 27.87 mW (~6%); 72106 um^2 -> 74950 um^2 (~4%)");

    // Component roll-up (the DSENT-style breakdown behind the totals).
    let a = RouterArea::forty_five_nm();
    println!("\narea breakdown (um^2):");
    println!("  input buffers   {:8.0}", a.buffers_um2);
    println!("  crossbar        {:8.0}", a.crossbar_um2);
    println!("  allocators      {:8.0}", a.allocators_um2);
    println!("  other           {:8.0}", a.other_um2);
    println!("  + load gen      {:8.0}", a.gather_load_gen_um2);
    println!("  + payload queue {:8.0}", a.gather_payload_q_um2);

    let e = RouterEnergy::forty_five_nm();
    println!("\nper-event energies (pJ): buf wr {:.2} / rd {:.2}, xbar {:.2}, arb {:.2}, link {:.2}",
        e.buffer_write_j * 1e12, e.buffer_read_j * 1e12, e.crossbar_j * 1e12,
        e.arbiter_j * 1e12, e.link_j * 1e12);

    assert!((r.power_overhead_pct - 6.0).abs() < 2.0, "power overhead out of band");
    assert!((r.area_overhead_pct - 4.0).abs() < 1.0, "area overhead out of band");

    let t = time_it(100, || overhead_report(1.0e9));
    println!("\nbench: overhead roll-up {t}");
}
