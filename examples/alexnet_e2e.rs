//! End-to-end driver: AlexNet inference on the full system.
//!
//! This is the repo's headline validation (DESIGN.md / EXPERIMENTS.md):
//! it exercises every layer of the stack on one workload and proves they
//! compose —
//!
//! * **Numerics** (L1 Pallas kernel → L2 JAX model → HLO artifact → PJRT
//!   from rust): the AlexNet-lite conv stack is executed layer by layer
//!   with real tensors, each layer checked against the in-tree reference
//!   convolution, activations chained through a stand-in for pooling.
//!   Requires the AOT artifacts (`make artifacts`); skipped with a loud
//!   note when they are absent, so the timing path still runs in CI.
//! * **Timing/power** (L3 cycle-accurate NoC): the full-size AlexNet
//!   model runs through the network executor on the 8×8 and 16×16
//!   meshes — uniform repetitive-unicast vs uniform gather plans (two-way
//!   streaming), reproducing the paper's headline comparison (Fig. 15) —
//!   plus the per-layer `best` plan, showing what per-layer policy
//!   selection buys over the best uniform plan.
//! * **Bookkeeping**: when the numeric path ran, the gather payload
//!   accounting is cross-checked — every output activation the numeric
//!   path produced corresponds to exactly one gather payload slot in the
//!   OS mapping.
//!
//! Run: `[make artifacts &&] cargo run --release --example alexnet_e2e`

use noc_dnn::config::SimConfig;
use noc_dnn::coordinator::executor::{best_plan_search, NetworkExecutor, PlanSearchOptions};
use noc_dnn::coordinator::experiment::{latency_improvement, power_improvement};
use noc_dnn::coordinator::report::table;
use noc_dnn::dataflow::os::OsMapping;
use noc_dnn::models::{lite, Network};
use noc_dnn::plan::{LayerPolicy, NetworkPlan};
use noc_dnn::runtime::layer_exec::LayerExecutor;
use noc_dnn::runtime::{max_abs_diff, reference, Tensor};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();

    // ------------------------------------------------------------------
    // 1) Numeric inference through the PJRT artifacts (AlexNet-lite).
    // ------------------------------------------------------------------
    let lite_layers = lite::alexnet_lite();
    let mut total_outputs = 0u64;
    if have_artifacts {
        println!("== numeric path: AlexNet-lite through PJRT artifacts ==");
        let mut exec = LayerExecutor::new(&artifacts)?;
        let mut rows = Vec::new();
        let mut activations = Tensor::random(vec![1, 3, 32, 32], 7);
        for (i, layer) in lite_layers.iter().enumerate() {
            // Chain: adapt the previous activations to this layer's input
            // shape (stand-in for the pooling/rescale between conv blocks).
            let input = adapt(&activations, layer.c, layer.h_in, 1000 + i as u64);
            let weights =
                Tensor::random(vec![layer.q, layer.c, layer.r, layer.r], 2000 + i as u64);
            let t0 = std::time::Instant::now();
            let out = exec.forward(layer, &input, &weights)?;
            let dt = t0.elapsed();
            let oracle = reference::conv2d(&input, &weights, layer.stride, layer.pad);
            let scale = oracle.data.iter().fold(1e-6f32, |m, v| m.max(v.abs()));
            let diff = max_abs_diff(&out.data, &oracle.data) / scale;
            anyhow::ensure!(diff < 1e-3, "layer {} numerics diverged: rel {diff}", layer.name);
            total_outputs += out.len() as u64;
            rows.push(vec![
                layer.name.to_string(),
                format!("{:?}", input.shape),
                format!("{:?}", out.shape),
                format!("{diff:.1e}"),
                format!("{:.1}ms", dt.as_secs_f64() * 1e3),
            ]);
            // ReLU + normalize (keeps chained magnitudes bounded, as the
            // pooling/normalization layers between conv blocks would).
            let peak = out.data.iter().fold(1e-6f32, |m, v| m.max(v.abs()));
            activations = Tensor::new(
                out.shape.clone(),
                out.data.iter().map(|v| v.max(0.0) / peak).collect(),
            );
        }
        print!("{}", table(&["layer", "input", "output", "max|d| vs ref", "exec"], &rows));
        println!("all {} lite layers match the reference conv\n", lite_layers.len());
    } else {
        println!(
            "== numeric path SKIPPED: artifacts not built (run `make artifacts`) ==\n"
        );
    }

    // ------------------------------------------------------------------
    // 2) Cycle-accurate NoC execution of full-size AlexNet (Fig. 15),
    //    whole model through the network executor.
    // ------------------------------------------------------------------
    println!("== timing path: full-size AlexNet on the mesh NoC (gather vs RU) ==");
    let model = Network::alexnet();
    let uniform = |collection| {
        let mut p = LayerPolicy::proposed();
        p.collection = collection;
        NetworkPlan::uniform(p, model.len())
    };
    for mesh in [8usize, 16] {
        let mut rows = Vec::new();
        let mut tot_g = 0u64;
        let mut tot_ru = 0u64;
        let mut tot_ge = 0.0f64;
        let mut tot_re = 0.0f64;
        for n in [1usize, 2, 4, 8] {
            let mut cfg = SimConfig::table1(mesh, n);
            cfg.trace_driven = true; // paper's trace methodology (§5.1)
            let ex = NetworkExecutor::new(cfg).without_reload();
            let g = ex.run(&model, &uniform(noc_dnn::config::Collection::Gather))?;
            let ru =
                ex.run(&model, &uniform(noc_dnn::config::Collection::RepetitiveUnicast))?;
            for (gl, rl) in g.layers.iter().zip(&ru.layers) {
                if n == 4 {
                    tot_g += gl.total_cycles;
                    tot_ru += rl.total_cycles;
                    tot_ge += gl.report.power.total_j;
                    tot_re += rl.report.power.total_j;
                }
                rows.push(vec![
                    gl.report.layer.clone(),
                    n.to_string(),
                    gl.report.run.rounds_total.to_string(),
                    rl.total_cycles.to_string(),
                    gl.total_cycles.to_string(),
                    format!("{:.2}", latency_improvement(&rl.report, &gl.report)),
                    format!("{:.2}", power_improvement(&rl.report, &gl.report)),
                ]);
            }
        }
        println!("-- {mesh}x{mesh} mesh --");
        print!(
            "{}",
            table(
                &["layer", "n", "rounds", "RU cycles", "gather cycles", "lat impr", "pow impr"],
                &rows
            )
        );
        println!(
            "total (n=4): RU {tot_ru} cycles / gather {tot_g} cycles = {:.2}x latency, {:.2}x energy\n",
            tot_ru as f64 / tot_g as f64,
            tot_re / tot_ge,
        );
    }

    // ------------------------------------------------------------------
    // 3) Per-layer policy selection: the `best` plan vs the proposed
    //    uniform plan, full round timing with inter-layer accounting.
    // ------------------------------------------------------------------
    println!("== per-layer policy selection: best plan vs uniform (8x8, n=4) ==");
    let cfg = SimConfig::table1_8x8(4);
    let ex = NetworkExecutor::new(cfg.clone());
    let search = best_plan_search(&cfg, &model, &PlanSearchOptions::default());
    let best_run = search.run_report(&cfg, &model);
    let unif_run = ex.run(&model, &NetworkPlan::uniform(LayerPolicy::proposed(), model.len()))?;
    print!("{}", noc_dnn::coordinator::report::network_run_text(&best_run));
    anyhow::ensure!(
        best_run.total_cycles <= unif_run.total_cycles,
        "best plan ({}) must not lose to the uniform proposed plan ({})",
        best_run.total_cycles,
        unif_run.total_cycles
    );
    println!(
        "best plan: {} cycles vs uniform two-way/gather/os: {} cycles ({:.3}x)\n",
        best_run.total_cycles,
        unif_run.total_cycles,
        unif_run.total_cycles as f64 / best_run.total_cycles as f64
    );

    // ------------------------------------------------------------------
    // 4) Gather payload bookkeeping ties the two paths together.
    // ------------------------------------------------------------------
    if have_artifacts {
        let cfg = SimConfig::table1_8x8(1);
        let mut mapped = 0u64;
        for layer in &lite_layers {
            mapped += OsMapping::new(&cfg, layer).useful_outputs(layer);
        }
        anyhow::ensure!(
            mapped == total_outputs,
            "gather payload accounting mismatch: OS mapping says {mapped}, numeric path produced {total_outputs}"
        );
        println!(
            "bookkeeping: {total_outputs} output activations == {mapped} gather payload slots (1:1)"
        );
    }
    println!("alexnet_e2e OK");
    Ok(())
}

/// Adapt an activation tensor to the next layer's expected input shape
/// (channel fold + nearest-neighbour resample; stands in for pooling).
fn adapt(t: &Tensor, c: usize, h: usize, seed: u64) -> Tensor {
    if t.shape == vec![1, c, h, h] {
        return t.clone();
    }
    let (tc, th) = (t.shape[1], t.shape[2]);
    let mut out = Tensor::zeros(vec![1, c, h, h]);
    // nearest-neighbour spatial resample, channel wrap
    for oc in 0..c {
        for oy in 0..h {
            for ox in 0..h {
                let iy = oy * th / h;
                let ix = ox * th / h;
                let ic = oc % tc;
                out.data[(oc * h + oy) * h + ox] = t.data[(ic * th + iy) * th + ix];
            }
        }
    }
    // tiny deterministic jitter so layers do not see degenerate repeats
    let mut rng = noc_dnn::util::rng::Rng::new(seed);
    for v in out.data.iter_mut() {
        *v += (rng.unit() as f32 - 0.5) * 1e-3;
    }
    out
}
