//! End-to-end driver: AlexNet inference on the full system.
//!
//! This is the repo's headline validation (DESIGN.md / EXPERIMENTS.md):
//! it exercises every layer of the stack on one workload and proves they
//! compose —
//!
//! * **Numerics** (L1 Pallas kernel → L2 JAX model → HLO artifact → PJRT
//!   from rust): the AlexNet-lite conv stack is executed layer by layer
//!   with real tensors, each layer checked against the in-tree reference
//!   convolution, activations chained through a stand-in for pooling.
//! * **Timing/power** (L3 cycle-accurate NoC): every *full-size* AlexNet
//!   conv layer is simulated on the 8×8 and 16×16 meshes under repetitive
//!   unicast and gather collection (two-way streaming), reproducing the
//!   paper's headline comparison (Fig. 15) and reporting the layer-wise
//!   and total improvements.
//! * **Bookkeeping**: the gather payload accounting is cross-checked —
//!   every output activation the numeric path produced corresponds to
//!   exactly one gather payload slot in the OS mapping.
//!
//! Run: `make artifacts && cargo run --release --example alexnet_e2e`

use noc_dnn::config::SimConfig;
use noc_dnn::coordinator::experiment::{latency_improvement, power_improvement, Experiment};
use noc_dnn::coordinator::report::table;
use noc_dnn::dataflow::os::OsMapping;
use noc_dnn::models::{alexnet, lite};
use noc_dnn::runtime::layer_exec::LayerExecutor;
use noc_dnn::runtime::{max_abs_diff, reference, Tensor};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );

    // ------------------------------------------------------------------
    // 1) Numeric inference through the PJRT artifacts (AlexNet-lite).
    // ------------------------------------------------------------------
    println!("== numeric path: AlexNet-lite through PJRT artifacts ==");
    let mut exec = LayerExecutor::new(&artifacts)?;
    let lite_layers = lite::alexnet_lite();
    let mut rows = Vec::new();
    let mut activations = Tensor::random(vec![1, 3, 32, 32], 7);
    let mut total_outputs = 0u64;
    for (i, layer) in lite_layers.iter().enumerate() {
        // Chain: adapt the previous activations to this layer's input
        // shape (stand-in for the pooling/rescale between conv blocks).
        let input = adapt(&activations, layer.c, layer.h_in, 1000 + i as u64);
        let weights =
            Tensor::random(vec![layer.q, layer.c, layer.r, layer.r], 2000 + i as u64);
        let t0 = std::time::Instant::now();
        let out = exec.forward(layer, &input, &weights)?;
        let dt = t0.elapsed();
        let oracle = reference::conv2d(&input, &weights, layer.stride, layer.pad);
        let scale = oracle.data.iter().fold(1e-6f32, |m, v| m.max(v.abs()));
        let diff = max_abs_diff(&out.data, &oracle.data) / scale;
        anyhow::ensure!(diff < 1e-3, "layer {} numerics diverged: rel {diff}", layer.name);
        total_outputs += out.len() as u64;
        rows.push(vec![
            layer.name.to_string(),
            format!("{:?}", input.shape),
            format!("{:?}", out.shape),
            format!("{diff:.1e}"),
            format!("{:.1}ms", dt.as_secs_f64() * 1e3),
        ]);
        // ReLU + normalize (keeps chained magnitudes bounded, as the
        // pooling/normalization layers between conv blocks would).
        let peak = out.data.iter().fold(1e-6f32, |m, v| m.max(v.abs()));
        activations = Tensor::new(
            out.shape.clone(),
            out.data.iter().map(|v| v.max(0.0) / peak).collect(),
        );
    }
    print!("{}", table(&["layer", "input", "output", "max|d| vs ref", "exec"], &rows));
    println!("all {} lite layers match the reference conv\n", lite_layers.len());

    // ------------------------------------------------------------------
    // 2) Cycle-accurate NoC simulation of full-size AlexNet (Fig. 15).
    // ------------------------------------------------------------------
    println!("== timing path: full-size AlexNet on the mesh NoC (gather vs RU) ==");
    let full_layers = alexnet::conv_layers();
    for mesh in [8usize, 16] {
        let mut rows = Vec::new();
        let mut tot_g = 0u64;
        let mut tot_ru = 0u64;
        let mut tot_ge = 0.0f64;
        let mut tot_re = 0.0f64;
        for n in [1usize, 2, 4, 8] {
            let mut cfg = SimConfig::table1(mesh, n);
            cfg.trace_driven = true; // paper's trace methodology (§5.1)
            for layer in &full_layers {
                let g = Experiment::proposed(cfg.clone()).run_layer(layer);
                let ru = Experiment::baseline_ru(cfg.clone()).run_layer(layer);
                if n == 4 {
                    tot_g += g.run.total_cycles;
                    tot_ru += ru.run.total_cycles;
                    tot_ge += g.power.total_j;
                    tot_re += ru.power.total_j;
                }
                rows.push(vec![
                    layer.name.to_string(),
                    n.to_string(),
                    g.run.rounds_total.to_string(),
                    ru.run.total_cycles.to_string(),
                    g.run.total_cycles.to_string(),
                    format!("{:.2}", latency_improvement(&ru, &g)),
                    format!("{:.2}", power_improvement(&ru, &g)),
                ]);
            }
        }
        println!("-- {mesh}x{mesh} mesh --");
        print!(
            "{}",
            table(
                &["layer", "n", "rounds", "RU cycles", "gather cycles", "lat impr", "pow impr"],
                &rows
            )
        );
        println!(
            "total (n=4): RU {tot_ru} cycles / gather {tot_g} cycles = {:.2}x latency, {:.2}x energy\n",
            tot_ru as f64 / tot_g as f64,
            tot_re / tot_ge,
        );
    }

    // ------------------------------------------------------------------
    // 3) Gather payload bookkeeping ties the two paths together.
    // ------------------------------------------------------------------
    let cfg = SimConfig::table1_8x8(1);
    let mut mapped = 0u64;
    for layer in &lite_layers {
        mapped += OsMapping::new(&cfg, layer).useful_outputs(layer);
    }
    anyhow::ensure!(
        mapped == total_outputs,
        "gather payload accounting mismatch: OS mapping says {mapped}, numeric path produced {total_outputs}"
    );
    println!(
        "bookkeeping: {total_outputs} output activations == {mapped} gather payload slots (1:1)"
    );
    println!("alexnet_e2e OK");
    Ok(())
}

/// Adapt an activation tensor to the next layer's expected input shape
/// (channel fold + nearest-neighbour resample; stands in for pooling).
fn adapt(t: &Tensor, c: usize, h: usize, seed: u64) -> Tensor {
    if t.shape == vec![1, c, h, h] {
        return t.clone();
    }
    let (tc, th) = (t.shape[1], t.shape[2]);
    let mut out = Tensor::zeros(vec![1, c, h, h]);
    // nearest-neighbour spatial resample, channel wrap
    for oc in 0..c {
        for oy in 0..h {
            for ox in 0..h {
                let iy = oy * th / h;
                let ix = ox * th / h;
                let ic = oc % tc;
                out.data[(oc * h + oy) * h + ox] = t.data[(ic * th + iy) * th + ix];
            }
        }
    }
    // tiny deterministic jitter so layers do not see degenerate repeats
    let mut rng = noc_dnn::util::rng::Rng::new(seed);
    for v in out.data.iter_mut() {
        *v += (rng.unit() as f32 - 0.5) * 1e-3;
    }
    out
}
