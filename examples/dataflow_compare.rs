//! Output-Stationary vs Weight-Stationary, end to end.
//!
//! Runs AlexNet (default) or VGG-16 through the cycle-accurate simulator
//! under both dataflows, for every streaming architecture × collection
//! scheme pairing (repetitive unicast vs gather vs in-network
//! accumulation — a 9-row grid), then drills into one representative
//! layer to show *why* the totals differ: per-round stream words,
//! payloads per node, round counts and the WS weight-pinning setup cost.
//!
//! Run: `cargo run --release --example dataflow_compare [-- --model vgg16]`

use noc_dnn::config::{DataflowKind, SimConfig, Streaming};
use noc_dnn::coordinator::report::{dataflow_compare_text, table};
use noc_dnn::coordinator::sweep::dataflow_compare;
use noc_dnn::dataflow::{Dataflow, OsMapping, WsMapping};
use noc_dnn::models::{alexnet, vgg16};
use noc_dnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["model", "mesh", "n"], &[])?;
    let model = args.get("model").unwrap_or("alexnet");
    let mesh: usize = args.get_parsed("mesh", 8)?;
    let n: usize = args.get_parsed("n", 4)?;
    let layers = match model {
        "alexnet" => alexnet::conv_layers(),
        "vgg16" => vgg16::conv_layers(),
        m => anyhow::bail!("unknown model '{m}' (alexnet | vgg16)"),
    };

    println!("== {model} on {mesh}x{mesh}, n={n}: OS vs WS across the architecture grid ==");
    let rows = dataflow_compare(mesh, n, &layers);
    print!("{}", dataflow_compare_text(&rows));

    // ---- why: per-layer mapping anatomy under the two dataflows ----
    println!("\n== mapping anatomy (two-way streaming, per layer) ==");
    let cfg = SimConfig::table1(mesh, n);
    let anatomy: Vec<Vec<String>> = layers
        .iter()
        .map(|layer| {
            let os = OsMapping::new(&cfg, layer);
            let ws = WsMapping::new(&cfg, layer);
            let os_row = os.stream_words().row;
            let ws_row = ws.stream_words().row;
            vec![
                layer.name.to_string(),
                os.rounds.to_string(),
                ws.rounds.to_string(),
                os_row.to_string(),
                ws_row.to_string(),
                ws.waves.to_string(),
                ws.setup_cycles(&cfg, Streaming::TwoWay).to_string(),
                ws.spread.to_string(),
            ]
        })
        .collect();
    print!(
        "{}",
        table(
            &[
                "layer",
                "OS rounds",
                "WS rounds",
                "OS row w/rnd",
                "WS row w/rnd",
                "WS waves",
                "WS setup cyc",
                "WS spread"
            ],
            &anatomy
        )
    );
    println!(
        "\nWS broadcasts one patch per round (row words independent of n = {n}); \
         OS streams {n} patch sets per router. WS pays instead at wave \
         boundaries (weight pinning) and when a filter exceeds the \
         {}-word register file (spread > 1 → NI accumulation).",
        cfg.ws_rf_words
    );

    // ---- sanity: the config-driven path agrees with the study ----
    let mut ws_cfg = SimConfig::table1(mesh, n);
    ws_cfg.dataflow = DataflowKind::WeightStationary;
    ws_cfg.validate()?;
    println!("\nconfig JSON with WS selected round-trips: {}", {
        let back = SimConfig::from_json(&ws_cfg.to_json())?;
        assert_eq!(back, ws_cfg);
        "ok"
    });
    Ok(())
}
