//! δ-timeout exploration beyond Fig. 12: fine-grained sweep, both mesh
//! sizes, plus the fault-tolerance angle the paper raises in §4.1 — a
//! large δ bounds how long a node waits when an expected gather packet
//! never arrives.
//!
//! Run: `cargo run --release --example delta_sweep [-- --mesh 8]`

use noc_dnn::config::{Collection, SimConfig};
use noc_dnn::coordinator::report::table;
use noc_dnn::coordinator::sweep::single_row_collection;
use noc_dnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["mesh"], &[])?;
    let mesh: usize = args.get_parsed("mesh", 8)?;

    for n in [1usize, 2, 4, 8] {
        println!("== {mesh}x{mesh} mesh, {n} PE(s)/router ==");
        let mut rows = Vec::new();
        let mut best: Option<(u64, u64)> = None;
        for factor in 0..=14u64 {
            let mut cfg = SimConfig::table1(mesh, n);
            cfg.delta = factor * cfg.kappa();
            let (lat, stats) = single_row_collection(&cfg, Collection::Gather);
            if best.map_or(true, |(_, l)| lat < l) {
                best = Some((factor, lat));
            }
            rows.push(vec![
                format!("{factor}k"),
                cfg.delta.to_string(),
                lat.to_string(),
                stats.packets_injected.to_string(),
                stats.gather_boards.to_string(),
                stats.delta_expiries.to_string(),
                stats.flit_hops.to_string(),
            ]);
        }
        print!(
            "{}",
            table(
                &["d/k", "d(cyc)", "latency", "packets", "boards", "expiries", "flit-hops"],
                &rows
            )
        );
        let (f, l) = best.unwrap();
        println!("first-best: d = {f}k ({l} cycles)");
        // §5.2: for an NxN mesh δ should let the leftmost header reach all
        // nodes; with the explicit link cycle that is (N-1)(κ+1)+κ.
        let cfg = SimConfig::table1(mesh, n);
        println!(
            "table-1 default d = {} cycles (= (N-1)(k+link)+k)\n",
            cfg.delta
        );
    }
    Ok(())
}
