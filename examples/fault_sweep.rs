//! Link-fault-rate sweep: what does each collection scheme lose when the
//! fabric degrades?
//!
//! AlexNet conv3 on the 8×8 mesh (two-way buses, OS dataflow) under a
//! seed-derived fault plan whose permanent-link-fault rate sweeps from 0
//! to 5%, with a constant trickle of flit corruption. Per collection
//! scheme (repetitive unicast / gather / in-network accumulation) the
//! table reports extrapolated layer latency against the fault-free
//! baseline and the degradation ledger of the measured prefix: the
//! fraction of result payloads lost (census exclusions + retry-exhausted
//! packets), detour hops taken by the fault-aware routes, and the
//! retransmission traffic the corruption trickle cost.
//!
//! Run: `cargo run --release --example fault_sweep`

use noc_dnn::config::{Collection, SimConfig, Streaming};
use noc_dnn::coordinator::report::table;
use noc_dnn::dataflow::{build, run_layer};
use noc_dnn::models::{alexnet, ConvLayer};
use noc_dnn::noc::FaultsConfig;

/// Simulate conv3 under one fault spec; returns the run plus the number
/// of result payloads the measured prefix posted (the denominator for
/// the dropped fraction — degradation counters are prefix-only).
fn run_point(
    layer: &ConvLayer,
    collection: Collection,
    spec: Option<&str>,
) -> anyhow::Result<(noc_dnn::dataflow::LayerRunResult, u64)> {
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.sim_rounds_cap = 4;
    if let Some(s) = spec {
        cfg.faults = Some(FaultsConfig::parse(s)?);
    }
    cfg.validate()?;
    let run = run_layer(&cfg, Streaming::TwoWay, collection, layer);
    let per_round = build(&cfg, layer).traffic_per_round(&cfg).payloads;
    let posted = per_round * run.simulated_rounds;
    Ok((run, posted))
}

fn main() -> anyhow::Result<()> {
    let layers = alexnet::conv_layers();
    let layer = layers
        .iter()
        .find(|l| l.name == "conv3")
        .expect("alexnet defines conv3");

    let rates = [0.0f64, 0.005, 0.01, 0.02, 0.05];
    for collection in
        [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
    {
        println!("== {collection:?}: AlexNet conv3, 8x8 mesh, two-way buses ==");
        let (clean, _) = run_point(layer, collection, None)?;
        let mut rows = Vec::new();
        for &rate in &rates {
            let spec =
                format!("seed=7,rate={rate},corrupt=0.001,retries=4,holdoff=8");
            let (run, posted) = run_point(layer, collection, Some(spec.as_str()))?;
            let d = run.degraded.expect("faults configured, report present");
            let dropped_frac = d.payloads_dropped as f64 / posted.max(1) as f64;
            rows.push(vec![
                format!("{:.1}%", rate * 100.0),
                run.total_cycles.to_string(),
                format!("{:.3}x", run.total_cycles as f64 / clean.total_cycles as f64),
                format!("{:.2}%", dropped_frac * 100.0),
                d.missing_contributors.to_string(),
                d.detour_hops.to_string(),
                d.retransmissions.to_string(),
                d.retries_exhausted.to_string(),
            ]);
        }
        print!(
            "{}",
            table(
                &[
                    "link faults",
                    "latency",
                    "vs clean",
                    "payloads lost",
                    "missing",
                    "detours",
                    "retx",
                    "exhausted",
                ],
                &rows
            )
        );
        println!("clean baseline: {} cycles\n", clean.total_cycles);
    }
    println!(
        "payload loss is the measured-prefix fraction (census exclusions + \
         retry-exhausted packets); latency is extrapolated to the full layer."
    );
    Ok(())
}
