//! Quickstart: the whole stack in ~60 lines.
//!
//! 1. Load the AOT-compiled conv artifact (L1 Pallas kernel inside the L2
//!    JAX model, lowered to HLO text) and execute it through PJRT.
//! 2. Verify the numerics against the in-tree reference convolution.
//! 3. Simulate the same layer on the 8×8 mesh NoC with gather support and
//!    with repetitive unicast, and print the improvement.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use noc_dnn::coordinator::experiment::{latency_improvement, power_improvement};
use noc_dnn::models::lite;
use noc_dnn::prelude::*;
use noc_dnn::runtime::layer_exec::LayerExecutor;
use noc_dnn::runtime::{max_abs_diff, reference, Tensor};

fn main() -> anyhow::Result<()> {
    let layer = lite::quickstart_layer();

    // --- numeric path: artifact through PJRT vs rust reference ---
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );
    let mut exec = LayerExecutor::new(&artifacts)?;
    let input = Tensor::random(vec![1, layer.c, layer.h_in, layer.h_in], 42);
    let weights = Tensor::random(vec![layer.q, layer.c, layer.r, layer.r], 43);
    let out = exec.forward(&layer, &input, &weights)?;
    let oracle = reference::conv2d(&input, &weights, layer.stride, layer.pad);
    let diff = max_abs_diff(&out.data, &oracle.data);
    println!(
        "numerics: conv {}x{}x{} -> {:?} via PJRT, max|delta| vs reference = {diff:.2e}",
        layer.c, layer.h_in, layer.h_in, out.shape
    );
    anyhow::ensure!(diff < 1e-3, "numeric mismatch");

    // --- timing path: cycle-accurate NoC simulation, gather vs RU ---
    // The typed façade: one builder per scenario, every invalid input a
    // ConfigError (swap .topology(TopologyKind::Torus) in to change the
    // fabric).
    let base = ScenarioBuilder::new().mesh(8).pes_per_router(4).trace_driven(true);
    let gather = base.build()?.simulate(&layer);
    let ru = ScenarioBuilder::new()
        .mesh(8)
        .pes_per_router(4)
        .trace_driven(true)
        .collection(Collection::RepetitiveUnicast)
        .build()?
        .simulate(&layer);
    println!("timing:  {} rounds on 8x8 mesh (4 PEs/router)", gather.run.rounds_total);
    println!(
        "         gather: {} cycles, {:.3} uJ   RU: {} cycles, {:.3} uJ",
        gather.run.total_cycles,
        gather.power.total_j * 1e6,
        ru.run.total_cycles,
        ru.power.total_j * 1e6
    );
    println!(
        "         improvement: {:.2}x latency, {:.2}x network power",
        latency_improvement(&ru, &gather),
        power_improvement(&ru, &gather)
    );
    println!("quickstart OK");
    Ok(())
}
