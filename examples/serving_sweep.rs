//! Arrival-rate sweep: where is the serving knee, and what does p99 do
//! past it?
//!
//! AlexNet on the 8×8 mesh (two-way buses, OS dataflow), profiled once
//! per collection scheme (repetitive unicast / gather / in-network
//! accumulation) with the link probes on, then served under a seeded
//! Poisson arrival process at rates placed around each profile's
//! serial-fabric capacity. Per collection the table reports offered vs
//! rejected load, sustained throughput, p50/p99 tail latency and fabric
//! utilization, with the saturation knee marked — the last rate with
//! zero rejections and p99 within 5× of the lowest rate's. The better a
//! collection scheme moves the many-to-one traffic, the shorter its
//! pass, the further right its knee sits.
//!
//! Run: `cargo run --release --example serving_sweep`

use noc_dnn::config::{Collection, SimConfig, Streaming};
use noc_dnn::coordinator::executor::NetworkExecutor;
use noc_dnn::coordinator::report::table;
use noc_dnn::models::Network;
use noc_dnn::plan::{LayerPolicy, NetworkPlan};
use noc_dnn::serving::{sweep, ArrivalKind, ServiceProfile, ServingConfig, KNEE_BLOWUP};

/// Profile the whole model under one collection scheme, probes on, so
/// the sweep can attribute the link that saturates first.
fn profile_for(model: &Network, collection: Collection) -> anyhow::Result<ServiceProfile> {
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.sim_rounds_cap = 4;
    cfg.collection = collection;
    cfg.probes = true;
    cfg.validate()?;
    let plan = NetworkPlan::uniform(
        LayerPolicy {
            streaming: Streaming::TwoWay,
            collection,
            dataflow: cfg.dataflow,
        },
        model.len(),
    );
    let run = NetworkExecutor::new(cfg).run(model, &plan)?;
    Ok(ServiceProfile::from_run(&run))
}

fn main() -> anyhow::Result<()> {
    let model = Network::alexnet();
    let base = ServingConfig {
        arrival: ArrivalKind::Poisson,
        batch: 4,
        queue_cap: 32,
        max_inflight: 2,
        seed: 7,
        ..ServingConfig::default()
    };
    // The same load points relative to each profile's own capacity, so
    // the three schemes are compared at equal stress, not equal rate.
    let fractions = [0.25, 0.5, 0.75, 0.9, 1.1, 1.5];

    for collection in
        [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
    {
        let profile = profile_for(&model, collection)?;
        let capacity = profile.capacity_per_mcycle(base.batch as u64);
        println!(
            "== {collection:?}: AlexNet serving on 8x8 mesh, two-way buses, \
             batch<={} — capacity ~{capacity:.3} req/Mcycle ==",
            base.batch
        );
        let rates: Vec<f64> = fractions.iter().map(|f| f * capacity).collect();
        let sw = sweep(&profile, &base, &rates)?;
        let rows: Vec<Vec<String>> = sw
            .points
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let r = &p.report;
                vec![
                    format!("{:.0}%", fractions[i] * 100.0),
                    format!("{:.3}", p.rate),
                    r.offered.to_string(),
                    r.rejected.to_string(),
                    format!("{:.3}", r.throughput_per_mcycle),
                    r.p50().to_string(),
                    r.p99().to_string(),
                    format!("{:.1}%", r.utilization * 100.0),
                    if sw.knee == Some(i) { "<- knee".into() } else { String::new() },
                ]
            })
            .collect();
        print!(
            "{}",
            table(
                &[
                    "load", "rate/Mcyc", "offered", "rejected", "tput/Mcyc", "p50",
                    "p99", "busy", ""
                ],
                &rows
            )
        );
        match sw.knee_rate() {
            Some(r) => println!("saturation knee at ~{r:.3} req/Mcycle"),
            None => println!("no pre-knee point: even the lowest rate saturates"),
        }
        if let Some(b) = profile.bottleneck() {
            println!(
                "link that saturates first: {} ({} stage, vc {}, util {:.2} in profile)\n",
                b.label(),
                b.stage.label(),
                b.vc,
                b.utilization
            );
        } else {
            println!();
        }
    }
    println!(
        "knee rule: last swept rate with zero rejections and p99 within \
         {KNEE_BLOWUP}x of the lowest rate's p99; latencies are in cycles."
    );
    Ok(())
}
