//! VGG-16 full-model study: the deepest workload in the paper's
//! evaluation, across mesh sizes, PEs/router and all three streaming
//! architectures — a superset of Figs. 14 and 16 for one model.
//!
//! Run: `cargo run --release --example vgg16_study [-- --fast]`

use noc_dnn::config::{Collection, SimConfig, Streaming};
use noc_dnn::coordinator::experiment::{latency_improvement, power_improvement, Experiment};
use noc_dnn::coordinator::report::table;
use noc_dnn::coordinator::server::{default_workers, parallel_map};
use noc_dnn::models::vgg16;
use noc_dnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[], &["fast"])?;
    let layers = vgg16::conv_layers();
    let layers = if args.get_bool("fast") { layers[..4].to_vec() } else { layers };

    // ---- gather vs RU across the (mesh, n) grid, whole model ----
    println!("== VGG-16 total: gather vs RU (two-way streaming, trace-driven) ==");
    let mut grid = Vec::new();
    for mesh in [8usize, 16] {
        for n in [1usize, 2, 4, 8] {
            grid.push((mesh, n));
        }
    }
    let layers_ref = &layers;
    let results = parallel_map(grid, default_workers(), |&(mesh, n)| {
        let mut cfg = SimConfig::table1(mesh, n);
        cfg.trace_driven = true;
        let mut tot = (0u64, 0u64, 0.0f64, 0.0f64);
        for layer in layers_ref {
            let g = Experiment::proposed(cfg.clone()).run_layer(layer);
            let ru = Experiment::baseline_ru(cfg.clone()).run_layer(layer);
            tot.0 += ru.run.total_cycles;
            tot.1 += g.run.total_cycles;
            tot.2 += ru.power.router_dynamic_j + ru.power.router_static_j;
            tot.3 += g.power.router_dynamic_j + g.power.router_static_j;
        }
        (mesh, n, tot)
    });
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(mesh, n, t)| {
            vec![
                format!("{mesh}x{mesh}"),
                n.to_string(),
                t.0.to_string(),
                t.1.to_string(),
                format!("{:.2}", t.0 as f64 / t.1 as f64),
                format!("{:.2}", t.2 / t.3),
            ]
        })
        .collect();
    print!(
        "{}",
        table(&["mesh", "n", "RU cycles", "gather cycles", "lat impr", "pow impr"], &rows)
    );

    // ---- streaming architecture comparison on one deep layer ----
    println!("\n== conv4_2: streaming architectures (n=1, full round timing) ==");
    let layer = layers.iter().find(|l| l.name == "conv4_2").unwrap_or(&layers[0]);
    let cfg = SimConfig::table1_8x8(1);
    let mesh_arch = Experiment::gather_only(cfg.clone()).run_layer(layer);
    let one = Experiment::new(cfg.clone(), Streaming::OneWay, Collection::Gather).run_layer(layer);
    let two = Experiment::proposed(cfg).run_layer(layer);
    let rows = vec![
        vec![
            "gather-only [27]".to_string(),
            mesh_arch.run.total_cycles.to_string(),
            "1.00".to_string(),
            format!("{:.3}", mesh_arch.power.total_j * 1e3),
        ],
        vec![
            "one-way bus".to_string(),
            one.run.total_cycles.to_string(),
            format!("{:.2}", latency_improvement(&mesh_arch, &one)),
            format!("{:.3}", one.power.total_j * 1e3),
        ],
        vec![
            "two-way bus".to_string(),
            two.run.total_cycles.to_string(),
            format!("{:.2}", latency_improvement(&mesh_arch, &two)),
            format!("{:.3}", two.power.total_j * 1e3),
        ],
    ];
    print!("{}", table(&["architecture", "cycles", "impr", "energy(mJ)"], &rows));
    let _ = power_improvement(&mesh_arch, &two);
    println!("vgg16_study OK");
    Ok(())
}
