"""AOT pipeline: lower the L2 conv model (with its L1 Pallas kernel) to
HLO **text** artifacts the rust runtime loads via PJRT.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the pinned xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts

Python runs exactly once, at build time; `make artifacts` is a no-op when
the artifacts are newer than the compile sources.
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import ConvSpec, all_artifact_specs, conv_forward


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: ConvSpec) -> str:
    """Lower one conv layer shape to HLO text."""
    fn = functools.partial(conv_forward, stride=spec.stride, pad=spec.pad)

    def entry(x, w):
        return (fn(x, w),)

    x = jax.ShapeDtypeStruct(spec.input_shape(), jax.numpy.float32)
    w = jax.ShapeDtypeStruct(spec.weight_shape(), jax.numpy.float32)
    lowered = jax.jit(entry).lower(x, w)
    return to_hlo_text(lowered)


def build_all(out_dir: pathlib.Path, specs: list[ConvSpec] | None = None) -> dict:
    """Lower every artifact spec; returns the manifest dict."""
    specs = specs if specs is not None else all_artifact_specs()
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"artifacts": []}
    for spec in specs:
        text = lower_spec(spec)
        path = out_dir / spec.artifact_name()
        path.write_text(text)
        manifest["artifacts"].append(
            {
                "name": spec.name,
                "file": spec.artifact_name(),
                "input_shape": list(spec.input_shape()),
                "weight_shape": list(spec.weight_shape()),
                "h_out": spec.h_out,
                "macs_per_output": spec.macs_per_output,
                "hlo_bytes": len(text),
            }
        )
        print(f"  wrote {path} ({len(text)} bytes)", file=sys.stderr)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    manifest = build_all(out_dir)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
