"""L1 — the Output-Stationary matmul Pallas kernel.

This is the MAC hot-spot of the accelerator expressed for the TPU memory
hierarchy. The OS dataflow of the paper (Fig. 4) keeps each PE's partial
sum stationary while input-activation and weight words stream past; the
Pallas translation keeps each **output tile** stationary in VMEM (the
analogue of the PE register file) while K-dimension slabs of the patch
matrix and the weight matrix stream HBM→VMEM under `BlockSpec` control —
the same schedule the paper implements with row/column streaming buses.

Hardware adaptation (DESIGN.md §Hardware-Adaptation):

* the paper's wire-level streaming becomes the `BlockSpec` index maps
  (grid dim 2 walks the K slabs = the paper's `C·R·R` operand stream);
* the per-PE 32-bit MAC becomes an MXU-shaped `jnp.dot` with f32
  accumulation (`preferred_element_type`);
* tiles default to 128×128×128 — MXU-aligned; pass smaller tiles for tiny
  problems (the wrapper pads every dimension to the tile grid).

`interpret=True` always: the CPU PJRT backend cannot run Mosaic
custom-calls; correctness is established against `ref.py` and real-TPU
performance is *estimated* from the VMEM footprint (see
`vmem_footprint_bytes` and DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile (f32). 3 tiles of 128x128xf32 = 192 KiB —
# comfortably inside a TensorCore's ~16 MiB VMEM even with double
# buffering.
DEFAULT_TILE = 128


def _grid_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: accumulate a_tile @ b_tile into o_tile.

    The output block index map ignores `k`, so the same VMEM tile is
    revisited across the K walk — *output stationary*. `k == 0` zeroes the
    accumulator (the PE reset at the start of a round).
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr = rows - x.shape[0]
    pc = cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k"))
def os_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    tile_k: int = DEFAULT_TILE,
) -> jax.Array:
    """`a [M, K] @ b [K, N] -> [M, N]` with the OS-dataflow Pallas kernel.

    `a` is the im2col patch matrix (one row per output position — the
    paper's `P` dimension), `b` is the transposed weight matrix (one
    column per filter — the paper's `Q` dimension). Inputs are padded to
    the tile grid and the result is sliced back.
    """
    assert a.ndim == 2 and b.ndim == 2, "os_matmul expects 2-D operands"
    assert a.shape[1] == b.shape[0], f"inner dims differ: {a.shape} @ {b.shape}"
    m, k = a.shape
    _, n = b.shape
    tile_m = min(tile_m, _ceil_to(m, 8))
    tile_n = min(tile_n, _ceil_to(n, 8))
    tile_k = min(tile_k, _ceil_to(k, 8))
    gm, gk, gn = _ceil_div(m, tile_m), _ceil_div(k, tile_k), _ceil_div(n, tile_n)
    a_p = _pad_to(a.astype(jnp.float32), gm * tile_m, gk * tile_k)
    b_p = _pad_to(b.astype(jnp.float32), gk * tile_k, gn * tile_n)

    out = pl.pallas_call(
        _grid_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            # Input patches stream along K for a fixed output row-tile —
            # the row streaming bus of Fig. 10(a).
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            # Weights stream along K for a fixed output column-tile — the
            # column streaming bus.
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        # Output tile index ignores kk: stationary accumulator.
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * tile_m, gn * tile_n), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _ceil_to(a: int, b: int) -> int:
    return _ceil_div(a, b) * b


def vmem_footprint_bytes(
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    tile_k: int = DEFAULT_TILE,
    *,
    double_buffered: bool = True,
) -> int:
    """Estimated VMEM residency of the kernel at the given tiling (f32).

    Streaming operands are double-buffered by the Pallas pipeline; the
    stationary accumulator is single-buffered. Used by the L1 perf report
    (EXPERIMENTS.md §Perf) since interpret-mode wall-clock is not a TPU
    proxy.
    """
    buf = 2 if double_buffered else 1
    stream = buf * (tile_m * tile_k + tile_k * tile_n) * 4
    acc = tile_m * tile_n * 4
    return stream + acc


def mxu_utilization_estimate(m: int, k: int, n: int, tile: int = DEFAULT_TILE) -> float:
    """Fraction of MXU work that is useful (non-padding) for a problem."""
    mm = _ceil_to(m, min(tile, _ceil_to(m, 8)))
    kk = _ceil_to(k, min(tile, _ceil_to(k, 8)))
    nn = _ceil_to(n, min(tile, _ceil_to(n, 8)))
    return (m * k * n) / float(mm * kk * nn)
