"""Pure-jnp correctness oracles for the L1 kernel and the L2 model.

These are the ground truth the Pallas kernel and the AOT artifacts are
tested against (pytest, build time) and that `rust/src/runtime/reference.rs`
mirrors on the rust side (run time).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain f32 matmul — the oracle for `os_matmul`."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int, pad: int) -> jax.Array:
    """NCHW/OIHW convolution oracle via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def im2col_ref(x: jax.Array, r: int, stride: int, pad: int) -> jax.Array:
    """Patch matrix `[P, C*R*R]` matching `reference.rs::im2col`.

    Row `p = oy*Wo + ox` holds the receptive field of output position
    (oy, ox), ordered (c, ky, kx) — the operand stream one PE row receives
    per round in the OS dataflow.
    """
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(r, r),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
    )  # [1, C*R*R, Ho, Wo], channel-major (c, ky, kx)
    _, k, ho, wo = patches.shape
    return patches.reshape(k, ho * wo).T
