"""L2 — the JAX model: convolution-layer forward in OS-dataflow form.

`conv_forward` is the compute graph the accelerator executes for one
layer: im2col the input (the row operand streams of Fig. 4), multiply by
the transposed filter bank (the column streams) with the L1 Pallas
OS-matmul kernel, and fold the `[P, Q]` result back to NCHW — each row of
the matmul output is exactly the set of partial sums one gather packet
round collects.

This module is build-time only: `aot.py` lowers `conv_forward` to HLO
text per layer shape, and the rust runtime executes the artifacts.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.os_matmul import os_matmul
from .kernels.ref import im2col_ref


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One convolution layer shape (mirrors rust `models::ConvLayer`)."""

    name: str
    c: int
    h_in: int
    r: int
    stride: int
    pad: int
    q: int

    @property
    def h_out(self) -> int:
        return (self.h_in + 2 * self.pad - self.r) // self.stride + 1

    @property
    def macs_per_output(self) -> int:
        return self.c * self.r * self.r

    def artifact_name(self) -> str:
        """Must match rust `runtime::layer_exec::artifact_name`."""
        return (
            f"conv_c{self.c}_h{self.h_in}_r{self.r}"
            f"_s{self.stride}_p{self.pad}_q{self.q}.hlo.txt"
        )

    def input_shape(self) -> tuple[int, int, int, int]:
        return (1, self.c, self.h_in, self.h_in)

    def weight_shape(self) -> tuple[int, int, int, int]:
        return (self.q, self.c, self.r, self.r)


def conv_forward(x: jax.Array, w: jax.Array, *, stride: int, pad: int) -> jax.Array:
    """OS-dataflow convolution: im2col × Wᵀ via the Pallas kernel.

    x: [1, C, H, H]; w: [Q, C, R, R] -> [1, Q, Ho, Wo].
    """
    n, c, h, _ = x.shape
    q, cw, r, _ = w.shape
    assert n == 1, "the accelerator model processes one image at a time"
    assert c == cw, f"channel mismatch: {c} vs {cw}"
    ho = (h + 2 * pad - r) // stride + 1
    patches = im2col_ref(x, r, stride, pad)  # [P, C*R*R]
    wt = w.reshape(q, c * r * r).T  # [C*R*R, Q]
    out = os_matmul(patches, wt)  # [P, Q]
    return out.T.reshape(1, q, ho, ho)


def quickstart_spec() -> ConvSpec:
    """The tiny layer used by examples/quickstart.rs."""
    return ConvSpec(name="quickstart", c=4, h_in=8, r=3, stride=1, pad=1, q=8)


def alexnet_lite_specs() -> list[ConvSpec]:
    """Downscaled AlexNet conv stack for the end-to-end example.

    Same layer topology (11/5/3/3/3 kernels, stride-4 stem) as torchvision
    AlexNet with H and channel counts reduced so interpret-mode Pallas
    stays tractable on CPU. The NoC *timing* simulation always uses the
    full-size shapes (it consumes shape parameters, not tensors); these
    lite shapes drive the *numeric* path through PJRT.
    """
    return [
        ConvSpec(name="lite1", c=3, h_in=32, r=11, stride=4, pad=2, q=16),
        ConvSpec(name="lite2", c=16, h_in=7, r=5, stride=1, pad=2, q=32),
        ConvSpec(name="lite3", c=32, h_in=7, r=3, stride=1, pad=1, q=64),
        ConvSpec(name="lite4", c=64, h_in=7, r=3, stride=1, pad=1, q=32),
        ConvSpec(name="lite5", c=32, h_in=7, r=3, stride=1, pad=1, q=32),
    ]


def all_artifact_specs() -> list[ConvSpec]:
    return [quickstart_spec(), *alexnet_lite_specs()]
