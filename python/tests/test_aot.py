"""AOT pipeline tests: lowering to HLO text and manifest integrity.

The HLO-text artifacts are the contract with the rust runtime; these tests
verify the text is parseable HLO with the expected entry signature and
that re-execution of the lowered computation (via jax) matches the oracle.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import build_all, lower_spec, to_hlo_text
from compile.kernels.ref import conv2d_ref
from compile.model import quickstart_spec

jax.config.update("jax_platform_name", "cpu")


def test_lowered_text_is_hlo(tmp_path):
    spec = quickstart_spec()
    text = lower_spec(spec)
    assert "HloModule" in text
    assert "f32[1,4,8,8]" in text, "entry must take the NCHW input"
    assert "f32[8,4,3,3]" in text, "entry must take the OIHW weights"
    # return_tuple=True: root is a tuple of one output
    assert "f32[1,8,8,8]" in text, "output activation shape"


def test_build_all_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    manifest = build_all(out, specs=[quickstart_spec()])
    assert (out / quickstart_spec().artifact_name()).exists()
    m = json.loads((out / "manifest.json").read_text())
    assert m == manifest
    entry = m["artifacts"][0]
    assert entry["input_shape"] == [1, 4, 8, 8]
    assert entry["h_out"] == 8
    assert entry["hlo_bytes"] > 1000


def test_roundtrip_numerics_via_hlo_text(tmp_path):
    """Compile the dumped HLO text with the local XLA client and compare
    numerics with the oracle — the same path the rust runtime takes."""
    from jax._src.lib import xla_client as xc

    spec = quickstart_spec()
    text = lower_spec(spec)
    # Parse the text back into a computation and run it on the CPU client.
    client = xc.make_cpu_client()
    comp = xc._xla.hlo_module_from_text(text)
    # xla_client offers no direct "compile hlo text" stable API across
    # versions; fall back to checking the rust side covers execution and
    # here just assert the text parses.
    assert comp is not None
    del client

    # Independently: the lowered jax function itself matches the oracle.
    x = jax.random.normal(jax.random.PRNGKey(0), spec.input_shape(), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), spec.weight_shape(), jnp.float32)
    from compile.model import conv_forward

    got = conv_forward(x, w, stride=spec.stride, pad=spec.pad)
    want = conv2d_ref(x, w, spec.stride, spec.pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_hlo_text_is_stable_across_lowerings():
    spec = quickstart_spec()
    a = lower_spec(spec)
    b = lower_spec(spec)
    assert a == b, "lowering must be deterministic for artifact caching"
