"""L1 kernel correctness: Pallas OS-matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes and tilings; every case asserts allclose against
ref.matmul_ref. This is the core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.os_matmul import (
    mxu_utilization_estimate,
    os_matmul,
    vmem_footprint_bytes,
)
from compile.kernels.ref import matmul_ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


def assert_matches_ref(m, k, n, seed=0, **tiles):
    a = rand((m, k), seed)
    b = rand((k, n), seed + 1)
    got = os_matmul(a, b, **tiles)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


class TestBasicShapes:
    def test_square(self):
        assert_matches_ref(32, 32, 32)

    def test_tile_exact(self):
        assert_matches_ref(128, 128, 128)

    def test_single_row(self):
        assert_matches_ref(1, 27, 8)

    def test_single_col(self):
        assert_matches_ref(17, 9, 1)

    def test_k_equals_one(self):
        assert_matches_ref(5, 1, 7)

    def test_wide(self):
        assert_matches_ref(8, 363, 64)  # AlexNet-conv1-like P-tile

    def test_tall(self):
        assert_matches_ref(3025 // 8, 27, 16)

    def test_non_divisible_everything(self):
        assert_matches_ref(33, 65, 17, tile_m=16, tile_n=16, tile_k=16)


class TestNumerics:
    def test_zeros(self):
        a = jnp.zeros((16, 16))
        b = jnp.zeros((16, 16))
        assert float(jnp.abs(os_matmul(a, b)).max()) == 0.0

    def test_identity(self):
        a = rand((24, 24), 3)
        got = os_matmul(a, jnp.eye(24))
        np.testing.assert_allclose(np.asarray(got), np.asarray(a), rtol=1e-6, atol=1e-6)

    def test_accumulation_order_stable(self):
        # Two different K tilings must agree (f32 accumulate in both).
        a = rand((16, 64), 5)
        b = rand((64, 16), 6)
        x = os_matmul(a, b, tile_k=16)
        y = os_matmul(a, b, tile_k=64)
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6)

    def test_dtype_is_f32(self):
        out = os_matmul(rand((8, 8), 1), rand((8, 8), 2))
        assert out.dtype == jnp.float32

    def test_rejects_mismatched_inner(self):
        with pytest.raises(AssertionError):
            os_matmul(rand((4, 5), 0), rand((6, 4), 1))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 96),
    n=st.integers(1, 80),
    tile=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_sweep(m, k, n, tile, seed):
    assert_matches_ref(m, k, n, seed=seed, tile_m=tile, tile_n=tile, tile_k=tile)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 64),
    n=st.integers(1, 40),
)
def test_hypothesis_dtype_sweep_bf16_inputs(m, k, n):
    # bf16 inputs must still accumulate in f32 (MXU semantics).
    a = rand((m, k), 11).astype(jnp.bfloat16)
    b = rand((k, n), 12).astype(jnp.bfloat16)
    got = os_matmul(a, b)
    want = matmul_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


class TestPerfModel:
    def test_vmem_footprint_fits_tensorcore(self):
        # Default tiling with double buffering must fit 16 MiB VMEM.
        assert vmem_footprint_bytes() < 16 * 1024 * 1024

    def test_vmem_footprint_formula(self):
        assert vmem_footprint_bytes(8, 8, 8, double_buffered=False) == 3 * 8 * 8 * 4

    def test_mxu_utilization_bounds(self):
        u = mxu_utilization_estimate(100, 100, 100)
        assert 0.0 < u <= 1.0
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
