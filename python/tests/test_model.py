"""L2 model correctness: OS-dataflow conv_forward vs the lax conv oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import conv2d_ref, im2col_ref
from compile.model import (
    ConvSpec,
    all_artifact_specs,
    alexnet_lite_specs,
    conv_forward,
    quickstart_spec,
)

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


def assert_conv_matches(c, h, r, stride, pad, q, seed=0):
    x = rand((1, c, h, h), seed)
    w = rand((q, c, r, r), seed + 1)
    got = conv_forward(x, w, stride=stride, pad=pad)
    want = conv2d_ref(x, w, stride, pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


class TestConvForward:
    def test_quickstart_shape(self):
        assert_conv_matches(4, 8, 3, 1, 1, 8)

    def test_strided_stem(self):
        # AlexNet-lite conv1: 11x11 stride 4 pad 2.
        assert_conv_matches(3, 32, 11, 4, 2, 16)

    def test_no_padding(self):
        assert_conv_matches(2, 9, 3, 1, 0, 4)

    def test_1x1_conv(self):
        assert_conv_matches(8, 6, 1, 1, 0, 12)

    def test_all_lite_layers(self):
        for spec in alexnet_lite_specs():
            assert_conv_matches(
                spec.c, spec.h_in, spec.r, spec.stride, spec.pad, spec.q, seed=42
            )


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 8),
    h=st.integers(4, 16),
    r=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 2),
    pad=st.integers(0, 2),
    q=st.integers(1, 12),
    seed=st.integers(0, 1000),
)
def test_hypothesis_conv_sweep(c, h, r, stride, pad, q, seed):
    if h + 2 * pad < r:
        return  # degenerate geometry
    assert_conv_matches(c, h, r, stride, pad, q, seed=seed)


class TestIm2col:
    def test_patch_matrix_shape(self):
        x = rand((1, 3, 8, 8), 0)
        p = im2col_ref(x, 3, 1, 1)
        assert p.shape == (64, 27)

    def test_patch_content_center(self):
        # With padding 0 and r=1, patches are just the pixels.
        x = rand((1, 2, 4, 4), 1)
        p = im2col_ref(x, 1, 1, 0)
        np.testing.assert_allclose(
            np.asarray(p), np.asarray(x.reshape(2, 16).T), rtol=1e-6
        )


class TestSpecs:
    def test_artifact_names_match_rust_convention(self):
        s = quickstart_spec()
        assert s.artifact_name() == "conv_c4_h8_r3_s1_p1_q8.hlo.txt"

    def test_h_out_geometry(self):
        s = ConvSpec("t", c=3, h_in=224, r=11, stride=4, pad=2, q=64)
        assert s.h_out == 55

    def test_all_specs_distinct_artifacts(self):
        names = [s.artifact_name() for s in all_artifact_specs()]
        assert len(names) == len(set(names))

    def test_lite_stack_is_five_layers(self):
        assert len(alexnet_lite_specs()) == 5
