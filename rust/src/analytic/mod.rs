//! Closed-form runtime latency of a convolution layer — Eqs. (3) and (4)
//! of the paper (§4.5), used to sanity-check the simulator in the
//! uncongested regime and to reproduce the paper's analysis of one-way vs
//! two-way streaming.
//!
//! The paper states both equations for the OS dataflow. Written against
//! the [`Dataflow`] interface they generalize to any mapping: the compute
//! term becomes `(stream + T_MAC) · rounds + setup`, and the collection
//! term depends only on the payloads each NI posts per round (`n` for OS,
//! `n/spread` for WS — see [`crate::dataflow::ws`]). The OS instantiation
//! is numerically identical to the paper's forms.
//!
//! Notation (paper → here):
//!
//! * `C·R·R` → `macs_per_pe` — operand words streamed per PE per round;
//! * `n` → [`Dataflow::psum_collection`] payloads per node
//!   (`cfg.pes_per_router` under OS);
//! * `f_l` → `cfg.bus_words_per_cycle` (halved effectively for one-way);
//! * `T_MAC` → `cfg.t_mac`;
//! * `κ` → `cfg.router_pipeline`; our model additionally charges the
//!   Table-1 link cycle explicitly, so the per-hop term is `κ + link`;
//! * `P/N · Q/M · 1/n` → `rounds` (with ceilings, see
//!   [`crate::dataflow::os::OsMapping`]);
//! * `L`, `L'`, `W` → unicast/gather packet flit counts;
//! * `η` → gather packet payload capacity;
//! * `Δ_R`, `Δ_G` → congestion terms, **zero here** — they are what the
//!   cycle-accurate simulation measures (§4.5: "We will evaluate the
//!   effects of Δ_R and Δ_G through simulations").

use crate::config::{Collection, SimConfig, Streaming};
use crate::dataflow::{build, Dataflow};
use crate::models::{ConvLayer, Network};
use crate::plan::{reload_cycles, LayerPolicy, NetworkPlan};

/// Zero-load compute term for any dataflow:
/// `(stream + T_MAC) · rounds + setup` — for OS exactly the
/// `(C·R·R·n/f_l + T_MAC) · rounds` of Eqs. (3)–(4) (OS has no setup
/// phase).
pub fn compute_cycles_for(
    cfg: &SimConfig,
    streaming: Streaming,
    mapping: &dyn Dataflow,
) -> u64 {
    // The closed forms only exist for the deterministic bus phase; mesh
    // operand delivery (and its contention) is what the simulator
    // measures — `Dataflow::stream_cycles` returns 0 there, which would
    // silently yield a wild underestimate.
    assert!(
        streaming != Streaming::Mesh,
        "mesh streaming latency is simulated, not closed-form (Eqs. 3-4 assume bus streaming)"
    );
    (mapping.stream_cycles(cfg, streaming) + cfg.t_mac) * mapping.rounds()
        + mapping.setup_cycles(cfg, streaming)
}

/// Zero-load compute term for the dataflow selected by `cfg.dataflow`.
pub fn compute_cycles(cfg: &SimConfig, streaming: Streaming, layer: &ConvLayer) -> u64 {
    compute_cycles_for(cfg, streaming, build(cfg, layer).as_ref())
}

/// Per-hop cycles of a head flit in our router model (κ + link).
fn per_hop(cfg: &SimConfig) -> u64 {
    cfg.router_pipeline + cfg.link_latency
}

/// The zero-load collection tail for a gather-supported row whose NIs
/// each post `ppn` payloads: the row needs `⌈M·ppn/η⌉` gather packets;
/// packet `i` starts `i·η/ppn` columns east of the initiator and
/// therefore travels `M − i·η/ppn` hops, each packet adding its own
/// serialization tail.
fn gather_collection_tail(cfg: &SimConfig, ppn: u64) -> u64 {
    let m = cfg.mesh_cols as u64;
    let eta = cfg.gather_capacity() as u64;
    let num_packets = (m * ppn).div_ceil(eta);
    let serialization = cfg.gather_packet_flits as u64 - 1;
    let mut collection = 0;
    for i in 0..num_packets {
        let hops = m.saturating_sub(i * eta / ppn);
        collection += hops * per_hop(cfg) + serialization;
    }
    collection
}

/// Eq. (3): repetitive-unicast layer latency, Δ_R = 0.
///
/// The head term is the *worst-placed* node's result packet (all nodes
/// transmit in parallel; the farthest-from-memory one dominates), plus
/// `⌈L/W⌉ − 1` for its remaining flits. On the paper's mesh the worst
/// node is the leftmost and the term is the `M·κ` of Eq. (3); the hop
/// count generalizes through
/// [`crate::noc::topology::Topology::worst_result_hops`] — a torus's
/// westbound wrap shortcut caps it near `M/2 + 1`, which is the fabric's
/// analytic RU win. The gather/INA forms below are topology-invariant:
/// their packets walk the full row on every fabric by construction.
pub fn latency_ru(cfg: &SimConfig, streaming: Streaming, layer: &ConvLayer) -> u64 {
    let hops = crate::noc::topology::worst_result_hops(cfg);
    let serialization = cfg.unicast_packet_flits as u64 - 1;
    compute_cycles(cfg, streaming, layer) + hops * per_hop(cfg) + serialization
}

/// Eq. (4): gather-supported layer latency, Δ_G = 0.
pub fn latency_gather(cfg: &SimConfig, streaming: Streaming, layer: &ConvLayer) -> u64 {
    let mapping = build(cfg, layer);
    let ppn = mapping.psum_collection().payloads_per_node as u64;
    compute_cycles_for(cfg, streaming, mapping.as_ref()) + gather_collection_tail(cfg, ppn)
}

/// Generalized Eq. (4) for in-network accumulation
/// ([`Collection::Ina`]): the initiator's packet travels the full row
/// (`M` hops) while transit folds and merges add zero latency, and its
/// serialization tail is the *small* INA packet
/// ([`Dataflow::ina_packet_flits`] − 1 body flits) instead of the
/// row-sized gather packet — INA's zero-load form is therefore the
/// leftmost-unicast form of Eq. (3) with the INA packet length.
pub fn latency_ina(cfg: &SimConfig, streaming: Streaming, layer: &ConvLayer) -> u64 {
    let mapping = build(cfg, layer);
    let serialization = mapping.ina_packet_flits(cfg) as u64 - 1;
    compute_cycles_for(cfg, streaming, mapping.as_ref())
        + cfg.mesh_cols as u64 * per_hop(cfg)
        + serialization
}

/// Zero-load latency for any (streaming, collection) pair under the
/// dataflow selected by `cfg.dataflow`.
pub fn latency(
    cfg: &SimConfig,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
) -> u64 {
    match collection {
        Collection::RepetitiveUnicast => latency_ru(cfg, streaming, layer),
        Collection::Gather => latency_gather(cfg, streaming, layer),
        Collection::Ina => latency_ina(cfg, streaming, layer),
    }
}

/// Zero-load latency of one layer under an explicit [`LayerPolicy`]
/// (the policy's dataflow/collection selectors applied to `cfg`). Bus
/// streaming policies only — mesh operand delivery has no closed form.
pub fn latency_policy(cfg: &SimConfig, policy: &LayerPolicy, layer: &ConvLayer) -> u64 {
    let lcfg = policy.apply(cfg);
    latency(&lcfg, policy.streaming, policy.collection, layer)
}

/// Model-scope generalization of Eqs. (3)/(4): the zero-load runtime of a
/// whole [`Network`] under a [`NetworkPlan`] is the sum over layers of
/// the per-layer closed form under that layer's policy **plus** the
/// inter-layer boundary charge ([`reload_cycles`]: layer ℓ's output
/// volume is layer ℓ+1's input traffic, refilled through the consuming
/// layer's streaming sources). This is exactly the accounting the
/// network executor applies to its simulated per-layer totals, so
/// analytic-vs-sim holds at model scope in the uncongested regime
/// (`tests/network_exec.rs`).
///
/// Panics (through [`compute_cycles_for`]) if any layer's policy uses
/// mesh streaming — that delivery time is simulated, not closed-form.
pub fn network_latency(cfg: &SimConfig, model: &Network, plan: &NetworkPlan) -> u64 {
    assert_eq!(
        plan.policies.len(),
        model.len(),
        "plan '{}' does not match model '{}'",
        plan.name,
        model.name
    );
    model
        .layers
        .iter()
        .enumerate()
        .map(|(i, layer)| {
            let policy = plan.policy(i);
            latency_policy(cfg, &policy, layer)
                + reload_cycles(&policy.apply(cfg), policy.streaming, model.input_words(i))
        })
        .sum()
}

/// Closed-form expected hop-weighted traffic (flit-hops, as counted by
/// [`crate::noc::stats::NetStats::flit_hops`]) to collect one row's
/// psums — `ppn` per node — at zero contention with ample δ. This is the
/// quantity INA minimizes: a single small packet crosses the row once,
/// versus one row-sized gather packet (or `⌈M·ppn/η⌉` of them), versus a
/// quadratic sum of unicasts.
///
/// Exact when the gather capacity `η` covers whole nodes (all Table-1
/// configurations); cross-checked against simulation by the test suite.
pub fn row_collection_flit_hops(cfg: &SimConfig, collection: Collection, ppn: u32) -> u64 {
    let m = cfg.mesh_cols as u64;
    let ppn = ppn as u64;
    match collection {
        Collection::RepetitiveUnicast => {
            // The node at column x sends its packets over M − x routers:
            // Σ_{x=0}^{M−1} (M − x) = M(M+1)/2, times packets × flits.
            let per_pkt = if cfg.ru_pack_payloads {
                (cfg.unicast_packet_flits as u64 - 1) * cfg.payloads_per_flit() as u64
            } else {
                1
            };
            let pkts_per_node = ppn.div_ceil(per_pkt);
            pkts_per_node * cfg.unicast_packet_flits as u64 * m * (m + 1) / 2
        }
        Collection::Gather => {
            // Packet i fills up after η/ppn nodes and the next initiates
            // there (§4.2/§5.2), so it crosses M − i·η/ppn routers.
            let eta = cfg.gather_capacity() as u64;
            let lg = cfg.gather_packet_flits as u64;
            let num_packets = (m * ppn).div_ceil(eta);
            (0..num_packets).map(|i| lg * (m - i * eta / ppn)).sum()
        }
        Collection::Ina => {
            // One small packet per row: folds and merges move no flits.
            cfg.ina_packet_flits(ppn as u32) as u64 * m
        }
    }
}

/// The analytic improvement factor RU/gather the paper derives in §4.5.
pub fn improvement(cfg: &SimConfig, streaming: Streaming, layer: &ConvLayer) -> f64 {
    latency_ru(cfg, streaming, layer) as f64 / latency_gather(cfg, streaming, layer) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::alexnet;

    fn layer() -> ConvLayer {
        alexnet::conv_layers()[2].clone()
    }

    #[test]
    fn one_gather_packet_on_8x8() {
        // η = 8n on the 8×8 default, so one packet covers the row.
        for n in [1, 2, 4, 8] {
            let cfg = SimConfig::table1_8x8(n);
            let m = 8u64;
            let eta = cfg.gather_capacity() as u64;
            assert_eq!((m * n as u64).div_ceil(eta), 1);
        }
    }

    #[test]
    fn two_gather_packets_on_16x16() {
        for n in [1, 2, 4, 8] {
            let cfg = SimConfig::table1_16x16(n);
            let eta = cfg.gather_capacity() as u64;
            assert_eq!((16 * n as u64).div_ceil(eta), 2);
        }
    }

    #[test]
    fn zero_load_forms_are_nearly_equal() {
        // §4.5: "When n=1, the time taken to transmit the unicast packet
        // from the leftmost node is nearly the same as the time taken to
        // transmit the gather packet" — the real gap is congestion
        // (Δ_R vs Δ_G), which the closed forms set to zero.
        for n in [1, 2, 4, 8] {
            for cfg in [SimConfig::table1_8x8(n), SimConfig::table1_16x16(n)] {
                let ru = latency_ru(&cfg, Streaming::TwoWay, &layer()) as f64;
                let g = latency_gather(&cfg, Streaming::TwoWay, &layer()) as f64;
                let ratio = g / ru;
                assert!((0.98..1.02).contains(&ratio), "n={n}: ratio={ratio}");
            }
        }
    }

    #[test]
    fn two_way_beats_one_way_analytically() {
        // §4.5 / Fig. 14: the two-way architecture halves the dominant
        // stream term for the OS dataflow.
        let cfg = SimConfig::table1_8x8(4);
        let two = latency_gather(&cfg, Streaming::TwoWay, &layer());
        let one = latency_gather(&cfg, Streaming::OneWay, &layer());
        assert!(one > two);
        let ratio = one as f64 / two as f64;
        assert!(ratio > 1.5 && ratio < 2.05, "ratio={ratio}");
    }

    #[test]
    fn ina_zero_load_latency_is_nearly_the_ru_and_gather_forms() {
        // All three schemes are leftmost-packet-bound at zero load; the
        // differences (smaller serialization tail than gather, fewer
        // packets than RU) are second order next to the compute term.
        for n in [1, 2, 4, 8] {
            let cfg = SimConfig::table1_8x8(n);
            let ina = latency_ina(&cfg, Streaming::TwoWay, &layer()) as f64;
            let ru = latency_ru(&cfg, Streaming::TwoWay, &layer()) as f64;
            let g = latency_gather(&cfg, Streaming::TwoWay, &layer()) as f64;
            assert!((0.98..1.02).contains(&(ina / ru)), "n={n}: INA/RU {}", ina / ru);
            assert!(ina <= g, "n={n}: INA tail must not exceed the gather tail");
        }
        let cfg = SimConfig::table1_8x8(4);
        assert_eq!(
            latency(&cfg, Streaming::TwoWay, Collection::Ina, &layer()),
            latency_ina(&cfg, Streaming::TwoWay, &layer())
        );
    }

    #[test]
    fn hop_weighted_traffic_orders_ina_below_gather_below_ru() {
        for n in [1u32, 2, 4, 8] {
            for cfg in [SimConfig::table1_8x8(n as usize), SimConfig::table1_16x16(n as usize)] {
                let ru = row_collection_flit_hops(&cfg, Collection::RepetitiveUnicast, n);
                let g = row_collection_flit_hops(&cfg, Collection::Gather, n);
                let ina = row_collection_flit_hops(&cfg, Collection::Ina, n);
                assert!(ina <= g, "n={n} m={}: INA {ina} vs gather {g}", cfg.mesh_cols);
                assert!(g <= ru, "n={n} m={}: gather {g} vs RU {ru}", cfg.mesh_cols);
                if n >= 2 {
                    assert!(ina < ru, "n={n}: INA must strictly undercut RU");
                }
            }
        }
        // Spot-check the closed forms on the Table-1 8×8, n=1 point:
        // RU: 8 nodes × 2 flits × mean hops — Σ(8−x) = 36 → 72;
        // gather: one 3-flit packet × 8 hops = 24; INA: 2 flits × 8 = 16.
        let cfg = SimConfig::table1_8x8(1);
        assert_eq!(row_collection_flit_hops(&cfg, Collection::RepetitiveUnicast, 1), 72);
        assert_eq!(row_collection_flit_hops(&cfg, Collection::Gather, 1), 24);
        assert_eq!(row_collection_flit_hops(&cfg, Collection::Ina, 1), 16);
    }

    #[test]
    fn network_latency_sums_per_layer_forms_plus_reload() {
        use crate::plan::{reload_cycles, LayerPolicy, NetworkPlan};
        let cfg = SimConfig::table1_8x8(4);
        let model = Network::alexnet();
        let plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
        let total = network_latency(&cfg, &model, &plan);
        let by_hand: u64 = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                latency_gather(&cfg, Streaming::TwoWay, l)
                    + reload_cycles(&cfg, Streaming::TwoWay, model.input_words(i))
            })
            .sum();
        assert_eq!(total, by_hand);
        assert!(total > 0);
    }

    #[test]
    #[should_panic(expected = "mesh streaming latency is simulated")]
    fn network_latency_rejects_mesh_policies() {
        use crate::plan::{LayerPolicy, NetworkPlan};
        let cfg = SimConfig::table1_8x8(1);
        let model = Network::alexnet();
        let mut policy = LayerPolicy::proposed();
        policy.streaming = Streaming::Mesh;
        let plan = NetworkPlan::uniform(policy, model.len());
        network_latency(&cfg, &model, &plan);
    }

    #[test]
    fn torus_ru_head_term_undercuts_the_mesh() {
        use crate::config::TopologyKind;
        let mesh = SimConfig::table1_8x8(4);
        let mut torus = mesh.clone();
        torus.topology = TopologyKind::Torus;
        // RU benefits from the wrap shortcut; gather is pinned to the
        // row walk and must be unchanged.
        assert!(
            latency_ru(&torus, Streaming::TwoWay, &layer())
                < latency_ru(&mesh, Streaming::TwoWay, &layer())
        );
        assert_eq!(
            latency_gather(&torus, Streaming::TwoWay, &layer()),
            latency_gather(&mesh, Streaming::TwoWay, &layer())
        );
        assert_eq!(
            latency_ina(&torus, Streaming::TwoWay, &layer()),
            latency_ina(&mesh, Streaming::TwoWay, &layer())
        );
    }

    #[test]
    fn compute_term_dominates_for_large_c() {
        let cfg = SimConfig::table1_8x8(1);
        let total = latency_gather(&cfg, Streaming::TwoWay, &layer());
        let compute = compute_cycles(&cfg, Streaming::TwoWay, &layer());
        assert!((total - compute) < total / 100);
    }
}
