//! The typed public construction-and-run façade: [`ScenarioBuilder`] →
//! [`Scenario`].
//!
//! Everything the crate can simulate is a *scenario*: a validated
//! configuration (geometry + topology + dataflow + collection), a
//! streaming architecture, and the router fabric built from them. The
//! builder is the one place invalid input is caught — every violation is
//! a typed [`ConfigError`], never a panic — and the [`Scenario`] it
//! produces is the single entry point the per-layer driver
//! ([`Scenario::simulate`]) and the whole-model executor
//! ([`Scenario::execute`]) hang off. `Experiment`,
//! `NetworkExecutor::run`'s per-layer evaluation and the free
//! `run_layer*` functions are all rebased on this seam.
//!
//! ```no_run
//! use noc_dnn::prelude::*;
//!
//! # fn main() -> Result<(), ConfigError> {
//! let scenario = ScenarioBuilder::new()
//!     .mesh(8)
//!     .pes_per_router(2)
//!     .topology(TopologyKind::Torus)
//!     .streaming(Streaming::TwoWay)
//!     .collection(Collection::Ina)
//!     .build()?;
//! let report = scenario.simulate(&alexnet::conv_layers()[2]);
//! println!("{} cycles, {:.3} mJ", report.run.total_cycles, report.power.total_j * 1e3);
//! # Ok(())
//! # }
//! ```
//!
//! ## Geometry semantics
//!
//! [`ScenarioBuilder::mesh`] names the **logical PE-array side**. For
//! mesh and torus fabrics that is also the router radix. Selecting
//! [`TopologyKind::CMesh`] concentrates 2×2 PE groups onto each router:
//! the router grid halves per dimension and `pes_per_router` multiplies
//! by 4, with the gather packet size and δ plateau re-derived for the
//! smaller radix — the same workload on a thinner fabric.
//! [`ScenarioBuilder::from_config`] skips all geometry derivation and
//! treats the given `SimConfig` as the literal router grid.

use std::sync::Arc;

use crate::config::{
    Collection, ConfigError, DataflowKind, PeGrouping, SimConfig, Streaming, TopologyKind,
};
use crate::coordinator::executor::{NetworkExecutor, NetworkRunReport};
use crate::coordinator::experiment::LayerReport;
use crate::dataflow::{driver::run_layer_with_fabric, LayerRunResult};
use crate::models::{ConvLayer, Network as Model};
use crate::noc::faults::FaultsConfig;
use crate::noc::topology::{self, Topology};
use crate::plan::NetworkPlan;
use crate::power::power_report;

/// Result of [`Scenario::simulate`]: the per-layer driver run plus the
/// power roll-up (the record the figure sweeps and `Experiment` report).
pub type RunReport = LayerReport;

/// PEs concentrated per router when [`TopologyKind::CMesh`] is built
/// from a logical PE array (a 2×2 group per router).
pub const CMESH_CONCENTRATION: usize = 4;

/// A deferred configuration edit queued by [`ScenarioBuilder::configure`].
type ConfigTweak = Box<dyn FnOnce(&mut SimConfig)>;

/// Fluent, validating constructor for [`Scenario`]s.
///
/// Defaults reproduce the paper's Table-1 8×8 mesh with 1 PE/router,
/// two-way streaming and gather collection. Every setter overrides one
/// axis; [`ScenarioBuilder::build`] derives the remaining Table-1
/// parameters, validates the whole configuration and returns a typed
/// [`ConfigError`] on any violation.
pub struct ScenarioBuilder {
    base: Option<SimConfig>,
    mesh: Option<usize>,
    pes_per_router: Option<usize>,
    topology: Option<TopologyKind>,
    streaming: Streaming,
    collection: Option<Collection>,
    dataflow: Option<DataflowKind>,
    pe_grouping: Option<PeGrouping>,
    delta: Option<u64>,
    rounds_cap: Option<usize>,
    threads: Option<usize>,
    intra_workers: Option<usize>,
    trace_driven: Option<bool>,
    probes: Option<bool>,
    ws_rf_words: Option<u32>,
    faults: Option<FaultsConfig>,
    tweaks: Vec<ConfigTweak>,
}

impl Default for ScenarioBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ScenarioBuilder {
    /// Start from the Table-1 defaults (8×8 mesh, 1 PE/router).
    pub fn new() -> ScenarioBuilder {
        ScenarioBuilder {
            base: None,
            mesh: None,
            pes_per_router: None,
            topology: None,
            streaming: Streaming::TwoWay,
            collection: None,
            dataflow: None,
            pe_grouping: None,
            delta: None,
            rounds_cap: None,
            threads: None,
            intra_workers: None,
            trace_driven: None,
            probes: None,
            ws_rf_words: None,
            faults: None,
            tweaks: Vec::new(),
        }
    }

    /// Start from an existing `SimConfig` (its dims are the literal
    /// router grid — no CMesh geometry derivation is applied). The shim
    /// the legacy `Experiment`/`run_layer` surfaces use to reach the
    /// façade.
    pub fn from_config(cfg: SimConfig) -> ScenarioBuilder {
        ScenarioBuilder { base: Some(cfg), ..ScenarioBuilder::new() }
    }

    /// Logical PE-array side (router radix on mesh/torus; halved for a
    /// concentrated mesh). Default 8. Geometry setters belong to the
    /// Table-1 derivation path — combining them with
    /// [`ScenarioBuilder::from_config`] is a [`ConfigError`] at `build()`
    /// (the base config's geometry is literal; edit it via
    /// [`ScenarioBuilder::configure`]).
    pub fn mesh(mut self, m: usize) -> Self {
        self.mesh = Some(m);
        self
    }

    /// PEs per router before any fabric concentration. Default 1. Same
    /// derivation-path-only rule as [`ScenarioBuilder::mesh`].
    pub fn pes_per_router(mut self, n: usize) -> Self {
        self.pes_per_router = Some(n);
        self
    }

    /// Router fabric (`mesh` / `torus` / `cmesh`).
    pub fn topology(mut self, t: TopologyKind) -> Self {
        self.topology = Some(t);
        self
    }

    /// Operand streaming architecture (two-way buses by default).
    pub fn streaming(mut self, s: Streaming) -> Self {
        self.streaming = s;
        self
    }

    /// Partial-sum collection scheme (gather by default).
    pub fn collection(mut self, c: Collection) -> Self {
        self.collection = Some(c);
        self
    }

    /// Dataflow mapping (Output-Stationary by default).
    pub fn dataflow(mut self, d: DataflowKind) -> Self {
        self.dataflow = Some(d);
        self
    }

    /// PE grouping behind each router (§4.4).
    pub fn pe_grouping(mut self, g: PeGrouping) -> Self {
        self.pe_grouping = Some(g);
        self
    }

    /// Gather timeout δ in cycles (default: the Table-1 plateau derived
    /// from the final router radix).
    pub fn delta(mut self, d: u64) -> Self {
        self.delta = Some(d);
        self
    }

    /// Flit-accurate round cap before steady-state extrapolation.
    pub fn rounds_cap(mut self, cap: usize) -> Self {
        self.rounds_cap = Some(cap);
        self
    }

    /// Worker threads for multi-layer fan-outs (0 = auto).
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    /// Band workers *inside* each simulation — the deterministic
    /// intra-layer parallel kernel of [`crate::noc::parallel`] (1 =
    /// sequential kernel, the default; results are bit-identical at any
    /// count).
    pub fn intra_workers(mut self, w: usize) -> Self {
        self.intra_workers = Some(w);
        self
    }

    /// Trace-driven round gating (the paper's Fig. 13/15/16 methodology).
    pub fn trace_driven(mut self, on: bool) -> Self {
        self.trace_driven = Some(on);
        self
    }

    /// Per-link observability probes ([`crate::noc::probes`]). When on,
    /// every simulated layer carries a `ProbeReport` in
    /// `LayerRunResult::probes`; when off (the default) the kernel runs
    /// probe-free and bit-identical.
    pub fn probes(mut self, on: bool) -> Self {
        self.probes = Some(on);
        self
    }

    /// Weight-Stationary register-file capacity in words.
    pub fn ws_rf_words(mut self, words: u32) -> Self {
        self.ws_rf_words = Some(words);
        self
    }

    /// Deterministic fault-injection plan ([`crate::noc::faults`]): link
    /// and router faults, transient windows, per-flit corruption with
    /// bounded retransmission. Off by default — an unset plan leaves the
    /// kernel bit-identical to the fault-free build. The plan is
    /// validated against the final fabric at `build()`.
    pub fn faults(mut self, f: FaultsConfig) -> Self {
        self.faults = Some(f);
        self
    }

    /// Escape hatch for knobs without a dedicated setter; applied after
    /// every named setter, still subject to `build()` validation.
    pub fn configure(mut self, f: impl FnOnce(&mut SimConfig) + 'static) -> Self {
        self.tweaks.push(Box::new(f));
        self
    }

    /// Derive, validate and freeze the scenario. Every invalid input —
    /// degenerate geometry, an odd PE array under CMesh concentration, a
    /// torus without dateline VCs, any `SimConfig::validate` violation —
    /// is a typed [`ConfigError`].
    pub fn build(self) -> Result<Scenario, ConfigError> {
        let streaming = self.streaming;
        let mut cfg = match self.base {
            Some(base) => {
                // The base config's geometry is literal; a geometry
                // setter here would be silently un-derived, so reject it
                // loudly instead.
                if self.mesh.is_some() || self.pes_per_router.is_some() {
                    return Err(ConfigError::invalid(
                        "builder",
                        "mesh()/pes_per_router() drive the Table-1 derivation path and \
                         do not combine with from_config() — the base config's geometry \
                         is literal; edit it with configure() instead",
                    ));
                }
                base
            }
            None => {
                let kind = self.topology.unwrap_or(TopologyKind::Mesh);
                let mesh = self.mesh.unwrap_or(8);
                let pes = self.pes_per_router.unwrap_or(1);
                let (radix, n) = match kind {
                    TopologyKind::CMesh => {
                        if mesh < 4 || mesh % 2 != 0 {
                            return Err(ConfigError::invalid(
                                "mesh",
                                format!(
                                    "concentrated mesh halves the radix: the PE-array side \
                                     must be an even number >= 4, got {mesh}"
                                ),
                            ));
                        }
                        (mesh / 2, pes * CMESH_CONCENTRATION)
                    }
                    _ => (mesh, pes),
                };
                // table1 re-derives the gather packet size, packets/row
                // and δ plateau for the (possibly halved) radix.
                SimConfig::table1(radix, n)
            }
        };
        if let Some(t) = self.topology {
            cfg.topology = t;
        }
        if let Some(c) = self.collection {
            cfg.collection = c;
        }
        if let Some(d) = self.dataflow {
            cfg.dataflow = d;
        }
        if let Some(g) = self.pe_grouping {
            cfg.pe_grouping = g;
        }
        if let Some(d) = self.delta {
            cfg.delta = d;
        }
        if let Some(cap) = self.rounds_cap {
            cfg.sim_rounds_cap = cap;
        }
        if let Some(t) = self.threads {
            cfg.threads = t;
        }
        if let Some(w) = self.intra_workers {
            cfg.intra_workers = w;
        }
        if let Some(on) = self.trace_driven {
            cfg.trace_driven = on;
        }
        if let Some(on) = self.probes {
            cfg.probes = on;
        }
        if let Some(w) = self.ws_rf_words {
            cfg.ws_rf_words = w;
        }
        if let Some(f) = self.faults {
            cfg.faults = Some(f);
        }
        for tweak in self.tweaks {
            tweak(&mut cfg);
        }
        cfg.validate()?;
        Ok(Scenario {
            topology: topology::build(&cfg),
            cfg: Arc::new(cfg),
            streaming,
        })
    }
}

/// A validated, runnable experiment point: shared config, built router
/// fabric, streaming architecture. Cheap to clone (two `Arc`s and an
/// enum); safe to fan out across threads.
#[derive(Debug, Clone)]
pub struct Scenario {
    cfg: Arc<SimConfig>,
    topology: Arc<dyn Topology>,
    streaming: Streaming,
}

impl Scenario {
    /// The validated configuration.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// The configuration `Arc`, for callers constructing many simulations
    /// from one scenario without deep clones.
    pub fn shared_config(&self) -> Arc<SimConfig> {
        self.cfg.clone()
    }

    /// The router fabric.
    pub fn topology(&self) -> &dyn Topology {
        self.topology.as_ref()
    }

    /// The streaming architecture.
    pub fn streaming(&self) -> Streaming {
        self.streaming
    }

    /// The collection scheme (held by the config).
    pub fn collection(&self) -> Collection {
        self.cfg.collection
    }

    /// Simulate one convolution layer: the flit-accurate round driver
    /// plus steady-state extrapolation ([`crate::dataflow::driver`]),
    /// without the power roll-up. Runs on this scenario's own fabric
    /// `Arc` — the topology [`Scenario::topology`] advertises is, by
    /// construction, the one simulated.
    pub fn run_raw(&self, layer: &ConvLayer) -> LayerRunResult {
        run_layer_with_fabric(
            &self.cfg,
            self.topology.clone(),
            self.streaming,
            self.cfg.collection,
            layer,
        )
    }

    /// Simulate one convolution layer and roll up power — the single
    /// per-layer entry point (`Experiment::run_layer` and the executor's
    /// per-layer evaluation are shims over this).
    pub fn simulate(&self, layer: &ConvLayer) -> RunReport {
        let run = self.run_raw(layer);
        let power = power_report(
            &self.cfg,
            self.streaming,
            self.cfg.collection,
            &run.net,
            &run.bus,
            run.total_cycles,
        );
        RunReport { layer: layer.name.to_string(), run, power }
    }

    /// Execute a whole model under a per-layer plan through the network
    /// executor (inter-layer reloads charged, layers fanned out over
    /// `threads` workers). The scenario's own streaming/collection/
    /// dataflow triple is what a `NetworkPlan::uniform` of
    /// [`Scenario::uniform_policy`] runs.
    pub fn execute(&self, model: &Model, plan: &NetworkPlan) -> crate::Result<NetworkRunReport> {
        NetworkExecutor::new(self.cfg.as_ref().clone()).run(model, plan)
    }

    /// This scenario's (streaming × collection × dataflow) triple as a
    /// per-layer policy — `NetworkPlan::uniform(scenario.uniform_policy(),
    /// model.len())` runs the whole model under exactly this scenario.
    pub fn uniform_policy(&self) -> crate::plan::LayerPolicy {
        crate::plan::LayerPolicy {
            streaming: self.streaming,
            collection: self.cfg.collection,
            dataflow: self.cfg.dataflow,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::alexnet;

    #[test]
    fn builder_defaults_match_table1() {
        let s = ScenarioBuilder::new().build().unwrap();
        assert_eq!(*s.config(), SimConfig::table1_8x8(1));
        assert_eq!(s.streaming(), Streaming::TwoWay);
        assert_eq!(s.collection(), Collection::Gather);
        assert_eq!(s.topology().kind(), TopologyKind::Mesh);
    }

    #[test]
    fn probes_setter_surfaces_a_report_through_simulate() {
        let layer = &alexnet::conv_layers()[0];
        let on = ScenarioBuilder::new()
            .rounds_cap(2)
            .probes(true)
            .build()
            .unwrap()
            .simulate(layer);
        let p = on.run.probes.as_ref().expect("probes on must yield a report");
        assert_eq!(p.total_flits, on.run.measured_net.link_traversals);
        assert!(p.max_utilization() > 0.0);
        // Probe-off runs carry no report and identical aggregates.
        let off = ScenarioBuilder::new().rounds_cap(2).build().unwrap().simulate(layer);
        assert!(off.run.probes.is_none());
        assert_eq!(on.run.net, off.run.net);
        assert_eq!(on.run.total_cycles, off.run.total_cycles);
    }

    #[test]
    fn faults_setter_installs_a_validated_plan() {
        let f = FaultsConfig::parse("seed=7,corrupt=0.01").unwrap();
        let s = ScenarioBuilder::new().faults(f.clone()).build().unwrap();
        assert_eq!(s.config().faults.as_ref(), Some(&f));
        // Out-of-grid fault coordinates are a typed error at build().
        let bad = FaultsConfig::parse("links=99:0:E").unwrap();
        assert!(matches!(
            ScenarioBuilder::new().faults(bad).build(),
            Err(ConfigError::Invalid { what: "faults", .. })
        ));
    }

    #[test]
    fn cmesh_halves_the_radix_and_concentrates() {
        let s = ScenarioBuilder::new()
            .mesh(8)
            .pes_per_router(2)
            .topology(TopologyKind::CMesh)
            .build()
            .unwrap();
        let c = s.config();
        assert_eq!((c.mesh_cols, c.mesh_rows), (4, 4));
        assert_eq!(c.pes_per_router, 8);
        assert_eq!(c.gather_packet_flits, SimConfig::gather_flits_for(8));
        // δ plateau re-derived for the smaller radix.
        assert_eq!(c.delta, SimConfig::table1(4, 8).delta);
        assert_eq!(s.topology().dims(), (4, 4));
        assert_eq!(s.topology().concentration(), 8);
    }

    #[test]
    fn builder_rejects_bad_geometry_with_typed_errors() {
        assert!(matches!(
            ScenarioBuilder::new().mesh(7).topology(TopologyKind::CMesh).build(),
            Err(ConfigError::Invalid { what: "mesh", .. })
        ));
        assert!(matches!(
            ScenarioBuilder::new().mesh(0).build(),
            Err(ConfigError::Invalid { what: "mesh", .. })
        ));
        assert!(matches!(
            ScenarioBuilder::new()
                .topology(TopologyKind::Torus)
                .configure(|c| c.vcs = 1)
                .build(),
            Err(ConfigError::Invalid { what: "vcs", .. })
        ));
        assert!(matches!(
            ScenarioBuilder::new().rounds_cap(1).build(),
            Err(ConfigError::Invalid { what: "sim_rounds_cap", .. })
        ));
        // Geometry setters do not combine with from_config (the base
        // config's dims are literal — silently ignoring the request
        // would simulate the wrong geometry).
        assert!(matches!(
            ScenarioBuilder::from_config(SimConfig::table1_8x8(1)).mesh(16).build(),
            Err(ConfigError::Invalid { what: "builder", .. })
        ));
        assert!(matches!(
            ScenarioBuilder::from_config(SimConfig::table1_8x8(1)).pes_per_router(4).build(),
            Err(ConfigError::Invalid { what: "builder", .. })
        ));
    }

    #[test]
    fn from_config_keeps_literal_dims() {
        let mut cfg = SimConfig::table1(4, 8);
        cfg.topology = TopologyKind::CMesh;
        let s = ScenarioBuilder::from_config(cfg.clone()).build().unwrap();
        assert_eq!(s.config().mesh_cols, 4);
        assert_eq!(s.config().pes_per_router, 8);
        assert_eq!(s.topology().kind(), TopologyKind::CMesh);
    }

    #[test]
    fn simulate_matches_the_legacy_free_function() {
        let mut base = SimConfig::table1_8x8(2);
        base.sim_rounds_cap = 2;
        let s = ScenarioBuilder::from_config(base.clone())
            .collection(Collection::Gather)
            .build()
            .unwrap();
        let facade = s.simulate(&alexnet::conv_layers()[0]);
        let mut legacy_cfg = base;
        legacy_cfg.collection = Collection::Gather;
        let legacy = crate::dataflow::run_layer(
            &legacy_cfg,
            Streaming::TwoWay,
            Collection::Gather,
            &alexnet::conv_layers()[0],
        );
        assert_eq!(facade.run.total_cycles, legacy.total_cycles);
        assert_eq!(facade.run.net, legacy.net);
    }

    #[test]
    fn uniform_policy_mirrors_the_scenario_triple() {
        let s = ScenarioBuilder::new()
            .streaming(Streaming::OneWay)
            .collection(Collection::Ina)
            .dataflow(DataflowKind::WeightStationary)
            .build()
            .unwrap();
        let p = s.uniform_policy();
        assert_eq!(p.streaming, Streaming::OneWay);
        assert_eq!(p.collection, Collection::Ina);
        assert_eq!(p.dataflow, DataflowKind::WeightStationary);
    }
}
