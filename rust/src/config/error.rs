//! Typed configuration errors.
//!
//! Every public construction and parse path of the crate —
//! [`super::SimConfig::validate`], the keyword parsers
//! ([`super::Streaming::parse`], [`super::Collection::parse`],
//! [`super::DataflowKind::parse`], [`super::TopologyKind::parse`]), plan
//! JSON loading ([`crate::plan::NetworkPlan::from_json`]) and the
//! [`crate::api::ScenarioBuilder`] façade — reports failures as a
//! [`ConfigError`] instead of panicking. The CLI prints the error and
//! exits nonzero; library callers can match on the variant.
//!
//! `ConfigError` implements [`std::error::Error`], so it converts into
//! the crate-wide `anyhow`-style [`crate::Result`] with `?`.

use std::fmt;

/// A configuration was invalid or could not be parsed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// A CLI/JSON keyword did not match any known spelling
    /// (e.g. `--collection broadcast`).
    UnknownKeyword {
        /// Which selector was being parsed (`"collection"`, `"topology"`, …).
        what: &'static str,
        /// The spelling that failed to parse.
        got: String,
        /// The accepted spellings, for the error message.
        expected: &'static str,
    },
    /// A field (or combination of fields) holds an invalid value
    /// (e.g. a torus with a single virtual channel).
    Invalid {
        /// Which field or constraint was violated.
        what: &'static str,
        /// Human-readable explanation.
        reason: String,
    },
    /// A JSON document failed to parse or is missing required structure.
    Json {
        /// Which document was being loaded (`"SimConfig"`, `"plan"`, …).
        what: &'static str,
        /// Parser or structural error text.
        reason: String,
    },
}

impl ConfigError {
    /// Shorthand for an [`ConfigError::Invalid`] with a formatted reason.
    pub fn invalid(what: &'static str, reason: impl fmt::Display) -> ConfigError {
        ConfigError::Invalid { what, reason: reason.to_string() }
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::UnknownKeyword { what, got, expected } => {
                write!(f, "unknown {what} '{got}' (expected {expected})")
            }
            ConfigError::Invalid { what, reason } => {
                write!(f, "invalid {what}: {reason}")
            }
            ConfigError::Json { what, reason } => {
                write!(f, "malformed {what} JSON: {reason}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_actionable() {
        let e = ConfigError::UnknownKeyword {
            what: "collection",
            got: "broadcast".into(),
            expected: "ru | gather | ina",
        };
        let s = e.to_string();
        assert!(s.contains("collection") && s.contains("broadcast") && s.contains("gather"));
        let e = ConfigError::invalid("vcs", "torus dateline rule needs >= 2 VCs");
        assert!(e.to_string().contains("vcs"));
    }

    #[test]
    fn converts_into_the_crate_result_with_question_mark() {
        fn inner() -> crate::Result<()> {
            let failed: Result<(), ConfigError> = Err(ConfigError::invalid("mesh", "too small"));
            failed?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("too small"));
    }
}
