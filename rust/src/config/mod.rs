//! Configuration types mirroring Table 1 of the paper plus simulator knobs.
//!
//! Every experiment in the paper is a point in this configuration space:
//! mesh size (8×8 / 16×16), PEs per router (1/2/4/8), gather packet size
//! (3/5/9/17 flits), timeout `δ`, and the collection/streaming mode.

use crate::noc::faults::FaultsConfig;
use crate::util::json::Json;

mod error;

pub use error::ConfigError;

/// Which router fabric connects the PEs (see [`crate::noc::topology`]).
///
/// The paper evaluates a plain mesh only; the other fabrics generalize
/// its streaming/gather mechanisms. The kind is a plain config key — the
/// behavioral object is the [`crate::noc::topology::Topology`] trait,
/// built from a config by [`crate::noc::topology::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// The paper's 2D mesh: XY routing, memory elements off the east
    /// edge. The default, and the only fabric the frozen reference
    /// kernel ([`crate::noc::reference`]) supports.
    Mesh,
    /// 2D torus: the mesh plus wraparound links on both dimensions.
    /// Collection semantics (gather paths, operand streams) keep the
    /// mesh's row/column walks; unicast result traffic takes ring-minimal
    /// routes, protected from deadlock by a dateline VC rule (needs
    /// `vcs >= 2`).
    Torus,
    /// Concentrated mesh: `c` PEs share each router (via the existing
    /// `pes_per_router` / [`PeGrouping`] machinery), halving the router
    /// radix per dimension. Routing is XY on the smaller grid.
    CMesh,
}

impl TopologyKind {
    pub fn label(&self) -> &'static str {
        match self {
            TopologyKind::Mesh => "mesh",
            TopologyKind::Torus => "torus",
            TopologyKind::CMesh => "cmesh",
        }
    }

    /// Short machine-readable spelling (CLI `--topology`, config JSON).
    pub fn key(&self) -> &'static str {
        self.label()
    }

    /// Parse a CLI/JSON spelling (`mesh` / `torus` / `cmesh`, long names
    /// accepted).
    pub fn parse(s: &str) -> Result<TopologyKind, ConfigError> {
        match s {
            "mesh" => Ok(TopologyKind::Mesh),
            "torus" => Ok(TopologyKind::Torus),
            "cmesh" | "concentrated-mesh" | "cmesh4" => Ok(TopologyKind::CMesh),
            other => Err(ConfigError::UnknownKeyword {
                what: "topology",
                got: other.to_string(),
                expected: "mesh | torus | cmesh",
            }),
        }
    }
}

/// Which dataflow maps a convolution layer onto the mesh (see
/// [`crate::dataflow::Dataflow`]). The paper evaluates Output-Stationary
/// only; Weight-Stationary generalizes its streaming/gather mechanisms to
/// a second traffic shape (pinned weights, broadcast activations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataflowKind {
    /// Output-Stationary (Fig. 4): each PE accumulates one output element
    /// per round; inputs ride the row buses, weights the column buses.
    OutputStationary,
    /// Weight-Stationary: filter weights are pinned in PE register files
    /// for a whole wave of rounds; one input patch per round is broadcast
    /// on the row buses; completed sums ride gather packets east.
    WeightStationary,
}

impl DataflowKind {
    pub fn label(&self) -> &'static str {
        match self {
            DataflowKind::OutputStationary => "os",
            DataflowKind::WeightStationary => "ws",
        }
    }

    /// Parse a CLI/JSON spelling (`os` / `ws`, long names accepted).
    pub fn parse(s: &str) -> Result<DataflowKind, ConfigError> {
        match s {
            "os" | "output-stationary" => Ok(DataflowKind::OutputStationary),
            "ws" | "weight-stationary" => Ok(DataflowKind::WeightStationary),
            other => Err(ConfigError::UnknownKeyword {
                what: "dataflow",
                got: other.to_string(),
                expected: "os | ws",
            }),
        }
    }
}

/// How partial sums travel back to the global memory (east edge).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collection {
    /// Baseline: every NI unicasts its payloads to the row's memory element
    /// ("repetitive unicast", RU).
    RepetitiveUnicast,
    /// Proposed: gather packets per Algorithm 1 with timeout `δ`.
    Gather,
    /// In-Network Accumulation (the arXiv:2209.10056 follow-up): psums are
    /// tagged with an accumulation space and *added* at intermediate
    /// routers — a passing packet folds a transit NI's same-space psums at
    /// zero latency, and two same-space packets meeting in a router merge
    /// into one. Packets stay small (head + ⌈payloads/slots⌉ flits) no
    /// matter how many nodes contribute; the router pays an ALU add per
    /// folded word (priced by `crate::power`).
    Ina,
}

/// How input activations / filter weights reach the PEs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Streaming {
    /// Operands are distributed over the mesh itself as row/column multicast
    /// wormhole streams (the "gather-only" architecture of [27]).
    Mesh,
    /// One shared bus per row carries inputs and weights interleaved
    /// (Fig. 10(b)).
    OneWay,
    /// Separate input-activation (row) and weight (column) buses
    /// (Fig. 10(a)).
    TwoWay,
}

/// How the n PEs behind one router are grouped (§4.4): column grouping
/// shares one filter stream and n input-activation streams per NI; row
/// grouping shares one input stream and n filter streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeGrouping {
    /// "multiple PEs on the same column sharing one router" — n patch
    /// streams, one filter stream (the paper's primary option).
    Column,
    /// "multiple PEs on the same row sharing one router" — one patch
    /// stream, n filter streams.
    Row,
}

impl PeGrouping {
    pub fn label(&self) -> &'static str {
        match self {
            PeGrouping::Column => "column",
            PeGrouping::Row => "row",
        }
    }
}

/// Network + PE configuration (Table 1) and simulator controls.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// Router fabric connecting the PEs (CLI `--topology mesh|torus|cmesh`).
    /// `mesh_cols`/`mesh_rows` are always the *router* grid — for a
    /// concentrated mesh they are the already-halved radix (the
    /// [`crate::api::ScenarioBuilder`] derives them from the logical PE
    /// array).
    pub topology: TopologyKind,
    /// Mesh columns (M in the paper; X dimension, gather direction is +X).
    pub mesh_cols: usize,
    /// Mesh rows (N in the paper; Y dimension).
    pub mesh_rows: usize,
    /// Virtual channels per input port.
    pub vcs: usize,
    /// Buffer depth per VC, in flits.
    pub buffer_depth: usize,
    /// Router pipeline depth κ in cycles (RC, VA, SA, ST).
    pub router_pipeline: u64,
    /// Link traversal latency in cycles.
    pub link_latency: u64,
    /// Flit width in bits.
    pub flit_bits: u32,
    /// One gather payload (a partial sum) in bits.
    pub gather_payload_bits: u32,
    /// PEs attached to each router (n).
    pub pes_per_router: usize,
    /// Total flits in one gather packet (head + body/tail).
    pub gather_packet_flits: usize,
    /// Number of gather packets expected per row per round (1 for 8×8,
    /// 2 for 16×16 per §5.2).
    pub gather_packets_per_row: usize,
    /// Total flits in one unicast packet.
    pub unicast_packet_flits: usize,
    /// MAC pipeline depth (cycles from last operand to partial sum ready).
    pub t_mac: u64,
    /// Gather timeout δ in cycles. A NI with a pending payload waits this
    /// long for a passing gather packet before injecting its own.
    pub delta: u64,
    /// Streaming bus word width in payload words per cycle (f_l). The
    /// default is 4: a 128-bit bus matching the Table-1 flit width (§4.4:
    /// "Depending on the bus width, multiple input activations and weights
    /// can be streamed in each NI at one time").
    pub bus_words_per_cycle: u32,
    /// PE grouping behind each router (§4.4).
    pub pe_grouping: PeGrouping,
    /// Dataflow used to map layers onto the mesh (default: the paper's
    /// Output-Stationary).
    pub dataflow: DataflowKind,
    /// Default partial-sum collection scheme for tools that serialize a
    /// whole experiment as one config (CLI `--collection ru|gather|ina`).
    /// `Network::new` still takes the scheme explicitly; this field is the
    /// config-file/CLI default, not a hidden override.
    pub collection: Collection,
    /// Weight-Stationary only: per-PE register-file capacity in weight
    /// words. A filter whose `C·R·R` weights exceed this is spread across
    /// the PEs behind one router, and the NI accumulates their partial
    /// sums before collection (see `dataflow::ws`).
    pub ws_rf_words: u32,
    /// Pack up to `payloads_per_flit` partial sums into each RU unicast
    /// packet body instead of the literal one-packet-per-result repetitive
    /// unicast. Ablation knob (benches/fig15 variants); the paper's RU
    /// baseline repeats a fixed 2-flit unicast per result.
    pub ru_pack_payloads: bool,
    /// Trace-driven round gating (the paper's simulation methodology for
    /// Figs. 13/15/16): successive OS rounds are injected as soon as the
    /// previous round's payloads have drained — compute/streaming time is
    /// fully overlapped and the network is the bottleneck. When false, the
    /// full Eq. (3)/(4) round period gates injection (used for Fig. 14 and
    /// the analytic cross-check).
    pub trace_driven: bool,
    /// Maximum number of OS rounds simulated flit-accurately; remaining
    /// rounds are extrapolated from the measured steady state (see
    /// DESIGN.md "Cycle simulation with round extrapolation").
    pub sim_rounds_cap: usize,
    /// Worker threads for multi-layer / multi-point execution (the
    /// network executor and plan search fan layers out over this many OS
    /// threads; CLI `--threads`). `0` means auto (one per core, capped).
    /// Simulations are pure functions of their inputs, so results are
    /// bit-identical for every thread count; `threads = 1` additionally
    /// serializes execution for debugging.
    pub threads: usize,
    /// Worker threads *inside* one network simulation (the intra-layer
    /// parallel kernel, [`crate::noc::parallel`]): the router grid is
    /// sharded into contiguous row bands, one band per worker, and the
    /// band-local pipeline phases run concurrently with deferred effects
    /// merged in ascending band order at a per-cycle barrier — results
    /// are bit-identical to the sequential kernel for every worker
    /// count. `1` (the default) selects today's sequential kernel with
    /// zero extra state. The executor clamps `threads × intra_workers`
    /// against the machine's core budget so nested fan-out cannot
    /// oversubscribe (see `coordinator::executor`).
    pub intra_workers: usize,
    /// Enable the per-link observability probes
    /// ([`crate::noc::probes`]): per-directed-link / per-VC traversal and
    /// credit-block counters plus a cycle-bucketed utilization series,
    /// surfaced as a `ProbeReport` and by `noc-dnn analyze`. Off by
    /// default: the probe-off hot path carries no probe state at all and
    /// is bit-identical to the unprobed kernel.
    pub probes: bool,
    /// Deterministic fault injection ([`crate::noc::faults`]): permanent
    /// and transient link faults, router hard-faults, per-flit corruption
    /// with link-level retransmission, fault-aware rerouting and graceful
    /// gather degradation. `None` (the default) takes none of those paths
    /// and is bit-identical to the fault-free kernel.
    pub faults: Option<FaultsConfig>,
    /// Hard cap on simulated cycles for any single `run_until` /
    /// `run_until_idle` call: the kernel returns a typed
    /// `RunOutcome::CycleCapExceeded` instead of spinning CI forever.
    /// The default is generous (10^9 cycles); callers' own bounds still
    /// apply on top (the effective limit is the minimum of the two).
    pub max_cycles: u64,
    /// Clock frequency in Hz (power reporting only).
    pub clock_hz: f64,
}

impl SimConfig {
    /// Table 1 defaults for an `m`×`m` mesh with `n` PEs per router.
    ///
    /// Gather packet sizes follow the paper: 3, 5, 9, 17 flits for
    /// 1, 2, 4, 8 PEs/router; one gather packet per row on 8×8, two on
    /// 16×16 (§5.2 conclusion).
    ///
    /// `n` outside the paper's {1, 2, 4, 8} uses the generalized gather
    /// packet sizing of [`SimConfig::gather_flits_for`] (a concentrated
    /// mesh concentrates to n = 16/32); degenerate geometry is caught by
    /// [`SimConfig::validate`], never by a panic here.
    pub fn table1(m: usize, n: usize) -> Self {
        SimConfig {
            topology: TopologyKind::Mesh,
            mesh_cols: m,
            mesh_rows: m,
            vcs: 2,
            buffer_depth: 4,
            router_pipeline: 4,
            link_latency: 1,
            flit_bits: 128,
            gather_payload_bits: 32,
            pes_per_router: n,
            gather_packet_flits: Self::gather_flits_for(n),
            gather_packets_per_row: if m > 8 { 2 } else { 1 },
            unicast_packet_flits: 2,
            t_mac: 5,
            // §5.2 sets δ = (N-1)·κ so the leftmost packet reaches every
            // node before timeout. The paper folds link traversal into κ;
            // our model charges the Table-1 link cycle explicitly, so the
            // equivalent plateau is (N-1)·(κ+link)+κ (see noc::gather docs).
            delta: (m as u64).saturating_sub(1) * (4 + 1) + 4,
            bus_words_per_cycle: 4,
            pe_grouping: PeGrouping::Column,
            dataflow: DataflowKind::OutputStationary,
            collection: Collection::Gather,
            // 2048 words (8 KiB of f32) holds every AlexNet filter
            // (conv3: C·R·R = 1728); the deep VGG-16 layers (4608) spread
            // across PEs.
            ws_rf_words: 2048,
            ru_pack_payloads: false,
            trace_driven: false,
            sim_rounds_cap: 8,
            threads: 0,
            intra_workers: 1,
            probes: false,
            faults: None,
            max_cycles: 1_000_000_000,
            clock_hz: 1.0e9,
        }
    }

    /// Table 1 defaults, 8×8 mesh.
    pub fn table1_8x8(n: usize) -> Self {
        Self::table1(8, n)
    }

    /// Table 1 defaults, 16×16 mesh.
    pub fn table1_16x16(n: usize) -> Self {
        Self::table1(16, n)
    }

    /// Default gather packet size (flits) for `n` PEs/router (Table 1).
    pub fn gather_flits_for(n: usize) -> usize {
        match n {
            1 => 3,
            2 => 5,
            4 => 9,
            8 => 17,
            _ => 1 + (n * 8 + 3) / 4, // generalization: head + ceil(8n/4) body
        }
    }

    /// Gather payload slots per flit.
    pub fn payloads_per_flit(&self) -> u32 {
        self.flit_bits / self.gather_payload_bits
    }

    /// Total payload capacity of one gather packet
    /// (body/tail flits × slots per flit).
    pub fn gather_capacity(&self) -> u32 {
        (self.gather_packet_flits as u32 - 1) * self.payloads_per_flit()
    }

    /// Flits of one in-network-accumulation packet carrying `payloads`
    /// physical psum words: a head plus `⌈payloads/slots⌉` body/tail
    /// flits. Downstream routers add into those words instead of
    /// appending slots, so the packet never grows in flight. The single
    /// source of truth for INA packet framing — the network's staging
    /// logic, the [`crate::dataflow::Dataflow`] view and the analytic
    /// closed forms all call this.
    pub fn ina_packet_flits(&self, payloads: u32) -> u32 {
        1 + payloads.div_ceil(self.payloads_per_flit()).max(1)
    }

    /// Number of unicast packets one NI sends per round under repetitive
    /// unicast: one fixed-size packet per partial sum ([31][32] model the
    /// collection as repeating a unicast per result).
    pub fn unicast_packets_per_node(&self) -> usize {
        self.pes_per_router
    }

    /// Router pipeline depth κ.
    pub fn kappa(&self) -> u64 {
        self.router_pipeline
    }

    /// Validate internal consistency. Every violation is a typed
    /// [`ConfigError`] — this is the single gate the public construction
    /// paths ([`crate::api::ScenarioBuilder::build`], JSON loading, the
    /// CLI) rely on instead of panicking.
    pub fn validate(&self) -> Result<(), ConfigError> {
        fn check(cond: bool, what: &'static str, reason: &str) -> Result<(), ConfigError> {
            if cond {
                Ok(())
            } else {
                Err(ConfigError::invalid(what, reason))
            }
        }
        check(self.mesh_cols >= 2 && self.mesh_rows >= 1, "mesh", "mesh too small")?;
        check(self.pes_per_router >= 1, "pes_per_router", "need at least one PE per router")?;
        check(self.vcs >= 1, "vcs", "need at least one VC")?;
        check(self.buffer_depth >= 1, "buffer_depth", "need at least one buffer slot")?;
        check(
            self.gather_payload_bits > 0 && self.flit_bits % self.gather_payload_bits == 0,
            "flit_bits",
            "flit size must be a non-zero multiple of the gather payload size",
        )?;
        check(self.gather_packet_flits >= 2, "gather_packet_flits", "gather packet needs head + body")?;
        check(self.unicast_packet_flits >= 2, "unicast_packet_flits", "unicast packet needs head + body")?;
        check(self.gather_packets_per_row >= 1, "gather_packets_per_row", "need at least one gather packet")?;
        check(self.router_pipeline >= 2, "router_pipeline", "pipeline must cover RC/VA + SA/ST")?;
        check(self.sim_rounds_cap >= 2, "sim_rounds_cap", "need >= 2 simulated rounds to extrapolate")?;
        check(self.ws_rf_words >= 1, "ws_rf_words", "WS register file needs at least one word")?;
        check(
            self.intra_workers >= 1,
            "intra_workers",
            "need at least one intra-layer worker (1 = sequential kernel)",
        )?;
        if self.topology == TopologyKind::Torus {
            // The dateline deadlock-avoidance rule splits the VCs into two
            // classes per link (see `noc::topology::Torus2D`).
            check(self.vcs >= 2, "vcs", "torus dateline VC rule needs >= 2 virtual channels")?;
            check(
                self.mesh_rows >= 2,
                "mesh",
                "torus wraparound needs >= 2 rows (a 1-row ring self-loops)",
            )?;
        }
        check(self.max_cycles >= 1, "max_cycles", "the cycle cap must be at least one cycle")?;
        if let Some(f) = &self.faults {
            // Coordinate bounds and link existence depend on the concrete
            // fabric (torus edge links wrap; a mesh's don't).
            crate::noc::topology::with_fabric(self, |topo| f.validate(topo))?;
        }
        Ok(())
    }

    /// Serialize to JSON (see `crate::util::json`).
    pub fn to_json(&self) -> String {
        let mut j = Json::obj();
        j.set("topology", Json::Str(self.topology.key().to_string()))
            .set("mesh_cols", Json::Num(self.mesh_cols as f64))
            .set("mesh_rows", Json::Num(self.mesh_rows as f64))
            .set("vcs", Json::Num(self.vcs as f64))
            .set("buffer_depth", Json::Num(self.buffer_depth as f64))
            .set("router_pipeline", Json::Num(self.router_pipeline as f64))
            .set("link_latency", Json::Num(self.link_latency as f64))
            .set("flit_bits", Json::Num(self.flit_bits as f64))
            .set("gather_payload_bits", Json::Num(self.gather_payload_bits as f64))
            .set("pes_per_router", Json::Num(self.pes_per_router as f64))
            .set("gather_packet_flits", Json::Num(self.gather_packet_flits as f64))
            .set("gather_packets_per_row", Json::Num(self.gather_packets_per_row as f64))
            .set("unicast_packet_flits", Json::Num(self.unicast_packet_flits as f64))
            .set("t_mac", Json::Num(self.t_mac as f64))
            .set("delta", Json::Num(self.delta as f64))
            .set("bus_words_per_cycle", Json::Num(self.bus_words_per_cycle as f64))
            .set("pe_grouping", Json::Str(self.pe_grouping.label().to_string()))
            .set("dataflow", Json::Str(self.dataflow.label().to_string()))
            .set("collection", Json::Str(self.collection.label().to_string()))
            .set("ws_rf_words", Json::Num(self.ws_rf_words as f64))
            .set("ru_pack_payloads", Json::Bool(self.ru_pack_payloads))
            .set("trace_driven", Json::Bool(self.trace_driven))
            .set("sim_rounds_cap", Json::Num(self.sim_rounds_cap as f64))
            .set("threads", Json::Num(self.threads as f64))
            .set("intra_workers", Json::Num(self.intra_workers as f64))
            .set("probes", Json::Bool(self.probes))
            .set("max_cycles", Json::Num(self.max_cycles as f64))
            .set("clock_hz", Json::Num(self.clock_hz));
        if let Some(f) = &self.faults {
            j.set("faults", f.to_json());
        }
        j.to_pretty()
    }

    /// Deserialize from JSON produced by [`SimConfig::to_json`]. Missing
    /// fields fall back to Table-1 8×8 / 1-PE defaults so configs stay
    /// forward-compatible.
    pub fn from_json(s: &str) -> Result<SimConfig, ConfigError> {
        let j = crate::util::json::parse(s)
            .map_err(|e| ConfigError::Json { what: "SimConfig", reason: e.to_string() })?;
        let d = SimConfig::default();
        let u = |k: &str, dv: u64| j.get(k).and_then(Json::as_u64).unwrap_or(dv);
        let us = |k: &str, dv: usize| j.get(k).and_then(Json::as_usize).unwrap_or(dv);
        let cfg = SimConfig {
            topology: match j.get("topology").and_then(Json::as_str) {
                Some(s) => TopologyKind::parse(s)?,
                None => d.topology,
            },
            mesh_cols: us("mesh_cols", d.mesh_cols),
            mesh_rows: us("mesh_rows", d.mesh_rows),
            vcs: us("vcs", d.vcs),
            buffer_depth: us("buffer_depth", d.buffer_depth),
            router_pipeline: u("router_pipeline", d.router_pipeline),
            link_latency: u("link_latency", d.link_latency),
            flit_bits: u("flit_bits", d.flit_bits as u64) as u32,
            gather_payload_bits: u("gather_payload_bits", d.gather_payload_bits as u64) as u32,
            pes_per_router: us("pes_per_router", d.pes_per_router),
            gather_packet_flits: us("gather_packet_flits", d.gather_packet_flits),
            gather_packets_per_row: us("gather_packets_per_row", d.gather_packets_per_row),
            unicast_packet_flits: us("unicast_packet_flits", d.unicast_packet_flits),
            t_mac: u("t_mac", d.t_mac),
            delta: u("delta", d.delta),
            bus_words_per_cycle: u("bus_words_per_cycle", d.bus_words_per_cycle as u64) as u32,
            pe_grouping: match j.get("pe_grouping").and_then(Json::as_str) {
                Some("row") => PeGrouping::Row,
                _ => PeGrouping::Column,
            },
            dataflow: match j.get("dataflow").and_then(Json::as_str) {
                Some(s) => DataflowKind::parse(s)?,
                None => d.dataflow,
            },
            collection: match j.get("collection").and_then(Json::as_str) {
                Some(s) => Collection::parse(s)?,
                None => d.collection,
            },
            ws_rf_words: u("ws_rf_words", d.ws_rf_words as u64) as u32,
            ru_pack_payloads: j
                .get("ru_pack_payloads")
                .and_then(Json::as_bool)
                .unwrap_or(d.ru_pack_payloads),
            trace_driven: j
                .get("trace_driven")
                .and_then(Json::as_bool)
                .unwrap_or(d.trace_driven),
            sim_rounds_cap: us("sim_rounds_cap", d.sim_rounds_cap),
            threads: us("threads", d.threads),
            intra_workers: us("intra_workers", d.intra_workers),
            probes: j.get("probes").and_then(Json::as_bool).unwrap_or(d.probes),
            // Configs written before the fault subsystem stay fault-free.
            faults: match j.get("faults") {
                Some(v) => Some(FaultsConfig::from_json(v)?),
                None => None,
            },
            max_cycles: u("max_cycles", d.max_cycles),
            clock_hz: j.get("clock_hz").and_then(Json::as_f64).unwrap_or(d.clock_hz),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

impl Collection {
    pub fn label(&self) -> &'static str {
        match self {
            Collection::RepetitiveUnicast => "RU",
            Collection::Gather => "gather",
            Collection::Ina => "INA",
        }
    }

    /// Parse a CLI/JSON spelling (`ru` / `gather` / `ina`, long names and
    /// the `label()` spellings accepted).
    pub fn parse(s: &str) -> Result<Collection, ConfigError> {
        match s {
            "ru" | "RU" | "unicast" | "repetitive-unicast" => Ok(Collection::RepetitiveUnicast),
            "gather" => Ok(Collection::Gather),
            "ina" | "INA" | "in-network-accumulation" => Ok(Collection::Ina),
            other => Err(ConfigError::UnknownKeyword {
                what: "collection",
                got: other.to_string(),
                expected: "ru | gather | ina",
            }),
        }
    }
}

impl Streaming {
    pub fn label(&self) -> &'static str {
        match self {
            Streaming::Mesh => "mesh (gather-only)",
            Streaming::OneWay => "one-way bus",
            Streaming::TwoWay => "two-way bus",
        }
    }

    /// Short machine-readable spelling (CLI flags, plan JSON).
    pub fn key(&self) -> &'static str {
        match self {
            Streaming::Mesh => "mesh",
            Streaming::OneWay => "one-way",
            Streaming::TwoWay => "two-way",
        }
    }

    /// Parse a CLI/JSON spelling (`mesh` / `one-way` / `two-way`; the
    /// `key()` spellings round-trip).
    pub fn parse(s: &str) -> Result<Streaming, ConfigError> {
        match s {
            "mesh" | "gather-only" => Ok(Streaming::Mesh),
            "one-way" | "oneway" | "1way" => Ok(Streaming::OneWay),
            "two-way" | "twoway" | "2way" => Ok(Streaming::TwoWay),
            other => Err(ConfigError::UnknownKeyword {
                what: "streaming",
                got: other.to_string(),
                expected: "mesh | one-way | two-way",
            }),
        }
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        Self::table1_8x8(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults_match_paper() {
        let c = SimConfig::table1_8x8(1);
        assert_eq!(c.vcs, 2);
        assert_eq!(c.buffer_depth, 4);
        assert_eq!(c.router_pipeline, 4);
        assert_eq!(c.link_latency, 1);
        assert_eq!(c.flit_bits, 128);
        assert_eq!(c.gather_payload_bits, 32);
        assert_eq!(c.unicast_packet_flits, 2);
        assert_eq!(c.t_mac, 5);
        c.validate().unwrap();
    }

    #[test]
    fn gather_packet_sizes_match_table1() {
        // Table 1: 3,5,9,17 flits/packet for 1,2,4,8 PEs/router.
        assert_eq!(SimConfig::gather_flits_for(1), 3);
        assert_eq!(SimConfig::gather_flits_for(2), 5);
        assert_eq!(SimConfig::gather_flits_for(4), 9);
        assert_eq!(SimConfig::gather_flits_for(8), 17);
    }

    #[test]
    fn gather_capacity_covers_a_full_8x8_row() {
        // §5.1: the default flit count is "enough to collect all the gather
        // payloads for an 8x8 network".
        for n in [1usize, 2, 4, 8] {
            let c = SimConfig::table1_8x8(n);
            assert!(
                c.gather_capacity() >= (8 * n) as u32,
                "n={n}: capacity {} < {}",
                c.gather_capacity(),
                8 * n
            );
        }
    }

    #[test]
    fn sixteen_mesh_needs_two_gather_packets() {
        // §5.1: "for a 16x16 NoC, two gather packets are needed".
        for n in [1usize, 2, 4, 8] {
            let c = SimConfig::table1_16x16(n);
            assert!(c.gather_capacity() < (16 * n) as u32);
            assert!(c.gather_capacity() * 2 >= (16 * n) as u32);
            assert_eq!(c.gather_packets_per_row, 2);
        }
    }

    #[test]
    fn unicast_packets_per_node_is_one_per_partial_sum() {
        assert_eq!(SimConfig::table1_8x8(1).unicast_packets_per_node(), 1);
        assert_eq!(SimConfig::table1_8x8(4).unicast_packets_per_node(), 4);
        assert_eq!(SimConfig::table1_8x8(8).unicast_packets_per_node(), 8);
    }

    #[test]
    fn config_roundtrips_through_json() {
        let c = SimConfig::table1_16x16(4);
        let s = c.to_json();
        let d = SimConfig::from_json(&s).unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn dataflow_selection_roundtrips_and_parses() {
        let mut c = SimConfig::table1_8x8(2);
        c.dataflow = DataflowKind::WeightStationary;
        c.ws_rf_words = 512;
        let d = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, d);
        assert_eq!(DataflowKind::parse("weight-stationary").unwrap(), c.dataflow);
        assert_eq!(DataflowKind::parse("os").unwrap(), DataflowKind::OutputStationary);
        assert!(DataflowKind::parse("systolic").is_err());
        // Configs written before the dataflow field default to OS.
        let legacy = SimConfig::from_json("{}").unwrap();
        assert_eq!(legacy.dataflow, DataflowKind::OutputStationary);
    }

    #[test]
    fn collection_selection_roundtrips_and_parses() {
        let mut c = SimConfig::table1_8x8(2);
        c.collection = Collection::Ina;
        let d = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, d);
        assert_eq!(Collection::parse("ina").unwrap(), Collection::Ina);
        assert_eq!(Collection::parse("ru").unwrap(), Collection::RepetitiveUnicast);
        assert_eq!(Collection::parse("gather").unwrap(), Collection::Gather);
        // label() spellings round-trip through parse().
        for coll in [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina] {
            assert_eq!(Collection::parse(coll.label()).unwrap(), coll);
        }
        assert!(Collection::parse("broadcast").is_err());
        // Configs written before the collection field default to gather.
        let legacy = SimConfig::from_json("{}").unwrap();
        assert_eq!(legacy.collection, Collection::Gather);
    }

    #[test]
    fn streaming_key_roundtrips_and_parses() {
        for s in [Streaming::Mesh, Streaming::OneWay, Streaming::TwoWay] {
            assert_eq!(Streaming::parse(s.key()).unwrap(), s);
        }
        assert_eq!(Streaming::parse("two-way").unwrap(), Streaming::TwoWay);
        assert!(Streaming::parse("bus").is_err());
    }

    #[test]
    fn threads_and_rounds_cap_roundtrip_through_json() {
        let mut c = SimConfig::table1_8x8(4);
        c.threads = 6;
        c.sim_rounds_cap = 3;
        let d = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, d);
        assert_eq!(d.threads, 6);
        assert_eq!(d.sim_rounds_cap, 3);
        // Configs written before the threads field default to auto (0).
        let legacy = SimConfig::from_json("{}").unwrap();
        assert_eq!(legacy.threads, 0);
    }

    #[test]
    fn intra_workers_roundtrip_through_json_and_default_sequential() {
        let mut c = SimConfig::table1_8x8(4);
        c.intra_workers = 4;
        let d = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, d);
        assert_eq!(d.intra_workers, 4);
        // Configs written before the field default to the sequential kernel.
        let legacy = SimConfig::from_json("{}").unwrap();
        assert_eq!(legacy.intra_workers, 1);
        // Zero workers is a typed validate error, not a silent sequential run.
        let mut bad = SimConfig::default();
        bad.intra_workers = 0;
        assert!(matches!(
            bad.validate(),
            Err(ConfigError::Invalid { what: "intra_workers", .. })
        ));
    }

    #[test]
    fn probes_roundtrip_through_json_and_default_off() {
        let mut c = SimConfig::table1_8x8(4);
        c.probes = true;
        let d = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, d);
        assert!(d.probes);
        // Configs written before the probes field stay probe-free.
        let legacy = SimConfig::from_json("{}").unwrap();
        assert!(!legacy.probes);
        assert!(!SimConfig::table1_8x8(1).probes);
    }

    #[test]
    fn faults_roundtrip_through_json_and_default_off() {
        let mut c = SimConfig::table1_8x8(4);
        c.faults =
            Some(FaultsConfig::parse("seed=5,rate=0.02,links=3:3:E,corrupt=0.001").unwrap());
        let d = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, d);
        // Configs written before the fault subsystem stay fault-free.
        let legacy = SimConfig::from_json("{}").unwrap();
        assert!(legacy.faults.is_none());
        assert!(SimConfig::table1_8x8(1).faults.is_none());
        // A fault plan naming a link outside the grid is a typed validate
        // error surfaced by from_json, not a panic.
        let mut bad = SimConfig::table1_8x8(1);
        bad.faults = Some(FaultsConfig::parse("links=99:0:E").unwrap());
        assert!(matches!(bad.validate(), Err(ConfigError::Invalid { what: "faults", .. })));
        assert!(SimConfig::from_json(&bad.to_json()).is_err());
    }

    #[test]
    fn max_cycles_roundtrips_and_rejects_zero() {
        let mut c = SimConfig::table1_8x8(2);
        c.max_cycles = 123_456;
        let d = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(d.max_cycles, 123_456);
        let legacy = SimConfig::from_json("{}").unwrap();
        assert_eq!(legacy.max_cycles, 1_000_000_000);
        c.max_cycles = 0;
        assert!(matches!(c.validate(), Err(ConfigError::Invalid { what: "max_cycles", .. })));
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut c = SimConfig::default();
        c.flit_bits = 100; // not a multiple of 32
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.gather_packet_flits = 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn topology_key_roundtrips_and_parses() {
        for t in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::CMesh] {
            assert_eq!(TopologyKind::parse(t.key()).unwrap(), t);
        }
        assert_eq!(TopologyKind::parse("concentrated-mesh").unwrap(), TopologyKind::CMesh);
        assert!(matches!(
            TopologyKind::parse("hypercube"),
            Err(ConfigError::UnknownKeyword { what: "topology", .. })
        ));
        // Configs written before the topology field default to mesh.
        let legacy = SimConfig::from_json("{}").unwrap();
        assert_eq!(legacy.topology, TopologyKind::Mesh);
        // And the field round-trips.
        let mut c = SimConfig::table1_8x8(2);
        c.topology = TopologyKind::Torus;
        assert_eq!(SimConfig::from_json(&c.to_json()).unwrap(), c);
    }

    #[test]
    fn parse_errors_are_typed_not_panics() {
        assert!(matches!(
            Collection::parse("broadcast"),
            Err(ConfigError::UnknownKeyword { what: "collection", .. })
        ));
        assert!(matches!(
            Streaming::parse("bus"),
            Err(ConfigError::UnknownKeyword { what: "streaming", .. })
        ));
        assert!(matches!(
            DataflowKind::parse("systolic"),
            Err(ConfigError::UnknownKeyword { what: "dataflow", .. })
        ));
        assert!(matches!(
            SimConfig::from_json("{nonsense"),
            Err(ConfigError::Json { what: "SimConfig", .. })
        ));
    }

    #[test]
    fn torus_demands_dateline_vcs() {
        let mut c = SimConfig::table1_8x8(2);
        c.topology = TopologyKind::Torus;
        c.validate().unwrap();
        c.vcs = 1;
        assert!(matches!(c.validate(), Err(ConfigError::Invalid { what: "vcs", .. })));
        // The same single-VC config is fine on a plain mesh.
        c.topology = TopologyKind::Mesh;
        c.validate().unwrap();
    }

    #[test]
    fn table1_tolerates_off_grid_n_without_panicking() {
        // Concentrated meshes produce n = 16/32; table1 must size the
        // gather packet via the generalized formula instead of asserting.
        let c = SimConfig::table1(4, 16);
        assert_eq!(c.gather_packet_flits, SimConfig::gather_flits_for(16));
        c.validate().unwrap();
        // Degenerate geometry is a typed validate error, not a panic.
        assert!(SimConfig::table1(0, 1).validate().is_err());
        assert!(SimConfig::table1(8, 0).validate().is_err());
    }
}
