//! Whole-network execution engine: run a [`Network`] under a
//! [`NetworkPlan`], one flit-accurate per-layer simulation at a time,
//! with inter-layer traffic accounting and a thread fan-out across
//! layers.
//!
//! The executor is the model-scope counterpart of
//! [`crate::dataflow::run_layer`]: each layer still runs through the
//! per-layer round driver (simulated prefix + steady-state
//! extrapolation, see `dataflow/driver.rs`), but the layers are tied
//! together the way a real inference is —
//!
//! * every layer gets its **own policy** (streaming × collection ×
//!   dataflow) from the plan;
//! * layer ℓ's output feature map is layer ℓ+1's input traffic: the
//!   volume is refilled through the consuming layer's streaming sources
//!   at a closed-form boundary charge ([`crate::plan::reload_cycles`]),
//!   mirrored exactly by [`crate::analytic::network_latency`]. Dataflow
//!   setup/drain costs (WS weight pinning, the last round's collection
//!   tail) are already inside the per-layer driver totals;
//! * layers fan out over [`super::server::parallel_map`] worker threads
//!   (`SimConfig::threads`, CLI `--threads`; `0` = auto). Each layer
//!   simulation is a pure function of its inputs, so totals are
//!   bit-identical across thread counts (pinned by
//!   `tests/determinism.rs`).
//!
//! [`best_plan`] builds the per-layer argmin plan: the analytic closed
//! forms rank the bus policy grid, the shortlist is sim-verified through
//! the same per-layer evaluation the executor uses, and the simulated
//! minimum wins — so the resulting plan's total can never exceed any
//! uniform plan's total over the searched grid (asserted in
//! `tests/network_exec.rs`).
//!
//! Per-layer evaluation is rebased on the [`crate::api::Scenario`]
//! façade: each (layer, policy) point builds a scenario and runs through
//! `Scenario::run_raw`, the same entry the public API exposes.

use crate::api::ScenarioBuilder;
use crate::config::{SimConfig, Streaming};
use crate::models::{ConvLayer, LayerInfo, Network};
use crate::plan::{
    bus_policy_grid, mesh_policy_grid, reload_cycles, reload_net_stats, LayerPolicy, NetworkPlan,
};
use crate::power::power_report;

use super::experiment::LayerReport;
use super::report::LayerResult;
use super::server::{parallel_map, resolve_workers_clamped};

/// One layer of a network run: the per-layer driver result plus the
/// inter-layer boundary charge.
#[derive(Debug, Clone)]
pub struct LayerExecution {
    /// Position of the layer in the model.
    pub index: usize,
    /// The policy this layer ran under.
    pub policy: LayerPolicy,
    /// Per-layer driver result and power roll-up (the same record the
    /// figure sweeps use).
    pub report: LayerReport,
    /// Closed-form cycles to refill this layer's input feature map
    /// through its streaming sources (0 when the executor was built
    /// [`NetworkExecutor::without_reload`]).
    pub reload_cycles: u64,
    /// `report.run.total_cycles + reload_cycles`.
    pub total_cycles: u64,
}

impl LayerExecution {
    /// This layer's row in the shared per-layer result record.
    pub fn as_result(&self, model: &str, cfg: &SimConfig) -> LayerResult {
        let mut row = LayerResult::new(
            model,
            self.report.layer.clone(),
            cfg.mesh_cols,
            cfg.pes_per_router,
        )
        .tag("policy", self.policy.label())
        .metric("rounds", self.report.run.rounds_total as f64)
        .metric("sim_cycles", self.report.run.total_cycles as f64)
        .metric("reload_cycles", self.reload_cycles as f64)
        .metric("total_cycles", self.total_cycles as f64)
        .metric("energy_mj", self.report.power.total_j * 1e3);
        // Diagnostic column when the run carried probes (`cfg.probes`):
        // the measured max per-link utilization — the contention signal
        // `best_plan` reports surface next to the analytic ranking.
        if let Some(p) = &self.report.run.probes {
            row = row.metric("max_link_util", p.max_utilization());
        }
        row
    }
}

/// Result of running a whole model under a plan.
#[derive(Debug, Clone)]
pub struct NetworkRunReport {
    pub model: String,
    pub plan: String,
    pub layers: Vec<LayerExecution>,
    /// Per-layer shape metadata (MACs, volumes), parallel to `layers`.
    pub infos: Vec<LayerInfo>,
    /// Sum of per-layer totals (driver cycles + boundary reloads).
    pub total_cycles: u64,
    /// Sum of per-layer energies.
    pub total_energy_j: f64,
    /// Total MACs of the model (workload size, for roofline-style
    /// normalization of the totals).
    pub total_macs: u64,
    /// The configuration the run used (mesh geometry for the report rows).
    pub cfg: SimConfig,
}

impl NetworkRunReport {
    /// Per-layer rows in the shared [`LayerResult`] record, annotated
    /// with the layer's workload metadata.
    pub fn rows(&self) -> Vec<LayerResult> {
        self.layers
            .iter()
            .zip(&self.infos)
            .map(|(l, info)| {
                l.as_result(&self.model, &self.cfg)
                    .metric("macs", info.macs as f64)
                    .metric("out_words", info.output_volume as f64)
            })
            .collect()
    }
}

/// Evaluate one layer under one policy: the per-layer driver run, the
/// boundary reload charge, and the power roll-up over the combined
/// runtime (reload words are charged as row-bus traffic under bus
/// streaming). Shared by [`NetworkExecutor::run`] and the plan search, so
/// "best" is judged by exactly the metric the executor reports.
fn evaluate_layer(
    cfg: &SimConfig,
    index: usize,
    layer: &ConvLayer,
    policy: LayerPolicy,
    input_words: u64,
    charge_reload: bool,
) -> LayerExecution {
    // One scenario per (layer, policy) — the policy applied to the base
    // config, validated and frozen behind an Arc the `Network` and the
    // power roll-up share. This is the same per-layer entry point
    // `Scenario::simulate` exposes publicly; the executor only differs in
    // charging the boundary reload before pricing power.
    let scenario = ScenarioBuilder::from_config(policy.apply(cfg))
        .streaming(policy.streaming)
        .build()
        .expect("invalid SimConfig");
    let lcfg = scenario.shared_config();
    let run = scenario.run_raw(layer);
    let reload = if charge_reload {
        reload_cycles(&lcfg, policy.streaming, input_words)
    } else {
        0
    };
    let total_cycles = run.total_cycles + reload;
    // The reload words are charged energy through whatever carries them:
    // the row buses under bus streaming, closed-form router events under
    // mesh streaming (neither fabric moves the input feature map for
    // free). Only the power roll-up sees the merged counters — the
    // driver's own `run.net` stays the bare per-layer simulation.
    let mut bus = run.bus.clone();
    let mut priced_net = run.net.clone();
    if charge_reload {
        if policy.streaming == Streaming::Mesh {
            priced_net.merge(&reload_net_stats(&lcfg, policy.streaming, input_words));
        } else {
            bus.row_words += input_words;
            bus.active_cycles += reload;
        }
    }
    let power =
        power_report(&lcfg, policy.streaming, policy.collection, &priced_net, &bus, total_cycles);
    LayerExecution {
        index,
        policy,
        report: LayerReport { layer: layer.name.to_string(), run, power },
        reload_cycles: reload,
        total_cycles,
    }
}

/// The network-level execution engine.
#[derive(Debug, Clone)]
pub struct NetworkExecutor {
    cfg: SimConfig,
    charge_reload: bool,
}

impl NetworkExecutor {
    pub fn new(cfg: SimConfig) -> NetworkExecutor {
        NetworkExecutor { cfg, charge_reload: true }
    }

    /// Disable the inter-layer reload charge. The figure sweeps use this:
    /// the paper's per-layer comparisons (Figs. 13–16) measure round
    /// pipelines only, so charging boundaries there would dilute the
    /// ratios the figures plot.
    pub fn without_reload(mut self) -> NetworkExecutor {
        self.charge_reload = false;
        self
    }

    /// Worker threads for the layer fan-out, clamped so `layer workers ×
    /// cfg.intra_workers` (each simulation's band threads) stays within
    /// the host budget — see [`resolve_workers_clamped`].
    pub fn workers(&self) -> usize {
        resolve_workers_clamped(self.cfg.threads, self.cfg.intra_workers)
    }

    /// Run `model` under `plan`.
    pub fn run(&self, model: &Network, plan: &NetworkPlan) -> crate::Result<NetworkRunReport> {
        self.cfg.validate()?;
        plan.validate(model)?;
        let jobs: Vec<(usize, ConvLayer, LayerPolicy, u64)> = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| (i, l.clone(), plan.policy(i), model.input_words(i)))
            .collect();
        let layers = parallel_map(jobs, self.workers(), |(i, layer, policy, words)| {
            evaluate_layer(&self.cfg, *i, layer, *policy, *words, self.charge_reload)
        });
        let total_cycles = layers.iter().map(|l| l.total_cycles).sum();
        let total_energy_j = layers.iter().map(|l| l.report.power.total_j).sum();
        Ok(NetworkRunReport {
            model: model.name.clone(),
            plan: plan.name.clone(),
            layers,
            infos: model.layer_infos(),
            total_cycles,
            total_energy_j,
            total_macs: model.total_macs(),
            cfg: self.cfg.clone(),
        })
    }
}

/// Options of the per-layer plan search.
#[derive(Debug, Clone)]
pub struct PlanSearchOptions {
    /// Sim-verify every bus policy whose analytic zero-load latency is
    /// within this factor of the layer's analytic minimum. The default is
    /// generous next to the ≤5% analytic-vs-sim tolerance the test suite
    /// pins, so analytic misranking cannot prune the true winner.
    pub prune_factor: f64,
    /// Also sim-evaluate the six mesh-streaming policies (no closed form
    /// exists for them). Off by default: mesh operand delivery is
    /// strictly dominated by the two-way buses on this fabric (pinned by
    /// `dataflow::driver` tests), so the sims would only add cost.
    pub include_mesh: bool,
}

impl Default for PlanSearchOptions {
    fn default() -> Self {
        PlanSearchOptions { prune_factor: 1.3, include_mesh: false }
    }
}

/// One layer's search outcome: every sim-verified candidate with its
/// simulated total (executor metric: driver cycles + boundary reload).
#[derive(Debug, Clone)]
pub struct LayerSearch {
    pub index: usize,
    pub best: LayerPolicy,
    /// The winning candidate's full evaluation — the same
    /// `evaluate_layer` result `NetworkExecutor::run` would recompute for
    /// this (layer, policy), kept so the best-plan path never simulates
    /// twice.
    pub execution: LayerExecution,
    /// `(policy, simulated total_cycles)` for each sim-verified candidate,
    /// in grid order.
    pub evaluated: Vec<(LayerPolicy, u64)>,
}

/// Result of [`best_plan_search`]: the argmin plan plus the per-layer
/// evidence.
#[derive(Debug, Clone)]
pub struct PlanSearch {
    pub plan: NetworkPlan,
    pub layers: Vec<LayerSearch>,
}

impl PlanSearch {
    /// Assemble the executor report for the winning plan from the
    /// search's own evaluations. Simulations are pure functions, so this
    /// equals `NetworkExecutor::new(cfg).run(model, &self.plan)` without
    /// re-simulating every layer (asserted by the executor tests).
    pub fn run_report(&self, cfg: &SimConfig, model: &Network) -> NetworkRunReport {
        assert_eq!(
            self.layers.len(),
            model.len(),
            "plan search was built for a {}-layer model, not '{}' ({} layers)",
            self.layers.len(),
            model.name,
            model.len()
        );
        let layers: Vec<LayerExecution> =
            self.layers.iter().map(|l| l.execution.clone()).collect();
        let total_cycles = layers.iter().map(|l| l.total_cycles).sum();
        let total_energy_j = layers.iter().map(|l| l.report.power.total_j).sum();
        NetworkRunReport {
            model: model.name.clone(),
            plan: self.plan.name.clone(),
            layers,
            infos: model.layer_infos(),
            total_cycles,
            total_energy_j,
            total_macs: model.total_macs(),
            cfg: cfg.clone(),
        }
    }
}

/// Build the `best_per_layer` plan: for each layer, rank the bus policy
/// grid by the analytic closed forms ([`crate::analytic::latency_policy`]
/// plus the boundary reload), sim-verify the shortlist through the
/// executor's own per-layer evaluation, and keep the simulated argmin
/// (ties break toward the earliest grid entry — the paper's proposed
/// two-way/gather/OS). Layers fan out over the `cfg.threads` workers.
pub fn best_plan_search(
    cfg: &SimConfig,
    model: &Network,
    opts: &PlanSearchOptions,
) -> PlanSearch {
    let workers = resolve_workers_clamped(cfg.threads, cfg.intra_workers);
    let jobs: Vec<usize> = (0..model.len()).collect();
    let layers = parallel_map(jobs, workers, |&i| {
        let layer = &model.layers[i];
        let input_words = model.input_words(i);
        // Analytic ranking over the bus grid (mesh has no closed form).
        let scored: Vec<(LayerPolicy, u64)> = bus_policy_grid()
            .into_iter()
            .map(|p| {
                let lcfg = p.apply(cfg);
                let a = crate::analytic::latency_policy(cfg, &p, layer)
                    + reload_cycles(&lcfg, p.streaming, input_words);
                (p, a)
            })
            .collect();
        let amin = scored.iter().map(|&(_, a)| a).min().expect("non-empty grid");
        // The paper's proposed triple is always sim-verified, even when
        // the analytic ranking prunes it — it heads the list so ties
        // still break toward it, and `best` can never lose to the
        // proposed uniform plan by construction.
        let mut shortlist = vec![LayerPolicy::proposed()];
        shortlist.extend(
            scored
                .iter()
                .filter(|&&(p, a)| {
                    p != LayerPolicy::proposed() && a as f64 <= opts.prune_factor * amin as f64
                })
                .map(|&(p, _)| p),
        );
        if opts.include_mesh {
            shortlist.extend(mesh_policy_grid());
        }
        // Sim-verify the shortlist with the executor's own metric.
        let mut evals: Vec<(LayerPolicy, LayerExecution)> = shortlist
            .iter()
            .map(|&p| (p, evaluate_layer(cfg, i, layer, p, input_words, true)))
            .collect();
        let evaluated: Vec<(LayerPolicy, u64)> =
            evals.iter().map(|(p, e)| (*p, e.total_cycles)).collect();
        // Measured contention signal: with `cfg.probes` on, exact
        // total_cycles ties break toward the candidate with the lower
        // measured max link utilization (more headroom). Probe-off runs
        // carry no report, every candidate reads 0.0, and the earliest
        // grid entry keeps winning ties exactly as before.
        let max_util = |e: &LayerExecution| {
            e.report.run.probes.as_ref().map(|p| p.max_utilization()).unwrap_or(0.0)
        };
        let mut best_idx = 0;
        for (k, (_, e)) in evals.iter().enumerate().skip(1) {
            let b = &evals[best_idx].1;
            if e.total_cycles < b.total_cycles
                || (e.total_cycles == b.total_cycles && max_util(e) < max_util(b))
            {
                best_idx = k;
            }
        }
        let (best_policy, execution) = evals.swap_remove(best_idx);
        LayerSearch { index: i, best: best_policy, execution, evaluated }
    });
    let policies = layers.iter().map(|l| l.best).collect();
    PlanSearch {
        plan: NetworkPlan { name: "best".to_string(), policies },
        layers,
    }
}

/// The `best_per_layer` plan under the default search options.
pub fn best_plan(cfg: &SimConfig, model: &Network) -> NetworkPlan {
    best_plan_search(cfg, model, &PlanSearchOptions::default()).plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Collection, DataflowKind};
    use crate::dataflow::run_layer;

    fn tiny_model() -> Network {
        Network::new(
            "tiny",
            vec![
                ConvLayer { name: "t1", c: 4, h_in: 8, r: 3, stride: 1, pad: 1, q: 16 },
                ConvLayer { name: "t2", c: 16, h_in: 8, r: 1, stride: 2, pad: 0, q: 8 },
            ],
        )
    }

    #[test]
    fn executor_runs_a_plan_and_rolls_up_totals() {
        let mut cfg = SimConfig::table1_8x8(2);
        cfg.sim_rounds_cap = 2;
        let model = tiny_model();
        let mut plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
        plan.policies[1].collection = Collection::Ina;
        plan.policies[1].dataflow = DataflowKind::WeightStationary;
        let r = NetworkExecutor::new(cfg).run(&model, &plan).unwrap();
        assert_eq!(r.layers.len(), 2);
        assert_eq!(
            r.total_cycles,
            r.layers.iter().map(|l| l.total_cycles).sum::<u64>()
        );
        assert!(r.total_energy_j > 0.0);
        assert_eq!(r.total_macs, model.total_macs());
        // Mixed policies actually reach the per-layer runs.
        assert_eq!(r.layers[0].report.run.dataflow, "os");
        assert_eq!(r.layers[1].report.run.dataflow, "ws");
        // Reload is charged per layer and feeds the totals.
        assert!(r.layers.iter().all(|l| l.reload_cycles > 0));
        assert!(r.layers.iter().all(|l| l.total_cycles
            == l.report.run.total_cycles + l.reload_cycles));
        // Rows reuse the shared LayerResult record.
        let rows = r.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].tags[0].1, plan.policies[1].label());
        assert_eq!(rows[0].get("total_cycles"), Some(r.layers[0].total_cycles as f64));
    }

    #[test]
    fn without_reload_matches_the_bare_driver() {
        let mut cfg = SimConfig::table1_8x8(2);
        cfg.sim_rounds_cap = 2;
        let model = tiny_model();
        let plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
        let r = NetworkExecutor::new(cfg.clone()).without_reload().run(&model, &plan).unwrap();
        for (l, layer) in r.layers.iter().zip(&model.layers) {
            let direct = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, layer);
            assert_eq!(l.reload_cycles, 0);
            assert_eq!(l.total_cycles, direct.total_cycles);
        }
    }

    #[test]
    fn mismatched_plan_is_rejected() {
        let cfg = SimConfig::table1_8x8(1);
        let model = tiny_model();
        let plan = NetworkPlan::uniform(LayerPolicy::proposed(), 5);
        assert!(NetworkExecutor::new(cfg).run(&model, &plan).is_err());
    }

    #[test]
    fn best_plan_search_shortlists_and_verifies() {
        let mut cfg = SimConfig::table1_8x8(2);
        cfg.sim_rounds_cap = 2;
        let model = tiny_model();
        let search = best_plan_search(&cfg, &model, &PlanSearchOptions::default());
        assert_eq!(search.plan.policies.len(), model.len());
        assert_eq!(search.plan.name, "best");
        for l in &search.layers {
            assert!(!l.evaluated.is_empty(), "layer {} verified nothing", l.index);
            // The chosen policy carries the minimal simulated total.
            let min = l.evaluated.iter().map(|&(_, t)| t).min().unwrap();
            let chosen = l.evaluated.iter().find(|&&(p, _)| p == l.best).unwrap();
            assert_eq!(chosen.1, min);
            assert_eq!(l.execution.total_cycles, min);
            assert_eq!(l.execution.policy, l.best);
            // The proposed triple is always sim-verified (shortlist head).
            assert_eq!(l.evaluated[0].0, LayerPolicy::proposed());
            // Mesh is excluded by default.
            assert!(l.evaluated.iter().all(|(p, _)| p.streaming != Streaming::Mesh));
        }
        // The cached report equals a fresh executor run of the same plan.
        let cached = search.run_report(&cfg, &model);
        let rerun = NetworkExecutor::new(cfg).run(&model, &search.plan).unwrap();
        assert_eq!(cached.total_cycles, rerun.total_cycles);
        assert_eq!(cached.total_energy_j, rerun.total_energy_j);
        assert_eq!(cached.layers.len(), rerun.layers.len());
    }
}
