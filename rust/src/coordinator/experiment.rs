//! One experiment = (network config, streaming architecture, collection
//! scheme) applied to a workload. This is the unit every figure sweep and
//! bench composes.
//!
//! `Experiment` predates the [`crate::api::Scenario`] façade and is now a
//! thin shim over it: [`Experiment::run_layer`] builds a scenario once
//! and delegates to [`crate::api::Scenario::simulate`], so the sweeps and
//! the typed public API cannot drift apart.

use crate::api::{Scenario, ScenarioBuilder};
use crate::config::{Collection, SimConfig, Streaming};
use crate::dataflow::LayerRunResult;
use crate::models::ConvLayer;
use crate::power::PowerReport;

/// An architecture point under evaluation.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub cfg: SimConfig,
    pub streaming: Streaming,
    pub collection: Collection,
}

/// Result of one layer under one experiment.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: String,
    pub run: LayerRunResult,
    pub power: PowerReport,
}

/// Result of a whole model (sum over conv layers, §5.3 "total runtime
/// latency" — the output feature map of each layer is completely generated
/// before the next layer starts, §5.1).
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub layers: Vec<LayerReport>,
    pub total_cycles: u64,
    pub total_energy_j: f64,
}

impl Experiment {
    pub fn new(cfg: SimConfig, streaming: Streaming, collection: Collection) -> Experiment {
        Experiment { cfg, streaming, collection }
    }

    /// The paper's proposed architecture: two-way streaming + gather.
    pub fn proposed(cfg: SimConfig) -> Experiment {
        Experiment::new(cfg, Streaming::TwoWay, Collection::Gather)
    }

    /// The paper's baseline: two-way streaming + repetitive unicast
    /// (§5.3 compares collection schemes on the same streaming fabric).
    pub fn baseline_ru(cfg: SimConfig) -> Experiment {
        Experiment::new(cfg, Streaming::TwoWay, Collection::RepetitiveUnicast)
    }

    /// The gather-only architecture of [27]: gather packets but operand
    /// distribution over the mesh itself.
    pub fn gather_only(cfg: SimConfig) -> Experiment {
        Experiment::new(cfg, Streaming::Mesh, Collection::Gather)
    }

    /// The [`Scenario`] this experiment denotes. Panics on an invalid
    /// `cfg` — exactly the failure `Network::shared` raised before the
    /// façade existed; callers wanting a typed error build the scenario
    /// themselves through [`ScenarioBuilder`].
    pub fn scenario(&self) -> Scenario {
        ScenarioBuilder::from_config(self.cfg.clone())
            .streaming(self.streaming)
            .collection(self.collection)
            .build()
            .expect("invalid SimConfig")
    }

    pub fn run_layer(&self, layer: &ConvLayer) -> LayerReport {
        self.scenario().simulate(layer)
    }

    pub fn run_model(&self, layers: &[ConvLayer]) -> ModelReport {
        // One scenario for the whole model: every layer's `Network`
        // clones the config `Arc`, not the `SimConfig`.
        let scenario = self.scenario();
        let layers: Vec<LayerReport> = layers.iter().map(|l| scenario.simulate(l)).collect();
        let total_cycles = layers.iter().map(|l| l.run.total_cycles).sum();
        let total_energy_j = layers.iter().map(|l| l.power.total_j).sum();
        ModelReport { layers, total_cycles, total_energy_j }
    }
}

/// Improvement factor of `ours` over `base` (>1 means ours is better) for
/// latency.
pub fn latency_improvement(base: &LayerReport, ours: &LayerReport) -> f64 {
    base.run.total_cycles as f64 / ours.run.total_cycles as f64
}

/// Improvement factor for *network* power consumption, as in Figs.
/// 15(b)/(d) and 16(b)/(d): the paper's Orion-estimated NoC power (router
/// dynamic + static over the runtime). The streaming buses are identical
/// on both sides of the comparison and are reported separately by DSENT
/// in the paper, so they are excluded from this ratio.
pub fn power_improvement(base: &LayerReport, ours: &LayerReport) -> f64 {
    (base.power.router_dynamic_j + base.power.router_static_j)
        / (ours.power.router_dynamic_j + ours.power.router_static_j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ConvLayer;

    fn tiny() -> ConvLayer {
        ConvLayer { name: "tiny", c: 4, h_in: 8, r: 3, stride: 1, pad: 1, q: 16 }
    }

    #[test]
    fn proposed_beats_baseline_on_congested_config() {
        let cfg = SimConfig::table1_8x8(8);
        let ours = Experiment::proposed(cfg.clone()).run_layer(&tiny());
        let base = Experiment::baseline_ru(cfg).run_layer(&tiny());
        let li = latency_improvement(&base, &ours);
        let pi = power_improvement(&base, &ours);
        assert!(li >= 1.0, "latency improvement {li}");
        assert!(pi >= 1.0, "power improvement {pi}");
    }

    #[test]
    fn model_report_sums_layers() {
        let cfg = SimConfig::table1_8x8(2);
        let e = Experiment::proposed(cfg);
        let m = e.run_model(&[tiny(), tiny()]);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.total_cycles, m.layers.iter().map(|l| l.run.total_cycles).sum::<u64>());
    }
}
