//! Experiment orchestration: the [`experiment`] unit, the whole-network
//! [`executor`] (plan-driven model runs with per-layer policies), the
//! per-figure [`sweep`] generators, text/JSON [`report`] formatting and
//! the leader/worker [`server`] that fans independent simulations out
//! over threads.

pub mod executor;
pub mod experiment;
pub mod report;
pub mod server;
pub mod sweep;

pub use executor::{best_plan, NetworkExecutor, NetworkRunReport};
pub use experiment::{latency_improvement, power_improvement, Experiment, LayerReport, ModelReport};
