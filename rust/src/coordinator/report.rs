//! Report formatting: aligned text tables (what the paper's figures plot)
//! and JSON for downstream tooling.

use crate::noc::faults::DegradationReport;
use crate::noc::probes::ProbeReport;
use crate::util::json::Json;

use super::executor::NetworkRunReport;
use super::sweep::{DataflowCompareRow, Fig12Series};

/// One per-layer result row — the single record shared by every per-layer
/// producer: the figure sweeps (`fig13` / `fig14` / `fig_model`, which
/// used to carry three near-identical structs) and the network executor's
/// per-layer rows. A row names its workload point (model, layer, mesh,
/// PEs/router) plus free-form string `tags` (e.g. the executor's policy
/// triple) and named scalar `metrics` in presentation order; the text and
/// JSON renderers below consume the keys directly, so producers stay
/// declarative.
#[derive(Debug, Clone)]
pub struct LayerResult {
    pub model: String,
    pub layer: String,
    pub mesh: usize,
    pub pes_per_router: usize,
    /// Free-form labels, e.g. `("policy", "two-way/gather/os")`.
    pub tags: Vec<(&'static str, String)>,
    /// Named scalar metrics, e.g. `("latency_improvement", 1.42)`.
    pub metrics: Vec<(&'static str, f64)>,
}

impl LayerResult {
    pub fn new(
        model: impl Into<String>,
        layer: impl Into<String>,
        mesh: usize,
        pes_per_router: usize,
    ) -> LayerResult {
        LayerResult {
            model: model.into(),
            layer: layer.into(),
            mesh,
            pes_per_router,
            tags: Vec::new(),
            metrics: Vec::new(),
        }
    }

    pub fn tag(mut self, key: &'static str, value: impl Into<String>) -> LayerResult {
        self.tags.push((key, value.into()));
        self
    }

    pub fn metric(mut self, key: &'static str, value: f64) -> LayerResult {
        self.metrics.push((key, value));
        self
    }

    /// Look a metric up by key.
    pub fn get(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }
}

/// Format a metric: counts print as integers, ratios with 2 decimals.
fn metric_cell(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        f2(v)
    }
}

/// Render per-layer result rows as an aligned table. Column layout comes
/// from the first row's tag/metric keys (all rows of one report share
/// them).
pub fn layer_results_text(rows: &[LayerResult]) -> String {
    let Some(first) = rows.first() else { return String::new() };
    let mut headers: Vec<&str> = vec!["model", "layer", "mesh", "PEs/router"];
    headers.extend(first.tags.iter().map(|(k, _)| *k));
    headers.extend(first.metrics.iter().map(|(k, _)| *k));
    let data: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut cells = vec![
                r.model.clone(),
                r.layer.clone(),
                format!("{0}x{0}", r.mesh),
                r.pes_per_router.to_string(),
            ];
            cells.extend(r.tags.iter().map(|(_, v)| v.clone()));
            cells.extend(r.metrics.iter().map(|(_, v)| metric_cell(*v)));
            cells
        })
        .collect();
    table(&headers, &data)
}

/// JSON array of per-layer result rows.
pub fn layer_results_json(rows: &[LayerResult]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("model", Json::Str(r.model.clone()))
                    .set("layer", Json::Str(r.layer.clone()))
                    .set("mesh", Json::Num(r.mesh as f64))
                    .set("pes_per_router", Json::Num(r.pes_per_router as f64));
                for (k, v) in &r.tags {
                    o.set(k, Json::Str(v.clone()));
                }
                for (k, v) in &r.metrics {
                    o.set(k, Json::Num(*v));
                }
                o
            })
            .collect(),
    )
}

/// Render an aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let header: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&header, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Fig. 12 text report: normalized latency/power vs the δ<κ point.
pub fn fig12_text(series: &[Fig12Series]) -> String {
    let mut rows = Vec::new();
    for s in series {
        let base = &s.points[0];
        for p in &s.points {
            rows.push(vec![
                s.pes_per_router.to_string(),
                if p.delta_over_kappa == 0 { "<1".into() } else { p.delta_over_kappa.to_string() },
                p.latency_cycles.to_string(),
                f3(p.latency_cycles as f64 / base.latency_cycles as f64),
                f3(p.energy_j / base.energy_j),
                p.packets.to_string(),
            ]);
        }
    }
    table(
        &["PEs/router", "δ/κ", "latency(cyc)", "norm.latency", "norm.power", "gather pkts"],
        &rows,
    )
}

pub fn fig12_json(series: &[Fig12Series]) -> Json {
    Json::Arr(
        series
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("pes_per_router", Json::Num(s.pes_per_router as f64));
                o.set(
                    "points",
                    Json::Arr(
                        s.points
                            .iter()
                            .map(|p| {
                                let mut q = Json::obj();
                                q.set("delta_over_kappa", Json::Num(p.delta_over_kappa as f64))
                                    .set("delta", Json::Num(p.delta as f64))
                                    .set("latency_cycles", Json::Num(p.latency_cycles as f64))
                                    .set("energy_j", Json::Num(p.energy_j))
                                    .set("packets", Json::Num(p.packets as f64));
                                q
                            })
                            .collect(),
                    ),
                );
                o
            })
            .collect(),
    )
}

/// Fig. 13 text report.
pub fn fig13_text(rows: &[LayerResult]) -> String {
    layer_results_text(rows)
}

/// Fig. 14 text report: per-layer rows plus the improvement averages the
/// paper quotes.
pub fn fig14_text(rows: &[LayerResult]) -> String {
    let mut data = rows.to_vec();
    if !rows.is_empty() {
        let avg = |key: &str| {
            rows.iter().filter_map(|r| r.get(key)).sum::<f64>() / rows.len() as f64
        };
        let mut mean = LayerResult::new("average", "-", rows[0].mesh, rows[0].pes_per_router);
        for &(k, _) in &rows[0].metrics {
            mean = mean.metric(k, avg(k));
        }
        data.push(mean);
    }
    layer_results_text(&data)
}

/// Figs. 15/16 text report.
pub fn fig_model_text(points: &[LayerResult]) -> String {
    layer_results_text(points)
}

pub fn fig_model_json(points: &[LayerResult]) -> Json {
    layer_results_json(points)
}

/// Whole-network execution report (`noc-dnn model`): one [`LayerResult`]
/// row per layer plus the model totals.
pub fn network_run_text(r: &NetworkRunReport) -> String {
    let mut out = layer_results_text(&r.rows());
    out.push_str(&format!(
        "TOTAL [{} under plan '{}']: {} cycles = {:.3} ms, {:.3} mJ, {} MACs\n",
        r.model,
        r.plan,
        r.total_cycles,
        r.total_cycles as f64 / r.cfg.clock_hz * 1e3,
        r.total_energy_j * 1e3,
        r.total_macs
    ));
    out
}

/// Whole-network execution report as JSON: per-layer rows + model totals.
pub fn network_run_json(r: &NetworkRunReport) -> Json {
    let mut o = Json::obj();
    o.set("model", Json::Str(r.model.clone()))
        .set("plan", Json::Str(r.plan.clone()))
        .set("layers", layer_results_json(&r.rows()))
        .set("total_cycles", Json::Num(r.total_cycles as f64))
        .set("total_energy_j", Json::Num(r.total_energy_j))
        .set("total_macs", Json::Num(r.total_macs as f64));
    o
}

/// Text link-utilization heatmap for one analyzed layer (`noc-dnn
/// analyze`): a router grid whose cells show the utilization (percent of
/// the one-flit-per-cycle link capacity) of the router's hottest
/// *outgoing* link, suffixed with that link's direction letter; `·`
/// marks routers that sent nothing. A top-links table follows, so the
/// per-direction detail behind each cell is one glance away.
pub fn probe_heatmap_text(layer: &str, p: &ProbeReport) -> String {
    let (mut cols, mut rows) = (0u16, 0u16);
    for l in &p.links {
        cols = cols.max(l.from.x + 1).max(l.to.x + 1);
        rows = rows.max(l.from.y + 1).max(l.to.y + 1);
    }
    let mut out = format!(
        "link-utilization heatmap [{layer}] ({} cycles; % of link capacity, \
         hottest outgoing direction per router)\n",
        p.cycles
    );
    let mut headers: Vec<String> = vec!["y\\x".to_string()];
    headers.extend((0..cols).map(|x| x.to_string()));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let grid: Vec<Vec<String>> = (0..rows)
        .map(|y| {
            let mut cells = vec![y.to_string()];
            for x in 0..cols {
                let hot = p
                    .links
                    .iter()
                    .filter(|l| l.from.x == x && l.from.y == y)
                    .fold(None, |best: Option<&crate::noc::probes::LinkRecord>, l| {
                        match best {
                            Some(b) if b.flits >= l.flits => Some(b),
                            _ => Some(l),
                        }
                    });
                cells.push(match hot {
                    Some(l) if l.flits > 0 => {
                        format!("{:.1}{}", 100.0 * l.utilization(p.cycles), l.port.letter())
                    }
                    _ => "·".to_string(),
                });
            }
            cells
        })
        .collect();
    out.push_str(&table(&header_refs, &grid));
    // Top links by traffic, ties in row-major order (stable sort).
    let mut by_flits: Vec<&crate::noc::probes::LinkRecord> =
        p.links.iter().filter(|l| l.flits > 0).collect();
    by_flits.sort_by(|a, b| b.flits.cmp(&a.flits));
    if !by_flits.is_empty() {
        out.push_str("hottest links:\n");
        let data: Vec<Vec<String>> = by_flits
            .iter()
            .take(5)
            .map(|l| {
                vec![
                    l.label(),
                    l.flits.to_string(),
                    l.payloads.to_string(),
                    l.stream_flits.to_string(),
                    l.result_flits().to_string(),
                    l.peak_bucket_flits.to_string(),
                    l.blocked_total().to_string(),
                    f2(100.0 * l.utilization(p.cycles)),
                ]
            })
            .collect();
        out.push_str(&table(
            &["link", "flits", "payloads", "stream", "result", "peak/bkt", "blocked", "util%"],
            &data,
        ));
    }
    out
}

/// One `noc-dnn analyze` layer: the probe snapshot plus the fault
/// degradation accounting (present iff the run was configured with
/// `--faults` / `SimConfig::faults`).
#[derive(Debug, Clone)]
pub struct AnalyzedLayer {
    pub name: String,
    pub probes: ProbeReport<'static>,
    pub degraded: Option<DegradationReport>,
}

/// Bottleneck-attribution table (`noc-dnn analyze`): per layer, the link
/// that bounds the run, its dominant traffic stage (retransmission-heavy
/// links attribute to their own class), utilization, busiest VC and
/// credit-blocked cycles.
pub fn bottleneck_table_text(layers: &[AnalyzedLayer]) -> String {
    let data: Vec<Vec<String>> = layers
        .iter()
        .map(|l| match l.probes.bottleneck() {
            Some(b) => vec![
                l.name.clone(),
                l.probes.cycles.to_string(),
                b.label(),
                b.stage.label().to_string(),
                f2(100.0 * b.utilization),
                b.vc.to_string(),
                b.blocked_cycles.to_string(),
                l.probes.total_flits.to_string(),
            ],
            None => vec![
                l.name.clone(),
                l.probes.cycles.to_string(),
                "-".to_string(),
                "-".to_string(),
                "0.00".to_string(),
                "-".to_string(),
                "0".to_string(),
                "0".to_string(),
            ],
        })
        .collect();
    table(
        &["layer", "cycles", "bottleneck", "stage", "util%", "vc", "blocked", "link flits"],
        &data,
    )
}

/// Fault-degradation table (`noc-dnn analyze` under `--faults`): the
/// per-layer `DegradationReport` counters. Empty when no layer carried a
/// fault plan, so fault-free output is unchanged.
pub fn degradation_table_text(layers: &[AnalyzedLayer]) -> String {
    let with: Vec<(&str, &DegradationReport)> = layers
        .iter()
        .filter_map(|l| l.degraded.as_ref().map(|d| (l.name.as_str(), d)))
        .collect();
    if with.is_empty() {
        return String::new();
    }
    let data: Vec<Vec<String>> = with
        .iter()
        .map(|(name, d)| {
            vec![
                name.to_string(),
                d.flits_corrupted.to_string(),
                d.retransmissions.to_string(),
                d.retries_exhausted.to_string(),
                d.packets_dropped.to_string(),
                d.payloads_dropped.to_string(),
                d.missing_contributors.to_string(),
                d.detour_hops.to_string(),
                format!("{}/{}", d.streams_truncated, d.streams_dropped),
            ]
        })
        .collect();
    let mut out = "fault degradation (measured prefix):\n".to_string();
    out.push_str(&table(
        &[
            "layer",
            "corrupt",
            "retx",
            "exhaust",
            "pkt drop",
            "payload drop",
            "missing",
            "detours",
            "trunc/drop",
        ],
        &data,
    ));
    out
}

/// `noc-dnn analyze --json`: per-layer probe snapshots (links, series,
/// bottleneck attribution, fault degradation) under the model header.
pub fn analyze_json(model: &str, layers: &[AnalyzedLayer]) -> Json {
    let mut o = Json::obj();
    o.set("model", Json::Str(model.to_string()));
    o.set(
        "layers",
        Json::Arr(
            layers
                .iter()
                .map(|l| {
                    let mut j = l.probes.to_json();
                    j.set("layer", Json::Str(l.name.clone()));
                    if let Some(d) = &l.degraded {
                        j.set("degraded", d.to_json());
                    }
                    j
                })
                .collect(),
        ),
    );
    o
}

/// OS-vs-WS study text report (the `noc-dnn compare` output): one row
/// per streaming mode × collection scheme (RU vs gather vs INA), with
/// both dataflows' latency/energy and the WS-vs-OS ratios.
pub fn dataflow_compare_text(rows: &[DataflowCompareRow]) -> String {
    let data: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.streaming.label().to_string(),
                r.collection.label().to_string(),
                r.os_cycles.to_string(),
                r.ws_cycles.to_string(),
                f2(r.ws_speedup()),
                f3(r.os_energy_j * 1e3),
                f3(r.ws_energy_j * 1e3),
                f2(r.ws_energy_improvement()),
            ]
        })
        .collect();
    table(
        &[
            "streaming",
            "collection",
            "OS cycles",
            "WS cycles",
            "WS speedup",
            "OS mJ",
            "WS mJ",
            "WS energy impr",
        ],
        &data,
    )
}

/// OS-vs-WS study JSON report.
pub fn dataflow_compare_json(rows: &[DataflowCompareRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("streaming", Json::Str(r.streaming.label().to_string()))
                    .set("collection", Json::Str(r.collection.label().to_string()))
                    .set("os_cycles", Json::Num(r.os_cycles as f64))
                    .set("ws_cycles", Json::Num(r.ws_cycles as f64))
                    .set("ws_speedup", Json::Num(r.ws_speedup()))
                    .set("os_energy_j", Json::Num(r.os_energy_j))
                    .set("ws_energy_j", Json::Num(r.ws_energy_j))
                    .set("ws_energy_improvement", Json::Num(r.ws_energy_improvement()));
                o
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(
            &["a", "long-header"],
            &[vec!["1".into(), "2".into()], vec!["100".into(), "x".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        // All data lines same width.
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[0].contains("long-header"));
    }

    #[test]
    fn float_formats() {
        assert_eq!(f2(1.867), "1.87");
        assert_eq!(f3(0.12345), "0.123");
    }

    #[test]
    fn layer_results_render_tags_and_metrics() {
        let rows = vec![
            LayerResult::new("alexnet", "conv1", 8, 4)
                .tag("policy", "two-way/gather/os")
                .metric("total_cycles", 1234.0)
                .metric("latency_improvement", 1.421),
            LayerResult::new("alexnet", "conv2", 8, 4)
                .tag("policy", "two-way/INA/ws")
                .metric("total_cycles", 99.0)
                .metric("latency_improvement", 0.97),
        ];
        let t = layer_results_text(&rows);
        assert!(t.contains("policy"), "tag header missing:\n{t}");
        assert!(t.contains("two-way/INA/ws"));
        assert!(t.contains("1234"), "counts render as integers:\n{t}");
        assert!(t.contains("1.42"), "ratios render with 2 decimals:\n{t}");
        let j = layer_results_json(&rows);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("layer").unwrap().as_str(), Some("conv1"));
        assert_eq!(arr[0].get("total_cycles").unwrap().as_u64(), Some(1234));
        assert_eq!(arr[1].get("policy").unwrap().as_str(), Some("two-way/INA/ws"));
        assert_eq!(rows[0].get("latency_improvement"), Some(1.421));
        assert_eq!(rows[0].get("absent"), None);
        assert!(layer_results_text(&[]).is_empty());
    }

    #[test]
    fn fig14_report_appends_the_average_row() {
        let rows = vec![
            LayerResult::new("alexnet", "conv1", 8, 1).metric("two_way_improvement", 2.0),
            LayerResult::new("alexnet", "conv2", 8, 1).metric("two_way_improvement", 3.0),
        ];
        let t = fig14_text(&rows);
        assert!(t.contains("average"));
        assert!(t.contains("2.50"), "mean of 2 and 3 missing:\n{t}");
    }

    #[test]
    fn analyze_reports_render_heatmap_bottleneck_and_json() {
        use crate::noc::probes::LinkProbes;
        use crate::noc::topology::Mesh2D;
        use crate::noc::Port;
        let topo = Mesh2D::new(2, 2);
        let mut probes = LinkProbes::new(4, 2);
        // Router (0,1) east is the hot link: 3 collection flits.
        for c in 0..3 {
            probes.record_traversal(2, Port::East.index(), 0, c, c == 0, 2, false);
        }
        probes.record_traversal(0, Port::South.index(), 1, 0, false, 0, true);
        let p = probes.report(&topo, 2, 2, 100);
        let hm = probe_heatmap_text("conv1", &p);
        assert!(hm.contains("conv1"), "layer header missing:\n{hm}");
        assert!(hm.contains("3.0E"), "hot-cell percent+direction missing:\n{hm}");
        assert!(hm.contains("·"), "idle routers marked:\n{hm}");
        assert!(hm.contains("(0,1)->E(1,1)"), "top-links table missing:\n{hm}");
        let analyzed = [AnalyzedLayer {
            name: "conv1".to_string(),
            probes: p.clone().into_owned(),
            degraded: None,
        }];
        let bt = bottleneck_table_text(&analyzed);
        assert!(bt.contains("(0,1)->E(1,1)"), "bottleneck link missing:\n{bt}");
        assert!(bt.contains("collection"), "stage missing:\n{bt}");
        // Fault-free analyze output carries no degradation section.
        assert!(degradation_table_text(&analyzed).is_empty());
        let j = analyze_json("alexnet", &analyzed);
        assert_eq!(j.get("model").unwrap().as_str(), Some("alexnet"));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].get("layer").unwrap().as_str(), Some("conv1"));
        assert_eq!(
            layers[0].get("bottleneck").unwrap().get("stage").unwrap().as_str(),
            Some("collection")
        );
        assert!(layers[0].get("links").unwrap().as_arr().unwrap().len() >= 8);
        assert!(layers[0].get("degraded").is_none());
    }

    #[test]
    fn degraded_layers_render_the_fault_table_and_json() {
        use crate::noc::probes::LinkProbes;
        use crate::noc::topology::Mesh2D;
        let p = LinkProbes::new(4, 2).report(&Mesh2D::new(2, 2), 2, 2, 10);
        let analyzed = [AnalyzedLayer {
            name: "conv1".to_string(),
            probes: p.into_owned(),
            degraded: Some(DegradationReport {
                flits_corrupted: 7,
                retransmissions: 5,
                payloads_dropped: 3,
                ..Default::default()
            }),
        }];
        let t = degradation_table_text(&analyzed);
        assert!(t.contains("fault degradation"), "header missing:\n{t}");
        assert!(t.contains("conv1") && t.contains("7") && t.contains("5"), "counters:\n{t}");
        let j = analyze_json("alexnet", &analyzed);
        let d = j.get("layers").unwrap().as_arr().unwrap()[0].get("degraded").unwrap();
        assert_eq!(d.get("flits_corrupted").unwrap().as_u64(), Some(7));
        assert_eq!(d.get("payloads_dropped").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn bottleneck_table_handles_idle_layers() {
        use crate::noc::probes::LinkProbes;
        use crate::noc::topology::Mesh2D;
        let p = LinkProbes::new(4, 2).report(&Mesh2D::new(2, 2), 2, 2, 10);
        let t = bottleneck_table_text(&[AnalyzedLayer {
            name: "idle".to_string(),
            probes: p.into_owned(),
            degraded: None,
        }]);
        assert!(t.contains("idle"));
        assert!(t.contains("-"), "idle layers render placeholders:\n{t}");
    }

    #[test]
    fn dataflow_compare_report_renders_ratios() {
        use crate::config::{Collection, Streaming};
        let rows = vec![DataflowCompareRow {
            streaming: Streaming::TwoWay,
            collection: Collection::Gather,
            os_cycles: 200,
            ws_cycles: 100,
            os_energy_j: 4.0e-3,
            ws_energy_j: 1.0e-3,
        }];
        let t = dataflow_compare_text(&rows);
        assert!(t.contains("2.00"), "speedup column missing:\n{t}");
        assert!(t.contains("4.00"), "energy column missing:\n{t}");
        let j = dataflow_compare_json(&rows);
        assert!(j.to_string().contains("ws_speedup"));
    }
}
