//! Report formatting: aligned text tables (what the paper's figures plot)
//! and JSON for downstream tooling.

use crate::util::json::Json;

use super::sweep::{DataflowCompareRow, Fig12Series, Fig13Row, Fig14Row, ModelFigPoint};

/// Render an aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let header: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&header, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Fig. 12 text report: normalized latency/power vs the δ<κ point.
pub fn fig12_text(series: &[Fig12Series]) -> String {
    let mut rows = Vec::new();
    for s in series {
        let base = &s.points[0];
        for p in &s.points {
            rows.push(vec![
                s.pes_per_router.to_string(),
                if p.delta_over_kappa == 0 { "<1".into() } else { p.delta_over_kappa.to_string() },
                p.latency_cycles.to_string(),
                f3(p.latency_cycles as f64 / base.latency_cycles as f64),
                f3(p.energy_j / base.energy_j),
                p.packets.to_string(),
            ]);
        }
    }
    table(
        &["PEs/router", "δ/κ", "latency(cyc)", "norm.latency", "norm.power", "gather pkts"],
        &rows,
    )
}

pub fn fig12_json(series: &[Fig12Series]) -> Json {
    Json::Arr(
        series
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("pes_per_router", Json::Num(s.pes_per_router as f64));
                o.set(
                    "points",
                    Json::Arr(
                        s.points
                            .iter()
                            .map(|p| {
                                let mut q = Json::obj();
                                q.set("delta_over_kappa", Json::Num(p.delta_over_kappa as f64))
                                    .set("delta", Json::Num(p.delta as f64))
                                    .set("latency_cycles", Json::Num(p.latency_cycles as f64))
                                    .set("energy_j", Json::Num(p.energy_j))
                                    .set("packets", Json::Num(p.packets as f64));
                                q
                            })
                            .collect(),
                    ),
                );
                o
            })
            .collect(),
    )
}

/// Fig. 13 text report.
pub fn fig13_text(rows: &[Fig13Row]) -> String {
    let data: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{0}x{0}", r.mesh),
                r.pes_per_router.to_string(),
                f2(r.one_large.0),
                f2(r.one_large.1),
                f2(r.two_small.0),
                f2(r.two_small.1),
            ]
        })
        .collect();
    table(
        &["mesh", "PEs/router", "1pkt lat.impr", "1pkt pow.impr", "2pkt lat.impr", "2pkt pow.impr"],
        &data,
    )
}

/// Fig. 14 text report.
pub fn fig14_text(rows: &[Fig14Row]) -> String {
    let mut data: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![r.model.to_string(), r.layer.clone(), f2(r.two_way), f2(r.one_way)]
        })
        .collect();
    let avg2 = rows.iter().map(|r| r.two_way).sum::<f64>() / rows.len() as f64;
    let avg1 = rows.iter().map(|r| r.one_way).sum::<f64>() / rows.len() as f64;
    data.push(vec!["average".into(), "-".into(), f2(avg2), f2(avg1)]);
    table(&["model", "layer", "2-way vs gather-only", "1-way vs gather-only"], &data)
}

/// Figs. 15/16 text report.
pub fn fig_model_text(points: &[ModelFigPoint]) -> String {
    let data: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.layer.clone(),
                format!("{0}x{0}", p.mesh),
                p.pes_per_router.to_string(),
                f2(p.latency_improvement),
                f2(p.power_improvement),
            ]
        })
        .collect();
    table(&["layer", "mesh", "PEs/router", "latency impr (RU/G)", "power impr (RU/G)"], &data)
}

pub fn fig_model_json(points: &[ModelFigPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("layer", Json::Str(p.layer.clone()))
                    .set("mesh", Json::Num(p.mesh as f64))
                    .set("pes_per_router", Json::Num(p.pes_per_router as f64))
                    .set("latency_improvement", Json::Num(p.latency_improvement))
                    .set("power_improvement", Json::Num(p.power_improvement));
                o
            })
            .collect(),
    )
}

/// OS-vs-WS study text report (the `noc-dnn compare` output): one row
/// per streaming mode × collection scheme (RU vs gather vs INA), with
/// both dataflows' latency/energy and the WS-vs-OS ratios.
pub fn dataflow_compare_text(rows: &[DataflowCompareRow]) -> String {
    let data: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.streaming.label().to_string(),
                r.collection.label().to_string(),
                r.os_cycles.to_string(),
                r.ws_cycles.to_string(),
                f2(r.ws_speedup()),
                f3(r.os_energy_j * 1e3),
                f3(r.ws_energy_j * 1e3),
                f2(r.ws_energy_improvement()),
            ]
        })
        .collect();
    table(
        &[
            "streaming",
            "collection",
            "OS cycles",
            "WS cycles",
            "WS speedup",
            "OS mJ",
            "WS mJ",
            "WS energy impr",
        ],
        &data,
    )
}

/// OS-vs-WS study JSON report.
pub fn dataflow_compare_json(rows: &[DataflowCompareRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("streaming", Json::Str(r.streaming.label().to_string()))
                    .set("collection", Json::Str(r.collection.label().to_string()))
                    .set("os_cycles", Json::Num(r.os_cycles as f64))
                    .set("ws_cycles", Json::Num(r.ws_cycles as f64))
                    .set("ws_speedup", Json::Num(r.ws_speedup()))
                    .set("os_energy_j", Json::Num(r.os_energy_j))
                    .set("ws_energy_j", Json::Num(r.ws_energy_j))
                    .set("ws_energy_improvement", Json::Num(r.ws_energy_improvement()));
                o
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(
            &["a", "long-header"],
            &[vec!["1".into(), "2".into()], vec!["100".into(), "x".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        // All data lines same width.
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[0].contains("long-header"));
    }

    #[test]
    fn float_formats() {
        assert_eq!(f2(1.867), "1.87");
        assert_eq!(f3(0.12345), "0.123");
    }

    #[test]
    fn dataflow_compare_report_renders_ratios() {
        use crate::config::{Collection, Streaming};
        let rows = vec![DataflowCompareRow {
            streaming: Streaming::TwoWay,
            collection: Collection::Gather,
            os_cycles: 200,
            ws_cycles: 100,
            os_energy_j: 4.0e-3,
            ws_energy_j: 1.0e-3,
        }];
        let t = dataflow_compare_text(&rows);
        assert!(t.contains("2.00"), "speedup column missing:\n{t}");
        assert!(t.contains("4.00"), "energy column missing:\n{t}");
        let j = dataflow_compare_json(&rows);
        assert!(j.to_string().contains("ws_speedup"));
    }
}
