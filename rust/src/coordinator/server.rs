//! Leader/worker execution of independent experiments.
//!
//! Figure sweeps run dozens of independent simulations; this module fans
//! them out over OS threads (the offline environment has no async runtime,
//! and simulations are CPU-bound anyway — threads are the right tool).
//! The leader owns the work list; workers claim indices from a shared
//! atomic counter, so long and short simulations balance automatically.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `inputs` using up to `workers` threads, preserving input
/// order in the output. Panics in `f` propagate to the caller.
///
/// Lock-free by construction: each worker accumulates `(index, output)`
/// pairs in its own local vector and hands the whole vector back through
/// its join handle; the leader scatters the pairs into a pre-allocated
/// output table after the scope ends. The old per-slot `Mutex<Option<O>>`
/// scheme took one uncontended lock per item for slots no two threads
/// ever race on (the claim counter already makes every index exclusive) —
/// the join-handle hand-off expresses that exclusivity in the type system
/// instead of re-proving it at runtime, and makes panic propagation
/// explicit rather than a poisoned-lock side effect.
pub fn parallel_map<I, O, F>(inputs: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Send + Sync,
    O: Send,
    F: Fn(&I) -> O + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return inputs.iter().map(|i| f(i)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<O>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, O)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(&inputs[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(local) => {
                    for (i, out) in local {
                        slots[i] = Some(out);
                    }
                }
                // Surface the worker's panic on the calling thread with
                // its original payload (scope would otherwise re-raise at
                // scope exit anyway; doing it here keeps the panic origin
                // unambiguous and skips the useless scatter).
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("worker skipped a slot"))
        .collect()
}

/// Default worker count: physical parallelism with a small cap to keep
/// the host responsive.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16)
}

/// Resolve a `SimConfig::threads`-style knob: `0` means auto
/// ([`default_workers`]), anything else is an explicit worker count. The
/// single rule the network executor and the plan search share.
pub fn resolve_workers(threads: usize) -> usize {
    if threads == 0 {
        default_workers()
    } else {
        threads
    }
}

/// Resolve the layer fan-out width with the intra-layer kernel's fan-out
/// accounted for: each layer worker spawns `intra_workers` band threads
/// per simulated cycle (`SimConfig::intra_workers`), so the product
/// `layer workers × intra_workers` is clamped to [`default_workers`].
/// At least one layer worker always survives the clamp, and the clamp
/// never *raises* an explicit `threads` setting.
pub fn resolve_workers_clamped(threads: usize, intra_workers: usize) -> usize {
    let per_sim = intra_workers.max(1);
    resolve_workers(threads).min((default_workers() / per_sim).max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect(), 8, |&x: &i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |&x| x);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(vec![7], 4, |&x: &i32| x + 1), vec![8]);
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        assert_eq!(parallel_map(vec![1, 2], 64, |&x: &i32| x), vec![1, 2]);
    }

    #[test]
    fn preserves_order_under_skewed_contention() {
        // Early indices sleep, late indices return instantly: workers
        // finish wildly out of claim order, so the scatter-by-index is
        // what the assertion exercises.
        let out = parallel_map((0..64).collect(), 8, |&x: &i32| {
            if x < 8 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            x * 10
        });
        assert_eq!(out, (0..64).map(|x| x * 10).collect::<Vec<_>>());
    }

    #[test]
    fn worker_panics_propagate_with_their_payload() {
        let result = std::panic::catch_unwind(|| {
            parallel_map((0..32).collect(), 4, |&x: &i32| {
                if x == 17 {
                    panic!("boom at {x}");
                }
                x
            })
        });
        let payload = result.expect_err("the worker panic must reach the caller");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at 17"), "payload lost: {msg:?}");
    }

    #[test]
    fn zero_threads_resolves_to_auto() {
        assert_eq!(resolve_workers(0), default_workers());
        assert_eq!(resolve_workers(3), 3);
    }

    #[test]
    fn intra_workers_clamp_bounds_the_thread_product() {
        let host = default_workers();
        // Sequential kernel: the clamp is a no-op.
        assert_eq!(resolve_workers_clamped(0, 1), host);
        assert_eq!(resolve_workers_clamped(3, 1), 3);
        // Wide intra-layer kernel: layer workers shrink so the product
        // stays within the host budget...
        assert!(resolve_workers_clamped(0, 4) * 4 <= host.max(4));
        // ...but never below one layer worker, even when the intra-layer
        // fan-out alone exceeds the host.
        assert_eq!(resolve_workers_clamped(8, host * 2), 1);
        // The clamp never raises an explicit small setting.
        assert_eq!(resolve_workers_clamped(1, 2), 1);
    }
}
