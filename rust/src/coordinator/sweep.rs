//! Figure generators: each function reproduces the workload, sweep and
//! normalization of one figure in the paper's evaluation (§5.2–§5.3).
//! The bench targets and the CLI `figure` subcommand are thin wrappers
//! over these.

use crate::config::{Collection, DataflowKind, SimConfig, Streaming};
use crate::models::{ConvLayer, Network as Model};
use crate::noc::network::Network;
use crate::noc::stats::{BusStats, NetStats};
use crate::noc::Coord;
use crate::plan::{LayerPolicy, NetworkPlan};
use crate::power::power_report;

use super::executor::NetworkExecutor;
use super::experiment::{latency_improvement, power_improvement, Experiment};
use super::report::LayerResult;
use super::server::{default_workers, parallel_map};

// ---------------------------------------------------------------------
// Fig. 12 — analysis of δ on the single-row collection scenario (Fig. 5)
// ---------------------------------------------------------------------

/// One point of the δ sweep.
#[derive(Debug, Clone)]
pub struct Fig12Point {
    /// δ in units of κ (0 encodes the paper's "δ < κ" leftmost point).
    pub delta_over_kappa: u64,
    pub delta: u64,
    pub latency_cycles: u64,
    pub energy_j: f64,
    /// Gather packets the row ended up using.
    pub packets: u64,
}

#[derive(Debug, Clone)]
pub struct Fig12Series {
    pub pes_per_router: usize,
    pub points: Vec<Fig12Point>,
}

/// The Fig. 5 microbenchmark: every node of row 0 has one round of
/// payloads ready at t=0 and delivers them to the row memory element.
/// Returns (runtime latency, raw stats).
pub fn single_row_collection(cfg: &SimConfig, collection: Collection) -> (u64, NetStats) {
    let mut net = Network::new(cfg, collection);
    for x in 0..cfg.mesh_cols {
        net.post_result(0, Coord::new(x as u16, 0), cfg.pes_per_router as u32);
    }
    let total = (cfg.mesh_cols * cfg.pes_per_router) as u64;
    let bound = 1_000_000 + cfg.delta * 4;
    let ok = net.run_until(|n| n.payloads_delivered >= total, bound);
    assert!(ok, "single-row collection stalled: {}/{total}", net.payloads_delivered);
    (net.cycle, net.stats.clone())
}

/// Fig. 12: sweep δ over multiples of κ for each PEs/router setting.
pub fn fig12(mesh: usize, kappa_factors: &[u64]) -> Vec<Fig12Series> {
    [1usize, 2, 4, 8]
        .iter()
        .map(|&n| {
            let points = kappa_factors
                .iter()
                .map(|&f| {
                    let mut cfg = SimConfig::table1(mesh, n);
                    let kappa = cfg.kappa();
                    // factor 0 = the "δ < κ" regime (timeout fires at once).
                    cfg.delta = f * kappa;
                    let (lat, stats) = single_row_collection(&cfg, Collection::Gather);
                    // No streaming in this microbenchmark: network power only.
                    let p = power_report(
                        &cfg,
                        Streaming::Mesh,
                        Collection::Gather,
                        &stats,
                        &BusStats::default(),
                        lat,
                    );
                    Fig12Point {
                        delta_over_kappa: f,
                        delta: cfg.delta,
                        latency_cycles: lat,
                        // Traffic-dependent (Orion dynamic) energy: the
                        // microbenchmark isolates the gather mechanism, so
                        // fabric leakage over the tiny window is excluded.
                        energy_j: p.router_dynamic_j,
                        packets: stats.packets_injected,
                    }
                })
                .collect();
            Fig12Series { pes_per_router: n, points }
        })
        .collect()
}

// ---------------------------------------------------------------------
// Fig. 13 — gather packet size study (1 large vs 2 small packets)
// ---------------------------------------------------------------------

/// Configure the gather packet size for `packets_per_row` packets covering
/// an `m`-column row with `n` PEs/router (head + payload flits).
pub fn packet_flits_for_row(cfg: &SimConfig, packets_per_row: usize) -> usize {
    let slots = (cfg.mesh_cols * cfg.pes_per_router) as u32;
    let per_packet = slots.div_ceil(packets_per_row as u32);
    1 + per_packet.div_ceil(cfg.payloads_per_flit()) as usize
}

/// Fig. 13: latency/power improvement over RU for the two packet-size
/// policies, on `mesh`×`mesh`, for each PEs/router setting. One
/// [`LayerResult`] per (mesh, n) with the four improvement metrics.
pub fn fig13(mesh: usize, layer: &ConvLayer) -> Vec<LayerResult> {
    let jobs: Vec<usize> = vec![1, 2, 4, 8];
    parallel_map(jobs, default_workers(), |&n| {
        let mut base_cfg = SimConfig::table1(mesh, n);
        base_cfg.trace_driven = true; // §5.1 trace methodology
        let ru = Experiment::baseline_ru(base_cfg.clone()).run_layer(layer);

        let mut one = base_cfg.clone();
        one.gather_packets_per_row = 1;
        one.gather_packet_flits = packet_flits_for_row(&one, 1);
        let one_rep = Experiment::proposed(one).run_layer(layer);

        let mut two = base_cfg.clone();
        two.gather_packets_per_row = 2;
        two.gather_packet_flits = packet_flits_for_row(&two, 2);
        let two_rep = Experiment::proposed(two).run_layer(layer);

        // The workload is a single representative layer, not a whole
        // model — the model column carries the layer's provenance only
        // through its name.
        LayerResult::new("-", layer.name, mesh, n)
            .metric("one_pkt_lat_impr", latency_improvement(&ru, &one_rep))
            .metric("one_pkt_pow_impr", power_improvement(&ru, &one_rep))
            .metric("two_pkt_lat_impr", latency_improvement(&ru, &two_rep))
            .metric("two_pkt_pow_impr", power_improvement(&ru, &two_rep))
    })
}

// ---------------------------------------------------------------------
// Fig. 14 — streaming architectures vs gather-only [27]
// ---------------------------------------------------------------------

/// Fig. 14: per conv layer of AlexNet and VGG-16, runtime improvement of
/// the streaming architectures over the gather-only architecture of [27].
/// The three architectures are three uniform plans run through the
/// network executor (which fans the layers out over worker threads); the
/// per-layer rows are zipped into improvement ratios.
pub fn fig14(mesh: usize, n: usize) -> Vec<LayerResult> {
    let cfg = SimConfig::table1(mesh, n);
    // Paper methodology: per-layer round pipelines, no boundary charge.
    let ex = NetworkExecutor::new(cfg).without_reload();
    let uniform = |streaming, layers| {
        let mut p = LayerPolicy::proposed();
        p.streaming = streaming;
        NetworkPlan::uniform(p, layers)
    };
    let mut rows = Vec::new();
    for model in [Model::alexnet(), Model::vgg16()] {
        let run = |streaming| {
            ex.run(&model, &uniform(streaming, model.len())).expect("uniform plan matches model")
        };
        let base = run(Streaming::Mesh);
        let two = run(Streaming::TwoWay);
        let one = run(Streaming::OneWay);
        for i in 0..model.len() {
            rows.push(
                LayerResult::new(model.name.clone(), model.layers[i].name, mesh, n)
                    .metric(
                        "two_way_improvement",
                        latency_improvement(&base.layers[i].report, &two.layers[i].report),
                    )
                    .metric(
                        "one_way_improvement",
                        latency_improvement(&base.layers[i].report, &one.layers[i].report),
                    ),
            );
        }
    }
    rows
}

// ---------------------------------------------------------------------
// Figs. 15/16 — per-layer improvement over RU across mesh sizes and n
// ---------------------------------------------------------------------

/// Figs. 15 (AlexNet) and 16 (VGG-16): for each conv layer, mesh size and
/// PEs/router, the improvement of gather over RU (both on the two-way
/// streaming fabric, §5.3). Each (mesh, n, collection) point is one
/// uniform plan run through the network executor; the flat fan-out over
/// points (each executor pinned to one worker) keeps the sweep as
/// parallel as the bespoke per-layer job list it replaces.
pub fn fig_model(model: &Model, meshes: &[usize], ns: &[usize]) -> Vec<LayerResult> {
    let mut points = Vec::new();
    for &mesh in meshes {
        for &n in ns {
            for collection in [Collection::RepetitiveUnicast, Collection::Gather] {
                points.push((mesh, n, collection));
            }
        }
    }
    let runs = parallel_map(points.clone(), default_workers(), |&(mesh, n, collection)| {
        let mut cfg = SimConfig::table1(mesh, n);
        cfg.trace_driven = true; // §5.1 trace methodology
        cfg.threads = 1; // the sweep itself is the fan-out level
        let mut p = LayerPolicy::proposed();
        p.collection = collection;
        NetworkExecutor::new(cfg)
            .without_reload()
            .run(model, &NetworkPlan::uniform(p, model.len()))
            .expect("uniform plan matches model")
    });
    let mut rows = Vec::new();
    // Points were pushed RU-then-gather per (mesh, n): pair them back up.
    for (pair, run_pair) in points.chunks(2).zip(runs.chunks(2)) {
        let (mesh, n, _) = pair[0];
        let (ru, g) = (&run_pair[0], &run_pair[1]);
        for i in 0..model.len() {
            rows.push(
                LayerResult::new(model.name.clone(), model.layers[i].name, mesh, n)
                    .metric(
                        "latency_improvement",
                        latency_improvement(&ru.layers[i].report, &g.layers[i].report),
                    )
                    .metric(
                        "power_improvement",
                        power_improvement(&ru.layers[i].report, &g.layers[i].report),
                    ),
            );
        }
    }
    rows
}

// ---------------------------------------------------------------------
// Dataflow study — OS vs WS under every streaming × collection pairing
// ---------------------------------------------------------------------

/// One point of the OS-vs-WS study: a whole model run under one
/// (streaming, collection) pairing for both dataflows.
#[derive(Debug, Clone)]
pub struct DataflowCompareRow {
    pub streaming: Streaming,
    pub collection: Collection,
    pub os_cycles: u64,
    pub ws_cycles: u64,
    pub os_energy_j: f64,
    pub ws_energy_j: f64,
}

impl DataflowCompareRow {
    /// OS/WS runtime ratio (>1 means WS is faster).
    pub fn ws_speedup(&self) -> f64 {
        self.os_cycles as f64 / self.ws_cycles as f64
    }

    /// OS/WS total-energy ratio (>1 means WS spends less).
    pub fn ws_energy_improvement(&self) -> f64 {
        self.os_energy_j / self.ws_energy_j
    }
}

/// The OS-vs-WS study: run `layers` (whole-model total, §5.3 convention)
/// under Mesh / one-way / two-way streaming × RU / gather / INA
/// collection, once per dataflow, on a Table-1 `mesh`×`mesh`
/// configuration with `n` PEs/router. Streams and collection traffic are
/// produced by the same [`crate::dataflow::Dataflow`] machinery the
/// figure sweeps use; the three-way collection axis is the RU vs Gather
/// vs INA comparison of the `compare` CLI table.
pub fn dataflow_compare(mesh: usize, n: usize, layers: &[ConvLayer]) -> Vec<DataflowCompareRow> {
    let mut combos = Vec::new();
    for streaming in [Streaming::Mesh, Streaming::OneWay, Streaming::TwoWay] {
        for collection in
            [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
        {
            combos.push((streaming, collection));
        }
    }
    parallel_map(combos, default_workers(), |&(streaming, collection)| {
        let run = |kind: DataflowKind| {
            let mut cfg = SimConfig::table1(mesh, n);
            cfg.dataflow = kind;
            let m = Experiment::new(cfg, streaming, collection).run_model(layers);
            (m.total_cycles, m.total_energy_j)
        };
        let (os_cycles, os_energy_j) = run(DataflowKind::OutputStationary);
        let (ws_cycles, ws_energy_j) = run(DataflowKind::WeightStationary);
        DataflowCompareRow {
            streaming,
            collection,
            os_cycles,
            ws_cycles,
            os_energy_j,
            ws_energy_j,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_row_gather_uses_one_packet_with_ample_delta() {
        let cfg = SimConfig::table1_8x8(1);
        let (lat, stats) = single_row_collection(&cfg, Collection::Gather);
        // One gather packet collects the whole row.
        assert_eq!(stats.packets_injected, 1, "stats: {stats:?}");
        assert_eq!(stats.gather_boards, 7);
        assert!(lat > 0);
    }

    #[test]
    fn single_row_ru_uses_one_packet_per_node() {
        let cfg = SimConfig::table1_8x8(1);
        let (_, stats) = single_row_collection(&cfg, Collection::RepetitiveUnicast);
        assert_eq!(stats.packets_injected, 8);
    }

    #[test]
    fn tiny_delta_degenerates_to_per_node_packets() {
        let mut cfg = SimConfig::table1_8x8(1);
        cfg.delta = 0;
        let (_, stats) = single_row_collection(&cfg, Collection::Gather);
        // δ < κ: every node fires its own packet (paper §5.2).
        assert!(stats.packets_injected >= 7, "packets: {}", stats.packets_injected);
    }

    #[test]
    fn packet_sizing_matches_table1() {
        // One full-row packet on 8×8 must equal Table 1's defaults.
        for n in [1usize, 2, 4, 8] {
            let cfg = SimConfig::table1_8x8(n);
            assert_eq!(packet_flits_for_row(&cfg, 1), SimConfig::gather_flits_for(n));
        }
        // Two-packet sizing halves the payload flits (+ head).
        let cfg = SimConfig::table1_8x8(8);
        assert_eq!(packet_flits_for_row(&cfg, 2), 9);
    }

    #[test]
    fn dataflow_compare_covers_the_full_grid() {
        // A single quick layer keeps the test fast; the full AlexNet study
        // runs through the CLI (`noc-dnn compare`).
        let layer = ConvLayer { name: "t", c: 8, h_in: 10, r: 3, stride: 1, pad: 1, q: 32 };
        let rows = dataflow_compare(8, 2, std::slice::from_ref(&layer));
        assert_eq!(rows.len(), 9, "3 streaming modes x 3 collection schemes");
        for r in &rows {
            assert!(r.os_cycles > 0 && r.ws_cycles > 0);
            assert!(r.os_energy_j > 0.0 && r.ws_energy_j > 0.0);
        }
        // All three streaming modes are present for each collection.
        for coll in [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina] {
            let per: Vec<_> = rows.iter().filter(|r| r.collection == coll).collect();
            assert_eq!(per.len(), 3, "{coll:?} rows missing");
        }
    }

    #[test]
    fn fig12_latency_improves_with_delta_under_load() {
        let series = fig12(8, &[0, 9]);
        let s8 = series.iter().find(|s| s.pes_per_router == 8).unwrap();
        let degenerate = &s8.points[0];
        let plateau = &s8.points[1];
        assert!(
            plateau.latency_cycles <= degenerate.latency_cycles,
            "δ=9κ ({}) should beat δ<κ ({})",
            plateau.latency_cycles,
            degenerate.latency_cycles
        );
        assert!(plateau.energy_j < degenerate.energy_j);
        assert!(plateau.packets < degenerate.packets);
    }
}
