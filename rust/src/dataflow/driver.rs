//! Per-layer round driver: executes the OS dataflow schedule of Fig. 11 on
//! the cycle-accurate network and extrapolates full-layer totals.
//!
//! ## Round schedule
//!
//! * **Bus streaming** (one-way / two-way): the per-round operand phase is
//!   deterministic (`stream + T_MAC` cycles, Eqs. (3)–(4)), so partial sums
//!   of round `r` become ready at `(r+1)·(stream + T_MAC)`. Collection
//!   overlaps the next round's streaming exactly as Fig. 11 shows.
//! * **Mesh streaming** (gather-only baseline of [27]): operands travel the
//!   mesh as row/column multicast wormhole streams; round `r+1`'s streams
//!   are injected when round `r`'s streams finish delivering, and the
//!   observed delivery time *is* the stream phase — contention between
//!   crossing streams and with collection traffic emerges from simulation.
//!
//! ## Extrapolation
//!
//! A layer can need thousands of statistically identical rounds; the
//! driver simulates `min(rounds, sim_rounds_cap)` rounds flit-accurately,
//! measures the steady-state round period from the simulated completions,
//! and extrapolates total latency and event counts. `EXPERIMENTS.md`
//! records the cap-sensitivity study validating this.

use crate::config::{Collection, SimConfig, Streaming};
use crate::models::ConvLayer;
use crate::noc::network::{Network, StreamEdge};
use crate::noc::stats::{BusStats, NetStats};
use crate::pe;

use super::os::OsMapping;

/// Full-layer result (extrapolated) plus the measured prefix.
#[derive(Debug, Clone)]
pub struct LayerRunResult {
    pub layer_name: String,
    pub rounds_total: u64,
    pub simulated_rounds: u64,
    /// Extrapolated full-layer runtime latency in cycles.
    pub total_cycles: u64,
    /// Cycle at which the simulated prefix finished.
    pub simulated_cycles: u64,
    /// Steady-state cycles per round used for extrapolation.
    pub steady_period: f64,
    /// Event counters extrapolated to the full layer.
    pub net: NetStats,
    /// Streaming-bus counters extrapolated to the full layer (zero for
    /// mesh streaming).
    pub bus: BusStats,
    /// Raw counters for the simulated prefix.
    pub measured_net: NetStats,
}

impl LayerRunResult {
    /// Seconds at the configured clock.
    pub fn total_seconds(&self, cfg: &SimConfig) -> f64 {
        self.total_cycles as f64 / cfg.clock_hz
    }
}

/// Simulate `layer` on `cfg` with the given streaming/collection modes.
pub fn run_layer(
    cfg: &SimConfig,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
) -> LayerRunResult {
    let mapping = OsMapping::new(cfg, layer);
    match streaming {
        Streaming::OneWay | Streaming::TwoWay => {
            run_bus_layer(cfg, streaming, collection, layer, &mapping)
        }
        Streaming::Mesh => run_mesh_layer(cfg, collection, layer, &mapping),
    }
}

/// Per-round payload total for completion tracking.
fn payloads_per_round(cfg: &SimConfig) -> u64 {
    (cfg.mesh_rows * cfg.mesh_cols * cfg.pes_per_router) as u64
}

fn post_round(net: &mut Network, cfg: &SimConfig, ready: u64) {
    for y in 0..cfg.mesh_rows {
        for x in 0..cfg.mesh_cols {
            net.post_result(
                ready,
                crate::noc::Coord::new(x as u16, y as u16),
                cfg.pes_per_router as u32,
            );
        }
    }
}

/// Run the simulated prefix to completion and extrapolate.
struct PrefixOutcome {
    completions: Vec<u64>,
    net: NetStats,
}

fn extrapolate(
    layer: &ConvLayer,
    mapping: &OsMapping,
    sim_rounds: u64,
    outcome: PrefixOutcome,
    min_period: u64,
    bus_per_round: BusStats,
) -> LayerRunResult {
    let completions = outcome.completions;
    let simulated_cycles = *completions.last().expect("at least one round simulated");
    // Steady-state period: average spacing over the second half of the
    // simulated rounds (skips the cold-start transient).
    let steady = if completions.len() >= 2 {
        let half = completions.len() / 2;
        let span = completions[completions.len() - 1] - completions[half - 1];
        span as f64 / (completions.len() - half) as f64
    } else {
        completions[0] as f64
    };
    let steady = steady.max(min_period as f64);
    let remaining = mapping.rounds - sim_rounds;
    let total_cycles = simulated_cycles + (remaining as f64 * steady).round() as u64;
    let scale = mapping.rounds as f64 / sim_rounds as f64;
    let mut net = outcome.net.scaled(scale);
    net.cycles_simulated = total_cycles;
    LayerRunResult {
        layer_name: layer.name.to_string(),
        rounds_total: mapping.rounds,
        simulated_rounds: sim_rounds,
        total_cycles,
        simulated_cycles,
        steady_period: steady,
        net,
        bus: bus_per_round.scaled(mapping.rounds as f64),
        measured_net: outcome.net,
    }
}

fn run_bus_layer(
    cfg: &SimConfig,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
    mapping: &OsMapping,
) -> LayerRunResult {
    let timing = pe::round_timing(cfg, streaming, mapping.macs_per_pe);
    // Trace-driven mode (the paper's Fig. 13/15/16 methodology): compute
    // and streaming are fully overlapped with collection; rounds are gated
    // by the network drain alone. Otherwise the full Eq. (3)/(4) period
    // applies.
    let period = if cfg.trace_driven { cfg.t_mac } else { timing.ready_after() };
    let sim_rounds = mapping.rounds.min(cfg.sim_rounds_cap as u64);
    let per_round = payloads_per_round(cfg);

    let mut net = Network::new(cfg, collection);
    let mut completions = Vec::with_capacity(sim_rounds as usize);
    // Generous bound: rounds can never take longer than their traffic
    // serialized one flit at a time over the full mesh.
    let bound = (sim_rounds + 2) * period
        + 40 * per_round * (cfg.mesh_cols as u64 + cfg.gather_packet_flits as u64)
        + 200_000;
    // Round schedule (Fig. 11): the collection of round r overlaps the
    // *streaming* of round r+1, so round r+1's partial sums become ready
    // at max(its compute schedule, completion of round r's collection) +
    // T_MAC — collections of successive rounds do not overlap in the
    // network. A round whose collection outlasts the compute period
    // stretches the layer makespan: that is the Δ_R vs Δ_G difference the
    // paper measures.
    let p = period.max(1);
    let mut ready = p;
    for r in 0..sim_rounds {
        post_round(&mut net, cfg, ready);
        let target = (r + 1) * per_round;
        let ok = net.run_until(|n| n.payloads_delivered >= target, bound);
        assert!(
            ok,
            "round {r} did not complete by cycle {bound} (deadlock or \
             mis-sized gather capacity): delivered {} of {target}",
            net.payloads_delivered
        );
        let done = net.cycle;
        completions.push(done);
        ready = (ready + p).max(done + cfg.t_mac);
    }

    // Per-round streaming bus activity (power accounting).
    let bus_per_round = crate::streaming::per_round_bus_stats(cfg, streaming, mapping);

    extrapolate(
        layer,
        mapping,
        sim_rounds,
        PrefixOutcome { completions, net: net.stats.clone() },
        period,
        bus_per_round,
    )
}

fn run_mesh_layer(
    cfg: &SimConfig,
    collection: Collection,
    layer: &ConvLayer,
    mapping: &OsMapping,
) -> LayerRunResult {
    let sim_rounds = mapping.rounds.min(cfg.sim_rounds_cap as u64);
    let per_round = payloads_per_round(cfg);
    let streams_per_round = (cfg.mesh_rows + cfg.mesh_cols) as u64;

    let mut net = Network::new(cfg, collection);
    let mut completions = Vec::with_capacity(sim_rounds as usize);
    // Mesh streams serialize at worst one flit/cycle per row with crossing
    // contention; bound generously.
    let per_round_flits = cfg.mesh_rows as u64
        * mapping.row_stream_words.div_ceil(cfg.payloads_per_flit() as u64)
        + cfg.mesh_cols as u64
            * mapping.col_stream_words.div_ceil(cfg.payloads_per_flit() as u64);
    let bound = (sim_rounds + 2) * (per_round_flits * 8 + 100_000);

    let post_streams = |net: &mut Network, at: u64| {
        for y in 0..cfg.mesh_rows {
            net.post_operand_stream(at, StreamEdge::Row(y), mapping.row_stream_words);
        }
        for x in 0..cfg.mesh_cols {
            net.post_operand_stream(at, StreamEdge::Col(x), mapping.col_stream_words);
        }
    };
    post_streams(&mut net, 0);
    for r in 0..sim_rounds {
        // Wait for this round's operand delivery (tails eject at the far
        // edge) — possibly already reached while draining collections.
        let target_tails = (r + 1) * streams_per_round;
        let ok = net.run_until(|n| n.stream_tails_ejected >= target_tails, bound);
        assert!(ok, "round {r}: operand streams stalled (delivered {} of {target_tails} tails)",
            net.stream_tails_ejected);
        let stream_end = net.cycle;
        // Next round's streams enter immediately (the PEs hold this round's
        // operands in their register files); collection of this round then
        // overlaps round r+1's distribution, as in Fig. 11.
        if r + 1 < sim_rounds {
            post_streams(&mut net, stream_end);
        }
        post_round(&mut net, cfg, stream_end + cfg.t_mac);

        let target = (r + 1) * per_round;
        let ok = net.run_until(|n| n.payloads_delivered >= target, bound);
        assert!(ok, "round {r}: collection stalled ({} of {target} payloads)",
            net.payloads_delivered);
        completions.push(net.cycle);
    }

    extrapolate(
        layer,
        mapping,
        sim_rounds,
        PrefixOutcome { completions, net: net.stats.clone() },
        1,
        BusStats::default(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::alexnet;

    fn small_layer() -> ConvLayer {
        ConvLayer { name: "tiny", c: 4, h_in: 10, r: 3, stride: 1, pad: 1, q: 16 }
    }

    #[test]
    fn bus_layer_completes_and_extrapolates() {
        let cfg = SimConfig::table1_8x8(1);
        let r = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &small_layer());
        assert!(r.simulated_rounds >= 2);
        assert!(r.total_cycles >= r.simulated_cycles);
        assert_eq!(r.rounds_total, OsMapping::new(&cfg, &small_layer()).rounds);
        // All simulated payloads delivered.
        assert!(r.measured_net.packets_ejected > 0);
    }

    #[test]
    fn gather_beats_ru_on_congested_mesh() {
        // n=4 on 8×8, trace-driven (network-bound) — the regime where the
        // paper reports clear wins.
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.trace_driven = true;
        let layer = &alexnet::conv_layers()[2];
        let g = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, layer);
        let ru = run_layer(&cfg, Streaming::TwoWay, Collection::RepetitiveUnicast, layer);
        assert!(
            g.total_cycles <= ru.total_cycles,
            "gather {} should not exceed RU {}",
            g.total_cycles,
            ru.total_cycles
        );
        // Gather moves strictly fewer packets.
        assert!(g.net.packets_injected < ru.net.packets_injected);
    }

    #[test]
    fn two_way_streams_faster_than_one_way() {
        let cfg = SimConfig::table1_8x8(2);
        let layer = small_layer();
        let two = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
        let one = run_layer(&cfg, Streaming::OneWay, Collection::Gather, &layer);
        assert!(two.total_cycles < one.total_cycles);
    }

    #[test]
    fn mesh_streaming_slower_than_two_way_bus() {
        let cfg = SimConfig::table1_8x8(2);
        let layer = small_layer();
        let bus = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
        let mesh = run_layer(&cfg, Streaming::Mesh, Collection::Gather, &layer);
        assert!(
            mesh.total_cycles > bus.total_cycles,
            "mesh {} must exceed dedicated bus {}",
            mesh.total_cycles,
            bus.total_cycles
        );
    }

    #[test]
    fn all_simulated_payloads_reach_memory() {
        let cfg = SimConfig::table1_8x8(8);
        let layer = small_layer();
        for coll in [Collection::Gather, Collection::RepetitiveUnicast] {
            let r = run_layer(&cfg, Streaming::TwoWay, coll, &layer);
            let expected =
                r.simulated_rounds * (cfg.mesh_rows * cfg.mesh_cols * cfg.pes_per_router) as u64;
            // measured payload conservation: every posted payload ejected.
            let per_round = expected / r.simulated_rounds;
            assert_eq!(expected, r.simulated_rounds * per_round);
        }
    }
}
