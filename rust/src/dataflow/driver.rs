//! Per-layer round driver: executes a dataflow schedule (Fig. 11 for OS;
//! the analogous wave/round pipeline for WS) on the cycle-accurate network
//! and extrapolates full-layer totals.
//!
//! The driver is dataflow-generic: everything it needs from a mapping —
//! round count, per-round stream demand, per-NI payload counts, the
//! closed-form bus phase and any setup cost — comes through the
//! [`Dataflow`] trait, so OS and WS (and future mappings) share one
//! simulation loop.
//!
//! ## Round schedule
//!
//! * **Bus streaming** (one-way / two-way): the per-round operand phase is
//!   deterministic (`stream + T_MAC` cycles, Eqs. (3)–(4)), so partial sums
//!   of round `r` become ready at `(r+1)·(stream + T_MAC)`. Collection
//!   overlaps the next round's streaming exactly as Fig. 11 shows.
//! * **Mesh streaming** (gather-only baseline of [27]): operands travel the
//!   mesh as row/column multicast wormhole streams; round `r+1`'s streams
//!   are injected when round `r`'s streams finish delivering, and the
//!   observed delivery time *is* the stream phase — contention between
//!   crossing streams and with collection traffic emerges from simulation.
//! * **Setup phases** (WS weight pinning at wave boundaries) are not
//!   simulated round-by-round; their closed-form cost
//!   ([`Dataflow::setup_cycles`]) is added to the extrapolated total.
//!
//! ## Extrapolation
//!
//! A layer can need thousands of statistically identical rounds; the
//! driver simulates `min(rounds, sim_rounds_cap)` rounds flit-accurately,
//! measures the steady-state round period from the simulated completions,
//! and extrapolates total latency and event counts. `EXPERIMENTS.md`
//! records the cap-sensitivity study validating this.

use std::sync::Arc;

use crate::config::{Collection, SimConfig, Streaming};
use crate::models::ConvLayer;
use crate::noc::faults::DegradationReport;
use crate::noc::network::{Network, RunOutcome, StreamEdge};
use crate::noc::probes::ProbeReport;
use crate::noc::stats::{BusStats, NetStats};
use crate::noc::topology::{self, Topology};

use super::{build, Dataflow};

/// Full-layer result (extrapolated) plus the measured prefix.
#[derive(Debug, Clone)]
pub struct LayerRunResult {
    pub layer_name: String,
    /// Label of the dataflow that produced this run (`os` / `ws`).
    pub dataflow: &'static str,
    pub rounds_total: u64,
    pub simulated_rounds: u64,
    /// Extrapolated full-layer runtime latency in cycles (includes any
    /// dataflow setup phases).
    pub total_cycles: u64,
    /// Cycle at which the simulated prefix finished.
    pub simulated_cycles: u64,
    /// Steady-state cycles per round used for extrapolation.
    pub steady_period: f64,
    /// One-off setup cycles (e.g. WS weight pinning) included in
    /// `total_cycles`.
    pub setup_cycles: u64,
    /// Event counters extrapolated to the full layer.
    pub net: NetStats,
    /// Streaming-bus counters extrapolated to the full layer (zero for
    /// mesh streaming).
    pub bus: BusStats,
    /// Raw counters for the simulated prefix.
    pub measured_net: NetStats,
    /// Per-link observability snapshot for the simulated prefix —
    /// present iff `cfg.probes` was on. Like [`measured_net`](Self::measured_net)
    /// it is *not* extrapolated: `probes.total_flits` reconciles with
    /// `measured_net.link_traversals` bit-exactly.
    pub probes: Option<ProbeReport<'static>>,
    /// Fault-injection degradation accounting for the simulated prefix —
    /// present iff `cfg.faults` was configured (a clean report, with
    /// [`DegradationReport::is_clean`] true, means the plan injected no
    /// observable loss). Like [`probes`](Self::probes) it is *not*
    /// extrapolated.
    pub degraded: Option<DegradationReport>,
}

impl LayerRunResult {
    /// Seconds at the configured clock.
    pub fn total_seconds(&self, cfg: &SimConfig) -> f64 {
        self.total_cycles as f64 / cfg.clock_hz
    }
}

/// Simulate `layer` on `cfg` with the given streaming/collection modes,
/// under the dataflow selected by `cfg.dataflow`.
pub fn run_layer(
    cfg: &SimConfig,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
) -> LayerRunResult {
    run_layer_shared(&Arc::new(cfg.clone()), streaming, collection, layer)
}

/// [`run_layer`] over an already-shared config: callers that evaluate
/// many (layer, policy) points — the executor, the plan search, the
/// figure sweeps — hand the same `Arc` to every simulation instead of
/// deep-cloning `SimConfig` per constructed `Network`. The router fabric
/// is built from `cfg.topology`.
pub fn run_layer_shared(
    cfg: &Arc<SimConfig>,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
) -> LayerRunResult {
    run_layer_with_fabric(cfg, topology::build(cfg), streaming, collection, layer)
}

/// [`run_layer_shared`] over a pre-built router fabric — the
/// [`crate::api::Scenario`] path: the fabric the scenario advertises is,
/// by construction, the one the simulation runs on.
pub fn run_layer_with_fabric(
    cfg: &Arc<SimConfig>,
    topo: Arc<dyn Topology>,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
) -> LayerRunResult {
    let mapping = build(cfg, layer);
    run_layer_mapped_fabric(cfg, &topo, streaming, collection, layer, mapping.as_ref())
}

/// Simulate `layer` under an explicit dataflow mapping.
pub fn run_layer_mapped(
    cfg: &SimConfig,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
    mapping: &dyn Dataflow,
) -> LayerRunResult {
    let cfg = Arc::new(cfg.clone());
    let topo = topology::build(&cfg);
    run_layer_mapped_fabric(&cfg, &topo, streaming, collection, layer, mapping)
}

fn run_layer_mapped_fabric(
    cfg: &Arc<SimConfig>,
    topo: &Arc<dyn Topology>,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
    mapping: &dyn Dataflow,
) -> LayerRunResult {
    match streaming {
        Streaming::OneWay | Streaming::TwoWay => {
            run_bus_layer(cfg, topo, streaming, collection, layer, mapping)
        }
        Streaming::Mesh => run_mesh_layer(cfg, topo, collection, layer, mapping),
    }
}

fn post_round(net: &mut Network, cfg: &SimConfig, ready: u64, payloads_per_node: u32) {
    for y in 0..cfg.mesh_rows {
        for x in 0..cfg.mesh_cols {
            net.post_result(ready, crate::noc::Coord::new(x as u16, y as u16), payloads_per_node);
        }
    }
}

/// Run the simulated prefix to completion and extrapolate.
struct PrefixOutcome {
    completions: Vec<u64>,
    net: NetStats,
}

fn extrapolate(
    layer: &ConvLayer,
    mapping: &dyn Dataflow,
    sim_rounds: u64,
    outcome: PrefixOutcome,
    min_period: u64,
    setup_cycles: u64,
    bus_per_round: BusStats,
) -> LayerRunResult {
    let rounds = mapping.rounds();
    let completions = outcome.completions;
    let simulated_cycles = *completions.last().expect("at least one round simulated");
    // Steady-state period: average spacing over the second half of the
    // simulated rounds (skips the cold-start transient).
    let steady = if completions.len() >= 2 {
        let half = completions.len() / 2;
        let span = completions[completions.len() - 1] - completions[half - 1];
        span as f64 / (completions.len() - half) as f64
    } else {
        completions[0] as f64
    };
    let steady = steady.max(min_period as f64);
    let remaining = rounds - sim_rounds;
    let total_cycles =
        simulated_cycles + (remaining as f64 * steady).round() as u64 + setup_cycles;
    let scale = rounds as f64 / sim_rounds as f64;
    let mut net = outcome.net.scaled(scale);
    net.cycles_simulated = total_cycles;
    LayerRunResult {
        layer_name: layer.name.to_string(),
        dataflow: mapping.kind().label(),
        rounds_total: rounds,
        simulated_rounds: sim_rounds,
        total_cycles,
        simulated_cycles,
        steady_period: steady,
        setup_cycles,
        net,
        bus: bus_per_round.scaled(rounds as f64),
        measured_net: outcome.net,
        probes: None,
        degraded: None,
    }
}

fn run_bus_layer(
    cfg: &Arc<SimConfig>,
    topo: &Arc<dyn Topology>,
    streaming: Streaming,
    collection: Collection,
    layer: &ConvLayer,
    mapping: &dyn Dataflow,
) -> LayerRunResult {
    // Trace-driven mode (the paper's Fig. 13/15/16 methodology): compute
    // and streaming are fully overlapped with collection; rounds are gated
    // by the network drain alone. Otherwise the full Eq. (3)/(4) period
    // applies.
    let period = if cfg.trace_driven {
        cfg.t_mac
    } else {
        mapping.stream_cycles(cfg, streaming) + cfg.t_mac
    };
    let rounds = mapping.rounds();
    let sim_rounds = rounds.min(cfg.sim_rounds_cap as u64);
    let per_round = mapping.traffic_per_round(cfg).payloads;
    let payloads_per_node = mapping.psum_collection().payloads_per_node;

    let mut net = Network::with_topology(cfg.clone(), topo.clone(), collection);
    let mut completions = Vec::with_capacity(sim_rounds as usize);
    // Generous bound: rounds can never take longer than their traffic
    // serialized one flit at a time over the full mesh.
    let bound = (sim_rounds + 2) * period
        + 40 * per_round * (cfg.mesh_cols as u64 + cfg.gather_packet_flits as u64)
        + 200_000;
    // Round schedule (Fig. 11): the collection of round r overlaps the
    // *streaming* of round r+1, so round r+1's partial sums become ready
    // at max(its compute schedule, completion of round r's collection) +
    // T_MAC — collections of successive rounds do not overlap in the
    // network. A round whose collection outlasts the compute period
    // stretches the layer makespan: that is the Δ_R vs Δ_G difference the
    // paper measures.
    let p = period.max(1);
    let mut ready = p;
    for r in 0..sim_rounds {
        post_round(&mut net, cfg, ready, payloads_per_node);
        let target = (r + 1) * per_round;
        // Fault tolerance: payloads lost to the fault plan (dropped
        // packets, excluded contributors) count toward round completion —
        // a degraded round still finishes, it just delivers less.
        let outcome = net
            .run_until_outcome(|n| n.payloads_delivered + n.payloads_dropped >= target, bound);
        assert!(
            outcome == RunOutcome::Satisfied,
            "round {r} did not complete by cycle {bound} ({}): delivered {} \
             (+{} dropped) of {target}",
            outcome.describe(),
            net.payloads_delivered,
            net.payloads_dropped
        );
        let done = net.cycle;
        completions.push(done);
        ready = (ready + p).max(done + cfg.t_mac);
    }

    // Per-round streaming bus activity (power accounting).
    let bus_per_round = crate::streaming::per_round_bus_stats(cfg, streaming, mapping);
    let setup = mapping.setup_cycles(cfg, streaming);

    let mut result = extrapolate(
        layer,
        mapping,
        sim_rounds,
        PrefixOutcome { completions, net: net.stats.clone() },
        period,
        setup,
        bus_per_round,
    );
    // Setup-phase bus words (WS weight loads) are charged energy too.
    result.bus.merge(&mapping.setup_bus_stats(cfg, streaming));
    apply_accumulation_counts(&mut result, cfg, mapping);
    result.probes = net.probe_report().map(|p| p.into_owned());
    result.degraded = net.degradation_report();
    result
}

/// Fold the mapping's per-round NI accumulate operations into the stats
/// (the simulator does not model the NI adder; the count is closed-form).
fn apply_accumulation_counts(result: &mut LayerRunResult, cfg: &SimConfig, mapping: &dyn Dataflow) {
    let per_round = (cfg.mesh_rows * cfg.mesh_cols) as u64
        * mapping.psum_collection().accumulations_per_node as u64;
    result.net.ni_accumulations = mapping.rounds() * per_round;
    result.measured_net.ni_accumulations = result.simulated_rounds * per_round;
}

fn run_mesh_layer(
    cfg: &Arc<SimConfig>,
    topo: &Arc<dyn Topology>,
    collection: Collection,
    layer: &ConvLayer,
    mapping: &dyn Dataflow,
) -> LayerRunResult {
    let rounds = mapping.rounds();
    let sim_rounds = rounds.min(cfg.sim_rounds_cap as u64);
    let traffic = mapping.traffic_per_round(cfg);
    let per_round = traffic.payloads;
    let payloads_per_node = mapping.psum_collection().payloads_per_node;
    let words = mapping.stream_words();
    // Streams with zero words (e.g. WS column buses in steady state) are
    // simply not posted.
    let row_streams = if words.row > 0 { cfg.mesh_rows as u64 } else { 0 };
    let col_streams = if words.col > 0 { cfg.mesh_cols as u64 } else { 0 };
    let streams_per_round = row_streams + col_streams;

    let mut net = Network::with_topology(cfg.clone(), topo.clone(), collection);
    let mut completions = Vec::with_capacity(sim_rounds as usize);
    // Mesh streams serialize at worst one flit/cycle per row with crossing
    // contention; bound generously.
    let bound = (sim_rounds + 2) * (traffic.stream_flits * 8 + 100_000);

    let post_streams = |net: &mut Network, at: u64| {
        if words.row > 0 {
            for y in 0..cfg.mesh_rows {
                net.post_operand_stream(at, StreamEdge::Row(y), words.row);
            }
        }
        if words.col > 0 {
            for x in 0..cfg.mesh_cols {
                net.post_operand_stream(at, StreamEdge::Col(x), words.col);
            }
        }
    };
    post_streams(&mut net, 0);
    for r in 0..sim_rounds {
        // Wait for this round's operand delivery (tails eject at the far
        // edge) — possibly already reached while draining collections.
        let target_tails = (r + 1) * streams_per_round;
        // Streams clamped short of the far edge still eject their tail at
        // the clamped destination; streams dropped whole (entry router
        // down, head retry exhaustion) are credited via `streams_dropped`.
        let outcome = net.run_until_outcome(
            |n| n.stream_tails_ejected + n.streams_dropped >= target_tails,
            bound,
        );
        assert!(
            outcome == RunOutcome::Satisfied,
            "round {r}: operand streams stalled ({}): delivered {} (+{} dropped) \
             of {target_tails} tails",
            outcome.describe(),
            net.stream_tails_ejected,
            net.streams_dropped
        );
        let stream_end = net.cycle;
        // Next round's streams enter immediately (the PEs hold this round's
        // operands in their register files); collection of this round then
        // overlaps round r+1's distribution, as in Fig. 11.
        if r + 1 < sim_rounds {
            post_streams(&mut net, stream_end);
        }
        post_round(&mut net, cfg, stream_end + cfg.t_mac, payloads_per_node);

        let target = (r + 1) * per_round;
        let outcome = net
            .run_until_outcome(|n| n.payloads_delivered + n.payloads_dropped >= target, bound);
        assert!(
            outcome == RunOutcome::Satisfied,
            "round {r}: collection stalled ({}): {} (+{} dropped) of {target} payloads",
            outcome.describe(),
            net.payloads_delivered,
            net.payloads_dropped
        );
        completions.push(net.cycle);
    }

    // Wave-boundary setup (WS weight distribution over the mesh) is
    // closed-form, not simulated — see `Dataflow::setup_cycles`.
    let setup = mapping.setup_cycles(cfg, Streaming::Mesh);

    let mut result = extrapolate(
        layer,
        mapping,
        sim_rounds,
        PrefixOutcome { completions, net: net.stats.clone() },
        1,
        setup,
        BusStats::default(),
    );
    // Setup-phase mesh traffic (WS weight distribution) is charged router
    // energy in closed form, since wave boundaries are not simulated —
    // its closed-form link_traversals are merged into `net` only, never
    // into the probes, which record simulated traffic exclusively.
    result.net.merge(&mapping.setup_net_stats(cfg, Streaming::Mesh));
    apply_accumulation_counts(&mut result, cfg, mapping);
    result.probes = net.probe_report().map(|p| p.into_owned());
    result.degraded = net.degradation_report();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataflowKind;
    use crate::dataflow::os::OsMapping;
    use crate::dataflow::ws::WsMapping;
    use crate::models::alexnet;

    fn small_layer() -> ConvLayer {
        ConvLayer { name: "tiny", c: 4, h_in: 10, r: 3, stride: 1, pad: 1, q: 16 }
    }

    #[test]
    fn bus_layer_completes_and_extrapolates() {
        let cfg = SimConfig::table1_8x8(1);
        let r = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &small_layer());
        assert!(r.simulated_rounds >= 2);
        assert!(r.total_cycles >= r.simulated_cycles);
        assert_eq!(r.rounds_total, OsMapping::new(&cfg, &small_layer()).rounds);
        assert_eq!(r.dataflow, "os");
        // All simulated payloads delivered.
        assert!(r.measured_net.packets_ejected > 0);
    }

    #[test]
    fn gather_beats_ru_on_congested_mesh() {
        // n=4 on 8×8, trace-driven (network-bound) — the regime where the
        // paper reports clear wins.
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.trace_driven = true;
        let layer = &alexnet::conv_layers()[2];
        let g = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, layer);
        let ru = run_layer(&cfg, Streaming::TwoWay, Collection::RepetitiveUnicast, layer);
        assert!(
            g.total_cycles <= ru.total_cycles,
            "gather {} should not exceed RU {}",
            g.total_cycles,
            ru.total_cycles
        );
        // Gather moves strictly fewer packets.
        assert!(g.net.packets_injected < ru.net.packets_injected);
    }

    #[test]
    fn two_way_streams_faster_than_one_way() {
        let cfg = SimConfig::table1_8x8(2);
        let layer = small_layer();
        let two = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
        let one = run_layer(&cfg, Streaming::OneWay, Collection::Gather, &layer);
        assert!(two.total_cycles < one.total_cycles);
    }

    #[test]
    fn mesh_streaming_slower_than_two_way_bus() {
        let cfg = SimConfig::table1_8x8(2);
        let layer = small_layer();
        let bus = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
        let mesh = run_layer(&cfg, Streaming::Mesh, Collection::Gather, &layer);
        assert!(
            mesh.total_cycles > bus.total_cycles,
            "mesh {} must exceed dedicated bus {}",
            mesh.total_cycles,
            bus.total_cycles
        );
    }

    #[test]
    fn all_simulated_payloads_reach_memory() {
        let cfg = SimConfig::table1_8x8(8);
        let layer = small_layer();
        for coll in [Collection::Gather, Collection::RepetitiveUnicast] {
            let r = run_layer(&cfg, Streaming::TwoWay, coll, &layer);
            let expected =
                r.simulated_rounds * (cfg.mesh_rows * cfg.mesh_cols * cfg.pes_per_router) as u64;
            // measured payload conservation: every posted payload ejected.
            let per_round = expected / r.simulated_rounds;
            assert_eq!(expected, r.simulated_rounds * per_round);
        }
    }

    #[test]
    fn ws_layer_runs_under_every_streaming_mode() {
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.dataflow = DataflowKind::WeightStationary;
        let layer = small_layer();
        let mapping = WsMapping::new(&cfg, &layer);
        for streaming in [Streaming::TwoWay, Streaming::OneWay, Streaming::Mesh] {
            let r = run_layer(&cfg, streaming, Collection::Gather, &layer);
            assert_eq!(r.dataflow, "ws");
            assert_eq!(r.rounds_total, mapping.rounds);
            assert!(r.total_cycles >= r.simulated_cycles);
            assert_eq!(r.setup_cycles, mapping.setup_cycles(&cfg, streaming));
            assert!(r.measured_net.packets_ejected > 0, "{streaming:?} moved no packets");
            // Weight-load words are charged to the buses that carry them.
            match streaming {
                Streaming::TwoWay => assert!(r.bus.col_words > 0, "weight loads missing"),
                Streaming::OneWay => assert_eq!(r.bus.col_words, 0),
                Streaming::Mesh => assert_eq!(r.bus, BusStats::default()),
            }
        }
    }

    #[test]
    fn both_dataflows_drive_ina_collection() {
        // The driver is collection-generic: the same round loop that runs
        // RU and gather must run INA for OS and WS alike, delivering every
        // posted payload while moving no more flit-hops than gather.
        let layer = small_layer();
        for kind in [DataflowKind::OutputStationary, DataflowKind::WeightStationary] {
            for streaming in [Streaming::TwoWay, Streaming::Mesh] {
                let mut cfg = SimConfig::table1_8x8(4);
                cfg.dataflow = kind;
                let ina = run_layer(&cfg, streaming, Collection::Ina, &layer);
                let g = run_layer(&cfg, streaming, Collection::Gather, &layer);
                assert_eq!(ina.rounds_total, g.rounds_total);
                assert!(ina.measured_net.packets_ejected > 0, "{kind:?}/{streaming:?}");
                assert!(
                    ina.measured_net.flit_hops <= g.measured_net.flit_hops,
                    "{kind:?}/{streaming:?}: INA hops {} exceed gather {}",
                    ina.measured_net.flit_hops,
                    g.measured_net.flit_hops
                );
                assert!(ina.measured_net.ina_folds > 0, "transit NIs must fold psums");
            }
        }
    }

    #[test]
    fn ws_explicit_mapping_matches_config_selected_run() {
        let mut cfg = SimConfig::table1_8x8(2);
        cfg.dataflow = DataflowKind::WeightStationary;
        let layer = small_layer();
        let via_cfg = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
        let mapping = WsMapping::new(&cfg, &layer);
        let explicit =
            run_layer_mapped(&cfg, Streaming::TwoWay, Collection::Gather, &layer, &mapping);
        assert_eq!(via_cfg.total_cycles, explicit.total_cycles);
        assert_eq!(via_cfg.net, explicit.net);
    }
}
