//! Dataflow abstraction: how a convolution layer becomes per-round NoC
//! traffic, plus the per-layer round driver.
//!
//! The paper evaluates its streaming buses and gather packets under the
//! Output-Stationary (OS) dataflow only, but frames both mechanisms as
//! general one-to-many / many-to-one primitives (§4). The [`Dataflow`]
//! trait captures exactly the contract the rest of the simulator needs
//! from a mapping — round count, per-round stream word demand, per-round
//! partial-sum collection shape, and the closed-form bus timing — so new
//! dataflows plug in without touching the network model:
//!
//! * [`os`] — the paper's OS mapping of Fig. 4: rows ↔ input patches,
//!   columns ↔ filters, `n` PEs/router, `rounds = ⌈P/(N·n)⌉·⌈Q/M⌉`.
//! * [`ws`] — a Weight-Stationary mapping: filter weights pinned in PE
//!   register files for a wave of rounds, one input patch per round
//!   broadcast on the row buses, completed sums gathered east.
//! * [`driver`] — runs any mapping on the cycle-accurate
//!   [`crate::noc::Network`], round by round, and extrapolates the
//!   full-layer latency/energy from the simulated prefix (see DESIGN.md,
//!   "Cycle simulation with round extrapolation").
//!
//! Select a dataflow with [`crate::config::SimConfig::dataflow`] (CLI:
//! `--dataflow os|ws`) or construct one directly with [`build`] /
//! [`Dataflow::map_layer`].

pub mod driver;
pub mod os;
pub mod ws;

pub use driver::{
    run_layer, run_layer_mapped, run_layer_shared, run_layer_with_fabric, LayerRunResult,
};
pub use os::OsMapping;
pub use ws::WsMapping;

use crate::config::{DataflowKind, SimConfig, Streaming};
use crate::models::ConvLayer;
use crate::noc::stats::{BusStats, NetStats};

/// Per-round operand demand on one streaming bus (or mesh stream) of each
/// kind. `row` is the words one row bus must deliver per round, `col` the
/// words one column bus must deliver; either may be zero (e.g. WS streams
/// no weights in steady state).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamWords {
    pub row: u64,
    pub col: u64,
}

/// Per-round partial-sum collection shape at each router's NI.
///
/// The shape is collection-scheme independent: the same
/// `payloads_per_node` rides repetitive unicasts, gather packets
/// (Algorithm 1) or INA packets ([`crate::config::Collection::Ina`]) —
/// which is what lets every [`Dataflow`] drive all three schemes through
/// one driver. Under INA the per-node payloads are additionally the
/// packet's physical psum word count (see [`Dataflow::ina_packet_flits`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PsumCollection {
    /// Result payloads each NI posts per round (the gather `sizeof(P)`).
    pub payloads_per_node: u32,
    /// True when several PEs' partial products are accumulated into one
    /// payload before collection (the in-network/NI accumulation reading
    /// of the gather mechanism; see [`ws`]).
    pub in_network_accumulation: bool,
    /// Partial-sum *add* operations the NI performs per round to fold its
    /// PEs' partials into the posted payloads (0 when each PE finishes its
    /// own output). The driver turns this into
    /// [`crate::noc::stats::NetStats::ni_accumulations`] so the power
    /// model can charge the adder/register writes.
    pub accumulations_per_node: u32,
}

/// Aggregate per-round traffic, used for completion tracking and
/// simulation bounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundTraffic {
    /// Result payloads produced network-wide per round.
    pub payloads: u64,
    /// Flits one round's operand streams occupy when carried over the mesh
    /// itself (gather-only architecture); zero-words streams contribute
    /// nothing.
    pub stream_flits: u64,
}

/// A dataflow mapping of one convolution layer onto one mesh
/// configuration.
///
/// Implementations are pure shape arithmetic: they decide *what* traffic
/// each round carries; the [`driver`] decides *when* by running it on the
/// cycle-accurate network. The contract:
///
/// * every round posts [`PsumCollection::payloads_per_node`] payloads at
///   every NI, destined for the row memory element;
/// * operand delivery is either the deterministic bus phase
///   ([`Dataflow::stream_cycles`]) or mesh streams sized by
///   [`Dataflow::stream_words`];
/// * one-off per-layer costs (e.g. WS weight pinning) are reported by
///   [`Dataflow::setup_cycles`] and added to the extrapolated total.
pub trait Dataflow {
    /// Map `layer` onto `cfg` (the constructor used by [`build`]).
    fn map_layer(cfg: &SimConfig, layer: &ConvLayer) -> Self
    where
        Self: Sized;

    /// Which dataflow this mapping implements.
    fn kind(&self) -> DataflowKind;

    /// Total rounds needed to cover the layer's `P × Q` outputs.
    fn rounds(&self) -> u64;

    /// MACs each PE executes per round (the compute term of Eqs. (3)–(4)).
    fn macs_per_pe(&self) -> u64;

    /// Per-round operand words on each row/column bus (or mesh stream).
    fn stream_words(&self) -> StreamWords;

    /// Per-round partial-sum collection shape.
    fn psum_collection(&self) -> PsumCollection;

    /// Deterministic operand-phase length in cycles for a bus streaming
    /// architecture; must return 0 for [`Streaming::Mesh`], whose delivery
    /// time is simulated, not closed-form.
    fn stream_cycles(&self, cfg: &SimConfig, streaming: Streaming) -> u64;

    /// One-off cycles outside the round pipeline (weight pinning phases
    /// and the like); 0 for dataflows without a setup phase.
    fn setup_cycles(&self, cfg: &SimConfig, streaming: Streaming) -> u64;

    /// Whole-layer bus traffic of the setup phases (e.g. WS weight loads
    /// at wave boundaries), so setup words are charged bus energy just
    /// like steady-state words. Zero for dataflows without setup and for
    /// mesh streaming (no buses).
    fn setup_bus_stats(&self, _cfg: &SimConfig, _streaming: Streaming) -> BusStats {
        BusStats::default()
    }

    /// Whole-layer *router* events of the setup phases when operands ride
    /// the mesh itself ([`Streaming::Mesh`]): wave boundaries are not
    /// simulated, so their flit traffic is accounted in closed form and
    /// merged into the run's [`NetStats`] — otherwise the mesh rows of an
    /// energy comparison would move setup traffic for free. Zero for
    /// dataflows without setup and for bus streaming (covered by
    /// [`Dataflow::setup_bus_stats`]).
    fn setup_net_stats(&self, _cfg: &SimConfig, _streaming: Streaming) -> NetStats {
        NetStats::default()
    }

    /// Output elements of the layer actually needed (`P·Q`); padding
    /// outputs of the final round are discarded by the memory element.
    fn useful_outputs(&self, layer: &ConvLayer) -> u64;

    /// Flits of one in-network-accumulation result packet under this
    /// mapping ([`crate::config::Collection::Ina`]): a head plus enough
    /// body/tail flits for one node's physical psum words. Downstream
    /// routers *add* into those words instead of appending slots, so the
    /// packet never grows — this is the `psum_collection` generalization
    /// that lets any dataflow (OS posts `n` finished outputs, WS posts
    /// `n/spread` pre-accumulated sums) drive INA collection. Mirrors the
    /// packet the network stages from `payloads_per_node` pending psums.
    fn ina_packet_flits(&self, cfg: &SimConfig) -> u32 {
        cfg.ina_packet_flits(self.psum_collection().payloads_per_node)
    }

    /// Aggregate per-round traffic (derived; used by the driver for
    /// completion targets and deadlock bounds).
    fn traffic_per_round(&self, cfg: &SimConfig) -> RoundTraffic {
        let sw = self.stream_words();
        let ppf = cfg.payloads_per_flit() as u64;
        RoundTraffic {
            payloads: (cfg.mesh_rows * cfg.mesh_cols) as u64
                * self.psum_collection().payloads_per_node as u64,
            stream_flits: cfg.mesh_rows as u64 * sw.row.div_ceil(ppf)
                + cfg.mesh_cols as u64 * sw.col.div_ceil(ppf),
        }
    }
}

/// Construct the mapping selected by `cfg.dataflow`.
pub fn build(cfg: &SimConfig, layer: &ConvLayer) -> Box<dyn Dataflow> {
    match cfg.dataflow {
        DataflowKind::OutputStationary => Box::new(OsMapping::map_layer(cfg, layer)),
        DataflowKind::WeightStationary => Box::new(WsMapping::map_layer(cfg, layer)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::alexnet;

    #[test]
    fn build_follows_the_config_selector() {
        let layer = &alexnet::conv_layers()[2];
        let mut cfg = SimConfig::table1_8x8(4);
        assert_eq!(build(&cfg, layer).kind(), DataflowKind::OutputStationary);
        cfg.dataflow = DataflowKind::WeightStationary;
        assert_eq!(build(&cfg, layer).kind(), DataflowKind::WeightStationary);
    }

    #[test]
    fn traffic_per_round_matches_mapping_shape() {
        let layer = &alexnet::conv_layers()[2];
        let cfg = SimConfig::table1_8x8(4);
        let m = build(&cfg, layer);
        let t = m.traffic_per_round(&cfg);
        assert_eq!(
            t.payloads,
            64 * m.psum_collection().payloads_per_node as u64
        );
        let sw = m.stream_words();
        let ppf = cfg.payloads_per_flit() as u64;
        assert_eq!(
            t.stream_flits,
            8 * sw.row.div_ceil(ppf) + 8 * sw.col.div_ceil(ppf)
        );
    }

    #[test]
    fn ina_packet_is_sized_by_physical_words_not_row_population() {
        // Gather packets grow with the row (3/5/9/17 flits for 1/2/4/8
        // PEs/router on 8×8); an INA packet only carries one node's words
        // because downstream psums are added in place.
        let layer = &alexnet::conv_layers()[2];
        for (n, want) in [(1usize, 2u32), (2, 2), (4, 2), (8, 3)] {
            let cfg = SimConfig::table1_8x8(n);
            let m = build(&cfg, layer);
            assert_eq!(m.ina_packet_flits(&cfg), want, "n={n}");
            assert!(
                (m.ina_packet_flits(&cfg) as usize) < cfg.gather_packet_flits || n == 1,
                "n={n}: INA packet should undercut the row-sized gather packet"
            );
        }
        // WS spread groups post n/spread pre-accumulated sums; the INA
        // packet shrinks accordingly.
        let mut cfg = SimConfig::table1_8x8(8);
        cfg.dataflow = DataflowKind::WeightStationary;
        cfg.ws_rf_words = 512; // conv3 spreads 4-wide: 2 payloads/node
        let ws = build(&cfg, layer);
        assert_eq!(ws.psum_collection().payloads_per_node, 2);
        assert_eq!(ws.ina_packet_flits(&cfg), 2);
    }

    #[test]
    fn both_dataflows_cover_every_useful_output() {
        // Coverage invariant: rounds × per-round payload capacity ≥ P·Q.
        for layer in alexnet::conv_layers() {
            for n in [1usize, 4] {
                let cfg = SimConfig::table1_8x8(n);
                for m in [
                    Box::new(OsMapping::map_layer(&cfg, &layer)) as Box<dyn Dataflow>,
                    Box::new(WsMapping::map_layer(&cfg, &layer)) as Box<dyn Dataflow>,
                ] {
                    let per_round = m.traffic_per_round(&cfg).payloads;
                    assert!(
                        m.rounds() * per_round >= m.useful_outputs(&layer),
                        "{} under {:?} does not cover the layer",
                        layer.name,
                        m.kind()
                    );
                }
            }
        }
    }
}
