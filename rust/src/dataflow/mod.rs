//! The Output-Stationary dataflow mapper and the per-layer round driver.
//!
//! [`os`] turns a convolution layer shape into the OS mapping of Fig. 4:
//! rows ↔ input patches, columns ↔ filters, `n` PEs/router, and the number
//! of rounds needed to cover `P × Q`. [`driver`] runs the mapped layer on
//! the cycle-accurate [`crate::noc::Network`], round by round, and
//! extrapolates the full-layer latency/energy from the simulated prefix
//! (see DESIGN.md, "Cycle simulation with round extrapolation").

pub mod driver;
pub mod os;

pub use driver::{run_layer, LayerRunResult};
pub use os::OsMapping;
