//! Output-Stationary mapping of a convolution layer onto the mesh (Fig. 4).
//!
//! Each round, every PE computes one output element: the PE at row `y`,
//! column `x` (one of `n` behind the router) accumulates
//! `C·R·R` MACs between its patch's input stream (row bus / west edge) and
//! its filter's weight stream (column bus / north edge), per Eq. (2).
//! Rows cover input patches (`P`), columns cover filters (`Q`); with `n`
//! PEs per router grouped column-wise (§4.4 option 1), a round covers
//! `N·n` patches × `M` filters, hence
//! `rounds = ⌈P/(N·n)⌉ · ⌈Q/M⌉` — the `P/N · Q/M · 1/n` factor of
//! Eqs. (3)–(4).

use crate::config::{DataflowKind, PeGrouping, SimConfig, Streaming};
use crate::models::ConvLayer;

use super::{Dataflow, PsumCollection, StreamWords};

/// The OS mapping of one layer onto one mesh configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OsMapping {
    /// Patches covered per round (N·n).
    pub patches_per_round: u64,
    /// Filters covered per round (M).
    pub filters_per_round: u64,
    /// Total rounds to cover P × Q.
    pub rounds: u64,
    /// MACs per PE per round (C·R·R).
    pub macs_per_pe: u64,
    /// Result payloads per router NI per round (n partial sums).
    pub payloads_per_node: u32,
    /// Input-activation words one row bus must deliver per round
    /// (n patch streams × C·R·R words).
    pub row_stream_words: u64,
    /// Weight words one column bus must deliver per round
    /// (one filter stream × C·R·R words).
    pub col_stream_words: u64,
}

impl OsMapping {
    pub fn new(cfg: &SimConfig, layer: &ConvLayer) -> OsMapping {
        let n = cfg.pes_per_router as u64;
        let rows = cfg.mesh_rows as u64;
        let cols = cfg.mesh_cols as u64;
        let p = layer.p_patches();
        let q = layer.q as u64;
        let macs = layer.macs_per_output();
        // §4.4: column grouping multiplies the patch coverage (n input
        // sets per NI, one filter set); row grouping multiplies the
        // filter coverage (one input set, n filter sets).
        let (patches_per_round, filters_per_round, row_words, col_words) =
            match cfg.pe_grouping {
                PeGrouping::Column => (rows * n, cols, n * macs, macs),
                PeGrouping::Row => (rows, cols * n, macs, n * macs),
            };
        let rounds = p.div_ceil(patches_per_round) * q.div_ceil(filters_per_round);
        OsMapping {
            patches_per_round,
            filters_per_round,
            rounds,
            macs_per_pe: macs,
            payloads_per_node: n as u32,
            row_stream_words: row_words,
            col_stream_words: col_words,
        }
    }

    /// Result payloads produced network-wide per round.
    pub fn payloads_per_round(&self, cfg: &SimConfig) -> u64 {
        (cfg.mesh_rows * cfg.mesh_cols) as u64 * self.payloads_per_node as u64
    }

    /// Total output elements of the layer actually needed (`P·Q`); the
    /// final round's padding outputs are discarded by the memory element.
    pub fn useful_outputs(&self, layer: &ConvLayer) -> u64 {
        layer.p_patches() * layer.q as u64
    }
}

/// The OS mapping viewed through the generic dataflow interface. Every
/// method is a direct restatement of the struct fields, so the trait path
/// is cycle-identical to the concrete one (asserted by
/// `tests/dataflow_trait.rs`).
impl Dataflow for OsMapping {
    fn map_layer(cfg: &SimConfig, layer: &ConvLayer) -> OsMapping {
        OsMapping::new(cfg, layer)
    }

    fn kind(&self) -> DataflowKind {
        DataflowKind::OutputStationary
    }

    fn rounds(&self) -> u64 {
        self.rounds
    }

    fn macs_per_pe(&self) -> u64 {
        self.macs_per_pe
    }

    fn stream_words(&self) -> StreamWords {
        StreamWords { row: self.row_stream_words, col: self.col_stream_words }
    }

    fn psum_collection(&self) -> PsumCollection {
        // Each PE finishes its own output (full C·R·R reduction locally):
        // nothing to accumulate on the way out.
        PsumCollection {
            payloads_per_node: self.payloads_per_node,
            in_network_accumulation: false,
            accumulations_per_node: 0,
        }
    }

    fn stream_cycles(&self, cfg: &SimConfig, streaming: Streaming) -> u64 {
        match streaming {
            // Mesh delivery time is simulated, not closed-form.
            Streaming::Mesh => 0,
            _ => crate::pe::bus_stream_cycles(cfg, streaming, self.macs_per_pe),
        }
    }

    fn setup_cycles(&self, _cfg: &SimConfig, _streaming: Streaming) -> u64 {
        0
    }

    fn useful_outputs(&self, layer: &ConvLayer) -> u64 {
        OsMapping::useful_outputs(self, layer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::alexnet;

    #[test]
    fn rounds_follow_the_paper_formula() {
        // conv3 of AlexNet: P = 169, Q = 384, on 8×8 with n = 2.
        let cfg = SimConfig::table1_8x8(2);
        let layer = &alexnet::conv_layers()[2];
        let m = OsMapping::new(&cfg, layer);
        assert_eq!(m.patches_per_round, 16);
        assert_eq!(m.filters_per_round, 8);
        // ceil(169/16) * ceil(384/8) = 11 * 48
        assert_eq!(m.rounds, 11 * 48);
        assert_eq!(m.macs_per_pe, 192 * 9);
    }

    #[test]
    fn more_pes_reduce_rounds() {
        let layer = &alexnet::conv_layers()[1];
        let r1 = OsMapping::new(&SimConfig::table1_8x8(1), layer).rounds;
        let r8 = OsMapping::new(&SimConfig::table1_8x8(8), layer).rounds;
        assert!(r8 < r1);
        // Roughly 8x fewer rounds (up to ceiling effects).
        assert!(r1 as f64 / r8 as f64 > 6.0);
    }

    #[test]
    fn row_grouping_swaps_coverage_and_stream_words() {
        use crate::config::PeGrouping;
        let layer = &alexnet::conv_layers()[2];
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.pe_grouping = PeGrouping::Row;
        let m = OsMapping::new(&cfg, layer);
        assert_eq!(m.patches_per_round, 8);
        assert_eq!(m.filters_per_round, 32);
        assert_eq!(m.row_stream_words, m.macs_per_pe);
        assert_eq!(m.col_stream_words, 4 * m.macs_per_pe);
        // Same total coverage per round as column grouping.
        let col = OsMapping::new(&SimConfig::table1_8x8(4), layer);
        assert_eq!(
            m.patches_per_round * m.filters_per_round,
            col.patches_per_round * col.filters_per_round
        );
    }

    #[test]
    fn stream_words_scale_with_n() {
        let layer = &alexnet::conv_layers()[2];
        let m = OsMapping::new(&SimConfig::table1_8x8(4), layer);
        assert_eq!(m.row_stream_words, 4 * m.macs_per_pe);
        assert_eq!(m.col_stream_words, m.macs_per_pe);
    }
}
