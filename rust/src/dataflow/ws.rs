//! Weight-Stationary (WS) mapping of a convolution layer onto the mesh.
//!
//! WS pins filter weights in the PE register files and moves the *input
//! activations* instead — the dual of the paper's OS mapping, and the
//! dataflow under which the streaming bus pays off most: one patch per
//! round is **broadcast** on the row buses (every PE taps the same words,
//! so the per-round stream is `C·R·R` words regardless of `n`), while OS
//! must deliver `n` distinct patch streams per router.
//!
//! ## Mapping
//!
//! * Each PE is assigned one filter (or a `1/spread` slice of one, see
//!   below) whose weights stay resident for a whole **wave** of rounds.
//! * A wave covers `N·M·(n/spread)` filters; `⌈Q / filters_per_wave⌉`
//!   waves cover the layer.
//! * Within a wave, round `r` broadcasts patch `r` to every PE; each PE
//!   produces one finished output element (its filter × the patch), so a
//!   round yields `filters_per_wave` outputs and a wave takes `P` rounds:
//!   `rounds = waves · P`.
//! * At each wave boundary the next wave's weights are loaded over the
//!   column buses (two-way), the shared row buses (one-way) or column
//!   mesh streams (gather-only). This is the WS setup cost, reported via
//!   [`super::Dataflow::setup_cycles`] and amortized over the `P` rounds
//!   of the wave.
//!
//! ## Register-file spill and NI accumulation
//!
//! A filter whose `C·R·R` weights exceed the per-PE register file
//! (`cfg.ws_rf_words`) is split across `spread = ⌈C·R·R / rf⌉` PEs behind
//! the same router (capped at `n`). Each of the `spread` PEs computes a
//! partial sum over its weight slice and the NI accumulates the group's
//! partials into **one** gather payload before collection — the
//! in-flight-accumulation reading of the gather mechanism (cf. the
//! "In-Network Accumulation" follow-up work): the mesh then carries
//! `n/spread` payloads per node instead of `n`. When `spread > n` the
//! remaining reduction is folded in time (more MACs per PE per round);
//! the payload count never drops below one per node.
//!
//! Collection is otherwise identical to OS: payloads ride gather packets
//! (or repetitive unicasts) east to the row memory element, so Algorithm 1
//! and the δ machinery apply unchanged.

use crate::config::{DataflowKind, SimConfig, Streaming};
use crate::models::ConvLayer;
use crate::noc::stats::{BusStats, NetStats};

use super::{Dataflow, PsumCollection, StreamWords};

/// The WS mapping of one layer onto one mesh configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WsMapping {
    /// PEs cooperating on one filter (1 when the filter fits one RF).
    pub spread: u64,
    /// MACs per PE per round (`⌈C·R·R / spread⌉`).
    pub macs_per_pe: u64,
    /// Finished outputs per router NI per round (`max(1, n/spread)`),
    /// after NI accumulation of the spread group's partials.
    pub filters_per_node: u32,
    /// Filters resident per wave (`N·M·filters_per_node`).
    pub filters_per_wave: u64,
    /// Weight-pinning waves (`⌈Q / filters_per_wave⌉`).
    pub waves: u64,
    /// Input patches `P` (rounds per wave).
    pub patches: u64,
    /// Total rounds (`waves · P`).
    pub rounds: u64,
    /// Full per-output reduction length `C·R·R` (the broadcast patch
    /// words per round).
    pub patch_words: u64,
    /// Weight words pinned per router per wave
    /// (`filters_per_node · spread · macs_per_pe`).
    pub weight_words_per_node: u64,
}

impl WsMapping {
    pub fn new(cfg: &SimConfig, layer: &ConvLayer) -> WsMapping {
        let n = cfg.pes_per_router as u64;
        let nodes = (cfg.mesh_rows * cfg.mesh_cols) as u64;
        let macs = layer.macs_per_output();
        let spread = macs.div_ceil(cfg.ws_rf_words as u64).clamp(1, n);
        let macs_per_pe = macs.div_ceil(spread);
        let filters_per_node = (n / spread).max(1);
        let filters_per_wave = nodes * filters_per_node;
        let waves = (layer.q as u64).div_ceil(filters_per_wave);
        let patches = layer.p_patches();
        WsMapping {
            spread,
            macs_per_pe,
            filters_per_node: filters_per_node as u32,
            filters_per_wave,
            waves,
            patches,
            rounds: waves * patches,
            patch_words: macs,
            weight_words_per_node: filters_per_node * spread * macs_per_pe,
        }
    }

    /// Cycles to pin one wave's weights. Unlike the patch broadcast,
    /// every node needs *distinct* words, so a bus serves its nodes
    /// sequentially:
    ///
    /// * two-way: the column buses load in parallel, each feeding its
    ///   `N` nodes — `N · weight_words_per_node / f_l`;
    /// * one-way: weights ride the shared row buses (Fig. 10(b)), each
    ///   feeding `M` nodes — `M · weight_words_per_node / f_l`;
    /// * mesh: weights travel as column wormhole streams; approximated by
    ///   the flit serialization plus the pipeline fill of the column walk
    ///   (closed form — wave boundaries are not simulated).
    pub fn weight_load_cycles(&self, cfg: &SimConfig, streaming: Streaming) -> u64 {
        let f = cfg.bus_words_per_cycle as u64;
        match streaming {
            Streaming::TwoWay => (cfg.mesh_rows as u64 * self.weight_words_per_node).div_ceil(f),
            Streaming::OneWay => (cfg.mesh_cols as u64 * self.weight_words_per_node).div_ceil(f),
            Streaming::Mesh => {
                let ppf = cfg.payloads_per_flit() as u64;
                let flits = (cfg.mesh_rows as u64 * self.weight_words_per_node).div_ceil(ppf);
                flits + cfg.mesh_rows as u64 * (cfg.kappa() + cfg.link_latency)
            }
        }
    }

    /// Outputs produced per round network-wide.
    pub fn outputs_per_round(&self, cfg: &SimConfig) -> u64 {
        (cfg.mesh_rows * cfg.mesh_cols) as u64 * self.filters_per_node as u64
    }
}

impl Dataflow for WsMapping {
    fn map_layer(cfg: &SimConfig, layer: &ConvLayer) -> WsMapping {
        WsMapping::new(cfg, layer)
    }

    fn kind(&self) -> DataflowKind {
        DataflowKind::WeightStationary
    }

    fn rounds(&self) -> u64 {
        self.rounds
    }

    fn macs_per_pe(&self) -> u64 {
        self.macs_per_pe
    }

    fn stream_words(&self) -> StreamWords {
        // Steady state: one broadcast patch per round on the row buses,
        // nothing on the column buses (weights are resident).
        StreamWords { row: self.patch_words, col: 0 }
    }

    fn psum_collection(&self) -> PsumCollection {
        // Folding a spread group's partials into one payload takes
        // `spread − 1` adds per posted payload, performed by the NI's
        // accumulate stage; the driver reports them so the power model
        // can charge the adder/register writes.
        PsumCollection {
            payloads_per_node: self.filters_per_node,
            in_network_accumulation: self.spread > 1,
            accumulations_per_node: self.filters_per_node * (self.spread as u32 - 1),
        }
    }

    fn stream_cycles(&self, cfg: &SimConfig, streaming: Streaming) -> u64 {
        match streaming {
            Streaming::Mesh => 0,
            // The patch broadcast occupies the row bus for C·R·R/f_l
            // cycles; the one-way bus carries no interleaved weight stream
            // in steady state, so both architectures match here — WS is
            // insensitive to the one-way/two-way choice outside wave
            // boundaries.
            Streaming::OneWay | Streaming::TwoWay => {
                self.patch_words.div_ceil(cfg.bus_words_per_cycle as u64)
            }
        }
    }

    fn setup_cycles(&self, cfg: &SimConfig, streaming: Streaming) -> u64 {
        self.waves * self.weight_load_cycles(cfg, streaming)
    }

    fn setup_bus_stats(&self, cfg: &SimConfig, streaming: Streaming) -> BusStats {
        // Every node receives `weight_words_per_node` distinct words per
        // wave; the total driven words are the same whichever bus family
        // carries them — columns for two-way, the shared row buses for
        // one-way. Mesh streaming has no buses (its wave boundaries are a
        // documented closed-form approximation).
        let nodes = (cfg.mesh_rows * cfg.mesh_cols) as u64;
        let words = self.waves * nodes * self.weight_words_per_node;
        match streaming {
            Streaming::TwoWay => BusStats {
                row_words: 0,
                col_words: words,
                active_cycles: self.setup_cycles(cfg, streaming),
            },
            Streaming::OneWay => BusStats {
                row_words: words,
                col_words: 0,
                active_cycles: self.setup_cycles(cfg, streaming),
            },
            Streaming::Mesh => BusStats::default(),
        }
    }

    fn setup_net_stats(&self, cfg: &SimConfig, streaming: Streaming) -> NetStats {
        if streaming != Streaming::Mesh {
            return NetStats::default();
        }
        // Gather-only fabric: each wave sends one weight wormhole stream
        // down every column, delivering distinct words to its N nodes.
        // Mirror the event counts a simulated deliver-along-path stream
        // generates: every flit is written, read, switched and granted at
        // each of the N routers it traverses, and crosses N−1 links.
        let rows = cfg.mesh_rows as u64;
        let cols = cfg.mesh_cols as u64;
        let ppf = cfg.payloads_per_flit() as u64;
        let body = (rows * self.weight_words_per_node).div_ceil(ppf).max(1);
        let flits_per_stream = 1 + body;
        let streams = self.waves * cols;
        let per_router_events = streams * flits_per_stream * rows;
        NetStats {
            packets_injected: streams,
            packets_ejected: streams,
            flits_ejected: streams * flits_per_stream,
            buffer_writes: per_router_events,
            buffer_reads: per_router_events,
            crossbar_traversals: per_router_events,
            sa_grants: per_router_events,
            link_traversals: streams * flits_per_stream * (rows - 1),
            flit_hops: per_router_events,
            stream_deliveries: per_router_events,
            ..NetStats::default()
        }
    }

    fn useful_outputs(&self, layer: &ConvLayer) -> u64 {
        layer.p_patches() * layer.q as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::alexnet;

    #[test]
    fn conv3_mapping_shape() {
        // AlexNet conv3: C·R·R = 1728 ≤ 2048 RF words → spread 1;
        // Q = 384 over 64·4 resident filters → 2 waves of P = 169 rounds.
        let cfg = SimConfig::table1_8x8(4);
        let m = WsMapping::new(&cfg, &alexnet::conv_layers()[2]);
        assert_eq!(m.spread, 1);
        assert_eq!(m.filters_per_node, 4);
        assert_eq!(m.filters_per_wave, 256);
        assert_eq!(m.waves, 2);
        assert_eq!(m.patches, 169);
        assert_eq!(m.rounds, 2 * 169);
        assert_eq!(m.macs_per_pe, 1728);
        assert_eq!(m.weight_words_per_node, 4 * 1728);
    }

    #[test]
    fn oversized_filter_spreads_across_pes_and_accumulates_at_ni() {
        // Force a tiny register file: conv3's 1728-word filter must split.
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.ws_rf_words = 512; // spread = ceil(1728/512) = 4
        let m = WsMapping::new(&cfg, &alexnet::conv_layers()[2]);
        assert_eq!(m.spread, 4);
        assert_eq!(m.macs_per_pe, 432);
        assert_eq!(m.filters_per_node, 1);
        assert!(m.psum_collection().in_network_accumulation);
        // Spread caps at n: with n=1 the reduction folds in time instead.
        let cfg1 = {
            let mut c = SimConfig::table1_8x8(1);
            c.ws_rf_words = 512;
            c
        };
        let m1 = WsMapping::new(&cfg1, &alexnet::conv_layers()[2]);
        assert_eq!(m1.spread, 1);
        assert_eq!(m1.macs_per_pe, 1728);
        assert_eq!(m1.filters_per_node, 1);
    }

    #[test]
    fn broadcast_patch_is_independent_of_n() {
        let layer = &alexnet::conv_layers()[2];
        let w1 = WsMapping::new(&SimConfig::table1_8x8(1), layer).stream_words();
        let w8 = WsMapping::new(&SimConfig::table1_8x8(8), layer).stream_words();
        assert_eq!(w1.row, w8.row, "broadcast patch words do not scale with n");
        assert_eq!(w1.col, 0);
        assert_eq!(w8.col, 0);
    }

    #[test]
    fn one_way_matches_two_way_in_steady_state() {
        // WS streams no weights between wave boundaries, so the shared
        // one-way bus is no slower per round than two dedicated buses.
        let cfg = SimConfig::table1_8x8(4);
        let m = WsMapping::new(&cfg, &alexnet::conv_layers()[0]);
        assert_eq!(
            m.stream_cycles(&cfg, Streaming::OneWay),
            m.stream_cycles(&cfg, Streaming::TwoWay)
        );
        // ... but pays more at wave boundaries (row bus serves M nodes,
        // column buses serve N each, in parallel; equal only on square
        // meshes — then the shared bus also carries the patches).
        assert!(
            m.weight_load_cycles(&cfg, Streaming::OneWay)
                >= m.weight_load_cycles(&cfg, Streaming::TwoWay)
        );
    }

    #[test]
    fn setup_amortizes_over_waves() {
        let cfg = SimConfig::table1_8x8(4);
        let m = WsMapping::new(&cfg, &alexnet::conv_layers()[2]);
        assert_eq!(
            m.setup_cycles(&cfg, Streaming::TwoWay),
            m.waves * m.weight_load_cycles(&cfg, Streaming::TwoWay)
        );
        // Setup is a small fraction of the steady-state compute for this
        // layer (weight reuse across P = 169 patches).
        let steady = m.rounds * (m.stream_cycles(&cfg, Streaming::TwoWay) + cfg.t_mac);
        assert!(m.setup_cycles(&cfg, Streaming::TwoWay) * 4 < steady);
    }

    #[test]
    fn weight_loads_are_charged_as_bus_words() {
        let cfg = SimConfig::table1_8x8(4);
        let m = WsMapping::new(&cfg, &alexnet::conv_layers()[2]);
        let total = m.waves * 64 * m.weight_words_per_node;
        let two = m.setup_bus_stats(&cfg, Streaming::TwoWay);
        assert_eq!(two.col_words, total, "two-way loads ride the column buses");
        assert_eq!(two.row_words, 0);
        assert_eq!(two.active_cycles, m.setup_cycles(&cfg, Streaming::TwoWay));
        let one = m.setup_bus_stats(&cfg, Streaming::OneWay);
        assert_eq!(one.row_words, total, "one-way loads ride the shared row buses");
        assert_eq!(m.setup_bus_stats(&cfg, Streaming::Mesh), BusStats::default());
    }

    #[test]
    fn mesh_weight_distribution_is_charged_router_events() {
        let cfg = SimConfig::table1_8x8(4);
        let m = WsMapping::new(&cfg, &alexnet::conv_layers()[2]);
        let s = m.setup_net_stats(&cfg, Streaming::Mesh);
        // One weight stream per column per wave, events at every router
        // it traverses.
        assert_eq!(s.packets_injected, m.waves * 8);
        assert!(s.flit_hops > 0);
        assert_eq!(s.buffer_writes, s.buffer_reads);
        assert_eq!(s.flit_hops, s.crossbar_traversals);
        // Bus architectures charge weight loads to the buses instead.
        assert_eq!(m.setup_net_stats(&cfg, Streaming::TwoWay), NetStats::default());
        assert_eq!(m.setup_net_stats(&cfg, Streaming::OneWay), NetStats::default());
    }

    #[test]
    fn spread_group_reports_its_accumulate_operations() {
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.ws_rf_words = 512; // conv3: spread = 4, 1 filter/node
        let m = WsMapping::new(&cfg, &alexnet::conv_layers()[2]);
        let c = m.psum_collection();
        assert_eq!(c.accumulations_per_node, 3, "3 folds merge 4 partials");
        // No spill → no folds.
        let m1 = WsMapping::new(&SimConfig::table1_8x8(4), &alexnet::conv_layers()[2]);
        assert_eq!(m1.psum_collection().accumulations_per_node, 0);
    }

    #[test]
    fn ws_covers_the_layer_exactly_per_wave() {
        for layer in alexnet::conv_layers() {
            let cfg = SimConfig::table1_8x8(2);
            let m = WsMapping::new(&cfg, &layer);
            assert!(m.waves * m.filters_per_wave >= layer.q as u64);
            assert_eq!(m.outputs_per_round(&cfg), m.filters_per_wave);
            assert!(m.rounds * m.outputs_per_round(&cfg) >= m.useful_outputs(&layer));
        }
    }
}
