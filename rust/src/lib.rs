//! # noc-dnn — Data Streaming and Traffic Gathering in Mesh-based NoC for DNN Acceleration
//!
//! Full-system reproduction of Tiwari, Yang, Wang & Jiang (J. Systems
//! Architecture 2022 / arXiv 2021). The paper proposes two communication
//! mechanisms for mesh-based DNN accelerator NoCs running the
//! Output-Stationary (OS) dataflow:
//!
//! * **Gather packets** — a many-to-one collection packet that picks up the
//!   partial-sum payloads of intermediate routers on its way to the global
//!   memory (Algorithm 1 of the paper), governed by a timeout `δ`.
//! * **Streaming buses** — one-way / two-way buses that stream input
//!   activations and filter weights directly to PE rows/columns, relieving
//!   the mesh of one-to-many traffic.
//!
//! A third collection scheme goes beyond the source paper:
//! **in-network accumulation** ([`config::Collection::Ina`], after the
//! group's follow-up arXiv:2209.10056) — intermediate routers *add*
//! same-accumulation-space partial sums into a passing packet (and merge
//! whole packets at the switch), so a small constant-size packet collects
//! a row where gather needs a row-sized one.
//!
//! The crate contains every substrate the paper depends on, rebuilt from
//! scratch:
//!
//! * [`noc`] — a cycle-accurate, flit-level mesh NoC simulator
//!   (4-stage router pipeline, virtual channels, credit flow control,
//!   XY routing, gather and multicast packet support).
//! * [`streaming`] — the one-way/two-way streaming bus architecture.
//! * [`pe`] — processing-element and network-interface timing models.
//! * [`dataflow`] — the [`dataflow::Dataflow`] abstraction ("layer →
//!   per-round NoC traffic") with two implementations: the paper's
//!   Output-Stationary mapping ([`dataflow::os`]) and a Weight-Stationary
//!   mapping ([`dataflow::ws`]) where weights are pinned in PE register
//!   files and input patches are broadcast on the row buses.
//! * [`models`] — AlexNet / VGG-16 / ResNet-lite convolution layer shape
//!   tables, plus [`models::Network`]: a whole DNN as a first-class
//!   executable object (ordered layers + metadata).
//! * [`plan`] — per-layer execution policies: a
//!   [`plan::NetworkPlan`] assigns every layer its own
//!   (streaming × collection × dataflow) triple — uniform, JSON-loaded,
//!   or the sim-verified per-layer argmin built by
//!   [`coordinator::executor::best_plan`].
//! * [`power`] — Orion-3.0-style router energy and DSENT-style bus energy
//!   models plus the §5.4 area/power overhead roll-up.
//! * [`analytic`] — the closed-form latency models of Eqs. (3) and (4),
//!   generalized over the dataflow and cross-checked against simulation.
//! * [`coordinator`] — experiment orchestration: sweeps, baselines,
//!   regeneration of every figure in the paper's evaluation section, the
//!   OS-vs-WS dataflow study (`noc-dnn compare`), and the whole-network
//!   execution engine ([`coordinator::executor::NetworkExecutor`]): runs
//!   a model under a plan, layer by layer, with inter-layer traffic
//!   charged at the boundaries and the layers fanned out over worker
//!   threads (`noc-dnn model`).
//! * [`runtime`] — PJRT bridge that loads the AOT-compiled JAX/Pallas
//!   convolution artifacts (`artifacts/*.hlo.txt`) and executes the real
//!   layer numerics from rust; Python is never on the request path.
//!   Requires the `pjrt` cargo feature (plus the `xla` crate); the default
//!   offline build ships a stub that fails loudly at artifact load.
//! * [`config`] — configuration types with JSON round-trip (Table 1
//!   defaults), including the [`config::DataflowKind`] and
//!   [`config::Collection`] selectors.
//! * [`serving`] — serving-scale traffic on top of the executor: seeded
//!   request arrivals (Poisson / uniform / closed-loop), batch
//!   scheduling with per-tenant priority, a multi-pass fabric-sharing
//!   executor, and deterministic p50/p99/p999 tail-latency metrics with
//!   saturation-knee location (`noc-dnn serve`).
//!
//! See `ARCHITECTURE.md` at the repository root for the module map, the
//! simulator's per-cycle tick order, and the topology layer.
//!
//! ## Quickstart
//!
//! The public surface is the [`prelude`]: a [`api::ScenarioBuilder`]
//! constructs a validated [`api::Scenario`] (typed [`config::ConfigError`]
//! on any invalid input — no panicking constructors), and the scenario is
//! the single entry point for per-layer simulation and whole-model
//! execution:
//!
//! ```no_run
//! use noc_dnn::prelude::*;
//!
//! // 8x8 PE array concentrated onto a 4x4 router grid, Weight-Stationary
//! // dataflow, in-network accumulation. Swap TopologyKind::CMesh for
//! // ::Torus or ::Mesh to change the fabric — nothing else changes.
//! let scenario = ScenarioBuilder::new()
//!     .mesh(8)
//!     .pes_per_router(4)
//!     .topology(TopologyKind::CMesh)
//!     .dataflow(DataflowKind::WeightStationary)
//!     .collection(Collection::Ina)
//!     .build()?;
//! let layer = &alexnet::conv_layers()[0];
//! let report = scenario.simulate(layer);
//! println!(
//!     "latency = {} cycles under the {} dataflow",
//!     report.run.total_cycles,
//!     report.run.dataflow
//! );
//! # Ok::<(), noc_dnn::config::ConfigError>(())
//! ```
//!
//! Whole models run through the same scenario — each layer under its own
//! policy, totals rolled up with inter-layer traffic charged at the
//! boundaries:
//!
//! ```no_run
//! use noc_dnn::prelude::*;
//!
//! let scenario = ScenarioBuilder::new().mesh(8).pes_per_router(4).build()?;
//! let model = Network::alexnet(); // or vgg16() / resnet_lite()
//! let plan = best_plan(scenario.config(), &model); // per-layer argmin, sim-verified
//! let run = scenario.execute(&model, &plan).unwrap();
//! println!("{} cycles, {:.3} mJ", run.total_cycles, run.total_energy_j * 1e3);
//! # Ok::<(), noc_dnn::config::ConfigError>(())
//! ```
//!
//! From the CLI: `noc-dnn run --model alexnet --dataflow ws` simulates one
//! configuration (`--topology mesh|torus|cmesh` selects the fabric);
//! `noc-dnn model --model alexnet --plan best --json` runs the whole model
//! under per-layer policies; `noc-dnn compare` runs the full OS-vs-WS
//! study across all three streaming modes and all three collection
//! schemes (RU / gather / INA).

pub mod analytic;
pub mod api;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod models;
pub mod noc;
pub mod pe;
pub mod plan;
pub mod power;
pub mod runtime;
pub mod serving;
pub mod streaming;
pub mod util;

/// One-stop imports for the public API: the scenario façade, the config
/// selectors, models, plans and the most-used entry points.
pub mod prelude {
    pub use crate::api::{RunReport, Scenario, ScenarioBuilder};
    pub use crate::config::{
        Collection, ConfigError, DataflowKind, PeGrouping, SimConfig, Streaming, TopologyKind,
    };
    pub use crate::coordinator::executor::{best_plan, NetworkExecutor, NetworkRunReport};
    pub use crate::coordinator::Experiment;
    pub use crate::dataflow::run_layer;
    pub use crate::models::{alexnet, ConvLayer, Network};
    pub use crate::noc::faults::{DegradationReport, FaultsConfig};
    pub use crate::noc::network::{RunOutcome, StallReport};
    pub use crate::noc::probes::{Bottleneck, BottleneckStage, LinkRecord, ProbeReport};
    pub use crate::noc::topology::Topology;
    pub use crate::plan::{LayerPolicy, NetworkPlan};
    pub use crate::serving::{
        ArrivalKind, SchedKind, ServiceProfile, ServingConfig, ServingReport,
    };
    pub use crate::util::histogram::Histogram;
}

/// The north-star spelling of this crate's namespace: `pallas::prelude`
/// is [`prelude`] — embedders that standardize on the `pallas` name can
/// `use noc_dnn::pallas::prelude::*`.
pub mod pallas {
    pub use crate::prelude;
}

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
