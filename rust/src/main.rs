//! `noc-dnn` — CLI for the mesh-NoC DNN-acceleration reproduction.
//!
//! ```text
//! noc-dnn figure 12 [--mesh 8] [--json]         # δ sweep (Fig. 12)
//! noc-dnn figure 13 [--mesh 8]                  # gather packet size study
//! noc-dnn figure 14 [--mesh 8] [--n 4]          # streaming vs gather-only
//! noc-dnn figure 15                             # AlexNet vs RU
//! noc-dnn figure 16                             # VGG-16 vs RU
//! noc-dnn run --model alexnet [--mesh 8] [--n 4] [--streaming two-way]
//!             [--collection gather] [--dataflow os|ws] [--rounds-cap 8]
//! noc-dnn compare [--model alexnet] [--mesh 8] [--n 4] [--json]
//!                                               # OS vs WS dataflow study
//! noc-dnn overhead                              # §5.4 router overhead
//! noc-dnn config --show [--mesh 8] [--n 1]      # print Table-1 config JSON
//! ```

use anyhow::{bail, Result};
use noc_dnn::config::{Collection, DataflowKind, SimConfig, Streaming};
use noc_dnn::coordinator::{report, sweep, Experiment};
use noc_dnn::models::{alexnet, vgg16, ConvLayer};
use noc_dnn::power::area::overhead_report;
use noc_dnn::util::cli::Args;

const VALUED: &[&str] = &[
    "mesh",
    "n",
    "model",
    "streaming",
    "collection",
    "dataflow",
    "rounds-cap",
    "delta",
    "layer",
];
const BOOLEAN: &[&str] = &["json", "show", "help"];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUED, BOOLEAN)?;
    if args.get_bool("help") || args.positional(0).is_none() {
        print!("{}", usage());
        return Ok(());
    }
    match args.positional(0).unwrap() {
        "figure" => figure(&args),
        "run" => run(&args),
        "compare" => compare(&args),
        "overhead" => overhead(&args),
        "config" => config_cmd(&args),
        cmd => bail!("unknown command '{cmd}'\n{}", usage()),
    }
}

fn usage() -> &'static str {
    "noc-dnn — Data Streaming and Traffic Gathering in Mesh-based NoC for DNN Acceleration

USAGE:
  noc-dnn figure <12|13|14|15|16> [--mesh 8|16] [--n 1|2|4|8] [--json]
  noc-dnn run --model <alexnet|vgg16> [--mesh N] [--n N]
              [--streaming mesh|one-way|two-way] [--collection ru|gather|ina]
              [--dataflow os|ws] [--rounds-cap K] [--delta D] [--layer NAME]
  noc-dnn compare [--model <alexnet|vgg16>] [--mesh N] [--n N] [--json]
  noc-dnn overhead
  noc-dnn config --show [--mesh N] [--n N] [--dataflow os|ws]
                 [--collection ru|gather|ina]

FLAGS:
  --dataflow os|ws   dataflow mapping: Output-Stationary (paper default) or
                     Weight-Stationary (weights pinned in PE register files,
                     input patches broadcast on the row buses)
  --streaming MODE   operand distribution: dedicated one-way/two-way buses
                     (Fig. 10) or the mesh itself ('mesh', gather-only [27])
  --collection C     partial-sum collection: 'gather' packets (Algorithm 1),
                     repetitive unicast 'ru', or 'ina' in-network
                     accumulation (psums added at intermediate routers,
                     arXiv:2209.10056)

`compare` runs the whole model under OS and WS for every streaming mode x
RU/gather/INA collection scheme and prints latency/energy with WS-vs-OS
ratios.
"
}

fn cfg_from(args: &Args) -> Result<SimConfig> {
    let mesh: usize = args.get_parsed("mesh", 8)?;
    let n: usize = args.get_parsed("n", 1)?;
    let mut cfg = SimConfig::table1(mesh, n);
    cfg.sim_rounds_cap = args.get_parsed("rounds-cap", cfg.sim_rounds_cap)?;
    cfg.delta = args.get_parsed("delta", cfg.delta)?;
    if let Some(df) = args.get("dataflow") {
        cfg.dataflow = DataflowKind::parse(df)?;
    }
    if let Some(c) = args.get("collection") {
        cfg.collection = Collection::parse(c)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn model_layers(name: &str) -> Result<Vec<ConvLayer>> {
    match name {
        "alexnet" => Ok(alexnet::conv_layers()),
        "vgg16" => Ok(vgg16::conv_layers()),
        m => bail!("unknown model '{m}' (alexnet | vgg16)"),
    }
}

fn figure(args: &Args) -> Result<()> {
    let which = args.positional(1).ok_or_else(|| anyhow::anyhow!("figure needs a number"))?;
    let mesh: usize = args.get_parsed("mesh", 8)?;
    match which {
        "12" => {
            let series = sweep::fig12(mesh, &[0, 1, 3, 5, 7, 9, 11]);
            if args.get_bool("json") {
                println!("{}", report::fig12_json(&series).to_pretty());
            } else {
                println!("Fig. 12 — effect of δ on {mesh}x{mesh} single-row collection");
                print!("{}", report::fig12_text(&series));
            }
        }
        "13" => {
            let layer = &alexnet::conv_layers()[2]; // representative conv
            let rows = sweep::fig13(mesh, layer);
            println!(
                "Fig. 13 — gather packet size study on {mesh}x{mesh} (workload: AlexNet {})",
                layer.name
            );
            print!("{}", report::fig13_text(&rows));
        }
        "14" => {
            let n: usize = args.get_parsed("n", 1)?;
            let rows = sweep::fig14(mesh, n);
            println!("Fig. 14 — runtime improvement over gather-only [27] ({mesh}x{mesh}, n={n})");
            print!("{}", report::fig14_text(&rows));
        }
        "15" | "16" => {
            let layers =
                if which == "15" { alexnet::conv_layers() } else { vgg16::conv_layers() };
            let name = if which == "15" { "AlexNet" } else { "VGG-16" };
            let points = sweep::fig_model(&layers, &[8, 16], &[1, 2, 4, 8]);
            if args.get_bool("json") {
                println!("{}", report::fig_model_json(&points).to_pretty());
            } else {
                println!("Fig. {which} — {name}: gather vs RU on two-way streaming");
                print!("{}", report::fig_model_text(&points));
            }
        }
        f => bail!("unknown figure '{f}' (12..16)"),
    }
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    let cfg = cfg_from(args)?;
    let streaming = match args.get("streaming").unwrap_or("two-way") {
        "mesh" => Streaming::Mesh,
        "one-way" => Streaming::OneWay,
        "two-way" => Streaming::TwoWay,
        s => bail!("unknown streaming '{s}'"),
    };
    // cfg_from already folded --collection into the config.
    let collection = cfg.collection;
    let mut layers = model_layers(args.get("model").unwrap_or("alexnet"))?;
    if let Some(name) = args.get("layer") {
        layers.retain(|l| l.name == name);
        anyhow::ensure!(!layers.is_empty(), "no layer named '{name}'");
    }
    let exp = Experiment::new(cfg.clone(), streaming, collection);
    println!(
        "running {} layer(s) on {}x{} mesh, n={}, dataflow={}, streaming={}, collection={}",
        layers.len(),
        cfg.mesh_cols,
        cfg.mesh_rows,
        cfg.pes_per_router,
        cfg.dataflow.label(),
        streaming.label(),
        collection.label()
    );
    let m = exp.run_model(&layers);
    let rows: Vec<Vec<String>> = m
        .layers
        .iter()
        .map(|l| {
            vec![
                l.layer.clone(),
                l.run.rounds_total.to_string(),
                l.run.total_cycles.to_string(),
                format!("{:.3}", l.run.total_seconds(&cfg) * 1e3),
                format!("{:.3}", l.power.total_j * 1e3),
                format!("{:.1}", l.power.avg_power_w * 1e3),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["layer", "rounds", "cycles", "runtime(ms)", "energy(mJ)", "avg power(mW)"],
            &rows
        )
    );
    println!(
        "TOTAL: {} cycles = {:.3} ms, {:.3} mJ",
        m.total_cycles,
        m.total_cycles as f64 / cfg.clock_hz * 1e3,
        m.total_energy_j * 1e3
    );
    Ok(())
}

fn compare(args: &Args) -> Result<()> {
    let mesh: usize = args.get_parsed("mesh", 8)?;
    let n: usize = args.get_parsed("n", 4)?;
    // --dataflow is accepted for symmetry with `run` but the study always
    // covers both dataflows; the flag just validates.
    if let Some(df) = args.get("dataflow") {
        DataflowKind::parse(df)?;
    }
    let model = args.get("model").unwrap_or("alexnet");
    let layers = model_layers(model)?;
    let rows = sweep::dataflow_compare(mesh, n, &layers);
    if args.get_bool("json") {
        println!("{}", report::dataflow_compare_json(&rows).to_pretty());
    } else {
        println!(
            "Dataflow study — {model} total on {mesh}x{mesh}, n={n}: \
             Output-Stationary vs Weight-Stationary"
        );
        print!("{}", report::dataflow_compare_text(&rows));
        println!(
            "(WS pins weights in PE register files and broadcasts one patch/round \
             on the row buses; OS streams n patches/router and one filter/column.)"
        );
    }
    Ok(())
}

fn overhead(_args: &Args) -> Result<()> {
    let r = overhead_report(1.0e9);
    println!("§5.4 hardware overhead (45 nm, 1 GHz router, Table 1 config)");
    print!(
        "{}",
        report::table(
            &["", "baseline", "gather-supported", "overhead"],
            &[
                vec![
                    "power (mW)".into(),
                    format!("{:.2}", r.baseline_power_mw),
                    format!("{:.2}", r.proposed_power_mw),
                    format!("{:.1}%", r.power_overhead_pct),
                ],
                vec![
                    "area (µm²)".into(),
                    format!("{:.0}", r.baseline_area_um2),
                    format!("{:.0}", r.proposed_area_um2),
                    format!("{:.1}%", r.area_overhead_pct),
                ],
            ]
        )
    );
    Ok(())
}

fn config_cmd(args: &Args) -> Result<()> {
    let cfg = cfg_from(args)?;
    println!("{}", cfg.to_json());
    Ok(())
}
