//! `noc-dnn` — CLI for the mesh-NoC DNN-acceleration reproduction.
//!
//! ```text
//! noc-dnn figure 12 [--mesh 8] [--json]         # δ sweep (Fig. 12)
//! noc-dnn figure 13 [--mesh 8]                  # gather packet size study
//! noc-dnn figure 14 [--mesh 8] [--n 4]          # streaming vs gather-only
//! noc-dnn figure 15                             # AlexNet vs RU
//! noc-dnn figure 16                             # VGG-16 vs RU
//! noc-dnn run --model alexnet [--mesh 8] [--n 4] [--streaming two-way]
//!             [--collection gather] [--dataflow os|ws] [--rounds-cap 8]
//! noc-dnn model --model alexnet --plan best     # whole-model executor
//!               [--threads 0] [--json]          # (per-layer policies)
//! noc-dnn compare [--model alexnet] [--mesh 8] [--n 4] [--json]
//!                                               # OS vs WS dataflow study
//! noc-dnn analyze --model alexnet [--layer NAME] [--json]
//!                                               # per-link utilization +
//!                                               # bottleneck attribution
//! noc-dnn serve --model alexnet --arrival-rate 2 [--batch 4] [--json]
//!                                               # serving traffic: batch
//!                                               # scheduling + p99 tail +
//!                                               # saturation knee (--sweep)
//! noc-dnn overhead                              # §5.4 router overhead
//! noc-dnn config --show [--mesh 8] [--n 1]      # print Table-1 config JSON
//! ```

use anyhow::{bail, Result};
use noc_dnn::api::ScenarioBuilder;
use noc_dnn::config::{Collection, DataflowKind, SimConfig, Streaming, TopologyKind};
use noc_dnn::coordinator::executor::{best_plan_search, NetworkExecutor, PlanSearchOptions};
use noc_dnn::coordinator::{report, sweep};
use noc_dnn::models::{alexnet, Network};
use noc_dnn::plan::{LayerPolicy, NetworkPlan};
use noc_dnn::power::area::overhead_report;
use noc_dnn::serving::{self, ArrivalKind, SchedKind, ServiceProfile, ServingConfig};
use noc_dnn::util::cli::Args;

const VALUED: &[&str] = &[
    "mesh",
    "n",
    "model",
    "topology",
    "streaming",
    "collection",
    "dataflow",
    "rounds-cap",
    "threads",
    "intra-workers",
    "plan",
    "delta",
    "layer",
    "faults",
    "max-cycles",
    "arrival-rate",
    "arrivals",
    "batch",
    "batch-timeout",
    "tenants",
    "sched",
    "queue-cap",
    "max-inflight",
    "clients",
    "think",
    "duration",
    "seed",
    "sweep",
];
const BOOLEAN: &[&str] = &["json", "show", "help"];

fn main() {
    // Every failure — flag typos, unknown keywords, invalid geometry,
    // malformed plan JSON — surfaces as a printed error and a nonzero
    // exit, never an unwinding panic.
    if let Err(e) = cli_main() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
}

fn cli_main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUED, BOOLEAN)?;
    if args.get_bool("help") || args.positional(0).is_none() {
        print!("{}", usage());
        return Ok(());
    }
    match args.positional(0).unwrap() {
        "figure" => figure(&args),
        "run" => run(&args),
        "model" => model_cmd(&args),
        "compare" => compare(&args),
        "analyze" => analyze(&args),
        "serve" => serve_cmd(&args),
        "overhead" => overhead(&args),
        "config" => config_cmd(&args),
        cmd => bail!("unknown command '{cmd}'\n{}", usage()),
    }
}

fn usage() -> &'static str {
    "noc-dnn — Data Streaming and Traffic Gathering in Mesh-based NoC for DNN Acceleration

USAGE:
  noc-dnn figure <12|13|14|15|16> [--mesh 8|16] [--n 1|2|4|8] [--json]
  noc-dnn run --model <alexnet|vgg16|resnet-lite> [--mesh N] [--n N]
              [--topology mesh|torus|cmesh]
              [--streaming mesh|one-way|two-way] [--collection ru|gather|ina]
              [--dataflow os|ws] [--rounds-cap K] [--delta D] [--layer NAME]
              [--faults SPEC|file.json] [--max-cycles N]
  noc-dnn model --model <alexnet|vgg16|resnet-lite>
                [--plan uniform|best|<file.json>] [--mesh N] [--n N]
                [--topology T] [--streaming MODE] [--collection C]
                [--dataflow D] [--threads T] [--rounds-cap K] [--json]
  noc-dnn compare [--model <alexnet|vgg16|resnet-lite>] [--mesh N] [--n N]
                  [--json]
  noc-dnn analyze [--model <alexnet|vgg16|resnet-lite>] [--layer NAME]
                  [--mesh N] [--n N] [--topology T] [--streaming MODE]
                  [--collection C] [--dataflow D] [--rounds-cap K]
                  [--faults SPEC|file.json] [--json]
  noc-dnn serve --model <alexnet|vgg16|resnet-lite> --arrival-rate R
                [--arrivals poisson|uniform|closed] [--batch B]
                [--batch-timeout CYC] [--tenants T] [--sched fifo|priority]
                [--queue-cap Q] [--max-inflight P] [--duration CYC]
                [--clients K] [--think CYC] [--seed S] [--sweep R1,R2,..]
                [--mesh N] [--n N] [--topology T] [--streaming MODE]
                [--collection C] [--dataflow D] [--rounds-cap K]
                [--faults SPEC] [--json]
  noc-dnn overhead
  noc-dnn config --show [--mesh N] [--n N] [--topology T] [--dataflow os|ws]
                 [--collection ru|gather|ina] [--threads T]

FLAGS:
  --topology T       router fabric: 'mesh' (the paper's, default), 'torus'
                     (wraparound links; unicast results take ring-minimal
                     routes under a dateline VC rule) or 'cmesh'
                     (concentrated mesh: 2x2 PE groups per router — the
                     --mesh PE-array side maps onto a half-radix router
                     grid with 4x the PEs per router)
  --dataflow os|ws   dataflow mapping: Output-Stationary (paper default) or
                     Weight-Stationary (weights pinned in PE register files,
                     input patches broadcast on the row buses)
  --streaming MODE   operand distribution: dedicated one-way/two-way buses
                     (Fig. 10) or the fabric itself ('mesh', gather-only [27])
  --collection C     partial-sum collection: 'gather' packets (Algorithm 1),
                     repetitive unicast 'ru', or 'ina' in-network
                     accumulation (psums added at intermediate routers,
                     arXiv:2209.10056)
  --plan P           whole-network execution plan: 'uniform' applies the
                     --streaming/--collection/--dataflow triple to every
                     layer; 'best' searches the per-layer argmin over the
                     full policy grid (analytic ranking, sim-verified —
                     rejects the triple flags, which it would ignore); a
                     path loads a custom JSON plan (one policy per layer)
  --threads T        worker threads for the layer fan-out (0 = auto)
  --faults SPEC      deterministic fault injection: an inline spec
                     ('seed=7,rate=0.02,links=3:2:E,routers=5:5,
                     transient=1:1:E:100:400,corrupt=0.001,retries=4,
                     holdoff=8') or a path to a *.json fault plan.
                     Permanently faulted links/routers are routed around
                     (XY over the healthy subgraph), corrupted flits are
                     retransmitted under the retry budget, and gather/INA
                     degrade gracefully — analyze/run report the
                     DegradationReport. Unset = fault-free, bit-identical
                     to the unfaulted kernel
  --max-cycles N     hard cap on simulated cycles per run_until call; a
                     wedged run returns a typed outcome instead of
                     spinning forever
  --intra-workers W  band workers inside each simulation (the
                     deterministic intra-layer parallel kernel; 1 =
                     sequential, results bit-identical at any count; the
                     layer fan-out is clamped so threads x W stays within
                     the host)
  --arrival-rate R   serve: offered load in requests per million cycles
                     (open-loop modes; required unless --arrivals closed
                     or --sweep)
  --arrivals MODE    serve: 'poisson' (default), 'uniform' (constant gap)
                     or 'closed' (bounded population: --clients issuers,
                     one outstanding request each, --think cycles between
                     completion and reissue)
  --batch B          serve: max images per admitted batch (setup is paid
                     once per batch, streaming/compute per image)
  --batch-timeout C  serve: cycles a queue head may age before a partial
                     batch is forced out (0 = auto: half a full pass)
  --tenants T        serve: round-robin tenant count; with --sched
                     priority each tenant gets its own queue mapped to a
                     VC class, lower ids win ties
  --queue-cap Q      serve: waiting-request capacity; arrivals beyond it
                     are rejected (counted in the report)
  --max-inflight P   serve: concurrent passes time-sharing the fabric at
                     layer granularity
  --duration CYC     serve: arrival window; the run then drains
                     (0 = auto: 32 full-batch passes)
  --sweep R1,R2,..   serve: run each rate (strictly increasing) and mark
                     the saturation knee — the last rate with zero
                     rejections and p99 within 5x of the lowest rate's

`model` executes a whole DNN through the network executor: per-layer
flit-accurate simulation, per-layer policies, inter-layer traffic charged
at the boundaries, per-layer rows + model totals (use --json for machine
output). `compare` runs the whole model under OS and WS for every
streaming mode x RU/gather/INA collection scheme and prints latency/energy
with WS-vs-OS ratios. `analyze` re-runs the selected layers with the
per-link observability probes on and reports where the fabric saturates:
a bottleneck-attribution table (which link/VC/stage bounds each layer)
and a link-utilization heatmap per layer; --json emits the full
per-directed-link counters and the cycle-bucketed utilization series.
Under --faults, analyze also prints the per-layer fault-degradation
table (corrupted/retransmitted/dropped counts, missing gather
contributors, detour hops) and --json carries it as 'degraded'.

`serve` turns the executor into a capacity-planning tool: it profiles the
model once per layer (probes on), then time-shares the fabric across
concurrent inference passes fed by a seeded arrival process through a
batch scheduler, and reports throughput, offered/accepted/rejected
counts, queue depths, deterministic p50/p99/p999 latency and the link
that saturates first under load. --sweep serves each listed rate and
marks the saturation knee. Same seed, same ledger — bit for bit, at any
--threads/--intra-workers.
"
}

/// Build the scenario through the typed [`ScenarioBuilder`] façade:
/// `--mesh` names the logical PE-array side, which `--topology cmesh`
/// concentrates onto a half-radix router grid; every invalid combination
/// is a typed `ConfigError` printed by `main`. Built once per command —
/// `run` drives it directly, the other commands take its config.
fn scenario_from(args: &Args) -> Result<noc_dnn::api::Scenario> {
    let mut b = ScenarioBuilder::new()
        .mesh(args.get_parsed("mesh", 8)?)
        .pes_per_router(args.get_parsed("n", 1)?)
        .streaming(streaming_from(args)?);
    if let Some(t) = args.get("topology") {
        b = b.topology(TopologyKind::parse(t)?);
    }
    if let Some(df) = args.get("dataflow") {
        b = b.dataflow(DataflowKind::parse(df)?);
    }
    if let Some(c) = args.get("collection") {
        b = b.collection(Collection::parse(c)?);
    }
    if args.get("rounds-cap").is_some() {
        b = b.rounds_cap(args.get_parsed("rounds-cap", 0)?);
    }
    if args.get("threads").is_some() {
        b = b.threads(args.get_parsed("threads", 0)?);
    }
    if args.get("intra-workers").is_some() {
        b = b.intra_workers(args.get_parsed("intra-workers", 1)?);
    }
    if args.get("delta").is_some() {
        b = b.delta(args.get_parsed("delta", 0)?);
    }
    if let Some(spec) = args.get("faults") {
        b = b.faults(faults_from(spec)?);
    }
    if args.get("max-cycles").is_some() {
        let cap: u64 = args.get_parsed("max-cycles", 0)?;
        b = b.configure(move |c| c.max_cycles = cap);
    }
    Ok(b.build()?)
}

/// `--faults` accepts either an inline spec string
/// (`seed=7,rate=0.02,corrupt=0.001`) or a path to a JSON file in the
/// `FaultsConfig::to_json` shape; the plan itself is validated against
/// the final fabric by `ScenarioBuilder::build`.
fn faults_from(spec: &str) -> Result<noc_dnn::noc::FaultsConfig> {
    use noc_dnn::noc::FaultsConfig;
    if spec.ends_with(".json") {
        let text = std::fs::read_to_string(spec)
            .map_err(|e| anyhow::anyhow!("cannot read fault plan '{spec}': {e}"))?;
        let j = noc_dnn::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("fault plan '{spec}': {e}"))?;
        Ok(FaultsConfig::from_json(&j)?)
    } else {
        Ok(FaultsConfig::parse(spec)?)
    }
}

fn cfg_from(args: &Args) -> Result<SimConfig> {
    Ok(scenario_from(args)?.config().clone())
}

fn streaming_from(args: &Args) -> Result<Streaming> {
    Streaming::parse(args.get("streaming").unwrap_or("two-way"))
}

fn figure(args: &Args) -> Result<()> {
    // The figure sweeps reproduce the paper's mesh-only evaluation; a
    // silently ignored fabric flag would mislabel the output.
    anyhow::ensure!(
        args.get("topology").is_none(),
        "--topology only applies to run/model/config; the paper figures are mesh-only"
    );
    let which = args.positional(1).ok_or_else(|| anyhow::anyhow!("figure needs a number"))?;
    let mesh: usize = args.get_parsed("mesh", 8)?;
    match which {
        "12" => {
            let series = sweep::fig12(mesh, &[0, 1, 3, 5, 7, 9, 11]);
            if args.get_bool("json") {
                println!("{}", report::fig12_json(&series).to_pretty());
            } else {
                println!("Fig. 12 — effect of δ on {mesh}x{mesh} single-row collection");
                print!("{}", report::fig12_text(&series));
            }
        }
        "13" => {
            let layer = &alexnet::conv_layers()[2]; // representative conv
            let rows = sweep::fig13(mesh, layer);
            println!(
                "Fig. 13 — gather packet size study on {mesh}x{mesh} (workload: AlexNet {})",
                layer.name
            );
            print!("{}", report::fig13_text(&rows));
        }
        "14" => {
            let n: usize = args.get_parsed("n", 1)?;
            let rows = sweep::fig14(mesh, n);
            println!("Fig. 14 — runtime improvement over gather-only [27] ({mesh}x{mesh}, n={n})");
            print!("{}", report::fig14_text(&rows));
        }
        "15" | "16" => {
            let model = if which == "15" { Network::alexnet() } else { Network::vgg16() };
            let name = if which == "15" { "AlexNet" } else { "VGG-16" };
            let points = sweep::fig_model(&model, &[8, 16], &[1, 2, 4, 8]);
            if args.get_bool("json") {
                println!("{}", report::fig_model_json(&points).to_pretty());
            } else {
                println!("Fig. {which} — {name}: gather vs RU on two-way streaming");
                print!("{}", report::fig_model_text(&points));
            }
        }
        f => bail!("unknown figure '{f}' (12..16)"),
    }
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    // One scenario for the whole command: the façade validates once and
    // every layer simulation shares its config Arc.
    let scenario = scenario_from(args)?;
    let cfg = scenario.config();
    let mut layers = Network::by_name(args.get("model").unwrap_or("alexnet"))?.layers;
    if let Some(name) = args.get("layer") {
        layers.retain(|l| l.name == name);
        anyhow::ensure!(!layers.is_empty(), "no layer named '{name}'");
    }
    println!(
        "running {} layer(s) on {}x{} {} routers, n={}, dataflow={}, streaming={}, collection={}",
        layers.len(),
        cfg.mesh_cols,
        cfg.mesh_rows,
        cfg.topology.label(),
        cfg.pes_per_router,
        cfg.dataflow.label(),
        scenario.streaming().label(),
        scenario.collection().label()
    );
    let reports: Vec<_> = layers.iter().map(|l| scenario.simulate(l)).collect();
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|l| {
            vec![
                l.layer.clone(),
                l.run.rounds_total.to_string(),
                l.run.total_cycles.to_string(),
                format!("{:.3}", l.run.total_seconds(cfg) * 1e3),
                format!("{:.3}", l.power.total_j * 1e3),
                format!("{:.1}", l.power.avg_power_w * 1e3),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["layer", "rounds", "cycles", "runtime(ms)", "energy(mJ)", "avg power(mW)"],
            &rows
        )
    );
    let total_cycles: u64 = reports.iter().map(|l| l.run.total_cycles).sum();
    let total_energy_j: f64 = reports.iter().map(|l| l.power.total_j).sum();
    println!(
        "TOTAL: {} cycles = {:.3} ms, {:.3} mJ",
        total_cycles,
        total_cycles as f64 / cfg.clock_hz * 1e3,
        total_energy_j * 1e3
    );
    Ok(())
}

fn model_cmd(args: &Args) -> Result<()> {
    let cfg = cfg_from(args)?;
    let model = Network::by_name(args.get("model").unwrap_or("alexnet"))?;
    let rep = match args.get("plan").unwrap_or("uniform") {
        // The search's sim-verified evaluations are exactly what the
        // executor would recompute — reuse them instead of re-simulating.
        "best" => {
            // The search sweeps the whole policy grid; a per-run triple
            // would be silently discarded, so reject the combination.
            for flag in ["streaming", "collection", "dataflow"] {
                anyhow::ensure!(
                    args.get(flag).is_none(),
                    "--{flag} only applies to --plan uniform; \
                     --plan best searches every streaming/collection/dataflow combination"
                );
            }
            best_plan_search(&cfg, &model, &PlanSearchOptions::default())
                .run_report(&cfg, &model)
        }
        "uniform" => {
            let plan = NetworkPlan::uniform(
                LayerPolicy {
                    streaming: streaming_from(args)?,
                    collection: cfg.collection,
                    dataflow: cfg.dataflow,
                },
                model.len(),
            );
            NetworkExecutor::new(cfg).run(&model, &plan)?
        }
        path => {
            let plan = NetworkPlan::from_json(
                &std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("cannot read plan '{path}': {e}"))?,
            )?;
            NetworkExecutor::new(cfg).run(&model, &plan)?
        }
    };
    if args.get_bool("json") {
        println!("{}", report::network_run_json(&rep).to_pretty());
    } else {
        println!(
            "model {} ({} layers, {} MACs) under plan '{}' on {}x{}, n={}",
            rep.model,
            model.len(),
            rep.total_macs,
            rep.plan,
            rep.cfg.mesh_cols,
            rep.cfg.mesh_rows,
            rep.cfg.pes_per_router
        );
        print!("{}", report::network_run_text(&rep));
    }
    Ok(())
}

fn compare(args: &Args) -> Result<()> {
    // The OS-vs-WS study sweeps the mesh fabric only; reject rather than
    // silently ignore a fabric request (same convention as --plan best's
    // triple-flag rejection).
    anyhow::ensure!(
        args.get("topology").is_none(),
        "--topology only applies to run/model/config; the compare study is mesh-only"
    );
    let mesh: usize = args.get_parsed("mesh", 8)?;
    let n: usize = args.get_parsed("n", 4)?;
    // --dataflow is accepted for symmetry with `run` but the study always
    // covers both dataflows; the flag just validates.
    if let Some(df) = args.get("dataflow") {
        DataflowKind::parse(df)?;
    }
    let model = args.get("model").unwrap_or("alexnet");
    let layers = Network::by_name(model)?.layers;
    let rows = sweep::dataflow_compare(mesh, n, &layers);
    if args.get_bool("json") {
        println!("{}", report::dataflow_compare_json(&rows).to_pretty());
    } else {
        println!(
            "Dataflow study — {model} total on {mesh}x{mesh}, n={n}: \
             Output-Stationary vs Weight-Stationary"
        );
        print!("{}", report::dataflow_compare_text(&rows));
        println!(
            "(WS pins weights in PE register files and broadcasts one patch/round \
             on the row buses; OS streams n patches/router and one filter/column.)"
        );
    }
    Ok(())
}

fn analyze(args: &Args) -> Result<()> {
    // Same scenario façade as `run`, but with the per-link probes forced
    // on — `analyze` exists to look at the measured link counters, so
    // there is no probe-off variant to configure.
    let base = scenario_from(args)?;
    let mut cfg = base.config().clone();
    cfg.probes = true;
    let scenario = ScenarioBuilder::from_config(cfg).streaming(base.streaming()).build()?;
    let cfg = scenario.config();
    let model = args.get("model").unwrap_or("alexnet");
    let mut layers = Network::by_name(model)?.layers;
    if let Some(name) = args.get("layer") {
        layers.retain(|l| l.name == name);
        anyhow::ensure!(!layers.is_empty(), "no layer named '{name}'");
    }
    let analyzed: Vec<report::AnalyzedLayer> = layers
        .iter()
        .map(|l| {
            let run = scenario.run_raw(l);
            report::AnalyzedLayer {
                name: l.name.to_string(),
                probes: run.probes.expect("probes were forced on for analyze"),
                degraded: run.degraded,
            }
        })
        .collect();
    if args.get_bool("json") {
        println!("{}", report::analyze_json(model, &analyzed).to_pretty());
        return Ok(());
    }
    println!(
        "analyzing {} layer(s) of {} on {}x{} {} routers, n={}, dataflow={}, \
         streaming={}, collection={} (probes on, measured prefix)",
        analyzed.len(),
        model,
        cfg.mesh_cols,
        cfg.mesh_rows,
        cfg.topology.label(),
        cfg.pes_per_router,
        cfg.dataflow.label(),
        scenario.streaming().label(),
        scenario.collection().label()
    );
    println!("bottleneck attribution (per layer):");
    print!("{}", report::bottleneck_table_text(&analyzed));
    let degradation = report::degradation_table_text(&analyzed);
    if !degradation.is_empty() {
        println!();
        print!("{degradation}");
    }
    for l in &analyzed {
        println!();
        print!("{}", report::probe_heatmap_text(&l.name, &l.probes));
    }
    Ok(())
}

/// Assemble the serving knobs from the CLI flags; keyword and numeric
/// parses are typed errors, semantic validation happens in the serving
/// layer itself (and is re-checked per sweep point).
fn serving_cfg_from(args: &Args) -> Result<ServingConfig> {
    let mut cfg = ServingConfig::default();
    if let Some(k) = args.get("arrivals") {
        cfg.arrival = ArrivalKind::parse(k)?;
    }
    cfg.rate_per_mcycle = args.get_parsed("arrival-rate", cfg.rate_per_mcycle)?;
    cfg.clients = args.get_parsed("clients", cfg.clients)?;
    cfg.think_cycles = args.get_parsed("think", cfg.think_cycles)?;
    cfg.batch = args.get_parsed("batch", cfg.batch)?;
    cfg.batch_timeout = args.get_parsed("batch-timeout", cfg.batch_timeout)?;
    cfg.tenants = args.get_parsed("tenants", cfg.tenants)?;
    if let Some(s) = args.get("sched") {
        cfg.sched = SchedKind::parse(s)?;
    }
    cfg.queue_cap = args.get_parsed("queue-cap", cfg.queue_cap)?;
    cfg.max_inflight = args.get_parsed("max-inflight", cfg.max_inflight)?;
    cfg.duration = args.get_parsed("duration", cfg.duration)?;
    cfg.seed = args.get_parsed("seed", cfg.seed)?;
    Ok(cfg)
}

fn sweep_rates_from(spec: &str) -> Result<Vec<f64>> {
    let mut rates = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        let rate: f64 = part.parse().map_err(|_| {
            noc_dnn::config::ConfigError::invalid(
                "serving",
                format!("--sweep rate '{part}' is not a number"),
            )
        })?;
        if !rate.is_finite() || rate <= 0.0 {
            return Err(noc_dnn::config::ConfigError::invalid(
                "serving",
                format!("--sweep rate '{part}' must be positive and finite"),
            )
            .into());
        }
        rates.push(rate);
    }
    Ok(rates)
}

fn serve_cmd(args: &Args) -> Result<()> {
    // Validate every serving knob before paying for the profile run, so
    // a bad rate or batch spec fails in milliseconds.
    let scfg = serving_cfg_from(args)?;
    let rates = args.get("sweep").map(sweep_rates_from).transpose()?;
    match &rates {
        None => scfg.validate()?,
        Some(rates) => {
            anyhow::ensure!(
                scfg.arrival != ArrivalKind::ClosedLoop,
                "--sweep needs an open-loop arrival mode (a closed loop \
                 self-throttles and has no offered-rate axis)"
            );
            let mut probe = scfg.clone();
            probe.rate_per_mcycle = rates[0];
            probe.validate()?;
        }
    }

    // Profile the fabric once with the probes forced on (the `analyze`
    // convention): the serving report attributes which link saturates
    // first under load, so there is no probe-off variant to configure.
    let base = scenario_from(args)?;
    let mut cfg = base.config().clone();
    cfg.probes = true;
    let scenario = ScenarioBuilder::from_config(cfg).streaming(base.streaming()).build()?;
    let model = Network::by_name(args.get("model").unwrap_or("alexnet"))?;
    let plan = NetworkPlan::uniform(scenario.uniform_policy(), model.len());
    let run = scenario.execute(&model, &plan)?;
    let profile = ServiceProfile::from_run(&run);
    let cfg = scenario.config();

    if let Some(rates) = rates {
        let sw = serving::sweep(&profile, &scfg, &rates)?;
        if args.get_bool("json") {
            println!("{}", sw.to_json().to_pretty());
            return Ok(());
        }
        println!(
            "arrival-rate sweep: {} on {}x{}, n={}, batch<={} — serial-fabric \
             capacity ~{:.2} req/Mcycle",
            profile.model,
            cfg.mesh_cols,
            cfg.mesh_rows,
            cfg.pes_per_router,
            scfg.batch,
            profile.capacity_per_mcycle(scfg.batch as u64)
        );
        let rows: Vec<Vec<String>> = sw
            .points
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let r = &p.report;
                vec![
                    format!("{:.2}", p.rate),
                    r.offered.to_string(),
                    r.rejected.to_string(),
                    format!("{:.2}", r.throughput_per_mcycle),
                    r.p50().to_string(),
                    r.p99().to_string(),
                    format!("{:.1}%", r.utilization * 100.0),
                    if sw.knee == Some(i) { "<- knee".into() } else { String::new() },
                ]
            })
            .collect();
        print!(
            "{}",
            report::table(
                &["rate/Mcyc", "offered", "rejected", "tput/Mcyc", "p50", "p99", "busy", ""],
                &rows
            )
        );
        match sw.knee_rate() {
            Some(r) => println!(
                "saturation knee at ~{r:.2} req/Mcycle (last rate with zero \
                 rejections and p99 within {}x of the lowest rate's)",
                noc_dnn::serving::KNEE_BLOWUP
            ),
            None => println!("no pre-knee point: even the lowest swept rate saturates"),
        }
        if let Some(b) = profile.bottleneck() {
            println!(
                "link that saturates first: {} ({} stage, vc {}, util {:.2} in profile)",
                b.label(),
                b.stage.label(),
                b.vc,
                b.utilization
            );
        }
        return Ok(());
    }

    let rep = serving::serve(&profile, &scfg)?;
    if args.get_bool("json") {
        println!("{}", rep.to_json().to_pretty());
        return Ok(());
    }
    println!(
        "serving {} on {}x{} {} routers, n={}: {} arrivals, batch<={} \
         (timeout {} cyc), {} tenant(s) [{}], queue cap {}, max in-flight {}",
        rep.model,
        cfg.mesh_cols,
        cfg.mesh_rows,
        cfg.topology.label(),
        cfg.pes_per_router,
        scfg.arrival.key(),
        scfg.batch,
        rep.batch_timeout,
        scfg.tenants,
        scfg.sched.key(),
        scfg.queue_cap,
        scfg.max_inflight
    );
    println!(
        "offered {}  accepted {}  rejected {}  completed {}  batches {} (mean fill {:.2})",
        rep.offered, rep.accepted, rep.rejected, rep.completed, rep.batches, rep.mean_batch_fill
    );
    println!(
        "window {} cycles, drained at {} cycles; throughput {:.3} req/Mcycle, \
         fabric busy {:.1}%",
        rep.duration,
        rep.total_cycles,
        rep.throughput_per_mcycle,
        rep.utilization * 100.0
    );
    println!(
        "latency (cycles): p50 {}  p99 {}  p999 {}  mean {:.0}  max {}",
        rep.p50(),
        rep.p99(),
        rep.p999(),
        rep.latency.mean(),
        rep.latency.max()
    );
    println!(
        "queue depth: mean {:.2}  max {}",
        rep.queue_depth_mean, rep.queue_depth_max
    );
    if let Some(b) = &rep.bottleneck {
        println!(
            "saturates first under load: link {} ({} stage, vc {}, util {:.2} in profile)",
            b.label(),
            b.stage.label(),
            b.vc,
            b.utilization
        );
    }
    if let Some(d) = &rep.degraded {
        if !d.is_clean() {
            println!(
                "profiled on a degraded fabric: {} payloads dropped, {} \
                 retransmissions, {} detour hops",
                d.payloads_dropped, d.retransmissions, d.detour_hops
            );
        }
    }
    if rep.conservation_violations > 0 {
        println!(
            "WARNING: {} conservation violations (scheduler leaked requests)",
            rep.conservation_violations
        );
    }
    Ok(())
}

fn overhead(_args: &Args) -> Result<()> {
    let r = overhead_report(1.0e9);
    println!("§5.4 hardware overhead (45 nm, 1 GHz router, Table 1 config)");
    print!(
        "{}",
        report::table(
            &["", "baseline", "gather-supported", "overhead"],
            &[
                vec![
                    "power (mW)".into(),
                    format!("{:.2}", r.baseline_power_mw),
                    format!("{:.2}", r.proposed_power_mw),
                    format!("{:.1}%", r.power_overhead_pct),
                ],
                vec![
                    "area (µm²)".into(),
                    format!("{:.0}", r.baseline_area_um2),
                    format!("{:.0}", r.proposed_area_um2),
                    format!("{:.1}%", r.area_overhead_pct),
                ],
            ]
        )
    );
    Ok(())
}

fn config_cmd(args: &Args) -> Result<()> {
    let cfg = cfg_from(args)?;
    println!("{}", cfg.to_json());
    Ok(())
}
