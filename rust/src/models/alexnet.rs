//! AlexNet [4] convolution layers, torchvision shapes (the "one weird
//! trick" single-GPU variant the PyTorch model zoo ships: 64 conv1
//! filters). Pooling/FC layers generate negligible NoC collection traffic
//! relative to the conv stack and are not part of the paper's evaluation.

use super::ConvLayer;

/// The five convolution layers of torchvision AlexNet.
pub fn conv_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer { name: "conv1", c: 3, h_in: 224, r: 11, stride: 4, pad: 2, q: 64 },
        ConvLayer { name: "conv2", c: 64, h_in: 27, r: 5, stride: 1, pad: 2, q: 192 },
        ConvLayer { name: "conv3", c: 192, h_in: 13, r: 3, stride: 1, pad: 1, q: 384 },
        ConvLayer { name: "conv4", c: 384, h_in: 13, r: 3, stride: 1, pad: 1, q: 256 },
        ConvLayer { name: "conv5", c: 256, h_in: 13, r: 3, stride: 1, pad: 1, q: 256 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_conv_layers() {
        let ls = conv_layers();
        assert_eq!(ls.len(), 5);
        assert_eq!(ls[0].h_out(), 55);
        assert_eq!(ls[1].h_out(), 27);
        assert_eq!(ls[2].h_out(), 13);
        assert_eq!(ls[4].q, 256);
    }

    #[test]
    fn mac_count_order_of_magnitude() {
        // AlexNet convs are ~0.66 GMACs for the torchvision variant.
        let total: u64 = conv_layers().iter().map(|l| l.total_macs()).sum();
        assert!((500_000_000..1_500_000_000).contains(&total), "total={total}");
    }
}
