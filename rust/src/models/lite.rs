//! Downscaled layer shapes for the end-to-end *numeric* path.
//!
//! These mirror `python/compile/model.py::alexnet_lite_specs` /
//! `quickstart_spec` exactly — the artifact names are derived from the
//! shapes on both sides, so a mismatch fails loudly at load time.
//!
//! The NoC timing simulation always runs the full-size AlexNet/VGG-16
//! shapes (it consumes shape parameters, not tensors); the lite stack is
//! what the PJRT artifacts compute real activations for.

use super::ConvLayer;

/// The tiny layer used by `examples/quickstart.rs`.
pub fn quickstart_layer() -> ConvLayer {
    ConvLayer { name: "quickstart", c: 4, h_in: 8, r: 3, stride: 1, pad: 1, q: 8 }
}

/// Downscaled AlexNet conv stack (same topology, reduced H/C).
pub fn alexnet_lite() -> Vec<ConvLayer> {
    vec![
        ConvLayer { name: "lite1", c: 3, h_in: 32, r: 11, stride: 4, pad: 2, q: 16 },
        ConvLayer { name: "lite2", c: 16, h_in: 7, r: 5, stride: 1, pad: 2, q: 32 },
        ConvLayer { name: "lite3", c: 32, h_in: 7, r: 3, stride: 1, pad: 1, q: 64 },
        ConvLayer { name: "lite4", c: 64, h_in: 7, r: 3, stride: 1, pad: 1, q: 32 },
        ConvLayer { name: "lite5", c: 32, h_in: 7, r: 3, stride: 1, pad: 1, q: 32 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::layer_exec::artifact_name;

    #[test]
    fn artifact_names_match_python_side() {
        let q = quickstart_layer();
        assert_eq!(
            artifact_name(q.c, q.h_in, q.r, q.stride, q.pad, q.q),
            "conv_c4_h8_r3_s1_p1_q8.hlo.txt"
        );
        let lite = alexnet_lite();
        assert_eq!(
            artifact_name(lite[0].c, lite[0].h_in, lite[0].r, lite[0].stride, lite[0].pad, lite[0].q),
            "conv_c3_h32_r11_s4_p2_q16.hlo.txt"
        );
    }

    #[test]
    fn lite_stack_geometry_chains() {
        // lite1 output (7x7x16)... channel counts feed the next layer's C
        // only loosely (pooling omitted); geometry must at least be valid.
        for l in alexnet_lite() {
            assert!(l.h_out() >= 1, "{} collapsed", l.name);
        }
        assert_eq!(alexnet_lite()[0].h_out(), 7);
    }
}
