//! DNN workload shape tables.
//!
//! The paper's traces are generated from the PyTorch (torchvision)
//! definitions of AlexNet [4] and VGG-16 [5] (§5.1: "the parameters
//! obtained from Pytorch framework are used to model the traces for the
//! NoC"). The NoC traffic of a convolution layer is fully determined by
//! its shape, so these tables are the trace source.

pub mod alexnet;
pub mod lite;
pub mod resnet;
pub mod vgg16;

/// One convolutional layer, in the paper's notation:
/// `P` input patches of `C` channels convolved with `Q` filters of
/// `R × R × C` weights.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: &'static str,
    /// Input channels (C).
    pub c: usize,
    /// Input feature map height/width (square).
    pub h_in: usize,
    /// Kernel size (R).
    pub r: usize,
    pub stride: usize,
    pub pad: usize,
    /// Output channels / filters (Q).
    pub q: usize,
}

impl ConvLayer {
    /// Output feature-map side length.
    pub fn h_out(&self) -> usize {
        (self.h_in + 2 * self.pad - self.r) / self.stride + 1
    }

    /// Number of output positions (`P` in the paper: each output pixel is
    /// one input patch streamed to a PE row).
    pub fn p_patches(&self) -> u64 {
        let h = self.h_out() as u64;
        h * h
    }

    /// MACs per output element = `C·R·R` (the per-PE work of one round).
    pub fn macs_per_output(&self) -> u64 {
        (self.c * self.r * self.r) as u64
    }

    /// Total MACs in the layer.
    pub fn total_macs(&self) -> u64 {
        self.p_patches() * self.q as u64 * self.macs_per_output()
    }

    /// Total weights (no bias).
    pub fn weights(&self) -> u64 {
        (self.q * self.c * self.r * self.r) as u64
    }

    /// Output feature-map volume in words (`h_out² · Q`) — the traffic the
    /// layer hands to its successor in a whole-network run.
    pub fn output_volume(&self) -> u64 {
        self.p_patches() * self.q as u64
    }

    /// Input feature-map volume in words (`h_in² · C`).
    pub fn input_volume(&self) -> u64 {
        (self.h_in * self.h_in * self.c) as u64
    }
}

/// Per-layer metadata of a [`Network`]: position, name and the shape
/// aggregates the executor and reports key on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerInfo {
    pub index: usize,
    pub name: &'static str,
    /// Total MACs of the layer (`P·Q·C·R·R`).
    pub macs: u64,
    /// Output feature-map words (`h_out²·Q`) — the next layer's input
    /// traffic.
    pub output_volume: u64,
    /// Input feature-map words (`h_in²·C`).
    pub input_volume: u64,
}

/// A whole DNN as a first-class executable object: a named, ordered list
/// of convolution layers. This replaces the loose `&[ConvLayer]` tables —
/// the network executor ([`crate::coordinator::executor`]), the per-layer
/// policy plans ([`crate::plan`]) and the model-scope closed form
/// ([`crate::analytic::network_latency`]) all key on layer *positions*
/// within one `Network`, so the ordered type is what makes inter-layer
/// accounting (layer ℓ's output volume = layer ℓ+1's input traffic)
/// well-defined.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<ConvLayer>,
}

impl Network {
    /// A custom network. Panics on an empty layer list — a zero-layer
    /// model has no meaningful runtime.
    pub fn new(name: impl Into<String>, layers: Vec<ConvLayer>) -> Network {
        assert!(!layers.is_empty(), "a Network needs at least one layer");
        Network { name: name.into(), layers }
    }

    /// The five AlexNet convolution layers.
    pub fn alexnet() -> Network {
        Network::new("alexnet", alexnet::conv_layers())
    }

    /// The thirteen VGG-16 convolution layers.
    pub fn vgg16() -> Network {
        Network::new("vgg16", vgg16::conv_layers())
    }

    /// The ResNet-lite table (stride-2 and 1×1 downsample convolutions —
    /// shapes the AlexNet/VGG tables never exercise).
    pub fn resnet_lite() -> Network {
        Network::new("resnet-lite", resnet::conv_layers())
    }

    /// Look a model up by its CLI spelling.
    pub fn by_name(name: &str) -> crate::Result<Network> {
        match name {
            "alexnet" => Ok(Network::alexnet()),
            "vgg16" => Ok(Network::vgg16()),
            "resnet-lite" | "resnet_lite" | "resnet" => Ok(Network::resnet_lite()),
            m => anyhow::bail!("unknown model '{m}' (alexnet | vgg16 | resnet-lite)"),
        }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Words crossing the memory boundary ahead of layer `i`: the model
    /// input volume for layer 0, the **previous layer's output volume**
    /// otherwise. This is deliberately the producer's volume, not
    /// `layers[i].input_volume()` — §5.1 generates each feature map
    /// completely before the next layer starts, so the whole produced map
    /// drains to memory and is re-streamed at the boundary; pooling (and,
    /// in linearized tables like ResNet-lite, skipped branches) between
    /// the two shapes is not modeled, making this an upper-bound
    /// convention on the boundary traffic.
    pub fn input_words(&self, i: usize) -> u64 {
        if i == 0 {
            self.layers[0].input_volume()
        } else {
            self.layers[i - 1].output_volume()
        }
    }

    /// Per-layer metadata rows (name, index, MACs, volumes).
    pub fn layer_infos(&self) -> Vec<LayerInfo> {
        self.layers
            .iter()
            .enumerate()
            .map(|(index, l)| LayerInfo {
                index,
                name: l.name,
                macs: l.total_macs(),
                output_volume: l.output_volume(),
                input_volume: l.input_volume(),
            })
            .collect()
    }

    /// Total MACs across all layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(ConvLayer::total_macs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geometry() {
        // AlexNet conv1: 224x224x3, 64 filters 11x11, stride 4, pad 2 -> 55.
        let l = ConvLayer { name: "conv1", c: 3, h_in: 224, r: 11, stride: 4, pad: 2, q: 64 };
        assert_eq!(l.h_out(), 55);
        assert_eq!(l.p_patches(), 3025);
        assert_eq!(l.macs_per_output(), 363);
    }

    #[test]
    fn vgg_conv_keeps_resolution() {
        let l = ConvLayer { name: "c", c: 64, h_in: 224, r: 3, stride: 1, pad: 1, q: 64 };
        assert_eq!(l.h_out(), 224);
    }

    #[test]
    fn network_constructors_and_metadata() {
        let a = Network::alexnet();
        assert_eq!(a.name, "alexnet");
        assert_eq!(a.len(), 5);
        assert_eq!(Network::vgg16().len(), 13);
        assert_eq!(Network::by_name("resnet-lite").unwrap(), Network::resnet_lite());
        assert!(Network::by_name("lenet").is_err());

        let infos = a.layer_infos();
        assert_eq!(infos.len(), 5);
        assert_eq!(infos[0].name, "conv1");
        assert_eq!(infos[0].index, 0);
        assert_eq!(infos[0].macs, a.layers[0].total_macs());
        assert_eq!(a.total_macs(), infos.iter().map(|i| i.macs).sum::<u64>());
    }

    #[test]
    fn interlayer_traffic_is_the_predecessor_output_volume() {
        let a = Network::alexnet();
        // Layer 0 streams the model input; layer i>0 streams layer i-1's
        // output feature map.
        assert_eq!(a.input_words(0), (224 * 224 * 3) as u64);
        assert_eq!(a.input_words(1), a.layers[0].output_volume());
        assert_eq!(a.layers[0].output_volume(), 55 * 55 * 64);
        assert_eq!(a.input_words(4), a.layers[3].output_volume());
    }
}
