//! DNN workload shape tables.
//!
//! The paper's traces are generated from the PyTorch (torchvision)
//! definitions of AlexNet [4] and VGG-16 [5] (§5.1: "the parameters
//! obtained from Pytorch framework are used to model the traces for the
//! NoC"). The NoC traffic of a convolution layer is fully determined by
//! its shape, so these tables are the trace source.

pub mod alexnet;
pub mod lite;
pub mod vgg16;

/// One convolutional layer, in the paper's notation:
/// `P` input patches of `C` channels convolved with `Q` filters of
/// `R × R × C` weights.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: &'static str,
    /// Input channels (C).
    pub c: usize,
    /// Input feature map height/width (square).
    pub h_in: usize,
    /// Kernel size (R).
    pub r: usize,
    pub stride: usize,
    pub pad: usize,
    /// Output channels / filters (Q).
    pub q: usize,
}

impl ConvLayer {
    /// Output feature-map side length.
    pub fn h_out(&self) -> usize {
        (self.h_in + 2 * self.pad - self.r) / self.stride + 1
    }

    /// Number of output positions (`P` in the paper: each output pixel is
    /// one input patch streamed to a PE row).
    pub fn p_patches(&self) -> u64 {
        let h = self.h_out() as u64;
        h * h
    }

    /// MACs per output element = `C·R·R` (the per-PE work of one round).
    pub fn macs_per_output(&self) -> u64 {
        (self.c * self.r * self.r) as u64
    }

    /// Total MACs in the layer.
    pub fn total_macs(&self) -> u64 {
        self.p_patches() * self.q as u64 * self.macs_per_output()
    }

    /// Total weights (no bias).
    pub fn weights(&self) -> u64 {
        (self.q * self.c * self.r * self.r) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geometry() {
        // AlexNet conv1: 224x224x3, 64 filters 11x11, stride 4, pad 2 -> 55.
        let l = ConvLayer { name: "conv1", c: 3, h_in: 224, r: 11, stride: 4, pad: 2, q: 64 };
        assert_eq!(l.h_out(), 55);
        assert_eq!(l.p_patches(), 3025);
        assert_eq!(l.macs_per_output(), 363);
    }

    #[test]
    fn vgg_conv_keeps_resolution() {
        let l = ConvLayer { name: "c", c: 64, h_in: 224, r: 3, stride: 1, pad: 1, q: 64 };
        assert_eq!(l.h_out(), 224);
    }
}
