//! ResNet-lite convolution layers: a per-stage representative slice of
//! ResNet-18 [He et al. 2016, torchvision shapes].
//!
//! The table exists to exercise mapping/traffic paths the AlexNet and
//! VGG-16 tables never hit: the stride-2 7×7 stem, the **1×1 downsample
//! convolutions** (`R = 1`, stride 2 — one MAC-row per output, so the
//! gather payload-to-compute ratio is extreme) and stride-2 3×3
//! convolutions at stage boundaries. One basic-block pair per stage keeps
//! whole-model runs cheap while covering every distinct shape class.

use super::ConvLayer;

/// The eleven ResNet-lite convolution layers.
pub fn conv_layers() -> Vec<ConvLayer> {
    vec![
        // Stem: 7×7 stride-2 (the only R=7 shape in the repo's tables).
        ConvLayer { name: "conv1", c: 3, h_in: 224, r: 7, stride: 2, pad: 3, q: 64 },
        // Stage 2 (post-maxpool resolution 56): one basic block.
        ConvLayer { name: "conv2_1", c: 64, h_in: 56, r: 3, stride: 1, pad: 1, q: 64 },
        ConvLayer { name: "conv2_2", c: 64, h_in: 56, r: 3, stride: 1, pad: 1, q: 64 },
        // Stage 3 entry: 1×1 stride-2 projection shortcut + strided block.
        ConvLayer { name: "conv3_ds", c: 64, h_in: 56, r: 1, stride: 2, pad: 0, q: 128 },
        ConvLayer { name: "conv3_1", c: 64, h_in: 56, r: 3, stride: 2, pad: 1, q: 128 },
        ConvLayer { name: "conv3_2", c: 128, h_in: 28, r: 3, stride: 1, pad: 1, q: 128 },
        // Stage 4.
        ConvLayer { name: "conv4_ds", c: 128, h_in: 28, r: 1, stride: 2, pad: 0, q: 256 },
        ConvLayer { name: "conv4_1", c: 128, h_in: 28, r: 3, stride: 2, pad: 1, q: 256 },
        ConvLayer { name: "conv4_2", c: 256, h_in: 14, r: 3, stride: 1, pad: 1, q: 256 },
        // Stage 5.
        ConvLayer { name: "conv5_1", c: 256, h_in: 14, r: 3, stride: 2, pad: 1, q: 512 },
        ConvLayer { name: "conv5_2", c: 512, h_in: 7, r: 3, stride: 1, pad: 1, q: 512 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_of_the_stride_and_pointwise_shapes() {
        let ls = conv_layers();
        assert_eq!(ls.len(), 11);
        // Stem: (224 + 6 - 7)/2 + 1 = 112.
        assert_eq!(ls[0].h_out(), 112);
        // 1×1 stride-2 downsample: (56 - 1)/2 + 1 = 28, MACs/output = C.
        let ds = ls.iter().find(|l| l.name == "conv3_ds").unwrap();
        assert_eq!(ds.r, 1);
        assert_eq!(ds.h_out(), 28);
        assert_eq!(ds.macs_per_output(), 64);
        // Strided 3×3: (56 + 2 - 3)/2 + 1 = 28.
        let s2 = ls.iter().find(|l| l.name == "conv3_1").unwrap();
        assert_eq!(s2.h_out(), 28);
        // Downsample and strided conv of one stage agree on the output map.
        assert_eq!(ds.h_out(), s2.h_out());
        assert_eq!(ds.q, s2.q);
    }

    #[test]
    fn table_covers_shape_classes_absent_from_alexnet_and_vgg() {
        let ls = conv_layers();
        assert!(ls.iter().any(|l| l.r == 1), "needs a 1x1 conv");
        assert!(ls.iter().filter(|l| l.stride == 2).count() >= 4, "needs stride-2 shapes");
        assert!(ls.iter().any(|l| l.r == 7), "needs the 7x7 stem");
    }

    #[test]
    fn mac_count_order_of_magnitude() {
        // The per-stage slice of ResNet-18 lands at roughly half the full
        // model's ~1.8 GMACs.
        let total: u64 = conv_layers().iter().map(|l| l.total_macs()).sum();
        assert!((500_000_000..2_500_000_000).contains(&total), "total={total}");
    }
}
