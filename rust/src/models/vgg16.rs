//! VGG-16 [5] convolution layers (torchvision configuration "D": thirteen
//! 3×3 convolutions, resolution halved after each pooling block).

use super::ConvLayer;

/// The thirteen convolution layers of VGG-16.
pub fn conv_layers() -> Vec<ConvLayer> {
    let mk = |name, c, h_in, q| ConvLayer { name, c, h_in, r: 3, stride: 1, pad: 1, q };
    vec![
        mk("conv1_1", 3, 224, 64),
        mk("conv1_2", 64, 224, 64),
        mk("conv2_1", 64, 112, 128),
        mk("conv2_2", 128, 112, 128),
        mk("conv3_1", 128, 56, 256),
        mk("conv3_2", 256, 56, 256),
        mk("conv3_3", 256, 56, 256),
        mk("conv4_1", 256, 28, 512),
        mk("conv4_2", 512, 28, 512),
        mk("conv4_3", 512, 28, 512),
        mk("conv5_1", 512, 14, 512),
        mk("conv5_2", 512, 14, 512),
        mk("conv5_3", 512, 14, 512),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_layers_with_halving_resolution() {
        let ls = conv_layers();
        assert_eq!(ls.len(), 13);
        for l in &ls {
            assert_eq!(l.h_out(), l.h_in, "3x3 s1 p1 preserves resolution");
        }
        assert_eq!(ls[2].h_in, 112);
        assert_eq!(ls[12].h_in, 14);
    }

    #[test]
    fn mac_count_matches_published_vgg16() {
        // VGG-16 convolutions are ~15.3 GMACs (paper Fig. 1: 15.5G incl. FC).
        let total: u64 = conv_layers().iter().map(|l| l.total_macs()).sum();
        assert!((14_000_000_000..16_000_000_000).contains(&total), "total={total}");
    }
}
