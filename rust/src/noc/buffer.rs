//! Virtual-channel input buffers with credit accounting.
//!
//! Each router input port owns `vcs` FIFO buffers of `buffer_depth` flits.
//! Flow control is credit-based (§4.4 / [34]): the upstream router holds one
//! credit per free downstream slot and may only forward a flit into a VC for
//! which it holds a credit.

use super::flit::Flit;
use std::collections::VecDeque;

/// Per-VC state machine. A VC is idle until a head flit allocates it; it
/// stays bound to that packet until the tail flit departs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VcState {
    Idle,
    /// Head flit buffered; route computation pending/complete but no output
    /// VC granted yet. Holds (cycle at which VA may complete).
    Routing { sa_ready_cycle: u64 },
    /// Output VC granted: (output port index, output vc); flits may compete
    /// in switch allocation.
    Active { out_port: usize, out_vc: usize },
}

/// One virtual-channel FIFO, generic over the flit representation: the
/// frozen reference kernel buffers the wide [`Flit`] (the default, so its
/// code names `VcBuffer` unchanged), the event kernel buffers
/// [`crate::noc::flit::CompactFlit`].
#[derive(Debug)]
pub struct VcBuffer<F = Flit> {
    fifo: VecDeque<F>,
    depth: usize,
    pub state: VcState,
}

impl<F> VcBuffer<F> {
    pub fn new(depth: usize) -> Self {
        VcBuffer { fifo: VecDeque::with_capacity(depth), depth, state: VcState::Idle }
    }

    pub fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }

    pub fn len(&self) -> usize {
        self.fifo.len()
    }

    pub fn has_space(&self) -> bool {
        self.fifo.len() < self.depth
    }

    /// Push an arriving flit. Panics on overflow — credits must make this
    /// impossible; an overflow is a flow-control bug, not a runtime
    /// condition.
    pub fn push(&mut self, flit: F) {
        assert!(self.has_space(), "VC buffer overflow: credit protocol violated");
        self.fifo.push_back(flit);
    }

    pub fn front(&self) -> Option<&F> {
        self.fifo.front()
    }

    pub fn front_mut(&mut self) -> Option<&mut F> {
        self.fifo.front_mut()
    }

    pub fn pop(&mut self) -> Option<F> {
        self.fifo.pop_front()
    }

    /// Flit at position `i` from the front (0 = front), if buffered.
    pub fn get(&self, i: usize) -> Option<&F> {
        self.fifo.get(i)
    }

    /// Iterate the buffered flits, front to back.
    pub fn iter(&self) -> impl Iterator<Item = &F> {
        self.fifo.iter()
    }
}

/// Credit counters the upstream side keeps for one downstream input port:
/// `credits[vc]` = free slots in the downstream VC buffer.
#[derive(Debug, Clone)]
pub struct CreditTracker {
    credits: Vec<u32>,
}

impl CreditTracker {
    pub fn new(vcs: usize, depth: usize) -> Self {
        CreditTracker { credits: vec![depth as u32; vcs] }
    }

    pub fn available(&self, vc: usize) -> bool {
        self.credits[vc] > 0
    }

    pub fn consume(&mut self, vc: usize) {
        assert!(self.credits[vc] > 0, "consumed a credit we do not hold");
        self.credits[vc] -= 1;
    }

    pub fn refund(&mut self, vc: usize, depth: usize) {
        self.credits[vc] += 1;
        assert!(
            self.credits[vc] <= depth as u32,
            "credit refund exceeded buffer depth: protocol violated"
        );
    }

    pub fn count(&self, vc: usize) -> u32 {
        self.credits[vc]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::flit::{Coord, PacketDesc, PacketType};

    fn flit(seq: u32) -> Flit {
        PacketDesc {
            id: 1,
            ptype: PacketType::Unicast,
            src: Coord::new(0, 0),
            dst: Coord::new(3, 0),
            len_flits: 4,
            aspace: 0,
            space: 0,
            inject_cycle: 0,
            deliver_along_path: false,
            carried_payloads: 0,
        }
        .flit(seq)
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = VcBuffer::new(4);
        for i in 0..4 {
            b.push(flit(i));
        }
        assert!(!b.has_space());
        for i in 0..4 {
            assert_eq!(b.pop().unwrap().seq, i);
        }
        assert!(b.is_empty());
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut b = VcBuffer::new(2);
        b.push(flit(0));
        b.push(flit(1));
        b.push(flit(2));
    }

    #[test]
    fn credit_lifecycle() {
        let mut c = CreditTracker::new(2, 4);
        assert!(c.available(0));
        for _ in 0..4 {
            c.consume(0);
        }
        assert!(!c.available(0));
        assert!(c.available(1));
        c.refund(0, 4);
        assert!(c.available(0));
        assert_eq!(c.count(0), 1);
    }

    #[test]
    #[should_panic(expected = "credit refund exceeded")]
    fn over_refund_panics() {
        let mut c = CreditTracker::new(1, 4);
        c.refund(0, 4);
    }
}
