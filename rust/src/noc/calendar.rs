//! Calendar-queue event schedule for the cycle kernel.
//!
//! [`Calendar`] replaces the `BTreeMap<u64, Vec<_>>` schedules the network
//! used for NI result posts and operand-stream injections. The common
//! operations of the cycle loop — "is anything due this cycle?" and "pop
//! everything due" — are O(1) per cycle here, where the tree paid a root
//! descent per query (twice per calendar per cycle, every cycle).
//!
//! ## Layout
//!
//! A wheel of `WHEEL_SLOTS` (power of two) `Vec` buckets covers the
//! cycle window `[epoch, epoch + WHEEL_SLOTS)`; an entry scheduled for
//! cycle `c` inside the window lives in slot `c & (WHEEL_SLOTS-1)`, so
//! each slot holds exactly one cycle's entries and a drain never sorts.
//! Entries beyond the window go to an unordered *spillover* list with a
//! cached minimum; when the wheel advances past its window the spillover
//! is migrated (stably, so within-cycle FIFO order is preserved) into the
//! fresh window. Every entry is touched O(1) amortized times: one push,
//! at most one migration, one drain.
//!
//! ## Fast-forward
//!
//! [`Calendar::drain_up_to`] hops over empty stretches without walking
//! them: when the current window holds nothing, the wheel teleports to the
//! earliest spilled entry (or straight past the target cycle), so a
//! quiescent-network clock jump costs O(slots) in the worst case and O(1)
//! when the schedule is empty — never O(jump length).
//!
//! Slot `Vec`s keep their capacity across reuse, so a steady-state
//! simulation stops allocating here after warm-up.

/// Wheel width in cycles. Must be a power of two. 512 covers every
/// near-term schedule the round drivers produce (posts land within one
/// round period of "now"); longer horizons ride the spillover.
const WHEEL_SLOTS: usize = 512;

/// A monotone schedule of `(cycle, item)` entries with FIFO order within
/// a cycle. Cycles may only be drained in non-decreasing order.
#[derive(Debug)]
pub struct Calendar<T> {
    /// `wheel[c & mask]` holds the entries for cycle `c` when
    /// `epoch <= c < epoch + WHEEL_SLOTS`.
    wheel: Vec<Vec<(u64, T)>>,
    mask: u64,
    /// First undrained cycle; every stored entry is scheduled `>= base`.
    base: u64,
    /// Window start (aligned to `WHEEL_SLOTS`), `epoch <= base`.
    epoch: u64,
    /// Entries scheduled at or beyond `epoch + WHEEL_SLOTS`, unordered.
    spill: Vec<(u64, T)>,
    /// Cached minimum cycle in `spill` (`u64::MAX` when empty).
    spill_min: u64,
    /// Entries currently in the wheel.
    in_wheel: usize,
}

impl<T> Calendar<T> {
    pub fn new() -> Calendar<T> {
        Calendar {
            wheel: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            mask: WHEEL_SLOTS as u64 - 1,
            base: 0,
            epoch: 0,
            spill: Vec::new(),
            spill_min: u64::MAX,
            in_wheel: 0,
        }
    }

    #[inline]
    fn horizon(&self) -> u64 {
        self.epoch + WHEEL_SLOTS as u64
    }

    pub fn len(&self) -> usize {
        self.in_wheel + self.spill.len()
    }

    pub fn is_empty(&self) -> bool {
        self.in_wheel == 0 && self.spill.is_empty()
    }

    /// Schedule `item` for `cycle`. Scheduling into the drained past is a
    /// protocol error; release builds clamp it to the next drain instead
    /// of corrupting the window invariant.
    pub fn push(&mut self, cycle: u64, item: T) {
        debug_assert!(cycle >= self.base, "calendar push into the drained past");
        let cycle = cycle.max(self.base);
        if cycle < self.horizon() {
            self.wheel[(cycle & self.mask) as usize].push((cycle, item));
            self.in_wheel += 1;
        } else {
            self.spill_min = self.spill_min.min(cycle);
            self.spill.push((cycle, item));
        }
    }

    /// Smallest scheduled cycle, if any.
    pub fn next_cycle(&self) -> Option<u64> {
        if self.is_empty() {
            return None;
        }
        let mut best = self.spill_min;
        if self.in_wheel > 0 {
            for c in self.base..self.horizon() {
                if !self.wheel[(c & self.mask) as usize].is_empty() {
                    best = best.min(c);
                    break;
                }
            }
        }
        Some(best)
    }

    /// Append every entry scheduled at or before `cycle` to `out` — in
    /// ascending cycle order, FIFO within a cycle — and advance the
    /// schedule past `cycle`.
    pub fn drain_up_to(&mut self, cycle: u64, out: &mut Vec<T>) {
        if self.base > cycle {
            return;
        }
        while self.base <= cycle {
            if self.in_wheel == 0 {
                if self.spill_min <= cycle {
                    // Hop the window straight to the earliest spilled
                    // entry; migration files it into the fresh wheel.
                    let target = self.spill_min;
                    self.jump_to(target);
                } else {
                    self.jump_to(cycle + 1);
                    return;
                }
            }
            // Walk the populated window up to `cycle`.
            let stop = cycle.min(self.horizon() - 1);
            let mut c = self.base;
            while c <= stop {
                let slot = &mut self.wheel[(c & self.mask) as usize];
                if !slot.is_empty() {
                    self.in_wheel -= slot.len();
                    out.extend(slot.drain(..).map(|(_, item)| item));
                }
                c += 1;
                if self.in_wheel == 0 {
                    break;
                }
            }
            self.base = c;
        }
    }

    /// Teleport the (empty) wheel so its window starts at or before
    /// `cycle`, and file any newly-covered spillover entries.
    fn jump_to(&mut self, cycle: u64) {
        debug_assert_eq!(self.in_wheel, 0, "calendar jump over live wheel entries");
        self.base = cycle;
        self.epoch = cycle & !self.mask;
        if self.spill_min < self.horizon() {
            self.migrate_spill();
        }
    }

    /// Stable partition of the spillover: entries now inside the window
    /// move to their wheel slot (in insertion order, ahead of any future
    /// direct pushes for the same cycle — FIFO is preserved end to end).
    fn migrate_spill(&mut self) {
        let horizon = self.horizon();
        let mut new_min = u64::MAX;
        let spill = std::mem::take(&mut self.spill);
        for (c, item) in spill {
            if c < horizon {
                debug_assert!(c >= self.base, "spill entry behind the drain point");
                self.wheel[(c & self.mask) as usize].push((c, item));
                self.in_wheel += 1;
            } else {
                new_min = new_min.min(c);
                self.spill.push((c, item));
            }
        }
        self.spill_min = new_min;
    }

    /// Iterate every scheduled entry (arbitrary order — bookkeeping
    /// sums such as `payloads_in_flight`, not drain order).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.wheel
            .iter()
            .flat_map(|s| s.iter())
            .chain(self.spill.iter())
            .map(|(_, item)| item)
    }
}

impl<T> Default for Calendar<T> {
    fn default() -> Self {
        Calendar::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(c: &mut Calendar<u32>, cycle: u64) -> Vec<u32> {
        let mut out = Vec::new();
        c.drain_up_to(cycle, &mut out);
        out
    }

    #[test]
    fn drains_in_cycle_then_fifo_order() {
        let mut c = Calendar::new();
        c.push(5, 50);
        c.push(3, 30);
        c.push(5, 51);
        c.push(3, 31);
        assert_eq!(c.next_cycle(), Some(3));
        assert_eq!(drain(&mut c, 4), vec![30, 31]);
        assert_eq!(drain(&mut c, 4), Vec::<u32>::new());
        assert_eq!(drain(&mut c, 5), vec![50, 51]);
        assert!(c.is_empty());
        assert_eq!(c.next_cycle(), None);
    }

    #[test]
    fn spillover_entries_survive_window_hops() {
        let mut c = Calendar::new();
        let far = 10 * WHEEL_SLOTS as u64 + 17;
        c.push(far, 1);
        c.push(far, 2);
        c.push(2, 0);
        assert_eq!(c.len(), 3);
        assert_eq!(c.next_cycle(), Some(2));
        assert_eq!(drain(&mut c, 2), vec![0]);
        assert_eq!(c.next_cycle(), Some(far));
        // Jump straight over the empty stretch.
        assert_eq!(drain(&mut c, far), vec![1, 2]);
        assert!(c.is_empty());
    }

    #[test]
    fn migration_preserves_within_cycle_fifo() {
        let mut c = Calendar::new();
        let target = WHEEL_SLOTS as u64 + 9; // beyond the initial window
        c.push(target, 1); // spilled
        c.push(target, 2); // spilled
        // Advance the window past the first epoch so the spill migrates.
        c.push(1, 0);
        assert_eq!(drain(&mut c, WHEEL_SLOTS as u64), vec![0]);
        // Post-migration push for the same cycle lands behind.
        c.push(target, 3);
        assert_eq!(drain(&mut c, target), vec![1, 2, 3]);
    }

    #[test]
    fn interleaved_push_and_drain_matches_a_btreemap_model() {
        use std::collections::BTreeMap;
        let mut cal = Calendar::new();
        let mut model: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        // Deterministic pseudo-random schedule exercising hops, spills
        // and window rolls.
        let mut x: u64 = 0x9E3779B97F4A7C15;
        let mut now = 0u64;
        let mut seq = 0u32;
        for step in 0..2_000u64 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            // The scheduling contract mirrors the network's: entries are
            // only ever pushed at or after the drain point (`now + 1`,
            // since everything <= now is already drained).
            let at = now + 1 + (x % (3 * WHEEL_SLOTS as u64));
            cal.push(at, seq);
            model.entry(at).or_default().push(seq);
            seq += 1;
            if step % 3 == 0 {
                now += x % 97;
                let mut got = Vec::new();
                cal.drain_up_to(now, &mut got);
                let mut want = Vec::new();
                while let Some((&c, _)) = model.iter().next() {
                    if c > now {
                        break;
                    }
                    want.extend(model.remove(&c).unwrap());
                }
                assert_eq!(got, want, "diverged at step {step} (now={now})");
            }
        }
        assert_eq!(cal.len(), model.values().map(Vec::len).sum::<usize>());
    }

    #[test]
    fn iter_visits_every_scheduled_entry() {
        let mut c = Calendar::new();
        c.push(1, 10);
        c.push(700_000, 20);
        c.push(3, 30);
        let mut all: Vec<u32> = c.iter().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![10, 20, 30]);
    }
}
