//! Deterministic fault injection: link/router faults, flit corruption,
//! and the retransmission machinery that keeps the kernel's conservation
//! invariants intact while packets are being damaged.
//!
//! The subsystem is **off by default** ([`crate::config::SimConfig::faults`]
//! is `None`) and, like the probe layer, strictly additive: with no plan
//! configured the kernel takes none of these paths and stays bit-identical
//! to the fault-free simulator. With a plan configured every decision —
//! which links fail, which flits corrupt, how long a retry holds off — is
//! a pure function of the plan seed and the flit's identity, so two runs
//! (at any `intra_workers` count) agree bit for bit.
//!
//! Three layers:
//!
//! * [`FaultsConfig`] — the user-facing, validated description (spec
//!   string / JSON / builder), stored on `SimConfig`.
//! * [`FaultPlan`] — the compiled form: dense link/router masks, sorted
//!   transient windows keyed by receiver-side link id, and (when any
//!   topology fault exists) BFS next-hop tables that route *around* the
//!   fault region, falling back to the fabric's own deterministic route
//!   whenever that route is still minimal over the healthy subgraph.
//! * [`FaultState`] — mutable runtime state owned by the network: the
//!   per-link retransmission queues, the poison set of packets being
//!   dropped, and the degradation counters that feed
//!   [`DegradationReport`].
//!
//! Corruption is detected at the *delivery point* (the arrival side of a
//! link), which is sequential in both kernels: the corrupted flit is held
//! in the sender-modelled retransmission slot (keeping the downstream
//! buffer credit it already consumed, so replay can never overflow the
//! buffer) and replayed after an exponential hold-off. Head flits carry
//! the retry budget: a head that exhausts it poisons its packet, and every
//! other flit of that packet is dropped — with its credit refunded — at
//! whatever link it next arrives on. Wormhole order makes this safe: the
//! head crosses every link first, so nothing of the packet exists beyond
//! the failing link.

use std::collections::VecDeque;

use crate::config::ConfigError;
use crate::util::json::Json;

use super::flit::{CompactFlit, Coord, PacketType};
use super::routing::Port;
use super::topology::Topology;

/// Router ports that carry inter-router links (everything but `Local`).
const LINK_PORTS: [Port; 4] = [Port::North, Port::South, Port::East, Port::West];
const PORTS: usize = Port::COUNT;

// ---------------------------------------------------------------------------
// User-facing configuration
// ---------------------------------------------------------------------------

/// A transient link fault: the directed link out of `(x, y)` through
/// `port` is down for cycles `start..end` (arrivals in the window are held
/// at the receiver and replayed at `end`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransientFault {
    pub x: u16,
    pub y: u16,
    pub port: Port,
    pub start: u64,
    pub end: u64,
}

/// Declarative fault schedule, attached to
/// [`crate::config::SimConfig::faults`]. Parsed from a compact spec string
/// (CLI `--faults`) or a JSON object, validated against the topology by
/// [`crate::config::SimConfig::validate`].
///
/// Spec grammar — comma-separated `key=value` pairs:
///
/// ```text
/// seed=7,rate=0.02,links=3:2:E;4:4:N,routers=5:5,
/// transient=1:1:E:100:400,corrupt=0.001,retries=4,holdoff=8
/// ```
///
/// `rate` draws permanent directed-link faults Bernoulli(`rate`) per link
/// from `seed`; `links`/`routers` add explicit permanent faults;
/// `transient` (repeatable via `;`) adds windows; `corrupt` is the
/// per-flit per-link-traversal corruption probability; `retries` bounds
/// head-flit replays; `holdoff` is the base replay delay (doubled per
/// attempt).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsConfig {
    /// Seed for the random link draw and the corruption hash.
    pub seed: u64,
    /// Permanent directed-link fault probability, `[0, 1)`.
    pub link_rate: f64,
    /// Explicit permanent directed link faults (sender coord, out port).
    pub links: Vec<(u16, u16, Port)>,
    /// Routers that are hard-down from cycle 0.
    pub routers: Vec<(u16, u16)>,
    /// Transient link-down windows.
    pub transients: Vec<TransientFault>,
    /// Per-flit corruption probability per link traversal, `[0, 1)`.
    pub corrupt: f64,
    /// Replay budget for a head flit before its packet is dropped (≥ 1).
    pub retries: u32,
    /// Base hold-off in cycles before a corrupted flit replays.
    pub holdoff: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            seed: 1,
            link_rate: 0.0,
            links: Vec::new(),
            routers: Vec::new(),
            transients: Vec::new(),
            corrupt: 0.0,
            retries: 3,
            holdoff: 4,
        }
    }
}

const WHAT: &str = "faults";

fn parse_port(s: &str) -> Result<Port, ConfigError> {
    match s {
        "N" | "n" => Ok(Port::North),
        "S" | "s" => Ok(Port::South),
        "E" | "e" => Ok(Port::East),
        "W" | "w" => Ok(Port::West),
        other => Err(ConfigError::UnknownKeyword {
            what: "fault link direction",
            got: other.to_string(),
            expected: "N | S | E | W",
        }),
    }
}

fn parse_num<T: std::str::FromStr>(s: &str, field: &str) -> Result<T, ConfigError> {
    s.parse::<T>()
        .map_err(|_| ConfigError::invalid(WHAT, format!("{field}: cannot parse '{s}'")))
}

fn parse_coord(s: &str, field: &str) -> Result<(u16, u16), ConfigError> {
    let mut it = s.split(':');
    let x = parse_num(it.next().unwrap_or(""), field)?;
    let y = parse_num(
        it.next().ok_or_else(|| ConfigError::invalid(WHAT, format!("{field}: expected x:y, got '{s}'")))?,
        field,
    )?;
    if it.next().is_some() {
        return Err(ConfigError::invalid(WHAT, format!("{field}: expected x:y, got '{s}'")));
    }
    Ok((x, y))
}

fn parse_link(s: &str) -> Result<(u16, u16, Port), ConfigError> {
    let parts: Vec<&str> = s.split(':').collect();
    if parts.len() != 3 {
        return Err(ConfigError::invalid(WHAT, format!("links: expected x:y:dir, got '{s}'")));
    }
    Ok((parse_num(parts[0], "links")?, parse_num(parts[1], "links")?, parse_port(parts[2])?))
}

fn parse_transient(s: &str) -> Result<TransientFault, ConfigError> {
    let parts: Vec<&str> = s.split(':').collect();
    if parts.len() != 5 {
        return Err(ConfigError::invalid(
            WHAT,
            format!("transient: expected x:y:dir:start:end, got '{s}'"),
        ));
    }
    Ok(TransientFault {
        x: parse_num(parts[0], "transient")?,
        y: parse_num(parts[1], "transient")?,
        port: parse_port(parts[2])?,
        start: parse_num(parts[3], "transient")?,
        end: parse_num(parts[4], "transient")?,
    })
}

impl FaultsConfig {
    /// Parse the compact `key=value,...` spec string (the CLI form).
    pub fn parse(spec: &str) -> Result<FaultsConfig, ConfigError> {
        let mut f = FaultsConfig::default();
        for pair in spec.split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let (key, val) = pair.split_once('=').ok_or_else(|| {
                ConfigError::invalid(WHAT, format!("expected key=value, got '{pair}'"))
            })?;
            match key {
                "seed" => f.seed = parse_num(val, "seed")?,
                "rate" => f.link_rate = parse_num(val, "rate")?,
                "corrupt" => f.corrupt = parse_num(val, "corrupt")?,
                "retries" => f.retries = parse_num(val, "retries")?,
                "holdoff" => f.holdoff = parse_num(val, "holdoff")?,
                "links" => {
                    for item in val.split(';').filter(|s| !s.is_empty()) {
                        f.links.push(parse_link(item)?);
                    }
                }
                "routers" => {
                    for item in val.split(';').filter(|s| !s.is_empty()) {
                        f.routers.push(parse_coord(item, "routers")?);
                    }
                }
                "transient" => {
                    for item in val.split(';').filter(|s| !s.is_empty()) {
                        f.transients.push(parse_transient(item)?);
                    }
                }
                other => {
                    return Err(ConfigError::UnknownKeyword {
                        what: "faults key",
                        got: other.to_string(),
                        expected: "seed | rate | links | routers | transient | corrupt | retries | holdoff",
                    })
                }
            }
        }
        Ok(f)
    }

    /// Parse the JSON object form (`--faults plan.json`); field names
    /// mirror the spec keys, with `links`/`routers`/`transients` as
    /// arrays of the same `:`-separated fragments.
    pub fn from_json(j: &Json) -> Result<FaultsConfig, ConfigError> {
        let bad = |reason: String| ConfigError::Json { what: "faults", reason };
        if !matches!(j, Json::Obj(_)) {
            return Err(bad("expected an object".into()));
        }
        let mut f = FaultsConfig::default();
        if let Some(v) = j.get("seed") {
            f.seed = v.as_u64().ok_or_else(|| bad("seed must be a number".into()))?;
        }
        if let Some(v) = j.get("rate") {
            f.link_rate = v.as_f64().ok_or_else(|| bad("rate must be a number".into()))?;
        }
        if let Some(v) = j.get("corrupt") {
            f.corrupt = v.as_f64().ok_or_else(|| bad("corrupt must be a number".into()))?;
        }
        if let Some(v) = j.get("retries") {
            f.retries = v.as_u64().ok_or_else(|| bad("retries must be a number".into()))? as u32;
        }
        if let Some(v) = j.get("holdoff") {
            f.holdoff = v.as_u64().ok_or_else(|| bad("holdoff must be a number".into()))?;
        }
        let strs = |v: &Json, field: &str| -> Result<Vec<String>, ConfigError> {
            v.as_arr()
                .ok_or_else(|| bad(format!("{field} must be an array of strings")))?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| bad(format!("{field} must be an array of strings")))
                })
                .collect()
        };
        if let Some(v) = j.get("links") {
            for s in strs(v, "links")? {
                f.links.push(parse_link(&s)?);
            }
        }
        if let Some(v) = j.get("routers") {
            for s in strs(v, "routers")? {
                f.routers.push(parse_coord(&s, "routers")?);
            }
        }
        if let Some(v) = j.get("transients") {
            for s in strs(v, "transients")? {
                f.transients.push(parse_transient(&s)?);
            }
        }
        Ok(f)
    }

    /// Serialize back to the JSON object form (round-trips `from_json`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seed", Json::Num(self.seed as f64))
            .set("rate", Json::Num(self.link_rate))
            .set("corrupt", Json::Num(self.corrupt))
            .set("retries", Json::Num(self.retries as f64))
            .set("holdoff", Json::Num(self.holdoff as f64))
            .set(
                "links",
                Json::Arr(
                    self.links
                        .iter()
                        .map(|&(x, y, p)| Json::Str(format!("{x}:{y}:{}", port_letter(p))))
                        .collect(),
                ),
            )
            .set(
                "routers",
                Json::Arr(self.routers.iter().map(|&(x, y)| Json::Str(format!("{x}:{y}"))).collect()),
            )
            .set(
                "transients",
                Json::Arr(
                    self.transients
                        .iter()
                        .map(|t| {
                            Json::Str(format!(
                                "{}:{}:{}:{}:{}",
                                t.x,
                                t.y,
                                port_letter(t.port),
                                t.start,
                                t.end
                            ))
                        })
                        .collect(),
                ),
            );
        j
    }

    /// Validate against the concrete fabric: probability ranges, retry
    /// budget, coordinate bounds, and — for explicit link/transient
    /// faults — that the named directed link actually has a receiving
    /// router (edge links toward the row memories cannot fault).
    pub fn validate(&self, topo: &dyn Topology) -> Result<(), ConfigError> {
        let check = |cond: bool, reason: String| -> Result<(), ConfigError> {
            if cond {
                Ok(())
            } else {
                Err(ConfigError::Invalid { what: WHAT, reason })
            }
        };
        check(
            (0.0..1.0).contains(&self.link_rate),
            format!("rate must be in [0, 1), got {}", self.link_rate),
        )?;
        check(
            (0.0..1.0).contains(&self.corrupt),
            format!("corrupt must be in [0, 1), got {}", self.corrupt),
        )?;
        check(self.retries >= 1, format!("retries must be >= 1, got {}", self.retries))?;
        let (cols, rows) = topo.dims();
        let in_grid = |x: u16, y: u16| (x as usize) < cols && (y as usize) < rows;
        for &(x, y) in &self.routers {
            check(in_grid(x, y), format!("router {x}:{y} outside the {cols}x{rows} grid"))?;
        }
        let link_ok = |x: u16, y: u16, p: Port| -> Result<(), ConfigError> {
            check(in_grid(x, y), format!("link {x}:{y} outside the {cols}x{rows} grid"))?;
            check(
                topo.neighbor(Coord::new(x, y), p).is_some(),
                format!("link {x}:{y}:{} has no receiving router on this topology", port_letter(p)),
            )
        };
        for &(x, y, p) in &self.links {
            link_ok(x, y, p)?;
        }
        for t in &self.transients {
            link_ok(t.x, t.y, t.port)?;
            check(
                t.start < t.end,
                format!("transient window [{}, {}) is empty", t.start, t.end),
            )?;
        }
        Ok(())
    }
}

fn port_letter(p: Port) -> char {
    match p {
        Port::North => 'N',
        Port::South => 'S',
        Port::East => 'E',
        Port::West => 'W',
        Port::Local => 'L',
    }
}

// ---------------------------------------------------------------------------
// Deterministic hashing
// ---------------------------------------------------------------------------

/// SplitMix64 finalizer — the deterministic coin for link draws and
/// corruption rolls.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn hash_words(seed: u64, words: &[u64]) -> u64 {
    let mut h = splitmix(seed);
    for &w in words {
        h = splitmix(h ^ w);
    }
    h
}

/// Convert a probability in `[0, 1)` to a 64-bit comparison threshold.
fn threshold(p: f64) -> u64 {
    (p * 18_446_744_073_709_551_616.0) as u64
}

// ---------------------------------------------------------------------------
// Compiled plan
// ---------------------------------------------------------------------------

/// The compiled, immutable fault schedule the kernel consults on its hot
/// paths. Built once per network from a validated [`FaultsConfig`].
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub cols: usize,
    pub rows: usize,
    /// Sender-side permanent link faults: `ridx * PORTS + out_port`.
    pub link_down: Vec<bool>,
    /// Receiver-side mirror of `link_down`: `ridx * PORTS + in_port`.
    pub link_dead_recv: Vec<bool>,
    /// Hard-down routers by node index.
    pub router_down: Vec<bool>,
    /// Transient windows keyed by receiver-side link id, sorted by link.
    pub transients: Vec<(usize, u64, u64)>,
    /// Corruption threshold (`corrupt` probability as a u64 compare).
    pub corrupt_threshold: u64,
    pub retry_budget: u32,
    pub holdoff_base: u64,
    pub seed: u64,
    /// True when any link or router is permanently down — the routing
    /// override and stream clamping are consulted only then.
    pub reroutes: bool,
    /// `next_hop[dst_key * n + ridx]`: the healthy-subgraph minimal next
    /// hop from router `ridx` toward `dst_key` (`None` = unreachable).
    /// Empty unless `reroutes`. Keys: node index for router
    /// destinations, `cols*rows + y` for the row-`y` memory element.
    next_hop: Vec<Option<Port>>,
}

impl FaultPlan {
    /// Compile a validated config against the concrete fabric.
    pub fn build(cfg: &FaultsConfig, topo: &dyn Topology) -> FaultPlan {
        let (cols, rows) = topo.dims();
        let n = cols * rows;
        let mut link_down = vec![false; n * PORTS];
        let mut router_down = vec![false; n];
        let node = |x: u16, y: u16| y as usize * cols + x as usize;
        for &(x, y) in &cfg.routers {
            router_down[node(x, y)] = true;
        }
        for &(x, y, p) in &cfg.links {
            link_down[node(x, y) * PORTS + p.index()] = true;
        }
        // Seed-derived random permanent faults: one deterministic coin
        // per existing directed link, independent of the explicit list.
        if cfg.link_rate > 0.0 {
            let th = threshold(cfg.link_rate);
            for ridx in 0..n {
                let c = Coord::new((ridx % cols) as u16, (ridx / cols) as u16);
                for p in LINK_PORTS {
                    if topo.neighbor(c, p).is_none() {
                        continue;
                    }
                    if hash_words(cfg.seed, &[0x11, ridx as u64, p.index() as u64]) < th {
                        link_down[ridx * PORTS + p.index()] = true;
                    }
                }
            }
        }
        // Receiver-side mirror for the arrival filter.
        let mut link_dead_recv = vec![false; n * PORTS];
        for ridx in 0..n {
            let c = Coord::new((ridx % cols) as u16, (ridx / cols) as u16);
            for p in LINK_PORTS {
                if !link_down[ridx * PORTS + p.index()] {
                    continue;
                }
                if let Some(nb) = topo.neighbor(c, p) {
                    let nb_idx = nb.y as usize * cols + nb.x as usize;
                    link_dead_recv[nb_idx * PORTS + p.opposite().index()] = true;
                }
            }
        }
        let mut transients: Vec<(usize, u64, u64)> = cfg
            .transients
            .iter()
            .map(|t| {
                let nb = topo
                    .neighbor(Coord::new(t.x, t.y), t.port)
                    .expect("validated transient link lost its neighbor");
                let nb_idx = nb.y as usize * cols + nb.x as usize;
                (nb_idx * PORTS + t.port.opposite().index(), t.start, t.end)
            })
            .collect();
        transients.sort_unstable();
        let reroutes = link_down.iter().any(|&d| d) || router_down.iter().any(|&d| d);
        let mut plan = FaultPlan {
            cols,
            rows,
            link_down,
            link_dead_recv,
            router_down,
            transients,
            corrupt_threshold: threshold(cfg.corrupt),
            retry_budget: cfg.retries,
            holdoff_base: cfg.holdoff.max(1),
            seed: cfg.seed,
            reroutes,
            next_hop: Vec::new(),
        };
        if reroutes {
            plan.build_tables(topo);
        }
        plan
    }

    fn n(&self) -> usize {
        self.cols * self.rows
    }

    /// Destination key for the next-hop table: node index for router
    /// coordinates, `n + y` for the row-`y` memory element east of the
    /// grid.
    pub fn dst_key(&self, dst: Coord) -> usize {
        if (dst.x as usize) < self.cols {
            dst.y as usize * self.cols + dst.x as usize
        } else {
            self.n() + dst.y as usize
        }
    }

    /// Healthy-subgraph next hop from router `ridx` toward `dst`.
    /// `None` when `dst` is unreachable over healthy links. Only
    /// meaningful when [`FaultPlan::reroutes`]; callers gate on it.
    pub fn route(&self, ridx: usize, dst: Coord) -> Option<Port> {
        self.next_hop[self.dst_key(dst) * self.n() + ridx]
    }

    /// Whether the memory element (or router) `dst` can be reached from
    /// router `ridx` at all. Always true when no topology fault exists.
    pub fn reachable(&self, ridx: usize, dst: Coord) -> bool {
        !self.reroutes || self.route(ridx, dst).is_some()
    }

    /// Whether `link` (receiver-side id) is inside a transient-down
    /// window at `cycle`; returns the window end for the replay deadline.
    pub fn transient_until(&self, link: usize, cycle: u64) -> Option<u64> {
        let start = self.transients.partition_point(|&(l, _, _)| l < link);
        self.transients[start..]
            .iter()
            .take_while(|&&(l, _, _)| l == link)
            .find(|&&(_, s, e)| s <= cycle && cycle < e)
            .map(|&(_, _, e)| e)
    }

    /// Deterministic corruption roll for one delivery attempt of one flit
    /// (identified by `pid`/`seq`) over one directed link.
    pub fn corrupts(&self, pid: u32, seq: u32, link: usize, attempt: u32) -> bool {
        if self.corrupt_threshold == 0 {
            return false;
        }
        hash_words(self.seed, &[0x22, pid as u64, seq as u64, link as u64, attempt as u64])
            < self.corrupt_threshold
    }

    /// Exponential hold-off before replay `attempt` (1-based).
    pub fn holdoff(&self, attempt: u32) -> u64 {
        let shift = (attempt.saturating_sub(1)).min(20);
        self.holdoff_base.saturating_mul(1u64 << shift)
    }

    /// BFS next-hop tables over the healthy subgraph, one per
    /// destination key, reverse-BFS from the destination so every entry
    /// is minimal. Tie-break: the fabric's own preferred route when it is
    /// minimal (zero-fault tables therefore reproduce XY / ring-minimal
    /// exactly), else the lowest port index — both independent of
    /// traversal order, so the tables are deterministic.
    fn build_tables(&mut self, topo: &dyn Topology) {
        let (cols, rows) = (self.cols, self.rows);
        let n = self.n();
        let keys = n + rows;
        self.next_hop = vec![None; keys * n];
        let coord = |ridx: usize| Coord::new((ridx % cols) as u16, (ridx / cols) as u16);
        let mut dist = vec![u32::MAX; n];
        let mut queue = VecDeque::new();
        for key in 0..keys {
            dist.iter_mut().for_each(|d| *d = u32::MAX);
            queue.clear();
            // A memory-bound flit granted East from the last column ejects
            // at that row's memory: for memory keys the east-edge links
            // out of column cols-1 must not appear as graph edges (a torus
            // wrap there would be hijacked by the ejection check), and the
            // sole sink is the dst row's edge router with the fabric's own
            // exit port.
            let mem = key >= n;
            let (dst_coord, exit_ridx) = if mem {
                let y = (key - n) as u16;
                (Coord::new(cols as u16, y), (y as usize) * cols + (cols - 1))
            } else {
                (coord(key), key)
            };
            if self.router_down[exit_ridx] {
                continue; // destination itself is gone: all-None column
            }
            let exit_port = if mem {
                topo.route(PacketType::Unicast, coord(exit_ridx), dst_coord)
            } else {
                Port::Local
            };
            dist[exit_ridx] = 0;
            self.next_hop[key * n + exit_ridx] = Some(exit_port);
            queue.push_back(exit_ridx);
            let edge_ok = |u: usize, p: Port| -> bool {
                !self.router_down[u]
                    && !self.link_down[u * PORTS + p.index()]
                    && !(mem && p == Port::East && u % cols == cols - 1)
            };
            while let Some(v) = queue.pop_front() {
                let vd = dist[v];
                for p in LINK_PORTS {
                    let Some(uc) = topo.neighbor(coord(v), p) else { continue };
                    let u = uc.y as usize * cols + uc.x as usize;
                    // The edge u -> v runs through u's opposite port.
                    let q = p.opposite();
                    debug_assert_eq!(topo.neighbor(uc, q), Some(coord(v)));
                    if dist[u] != u32::MAX || !edge_ok(u, q) {
                        continue;
                    }
                    dist[u] = vd + 1;
                    queue.push_back(u);
                }
            }
            for u in 0..n {
                if u == exit_ridx || dist[u] == u32::MAX {
                    continue;
                }
                let uc = coord(u);
                let minimal = |p: Port| -> bool {
                    if !edge_ok(u, p) {
                        return false;
                    }
                    match topo.neighbor(uc, p) {
                        Some(vc) => {
                            let v = vc.y as usize * cols + vc.x as usize;
                            dist[v] != u32::MAX && dist[v] + 1 == dist[u]
                        }
                        None => false,
                    }
                };
                let preferred = topo.route(PacketType::Unicast, uc, dst_coord);
                let hop = if preferred != Port::Local && minimal(preferred) {
                    Some(preferred)
                } else {
                    LINK_PORTS.into_iter().find(|&p| minimal(p))
                };
                debug_assert!(hop.is_some(), "BFS-reached router without a minimal hop");
                self.next_hop[key * n + u] = hop;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Runtime state
// ---------------------------------------------------------------------------

/// One flit parked in a link's retransmission slot: the arrival it will
/// re-present, the replay attempt count, and the cycle it becomes due.
/// Held flits keep the downstream buffer credit they consumed, so replay
/// can never overflow the buffer.
#[derive(Debug, Clone)]
pub struct RetxEntry {
    pub router: u32,
    pub port: Port,
    pub vc: u8,
    pub flit: CompactFlit,
    pub attempt: u32,
    pub due: u64,
}

/// Mutable fault-machinery state owned by the network. All mutation
/// happens on the owner thread (the arrival filter and the post paths),
/// which is what keeps the sequential and band-parallel kernels
/// bit-identical.
#[derive(Debug)]
pub struct FaultState {
    pub plan: FaultPlan,
    /// Per receiver-side link FIFO of held flits (`ridx * PORTS + port`).
    pub retx: Vec<VecDeque<RetxEntry>>,
    /// Sorted ids of links with a non-empty retx queue (ascending pump
    /// order = deterministic replay order).
    pub active_links: Vec<usize>,
    /// Sorted pids of packets being dropped flit-by-flit.
    pub poisoned: Vec<u32>,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        let links = plan.n() * PORTS;
        FaultState { plan, retx: (0..links).map(|_| VecDeque::new()).collect(), active_links: Vec::new(), poisoned: Vec::new() }
    }

    pub fn mark_active(&mut self, link: usize) {
        if let Err(i) = self.active_links.binary_search(&link) {
            self.active_links.insert(i, link);
        }
    }

    pub fn mark_idle(&mut self, link: usize) {
        if let Ok(i) = self.active_links.binary_search(&link) {
            self.active_links.remove(i);
        }
    }

    pub fn poison(&mut self, pid: u32) {
        if let Err(i) = self.poisoned.binary_search(&pid) {
            self.poisoned.insert(i, pid);
        }
    }

    pub fn unpoison(&mut self, pid: u32) {
        if let Ok(i) = self.poisoned.binary_search(&pid) {
            self.poisoned.remove(i);
        }
    }

    pub fn is_poisoned(&self, pid: u32) -> bool {
        self.poisoned.binary_search(&pid).is_ok()
    }

    /// Any flit parked in a retransmission slot (they stay counted in
    /// `flits_active`, so quiescence — and idle fast-forward — waits for
    /// them).
    pub fn holding(&self) -> bool {
        !self.active_links.is_empty()
    }

    /// True when some held flit is legitimately waiting for a future
    /// cycle (hold-off or transient window) — the watchdog defers to it.
    pub fn pending_future_replay(&self, cycle: u64) -> bool {
        self.active_links
            .iter()
            .any(|&l| self.retx[l].front().is_some_and(|e| e.due > cycle))
    }
}

// ---------------------------------------------------------------------------
// Degradation report
// ---------------------------------------------------------------------------

/// What the fault subsystem cost a run: the census shortfall, every drop
/// class, and the rerouting/retransmission overhead. Attached to
/// [`crate::dataflow::LayerRunResult::degraded`] whenever faults are
/// configured (even if every counter is zero).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DegradationReport {
    /// Contributors excluded from the gather/INA census (router down or
    /// memory unreachable at post time).
    pub missing_contributors: u64,
    /// Result payloads that never reached memory (post-time exclusions
    /// plus retry-exhausted packet drops).
    pub payloads_dropped: u64,
    /// Packets poisoned after a head flit exhausted its retry budget.
    pub packets_dropped: u64,
    /// Individual flits discarded (poisoned packets, dead-link arrivals).
    pub flits_dropped: u64,
    /// Delivery attempts that failed the corruption roll.
    pub flits_corrupted: u64,
    /// Replays performed from retransmission slots.
    pub retransmissions: u64,
    /// Head flits whose packet was dropped after the retry budget.
    pub retries_exhausted: u64,
    /// Extra hops taken relative to the fabric's fault-free route.
    pub detour_hops: u64,
    /// Operand streams clamped short of their full path by a fault.
    pub streams_truncated: u64,
    /// Operand streams dropped whole (entry router down or head lost).
    pub streams_dropped: u64,
}

impl DegradationReport {
    /// No fault ever bit: the run was degradation-free.
    pub fn is_clean(&self) -> bool {
        *self == DegradationReport::default()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("missing_contributors", Json::Num(self.missing_contributors as f64))
            .set("payloads_dropped", Json::Num(self.payloads_dropped as f64))
            .set("packets_dropped", Json::Num(self.packets_dropped as f64))
            .set("flits_dropped", Json::Num(self.flits_dropped as f64))
            .set("flits_corrupted", Json::Num(self.flits_corrupted as f64))
            .set("retransmissions", Json::Num(self.retransmissions as f64))
            .set("retries_exhausted", Json::Num(self.retries_exhausted as f64))
            .set("detour_hops", Json::Num(self.detour_hops as f64))
            .set("streams_truncated", Json::Num(self.streams_truncated as f64))
            .set("streams_dropped", Json::Num(self.streams_dropped as f64));
        j
    }

    /// One-line human summary for reports and the analyze command.
    pub fn summary(&self) -> String {
        if self.is_clean() {
            return "faults enabled, no degradation".to_string();
        }
        format!(
            "missing contributors {}, payloads dropped {}, packets dropped {}, \
             corrupted {}, retransmitted {}, retries exhausted {}, detour hops {}, \
             streams truncated {} / dropped {}",
            self.missing_contributors,
            self.payloads_dropped,
            self.packets_dropped,
            self.flits_corrupted,
            self.retransmissions,
            self.retries_exhausted,
            self.detour_hops,
            self.streams_truncated,
            self.streams_dropped,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::topology::{Mesh2D, Torus2D};

    #[test]
    fn spec_string_parses_every_key() {
        let f = FaultsConfig::parse(
            "seed=7,rate=0.25,links=3:2:E;4:4:N,routers=5:5,transient=1:1:E:100:400,\
             corrupt=0.001,retries=4,holdoff=8",
        )
        .unwrap();
        assert_eq!(f.seed, 7);
        assert_eq!(f.link_rate, 0.25);
        assert_eq!(f.links, vec![(3, 2, Port::East), (4, 4, Port::North)]);
        assert_eq!(f.routers, vec![(5, 5)]);
        assert_eq!(f.transients.len(), 1);
        assert_eq!(f.transients[0].port, Port::East);
        assert_eq!((f.transients[0].start, f.transients[0].end), (100, 400));
        assert_eq!(f.corrupt, 0.001);
        assert_eq!(f.retries, 4);
        assert_eq!(f.holdoff, 8);
    }

    #[test]
    fn malformed_specs_are_typed_errors() {
        assert!(matches!(
            FaultsConfig::parse("bogus=1"),
            Err(ConfigError::UnknownKeyword { what: "faults key", .. })
        ));
        assert!(matches!(
            FaultsConfig::parse("links=1:2:Q"),
            Err(ConfigError::UnknownKeyword { what: "fault link direction", .. })
        ));
        assert!(FaultsConfig::parse("rate=notanumber").is_err());
        assert!(FaultsConfig::parse("transient=1:1:E:9").is_err());
        assert!(FaultsConfig::parse("seed").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let f = FaultsConfig::parse(
            "seed=9,rate=0.1,links=0:0:E,routers=2:2,transient=1:0:S:5:50,corrupt=0.01",
        )
        .unwrap();
        let back = FaultsConfig::from_json(&f.to_json()).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn validate_rejects_out_of_range_and_edge_links() {
        let topo = Mesh2D::new(8, 8);
        let ok = FaultsConfig::parse("links=3:3:E,routers=7:7").unwrap();
        assert!(ok.validate(&topo).is_ok());
        // North out of row 0 has no receiver on a mesh...
        let bad = FaultsConfig::parse("links=3:0:N").unwrap();
        assert!(bad.validate(&topo).is_err());
        // ...but does on a torus.
        assert!(bad.validate(&Torus2D::new(8, 8)).is_ok());
        // East out of the last column is the memory link: never faultable.
        let mem = FaultsConfig::parse("links=7:3:E").unwrap();
        assert!(mem.validate(&topo).is_err());
        assert!(FaultsConfig::parse("routers=8:0").unwrap().validate(&topo).is_err());
        assert!(FaultsConfig::parse("rate=1.5").unwrap().validate(&topo).is_err());
        assert!(FaultsConfig::parse("retries=0").unwrap().validate(&topo).is_err());
        assert!(FaultsConfig::parse("transient=1:1:E:9:9").unwrap().validate(&topo).is_err());
    }

    #[test]
    fn zero_fault_tables_reproduce_the_fabric_route() {
        // With reroutes forced on but nothing actually down, every table
        // entry must equal the fabric's own deterministic route — the
        // detour logic is a strict superset of XY.
        let topo = Mesh2D::new(6, 6);
        let mut cfg = FaultsConfig::default();
        cfg.links.push((2, 2, Port::East)); // make reroutes true...
        let mut plan = FaultPlan::build(&cfg, &topo);
        // ...then heal it and rebuild the tables over the full graph.
        plan.link_down.iter_mut().for_each(|d| *d = false);
        plan.link_dead_recv.iter_mut().for_each(|d| *d = false);
        plan.build_tables(&topo);
        for y in 0..6u16 {
            let mem = Coord::new(6, y);
            for ridx in 0..36 {
                let here = Coord::new((ridx % 6) as u16, (ridx / 6) as u16);
                if here.x == 5 && here.y != y {
                    // Last-column routers on the wrong row: the fabric
                    // would say East, but granting East there ejects into
                    // the *wrong* row's memory, so the table deliberately
                    // jogs toward the dst row instead. Real fault-free
                    // traffic never routes mem row y through here.
                    continue;
                }
                let want = topo.route(PacketType::Unicast, here, mem);
                assert_eq!(plan.route(ridx, mem), Some(want), "router {here:?} -> mem row {y}");
            }
        }
    }

    #[test]
    fn tables_detour_around_a_dead_link_and_mark_unreachable() {
        let topo = Mesh2D::new(4, 4);
        // Kill the East link out of every router in column 2 at every row:
        // column 3 (and memory) stays reachable only... no — row paths can
        // jog through other rows? Also dead: that's all E links at x=2, so
        // reaching x=3 is impossible and memory keys must go None west of
        // the cut while column 3 itself stays fine.
        let cfg = FaultsConfig::parse("links=2:0:E;2:1:E;2:2:E;2:3:E").unwrap();
        cfg.validate(&topo).unwrap();
        let plan = FaultPlan::build(&cfg, &topo);
        assert!(plan.reroutes);
        let mem0 = Coord::new(4, 0);
        assert!(plan.route(0, mem0).is_none(), "memory unreachable across the cut");
        assert!(!plan.reachable(0, mem0));
        let east_ridx = 3; // (3, 0): east of the cut
        assert_eq!(plan.route(east_ridx, mem0), Some(Port::East));
        // A single dead link detours instead.
        let cfg = FaultsConfig::parse("links=1:1:E").unwrap();
        let plan = FaultPlan::build(&cfg, &topo);
        let mem1 = Coord::new(4, 1);
        let at_cut = 1 * 4 + 1; // (1,1)
        let hop = plan.route(at_cut, mem1).unwrap();
        assert!(hop == Port::North || hop == Port::South, "must jog around the dead link");
        // Every healthy router still reaches its memory row.
        for ridx in 0..16 {
            assert!(plan.reachable(ridx, Coord::new(4, (ridx / 4) as u16)));
        }
    }

    #[test]
    fn router_fault_excludes_itself_and_random_rate_is_deterministic() {
        let topo = Mesh2D::new(4, 4);
        let cfg = FaultsConfig::parse("routers=1:1").unwrap();
        let plan = FaultPlan::build(&cfg, &topo);
        let down = 1 * 4 + 1;
        // No destination is reachable *from* the dead router, and no
        // table routes *through* it.
        assert!(plan.route(down, Coord::new(4, 1)).is_none());
        for ridx in 0..16 {
            if ridx == down {
                continue;
            }
            for y in 0..4u16 {
                let mem = Coord::new(4, y);
                if let Some(p) = plan.route(ridx, mem) {
                    let here = Coord::new((ridx % 4) as u16, (ridx / 4) as u16);
                    let nb = topo.neighbor(here, p);
                    assert_ne!(nb, Some(Coord::new(1, 1)), "routed into a dead router");
                }
            }
        }
        let a = FaultPlan::build(&FaultsConfig::parse("seed=3,rate=0.3").unwrap(), &topo);
        let b = FaultPlan::build(&FaultsConfig::parse("seed=3,rate=0.3").unwrap(), &topo);
        assert_eq!(a.link_down, b.link_down, "same seed must fault the same links");
        let c = FaultPlan::build(&FaultsConfig::parse("seed=4,rate=0.3").unwrap(), &topo);
        assert!(a.link_down != c.link_down || a.link_down.iter().all(|&d| !d));
    }

    #[test]
    fn corruption_roll_and_transient_lookup_are_deterministic() {
        let topo = Mesh2D::new(4, 4);
        let cfg = FaultsConfig::parse("corrupt=0.5,transient=1:1:E:100:200").unwrap();
        let plan = FaultPlan::build(&cfg, &topo);
        assert!(!plan.reroutes, "corruption alone must not arm rerouting");
        let roll = plan.corrupts(9, 0, 13, 0);
        assert_eq!(roll, plan.corrupts(9, 0, 13, 0));
        // Attempts decorrelate: over many flits both outcomes appear.
        let mut flipped = false;
        for pid in 0..64 {
            if plan.corrupts(pid, 0, 13, 0) != plan.corrupts(pid, 0, 13, 1) {
                flipped = true;
            }
        }
        assert!(flipped);
        // The transient window: receiver side of (1,1)->E is (2,1) West.
        let link = (1 * 4 + 2) * PORTS + Port::West.index();
        assert_eq!(plan.transient_until(link, 99), None);
        assert_eq!(plan.transient_until(link, 100), Some(200));
        assert_eq!(plan.transient_until(link, 199), Some(200));
        assert_eq!(plan.transient_until(link, 200), None);
        assert_eq!(plan.transient_until(link + 1, 150), None);
    }

    #[test]
    fn holdoff_grows_exponentially_and_saturates() {
        let topo = Mesh2D::new(2, 2);
        let plan = FaultPlan::build(&FaultsConfig::parse("holdoff=4").unwrap(), &topo);
        assert_eq!(plan.holdoff(1), 4);
        assert_eq!(plan.holdoff(2), 8);
        assert_eq!(plan.holdoff(3), 16);
        assert!(plan.holdoff(1000) >= plan.holdoff(21));
    }

    #[test]
    fn fault_state_bookkeeping() {
        let topo = Mesh2D::new(2, 2);
        let plan = FaultPlan::build(&FaultsConfig::default(), &topo);
        let mut fs = FaultState::new(plan);
        fs.mark_active(7);
        fs.mark_active(3);
        fs.mark_active(7);
        assert_eq!(fs.active_links, vec![3, 7]);
        fs.mark_idle(7);
        assert_eq!(fs.active_links, vec![3]);
        fs.poison(9);
        fs.poison(2);
        assert!(fs.is_poisoned(9) && fs.is_poisoned(2) && !fs.is_poisoned(5));
        fs.unpoison(9);
        assert!(!fs.is_poisoned(9));
        assert!(!fs.pending_future_replay(0));
    }

    #[test]
    fn degradation_report_summary_and_json() {
        let mut d = DegradationReport::default();
        assert!(d.is_clean());
        assert!(d.summary().contains("no degradation"));
        d.payloads_dropped = 3;
        d.retransmissions = 11;
        assert!(!d.is_clean());
        let s = d.summary();
        assert!(s.contains("payloads dropped 3") && s.contains("retransmitted 11"));
        assert_eq!(d.to_json().get("retransmissions").unwrap().as_u64(), Some(11));
    }
}
