//! Flit and packet field types — the packet format of Fig. 6(a).
//!
//! A packet is a head flit followed by body flits and a tail flit (a 2-flit
//! packet is head + tail). The head carries `FT`, `PT`, `ASpace`, `Src`,
//! `Dst` (and `MDst` for multicast); body/tail flits carry payload words.


/// Flit type field (`FT` in Fig. 6(a)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlitType {
    Head,
    Body,
    Tail,
}

/// Packet type field (`PT` in Fig. 6(a)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PacketType {
    /// One-to-one result/parameter traffic.
    Unicast,
    /// One-to-many operand distribution (row/column streams over the mesh).
    Multicast,
    /// Many-to-one partial-sum collection (the paper's contribution).
    Gather,
    /// Many-to-one partial-sum collection with in-network accumulation
    /// (the arXiv:2209.10056 follow-up): routers *add* same-space psums
    /// into a passing packet — or merge two whole packets — instead of
    /// appending payload slots, so the packet never grows.
    Ina,
}

/// A node coordinate on the mesh. `x` grows eastward (toward the global
/// memory column), `y` grows southward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub x: u16,
    pub y: u16,
}

impl Coord {
    pub const fn new(x: u16, y: u16) -> Self {
        Coord { x, y }
    }

    /// Manhattan distance (hop count under XY routing).
    pub fn manhattan(&self, other: &Coord) -> u64 {
        (self.x.abs_diff(other.x) as u64) + (self.y.abs_diff(other.y) as u64)
    }
}

/// Globally unique packet id (simulator bookkeeping, not an on-wire field).
pub type PacketId = u64;

/// One flit in flight. This is the unit the simulator moves around.
///
/// For timing simulation the data words themselves are not carried; the
/// gather payload occupancy is tracked via [`Flit::aspace`] on the head flit
/// exactly as the hardware does (Fig. 6(b)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Flit {
    pub packet_id: PacketId,
    pub ftype: FlitType,
    pub ptype: PacketType,
    pub src: Coord,
    pub dst: Coord,
    /// Remaining gather payload slots (`ASpace`); meaningful on gather
    /// heads. On INA heads this field is repurposed to hold the packet's
    /// *physical* psum word count, which stays constant under accumulation
    /// (adds happen in place) and prices the router ALU work of a merge.
    pub aspace: u32,
    /// Accumulation space this packet's psums belong to (INA packets
    /// only; 0 otherwise). Two psums may be added by a router ALU only
    /// when they share a space — in practice (row, round) — and a
    /// destination memory node.
    pub space: u64,
    /// Index of this flit within its packet (head = 0).
    pub seq: u32,
    /// Total flits in the packet.
    pub packet_len: u32,
    /// Cycle at which the packet was injected into the network (for latency
    /// accounting; carried on every flit so the tail can report).
    pub inject_cycle: u64,
    /// For multicast operand streams: deliver a copy to the local port of
    /// every router traversed (row/column streaming over the mesh).
    pub deliver_along_path: bool,
    /// Gather payloads carried so far (head flits; starts at the
    /// initiator's own payload count, incremented on boarding). For unicast
    /// result packets, set at injection.
    pub carried_payloads: u32,
    /// Cycle this flit was last written into a buffer (simulator
    /// bookkeeping for SA eligibility, not an on-wire field).
    pub arrival: u64,
}

impl Flit {
    pub fn is_head(&self) -> bool {
        self.ftype == FlitType::Head
    }

    pub fn is_tail(&self) -> bool {
        self.ftype == FlitType::Tail
    }
}

/// Builds the flit sequence for one packet.
#[derive(Debug, Clone)]
pub struct PacketDesc {
    pub id: PacketId,
    pub ptype: PacketType,
    pub src: Coord,
    pub dst: Coord,
    pub len_flits: u32,
    pub aspace: u32,
    /// Accumulation space tag (INA packets; 0 otherwise).
    pub space: u64,
    pub inject_cycle: u64,
    pub deliver_along_path: bool,
    /// Result payloads carried by this packet at injection time.
    pub carried_payloads: u32,
}

impl PacketDesc {
    /// Materialize the `i`-th flit of this packet.
    pub fn flit(&self, i: u32) -> Flit {
        debug_assert!(i < self.len_flits);
        let ftype = if i == 0 {
            FlitType::Head
        } else if i + 1 == self.len_flits {
            FlitType::Tail
        } else {
            FlitType::Body
        };
        Flit {
            packet_id: self.id,
            ftype,
            ptype: self.ptype,
            src: self.src,
            dst: self.dst,
            aspace: self.aspace,
            space: self.space,
            seq: i,
            packet_len: self.len_flits,
            inject_cycle: self.inject_cycle,
            deliver_along_path: self.deliver_along_path,
            carried_payloads: self.carried_payloads,
            arrival: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manhattan_distance() {
        let a = Coord::new(0, 0);
        let b = Coord::new(7, 3);
        assert_eq!(a.manhattan(&b), 10);
        assert_eq!(b.manhattan(&a), 10);
        assert_eq!(a.manhattan(&a), 0);
    }

    #[test]
    fn packet_desc_flit_types() {
        let d = PacketDesc {
            id: 1,
            ptype: PacketType::Gather,
            src: Coord::new(0, 0),
            dst: Coord::new(7, 0),
            len_flits: 3,
            aspace: 8,
            space: 0,
            inject_cycle: 100,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        assert_eq!(d.flit(0).ftype, FlitType::Head);
        assert_eq!(d.flit(1).ftype, FlitType::Body);
        assert_eq!(d.flit(2).ftype, FlitType::Tail);
        assert!(d.flit(0).is_head());
        assert!(d.flit(2).is_tail());
    }

    #[test]
    fn two_flit_packet_is_head_plus_tail() {
        let d = PacketDesc {
            id: 2,
            ptype: PacketType::Unicast,
            src: Coord::new(3, 2),
            dst: Coord::new(7, 2),
            len_flits: 2,
            aspace: 0,
            space: 0,
            inject_cycle: 0,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        assert_eq!(d.flit(0).ftype, FlitType::Head);
        assert_eq!(d.flit(1).ftype, FlitType::Tail);
    }
}
