//! Flit and packet field types — the packet format of Fig. 6(a).
//!
//! A packet is a head flit followed by body flits and a tail flit (a 2-flit
//! packet is head + tail). The head carries `FT`, `PT`, `ASpace`, `Src`,
//! `Dst` (and `MDst` for multicast); body/tail flits carry payload words.


/// Flit type field (`FT` in Fig. 6(a)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlitType {
    Head,
    Body,
    Tail,
}

/// Packet type field (`PT` in Fig. 6(a)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PacketType {
    /// One-to-one result/parameter traffic.
    Unicast,
    /// One-to-many operand distribution (row/column streams over the mesh).
    Multicast,
    /// Many-to-one partial-sum collection (the paper's contribution).
    Gather,
    /// Many-to-one partial-sum collection with in-network accumulation
    /// (the arXiv:2209.10056 follow-up): routers *add* same-space psums
    /// into a passing packet — or merge two whole packets — instead of
    /// appending payload slots, so the packet never grows.
    Ina,
}

/// A node coordinate on the mesh. `x` grows eastward (toward the global
/// memory column), `y` grows southward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub x: u16,
    pub y: u16,
}

impl Coord {
    pub const fn new(x: u16, y: u16) -> Self {
        Coord { x, y }
    }

    /// Manhattan distance (hop count under XY routing).
    pub fn manhattan(&self, other: &Coord) -> u64 {
        (self.x.abs_diff(other.x) as u64) + (self.y.abs_diff(other.y) as u64)
    }
}

/// Globally unique packet id (simulator bookkeeping, not an on-wire field).
pub type PacketId = u64;

/// One flit in flight. This is the unit the simulator moves around.
///
/// For timing simulation the data words themselves are not carried; the
/// gather payload occupancy is tracked via [`Flit::aspace`] on the head flit
/// exactly as the hardware does (Fig. 6(b)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Flit {
    pub packet_id: PacketId,
    pub ftype: FlitType,
    pub ptype: PacketType,
    pub src: Coord,
    pub dst: Coord,
    /// Remaining gather payload slots (`ASpace`); meaningful on gather
    /// heads. On INA heads this field is repurposed to hold the packet's
    /// *physical* psum word count, which stays constant under accumulation
    /// (adds happen in place) and prices the router ALU work of a merge.
    pub aspace: u32,
    /// Accumulation space this packet's psums belong to (INA packets
    /// only; 0 otherwise). Two psums may be added by a router ALU only
    /// when they share a space — in practice (row, round) — and a
    /// destination memory node.
    pub space: u64,
    /// Index of this flit within its packet (head = 0).
    pub seq: u32,
    /// Total flits in the packet.
    pub packet_len: u32,
    /// Cycle at which the packet was injected into the network (for latency
    /// accounting; carried on every flit so the tail can report).
    pub inject_cycle: u64,
    /// For multicast operand streams: deliver a copy to the local port of
    /// every router traversed (row/column streaming over the mesh).
    pub deliver_along_path: bool,
    /// Gather payloads carried so far (head flits; starts at the
    /// initiator's own payload count, incremented on boarding). For unicast
    /// result packets, set at injection.
    pub carried_payloads: u32,
    /// Cycle this flit was last written into a buffer (simulator
    /// bookkeeping for SA eligibility, not an on-wire field).
    pub arrival: u64,
}

impl Flit {
    pub fn is_head(&self) -> bool {
        self.ftype == FlitType::Head
    }

    pub fn is_tail(&self) -> bool {
        self.ftype == FlitType::Tail
    }
}

/// The one flit query the shared router plumbing ([`super::router`],
/// [`super::buffer`]) needs, so the VC state machine works over both the
/// reference kernel's [`Flit`] and the event kernel's [`CompactFlit`].
pub trait FlitLike {
    fn is_head(&self) -> bool;
}

impl FlitLike for Flit {
    fn is_head(&self) -> bool {
        Flit::is_head(self)
    }
}

impl FlitLike for CompactFlit {
    fn is_head(&self) -> bool {
        CompactFlit::is_head(self)
    }
}

const HEAD_BIT: u8 = 1 << 0;
const TAIL_BIT: u8 = 1 << 1;
const MEM_DST_BIT: u8 = 1 << 2;
const ALONG_PATH_BIT: u8 = 1 << 3;
const PTYPE_SHIFT: u8 = 4;

/// The in-flight flit of the event kernel: a packet-table index plus the
/// genuinely per-flit mutable state. Everything packet-constant (`src`,
/// `dst`, `packet_len`, `inject_cycle`, `space`, ...) lives in the
/// [`PacketTable`] entry named by `pid`, so a buffer hop copies 32 bytes
/// instead of the full [`Flit`].
///
/// `flags` caches the per-flit bits the hot loops test every cycle:
/// head/tail position, `dst.x >= cols` (memory-column destination),
/// `deliver_along_path`, and the 2-bit packet type — all derivable from
/// the table but free to read here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactFlit {
    /// Live index into the owning [`PacketTable`].
    pub pid: u32,
    /// Index of this flit within its packet (head = 0).
    pub seq: u32,
    /// Remaining gather payload slots / INA physical word count — the
    /// per-flit mutable twin of [`Flit::aspace`] (head flits).
    pub aspace: u32,
    /// Gather payloads carried so far (head flits) — see
    /// [`Flit::carried_payloads`].
    pub carried_payloads: u32,
    /// Cycle this flit was last written into a buffer (SA eligibility).
    pub arrival: u64,
    flags: u8,
}

// The whole point of the compact layout: if a field lands here that
// pushes the in-flight flit past 32 bytes, fail the build, not a bench.
const _: () = assert!(
    std::mem::size_of::<CompactFlit>() <= 32,
    "CompactFlit must stay within 32 bytes: intern packet-constant fields in PacketTable instead"
);

impl CompactFlit {
    #[inline]
    pub fn is_head(&self) -> bool {
        self.flags & HEAD_BIT != 0
    }

    /// True for the tail flit — including the single flit of a length-1
    /// packet, so the old `is_tail() || packet_len == 1` retire test is
    /// one bit test here.
    #[inline]
    pub fn is_tail(&self) -> bool {
        self.flags & TAIL_BIT != 0
    }

    /// Cached `dst.x >= cols`: the packet is bound for the memory column
    /// east of the fabric.
    #[inline]
    pub fn mem_dst(&self) -> bool {
        self.flags & MEM_DST_BIT != 0
    }

    #[inline]
    pub fn along_path(&self) -> bool {
        self.flags & ALONG_PATH_BIT != 0
    }

    #[inline]
    pub fn ptype(&self) -> PacketType {
        match self.flags >> PTYPE_SHIFT {
            0 => PacketType::Unicast,
            1 => PacketType::Multicast,
            2 => PacketType::Gather,
            _ => PacketType::Ina,
        }
    }
}

fn ptype_bits(ptype: PacketType) -> u8 {
    match ptype {
        PacketType::Unicast => 0,
        PacketType::Multicast => 1,
        PacketType::Gather => 2,
        PacketType::Ina => 3,
    }
}

/// One interned packet: the fields every flit of the packet shares, plus
/// the retire refcount.
#[derive(Debug, Clone, Copy)]
struct PacketEntry {
    ptype: PacketType,
    src: Coord,
    dst: Coord,
    len: u32,
    space: u64,
    inject_cycle: u64,
    mem_dst: bool,
    deliver_along_path: bool,
    /// `aspace` / `carried_payloads` at injection time — the values
    /// [`PacketTable::make_flit`] stamps on materialized flits (boarding
    /// then mutates the head's copies in flight).
    aspace0: u32,
    carried0: u32,
    /// Flits of this packet not yet retired. Ejection retires one flit at
    /// a time; an INA merge retires the whole absorbed packet at once.
    /// The slot is recycled (pushed on the free list) when it hits 0, so
    /// `remaining > 0` *is* the liveness predicate.
    remaining: u32,
}

/// Slab of live packets, indexed by [`CompactFlit::pid`], with free-list
/// recycling at tail retire. Interning happens exactly where the kernel
/// counts `packets_injected`, and a slot is released exactly when its
/// last flit leaves the network, so at every cycle boundary
/// `live == packets_injected - packets_ejected - ina_merges`.
#[derive(Debug, Default)]
pub struct PacketTable {
    entries: Vec<PacketEntry>,
    free: Vec<u32>,
    live: u64,
    peak_live: u64,
}

impl PacketTable {
    pub fn new() -> PacketTable {
        PacketTable::default()
    }

    /// Intern one packet; `mem_dst` caches the caller's `dst.x >= cols`
    /// test. Returns the slab index the packet's flits carry as `pid`.
    pub fn intern(&mut self, desc: &PacketDesc, mem_dst: bool) -> u32 {
        let entry = PacketEntry {
            ptype: desc.ptype,
            src: desc.src,
            dst: desc.dst,
            len: desc.len_flits,
            space: desc.space,
            inject_cycle: desc.inject_cycle,
            mem_dst,
            deliver_along_path: desc.deliver_along_path,
            aspace0: desc.aspace,
            carried0: desc.carried_payloads,
            remaining: desc.len_flits,
        };
        debug_assert!(entry.remaining > 0, "interned a zero-length packet");
        let pid = match self.free.pop() {
            Some(pid) => {
                self.entries[pid as usize] = entry;
                pid
            }
            None => {
                self.entries.push(entry);
                (self.entries.len() - 1) as u32
            }
        };
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        pid
    }

    /// Retire `flits` flits of packet `pid`; recycles the slot when the
    /// last flit goes.
    pub fn release(&mut self, pid: u32, flits: u32) {
        let e = &mut self.entries[pid as usize];
        debug_assert!(
            e.remaining >= flits && flits > 0,
            "released {flits} flits of packet {pid} with {} remaining",
            e.remaining
        );
        e.remaining -= flits;
        if e.remaining == 0 {
            self.free.push(pid);
            self.live -= 1;
        }
    }

    /// Materialize flit `seq` of packet `pid` (`arrival` starts at 0,
    /// exactly like [`PacketDesc::flit`]).
    pub fn make_flit(&self, pid: u32, seq: u32) -> CompactFlit {
        let e = &self.entries[pid as usize];
        debug_assert!(seq < e.len);
        let mut flags = ptype_bits(e.ptype) << PTYPE_SHIFT;
        if seq == 0 {
            flags |= HEAD_BIT;
        }
        if seq + 1 == e.len {
            flags |= TAIL_BIT;
        }
        if e.mem_dst {
            flags |= MEM_DST_BIT;
        }
        if e.deliver_along_path {
            flags |= ALONG_PATH_BIT;
        }
        CompactFlit {
            pid,
            seq,
            aspace: e.aspace0,
            carried_payloads: e.carried0,
            arrival: 0,
            flags,
        }
    }

    #[inline]
    pub fn src(&self, pid: u32) -> Coord {
        self.entries[pid as usize].src
    }

    #[inline]
    pub fn dst(&self, pid: u32) -> Coord {
        self.entries[pid as usize].dst
    }

    #[inline]
    pub fn ptype(&self, pid: u32) -> PacketType {
        self.entries[pid as usize].ptype
    }

    #[inline]
    pub fn len(&self, pid: u32) -> u32 {
        self.entries[pid as usize].len
    }

    #[inline]
    pub fn space(&self, pid: u32) -> u64 {
        self.entries[pid as usize].space
    }

    #[inline]
    pub fn inject_cycle(&self, pid: u32) -> u64 {
        self.entries[pid as usize].inject_cycle
    }

    /// Packets currently interned.
    pub fn live(&self) -> u64 {
        self.live
    }

    /// High-water mark of simultaneously live packets.
    pub fn peak_live(&self) -> u64 {
        self.peak_live
    }

    /// Slab slots ever allocated (live + recyclable).
    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    /// Liveness of a slab index: false for freed (recyclable) slots and
    /// out-of-range indices.
    pub fn is_live(&self, pid: u32) -> bool {
        self.entries.get(pid as usize).is_some_and(|e| e.remaining > 0)
    }

    /// Flits of `pid` not yet retired (0 for freed slots).
    pub fn remaining(&self, pid: u32) -> u32 {
        self.entries[pid as usize].remaining
    }
}

/// Builds the flit sequence for one packet.
#[derive(Debug, Clone)]
pub struct PacketDesc {
    pub id: PacketId,
    pub ptype: PacketType,
    pub src: Coord,
    pub dst: Coord,
    pub len_flits: u32,
    pub aspace: u32,
    /// Accumulation space tag (INA packets; 0 otherwise).
    pub space: u64,
    pub inject_cycle: u64,
    pub deliver_along_path: bool,
    /// Result payloads carried by this packet at injection time.
    pub carried_payloads: u32,
}

impl PacketDesc {
    /// Materialize the `i`-th flit of this packet.
    pub fn flit(&self, i: u32) -> Flit {
        debug_assert!(i < self.len_flits);
        let ftype = if i == 0 {
            FlitType::Head
        } else if i + 1 == self.len_flits {
            FlitType::Tail
        } else {
            FlitType::Body
        };
        Flit {
            packet_id: self.id,
            ftype,
            ptype: self.ptype,
            src: self.src,
            dst: self.dst,
            aspace: self.aspace,
            space: self.space,
            seq: i,
            packet_len: self.len_flits,
            inject_cycle: self.inject_cycle,
            deliver_along_path: self.deliver_along_path,
            carried_payloads: self.carried_payloads,
            arrival: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manhattan_distance() {
        let a = Coord::new(0, 0);
        let b = Coord::new(7, 3);
        assert_eq!(a.manhattan(&b), 10);
        assert_eq!(b.manhattan(&a), 10);
        assert_eq!(a.manhattan(&a), 0);
    }

    #[test]
    fn packet_desc_flit_types() {
        let d = PacketDesc {
            id: 1,
            ptype: PacketType::Gather,
            src: Coord::new(0, 0),
            dst: Coord::new(7, 0),
            len_flits: 3,
            aspace: 8,
            space: 0,
            inject_cycle: 100,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        assert_eq!(d.flit(0).ftype, FlitType::Head);
        assert_eq!(d.flit(1).ftype, FlitType::Body);
        assert_eq!(d.flit(2).ftype, FlitType::Tail);
        assert!(d.flit(0).is_head());
        assert!(d.flit(2).is_tail());
    }

    #[test]
    fn two_flit_packet_is_head_plus_tail() {
        let d = PacketDesc {
            id: 2,
            ptype: PacketType::Unicast,
            src: Coord::new(3, 2),
            dst: Coord::new(7, 2),
            len_flits: 2,
            aspace: 0,
            space: 0,
            inject_cycle: 0,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        assert_eq!(d.flit(0).ftype, FlitType::Head);
        assert_eq!(d.flit(1).ftype, FlitType::Tail);
    }

    fn desc(id: PacketId, ptype: PacketType, len: u32) -> PacketDesc {
        PacketDesc {
            id,
            ptype,
            src: Coord::new(1, 2),
            dst: Coord::new(8, 2),
            len_flits: len,
            aspace: 5,
            space: 77,
            inject_cycle: 40,
            deliver_along_path: false,
            carried_payloads: 3,
        }
    }

    #[test]
    fn compact_flit_mirrors_the_wide_flit_fields() {
        let mut t = PacketTable::new();
        let d = desc(0, PacketType::Gather, 3);
        let pid = t.intern(&d, d.dst.x >= 8);
        for seq in 0..3 {
            let wide = d.flit(seq);
            let compact = t.make_flit(pid, seq);
            assert_eq!(compact.is_head(), wide.is_head(), "seq {seq}");
            assert_eq!(compact.is_tail(), wide.is_tail(), "seq {seq}");
            assert_eq!(compact.ptype(), wide.ptype);
            assert_eq!(compact.aspace, wide.aspace);
            assert_eq!(compact.carried_payloads, wide.carried_payloads);
            assert_eq!(compact.seq, wide.seq);
            assert_eq!(compact.arrival, 0);
            assert!(compact.mem_dst());
            assert!(!compact.along_path());
        }
        assert_eq!(t.src(pid), d.src);
        assert_eq!(t.dst(pid), d.dst);
        assert_eq!(t.len(pid), 3);
        assert_eq!(t.space(pid), 77);
        assert_eq!(t.inject_cycle(pid), 40);
    }

    #[test]
    fn single_flit_packet_is_both_head_and_tail() {
        let mut t = PacketTable::new();
        let pid = t.intern(&desc(0, PacketType::Ina, 1), false);
        let f = t.make_flit(pid, 0);
        assert!(f.is_head() && f.is_tail());
        assert_eq!(f.ptype(), PacketType::Ina);
        assert!(!f.mem_dst());
    }

    #[test]
    fn slab_recycles_only_fully_retired_slots() {
        let mut t = PacketTable::new();
        let a = t.intern(&desc(0, PacketType::Gather, 3), true);
        let b = t.intern(&desc(0, PacketType::Unicast, 2), true);
        assert_eq!(t.live(), 2);
        assert!(t.is_live(a) && t.is_live(b));
        t.release(a, 1);
        assert!(t.is_live(a), "partially retired packet must stay live");
        t.release(a, 2);
        assert!(!t.is_live(a));
        assert_eq!(t.live(), 1);
        // The freed slot is recycled; the live one is untouched.
        let c = t.intern(&desc(0, PacketType::Ina, 1), false);
        assert_eq!(c, a, "free list must hand back the retired slot");
        assert_ne!(c, b);
        assert_eq!(t.capacity(), 2);
        assert_eq!(t.peak_live(), 2);
        // Whole-packet retire (the INA absorb path).
        t.release(b, 2);
        t.release(c, 1);
        assert_eq!(t.live(), 0);
    }
}
