//! Gather-supported routing — Algorithm 1 of the paper, plus the NI-side
//! timeout machinery (§4.1, §4.2, §5.2).
//!
//! ## Boarding (Algorithm 1, Fig. 7)
//!
//! The paper's Algorithm 1 ("Gather support routing algorithm"), as
//! implemented by [`try_board`] — `F` is the arriving head flit, `P` the
//! local NI's pending payload set:
//!
//! ```text
//! if (F.FT = H) and (F.PT = G) and (F.Dst = P.Dst) and P pending:
//!     if F.ASpace >= sizeof(P):         // room for all local payloads
//!         Load <- 1                     // fill into body/tail flits
//!         F.ASpace <- F.ASpace - sizeof(P)
//!     else:                             // packet (nearly) full
//!         board what fits; initiate an own gather packet for the rest
//! ```
//!
//! "When the header flit of a gather packet arrives at the input buffer,
//! the Load signal is generated during the RC stage": boarding is decided
//! **on head arrival** at each transit router. If the NI holds pending
//! payloads with the same destination (`F.Dst = P.Dst`) and
//! `F.ASpace >= sizeof(P)`, `ASpace` is decremented and the payloads are
//! filled into the body/tail flits during their otherwise-unused RC/VA
//! pipeline slots (see the pipeline table in [`super::network`]). **No
//! extra pipeline stage and no extra latency** — in the simulator this is
//! a zero-cost mutation of the passing packet's occupancy at buffer-write
//! time. The hardware cost of this shortcut — the Load generator and the
//! NI payload queue of Fig. 8/9 — is what §5.4 prices at ~6% router power
//! and ~4% area ([`crate::power::area::overhead_report`]).
//!
//! ## Timeout δ and packet initiation (§4.1, §4.2, §5.2)
//!
//! * The leftmost node of a row is the hardwired initiator and stages its
//!   packet as soon as payloads are ready.
//! * Every other NI arms a timeout; if no gather packet passes within it,
//!   the node stages its own packet (the "δ < κ" regime of Fig. 12
//!   degenerates to per-node packets exactly as in the paper).
//! * **Full packets** (§4.2: "initiate its own gather packet if the
//!   incoming gather packet is full"; §5.2: "the second packet is only
//!   injected when the first packet reaches the node, with no space
//!   left... the first node to encounter such a situation will initiate a
//!   new gather packet"): a node whose boarding attempt finds no space
//!   stages its own packet **immediately**.
//!
//! Two engineering details keep the multi-packet regime (16×16 meshes) at
//! exactly `gather_packets_per_row` packets instead of a flood:
//!
//! 1. **One-cycle staging latency**: the packet-format unit (Fig. 9) takes
//!    a cycle to assemble the staged packet before it can enter the
//!    router. Since link arrivals are processed before NI injection within
//!    a cycle, the replacement packet launched by the *first* starved node
//!    arrives at each downstream starved node exactly in time to board its
//!    payloads and cancel that node's own staged packet.
//! 2. **Cancel-on-board**: a staged packet is re-validated against the
//!    NI's pending count when its head is about to enter the router; if a
//!    passing packet collected everything in the meantime, the staged
//!    packet is dropped.
//!
//! ## Choosing δ (§5.2, Fig. 12)
//!
//! δ trades collection latency against packet count. `δ < κ` degenerates
//! to one packet per node (every NI times out before the initiator's
//! packet can arrive — the leftmost Fig. 12 point); the paper's plateau
//! sets `δ = (N−1)·κ` so the initiator's header can reach every node of
//! the row first. Our router charges the Table-1 link cycle explicitly,
//! so the equivalent plateau is `(N−1)·(κ+link) + κ` — the
//! `SimConfig::table1` default. Larger δ buys no further latency but
//! bounds the wait of an orphaned node (the §4.1 fault-tolerance reading;
//! exercised in `benches/ablations.rs`).
//!
//! The per-column fine-tuning hook of §4.1 ("δ can be fine-tuned further
//! for an individual router") is kept for the timeout itself:
//! `effective_delta(δ, x) = δ + x` staggers self-injection eastward, which
//! de-bursts the δ<κ regime and covers arbitration jitter.
//!
//! Gather collection is dataflow-independent: the OS mapping posts `n`
//! payloads per NI per round, the WS mapping `n/spread` pre-accumulated
//! sums ([`crate::dataflow::ws`]) — Algorithm 1 handles both unchanged.

use super::flit::{Coord, Flit, PacketType};

/// NI-side gather state for one router (shared by the n attached PEs —
//  the NI aggregates their payloads, Fig. 9).
#[derive(Debug, Clone)]
pub struct NiState {
    /// Payload slots waiting to be shipped (one slot per partial sum).
    pub pending: u32,
    /// Destination (row memory element) of the pending payloads.
    pub dst: Coord,
    /// Timeout armed?
    pub armed: bool,
    /// Cycle at which this NI injects its own packet (staging happens κ
    /// cycles earlier).
    pub deadline: u64,
    /// Hardwired initiator (leftmost node of the row) — injects at post
    /// time without waiting.
    pub is_initiator: bool,
    /// Own gather packet staged in the NI (packet-format unit of Fig. 9)
    /// but not yet entered into the router. Guards against double-staging
    /// when several full packets pass in a row.
    pub staged: bool,
    /// Rounds whose results are computed but cannot enter the NI yet: the
    /// payload queue of Fig. 9 holds one round (payload count, INA
    /// accumulation space); further rounds back up here until the active
    /// round's payloads leave (boarded / injected). This is the
    /// backpressure that turns network congestion into round stalls — the
    /// Δ_R / Δ_G the paper measures.
    pub backlog: std::collections::VecDeque<(u32, u64)>,
    /// Accumulation space of the pending payloads (`Collection::Ina`):
    /// a router may only *add* psums that belong to the same space, so a
    /// passing INA packet of a different round must not fold this NI. The
    /// network derives it from the round's scheduled post cycle, which is
    /// node-independent — nodes that skip rounds or activate late out of
    /// a backlog can never collide with another round's space.
    pub space: u64,
}

impl NiState {
    pub fn new() -> Self {
        NiState {
            pending: 0,
            dst: Coord::new(0, 0),
            armed: false,
            deadline: 0,
            is_initiator: false,
            staged: false,
            backlog: std::collections::VecDeque::new(),
            space: 0,
        }
    }
}

impl Default for NiState {
    fn default() -> Self {
        Self::new()
    }
}

/// Outcome of a gather head passing an NI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoardOutcome {
    /// Not a gather head / destination mismatch / nothing pending.
    NotApplicable,
    /// `n` payloads boarded; NI fully drained.
    BoardedAll(u32),
    /// `n` payloads boarded but some remain pending (packet filled up).
    BoardedPartial(u32),
    /// Packet had no space at all.
    Full,
}

/// What a passing head does with a transit NI's pending payloads —
/// gather packets *fill* empty slots (bounded by `ASpace`), INA packets
/// *accumulate* into existing words (unbounded, one ALU add per word).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoardMode {
    /// Algorithm 1 of the source paper: occupy payload slots, decrement
    /// `ASpace`, spill to a fresh packet when full.
    Fill,
    /// In-network accumulation (arXiv:2209.10056): add same-space psums
    /// into the packet's existing words. Requires `flit.space ==
    /// ni.space`; never runs out of room, so `Full`/`BoardedPartial`
    /// cannot occur.
    Accumulate,
}

/// The boarding-relevant view of a passing head flit, independent of the
/// flit representation: the event kernel assembles it from a
/// [`crate::noc::flit::CompactFlit`] plus its packet-table entry, the
/// wide-`Flit` wrapper [`try_board_mode`] straight from the flit's own
/// fields. `aspace` / `carried` alias the per-flit mutable occupancy the
/// boarding decision updates.
pub struct BoardFields<'a> {
    pub is_head: bool,
    pub ptype: PacketType,
    pub dst: Coord,
    pub space: u64,
    pub aspace: &'a mut u32,
    pub carried: &'a mut u32,
}

/// Shared boarding logic for gather (`BoardMode::Fill`, Algorithm 1) and
/// INA (`BoardMode::Accumulate`) packets: try to board `ni`'s pending
/// payloads onto the passing head `f`. Mutates `f.aspace` / `f.carried`
/// and `ni.pending`. Caller handles re-arming on `BoardedPartial` /
/// `Full` (Fill mode only).
pub fn board_fields(f: BoardFields, ni: &mut NiState, mode: BoardMode) -> BoardOutcome {
    let want = match mode {
        BoardMode::Fill => PacketType::Gather,
        BoardMode::Accumulate => PacketType::Ina,
    };
    // if ((F.FT = H) and (F.PT = G|I) and (F.Dst = P.Dst) and pending)
    if !f.is_head || f.ptype != want {
        return BoardOutcome::NotApplicable;
    }
    if ni.pending == 0 || f.dst != ni.dst {
        return BoardOutcome::NotApplicable;
    }
    match mode {
        BoardMode::Fill => {
            // if (F.ASpace >= sizeof(P)) then Load <- 1 ; F.ASpace -= sizeof(P)
            if *f.aspace == 0 {
                return BoardOutcome::Full;
            }
            let boarded = (*f.aspace).min(ni.pending);
            *f.aspace -= boarded;
            *f.carried += boarded;
            ni.pending -= boarded;
            if ni.pending == 0 {
                ni.armed = false;
                BoardOutcome::BoardedAll(boarded)
            } else {
                BoardOutcome::BoardedPartial(boarded)
            }
        }
        BoardMode::Accumulate => {
            // Psums of different rounds must not be added together.
            if f.space != ni.space {
                return BoardOutcome::NotApplicable;
            }
            let folded = ni.pending;
            *f.carried += folded;
            // `aspace` holds the packet's physical word count under INA;
            // accumulation adds in place. Every node of a round posts the
            // same width under the uniform drivers, keeping it constant;
            // when a randomized workload posts heterogeneous widths the
            // count widens in place WITHOUT growing the flit count — a
            // documented modeling approximation (a physical packet sized
            // for fewer words would need extra flits), acceptable because
            // same-space psums cover the same outputs and thus the same
            // width in any physically meaningful mapping.
            *f.aspace = (*f.aspace).max(folded);
            ni.pending = 0;
            ni.armed = false;
            BoardOutcome::BoardedAll(folded)
        }
    }
}

/// [`board_fields`] over a wide [`Flit`] — the frozen reference kernel's
/// entry point (and the unit-test surface for Algorithm 1).
pub fn try_board_mode(flit: &mut Flit, ni: &mut NiState, mode: BoardMode) -> BoardOutcome {
    board_fields(
        BoardFields {
            is_head: flit.is_head(),
            ptype: flit.ptype,
            dst: flit.dst,
            space: flit.space,
            aspace: &mut flit.aspace,
            carried: &mut flit.carried_payloads,
        },
        ni,
        mode,
    )
}

/// Algorithm 1: try to board `ni`'s pending payloads onto the passing
/// gather head `flit` (the `BoardMode::Fill` instantiation of
/// [`try_board_mode`]).
pub fn try_board(flit: &mut Flit, ni: &mut NiState) -> BoardOutcome {
    try_board_mode(flit, ni, BoardMode::Fill)
}

/// Effective timeout of the node at column `x` (per-router fine-tuning,
/// see module docs). Saturating: a sentinel δ of `u64::MAX` ("never time
/// out") must not wrap into an immediate expiry.
pub fn effective_delta(delta: u64, x: u16) -> u64 {
    delta.saturating_add(x as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::flit::{FlitType, PacketDesc};

    fn gather_head(aspace: u32, dst: Coord) -> Flit {
        let mut f = PacketDesc {
            id: 7,
            ptype: PacketType::Gather,
            src: Coord::new(0, 2),
            dst,
            len_flits: 3,
            aspace,
            space: 0,
            inject_cycle: 0,
            deliver_along_path: false,
            carried_payloads: 1,
        }
        .flit(0);
        f.ftype = FlitType::Head;
        f
    }

    fn ni(pending: u32, dst: Coord) -> NiState {
        NiState { pending, dst, armed: true, deadline: 100, ..NiState::new() }
    }

    #[test]
    fn boards_all_when_space_suffices() {
        let dst = Coord::new(8, 2);
        let mut f = gather_head(7, dst);
        let mut n = ni(4, dst);
        assert_eq!(try_board(&mut f, &mut n), BoardOutcome::BoardedAll(4));
        assert_eq!(f.aspace, 3);
        assert_eq!(f.carried_payloads, 5);
        assert_eq!(n.pending, 0);
        assert!(!n.armed, "drained NI must disarm its timeout");
    }

    #[test]
    fn partial_board_when_packet_nearly_full() {
        let dst = Coord::new(8, 2);
        let mut f = gather_head(2, dst);
        let mut n = ni(4, dst);
        assert_eq!(try_board(&mut f, &mut n), BoardOutcome::BoardedPartial(2));
        assert_eq!(f.aspace, 0);
        assert_eq!(n.pending, 2);
        assert!(n.armed, "NI with leftovers keeps its timeout armed");
    }

    #[test]
    fn full_packet_boards_nothing() {
        let dst = Coord::new(8, 2);
        let mut f = gather_head(0, dst);
        let mut n = ni(4, dst);
        assert_eq!(try_board(&mut f, &mut n), BoardOutcome::Full);
        assert_eq!(n.pending, 4);
    }

    #[test]
    fn destination_mismatch_is_ignored() {
        // Algorithm 1 line: if (F.Dst = P.Dst) then Load <- 1
        let mut f = gather_head(8, Coord::new(8, 2));
        let mut n = ni(4, Coord::new(8, 3)); // different row's memory
        assert_eq!(try_board(&mut f, &mut n), BoardOutcome::NotApplicable);
        assert_eq!(f.aspace, 8);
    }

    #[test]
    fn non_gather_packets_never_board() {
        let dst = Coord::new(8, 2);
        let mut f = gather_head(8, dst);
        f.ptype = PacketType::Unicast;
        let mut n = ni(4, dst);
        assert_eq!(try_board(&mut f, &mut n), BoardOutcome::NotApplicable);
    }

    #[test]
    fn body_flits_never_board() {
        // Boarding is decided on the head (Load latched for the body).
        let dst = Coord::new(8, 2);
        let mut f = gather_head(8, dst);
        f.ftype = FlitType::Body;
        let mut n = ni(4, dst);
        assert_eq!(try_board(&mut f, &mut n), BoardOutcome::NotApplicable);
    }

    #[test]
    fn effective_delta_staggers_eastward() {
        assert_eq!(effective_delta(39, 0), 39);
        assert!(effective_delta(39, 9) > effective_delta(39, 8));
    }

    #[test]
    fn effective_delta_saturates_near_u64_max() {
        // A sentinel δ of u64::MAX means "never time out"; the per-column
        // stagger must not wrap it into an immediate expiry.
        assert_eq!(effective_delta(u64::MAX, 0), u64::MAX);
        assert_eq!(effective_delta(u64::MAX, 15), u64::MAX);
        assert_eq!(effective_delta(u64::MAX - 4, 9), u64::MAX);
        assert_eq!(effective_delta(u64::MAX - 9, 9), u64::MAX);
    }

    fn ina_head(words: u32, space: u64, dst: Coord) -> Flit {
        let mut f = PacketDesc {
            id: 9,
            ptype: PacketType::Ina,
            src: Coord::new(0, 2),
            dst,
            len_flits: 2,
            aspace: words,
            space,
            inject_cycle: 0,
            deliver_along_path: false,
            carried_payloads: words,
        }
        .flit(0);
        f.ftype = FlitType::Head;
        f
    }

    #[test]
    fn accumulate_mode_folds_everything_without_capacity() {
        // INA has no ASpace limit: however many psums are pending, they
        // all fold — the add happens in place, the packet never grows.
        let dst = Coord::new(8, 2);
        let mut f = ina_head(4, 7, dst);
        let mut n = NiState { space: 7, ..ni(29, dst) };
        assert_eq!(try_board_mode(&mut f, &mut n, BoardMode::Accumulate),
                   BoardOutcome::BoardedAll(29));
        assert_eq!(f.carried_payloads, 4 + 29, "represented psums accumulate");
        assert_eq!(f.aspace, 29, "physical words widen to the larger side");
        assert_eq!(n.pending, 0);
        assert!(!n.armed);
    }

    #[test]
    fn accumulate_mode_respects_the_space_tag() {
        // Psums of different rounds must never be added together.
        let dst = Coord::new(8, 2);
        let mut f = ina_head(4, 7, dst);
        let mut n = NiState { space: 8, ..ni(4, dst) };
        assert_eq!(try_board_mode(&mut f, &mut n, BoardMode::Accumulate),
                   BoardOutcome::NotApplicable);
        assert_eq!(n.pending, 4);
        // Gather packets never fold via the accumulate path and vice versa.
        let mut g = gather_head(8, dst);
        let mut n2 = NiState { space: 0, ..ni(4, dst) };
        assert_eq!(try_board_mode(&mut g, &mut n2, BoardMode::Accumulate),
                   BoardOutcome::NotApplicable);
        let mut i = ina_head(4, 0, dst);
        assert_eq!(try_board_mode(&mut i, &mut n2, BoardMode::Fill),
                   BoardOutcome::NotApplicable);
    }

    #[test]
    fn timeout_firing_when_the_boarding_flit_arrives_boards_instead() {
        // δ chosen so the farthest node's deadline lands exactly on the
        // cycle the initiator's head arrives: `deliver_arrivals` runs
        // before `gather_timeouts` within a cycle, so boarding wins and
        // the node stages nothing. One cycle earlier (δ−1) the timeout
        // fires first — but the one-cycle staging latency lets the
        // arriving head drain the NI and cancel the staged packet, so the
        // row still emits exactly one packet either way.
        use crate::config::{Collection, SimConfig};
        use crate::noc::network::Network;
        let cfg = SimConfig::table1_8x8(1);
        let m = cfg.mesh_cols as u64;
        let per_hop = cfg.kappa() + cfg.link_latency;
        // Head enters the initiator's router at cycle 1 and reaches
        // column x at 1 + x·(κ+link); node x's deadline is δ + x.
        let same_cycle_delta = 1 + (per_hop - 1) * (m - 1);
        for (delta, want_expiries) in [(same_cycle_delta, 0), (same_cycle_delta - 1, 1)] {
            let mut c = cfg.clone();
            c.delta = delta;
            let mut net = Network::new(&c, Collection::Gather);
            for x in 0..c.mesh_cols {
                net.post_result(0, Coord::new(x as u16, 0), 1);
            }
            let ok = net.run_until(|n| n.payloads_delivered >= m, 100_000);
            assert!(ok, "δ={delta}: collection stalled");
            assert_eq!(
                net.stats.delta_expiries, want_expiries,
                "δ={delta}: deliver_arrivals/gather_timeouts ordering drifted"
            );
            assert_eq!(net.stats.packets_injected, 1,
                "δ={delta}: cancel-on-board must keep the row at one packet");
            assert_eq!(net.stats.gather_boards, m - 1);
        }
    }
}
