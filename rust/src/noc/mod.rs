//! Cycle-accurate mesh NoC substrate.
//!
//! This module is the reproduction of the cycle-accurate C++ simulator the
//! paper's evaluation runs on [38], extended with the paper's own
//! contributions: gather-supported routing (Algorithm 1, [`gather`]) and
//! mesh-borne operand multicast streams (the gather-only baseline of [27]).
//!
//! See [`network::Network`] for the simulator entry point. The cycle
//! kernel is event-driven (active-router set + calendar-queue schedules,
//! see the [`network`] module docs) and topology-polymorphic: the router
//! fabric — geometry, links, deterministic routing, VC classes — is the
//! [`topology::Topology`] trait (`Mesh2D` / `Torus2D` /
//! `ConcentratedMesh`). The pre-refactor kernel survives as
//! [`reference::ReferenceNetwork`] — frozen **mesh-only**, the golden
//! twin the equivalence suite and the hot-path bench compare `Mesh2D`
//! against.

pub mod buffer;
pub mod calendar;
pub mod faults;
pub mod flit;
pub mod gather;
pub mod network;
pub mod parallel;
pub mod probes;
pub mod reference;
pub mod router;
pub mod routing;
pub mod stats;
pub mod topology;

pub use faults::{DegradationReport, FaultPlan, FaultsConfig};
pub use flit::{CompactFlit, Coord, Flit, FlitType, PacketDesc, PacketId, PacketTable, PacketType};
pub use network::{Network, RunOutcome, StallReport, StreamEdge};
pub use probes::{Bottleneck, BottleneckStage, LinkRecord, ProbeReport, BUCKET_CYCLES};
pub use reference::{ReferenceNetwork, SimKernel};
pub use routing::{Algorithm, Port};
pub use stats::{BusStats, NetStats};
pub use topology::{BusAttachments, ConcentratedMesh, Fabric, Mesh2D, Topology, Torus2D};
