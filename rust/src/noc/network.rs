//! The cycle-accurate mesh network simulator — event-driven core.
//!
//! One [`Network`] owns every router ([`RouterState`]), the inter-router
//! links, the NI-side gather machinery ([`NiState`]) and the injection
//! sources. `step()` advances one clock; `run_until` / `run_until_idle`
//! drive it with idle fast-forwarding so compute-only phases between
//! traffic bursts cost nothing.
//!
//! The simulator is dataflow-agnostic: it moves whatever payload counts
//! and operand streams the active [`crate::dataflow::Dataflow`] mapping
//! posts ([`Network::post_result`] / [`Network::post_operand_stream`]) —
//! the OS and WS mappings drive this same substrate.
//!
//! ## The 4-stage router pipeline (§4.1, Fig. 7; Table 1: κ = 4)
//!
//! Each router implements the canonical input-queued VC pipeline:
//!
//! | stage | name                  | model                                      |
//! |-------|-----------------------|--------------------------------------------|
//! | RC    | route computation     | XY ([`route`]) on the buffered head flit    |
//! | VA    | VC allocation         | [`RouterState::allocate_out_vc`], one output VC held head→tail (wormhole) |
//! | SA    | switch allocation     | separable round-robin: one grant per output port and per input port/cycle |
//! | ST    | switch traversal      | flit leaves on the link; arrives `link_latency` cycles later |
//!
//! A head flit buffered at cycle `t` finishes RC+VA no earlier than
//! `t + κ − 2`, competes in SA from `t + κ − 1`, and traverses the switch
//! one cycle later — an uncontended head therefore spends exactly `κ`
//! cycles per router plus the link cycle, the `κ + link` per-hop latency
//! the zero-load tests pin. Body/tail flits inherit the head's route and
//! output VC and use only SA/ST; their idle RC/VA slots are what the
//! gather support borrows to fill payloads at zero latency cost
//! ([`super::gather`], Fig. 7 "Modified router pipeline").
//!
//! ## Credit flow control (§4.4, [34])
//!
//! Buffering is credit-based per VC: an upstream router holds one credit
//! per free slot of the downstream input VC ([`super::buffer::CreditTracker`]
//! inside [`RouterState::out_credits`]) and SA refuses a grant without a
//! credit.
//! A credit is consumed when the flit is placed on the link and refunded
//! one cycle after the downstream slot frees (`credit_refunds` batch, step
//! 1 below), closing the credit loop at `κ + 2·link` cycles. Ejection
//! ports (`Local`, and East on the memory column) sink unconditionally —
//! the memory ingest is never the bottleneck, matching §5.1 — and edge
//! injection ports (West/North operand sources) check buffer space
//! directly instead of holding credits. `VcBuffer::push` panics on
//! overflow, so any credit-protocol violation fails loudly in simulation.
//!
//! ## The active-router set
//!
//! The per-cycle phases below do **not** scan the whole `rows×cols` mesh:
//! a dense bitset (`active`, one bit per router, iterated in ascending
//! index order so arbitration and boarding order match a full scan
//! exactly) tracks the routers that may have work. The invariant is:
//!
//! > **a router outside the set has no work and can receive none without
//! > a wakeup** — no buffered flit, no queued or in-flight injector
//! > packet, no armed δ timeout with pending payloads, no backlogged
//! > round.
//!
//! Wakeups are exactly the events that create such work: a buffer write
//! (link arrival or local injection), an NI post activating or
//! backlogging a round, and an injector push. Credit refunds need no
//! wakeup: a flit blocked on credits is still buffered upstream, so the
//! upstream router never left the set. Routers are retired from the set
//! in one sweep at the end of each cycle (`retire_idle_routers`).
//! Under saturating traffic the set degenerates to "all routers" and the
//! kernel behaves like the classic full scan; in the common drain-tail
//! and gather-window phases it shrinks to the handful of routers that
//! still hold flits — the dominant cost before this rewrite (the frozen
//! pre-refactor kernel is kept in [`super::reference`] and the golden
//! suite pins bit-identical [`NetStats`] between the two).
//!
//! ## Event schedules and fast-forward
//!
//! Scheduled NI posts and operand streams live in two calendar queues
//! ([`super::calendar::Calendar`]) — O(1) per cycle instead of a
//! `BTreeMap` descent — and quiescence is an O(1) counter check
//! (`flits_active`, `busy_injectors`, `backlogged_nodes`). When the
//! network is quiescent, [`Network::run_until`] jumps the clock straight
//! to [`Network::next_event_cycle`] (earliest scheduled post, stream, or
//! armed δ expiry) instead of ticking. The jump is sound exactly because
//! quiescence means no component can make progress on its own: every
//! future state change is initiated by a scheduled event.
//!
//! ## Per-cycle ordering
//!
//! 1. apply credit refunds scheduled last cycle;
//! 2. deliver link arrivals (buffer writes) — gather boarding and INA
//!    NI-folds happen here, on head arrival, in the RC slot;
//! 3. apply scheduled NI posts / operand-stream injections for this cycle;
//! 4. VC allocation for routed head flits;
//! 5. switch allocation + traversal (this is where stream delivery and —
//!    under [`Collection::Ina`] — same-space packet *merges* happen:
//!    boarding in step 2 runs strictly before steps 6/7 so a boarded NI
//!    never stages a redundant packet in the same cycle);
//! 6. NI injection sources feed one flit each into their local buffers;
//! 7. gather/INA timeout staging (one-cycle packet assembly before entry);
//! 8. retire work-less routers from the active set.
//!
//! ## In-Network Accumulation ([`Collection::Ina`])
//!
//! INA reuses the gather machinery (δ timeouts, leftmost initiator,
//! cancel-on-board) but *adds* psums instead of appending them:
//!
//! * on head arrival (step 2) a transit NI's same-space pending psums are
//!   folded into the packet by the router ALU at zero latency — the
//!   accumulate analogue of Algorithm-1 boarding, with no `ASpace` limit;
//! * during switch allocation (step 5), two complete same-space packets
//!   requesting the same output port merge: the absorbed packet's flits
//!   are read out of its VC (buffer reads, upstream credits refunded in
//!   one batch, its output VC released) and its psums are added into the
//!   survivor's head. The absorbed flits never traverse the crossbar or
//!   the link — that is the traffic INA saves.
//!
//! `Flit::carried_payloads` keeps counting *represented* psums across
//! folds and merges (so payload conservation and the driver's completion
//! targets are collection-independent), while `Flit::aspace` holds the
//! packet's constant physical word count, which prices the ALU adds.
//!
//! ## Topology & memory elements (§5.1)
//!
//! Routers live at `(x, y)`, `x ∈ [0, cols)` eastward, `y ∈ [0, rows)`
//! southward; links, route decisions and VC-class restrictions come from
//! the [`Topology`] fabric built from `SimConfig::topology`
//! ([`super::topology`]): the paper's `Mesh2D` (bit-identical to the
//! pre-topology hardwired geometry), `Torus2D` (wraparound links for
//! unicast result traffic under a dateline VC rule) and
//! `ConcentratedMesh` (halved radix, `c` PEs per router). The global
//! memory of row `y` is the virtual node `(cols, y)` on every fabric:
//! packets routed to it leave the east edge and are sunk unconditionally
//! (the memory ingest is never the bottleneck, as in the paper). Operand
//! streams enter at the west edge (input activations, one per row) and
//! the north edge (filter weights, one per column) — either over the
//! fabric itself (`deliver_along_path` multicast wormhole streams, the
//! "gather-only" baseline architecture; these walk rows/columns without
//! wrapping on every fabric) or over the dedicated streaming buses of
//! `crate::streaming` (which bypass this module entirely).

use std::collections::VecDeque;
use std::sync::Arc;

use super::buffer::VcState;
use super::calendar::Calendar;
use super::faults::{DegradationReport, FaultPlan, FaultState, RetxEntry};
use super::flit::{CompactFlit, Coord, PacketDesc, PacketTable, PacketType};
use super::gather::{board_fields, effective_delta, BoardFields, BoardMode, BoardOutcome, NiState};
use super::parallel::{self, ParState};
use super::probes::{LinkProbes, ProbeReport, BUCKET_CYCLES};
use super::router::{refresh_vc_state, RouterState};
use super::routing::Port;
use super::stats::NetStats;
use super::topology::{self, Fabric, Topology};
use crate::config::{Collection, SimConfig};

/// A flit in flight on a link, due to be written into a buffer.
/// (`pub(super)`: the intra-layer parallel kernel's band mailboxes carry
/// these across the cycle barrier — see [`super::parallel`].)
#[derive(Debug)]
pub(super) struct Arrival {
    pub(super) router: usize,
    pub(super) port: Port,
    pub(super) vc: usize,
    pub(super) flit: CompactFlit,
}

/// An entry in an injection source's queue.
#[derive(Debug)]
pub(super) struct InjEntry {
    pub(super) desc: PacketDesc,
    /// Staged by the NI gather machinery: re-validated against the NI's
    /// pending count when the head is about to enter the router (cancel-on
    /// -board, see `noc::gather` module docs).
    pub(super) from_ni: bool,
    /// Earliest cycle the head may enter the router (the packet-format
    /// unit of Fig. 9 takes one cycle to assemble staged gather packets).
    pub(super) not_before: u64,
}

/// One injection source: feeds at most one flit per cycle into a single
/// input port of its router (the NI↔router bandwidth of Fig. 9).
#[derive(Debug, Default)]
pub(super) struct Injector {
    pub(super) queue: VecDeque<InjEntry>,
    /// In-progress packet: (desc, next flit seq, chosen VC).
    pub(super) cur: Option<(PacketDesc, u32, usize)>,
}

/// Where an operand stream enters the mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamEdge {
    /// Input-activation stream for row `y` (enters west, exits at the
    /// east-most PE column).
    Row(usize),
    /// Weight stream for column `x` (enters north, exits at the bottom
    /// row).
    Col(usize),
}

/// A deferred NI post: `payloads` partial sums become ready at a node.
#[derive(Debug, Clone, Copy)]
struct NiPost {
    node: usize,
    payloads: u32,
    dst: Coord,
    /// Accumulation space (INA): the scheduled post cycle. All NIs of a
    /// round are posted for the same cycle, so the cycle is a node-
    /// independent round id — psums posted for different cycles never
    /// share a space, even when some nodes skip rounds or activate late
    /// out of a backlog.
    space: u64,
}

/// A deferred operand-stream injection.
type StreamPost = (usize, Port, PacketDesc);

/// Why a bounded run ([`Network::run_until_outcome`]) returned.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunOutcome {
    /// The caller's predicate was satisfied.
    Satisfied,
    /// The caller's cycle bound was reached with the predicate unmet.
    Exhausted,
    /// The [`crate::config::SimConfig::max_cycles`] hard cap tripped
    /// before the caller's bound — the CI-hang guard.
    CycleCapExceeded { cap: u64 },
    /// The quiescence watchdog detected a wedged network: flits in
    /// flight, zero progress over a full window, nothing scheduled.
    Stalled(StallReport),
}

impl RunOutcome {
    /// Short human description (panic messages, analyze output).
    pub fn describe(&self) -> String {
        match self {
            RunOutcome::Satisfied => "satisfied".to_string(),
            RunOutcome::Exhausted => "cycle bound exhausted".to_string(),
            RunOutcome::CycleCapExceeded { cap } => {
                format!("SimConfig::max_cycles cap of {cap} exceeded")
            }
            RunOutcome::Stalled(r) => r.describe(),
        }
    }
}

/// Diagnostic snapshot taken when the quiescence watchdog fires: what is
/// stuck and what it is stuck on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StallReport {
    pub cycle: u64,
    /// Flits resident in buffers, on links, or in retransmission slots.
    pub stuck_flits: u64,
    /// Sample (up to 8) of live packet ids among the stuck flits.
    pub stuck_packets: Vec<u32>,
    /// Credit-blocked Active VCs at stall time (up to 16):
    /// (router x, router y, blocked output port, output VC).
    pub blocking_links: Vec<(u16, u16, Port, u8)>,
    pub busy_injectors: usize,
    pub backlogged_nodes: usize,
}

impl StallReport {
    pub fn describe(&self) -> String {
        let links = if self.blocking_links.is_empty() {
            "none".to_string()
        } else {
            self.blocking_links
                .iter()
                .map(|&(x, y, p, vc)| format!("{x}:{y}->{p:?} vc{vc}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        format!(
            "stalled at cycle {}: {} flits stuck (packets {:?}), credit-blocked links: {}, \
             busy injectors {}, backlogged nodes {}",
            self.cycle,
            self.stuck_flits,
            self.stuck_packets,
            links,
            self.busy_injectors,
            self.backlogged_nodes
        )
    }
}

/// The simulator.
pub struct Network {
    /// Shared configuration: sweeps construct hundreds of `Network`s from
    /// one config, so it is reference-counted instead of deep-cloned per
    /// instance ([`Network::shared`]).
    pub cfg: Arc<SimConfig>,
    pub collection: Collection,
    /// The router fabric: geometry, links and deterministic routing. The
    /// kernel asks it for every route decision, neighbor lookup and VC
    /// class; `Mesh2D` reproduces the pre-topology hardwired behavior
    /// bit-identically (pinned against the frozen reference kernel by the
    /// golden suite).
    topo: Arc<dyn Topology>,
    /// Enum-dispatched twin of `topo` for the per-flit hot path: `route`,
    /// `vc_class` and `neighbor` inline through it instead of paying two
    /// virtual calls per occupied VC per cycle. Built from the same
    /// config (`with_topology` asserts kind + dims agree), so the two
    /// views can never diverge.
    fabric: Fabric,
    cols: usize,
    rows: usize,
    vcs: usize,
    routers: Vec<RouterState<CompactFlit>>,
    ni: Vec<NiState>,
    injectors: Vec<Injector>,
    /// Ring buffer of link arrivals; slot 0 = current cycle.
    arrivals: VecDeque<Vec<Arrival>>,
    /// Credit refunds to apply at the start of the next cycle:
    /// (router, out port index, vc).
    credit_refunds: Vec<(usize, usize, usize)>,
    /// Reused buffer for `apply_credit_refunds`.
    credit_scratch: Vec<(usize, usize, usize)>,
    ni_posts: Calendar<NiPost>,
    stream_posts: Calendar<StreamPost>,
    /// Reused drain buffers for `apply_posts` (no steady-state allocation).
    ni_scratch: Vec<NiPost>,
    stream_scratch: Vec<StreamPost>,
    pub stats: NetStats,
    pub cycle: u64,
    /// Flits resident in buffers or on links.
    flits_active: u64,
    /// Result payloads delivered to the east-edge memory elements.
    pub payloads_delivered: u64,
    /// Tails of operand stream packets that finished their path.
    pub stream_tails_ejected: u64,
    /// Gather packets sunk at the memory.
    pub gather_packets_ejected: u64,
    /// Result (gather or unicast) packets sunk at the memory.
    pub result_packets_ejected: u64,
    pub last_eject_cycle: u64,
    /// Nodes with rounds waiting behind a busy NI (see `apply_ni_post`).
    backlogged_nodes: usize,
    /// Injection sources holding a queued or in-flight packet — the O(1)
    /// quiescence check the idle fast-forward relies on.
    busy_injectors: usize,
    /// Buffered flits per router — lets the VA/SA loops skip idle routers
    /// entirely (the dominant cost at low-to-medium load; see
    /// EXPERIMENTS.md §Perf).
    occupancy: Vec<u32>,
    /// Active-router set: bit `r` is set while router `r` may have work
    /// (see the module docs for the invariant). Iterated in ascending
    /// index order, so phase behavior is bit-identical to a full scan.
    active: Vec<u64>,
    /// Per-link observability counters (`cfg.probes`); `None` keeps the
    /// probe-off hot path allocation-free and bit-identical (the probes
    /// only ever observe — see [`super::probes`]).
    probes: Option<Box<LinkProbes>>,
    /// Intra-layer parallel kernel state (`cfg.intra_workers > 1` on a
    /// shardable grid — see [`super::parallel`]); `None` keeps the
    /// sequential hot path carrying nothing but this discriminant.
    par: Option<Box<ParState>>,
    /// Fault-injection runtime state (`cfg.faults`): the compiled plan,
    /// per-link retransmission slots and the poison set. `None` keeps
    /// every fault path untaken — the kernel is bit-identical to the
    /// fault-free simulator (pinned by `tests/fault_injection.rs`).
    faults: Option<Box<FaultState>>,
    /// Reused scratch for the arrival fault filter (no steady-state
    /// allocation while faults are enabled).
    fault_scratch: Vec<Arrival>,
    /// Fault degradation: result payloads that will never reach memory
    /// (census exclusions at post time + retry-exhausted packet drops).
    pub payloads_dropped: u64,
    /// Fault degradation: contributors excluded from a round's census
    /// (router down or memory unreachable at post time).
    pub missing_contributors: u64,
    /// Fault degradation: operand streams clamped short of their full
    /// path by a permanent fault on it.
    pub streams_truncated: u64,
    /// Fault degradation: operand streams dropped whole (entry router
    /// down, or a stream head lost to the retry budget).
    pub streams_dropped: u64,
    /// Interned packet-constant fields of every in-flight packet, indexed
    /// by [`CompactFlit::pid`]. Slots are interned exactly where
    /// `packets_injected` is counted and recycled when the last flit
    /// retires (tail ejection, or an INA merge absorbing the packet), so
    /// `packets.live() == packets_injected - packets_ejected - ina_merges`
    /// at every cycle boundary.
    packets: PacketTable,
}

const PORTS: usize = Port::COUNT;

/// Visit every router in the active set, in ascending index order — the
/// order a full `0..rows·cols` scan would use, which keeps arbitration,
/// boarding and pid-allocation order bit-identical to the pre-refactor
/// kernel. Each word is snapshotted before the body runs, so the body may
/// mutate `$net` freely (including re-marking already-visited routers);
/// bits set *during* iteration are picked up next cycle, which is sound
/// because no phase creates same-phase work on another router (see the
/// module docs). `continue`/`return` inside the body behave as in a plain
/// nested loop. This is the single copy of the bitset index math.
macro_rules! for_each_active {
    ($net:ident, $r:ident, $body:block) => {
        for w in 0..$net.active.len() {
            let mut bits = $net.active[w];
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let $r = (w << 6) + b;
                $body
            }
        }
    };
}

/// Outcome of screening one delivery attempt at the arrival fault filter.
enum Screened {
    /// Passes: hand the arrival to the normal delivery path.
    Deliver(Arrival),
    /// Park in the link's retransmission slot (transient window or a
    /// corruption within budget); the caller chooses front/back.
    Hold(RetxEntry),
    /// The flit was consumed (poison, dead link/router, or retry
    /// exhaustion); all accounting already happened.
    Dropped,
}

impl Network {
    pub fn new(cfg: &SimConfig, collection: Collection) -> Self {
        Self::shared(Arc::new(cfg.clone()), collection)
    }

    /// Construct a network sharing `cfg` with the caller (and with any
    /// sibling networks of the same sweep) instead of deep-cloning it.
    /// The router fabric is built from `cfg.topology`
    /// ([`topology::build`]); use [`Network::with_topology`] to inject a
    /// pre-built fabric.
    pub fn shared(cfg: Arc<SimConfig>, collection: Collection) -> Self {
        let topo = topology::build(&cfg);
        Self::with_topology(cfg, topo, collection)
    }

    /// Construct a network over an explicit [`Topology`] (which must span
    /// the config's router grid). The typed construction path is
    /// [`crate::api::ScenarioBuilder`]; this constructor — like
    /// [`Network::new`] — expects an already-validated config.
    pub fn with_topology(
        cfg: Arc<SimConfig>,
        topo: Arc<dyn Topology>,
        collection: Collection,
    ) -> Self {
        cfg.validate().expect("invalid SimConfig");
        assert_eq!(
            topo.dims(),
            (cfg.mesh_cols, cfg.mesh_rows),
            "topology grid does not match the config's router grid"
        );
        // The config key must agree with the injected fabric: validate()
        // enforces per-fabric requirements (e.g. the torus dateline rule
        // needs vcs >= 2) keyed on cfg.topology, and the analytic/
        // streaming closed forms read the key — a mismatched fabric would
        // dodge validation and silently model the wrong network.
        assert_eq!(
            topo.kind(),
            cfg.topology,
            "injected topology does not match cfg.topology"
        );
        let fabric = Fabric::from_config(&cfg);
        let (cols, rows, vcs) = (cfg.mesh_cols, cfg.mesh_rows, cfg.vcs);
        let mut routers = Vec::with_capacity(cols * rows);
        for y in 0..rows {
            for x in 0..cols {
                // Which output ports have a downstream router to credit?
                // Ports with no link (mesh edges; East at the east edge is
                // the memory sink) carry no tracker. On a torus every port
                // has a wrap link — the east-edge East tracker simply never
                // has credits consumed by ejecting flits.
                let here = Coord::new(x as u16, y as u16);
                let mut nb = [false; PORTS];
                for p in [Port::North, Port::South, Port::East, Port::West] {
                    nb[p.index()] = topo.neighbor(here, p).is_some();
                }
                nb[Port::Local.index()] = false; // ejection: NI always sinks
                routers.push(RouterState::new(here, vcs, cfg.buffer_depth, &nb));
            }
        }
        let mut ni: Vec<NiState> = (0..cols * rows).map(|_| NiState::new()).collect();
        for y in 0..rows {
            // Hardwired initiator: leftmost node of each row (§4.1).
            ni[y * cols].is_initiator = true;
        }
        let link_window = (cfg.link_latency + 2) as usize;
        // Compile the fault plan against the concrete fabric before the
        // topology handle moves into the struct.
        let faults = cfg
            .faults
            .as_ref()
            .map(|f| Box::new(FaultState::new(FaultPlan::build(f, topo.as_ref()))));
        Network {
            collection,
            topo,
            fabric,
            cols,
            rows,
            vcs,
            routers,
            ni,
            injectors: (0..cols * rows * PORTS).map(|_| Injector::default()).collect(),
            arrivals: (0..link_window).map(|_| Vec::new()).collect(),
            credit_refunds: Vec::new(),
            credit_scratch: Vec::new(),
            ni_posts: Calendar::new(),
            stream_posts: Calendar::new(),
            ni_scratch: Vec::new(),
            stream_scratch: Vec::new(),
            stats: NetStats::default(),
            cycle: 0,
            flits_active: 0,
            payloads_delivered: 0,
            stream_tails_ejected: 0,
            gather_packets_ejected: 0,
            result_packets_ejected: 0,
            last_eject_cycle: 0,
            backlogged_nodes: 0,
            busy_injectors: 0,
            occupancy: vec![0; cols * rows],
            active: vec![0; (cols * rows).div_ceil(64)],
            probes: cfg
                .probes
                .then(|| Box::new(LinkProbes::new(cols * rows, vcs))),
            par: ParState::for_grid(cfg.intra_workers, cols, rows),
            faults,
            fault_scratch: Vec::new(),
            payloads_dropped: 0,
            missing_contributors: 0,
            streams_truncated: 0,
            streams_dropped: 0,
            packets: PacketTable::new(),
            cfg,
        }
    }

    /// Snapshot the per-link observability counters, or `None` when the
    /// network was built with `cfg.probes == false`. Counters cover
    /// everything simulated so far; `ProbeReport::total_flits` equals
    /// `self.stats.link_traversals` bit-exactly at any cycle boundary.
    pub fn probe_report(&self) -> Option<ProbeReport<'_>> {
        self.probes.as_ref().map(|p| {
            p.report(self.topo.as_ref(), self.cols as u16, self.rows as u16, self.cycle)
        })
    }

    #[inline]
    fn node_idx(&self, c: Coord) -> usize {
        c.y as usize * self.cols + c.x as usize
    }

    /// Memory element coordinate for row `y` (virtual east column).
    pub fn memory_of_row(&self, y: usize) -> Coord {
        Coord::new(self.cols as u16, y as u16)
    }

    /// Is a hop out of `out_port` at `here` toward `dst` an ejection
    /// (unconditional sink, no credits, no VC class)? Local always; East
    /// at the east-edge column when the destination is the row memory
    /// element. The single copy of this predicate — VC allocation (class
    /// selection) and `grant` (eject vs forward, credit consumption) must
    /// agree on it or a flit could be classed as a link hop yet ejected,
    /// or forwarded over a torus wrap link instead of sunk at memory.
    #[inline]
    fn is_memory_ejection(&self, here: Coord, out_port: Port, dst: Coord) -> bool {
        self.is_memory_ejection_flag(here, out_port, dst.x as usize >= self.cols)
    }

    /// [`Network::is_memory_ejection`] with the `dst.x >= cols` test
    /// pre-computed — the grant path reads it off the flit's cached
    /// `mem_dst` flag instead of fetching `dst` from the packet table.
    #[inline]
    fn is_memory_ejection_flag(&self, here: Coord, out_port: Port, mem_dst: bool) -> bool {
        out_port == Port::Local
            || (out_port == Port::East && here.x as usize + 1 == self.cols && mem_dst)
    }

    // ------------------------------------------------------------------
    // Active-set and quiescence bookkeeping
    // ------------------------------------------------------------------

    /// Wake a router: it gained work (buffer write, injector push, NI
    /// activation or backlog) and must be visited by the phase loops.
    #[inline]
    fn mark_active(&mut self, router: usize) {
        self.active[router >> 6] |= 1u64 << (router & 63);
    }

    /// The active-set invariant, evaluated for one router: any buffered
    /// flit, injector work, armed δ timeout with pending payloads, or
    /// backlogged round keeps it in the set.
    fn router_has_work(&self, r: usize) -> bool {
        if self.occupancy[r] > 0 {
            return true;
        }
        let base = r * PORTS;
        for inj in &self.injectors[base..base + PORTS] {
            if inj.cur.is_some() || !inj.queue.is_empty() {
                return true;
            }
        }
        let ni = &self.ni[r];
        (ni.armed && ni.pending > 0) || !ni.backlog.is_empty()
    }

    /// End-of-cycle sweep: drop routers that no longer satisfy
    /// `router_has_work` from the active set. (The one bitset walk not on
    /// `for_each_active!`: it rewrites each word as it goes.)
    fn retire_idle_routers(&mut self) {
        for w in 0..self.active.len() {
            let mut bits = self.active[w];
            let mut keep = bits;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                if !self.router_has_work((w << 6) + b) {
                    keep &= !(1u64 << b);
                }
            }
            self.active[w] = keep;
        }
    }

    /// Enqueue a packet on an injection source, maintaining the busy
    /// counter and the active set.
    fn push_injector(&mut self, ii: usize, entry: InjEntry) {
        let inj = &mut self.injectors[ii];
        if inj.cur.is_none() && inj.queue.is_empty() {
            self.busy_injectors += 1;
        }
        inj.queue.push_back(entry);
        self.mark_active(ii / PORTS);
    }

    /// Any NI holding an armed δ timeout with pending payloads? Armed NIs
    /// are always in the active set, so only it is scanned.
    fn has_armed_pending(&self) -> bool {
        for_each_active!(self, r, {
            let ni = &self.ni[r];
            if ni.armed && ni.pending > 0 {
                return true;
            }
        });
        false
    }

    // ------------------------------------------------------------------
    // Scheduling API (used by the round driver)
    // ------------------------------------------------------------------

    /// Schedule `payloads` partial sums to become ready at `node` at cycle
    /// `at`, destined for the row memory element.
    pub fn post_result(&mut self, at: u64, node: Coord, payloads: u32) {
        assert!(at >= self.cycle, "cannot post results in the past");
        let dst = self.memory_of_row(node.y as usize);
        let idx = self.node_idx(node);
        self.ni_posts.push(at, NiPost { node: idx, payloads, dst, space: at });
    }

    /// Schedule an operand stream of `words` payload words to enter the
    /// mesh at `edge` at cycle `at` (gather-only architecture). The stream
    /// is one multicast wormhole packet that delivers a copy of every flit
    /// to each router it traverses.
    pub fn post_operand_stream(&mut self, at: u64, edge: StreamEdge, words: u64) {
        assert!(at >= self.cycle, "cannot post streams in the past");
        let ppf = self.cfg.payloads_per_flit() as u64;
        let body = words.div_ceil(ppf).max(1);
        let (router, port, mut dst) = match edge {
            StreamEdge::Row(y) => (
                self.node_idx(Coord::new(0, y as u16)),
                Port::West,
                Coord::new(self.cols as u16 - 1, y as u16),
            ),
            StreamEdge::Col(x) => (
                self.node_idx(Coord::new(x as u16, 0)),
                Port::North,
                Coord::new(x as u16, self.rows as u16 - 1),
            ),
        };
        let src = match edge {
            StreamEdge::Row(y) => Coord::new(0, y as u16),
            StreamEdge::Col(x) => Coord::new(x as u16, 0),
        };
        // Multicast streams cannot reroute (their hardwired straight path
        // IS the delivery pattern): a permanent fault on the path clamps
        // the stream to the last healthy router, and a dead entry router
        // drops the whole stream. Transient faults are instead ridden out
        // by the retransmission machinery.
        if let Some(fs) = self.faults.as_deref() {
            if fs.plan.reroutes {
                let plan = &fs.plan;
                if plan.router_down[router] {
                    self.streams_dropped += 1;
                    return;
                }
                let step_port = match edge {
                    StreamEdge::Row(_) => Port::East,
                    StreamEdge::Col(_) => Port::South,
                };
                let (mut cx, mut cy) = (src.x as usize, src.y as usize);
                while (cx as u16, cy as u16) != (dst.x, dst.y) {
                    let ridx = cy * self.cols + cx;
                    if plan.link_down[ridx * PORTS + step_port.index()] {
                        break;
                    }
                    let (nx, ny) = match step_port {
                        Port::East => (cx + 1, cy),
                        _ => (cx, cy + 1),
                    };
                    if plan.router_down[ny * self.cols + nx] {
                        break;
                    }
                    cx = nx;
                    cy = ny;
                }
                let clamped = Coord::new(cx as u16, cy as u16);
                if clamped != dst {
                    self.streams_truncated += 1;
                    dst = clamped;
                }
            }
        }
        let desc = PacketDesc {
            id: 0, // interned (and assigned a table slot) when the post fires
            ptype: PacketType::Multicast,
            src,
            dst,
            len_flits: (1 + body) as u32,
            aspace: 0,
            space: 0,
            inject_cycle: at,
            deliver_along_path: true,
            carried_payloads: 0,
        };
        self.stream_posts.push(at, (router, port, desc));
    }

    /// Lowest cycle at which something is scheduled to happen, given an
    /// otherwise idle network (for fast-forwarding).
    pub fn next_event_cycle(&self) -> Option<u64> {
        let mut next: Option<u64> = None;
        let mut consider = |c: u64| {
            next = Some(next.map_or(c, |n: u64| n.min(c)));
        };
        if let Some(c) = self.ni_posts.next_cycle() {
            consider(c);
        }
        if let Some(c) = self.stream_posts.next_cycle() {
            consider(c);
        }
        // Armed δ timers live only on active routers.
        for_each_active!(self, r, {
            let ni = &self.ni[r];
            if ni.armed && ni.pending > 0 {
                consider(ni.deadline.saturating_sub(self.cfg.kappa()).max(self.cycle + 1));
            }
        });
        next
    }

    /// True when no flit is in flight and no injector holds work. O(1):
    /// the counters are maintained at every mutation site.
    pub fn quiescent(&self) -> bool {
        self.flits_active == 0 && self.backlogged_nodes == 0 && self.busy_injectors == 0
    }

    /// Advance until `pred` holds or `max_cycle` is reached. Returns true
    /// if the predicate was satisfied. Fast-forwards through idle gaps:
    /// with the network quiescent, the clock jumps straight to the next
    /// scheduled post, stream, or armed δ expiry.
    /// ([`Network::run_until_outcome`] is the typed form; this wrapper
    /// folds every non-satisfied outcome to `false`.)
    pub fn run_until(&mut self, pred: impl FnMut(&Network) -> bool, max_cycle: u64) -> bool {
        matches!(self.run_until_outcome(pred, max_cycle), RunOutcome::Satisfied)
    }

    /// Cycles of zero kernel progress (while non-quiescent, with no
    /// future event pending) after which the watchdog declares a stall.
    pub const STALL_WINDOW: u64 = 10_000;

    /// Advance until `pred` holds, reporting *why* the run ended. The
    /// effective bound is `min(max_cycle, cfg.max_cycles)`: tripping the
    /// config cap is [`RunOutcome::CycleCapExceeded`], tripping the
    /// caller's own bound is [`RunOutcome::Exhausted`]. A non-quiescent
    /// network that makes no progress for [`Self::STALL_WINDOW`] cycles
    /// with nothing scheduled (no calendar event, no armed δ, no held
    /// retransmission waiting on a future cycle) is a wedge: the
    /// watchdog stops stepping and returns [`RunOutcome::Stalled`] with
    /// a structured diagnostic instead of spinning to the bound.
    pub fn run_until_outcome(
        &mut self,
        mut pred: impl FnMut(&Network) -> bool,
        max_cycle: u64,
    ) -> RunOutcome {
        let bound = max_cycle.min(self.cfg.max_cycles);
        let mut marker = self.progress_marker();
        let mut marker_cycle = self.cycle;
        while self.cycle < bound {
            if pred(self) {
                return RunOutcome::Satisfied;
            }
            if self.quiescent() {
                match self.next_event_cycle() {
                    Some(c) if c > self.cycle => self.cycle = c,
                    Some(_) => {}
                    None => {
                        return if pred(self) {
                            RunOutcome::Satisfied
                        } else {
                            RunOutcome::Exhausted
                        };
                    }
                }
            }
            self.step();
            let m = self.progress_marker();
            if m != marker {
                marker = m;
                marker_cycle = self.cycle;
            } else if !self.quiescent()
                && self.cycle - marker_cycle >= Self::STALL_WINDOW
                && !self.has_future_event()
            {
                return RunOutcome::Stalled(self.stall_report());
            }
        }
        if pred(self) {
            RunOutcome::Satisfied
        } else if bound < max_cycle {
            RunOutcome::CycleCapExceeded { cap: bound }
        } else {
            RunOutcome::Exhausted
        }
    }

    /// Drain everything currently scheduled; returns false on `max_cycle`
    /// overrun (treated by callers as a deadlock/livelock failure).
    pub fn run_until_idle(&mut self, max_cycle: u64) -> bool {
        matches!(self.run_until_idle_outcome(max_cycle), RunOutcome::Satisfied)
    }

    /// [`Network::run_until_idle`] with the typed outcome (cap overruns
    /// and watchdog stalls carry their diagnostics).
    pub fn run_until_idle_outcome(&mut self, max_cycle: u64) -> RunOutcome {
        self.run_until_outcome(
            |n| {
                n.quiescent()
                    && n.ni_posts.is_empty()
                    && n.stream_posts.is_empty()
                    && !n.has_armed_pending()
            },
            max_cycle,
        )
    }

    /// Monotone counter that advances whenever the kernel does anything
    /// observable — a buffer write or read, an SA grant, a fault drop or
    /// a retransmission. The watchdog compares it across cycles.
    fn progress_marker(&self) -> u64 {
        self.stats.sa_grants
            + self.stats.buffer_writes
            + self.stats.buffer_reads
            + self.stats.flits_dropped
            + self.stats.retransmissions
    }

    /// Is anything scheduled to happen after the current cycle (a
    /// calendar post, an armed δ expiry, or a held retransmission
    /// waiting out its hold-off / transient window)? The watchdog defers
    /// to these: waiting is not a wedge.
    fn has_future_event(&self) -> bool {
        if let Some(fs) = self.faults.as_deref() {
            if fs.pending_future_replay(self.cycle) {
                return true;
            }
        }
        self.next_event_cycle().is_some()
    }

    /// Snapshot the wedge for [`RunOutcome::Stalled`].
    fn stall_report(&self) -> StallReport {
        let mut stuck_packets: Vec<u32> = Vec::new();
        let mut blocking_links: Vec<(u16, u16, Port, u8)> = Vec::new();
        for_each_active!(self, ridx, {
            let r = &self.routers[ridx];
            let mut mask = r.nonempty_mask;
            while mask != 0 {
                let idx = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                if let Some(f) = r.inputs[idx].front() {
                    if stuck_packets.len() < 8 && !stuck_packets.contains(&f.pid) {
                        stuck_packets.push(f.pid);
                    }
                }
                if let VcState::Active { out_port, out_vc } = r.inputs[idx].state {
                    if blocking_links.len() < 16 {
                        if let Some(ct) = &r.out_credits[out_port] {
                            if !ct.available(out_vc) {
                                blocking_links.push((
                                    r.coord.x,
                                    r.coord.y,
                                    Port::from_index(out_port),
                                    out_vc as u8,
                                ));
                            }
                        }
                    }
                }
            }
        });
        StallReport {
            cycle: self.cycle,
            stuck_flits: self.flits_active,
            stuck_packets,
            blocking_links,
            busy_injectors: self.busy_injectors,
            backlogged_nodes: self.backlogged_nodes,
        }
    }

    /// Test/diagnostic hook: drain every credit the router at `node`
    /// holds toward `port`, modelling a downstream that stopped
    /// refunding (a wedged neighbor). The watchdog suite hand-builds a
    /// stall with it; the kernel never calls it.
    pub fn drain_credits_for_test(&mut self, node: Coord, port: Port) {
        let idx = self.node_idx(node);
        let vcs = self.vcs;
        if let Some(ct) = self.routers[idx].out_credits[port.index()].as_mut() {
            for vc in 0..vcs {
                while ct.available(vc) {
                    ct.consume(vc);
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // The clock
    // ------------------------------------------------------------------

    pub fn step(&mut self) {
        if self.par.is_some() {
            self.step_parallel();
            return;
        }
        self.apply_credit_refunds();
        self.deliver_arrivals();
        self.apply_posts();
        self.vc_allocate();
        self.switch_allocate();
        self.feed_injectors();
        self.gather_timeouts();
        self.drain_backlogs();
        self.retire_idle_routers();
        self.cycle += 1;
        self.stats.cycles_simulated = self.cycle;
    }

    /// One clock under the intra-layer parallel kernel
    /// (`cfg.intra_workers > 1`). The phase order matches [`Network::step`]
    /// exactly; the two band-parallel sections — link delivery with
    /// gather boarding / INA folds, and fused VA + SA — fan out over
    /// contiguous row bands and merge their deferred effects in ascending
    /// band order at the per-cycle barrier, which keeps every observable
    /// bit-identical to the sequential kernel (see [`super::parallel`]).
    fn step_parallel(&mut self) {
        self.apply_credit_refunds();
        self.deliver_arrivals_parallel();
        self.apply_posts();
        self.va_sa_parallel();
        self.feed_injectors();
        self.gather_timeouts();
        self.drain_backlogs();
        self.retire_idle_routers();
        self.cycle += 1;
        self.stats.cycles_simulated = self.cycle;
    }

    /// Band-parallel `deliver_arrivals`: the cycle's arrival batch is
    /// partitioned by destination band (per-band relative order = batch
    /// order; arrivals to different bands touch disjoint state, so
    /// cross-band interleaving is unobservable), each band delivers
    /// concurrently, and the deferred effects merge at the barrier.
    fn deliver_arrivals_parallel(&mut self) {
        let mut par = self.par.take().expect("parallel step without ParState");
        let mut batch = self.arrivals.pop_front().expect("arrival ring underflow");
        // Fault screening happens here, on the owner thread, BEFORE the
        // band partition: every retransmission, drop and poison decision
        // is made in the same order as the sequential kernel.
        if self.faults.is_some() {
            self.filter_faults(&mut batch);
        }
        for a in batch.drain(..) {
            let b = par.band_of(a.router);
            par.inboxes[b].push(a);
        }
        self.arrivals.push_back(batch);
        {
            let shared = parallel::Shared {
                cfg: &self.cfg,
                fabric: self.fabric,
                packets: &self.packets,
                collection: self.collection,
                cols: self.cols,
                vcs: self.vcs,
                cycle: self.cycle,
                active: &self.active,
                faults: self.faults.as_deref().map(|fs| &fs.plan),
            };
            // Deliver records no probe counters (both record sites live
            // in SA/grant), so no band probe views are built here.
            let mut bands = parallel::make_bands(
                &par.bands,
                &mut self.routers,
                &mut self.ni,
                &mut self.injectors,
                &mut self.occupancy,
                None,
            );
            parallel::run_deliver(&shared, &mut bands, &mut par.effects, &mut par.inboxes);
        }
        self.absorb_band_effects(&mut par.effects);
        self.par = Some(par);
    }

    /// Band-parallel fused `vc_allocate` + `switch_allocate`: each worker
    /// runs VA then SA over its band's active routers (neither pass reads
    /// another router's state); grants defer forwarded flits, credit
    /// refunds and counters through the band mailbox.
    fn va_sa_parallel(&mut self) {
        let mut par = self.par.take().expect("parallel step without ParState");
        {
            let shared = parallel::Shared {
                cfg: &self.cfg,
                fabric: self.fabric,
                packets: &self.packets,
                collection: self.collection,
                cols: self.cols,
                vcs: self.vcs,
                cycle: self.cycle,
                active: &self.active,
                faults: self.faults.as_deref().map(|fs| &fs.plan),
            };
            let mut bands = parallel::make_bands(
                &par.bands,
                &mut self.routers,
                &mut self.ni,
                &mut self.injectors,
                &mut self.occupancy,
                self.probes.as_deref_mut(),
            );
            parallel::run_va_sa(&shared, &mut bands, &mut par.effects);
        }
        self.absorb_band_effects(&mut par.effects);
        self.par = Some(par);
    }

    /// Merge the per-band deferred effects in ascending band order — the
    /// order a sequential ascending-router-index scan would have produced
    /// them — keeping every counter, forwarded flit, credit refund and
    /// probe series bucket bit-identical to the sequential kernel.
    fn absorb_band_effects(&mut self, effects: &mut [parallel::Effects]) {
        let delay = (1 + self.cfg.link_latency) as usize;
        let bucket = self.cycle / BUCKET_CYCLES;
        for fx in effects.iter_mut() {
            // A band delta leaves `cycles_simulated` at 0, so merge's max
            // keeps the network's value untouched.
            self.stats.merge(&fx.stats);
            self.flits_active -= fx.flits_active_sub;
            self.payloads_delivered += fx.payloads_delivered;
            self.stream_tails_ejected += fx.stream_tails_ejected;
            self.gather_packets_ejected += fx.gather_packets_ejected;
            self.result_packets_ejected += fx.result_packets_ejected;
            if fx.tail_ejected {
                self.last_eject_cycle = self.cycle;
            }
            self.busy_injectors += fx.busy_injectors_add;
            for &r in fx.wakes.iter() {
                self.mark_active(r);
            }
            // Deferred packet-table retires (ejections + INA absorbs of
            // this band). Ascending band order replays the exact global
            // release sequence the sequential SA scan would have produced,
            // so the free list — and therefore every recycled pid — is
            // bit-identical to the sequential kernel.
            for &(pid, flits) in fx.pid_releases.iter() {
                self.packets.release(pid, flits);
            }
            self.credit_refunds.append(&mut fx.credit_refunds);
            self.arrivals[delay - 1].append(&mut fx.arrivals_out);
            if let Some(p) = self.probes.as_mut() {
                p.bump_series(bucket, fx.series_flits);
            }
            fx.reset();
        }
    }

    fn apply_credit_refunds(&mut self) {
        // Swap-with-scratch keeps the Vec's capacity across cycles (the
        // allocator was ~1/3 of the cycle cost before; EXPERIMENTS §Perf).
        // No wakeup here: a refund only matters to a router still holding
        // the blocked flit, which therefore never left the active set.
        std::mem::swap(&mut self.credit_refunds, &mut self.credit_scratch);
        for &(router, out_port, vc) in &self.credit_scratch {
            if let Some(ct) = self.routers[router].out_credits[out_port].as_mut() {
                ct.refund(vc, self.cfg.buffer_depth);
            }
        }
        self.credit_scratch.clear();
    }

    // ------------------------------------------------------------------
    // Fault injection: the arrival filter
    // ------------------------------------------------------------------

    /// Arrival-side fault filter, run by BOTH kernels on the owner thread
    /// before the cycle's batch is delivered (sequential) or partitioned
    /// into bands (parallel) — which is what keeps every fault decision
    /// bit-identical at any worker count. Phase 1 pumps due
    /// retransmission slots in ascending link id (replayed flits
    /// re-present themselves ahead of the fresh batch, preserving
    /// per-link flit order); phase 2 screens each fresh arrival for
    /// poison, dead links/routers, transient windows and corruption.
    /// Never called without `cfg.faults`.
    fn filter_faults(&mut self, batch: &mut Vec<Arrival>) {
        let Some(mut fs) = self.faults.take() else { return };
        let cycle = self.cycle;
        let mut out = std::mem::take(&mut self.fault_scratch);
        out.clear();
        // Phase 1: pump at most one due flit per link (the single
        // retransmission slot's replay bandwidth), ascending link id.
        let mut k = 0;
        while k < fs.active_links.len() {
            let link = fs.active_links[k];
            let due = fs.retx[link].front().is_some_and(|e| e.due <= cycle);
            if !due {
                k += 1;
                continue;
            }
            let e = fs.retx[link].pop_front().expect("due link with empty retx queue");
            let attempt = e.attempt;
            let a = Arrival {
                router: e.router as usize,
                port: e.port,
                vc: e.vc as usize,
                flit: e.flit,
            };
            match self.screen_delivery(&mut fs, a, attempt, link) {
                Screened::Deliver(a) => {
                    if attempt > 0 {
                        // A replay that finally went through. Probe
                        // mirror uses the sender-side link id.
                        self.stats.retransmissions += 1;
                        let here = self.routers[a.router].coord;
                        let up = self.fabric.neighbor(here, a.port);
                        if let (Some(up), Some(p)) = (up, self.probes.as_mut()) {
                            let up_idx = up.y as usize * self.cols + up.x as usize;
                            p.record_retransmission(up_idx, a.port.opposite().index());
                        }
                    }
                    out.push(a);
                }
                // Re-held (transient still open, or corrupted again):
                // back to the front, order preserved.
                Screened::Hold(en) => fs.retx[link].push_front(en),
                Screened::Dropped => {}
            }
            if fs.retx[link].is_empty() {
                fs.active_links.remove(k);
            } else {
                k += 1;
            }
        }
        // Phase 2: fresh arrivals, batch order.
        for a in batch.drain(..) {
            let link = a.router * PORTS + a.port.index();
            if !fs.retx[link].is_empty() {
                // Earlier flits of this link are still held: queue behind
                // them (FIFO per link keeps wormhole order).
                fs.retx[link].push_back(RetxEntry {
                    router: a.router as u32,
                    port: a.port,
                    vc: a.vc as u8,
                    flit: a.flit,
                    attempt: 0,
                    due: cycle,
                });
                continue;
            }
            match self.screen_delivery(&mut fs, a, 0, link) {
                Screened::Deliver(a) => out.push(a),
                Screened::Hold(en) => {
                    fs.retx[link].push_back(en);
                    fs.mark_active(link);
                }
                Screened::Dropped => {}
            }
        }
        std::mem::swap(batch, &mut out);
        self.fault_scratch = out;
        self.faults = Some(fs);
    }

    /// Screen one delivery attempt of one flit over one receiver-side
    /// link. `attempt` counts failed attempts so far (0 = fresh).
    fn screen_delivery(
        &mut self,
        fs: &mut FaultState,
        a: Arrival,
        attempt: u32,
        link: usize,
    ) -> Screened {
        let pid = a.flit.pid;
        // Poisoned packet: the head already died; every surviving flit
        // drops at its next delivery point.
        if fs.is_poisoned(pid) {
            self.drop_flit(fs, &a);
            return Screened::Dropped;
        }
        // Permanently dead link or receiving router: the flit is lost.
        // Its head poisons the packet so the body follows it down.
        if fs.plan.link_dead_recv[link] || fs.plan.router_down[a.router] {
            self.kill_packet(fs, &a);
            self.drop_flit(fs, &a);
            return Screened::Dropped;
        }
        // Transient window: hold to the window end; no attempt charged
        // (the link was down, the flit was never exposed to corruption).
        if let Some(end) = fs.plan.transient_until(link, self.cycle) {
            return Screened::Hold(RetxEntry {
                router: a.router as u32,
                port: a.port,
                vc: a.vc as u8,
                flit: a.flit,
                attempt,
                due: end,
            });
        }
        // Corruption roll for this attempt. Heads carry the retry
        // budget; body/tail flits replay until their (per-attempt
        // decorrelated) roll passes — wormhole-safe because the head
        // crossed every link first.
        if fs.plan.corrupts(pid, a.flit.seq, link, attempt) {
            self.stats.flits_corrupted += 1;
            let next = attempt + 1;
            if a.flit.is_head() && next > fs.plan.retry_budget {
                self.stats.retries_exhausted += 1;
                self.kill_packet(fs, &a);
                self.drop_flit(fs, &a);
                return Screened::Dropped;
            }
            let due = self.cycle + fs.plan.holdoff(next);
            return Screened::Hold(RetxEntry {
                router: a.router as u32,
                port: a.port,
                vc: a.vc as u8,
                flit: a.flit,
                attempt: next,
                due,
            });
        }
        Screened::Deliver(a)
    }

    /// Poison a packet whose head flit is being dropped, with the
    /// packet-level degradation accounting. No-op for non-head flits
    /// (their packet was poisoned when the head died).
    fn kill_packet(&mut self, fs: &mut FaultState, a: &Arrival) {
        if !a.flit.is_head() {
            return;
        }
        fs.poison(a.flit.pid);
        self.stats.packets_dropped += 1;
        if a.flit.mem_dst() {
            // Result payloads ride the head; they will never reach the
            // row memory now.
            self.payloads_dropped += a.flit.carried_payloads as u64;
        }
        if a.flit.ptype() == PacketType::Multicast {
            self.streams_dropped += 1;
        }
    }

    /// Discard one flit at a delivery point: count it, retire it from
    /// the packet table, and refund the upstream credit its buffer slot
    /// reservation was holding (held flits keep their credit; dropped
    /// flits give it back). Unpoisons the pid once its last flit is gone
    /// so a recycled table slot never inherits stale poison.
    fn drop_flit(&mut self, fs: &mut FaultState, a: &Arrival) {
        self.stats.flits_dropped += 1;
        self.flits_active -= 1;
        let here = self.routers[a.router].coord;
        if let Some(up) = self.neighbour(here, a.port) {
            let up_idx = self.node_idx(up);
            self.credit_refunds.push((up_idx, a.port.opposite().index(), a.vc));
        }
        let pid = a.flit.pid;
        self.packets.release(pid, 1);
        if !self.packets.is_live(pid) {
            fs.unpoison(pid);
        }
    }

    /// The fabric's deterministic route, overridden by the fault plan's
    /// healthy-subgraph tables when any link/router is permanently down.
    /// Multicast streams keep their hardwired path (they were clamped at
    /// post time); an unreachable destination falls back to the fabric
    /// route — the flit dies at the dead link's arrival filter, and the
    /// watchdog reports it if it wedges instead.
    #[inline]
    fn route_with_faults(&self, ptype: PacketType, ridx: usize, here: Coord, dst: Coord) -> Port {
        if let Some(fs) = self.faults.as_deref() {
            if fs.plan.reroutes && ptype != PacketType::Multicast {
                if let Some(p) = fs.plan.route(ridx, dst) {
                    return p;
                }
            }
        }
        self.fabric.route(ptype, here, dst)
    }

    /// Degradation summary, `Some` exactly when faults are configured
    /// (all-zero counters report a degradation-free faulted run).
    pub fn degradation_report(&self) -> Option<DegradationReport> {
        self.faults.as_ref().map(|_| DegradationReport {
            missing_contributors: self.missing_contributors,
            payloads_dropped: self.payloads_dropped,
            packets_dropped: self.stats.packets_dropped,
            flits_dropped: self.stats.flits_dropped,
            flits_corrupted: self.stats.flits_corrupted,
            retransmissions: self.stats.retransmissions,
            retries_exhausted: self.stats.retries_exhausted,
            detour_hops: self.stats.detour_hops,
            streams_truncated: self.streams_truncated,
            streams_dropped: self.streams_dropped,
        })
    }

    fn deliver_arrivals(&mut self) {
        let mut batch = self.arrivals.pop_front().expect("arrival ring underflow");
        if self.faults.is_some() {
            self.filter_faults(&mut batch);
        }
        for Arrival { router, port, vc, mut flit } in batch.drain(..) {
            flit.arrival = self.cycle;
            let ptype = flit.ptype();
            // Gather boarding happens at head *arrival* — the Load signal
            // is generated in the RC stage (Fig. 7) — so payloads of this
            // router's NI are folded into the packet at zero latency.
            if ptype == PacketType::Gather
                && flit.is_head()
                && self.routers[router].coord != self.packets.src(flit.pid)
            {
                let fields = BoardFields {
                    is_head: true,
                    ptype,
                    dst: self.packets.dst(flit.pid),
                    space: self.packets.space(flit.pid),
                    aspace: &mut flit.aspace,
                    carried: &mut flit.carried_payloads,
                };
                match board_fields(fields, &mut self.ni[router], BoardMode::Fill) {
                    BoardOutcome::BoardedAll(k) => {
                        self.stats.gather_boards += k as u64;
                    }
                    BoardOutcome::BoardedPartial(k) => {
                        // Packet filled up with payloads left behind: this
                        // node initiates a fresh packet immediately (§4.2).
                        self.stats.gather_boards += k as u64;
                        self.stage_own_gather(router);
                    }
                    BoardOutcome::Full => {
                        self.stage_own_gather(router);
                    }
                    BoardOutcome::NotApplicable => {}
                }
            } else if ptype == PacketType::Ina
                && flit.is_head()
                && self.routers[router].coord != self.packets.src(flit.pid)
            {
                // INA fold: the router ALU adds this NI's same-space psums
                // into the passing packet — zero latency, no capacity
                // limit, one add per folded word.
                let fields = BoardFields {
                    is_head: true,
                    ptype,
                    dst: self.packets.dst(flit.pid),
                    space: self.packets.space(flit.pid),
                    aspace: &mut flit.aspace,
                    carried: &mut flit.carried_payloads,
                };
                if let BoardOutcome::BoardedAll(k) =
                    board_fields(fields, &mut self.ni[router], BoardMode::Accumulate)
                {
                    self.stats.ina_folds += k as u64;
                    self.stats.ina_adds += k as u64;
                }
            }
            self.write_flit(router, port, vc, flit);
        }
        // Recycle the drained batch (keeps its capacity).
        self.arrivals.push_back(batch);
    }

    /// Stage this node's own gather/INA packet in the NI (one-cycle
    /// assembly; validated again at head entry — see `noc::gather` docs).
    /// Gather packets have the fixed Table-1 size; INA packets carry the
    /// node's physical psum words (head + ⌈pending/slots⌉ flits) and never
    /// grow, however many downstream psums accumulate into them.
    fn stage_own_gather(&mut self, node: usize) {
        let ni = &self.ni[node];
        if ni.staged || ni.pending == 0 {
            return;
        }
        let (ptype, len_flits, space) = match self.collection {
            Collection::Gather => (PacketType::Gather, self.cfg.gather_packet_flits as u32, 0),
            Collection::Ina => {
                (PacketType::Ina, self.cfg.ina_packet_flits(ni.pending), ni.space)
            }
            Collection::RepetitiveUnicast => unreachable!("RU never stages NI packets"),
        };
        let desc = PacketDesc {
            id: 0, // assigned at head entry
            ptype,
            src: self.routers[node].coord,
            dst: ni.dst,
            len_flits,
            aspace: 0, // computed at head entry
            space,
            inject_cycle: self.cycle,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        self.push_injector(
            node * PORTS + Port::Local.index(),
            InjEntry { desc, from_ni: true, not_before: self.cycle + 1 },
        );
        let ni = &mut self.ni[node];
        ni.staged = true;
        ni.armed = false;
    }

    /// Buffer write common to link arrivals and local injection. This is
    /// one of the active-set wakeup points.
    fn write_flit(&mut self, router: usize, port: Port, vc: usize, flit: CompactFlit) {
        let vcs = self.vcs;
        let r = &mut self.routers[router];
        let idx = port.index() * vcs + vc;
        let was_empty = r.inputs[idx].is_empty();
        if flit.is_head() {
            r.meta[idx].head_arrival = self.cycle;
        }
        r.inputs[idx].push(flit);
        r.nonempty_mask |= 1 << idx;
        self.occupancy[router] += 1;
        self.stats.buffer_writes += 1;
        // Only (re)start the VC state machine when the VC is idle: an empty
        // buffer in Active state is a packet whose head departed while its
        // body flits are still on the wire.
        if was_empty && r.inputs[idx].state == VcState::Idle {
            r.inputs[idx].state =
                refresh_vc_state(&r.inputs[idx], &mut r.meta[idx], self.cycle, self.cfg.kappa());
        }
        self.mark_active(router);
    }

    fn apply_posts(&mut self) {
        // Operand streams first, then result posts — ascending cycle
        // order, FIFO within a cycle: the order the BTreeMap schedules
        // applied before the calendar queues replaced them.
        let mut scratch = std::mem::take(&mut self.stream_scratch);
        self.stream_posts.drain_up_to(self.cycle, &mut scratch);
        for (router, port, mut desc) in scratch.drain(..) {
            self.stats.packets_injected += 1;
            desc.id = self.packets.intern(&desc, desc.dst.x as usize >= self.cols) as u64;
            self.push_injector(
                router * PORTS + port.index(),
                InjEntry { desc, from_ni: false, not_before: self.cycle },
            );
        }
        self.stream_scratch = scratch;

        let mut scratch = std::mem::take(&mut self.ni_scratch);
        self.ni_posts.drain_up_to(self.cycle, &mut scratch);
        for post in scratch.drain(..) {
            self.apply_ni_post(post);
        }
        self.ni_scratch = scratch;
    }

    fn apply_ni_post(&mut self, post: NiPost) {
        // Census degradation: a contributor sitting on a dead router — or
        // cut off from its row memory — can never deliver. Excluding it
        // here (instead of letting it arm a δ timer that can't fire a
        // packet anywhere) is what makes the gather census degrade
        // gracefully: the δ timeout machinery of the healthy nodes never
        // waits on it, and the shortfall is reported, not hung on.
        if let Some(fs) = self.faults.as_deref() {
            let plan = &fs.plan;
            if plan.router_down[post.node] || !plan.reachable(post.node, post.dst) {
                self.payloads_dropped += post.payloads as u64;
                self.missing_contributors += 1;
                return;
            }
        }
        // The NI payload queue (Fig. 9) holds one round; if the previous
        // round's payloads have not left this node yet, the new round backs
        // up (PE output registers stall) — this is the backpressure through
        // which network congestion stretches the round pipeline (Δ_R/Δ_G).
        self.ni[post.node].dst = post.dst;
        self.mark_active(post.node);
        if self.ni_busy(post.node) {
            self.ni[post.node].backlog.push_back((post.payloads, post.space));
            self.backlogged_nodes += 1;
        } else {
            self.activate_round(post.node, post.payloads, post.space);
        }
    }

    /// Does this node still hold payloads (or result packets) of a
    /// previous round?
    fn ni_busy(&self, node: usize) -> bool {
        let inj = &self.injectors[node * PORTS + Port::Local.index()];
        self.ni[node].pending > 0 || !inj.queue.is_empty() || inj.cur.is_some()
    }

    /// Make one round's payloads live at the NI. `space` is the round's
    /// accumulation-space id (the scheduled post cycle; used by INA only).
    fn activate_round(&mut self, node: usize, payloads: u32, space: u64) {
        match self.collection {
            Collection::RepetitiveUnicast => {
                // RU baseline: literal repetitive unicast — each PE's
                // partial sum is sent as its own fixed-size 2-flit packet
                // ([31][32]; Table 1 compares "gather packet size" against
                // "unicast packet size: 2 flits/packet" per result).
                // `ru_pack_payloads` is the packed ablation variant.
                let per_pkt = if self.cfg.ru_pack_payloads {
                    (self.cfg.unicast_packet_flits as u32 - 1) * self.cfg.payloads_per_flit()
                } else {
                    1
                };
                let src = self.routers[node].coord;
                let dst = self.ni[node].dst;
                let len_flits = self.cfg.unicast_packet_flits as u32;
                let mut remaining = payloads;
                while remaining > 0 {
                    let carried = remaining.min(per_pkt);
                    remaining -= carried;
                    let mut desc = PacketDesc {
                        id: 0,
                        ptype: PacketType::Unicast,
                        src,
                        dst,
                        len_flits,
                        aspace: 0,
                        space: 0,
                        inject_cycle: self.cycle,
                        deliver_along_path: false,
                        carried_payloads: carried,
                    };
                    desc.id = self.packets.intern(&desc, dst.x as usize >= self.cols) as u64;
                    self.stats.packets_injected += 1;
                    self.push_injector(
                        node * PORTS + Port::Local.index(),
                        InjEntry { desc, from_ni: false, not_before: self.cycle },
                    );
                }
            }
            Collection::Gather => {
                let x = self.routers[node].coord.x;
                let ni = &mut self.ni[node];
                ni.pending += payloads;
                if ni.is_initiator {
                    // Leftmost node: inject without waiting.
                    ni.armed = true;
                    ni.deadline = self.cycle;
                } else if !ni.armed {
                    ni.armed = true;
                    ni.deadline =
                        self.cycle.saturating_add(effective_delta(self.cfg.delta, x));
                }
            }
            Collection::Ina => {
                // Same δ machinery as gather, plus the accumulation-space
                // tag: all NIs posted for one round carry the same space
                // (the scheduled post cycle), which together with the dst
                // forms the merge-eligibility key — psums of different
                // rounds must never be added together, however skewed the
                // nodes' activation times become under backlog.
                let x = self.routers[node].coord.x;
                let ni = &mut self.ni[node];
                debug_assert_eq!(ni.pending, 0, "INA NI activates one round at a time");
                ni.pending += payloads;
                ni.space = space;
                ni.armed = true;
                ni.deadline = if ni.is_initiator {
                    self.cycle
                } else {
                    self.cycle.saturating_add(effective_delta(self.cfg.delta, x))
                };
            }
        }
    }

    /// Activate backlogged rounds on nodes whose NI has drained.
    /// Backlogged nodes are always in the active set.
    fn drain_backlogs(&mut self) {
        if self.backlogged_nodes == 0 {
            return;
        }
        for_each_active!(self, node, {
            if self.ni[node].backlog.is_empty() || self.ni_busy(node) {
                continue;
            }
            let (payloads, space) = self.ni[node].backlog.pop_front().unwrap();
            self.backlogged_nodes -= 1;
            self.activate_round(node, payloads, space);
        });
    }

    fn vc_allocate(&mut self) {
        for_each_active!(self, ridx, {
            self.vc_allocate_router(ridx);
        });
    }

    fn vc_allocate_router(&mut self, ridx: usize) {
        let vcs = self.vcs;
        let mut mask = self.routers[ridx].nonempty_mask;
        while mask != 0 {
            let idx = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let (dst, src, ptype) = {
                let r = &self.routers[ridx];
                match (r.inputs[idx].state, r.inputs[idx].front()) {
                    (VcState::Routing { sa_ready_cycle }, Some(f))
                        // VA completes one cycle before SA readiness.
                        if self.cycle + 1 >= sa_ready_cycle =>
                    {
                        (self.packets.dst(f.pid), self.packets.src(f.pid), f.ptype())
                    }
                    _ => continue,
                }
            };
            let here = self.routers[ridx].coord;
            let out_port = self.route_with_faults(ptype, ridx, here, dst);
            // Ejection hops sink unconditionally and carry no VC-class
            // restriction; for link hops the topology may confine
            // allocation to one VC class (the torus dateline rule — a
            // no-op on the mesh).
            let class = if self.is_memory_ejection(here, out_port, dst) {
                None
            } else {
                self.fabric.vc_class(ptype, src, here, dst, out_port)
            };
            let in_port = idx / vcs;
            let in_vc = idx % vcs;
            let granted = match class {
                None => self.routers[ridx].allocate_out_vc(out_port, vcs, (in_port, in_vc)),
                Some(c) => {
                    let half = (vcs / 2).max(1);
                    let (lo, hi) = if c == 0 { (0, half) } else { (half, vcs) };
                    self.routers[ridx].allocate_out_vc_range(out_port, lo, hi, vcs, (in_port, in_vc))
                }
            };
            if let Some(out_vc) = granted {
                self.stats.vc_allocs += 1;
                self.routers[ridx].inputs[idx].state = VcState::Active {
                    out_port: out_port.index(),
                    out_vc,
                };
            }
        }
    }

    fn switch_allocate(&mut self) {
        let vcs = self.vcs;
        let n = PORTS * vcs;
        // The request scratch is initialized once per cycle, not once per
        // router: `counts` guards which entries are live, so stale slots
        // from an earlier router are never read.
        let mut reqs = [[usize::MAX; 16]; PORTS];
        for_each_active!(self, ridx, {
            if self.routers[ridx].nonempty_mask == 0 {
                continue;
            }
            // One pass over the occupied VCs collects the eligible
            // requesters per output port; classic separable allocation
            // (one grant per output port, one per input port) follows.
            let mut counts = [0usize; PORTS];
            {
                let r = &self.routers[ridx];
                let mut mask = r.nonempty_mask;
                while mask != 0 {
                    let idx = mask.trailing_zeros() as usize;
                    mask &= mask - 1;
                    let buf = &r.inputs[idx];
                    let (op, ovc) = match buf.state {
                        VcState::Active { out_port, out_vc } => (out_port, out_vc),
                        _ => continue,
                    };
                    let Some(front) = buf.front() else { continue };
                    // SA eligibility: flit must have been buffered in an
                    // earlier cycle; heads additionally wait out RC/VA.
                    if front.arrival >= self.cycle {
                        continue;
                    }
                    if front.is_head() {
                        let head_ready = r.meta[idx].head_arrival + self.cfg.kappa() - 1;
                        let ready = head_ready.max(r.meta[idx].front_since + 1);
                        if self.cycle < ready {
                            continue;
                        }
                    }
                    // Credits toward downstream (None = ejection sink).
                    if let Some(ct) = &r.out_credits[op] {
                        if !ct.available(ovc) {
                            // Probe record site #2: one requester-cycle
                            // blocked on credit toward (link, out VC).
                            if let Some(p) = self.probes.as_mut() {
                                p.record_blocked(ridx, op, ovc);
                            }
                            continue;
                        }
                    }
                    reqs[op][counts[op]] = idx;
                    counts[op] += 1;
                }
            }
            // INA merge point: complete same-space packets competing for
            // the same output port collapse into one before arbitration —
            // the absorbed flits never traverse the crossbar or the link.
            if self.collection == Collection::Ina {
                self.merge_ina_requests(ridx, &mut reqs, &mut counts);
            }
            let mut in_port_used = [false; PORTS];
            for out_port_i in 0..PORTS {
                if counts[out_port_i] == 0 {
                    continue;
                }
                // Round-robin: smallest distance from the rr pointer.
                let rr = self.routers[ridx].sa_rr[out_port_i];
                let mut winner: Option<(usize, usize)> = None; // (dist, idx)
                for &idx in &reqs[out_port_i][..counts[out_port_i]] {
                    if in_port_used[idx / vcs] {
                        continue;
                    }
                    let dist = (idx + n - rr) % n;
                    if winner.map_or(true, |(d, _)| dist < d) {
                        winner = Some((dist, idx));
                    }
                }
                let Some((_, idx)) = winner else { continue };
                self.grant(ridx, idx, out_port_i);
                in_port_used[idx / vcs] = true;
                self.routers[ridx].sa_rr[out_port_i] = (idx + 1) % n;
            }
        });
    }

    /// Execute one SA grant: pop the flit, do gather boarding / stream
    /// delivery, refund the upstream credit, and either forward the flit to
    /// the neighbour or eject it.
    fn grant(&mut self, ridx: usize, idx: usize, out_port_i: usize) {
        let vcs = self.vcs;
        let out_port = Port::from_index(out_port_i);
        let kappa = self.cfg.kappa();

        // Capture the allocated output VC before any state reset.
        let out_vc = match self.routers[ridx].inputs[idx].state {
            VcState::Active { out_port: op, out_vc } => {
                debug_assert_eq!(op, out_port_i);
                out_vc
            }
            s => panic!("SA granted from non-active VC state {s:?}"),
        };

        let flit = self.routers[ridx].inputs[idx].pop().expect("SA granted an empty VC");
        if self.routers[ridx].inputs[idx].is_empty() {
            self.routers[ridx].nonempty_mask &= !(1 << idx);
        }
        self.occupancy[ridx] -= 1;
        self.stats.buffer_reads += 1;
        self.stats.sa_grants += 1;
        self.stats.crossbar_traversals += 1;
        self.stats.flit_hops += 1;

        // --- mesh operand stream delivery along the path ---
        if flit.along_path() {
            self.stats.stream_deliveries += 1;
        }

        // --- upstream credit refund (the slot we just freed) ---
        let in_port = Port::from_index(idx / vcs);
        let in_vc = idx % vcs;
        // Flits injected at this router (`src == here`: Local results, or
        // the West/North operand-stream sources) freed a slot no upstream
        // router holds credits for. On the mesh the source-port check is
        // redundant with the missing-neighbour check below; on a torus the
        // edge ports DO have (wrap) neighbours, so without it a stream
        // flit would refund a credit the wrap upstream never spent.
        if in_port != Port::Local && self.packets.src(flit.pid) != self.routers[ridx].coord {
            let here = self.routers[ridx].coord;
            if let Some(up) = self.neighbour(here, in_port) {
                let up_idx = self.node_idx(up);
                self.credit_refunds.push((up_idx, in_port.opposite().index(), in_vc));
            }
        }

        // --- tail: release the output VC and refresh the input VC ---
        if flit.is_tail() {
            self.routers[ridx].release_out_vc(out_port, out_vc, vcs);
            let r = &mut self.routers[ridx];
            r.inputs[idx].state = VcState::Idle;
            if !r.inputs[idx].is_empty() {
                r.inputs[idx].state =
                    refresh_vc_state(&r.inputs[idx], &mut r.meta[idx], self.cycle, kappa);
            }
        }

        // --- forward or eject ---
        let here = self.routers[ridx].coord;
        if self.is_memory_ejection_flag(here, out_port, flit.mem_dst()) {
            self.eject(flit);
            self.flits_active -= 1;
        } else {
            // Consume a credit and put the flit on the link.
            if let Some(ct) = self.routers[ridx].out_credits[out_port_i].as_mut() {
                ct.consume(out_vc);
            }
            let nb = self
                .neighbour(here, out_port)
                .expect("routed toward a missing neighbour");
            let nb_idx = self.node_idx(nb);
            self.stats.link_traversals += 1;
            // Fault-aware routing observability: a forwarded head taking
            // a hop off the fabric's fault-free route is one detour hop.
            if let Some(fs) = self.faults.as_deref() {
                if fs.plan.reroutes
                    && flit.is_head()
                    && out_port != self.fabric.route(flit.ptype(), here, self.packets.dst(flit.pid))
                {
                    self.stats.detour_hops += 1;
                }
            }
            // Probe record site #1: every link_traversals increment is
            // mirrored per directed link — ejections (the branch above)
            // and INA absorbs never reach here, so the per-link sums
            // partition this aggregate bit-exactly.
            if let Some(p) = self.probes.as_mut() {
                p.record_traversal(
                    ridx,
                    out_port_i,
                    out_vc,
                    self.cycle,
                    flit.is_head(),
                    flit.carried_payloads,
                    flit.along_path(),
                );
            }
            // ST (next cycle) + link. The ring was already popped for the
            // current cycle, so slot 0 is cycle+1: index delay−1 ⇒ arrival
            // at cycle + delay, giving the κ+link per-hop latency of
            // Table 1.
            let delay = (1 + self.cfg.link_latency) as usize;
            self.arrivals[delay - 1].push(Arrival {
                router: nb_idx,
                port: out_port.opposite(),
                vc: out_vc,
                flit,
            });
        }
    }

    /// Merge INA packets among one router's SA requesters: within each
    /// output port's request list, the first complete INA packet of an
    /// accumulation space survives and every later complete packet of the
    /// same (space, dst) is absorbed into it. Absorbed entries are removed
    /// from the request list before arbitration.
    ///
    /// Only *complete* buffered packets merge (head at the VC front, tail
    /// already buffered): a packet whose flits are still on the wire keeps
    /// wormhole ordering intact and simply merges a cycle later, or
    /// travels on its own.
    ///
    /// One order-preserving compaction pass per output port: each entry is
    /// visited once and either kept (first complete packet of its key, or
    /// not a complete packet) or absorbed into the survivor recorded for
    /// its key. This replaced an absorb-and-shift loop that was O(n²) in
    /// the request count under contention; the surviving request order —
    /// and therefore round-robin arbitration — is unchanged.
    fn merge_ina_requests(
        &mut self,
        ridx: usize,
        reqs: &mut [[usize; 16]; PORTS],
        counts: &mut [usize; PORTS],
    ) {
        for op in 0..PORTS {
            if counts[op] < 2 {
                continue;
            }
            // Survivor table: (merge key, input VC of the surviving
            // packet), at most one per request entry.
            let mut skeys = [(0u64, Coord::new(0, 0)); 16];
            let mut sidx = [0usize; 16];
            let mut nsurv = 0usize;
            let n_req = counts[op];
            let mut kept = 0usize;
            for j in 0..n_req {
                let idx = reqs[op][j];
                match self.ina_complete_head(ridx, idx) {
                    Some(key) => {
                        if let Some(k) = (0..nsurv).find(|&k| skeys[k] == key) {
                            self.absorb_ina_packet(ridx, idx, sidx[k]);
                            continue; // entry leaves the request list
                        }
                        skeys[nsurv] = key;
                        sidx[nsurv] = idx;
                        nsurv += 1;
                        reqs[op][kept] = idx;
                        kept += 1;
                    }
                    None => {
                        reqs[op][kept] = idx;
                        kept += 1;
                    }
                }
            }
            counts[op] = kept;
        }
    }

    /// If input VC `idx` fronts a *complete* buffered INA packet, return
    /// its merge key (accumulation space, destination).
    fn ina_complete_head(&self, ridx: usize, idx: usize) -> Option<(u64, Coord)> {
        let buf = &self.routers[ridx].inputs[idx];
        let head = buf.front()?;
        if head.ptype() != PacketType::Ina || !head.is_head() {
            return None;
        }
        let len = self.packets.len(head.pid) as usize;
        let tail = buf.get(len - 1)?;
        if tail.pid != head.pid {
            return None;
        }
        if len > 1 && !tail.is_tail() {
            return None;
        }
        Some((self.packets.space(head.pid), self.packets.dst(head.pid)))
    }

    /// Absorb the complete INA packet fronting input VC `absorbed` into
    /// the head fronting input VC `survivor` (same router): the router ALU
    /// adds the absorbed psums into the survivor's words, the absorbed
    /// flits are read out of the buffer (their upstream credits refunded
    /// in one batch), and the absorbed packet's output VC is released.
    fn absorb_ina_packet(&mut self, ridx: usize, absorbed: usize, survivor: usize) {
        let vcs = self.vcs;
        let kappa = self.cfg.kappa();
        let (pid, len, carried, words, absorbed_src) = {
            let f = self.routers[ridx].inputs[absorbed].front().expect("absorbed VC empty");
            (
                f.pid,
                self.packets.len(f.pid) as usize,
                f.carried_payloads,
                f.aspace,
                self.packets.src(f.pid),
            )
        };
        // SA requesters are Active: release the output VC the absorbed
        // packet held so a later packet can claim the lane.
        match self.routers[ridx].inputs[absorbed].state {
            VcState::Active { out_port, out_vc } => {
                self.routers[ridx].release_out_vc(Port::from_index(out_port), out_vc, vcs);
            }
            s => panic!("INA merge on non-active VC state {s:?}"),
        }
        for _ in 0..len {
            let f = self.routers[ridx].inputs[absorbed].pop().expect("absorbed packet truncated");
            debug_assert_eq!(f.pid, pid, "absorbed a foreign flit");
        }
        self.occupancy[ridx] -= len as u32;
        self.flits_active -= len as u64;
        // The merge reads the absorbed flits into the ALU; they are not
        // switched, linked or ejected. The whole packet retires at once —
        // this is the mid-flight retire path of the packet table.
        self.packets.release(pid, len as u32);
        self.stats.buffer_reads += len as u64;
        self.stats.ina_merges += 1;
        self.stats.ina_adds += words as u64;
        // Refund the upstream credits for the slots freed all at once
        // (skipping locally-injected packets, as in `grant`).
        let in_port = Port::from_index(absorbed / vcs);
        if in_port != Port::Local && absorbed_src != self.routers[ridx].coord {
            let here = self.routers[ridx].coord;
            if let Some(up) = self.neighbour(here, in_port) {
                let up_idx = self.node_idx(up);
                for _ in 0..len {
                    self.credit_refunds.push((up_idx, in_port.opposite().index(), absorbed % vcs));
                }
            }
        }
        // Reset the absorbed VC (wormhole ordering guarantees the next
        // flit, if any, is a fresh head).
        {
            let r = &mut self.routers[ridx];
            r.inputs[absorbed].state = VcState::Idle;
            if r.inputs[absorbed].is_empty() {
                r.nonempty_mask &= !(1 << absorbed);
            } else {
                r.inputs[absorbed].state = refresh_vc_state(
                    &r.inputs[absorbed],
                    &mut r.meta[absorbed],
                    self.cycle,
                    kappa,
                );
            }
        }
        // Fold the represented psums into the survivor; its physical word
        // count widens to the larger side (adds happen in place).
        let head = self.routers[ridx].inputs[survivor]
            .front_mut()
            .expect("survivor VC empty");
        debug_assert!(head.is_head() && head.ptype() == PacketType::Ina);
        head.carried_payloads += carried;
        head.aspace = head.aspace.max(words);
    }

    fn eject(&mut self, flit: CompactFlit) {
        self.stats.flits_ejected += 1;
        if flit.is_head() && flit.mem_dst() {
            // Result packet reached the row memory element.
            self.payloads_delivered += flit.carried_payloads as u64;
            if flit.ptype() == PacketType::Gather {
                self.gather_packets_ejected += 1;
            }
        }
        if flit.is_tail() {
            self.stats.packets_ejected += 1;
            let lat = self.cycle.saturating_sub(self.packets.inject_cycle(flit.pid));
            self.stats.total_packet_latency += lat;
            self.stats.max_packet_latency = self.stats.max_packet_latency.max(lat);
            self.last_eject_cycle = self.cycle;
            if flit.along_path() {
                self.stream_tails_ejected += 1;
            }
            if flit.mem_dst() {
                self.result_packets_ejected += 1;
            }
        }
        // Each ejected flit retires from its table slot; wormhole delivery
        // is in-order, so the tail's retire is the one that frees it.
        self.packets.release(flit.pid, 1);
    }

    fn neighbour(&self, c: Coord, p: Port) -> Option<Coord> {
        self.fabric.neighbor(c, p)
    }

    fn feed_injectors(&mut self) {
        if self.busy_injectors == 0 {
            return;
        }
        // Busy injectors belong to active routers by the set invariant.
        for_each_active!(self, ridx, {
            let base = ridx * PORTS;
            for port_i in 0..PORTS {
                let ii = base + port_i;
                if self.injectors[ii].cur.is_none() && self.injectors[ii].queue.is_empty() {
                    continue;
                }
                self.feed_one_injector(ridx, Port::from_index(port_i), ii);
            }
        });
    }

    /// Feed wrapper maintaining the busy-injector counter: the inner
    /// logic may complete a packet or cancel a staged one, idling the
    /// source.
    fn feed_one_injector(&mut self, ridx: usize, port: Port, ii: usize) {
        self.feed_one_injector_inner(ridx, port, ii);
        let inj = &self.injectors[ii];
        if inj.cur.is_none() && inj.queue.is_empty() {
            self.busy_injectors -= 1;
        }
    }

    fn feed_one_injector_inner(&mut self, ridx: usize, port: Port, ii: usize) {
        // Start the next packet if idle.
        if self.injectors[ii].cur.is_none() {
            let ready = match self.injectors[ii].queue.front() {
                Some(e) => e.not_before <= self.cycle,
                None => return,
            };
            if !ready {
                return;
            }
            let entry = self.injectors[ii].queue.pop_front().unwrap();
            let mut desc = entry.desc;
            if entry.from_ni {
                // Cancel-on-board: re-validate against the NI now.
                let cap = self.cfg.gather_capacity();
                let x = self.routers[ridx].coord.x;
                let collection = self.collection;
                let delta = self.cfg.delta;
                let cycle = self.cycle;
                let ni = &mut self.ni[ridx];
                ni.staged = false;
                if ni.pending == 0 {
                    return; // a passing packet collected/folded everything
                }
                let carried = match collection {
                    Collection::Gather => ni.pending.min(cap),
                    // INA has no capacity limit: the whole round ships.
                    Collection::Ina => ni.pending,
                    Collection::RepetitiveUnicast => {
                        unreachable!("RU never stages NI packets")
                    }
                };
                ni.pending -= carried;
                if ni.pending == 0 {
                    ni.armed = false;
                } else {
                    // Oversized gather round (payloads exceed one packet):
                    // keep the remainder armed for the next opportunity.
                    ni.armed = true;
                    ni.deadline = cycle.saturating_add(effective_delta(delta, x));
                }
                desc.carried_payloads = carried;
                // Gather: remaining payload slots. INA: the packet's
                // physical psum word count (constant under accumulation).
                desc.aspace = match collection {
                    Collection::Gather => cap - carried,
                    _ => carried,
                };
                desc.inject_cycle = self.cycle;
                desc.id = self.packets.intern(&desc, desc.dst.x as usize >= self.cols) as u64;
                self.stats.packets_injected += 1;
            }
            self.injectors[ii].cur = Some((desc, 0, usize::MAX));
        }
        // Feed one flit if buffer space allows.
        let vcs = self.vcs;
        let Some((desc, seq, vc_slot)) = self.injectors[ii].cur.take() else { return };
        let mut vc = vc_slot;
        if seq == 0 {
            // Choose the VC with the most free space for the whole packet.
            let r = &self.routers[ridx];
            let base = port.index() * vcs;
            vc = (0..vcs)
                .max_by_key(|&v| self.cfg.buffer_depth - r.inputs[base + v].len())
                .unwrap();
        }
        let idx = port.index() * vcs + vc;
        if self.routers[ridx].inputs[idx].has_space() {
            let flit = {
                let mut f = self.packets.make_flit(desc.id as u32, seq);
                f.arrival = self.cycle;
                f
            };
            self.write_flit(ridx, port, vc, flit);
            self.flits_active += 1;
            let next = seq + 1;
            if next < desc.len_flits {
                self.injectors[ii].cur = Some((desc, next, vc));
            }
        } else {
            self.injectors[ii].cur = Some((desc, seq, vc));
        }
    }

    fn gather_timeouts(&mut self) {
        // The δ timeout machinery is shared by gather and INA collection;
        // RU injects eagerly and never arms it. Armed NIs are always in
        // the active set.
        if self.collection == Collection::RepetitiveUnicast {
            return;
        }
        for_each_active!(self, ridx, {
            let ni = &self.ni[ridx];
            if !(ni.armed && ni.pending > 0 && !ni.staged) {
                continue;
            }
            if self.cycle < ni.deadline {
                continue;
            }
            let is_initiator = ni.is_initiator;
            self.stage_own_gather(ridx);
            if !is_initiator {
                self.stats.delta_expiries += 1;
            }
        });
    }

    /// The router fabric this network simulates.
    pub fn topology(&self) -> &dyn Topology {
        self.topo.as_ref()
    }

    // Exposed for tests.
    pub fn ni_state(&self, node: Coord) -> &NiState {
        &self.ni[self.node_idx(node)]
    }

    pub fn total_buffered_flits(&self) -> usize {
        self.routers.iter().map(|r| r.occupancy()).sum()
    }

    /// The packet-intern table (exposed for the property suite's
    /// aliasing/occupancy invariants).
    pub fn packet_table(&self) -> &PacketTable {
        &self.packets
    }

    /// Audit the packet table against every in-flight flit: each one must
    /// name a live slot with an in-range `seq`, and each in-progress
    /// injector packet must still be live. Returns the number of flits
    /// audited. Panics on any aliasing violation — a recycled slot being
    /// referenced by a stale flit is exactly the bug class the free list
    /// could introduce.
    pub fn audit_packet_table(&self) -> u64 {
        let mut audited = 0u64;
        let mut check = |flit: &CompactFlit, where_: &str| {
            assert!(
                self.packets.is_live(flit.pid),
                "{where_}: flit of packet {} references a freed table slot",
                flit.pid
            );
            assert!(
                flit.seq < self.packets.len(flit.pid),
                "{where_}: flit seq {} out of range for packet {}",
                flit.seq,
                flit.pid
            );
            audited += 1;
        };
        for r in &self.routers {
            for buf in &r.inputs {
                for f in buf.iter() {
                    check(f, "buffer");
                }
            }
        }
        for batch in &self.arrivals {
            for a in batch.iter() {
                check(&a.flit, "link");
            }
        }
        if let Some(fs) = self.faults.as_deref() {
            for q in &fs.retx {
                for e in q.iter() {
                    check(&e.flit, "retransmission slot");
                }
            }
        }
        for inj in &self.injectors {
            if let Some((desc, _, _)) = &inj.cur {
                assert!(
                    self.packets.is_live(desc.id as u32),
                    "injector holds a freed packet slot"
                );
            }
        }
        audited
    }

    /// Every result payload the network is still responsible for: posted
    /// but not yet activated, pending/backlogged at an NI, staged or
    /// queued in an injector, buffered in a router VC, or in flight on a
    /// link. At any cycle boundary
    /// `posted == payloads_delivered + payloads_dropped +
    /// payloads_in_flight()` — the flit conservation invariant the
    /// property suite pins (no payload is ever dropped by VC/switch
    /// allocation, boarding, or INA merging; under fault injection every
    /// loss is accounted in `payloads_dropped`).
    ///
    /// Payload counts ride on head flits only (`carried_payloads` is
    /// replicated onto body flits for convenience but represents the
    /// packet once), and a staged-but-unvalidated NI packet still counts
    /// via `NiState::pending` (cancel-on-board moves the count exactly
    /// once).
    pub fn payloads_in_flight(&self) -> u64 {
        let mut total = 0u64;
        total += self.ni_posts.iter().map(|p| p.payloads as u64).sum::<u64>();
        for ni in &self.ni {
            total += ni.pending as u64;
            total += ni.backlog.iter().map(|&(p, _)| p as u64).sum::<u64>();
        }
        for inj in &self.injectors {
            for e in &inj.queue {
                if !e.from_ni {
                    total += e.desc.carried_payloads as u64;
                }
                // from_ni entries: the count still sits in NiState::pending
                // until head entry validates the packet.
            }
            if let Some((desc, seq, _)) = &inj.cur {
                if *seq == 0 {
                    // Head not yet buffered; once it is, the buffer scan
                    // below owns the count.
                    total += desc.carried_payloads as u64;
                }
            }
        }
        for r in &self.routers {
            for buf in &r.inputs {
                total += buf
                    .iter()
                    .filter(|f| f.is_head())
                    .map(|f| f.carried_payloads as u64)
                    .sum::<u64>();
            }
        }
        for batch in &self.arrivals {
            total += batch
                .iter()
                .filter(|a| a.flit.is_head())
                .map(|a| a.flit.carried_payloads as u64)
                .sum::<u64>();
        }
        if let Some(fs) = self.faults.as_deref() {
            for q in &fs.retx {
                total += q
                    .iter()
                    .filter(|e| e.flit.is_head())
                    .map(|e| e.flit.carried_payloads as u64)
                    .sum::<u64>();
            }
        }
        total
    }
}
