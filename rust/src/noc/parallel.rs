//! Deterministic intra-layer parallel kernel: row-band sharding of one
//! network simulation.
//!
//! `SimConfig::threads` fans out *across* layers (one simulation per OS
//! thread, `coordinator::executor`); this module parallelizes *inside*
//! one simulation. The router grid is cut into contiguous **row bands**
//! — `rows.div_ceil(workers)` rows each, the last band ragged when the
//! row count does not divide — and the band-local phases of one clock
//! run concurrently, one band per scoped worker thread:
//!
//! * **deliver** (link arrivals + gather boarding / INA folds): the
//!   cycle's arrival batch is partitioned by destination band, order
//!   preserved within each band, and each worker writes only its band's
//!   buffers and NIs;
//! * **VA + SA** (VC allocation, switch allocation, grants, INA merges):
//!   both passes touch only the granting router's own state — output-VC
//!   holders, credit trackers, round-robin pointers — so a worker runs
//!   VA then SA over its band's active routers back to back.
//!
//! Everything a phase would write *outside* its band is deferred into a
//! per-band `Effects` mailbox instead of applied in place: flits put
//! on a link (they arrive `1 + link_latency` cycles later, so the
//! sequential kernel defers them too), upstream credit refunds (applied
//! next cycle), stat counter deltas, active-set wakeups and the probe
//! utilization-series count. At the per-cycle barrier (the end of
//! [`std::thread::scope`]) the owner merges the mailboxes **in
//! ascending band order** — exactly the order a sequential ascending-
//! router-index scan produces them — so arbitration, boarding, packet-id
//! assignment and every counter stay bit-identical to the sequential
//! kernel (`tests/golden_kernel.rs` and `tests/determinism.rs` pin
//! this at workers 1/2/4/8).
//!
//! The remaining phases of the cycle (credit refunds, calendar posts,
//! injector feeding, δ timeouts, backlog drain, active-set retirement)
//! stay sequential in [`Network::step_parallel`]: they are cheap O(live
//! work) scans, and they are where packet-table slots are interned —
//! keeping the [`super::flit::PacketTable`] mutations single-threaded
//! (interns in the sequential phases, retires replayed from
//! [`Effects::pid_releases`] at the barrier in ascending band order) is
//! what makes pid assignment and free-list recycling trivially
//! deterministic.
//!
//! Threads are spawned per parallel section via [`std::thread::scope`]
//! (band 0 runs inline on the calling thread). Scoped spawns keep the
//! module free of `unsafe` and of any persistent pool state; the spawn
//! overhead (~µs per section) is the honest cost — it amortizes on the
//! big meshes this kernel exists for (64×64 points in
//! `benches/sim_hotpath.rs`) and is why `intra_workers = 1` (the
//! default) bypasses this module entirely with zero extra state.
//!
//! [`Network::step_parallel`]: super::network::Network

use super::buffer::VcState;
use super::faults::FaultPlan;
use super::flit::{CompactFlit, Coord, PacketDesc, PacketTable, PacketType};
use super::gather::{board_fields, BoardFields, BoardMode, BoardOutcome, NiState};
use super::network::{Arrival, InjEntry, Injector};
use super::probes::{BandProbes, LinkProbes};
use super::router::{refresh_vc_state, RouterState};
use super::routing::Port;
use super::stats::NetStats;
use super::topology::Fabric;
use crate::config::{Collection, SimConfig};

const PORTS: usize = Port::COUNT;

/// Persistent parallel-kernel state owned by the `Network` (boxed; the
/// `intra_workers = 1` path carries only the `None` discriminant).
pub(super) struct ParState {
    /// Router-index ranges `[start, end)`, ascending, contiguous from
    /// router 0, covering the whole grid — whole rows each.
    pub(super) bands: Vec<(usize, usize)>,
    /// Per-band arrival inboxes for the deliver phase (capacity reused
    /// across cycles).
    pub(super) inboxes: Vec<Vec<Arrival>>,
    /// Per-band deferred-effect mailboxes (capacity reused across cycles).
    pub(super) effects: Vec<Effects>,
    rows_per_band: usize,
    cols: usize,
}

impl ParState {
    /// Band layout for `workers` workers over a `cols`×`rows` grid, or
    /// `None` when the parallel kernel cannot help (one worker, or too
    /// few rows to form two bands) — the caller then keeps the
    /// sequential kernel with zero extra state.
    pub(super) fn for_grid(workers: usize, cols: usize, rows: usize) -> Option<Box<ParState>> {
        if workers <= 1 || rows < 2 {
            return None;
        }
        let rpb = rows.div_ceil(workers);
        let nb = rows.div_ceil(rpb);
        if nb < 2 {
            return None;
        }
        let bands: Vec<(usize, usize)> =
            (0..nb).map(|b| (b * rpb * cols, ((b + 1) * rpb).min(rows) * cols)).collect();
        Some(Box::new(ParState {
            inboxes: (0..nb).map(|_| Vec::new()).collect(),
            effects: (0..nb).map(|_| Effects::default()).collect(),
            rows_per_band: rpb,
            cols,
            bands,
        }))
    }

    /// Which band owns `router` (bands are whole row groups).
    #[inline]
    pub(super) fn band_of(&self, router: usize) -> usize {
        (router / self.cols) / self.rows_per_band
    }
}

/// Everything a band phase would write outside its own band, deferred to
/// the barrier merge. Field order in `absorb` does not matter — every
/// entry is either a commutative sum, a max, or a list replayed in the
/// sequential order (ascending band = ascending router index).
#[derive(Default)]
pub(super) struct Effects {
    /// Stat counter deltas (summed into `Network::stats`;
    /// `cycles_simulated` stays 0 so `NetStats::merge` leaves it alone).
    pub(super) stats: NetStats,
    /// Flits put on links this cycle, in grant order (appended to the
    /// `arrivals[link_delay - 1]` ring slot).
    pub(super) arrivals_out: Vec<Arrival>,
    /// Upstream credit refunds (router, out-port index, vc) for next
    /// cycle's `apply_credit_refunds`.
    pub(super) credit_refunds: Vec<(usize, usize, usize)>,
    /// Routers to `mark_active` at the barrier (buffer writes and
    /// injector pushes inside the band; the set-bit merge is idempotent).
    pub(super) wakes: Vec<usize>,
    /// Flits ejected or absorbed (subtracted from `flits_active`).
    pub(super) flits_active_sub: u64,
    pub(super) payloads_delivered: u64,
    pub(super) stream_tails_ejected: u64,
    pub(super) gather_packets_ejected: u64,
    pub(super) result_packets_ejected: u64,
    /// Any packet tail ejected this cycle (`last_eject_cycle = cycle`).
    pub(super) tail_ejected: bool,
    /// Idle injectors that gained work (`busy_injectors` delta).
    pub(super) busy_injectors_add: usize,
    /// Link traversals counted toward the network-wide probe series
    /// bucket of this cycle ([`LinkProbes::bump_series`]).
    pub(super) series_flits: u64,
    /// Packet-table retires of this band — `(pid, flits)` per ejected
    /// flit (1) or absorbed INA packet (its full length) — replayed at
    /// the barrier in ascending band order, which reproduces the exact
    /// global release sequence (and therefore free-list state) of the
    /// sequential kernel.
    pub(super) pid_releases: Vec<(u32, u32)>,
}

impl Effects {
    /// Clear for the next parallel section, keeping `Vec` capacities.
    pub(super) fn reset(&mut self) {
        self.stats = NetStats::default();
        self.arrivals_out.clear();
        self.credit_refunds.clear();
        self.wakes.clear();
        self.flits_active_sub = 0;
        self.payloads_delivered = 0;
        self.stream_tails_ejected = 0;
        self.gather_packets_ejected = 0;
        self.result_packets_ejected = 0;
        self.tail_ejected = false;
        self.busy_injectors_add = 0;
        self.series_flits = 0;
        self.pid_releases.clear();
    }
}

/// Read-only cycle context shared by every worker. Every field is a
/// shared reference or `Copy` data, so the whole struct is `Sync`; the
/// packet table is read-only for the duration of a parallel section
/// (interns happen in the sequential phases, retires at the barrier).
pub(super) struct Shared<'a> {
    pub(super) cfg: &'a SimConfig,
    /// Enum-dispatched fabric — the same devirtualized `route`/
    /// `vc_class`/`neighbor` the sequential hot path uses.
    pub(super) fabric: Fabric,
    pub(super) packets: &'a PacketTable,
    pub(super) collection: Collection,
    pub(super) cols: usize,
    pub(super) vcs: usize,
    pub(super) cycle: u64,
    /// The active-router bitset, frozen for the section (wakes are
    /// deferred through [`Effects::wakes`], merged at the barrier).
    pub(super) active: &'a [u64],
    /// The compiled fault plan (`cfg.faults`): immutable for the whole
    /// run, so bands may consult the routing tables concurrently. All
    /// *mutable* fault state (retransmission slots, poison set) is owner-
    /// thread-only — the arrival filter runs before the band partition.
    pub(super) faults: Option<&'a FaultPlan>,
}

impl Shared<'_> {
    #[inline]
    fn node_idx(&self, c: Coord) -> usize {
        c.y as usize * self.cols + c.x as usize
    }

    /// Mirror of `Network::is_memory_ejection` (same predicate, read
    /// from the shared context instead of `&self`).
    #[inline]
    fn is_memory_ejection(&self, here: Coord, out_port: Port, dst: Coord) -> bool {
        self.is_memory_ejection_flag(here, out_port, dst.x as usize >= self.cols)
    }

    /// Mirror of `Network::is_memory_ejection_flag`.
    #[inline]
    fn is_memory_ejection_flag(&self, here: Coord, out_port: Port, mem_dst: bool) -> bool {
        out_port == Port::Local
            || (out_port == Port::East && here.x as usize + 1 == self.cols && mem_dst)
    }
}

/// One band's disjoint mutable view of the network arrays. Built fresh
/// per parallel section by [`make_bands`] via `split_at_mut` chains —
/// no `unsafe`, no aliasing.
pub(super) struct Band<'a> {
    /// Global router-index range `[start, end)` this band owns.
    pub(super) range: (usize, usize),
    pub(super) routers: &'a mut [RouterState<CompactFlit>],
    pub(super) ni: &'a mut [NiState],
    pub(super) injectors: &'a mut [Injector],
    pub(super) occupancy: &'a mut [u32],
    pub(super) probes: Option<BandProbes<'a>>,
}

impl Band<'_> {
    /// Band-local index of global router `router`.
    #[inline]
    fn r(&self, router: usize) -> usize {
        router - self.range.0
    }
}

/// Slice the network arrays into per-band views matching `bands`
/// (ascending, contiguous from index 0 — the [`ParState::for_grid`]
/// invariant the `split_at_mut` chain relies on).
pub(super) fn make_bands<'a>(
    bands: &[(usize, usize)],
    routers: &'a mut [RouterState<CompactFlit>],
    ni: &'a mut [NiState],
    injectors: &'a mut [Injector],
    occupancy: &'a mut [u32],
    probes: Option<&'a mut LinkProbes>,
) -> Vec<Band<'a>> {
    let mut probe_bands = probes.map(|p| p.split_bands(bands)).unwrap_or_default().into_iter();
    let (mut routers, mut ni, mut injectors, mut occupancy) = (routers, ni, injectors, occupancy);
    let mut out = Vec::with_capacity(bands.len());
    for &(start, end) in bands {
        let n = end - start;
        let (r, rest) = std::mem::take(&mut routers).split_at_mut(n);
        routers = rest;
        let (g, rest) = std::mem::take(&mut ni).split_at_mut(n);
        ni = rest;
        let (j, rest) = std::mem::take(&mut injectors).split_at_mut(n * PORTS);
        injectors = rest;
        let (o, rest) = std::mem::take(&mut occupancy).split_at_mut(n);
        occupancy = rest;
        out.push(Band {
            range: (start, end),
            routers: r,
            ni: g,
            injectors: j,
            occupancy: o,
            probes: probe_bands.next(),
        });
    }
    out
}

/// Run the deliver phase over all bands concurrently: band 0 inline on
/// the caller, the rest on scoped threads. The scope exit is the
/// barrier (joins every worker, propagates panics).
pub(super) fn run_deliver(
    sh: &Shared<'_>,
    bands: &mut [Band<'_>],
    effects: &mut [Effects],
    inboxes: &mut [Vec<Arrival>],
) {
    debug_assert!(bands.len() == effects.len() && bands.len() == inboxes.len());
    let mut items: Vec<_> = bands
        .iter_mut()
        .zip(effects.iter_mut())
        .zip(inboxes.iter_mut())
        .map(|((b, e), i)| (b, e, i))
        .collect();
    std::thread::scope(|s| {
        for (band, fx, inbox) in items.drain(1..) {
            s.spawn(move || deliver_band(sh, band, fx, inbox));
        }
        let (band0, fx0, inbox0) = items.pop().expect("at least one band");
        deliver_band(sh, band0, fx0, inbox0);
    });
}

/// Run fused VA + SA over all bands concurrently (same barrier shape as
/// [`run_deliver`]). VA completes for the whole band before its SA pass
/// starts — the same order the sequential kernel's two full sweeps give
/// each router, and neither pass reads another router's state.
pub(super) fn run_va_sa(sh: &Shared<'_>, bands: &mut [Band<'_>], effects: &mut [Effects]) {
    debug_assert_eq!(bands.len(), effects.len());
    let mut items: Vec<_> = bands.iter_mut().zip(effects.iter_mut()).collect();
    std::thread::scope(|s| {
        for (band, fx) in items.drain(1..) {
            s.spawn(move || {
                va_band(sh, band, fx);
                sa_band(sh, band, fx);
            });
        }
        let (band0, fx0) = items.pop().expect("at least one band");
        va_band(sh, band0, fx0);
        sa_band(sh, band0, fx0);
    });
}

/// Visit the active routers of `[start, end)` in ascending index order —
/// the band-windowed version of the kernel's `for_each_active!` walk.
/// Both 64-bit boundary words are masked to the range; the shift guards
/// keep every shift amount `< 64`.
#[inline]
fn for_band_active(active: &[u64], range: (usize, usize), mut f: impl FnMut(usize)) {
    let (start, end) = range;
    if start >= end {
        return;
    }
    let w_lo = start >> 6;
    let w_hi = (end - 1) >> 6;
    for w in w_lo..=w_hi {
        let mut bits = active[w];
        if w == w_lo {
            bits &= !0u64 << (start & 63);
        }
        let word_base = w << 6;
        let over = (word_base + 64).saturating_sub(end);
        if over > 0 {
            // keep = 64 - over bits; 1 <= keep <= 63 since w <= w_hi.
            bits &= (1u64 << (64 - over)) - 1;
        }
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            f(word_base + b);
        }
    }
}

// ----------------------------------------------------------------------
// Band transcriptions of the sequential phases. Each function mirrors
// its `Network` counterpart line for line, with `self.<array>[i]`
// becoming `band.<array>[band.r(i)]` and every out-of-band write routed
// through `fx`. Divergence here is a golden-suite failure, not a
// compile error — change them in lockstep with network.rs.
// ----------------------------------------------------------------------

/// Mirror of `Network::deliver_arrivals` for one band's inbox slice
/// (relative order within the band equals the sequential batch order).
fn deliver_band(sh: &Shared<'_>, band: &mut Band<'_>, fx: &mut Effects, inbox: &mut Vec<Arrival>) {
    for Arrival { router, port, vc, mut flit } in inbox.drain(..) {
        flit.arrival = sh.cycle;
        let ptype = flit.ptype();
        if ptype == PacketType::Gather
            && flit.is_head()
            && band.routers[band.r(router)].coord != sh.packets.src(flit.pid)
        {
            let bi = band.r(router);
            let fields = BoardFields {
                is_head: true,
                ptype,
                dst: sh.packets.dst(flit.pid),
                space: sh.packets.space(flit.pid),
                aspace: &mut flit.aspace,
                carried: &mut flit.carried_payloads,
            };
            match board_fields(fields, &mut band.ni[bi], BoardMode::Fill) {
                BoardOutcome::BoardedAll(k) => {
                    fx.stats.gather_boards += k as u64;
                }
                BoardOutcome::BoardedPartial(k) => {
                    fx.stats.gather_boards += k as u64;
                    stage_own_gather(sh, band, fx, router);
                }
                BoardOutcome::Full => {
                    stage_own_gather(sh, band, fx, router);
                }
                BoardOutcome::NotApplicable => {}
            }
        } else if ptype == PacketType::Ina
            && flit.is_head()
            && band.routers[band.r(router)].coord != sh.packets.src(flit.pid)
        {
            let bi = band.r(router);
            let fields = BoardFields {
                is_head: true,
                ptype,
                dst: sh.packets.dst(flit.pid),
                space: sh.packets.space(flit.pid),
                aspace: &mut flit.aspace,
                carried: &mut flit.carried_payloads,
            };
            if let BoardOutcome::BoardedAll(k) =
                board_fields(fields, &mut band.ni[bi], BoardMode::Accumulate)
            {
                fx.stats.ina_folds += k as u64;
                fx.stats.ina_adds += k as u64;
            }
        }
        write_flit(sh, band, fx, router, port, vc, flit);
    }
}

/// Mirror of `Network::stage_own_gather` (`desc.id` stays 0 — pids are
/// assigned at head entry by the sequential `feed_injectors` phase, so
/// assignment order is untouched by band parallelism).
fn stage_own_gather(sh: &Shared<'_>, band: &mut Band<'_>, fx: &mut Effects, node: usize) {
    let bi = band.r(node);
    let ni = &band.ni[bi];
    if ni.staged || ni.pending == 0 {
        return;
    }
    let (ptype, len_flits, space) = match sh.collection {
        Collection::Gather => (PacketType::Gather, sh.cfg.gather_packet_flits as u32, 0),
        Collection::Ina => (PacketType::Ina, sh.cfg.ina_packet_flits(ni.pending), ni.space),
        Collection::RepetitiveUnicast => unreachable!("RU never stages NI packets"),
    };
    let desc = PacketDesc {
        id: 0, // assigned at head entry
        ptype,
        src: band.routers[bi].coord,
        dst: ni.dst,
        len_flits,
        aspace: 0, // computed at head entry
        space,
        inject_cycle: sh.cycle,
        deliver_along_path: false,
        carried_payloads: 0,
    };
    push_injector(
        band,
        fx,
        node * PORTS + Port::Local.index(),
        InjEntry { desc, from_ni: true, not_before: sh.cycle + 1 },
    );
    let ni = &mut band.ni[bi];
    ni.staged = true;
    ni.armed = false;
}

/// Mirror of `Network::push_injector` (busy counter and wakeup deferred).
fn push_injector(band: &mut Band<'_>, fx: &mut Effects, ii: usize, entry: InjEntry) {
    let inj = &mut band.injectors[ii - band.range.0 * PORTS];
    if inj.cur.is_none() && inj.queue.is_empty() {
        fx.busy_injectors_add += 1;
    }
    inj.queue.push_back(entry);
    fx.wakes.push(ii / PORTS);
}

/// Mirror of `Network::write_flit` (wakeup deferred).
#[allow(clippy::too_many_arguments)]
fn write_flit(
    sh: &Shared<'_>,
    band: &mut Band<'_>,
    fx: &mut Effects,
    router: usize,
    port: Port,
    vc: usize,
    flit: CompactFlit,
) {
    let bi = band.r(router);
    let r = &mut band.routers[bi];
    let idx = port.index() * sh.vcs + vc;
    let was_empty = r.inputs[idx].is_empty();
    if flit.is_head() {
        r.meta[idx].head_arrival = sh.cycle;
    }
    r.inputs[idx].push(flit);
    r.nonempty_mask |= 1 << idx;
    band.occupancy[bi] += 1;
    fx.stats.buffer_writes += 1;
    let r = &mut band.routers[bi];
    if was_empty && r.inputs[idx].state == VcState::Idle {
        r.inputs[idx].state =
            refresh_vc_state(&r.inputs[idx], &mut r.meta[idx], sh.cycle, sh.cfg.kappa());
    }
    fx.wakes.push(router);
}

/// Mirror of `Network::vc_allocate` over one band's active routers.
fn va_band(sh: &Shared<'_>, band: &mut Band<'_>, fx: &mut Effects) {
    let range = band.range;
    for_band_active(sh.active, range, |ridx| {
        va_router(sh, band, fx, ridx);
    });
}

/// Mirror of `Network::vc_allocate_router`.
fn va_router(sh: &Shared<'_>, band: &mut Band<'_>, fx: &mut Effects, ridx: usize) {
    let vcs = sh.vcs;
    let bi = band.r(ridx);
    let mut mask = band.routers[bi].nonempty_mask;
    while mask != 0 {
        let idx = mask.trailing_zeros() as usize;
        mask &= mask - 1;
        let (dst, src, ptype) = {
            let r = &band.routers[bi];
            match (r.inputs[idx].state, r.inputs[idx].front()) {
                (VcState::Routing { sa_ready_cycle }, Some(f))
                    // VA completes one cycle before SA readiness.
                    if sh.cycle + 1 >= sa_ready_cycle =>
                {
                    (sh.packets.dst(f.pid), sh.packets.src(f.pid), f.ptype())
                }
                _ => continue,
            }
        };
        let here = band.routers[bi].coord;
        // Mirror of `Network::route_with_faults`: the fault plan's
        // healthy-subgraph table overrides the fabric when any link or
        // router is permanently down (multicast keeps its hardwired
        // path; unreachable falls back to the fabric route).
        let out_port = match sh.faults {
            Some(plan) if plan.reroutes && ptype != PacketType::Multicast => {
                plan.route(ridx, dst).unwrap_or_else(|| sh.fabric.route(ptype, here, dst))
            }
            _ => sh.fabric.route(ptype, here, dst),
        };
        let class = if sh.is_memory_ejection(here, out_port, dst) {
            None
        } else {
            sh.fabric.vc_class(ptype, src, here, dst, out_port)
        };
        let in_port = idx / vcs;
        let in_vc = idx % vcs;
        let granted = match class {
            None => band.routers[bi].allocate_out_vc(out_port, vcs, (in_port, in_vc)),
            Some(c) => {
                let half = (vcs / 2).max(1);
                let (lo, hi) = if c == 0 { (0, half) } else { (half, vcs) };
                band.routers[bi].allocate_out_vc_range(out_port, lo, hi, vcs, (in_port, in_vc))
            }
        };
        if let Some(out_vc) = granted {
            fx.stats.vc_allocs += 1;
            band.routers[bi].inputs[idx].state =
                VcState::Active { out_port: out_port.index(), out_vc };
        }
    }
}

/// Mirror of `Network::switch_allocate` over one band's active routers.
fn sa_band(sh: &Shared<'_>, band: &mut Band<'_>, fx: &mut Effects) {
    let vcs = sh.vcs;
    let n = PORTS * vcs;
    // Initialized once per band per cycle; `counts` guards liveness.
    let mut reqs = [[usize::MAX; 16]; PORTS];
    let range = band.range;
    for_band_active(sh.active, range, |ridx| {
        let bi = band.r(ridx);
        if band.routers[bi].nonempty_mask == 0 {
            return;
        }
        let mut counts = [0usize; PORTS];
        {
            let r = &band.routers[bi];
            let mut mask = r.nonempty_mask;
            while mask != 0 {
                let idx = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                let buf = &r.inputs[idx];
                let (op, ovc) = match buf.state {
                    VcState::Active { out_port, out_vc } => (out_port, out_vc),
                    _ => continue,
                };
                let Some(front) = buf.front() else { continue };
                if front.arrival >= sh.cycle {
                    continue;
                }
                if front.is_head() {
                    let head_ready = r.meta[idx].head_arrival + sh.cfg.kappa() - 1;
                    let ready = head_ready.max(r.meta[idx].front_since + 1);
                    if sh.cycle < ready {
                        continue;
                    }
                }
                if let Some(ct) = &r.out_credits[op] {
                    if !ct.available(ovc) {
                        if let Some(p) = band.probes.as_mut() {
                            p.record_blocked(ridx, op, ovc);
                        }
                        continue;
                    }
                }
                reqs[op][counts[op]] = idx;
                counts[op] += 1;
            }
        }
        if sh.collection == Collection::Ina {
            merge_ina_requests(sh, band, fx, ridx, &mut reqs, &mut counts);
        }
        let mut in_port_used = [false; PORTS];
        for out_port_i in 0..PORTS {
            if counts[out_port_i] == 0 {
                continue;
            }
            let rr = band.routers[bi].sa_rr[out_port_i];
            let mut winner: Option<(usize, usize)> = None; // (dist, idx)
            for &idx in &reqs[out_port_i][..counts[out_port_i]] {
                if in_port_used[idx / vcs] {
                    continue;
                }
                let dist = (idx + n - rr) % n;
                if winner.map_or(true, |(d, _)| dist < d) {
                    winner = Some((dist, idx));
                }
            }
            let Some((_, idx)) = winner else { continue };
            grant(sh, band, fx, ridx, idx, out_port_i);
            in_port_used[idx / vcs] = true;
            band.routers[bi].sa_rr[out_port_i] = (idx + 1) % n;
        }
    });
}

/// Mirror of `Network::grant` (forwarded flits, credit refunds and the
/// eject counters all defer through `fx`).
fn grant(
    sh: &Shared<'_>,
    band: &mut Band<'_>,
    fx: &mut Effects,
    ridx: usize,
    idx: usize,
    out_port_i: usize,
) {
    let vcs = sh.vcs;
    let bi = band.r(ridx);
    let out_port = Port::from_index(out_port_i);
    let kappa = sh.cfg.kappa();

    let out_vc = match band.routers[bi].inputs[idx].state {
        VcState::Active { out_port: op, out_vc } => {
            debug_assert_eq!(op, out_port_i);
            out_vc
        }
        s => panic!("SA granted from non-active VC state {s:?}"),
    };

    let flit = band.routers[bi].inputs[idx].pop().expect("SA granted an empty VC");
    if band.routers[bi].inputs[idx].is_empty() {
        band.routers[bi].nonempty_mask &= !(1 << idx);
    }
    band.occupancy[bi] -= 1;
    fx.stats.buffer_reads += 1;
    fx.stats.sa_grants += 1;
    fx.stats.crossbar_traversals += 1;
    fx.stats.flit_hops += 1;

    if flit.along_path() {
        fx.stats.stream_deliveries += 1;
    }

    let in_port = Port::from_index(idx / vcs);
    let in_vc = idx % vcs;
    let here = band.routers[bi].coord;
    if in_port != Port::Local && sh.packets.src(flit.pid) != here {
        if let Some(up) = sh.fabric.neighbor(here, in_port) {
            fx.credit_refunds.push((sh.node_idx(up), in_port.opposite().index(), in_vc));
        }
    }

    if flit.is_tail() {
        band.routers[bi].release_out_vc(out_port, out_vc, vcs);
        let r = &mut band.routers[bi];
        r.inputs[idx].state = VcState::Idle;
        if !r.inputs[idx].is_empty() {
            r.inputs[idx].state =
                refresh_vc_state(&r.inputs[idx], &mut r.meta[idx], sh.cycle, kappa);
        }
    }

    if sh.is_memory_ejection_flag(here, out_port, flit.mem_dst()) {
        eject(sh, fx, &flit);
        fx.flits_active_sub += 1;
    } else {
        if let Some(ct) = band.routers[bi].out_credits[out_port_i].as_mut() {
            ct.consume(out_vc);
        }
        let nb = sh.fabric.neighbor(here, out_port).expect("routed toward a missing neighbour");
        fx.stats.link_traversals += 1;
        // Mirror of the sequential kernel's detour-hop accounting.
        if let Some(plan) = sh.faults {
            if plan.reroutes
                && flit.is_head()
                && out_port != sh.fabric.route(flit.ptype(), here, sh.packets.dst(flit.pid))
            {
                fx.stats.detour_hops += 1;
            }
        }
        fx.series_flits += 1;
        if let Some(p) = band.probes.as_mut() {
            p.record_traversal(
                ridx,
                out_port_i,
                out_vc,
                sh.cycle,
                flit.is_head(),
                flit.carried_payloads,
                flit.along_path(),
            );
        }
        fx.arrivals_out.push(Arrival {
            router: sh.node_idx(nb),
            port: out_port.opposite(),
            vc: out_vc,
            flit,
        });
    }
}

/// Mirror of `Network::eject` (all sinks are counters or the deferred
/// release list, so it only touches `fx`).
fn eject(sh: &Shared<'_>, fx: &mut Effects, flit: &CompactFlit) {
    fx.stats.flits_ejected += 1;
    if flit.is_head() && flit.mem_dst() {
        fx.payloads_delivered += flit.carried_payloads as u64;
        if flit.ptype() == PacketType::Gather {
            fx.gather_packets_ejected += 1;
        }
    }
    if flit.is_tail() {
        fx.stats.packets_ejected += 1;
        let lat = sh.cycle.saturating_sub(sh.packets.inject_cycle(flit.pid));
        fx.stats.total_packet_latency += lat;
        fx.stats.max_packet_latency = fx.stats.max_packet_latency.max(lat);
        fx.tail_ejected = true;
        if flit.along_path() {
            fx.stream_tails_ejected += 1;
        }
        if flit.mem_dst() {
            fx.result_packets_ejected += 1;
        }
    }
    // Mirror of the sequential per-flit `packets.release(pid, 1)` —
    // deferred to the barrier, replayed in ascending band order.
    fx.pid_releases.push((flit.pid, 1));
}

/// Mirror of `Network::merge_ina_requests`.
fn merge_ina_requests(
    sh: &Shared<'_>,
    band: &mut Band<'_>,
    fx: &mut Effects,
    ridx: usize,
    reqs: &mut [[usize; 16]; PORTS],
    counts: &mut [usize; PORTS],
) {
    for op in 0..PORTS {
        if counts[op] < 2 {
            continue;
        }
        let mut skeys = [(0u64, Coord::new(0, 0)); 16];
        let mut sidx = [0usize; 16];
        let mut nsurv = 0usize;
        let n_req = counts[op];
        let mut kept = 0usize;
        for j in 0..n_req {
            let idx = reqs[op][j];
            match ina_complete_head(sh, band, ridx, idx) {
                Some(key) => {
                    if let Some(k) = (0..nsurv).find(|&k| skeys[k] == key) {
                        absorb_ina_packet(sh, band, fx, ridx, idx, sidx[k]);
                        continue; // entry leaves the request list
                    }
                    skeys[nsurv] = key;
                    sidx[nsurv] = idx;
                    nsurv += 1;
                    reqs[op][kept] = idx;
                    kept += 1;
                }
                None => {
                    reqs[op][kept] = idx;
                    kept += 1;
                }
            }
        }
        counts[op] = kept;
    }
}

/// Mirror of `Network::ina_complete_head`.
fn ina_complete_head(
    sh: &Shared<'_>,
    band: &Band<'_>,
    ridx: usize,
    idx: usize,
) -> Option<(u64, Coord)> {
    let buf = &band.routers[band.r(ridx)].inputs[idx];
    let head = buf.front()?;
    if head.ptype() != PacketType::Ina || !head.is_head() {
        return None;
    }
    let len = sh.packets.len(head.pid) as usize;
    let tail = buf.get(len - 1)?;
    if tail.pid != head.pid {
        return None;
    }
    if len > 1 && !tail.is_tail() {
        return None;
    }
    Some((sh.packets.space(head.pid), sh.packets.dst(head.pid)))
}

/// Mirror of `Network::absorb_ina_packet`.
fn absorb_ina_packet(
    sh: &Shared<'_>,
    band: &mut Band<'_>,
    fx: &mut Effects,
    ridx: usize,
    absorbed: usize,
    survivor: usize,
) {
    let vcs = sh.vcs;
    let kappa = sh.cfg.kappa();
    let bi = band.r(ridx);
    let (pid, len, carried, words, absorbed_src) = {
        let f = band.routers[bi].inputs[absorbed].front().expect("absorbed VC empty");
        (
            f.pid,
            sh.packets.len(f.pid) as usize,
            f.carried_payloads,
            f.aspace,
            sh.packets.src(f.pid),
        )
    };
    match band.routers[bi].inputs[absorbed].state {
        VcState::Active { out_port, out_vc } => {
            band.routers[bi].release_out_vc(Port::from_index(out_port), out_vc, vcs);
        }
        s => panic!("INA merge on non-active VC state {s:?}"),
    }
    for _ in 0..len {
        let f = band.routers[bi].inputs[absorbed].pop().expect("absorbed packet truncated");
        debug_assert_eq!(f.pid, pid, "absorbed a foreign flit");
    }
    band.occupancy[bi] -= len as u32;
    fx.flits_active_sub += len as u64;
    // Mirror of the sequential whole-packet `packets.release(pid, len)`
    // — the mid-flight retire path, deferred to the barrier.
    fx.pid_releases.push((pid, len as u32));
    fx.stats.buffer_reads += len as u64;
    fx.stats.ina_merges += 1;
    fx.stats.ina_adds += words as u64;
    let in_port = Port::from_index(absorbed / vcs);
    let here = band.routers[bi].coord;
    if in_port != Port::Local && absorbed_src != here {
        if let Some(up) = sh.fabric.neighbor(here, in_port) {
            let up_idx = sh.node_idx(up);
            for _ in 0..len {
                fx.credit_refunds.push((up_idx, in_port.opposite().index(), absorbed % vcs));
            }
        }
    }
    {
        let r = &mut band.routers[bi];
        r.inputs[absorbed].state = VcState::Idle;
        if r.inputs[absorbed].is_empty() {
            r.nonempty_mask &= !(1 << absorbed);
        } else {
            r.inputs[absorbed].state =
                refresh_vc_state(&r.inputs[absorbed], &mut r.meta[absorbed], sh.cycle, kappa);
        }
    }
    let head =
        band.routers[bi].inputs[survivor].front_mut().expect("survivor VC empty");
    debug_assert!(head.is_head() && head.ptype() == PacketType::Ina);
    head.carried_payloads += carried;
    head.aspace = head.aspace.max(words);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn band_layout_covers_grid_contiguously_including_ragged_last_band() {
        // 8 rows over 3 workers: ceil(8/3) = 3 rows/band -> bands of
        // 3, 3 and a ragged 2 rows.
        let cols = 5usize;
        let par = ParState::for_grid(3, cols, 8).expect("parallelizable grid");
        assert_eq!(par.bands, vec![(0, 3 * cols), (3 * cols, 6 * cols), (6 * cols, 8 * cols)]);
        // Every router maps into the band whose range holds it.
        for r in 0..8 * cols {
            let b = par.band_of(r);
            let (s, e) = par.bands[b];
            assert!(s <= r && r < e, "router {r} mapped to band {b} = [{s},{e})");
        }
        // Degenerate shapes stay sequential.
        assert!(ParState::for_grid(1, 8, 8).is_none(), "one worker is the sequential kernel");
        assert!(ParState::for_grid(4, 8, 1).is_none(), "one row cannot split");
        // More workers than rows: one row per band, rows bands.
        let par = ParState::for_grid(64, 4, 6).unwrap();
        assert_eq!(par.bands.len(), 6);
        assert_eq!(par.bands[5], (5 * 4, 6 * 4));
    }

    #[test]
    fn band_active_walk_masks_word_boundaries_exactly() {
        // 130 routers => 3 bitset words; mark every router active and
        // check each band walk visits exactly its own range, ascending.
        let n = 130usize;
        let mut active = vec![0u64; n.div_ceil(64)];
        for r in 0..n {
            active[r >> 6] |= 1 << (r & 63);
        }
        for &(start, end) in &[(0usize, 63usize), (63, 64), (64, 65), (0, 130), (100, 130)] {
            let mut seen = Vec::new();
            for_band_active(&active, (start, end), |r| seen.push(r));
            let want: Vec<usize> = (start..end).collect();
            assert_eq!(seen, want, "range [{start},{end})");
        }
        // A sparse set stays sparse within the window.
        let mut sparse = vec![0u64; 3];
        for r in [0usize, 63, 64, 129] {
            sparse[r >> 6] |= 1 << (r & 63);
        }
        let mut seen = Vec::new();
        for_band_active(&sparse, (1, 129), |r| seen.push(r));
        assert_eq!(seen, vec![63, 64]);
    }

    #[test]
    fn effects_reset_clears_every_field_and_keeps_capacity() {
        let mut fx = Effects::default();
        fx.stats.flit_hops = 7;
        fx.credit_refunds.push((1, 2, 0));
        fx.wakes.extend([3usize, 4]);
        fx.flits_active_sub = 2;
        fx.payloads_delivered = 9;
        fx.tail_ejected = true;
        fx.busy_injectors_add = 1;
        fx.series_flits = 5;
        fx.pid_releases.push((7, 1));
        let cap = fx.wakes.capacity();
        fx.reset();
        assert_eq!(fx.stats, NetStats::default());
        assert!(fx.arrivals_out.is_empty() && fx.credit_refunds.is_empty() && fx.wakes.is_empty());
        assert!(fx.pid_releases.is_empty());
        assert_eq!(
            (fx.flits_active_sub, fx.payloads_delivered, fx.busy_injectors_add, fx.series_flits),
            (0, 0, 0, 0)
        );
        assert!(!fx.tail_ejected);
        assert!(fx.wakes.capacity() >= cap, "reset must keep capacities");
    }
}
