//! Per-link observability probes (`SimConfig::probes`).
//!
//! `NetStats` reports whole-run aggregates; this module records *where*
//! traffic goes: one counter block per **directed link** — identified by
//! (source router, output port) — with a per-VC breakdown. The record
//! sites live in the event-driven kernel:
//!
//! * **Traversal** — the forward branch of `Network::grant`, immediately
//!   next to `NetStats::link_traversals += 1`. Every probe flit count is
//!   therefore a partition of `link_traversals`: ejections into the
//!   memory column and in-network-accumulation absorbs never touch a
//!   link and are never recorded, so
//!   `Σ links flits == NetStats.link_traversals` holds bit-exactly at
//!   every cycle boundary (pinned by `tests/probe_invariants.rs`).
//! * **Credit block** — the switch-allocation skip taken when the output
//!   VC has no credit. Each skip adds one *requester-cycle* to the
//!   blocked counter of the (link, VC) that refused the grant.
//!
//! Probes are strictly observational: they read flit metadata already in
//! scope at the record sites and never influence allocation, routing, or
//! timing. With `SimConfig::probes == false` (the default) the network
//! carries no probe state at all — the hot path stays allocation-free
//! and bit-identical to the unprobed kernel, which the `golden_kernel`
//! suite and `tests/determinism.rs` pin.
//!
//! Snapshots are taken with [`crate::noc::network::Network::probe_report`],
//! which resolves link endpoints through the active
//! [`crate::noc::topology::Topology`] (torus wrap links included) and
//! returns a [`ProbeReport`]. At the layer-driver level the report covers
//! the *measured prefix* — the simulated rounds before extrapolation —
//! exactly like `LayerRunResult::measured_net`.

use std::borrow::Cow;

use super::flit::Coord;
use super::routing::Port;
use super::topology::Topology;
use crate::util::json::Json;

/// Width of one utilization-series bucket in cycles. Chosen so a typical
/// layer prefix (10⁴–10⁶ cycles) yields tens-to-hundreds of points.
pub const BUCKET_CYCLES: u64 = 1024;

/// Mutable per-link counter state carried by the network while
/// `SimConfig::probes` is on. Flat `Vec`s indexed by
/// `router_index * Port::COUNT + port_index` (times `vcs` for the per-VC
/// planes) keep recording O(1), allocation-free after construction, and
/// deterministic — no hash maps anywhere.
#[derive(Debug, Clone)]
pub struct LinkProbes {
    vcs: usize,
    /// Flits that traversed each directed link.
    flits: Vec<u64>,
    /// Gather/result payloads carried across each link (head flits only).
    payloads: Vec<u64>,
    /// Traversals by operand-stream flits (`deliver_along_path`); the
    /// complement (`flits - stream_flits`) is result/collection traffic.
    stream_flits: Vec<u64>,
    /// Fault-injection replays pumped from each link's retransmission
    /// slot. Replays re-deliver an arrival at the receiver without the
    /// flit re-crossing `grant`, so they are *not* part of `flits` and
    /// the `Σ flits == link_traversals` partition stays exact. Recorded
    /// on the owner thread only (the arrival filter runs before the band
    /// partition), so no `BandProbes` view exists for this plane.
    retx_flits: Vec<u64>,
    /// Traversals per (link, output VC).
    per_vc_flits: Vec<u64>,
    /// Requester-cycles blocked on credit per (link, output VC).
    blocked: Vec<u64>,
    /// Lazy-rolled per-link bucket state for peak-demand tracking.
    bucket_id: Vec<u64>,
    bucket_cur: Vec<u64>,
    bucket_peak: Vec<u64>,
    /// Network-wide link traversals per [`BUCKET_CYCLES`] bucket.
    series: Vec<u64>,
}

impl LinkProbes {
    pub fn new(routers: usize, vcs: usize) -> LinkProbes {
        let links = routers * Port::COUNT;
        LinkProbes {
            vcs,
            flits: vec![0; links],
            payloads: vec![0; links],
            stream_flits: vec![0; links],
            retx_flits: vec![0; links],
            per_vc_flits: vec![0; links * vcs],
            blocked: vec![0; links * vcs],
            // u64::MAX forces the first traversal of each link to open a
            // fresh bucket (cycle 0 lives in bucket 0).
            bucket_id: vec![u64::MAX; links],
            bucket_cur: vec![0; links],
            bucket_peak: vec![0; links],
            series: Vec::new(),
        }
    }

    /// Record one flit crossing the directed link (`ridx`, `port`) on
    /// output VC `vc` at `cycle`. Called from the forward branch of
    /// `grant` only — never for ejections or INA absorbs.
    #[inline]
    pub fn record_traversal(
        &mut self,
        ridx: usize,
        port: usize,
        vc: usize,
        cycle: u64,
        is_head: bool,
        carried_payloads: u32,
        along_path: bool,
    ) {
        let li = ridx * Port::COUNT + port;
        self.flits[li] += 1;
        self.per_vc_flits[li * self.vcs + vc] += 1;
        if is_head {
            self.payloads[li] += carried_payloads as u64;
        }
        if along_path {
            self.stream_flits[li] += 1;
        }
        let bucket = cycle / BUCKET_CYCLES;
        if self.bucket_id[li] != bucket {
            self.bucket_id[li] = bucket;
            self.bucket_cur[li] = 0;
        }
        self.bucket_cur[li] += 1;
        if self.bucket_cur[li] > self.bucket_peak[li] {
            self.bucket_peak[li] = self.bucket_cur[li];
        }
        let bi = bucket as usize;
        if bi >= self.series.len() {
            self.series.resize(bi + 1, 0);
        }
        self.series[bi] += 1;
    }

    /// Record one requester-cycle blocked on credit for output VC `vc`
    /// of the directed link (`ridx`, `port`).
    #[inline]
    pub fn record_blocked(&mut self, ridx: usize, port: usize, vc: usize) {
        self.blocked[(ridx * Port::COUNT + port) * self.vcs + vc] += 1;
    }

    /// Record one fault-injection replay charged to the *sender-side*
    /// directed link (`ridx`, `port`) — the link whose receiver corrupted
    /// or transiently lost the flit. Called from the arrival filter on
    /// the owner thread only.
    #[inline]
    pub fn record_retransmission(&mut self, ridx: usize, port: usize) {
        self.retx_flits[ridx * Port::COUNT + port] += 1;
    }

    /// Snapshot the counters into a [`ProbeReport`] that borrows the
    /// utilization series where possible (see the comment on the series
    /// reconciliation below), resolving link endpoints through `topo`. Only physical links are emitted:
    /// (router, port) pairs where the topology wires a neighbour — on the
    /// torus that includes every wrap link. `Port::Local` is never a
    /// link (local traffic ejects or is absorbed before `grant`).
    pub fn report(&self, topo: &dyn Topology, cols: u16, rows: u16, cycles: u64) -> ProbeReport<'_> {
        let mut links = Vec::new();
        let mut total_flits = 0u64;
        let mut total_payloads = 0u64;
        let mut total_blocked = 0u64;
        let mut total_retx = 0u64;
        for y in 0..rows {
            for x in 0..cols {
                let from = Coord::new(x, y);
                let ridx = y as usize * cols as usize + x as usize;
                for pi in 0..Port::COUNT {
                    let port = Port::from_index(pi);
                    if port == Port::Local {
                        continue;
                    }
                    let Some(to) = topo.neighbor(from, port) else {
                        continue;
                    };
                    let li = ridx * Port::COUNT + pi;
                    let per_vc =
                        self.per_vc_flits[li * self.vcs..(li + 1) * self.vcs].to_vec();
                    let blocked = self.blocked[li * self.vcs..(li + 1) * self.vcs].to_vec();
                    total_flits += self.flits[li];
                    total_payloads += self.payloads[li];
                    total_blocked += blocked.iter().sum::<u64>();
                    total_retx += self.retx_flits[li];
                    links.push(LinkRecord {
                        from,
                        to,
                        port,
                        flits: self.flits[li],
                        payloads: self.payloads[li],
                        stream_flits: self.stream_flits[li],
                        retx_flits: self.retx_flits[li],
                        per_vc_flits: per_vc,
                        blocked_cycles: blocked,
                        peak_bucket_flits: self.bucket_peak[li],
                    });
                }
            }
        }
        // Reconcile the series with the observed window: `record_traversal`
        // only extends the series when a flit actually crosses a link, so a
        // calendar fast-forward that jumps the clock past whole buckets —
        // or a drain tail with no traffic after the last traversal — would
        // otherwise leave the series short. Pad with explicit zero buckets
        // so `series.len() == cycles.div_ceil(BUCKET_CYCLES)` always holds
        // and `series.len() × bucket_cycles` covers the final cycle. (The
        // lazy per-link bucket roll in `bucket_id`/`bucket_cur` needs no
        // equivalent fix: an empty bucket can never be the peak.)
        //
        // Recording never extends the series past the bucket of the last
        // traversal, so the already-full case borrows the live buffer
        // instead of cloning it — a snapshot is then allocation-free in
        // the series; callers that outlive the probes take
        // [`ProbeReport::into_owned`].
        let want = cycles.div_ceil(BUCKET_CYCLES) as usize;
        let series: Cow<'_, [u64]> = if self.series.len() >= want {
            Cow::Borrowed(&self.series)
        } else {
            let mut s = self.series.clone();
            s.resize(want, 0);
            Cow::Owned(s)
        };
        ProbeReport {
            cycles,
            bucket_cycles: BUCKET_CYCLES,
            links,
            series,
            total_flits,
            total_payloads,
            total_blocked_cycles: total_blocked,
            total_retransmissions: total_retx,
        }
    }

    /// Add `n` network-wide traversals to the series bucket covering
    /// `bucket` (used by the intra-layer parallel kernel to merge per-band
    /// series deltas at the cycle barrier). No-op for `n == 0`, so the
    /// series length stays bit-identical to sequential recording.
    pub fn bump_series(&mut self, bucket: u64, n: u64) {
        if n == 0 {
            return;
        }
        let bi = bucket as usize;
        if bi >= self.series.len() {
            self.series.resize(bi + 1, 0);
        }
        self.series[bi] += n;
    }

    /// Split the per-link counter planes into disjoint mutable band views,
    /// one per contiguous router range `[start, end)` of `bands` (the
    /// intra-layer parallel kernel's row bands). The bands must be
    /// ascending, contiguous from router 0 and cover every router. The
    /// network-wide `series` is *not* split — each band counts its
    /// traversals and the barrier merge applies them via
    /// [`LinkProbes::bump_series`].
    pub fn split_bands(&mut self, bands: &[(usize, usize)]) -> Vec<BandProbes<'_>> {
        let vcs = self.vcs;
        let mut out = Vec::with_capacity(bands.len());
        let (mut flits, mut payloads, mut stream_flits) =
            (&mut self.flits[..], &mut self.payloads[..], &mut self.stream_flits[..]);
        let (mut per_vc, mut blocked) = (&mut self.per_vc_flits[..], &mut self.blocked[..]);
        let (mut bid, mut bcur, mut bpeak) = (
            &mut self.bucket_id[..],
            &mut self.bucket_cur[..],
            &mut self.bucket_peak[..],
        );
        for &(start, end) in bands {
            let links = (end - start) * Port::COUNT;
            let (f, f2) = flits.split_at_mut(links);
            let (p, p2) = payloads.split_at_mut(links);
            let (s, s2) = stream_flits.split_at_mut(links);
            let (v, v2) = per_vc.split_at_mut(links * vcs);
            let (b, b2) = blocked.split_at_mut(links * vcs);
            let (i, i2) = bid.split_at_mut(links);
            let (c, c2) = bcur.split_at_mut(links);
            let (k, k2) = bpeak.split_at_mut(links);
            flits = f2;
            payloads = p2;
            stream_flits = s2;
            per_vc = v2;
            blocked = b2;
            bid = i2;
            bcur = c2;
            bpeak = k2;
            out.push(BandProbes {
                vcs,
                base_link: start * Port::COUNT,
                flits: f,
                payloads: p,
                stream_flits: s,
                per_vc_flits: v,
                blocked: b,
                bucket_id: i,
                bucket_cur: c,
                bucket_peak: k,
            });
        }
        out
    }
}

/// A disjoint mutable view over one band's slice of the [`LinkProbes`]
/// counter planes (see [`LinkProbes::split_bands`]). Record methods mirror
/// the sequential ones bit-for-bit; only the network-wide series is
/// deferred to the barrier merge.
#[derive(Debug)]
pub struct BandProbes<'a> {
    vcs: usize,
    /// Global link index of this band's first slot (`start_router × ports`).
    base_link: usize,
    flits: &'a mut [u64],
    payloads: &'a mut [u64],
    stream_flits: &'a mut [u64],
    per_vc_flits: &'a mut [u64],
    blocked: &'a mut [u64],
    bucket_id: &'a mut [u64],
    bucket_cur: &'a mut [u64],
    bucket_peak: &'a mut [u64],
}

impl BandProbes<'_> {
    /// Band-local mirror of [`LinkProbes::record_traversal`] minus the
    /// series update (counted by the caller, merged at the barrier).
    #[inline]
    pub fn record_traversal(
        &mut self,
        ridx: usize,
        port: usize,
        vc: usize,
        cycle: u64,
        is_head: bool,
        carried_payloads: u32,
        along_path: bool,
    ) {
        let li = ridx * Port::COUNT + port - self.base_link;
        self.flits[li] += 1;
        self.per_vc_flits[li * self.vcs + vc] += 1;
        if is_head {
            self.payloads[li] += carried_payloads as u64;
        }
        if along_path {
            self.stream_flits[li] += 1;
        }
        let bucket = cycle / BUCKET_CYCLES;
        if self.bucket_id[li] != bucket {
            self.bucket_id[li] = bucket;
            self.bucket_cur[li] = 0;
        }
        self.bucket_cur[li] += 1;
        if self.bucket_cur[li] > self.bucket_peak[li] {
            self.bucket_peak[li] = self.bucket_cur[li];
        }
    }

    /// Band-local mirror of [`LinkProbes::record_blocked`].
    #[inline]
    pub fn record_blocked(&mut self, ridx: usize, port: usize, vc: usize) {
        self.blocked[(ridx * Port::COUNT + port - self.base_link) * self.vcs + vc] += 1;
    }
}

/// Counters for one directed link, part of a [`ProbeReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct LinkRecord {
    /// Source router of the directed link.
    pub from: Coord,
    /// Destination router (wrap neighbour on the torus).
    pub to: Coord,
    /// Output port at `from` the link hangs off.
    pub port: Port,
    /// Flits that traversed the link.
    pub flits: u64,
    /// Result payloads carried across (summed from head flits).
    pub payloads: u64,
    /// Traversals by multicast operand-stream flits; the rest
    /// (`flits - stream_flits`) is collection/result traffic.
    pub stream_flits: u64,
    /// Fault-injection replays pumped from this link's retransmission
    /// slot (not part of [`flits`](Self::flits) — replays re-deliver at
    /// the receiver without re-crossing the switch).
    pub retx_flits: u64,
    /// Traversals per output VC (`Σ == flits`).
    pub per_vc_flits: Vec<u64>,
    /// Requester-cycles blocked on missing credit, per output VC.
    pub blocked_cycles: Vec<u64>,
    /// Most flits observed inside any single [`BUCKET_CYCLES`] window.
    pub peak_bucket_flits: u64,
}

impl LinkRecord {
    /// Flits carried per cycle of the observed window (one flit per
    /// cycle is the physical ceiling, so this is a true utilization).
    pub fn utilization(&self, cycles: u64) -> f64 {
        self.flits as f64 / cycles.max(1) as f64
    }

    /// Collection/result flits (complement of [`stream_flits`](Self::stream_flits)).
    pub fn result_flits(&self) -> u64 {
        self.flits - self.stream_flits
    }

    /// Total blocked requester-cycles across VCs.
    pub fn blocked_total(&self) -> u64 {
        self.blocked_cycles.iter().sum()
    }

    /// Compact label, e.g. `(6,2)->E(7,2)`.
    pub fn label(&self) -> String {
        format!(
            "({},{})->{}({},{})",
            self.from.x,
            self.from.y,
            self.port.letter(),
            self.to.x,
            self.to.y
        )
    }
}

/// Which pipeline stage the bottleneck link's traffic belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BottleneckStage {
    /// Result collection (unicast / gather / INA) bound for memory.
    Collection,
    /// Multicast operand streaming over the mesh.
    OperandStreaming,
    /// Fault-injection replay traffic (`SimConfig::faults`): the link is
    /// dominated by retransmissions of corrupted or transiently lost
    /// flits rather than first-attempt deliveries.
    Retransmission,
}

impl BottleneckStage {
    pub fn label(self) -> &'static str {
        match self {
            BottleneckStage::Collection => "collection",
            BottleneckStage::OperandStreaming => "operand-streaming",
            BottleneckStage::Retransmission => "retransmission",
        }
    }
}

/// The link that bounds a run, with attribution.
#[derive(Debug, Clone, PartialEq)]
pub struct Bottleneck {
    /// Source router of the bottleneck link.
    pub from: Coord,
    /// Destination router.
    pub to: Coord,
    /// Output port at `from`.
    pub port: Port,
    /// Flits carried by the link over the observed window.
    pub flits: u64,
    /// `flits / cycles` — fraction of the link's one-flit-per-cycle
    /// capacity consumed.
    pub utilization: f64,
    /// Busiest output VC on the link.
    pub vc: usize,
    /// Blocked requester-cycles charged to the link (all VCs).
    pub blocked_cycles: u64,
    /// Dominant traffic class on the link.
    pub stage: BottleneckStage,
}

impl Bottleneck {
    /// Compact label, e.g. `(6,2)->E(7,2)`.
    pub fn label(&self) -> String {
        format!(
            "({},{})->{}({},{})",
            self.from.x,
            self.from.y,
            self.port.letter(),
            self.to.x,
            self.to.y
        )
    }
}

/// Immutable snapshot of the per-link probes for one run (or one
/// simulated layer prefix).
///
/// Produced by `Network::probe_report` and surfaced as
/// `LayerRunResult::probes` through `Scenario::simulate` and
/// `NetworkExecutor`. All counters cover the **measured prefix** only —
/// like `measured_net`, nothing here is extrapolated, and
/// [`total_flits`](Self::total_flits) reconciles bit-exactly with the
/// prefix's `NetStats::link_traversals`.
///
/// The report derives `PartialEq` so determinism tests can require it to
/// be bit-identical across repeated seeded runs and executor thread
/// counts.
///
/// The utilization series borrows the probes' live buffer when no zero
/// padding is needed (the common case — any traversal in the final
/// bucket fills it); [`ProbeReport::into_owned`] detaches the snapshot
/// for callers that outlive the network.
#[derive(Debug, Clone, PartialEq)]
pub struct ProbeReport<'a> {
    /// Cycles in the observed window (the network's final cycle).
    pub cycles: u64,
    /// Width of one [`series`](Self::series) bucket ([`BUCKET_CYCLES`]).
    pub bucket_cycles: u64,
    /// One record per physical directed link (torus wraps included).
    pub links: Vec<LinkRecord>,
    /// Network-wide link traversals per bucket (index `b` covers cycles
    /// `[b * bucket_cycles, (b+1) * bucket_cycles)`).
    pub series: Cow<'a, [u64]>,
    /// `Σ links flits` — equals the prefix `NetStats::link_traversals`.
    pub total_flits: u64,
    /// `Σ links payloads`.
    pub total_payloads: u64,
    /// `Σ links blocked_cycles` across all VCs.
    pub total_blocked_cycles: u64,
    /// `Σ links retx_flits` — equals the prefix `NetStats::retransmissions`.
    pub total_retransmissions: u64,
}

impl ProbeReport<'_> {
    /// Detach the snapshot from the probes it was taken from (clones the
    /// series only when it is still borrowed).
    pub fn into_owned(self) -> ProbeReport<'static> {
        ProbeReport {
            cycles: self.cycles,
            bucket_cycles: self.bucket_cycles,
            links: self.links,
            series: Cow::Owned(self.series.into_owned()),
            total_flits: self.total_flits,
            total_payloads: self.total_payloads,
            total_blocked_cycles: self.total_blocked_cycles,
            total_retransmissions: self.total_retransmissions,
        }
    }

    /// The highest per-link utilization, in [0, 1].
    pub fn max_utilization(&self) -> f64 {
        self.hottest().map(|l| l.utilization(self.cycles)).unwrap_or(0.0)
    }

    /// The link carrying the most flits. Ties resolve to the earliest
    /// link in row-major (y, x, port) order, keeping the answer
    /// deterministic.
    pub fn hottest(&self) -> Option<&LinkRecord> {
        self.links
            .iter()
            .fold(None, |best: Option<&LinkRecord>, l| match best {
                Some(b) if b.flits >= l.flits => Some(b),
                _ if l.flits > 0 => Some(l),
                _ => best,
            })
    }

    /// Attribute the run's bottleneck: the hottest link, its busiest VC,
    /// and the traffic class that dominates it. `None` when no flit
    /// crossed any link.
    pub fn bottleneck(&self) -> Option<Bottleneck> {
        let l = self.hottest()?;
        let vc = l
            .per_vc_flits
            .iter()
            .enumerate()
            .fold((0usize, 0u64), |acc, (i, &f)| if f > acc.1 { (i, f) } else { acc })
            .0;
        // Retransmission outranks the first-attempt classes only when it
        // strictly dominates both, so fault-free runs (retx_flits == 0
        // everywhere) attribute exactly as before.
        let stage = if l.retx_flits > l.stream_flits && l.retx_flits > l.result_flits() {
            BottleneckStage::Retransmission
        } else if l.stream_flits > l.result_flits() {
            BottleneckStage::OperandStreaming
        } else {
            BottleneckStage::Collection
        };
        Some(Bottleneck {
            from: l.from,
            to: l.to,
            port: l.port,
            flits: l.flits,
            utilization: l.utilization(self.cycles),
            vc,
            blocked_cycles: l.blocked_total(),
            stage,
        })
    }

    /// Machine-readable form used by `noc-dnn analyze --json`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("cycles", Json::Num(self.cycles as f64))
            .set("bucket_cycles", Json::Num(self.bucket_cycles as f64))
            .set("total_flits", Json::Num(self.total_flits as f64))
            .set("total_payloads", Json::Num(self.total_payloads as f64))
            .set("total_blocked_cycles", Json::Num(self.total_blocked_cycles as f64))
            .set("total_retransmissions", Json::Num(self.total_retransmissions as f64))
            .set("max_link_utilization", Json::Num(self.max_utilization()))
            .set(
                "series",
                Json::Arr(self.series.iter().map(|&v| Json::Num(v as f64)).collect()),
            );
        let links = self
            .links
            .iter()
            .map(|l| {
                let mut o = Json::obj();
                o.set("link", Json::Str(l.label()))
                    .set(
                        "from",
                        Json::Arr(vec![
                            Json::Num(l.from.x as f64),
                            Json::Num(l.from.y as f64),
                        ]),
                    )
                    .set(
                        "to",
                        Json::Arr(vec![Json::Num(l.to.x as f64), Json::Num(l.to.y as f64)]),
                    )
                    .set("port", Json::Str(l.port.letter().to_string()))
                    .set("flits", Json::Num(l.flits as f64))
                    .set("payloads", Json::Num(l.payloads as f64))
                    .set("stream_flits", Json::Num(l.stream_flits as f64))
                    .set("result_flits", Json::Num(l.result_flits() as f64))
                    .set("retx_flits", Json::Num(l.retx_flits as f64))
                    .set(
                        "per_vc_flits",
                        Json::Arr(
                            l.per_vc_flits.iter().map(|&v| Json::Num(v as f64)).collect(),
                        ),
                    )
                    .set(
                        "blocked_cycles",
                        Json::Arr(
                            l.blocked_cycles.iter().map(|&v| Json::Num(v as f64)).collect(),
                        ),
                    )
                    .set("peak_bucket_flits", Json::Num(l.peak_bucket_flits as f64))
                    .set("utilization", Json::Num(l.utilization(self.cycles)));
                o
            })
            .collect();
        j.set("links", Json::Arr(links));
        if let Some(b) = self.bottleneck() {
            let mut o = Json::obj();
            o.set("link", Json::Str(b.label()))
                .set("port", Json::Str(b.port.letter().to_string()))
                .set("utilization", Json::Num(b.utilization))
                .set("flits", Json::Num(b.flits as f64))
                .set("vc", Json::Num(b.vc as f64))
                .set("blocked_cycles", Json::Num(b.blocked_cycles as f64))
                .set("stage", Json::Str(b.stage.label().to_string()));
            j.set("bottleneck", o);
        } else {
            j.set("bottleneck", Json::Null);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::topology::Mesh2D;

    fn probes_2x2() -> (LinkProbes, Mesh2D) {
        (LinkProbes::new(4, 2), Mesh2D::new(2, 2))
    }

    #[test]
    fn traversals_partition_into_links_and_vcs() {
        let (mut p, topo) = probes_2x2();
        // Router (0,0) east twice on vc 0, once on vc 1; (0,1) east once.
        p.record_traversal(0, Port::East.index(), 0, 5, true, 3, false);
        p.record_traversal(0, Port::East.index(), 0, 6, false, 0, false);
        p.record_traversal(0, Port::East.index(), 1, 7, true, 1, true);
        p.record_traversal(2, Port::East.index(), 0, 7, true, 2, false);
        let r = p.report(&topo, 2, 2, 100);
        assert_eq!(r.total_flits, 4);
        assert_eq!(r.total_payloads, 6);
        let e00 = r
            .links
            .iter()
            .find(|l| l.from == Coord::new(0, 0) && l.port == Port::East)
            .unwrap();
        assert_eq!(e00.flits, 3);
        assert_eq!(e00.per_vc_flits, vec![2, 1]);
        assert_eq!(e00.stream_flits, 1);
        assert_eq!(e00.result_flits(), 2);
        assert_eq!(e00.payloads, 4);
        assert_eq!(e00.to, Coord::new(1, 0));
        assert_eq!(e00.label(), "(0,0)->E(1,0)");
    }

    #[test]
    fn nonexistent_mesh_edges_are_not_links() {
        let (p, topo) = probes_2x2();
        let r = p.report(&topo, 2, 2, 1);
        // 2x2 mesh: 4 bidirectional edges = 8 directed links, no wraps.
        assert_eq!(r.links.len(), 8);
        assert!(r.links.iter().all(|l| l.port != Port::Local));
    }

    #[test]
    fn peak_tracks_the_busiest_bucket_and_series_is_gap_free() {
        let (mut p, topo) = probes_2x2();
        let e = Port::East.index();
        // Bucket 0: 2 flits; long idle gap; bucket 3: 1 flit.
        p.record_traversal(0, e, 0, 10, false, 0, false);
        p.record_traversal(0, e, 0, 11, false, 0, false);
        p.record_traversal(0, e, 0, 3 * BUCKET_CYCLES + 1, false, 0, false);
        let r = p.report(&topo, 2, 2, 4 * BUCKET_CYCLES);
        let l = r
            .links
            .iter()
            .find(|l| l.from == Coord::new(0, 0) && l.port == Port::East)
            .unwrap();
        assert_eq!(l.peak_bucket_flits, 2);
        assert_eq!(r.series, vec![2, 0, 0, 1]);
    }

    #[test]
    fn fast_forward_jump_pads_interior_and_trailing_buckets() {
        let (mut p, topo) = probes_2x2();
        let e = Port::East.index();
        // One flit in bucket 1 and nothing afterwards; the clock then
        // fast-forwards far past the last traversal. Both the leading idle
        // bucket and every trailing one must appear as explicit zeros.
        p.record_traversal(0, e, 0, BUCKET_CYCLES + 5, false, 0, false);
        let r = p.report(&topo, 2, 2, 7 * BUCKET_CYCLES + 1);
        assert_eq!(r.series, vec![0, 1, 0, 0, 0, 0, 0, 0]);
        assert_eq!(r.series.len() as u64, r.cycles.div_ceil(r.bucket_cycles));
        assert_eq!(r.series.iter().sum::<u64>(), r.total_flits);
    }

    #[test]
    fn traffic_free_window_still_reconciles_series_length() {
        // A window that never saw a traversal (all idle fast-forward) must
        // still report one zero bucket per BUCKET_CYCLES of wall clock.
        let (p, topo) = probes_2x2();
        let r = p.report(&topo, 2, 2, 3 * BUCKET_CYCLES);
        assert_eq!(r.series, vec![0, 0, 0]);
        // Partial last bucket rounds up; empty window reports no buckets.
        let (p2, topo2) = probes_2x2();
        assert_eq!(p2.report(&topo2, 2, 2, 1).series, vec![0]);
        let (p3, topo3) = probes_2x2();
        assert_eq!(p3.report(&topo3, 2, 2, 0).series, Vec::<u64>::new());
    }

    #[test]
    fn band_split_records_bit_identically_to_sequential() {
        // Record the same traversals through the band views (plus the
        // barrier-merge series bump) and sequentially; reports must match.
        let (mut seq, topo) = probes_2x2();
        let e = Port::East.index();
        seq.record_traversal(0, e, 0, 5, true, 3, false);
        seq.record_traversal(1, e, 1, 5, false, 0, true);
        seq.record_traversal(2, e, 0, 5, false, 0, false);
        seq.record_blocked(3, e, 1);
        let (mut par, topo2) = probes_2x2();
        {
            // 2x2 mesh, two row bands: routers [0,2) and [2,4).
            let mut bands = par.split_bands(&[(0, 2), (2, 4)]);
            bands[0].record_traversal(0, e, 0, 5, true, 3, false);
            bands[0].record_traversal(1, e, 1, 5, false, 0, true);
            bands[1].record_traversal(2, e, 0, 5, false, 0, false);
            bands[1].record_blocked(3, e, 1);
        }
        par.bump_series(5 / BUCKET_CYCLES, 3);
        assert_eq!(par.report(&topo2, 2, 2, 10), seq.report(&topo, 2, 2, 10));
    }

    #[test]
    fn bottleneck_names_the_strictly_hottest_link() {
        let (mut p, topo) = probes_2x2();
        let e = Port::East.index();
        p.record_traversal(0, e, 0, 1, false, 0, false);
        p.record_traversal(2, e, 1, 1, false, 0, false);
        p.record_traversal(2, e, 1, 2, false, 0, false);
        p.record_blocked(2, e, 1);
        let r = p.report(&topo, 2, 2, 10);
        let b = r.bottleneck().unwrap();
        assert_eq!(b.from, Coord::new(0, 1));
        assert_eq!(b.port, Port::East);
        assert_eq!(b.vc, 1);
        assert_eq!(b.blocked_cycles, 1);
        assert_eq!(b.stage, BottleneckStage::Collection);
        assert!((b.utilization - 0.2).abs() < 1e-12);
        assert_eq!(r.total_blocked_cycles, 1);
    }

    #[test]
    fn stream_dominated_link_attributes_to_operand_streaming() {
        let (mut p, topo) = probes_2x2();
        let e = Port::East.index();
        p.record_traversal(0, e, 0, 1, true, 0, true);
        p.record_traversal(0, e, 0, 2, false, 0, true);
        p.record_traversal(0, e, 0, 3, true, 1, false);
        let r = p.report(&topo, 2, 2, 10);
        assert_eq!(r.bottleneck().unwrap().stage, BottleneckStage::OperandStreaming);
    }

    #[test]
    fn retransmission_dominated_link_attributes_to_its_own_class() {
        let (mut p, topo) = probes_2x2();
        let e = Port::East.index();
        p.record_traversal(0, e, 0, 1, true, 0, false);
        p.record_retransmission(0, e);
        p.record_retransmission(0, e);
        let r = p.report(&topo, 2, 2, 10);
        assert_eq!(r.total_retransmissions, 2);
        let l = r
            .links
            .iter()
            .find(|l| l.from == Coord::new(0, 0) && l.port == Port::East)
            .unwrap();
        assert_eq!(l.retx_flits, 2);
        // Retx (2) strictly dominates stream (0) and result (1) flits.
        assert_eq!(r.bottleneck().unwrap().stage, BottleneckStage::Retransmission);
        // Replays never join the traversal partition.
        assert_eq!(r.total_flits, 1);
    }

    #[test]
    fn empty_network_has_no_bottleneck() {
        let (p, topo) = probes_2x2();
        let r = p.report(&topo, 2, 2, 10);
        assert_eq!(r.bottleneck(), None);
        assert_eq!(r.max_utilization(), 0.0);
        assert_eq!(r.hottest(), None);
    }

    #[test]
    fn json_shape_carries_links_and_bottleneck() {
        let (mut p, topo) = probes_2x2();
        p.record_traversal(0, Port::East.index(), 0, 1, true, 2, false);
        let j = p.report(&topo, 2, 2, 10).to_json();
        assert_eq!(j.get("total_flits").unwrap().as_u64(), Some(1));
        assert_eq!(
            j.get("bottleneck").unwrap().get("stage").unwrap().as_str(),
            Some("collection")
        );
        let links = j.get("links").unwrap().as_arr().unwrap();
        assert_eq!(links.len(), 8);
        // Round-trips through the crate's JSON printer/parser.
        let back = crate::util::json::parse(&j.to_pretty()).unwrap();
        assert_eq!(back.get("total_flits").unwrap().as_u64(), Some(1));
    }
}
