//! The frozen **reference kernel**: the cycle-accurate simulator exactly as
//! it stood before the event-driven rewrite of [`super::network`].
//!
//! This module is the golden twin of the production kernel. It keeps the
//! original orchestration — full `rows×cols` router scans every cycle,
//! `BTreeMap` post schedules, O(routers·ports) quiescence checks — on top
//! of the *same* shared components (`router`, `buffer`, `gather`, `flit`,
//! `routing`, `stats`), so the two kernels can only diverge in the parts
//! the rewrite actually changed: scheduling and iteration order.
//!
//! Two things depend on it:
//!
//! * **the golden equivalence suite** (`tests/golden_kernel.rs`) drives
//!   both kernels through the [`SimKernel`] trait across the full seed
//!   matrix (3 collections × 2 dataflows × 3 streaming fabrics) and
//!   asserts bit-identical [`NetStats`] and final cycle counts;
//! * **`benches/sim_hotpath.rs`** times both kernels on the same
//!   workloads, so every bench run reports a true before/after speedup.
//!
//! Do **not** optimize this module; its value is staying byte-for-byte
//! faithful to the pre-refactor behavior. See `ARCHITECTURE.md`,
//! "Event-driven simulation core".

use std::collections::{BTreeMap, VecDeque};

use super::buffer::VcState;
use super::flit::{Coord, Flit, PacketDesc, PacketId, PacketType};
use super::gather::{effective_delta, try_board, try_board_mode, BoardMode, BoardOutcome, NiState};
use super::network::{Network, StreamEdge};
use super::router::{refresh_vc_state, RouterState};
use super::routing::{route, Algorithm, Port};
use super::stats::NetStats;
use crate::config::{Collection, SimConfig};

/// Uniform driving surface over the event-driven kernel and this frozen
/// reference kernel. The golden equivalence tests and the hot-path bench
/// are written once against this trait and instantiated for both.
pub trait SimKernel {
    /// Schedule `payloads` partial sums to become ready at `node` at
    /// cycle `at`, destined for the row memory element.
    fn post_result(&mut self, at: u64, node: Coord, payloads: u32);
    /// Schedule an operand stream over the mesh (gather-only fabric).
    fn post_operand_stream(&mut self, at: u64, edge: StreamEdge, words: u64);
    /// Run until `payloads_delivered >= target` or `max_cycle`.
    fn run_until_delivered(&mut self, target: u64, max_cycle: u64) -> bool;
    /// Run until `stream_tails_ejected >= target` or `max_cycle`.
    fn run_until_stream_tails(&mut self, target: u64, max_cycle: u64) -> bool;
    /// Drain everything scheduled; false on `max_cycle` overrun.
    fn run_until_idle(&mut self, max_cycle: u64) -> bool;
    fn stats(&self) -> &NetStats;
    fn cycle(&self) -> u64;
    fn payloads_delivered(&self) -> u64;
    fn stream_tails_ejected(&self) -> u64;
    /// Flits resident in router buffers (0 after a complete drain).
    fn buffered_flits(&self) -> usize;
    /// Result payloads still owned by the network (0 after a drain).
    fn payloads_in_flight(&self) -> u64;
}

impl SimKernel for Network {
    fn post_result(&mut self, at: u64, node: Coord, payloads: u32) {
        Network::post_result(self, at, node, payloads);
    }
    fn post_operand_stream(&mut self, at: u64, edge: StreamEdge, words: u64) {
        Network::post_operand_stream(self, at, edge, words);
    }
    fn run_until_delivered(&mut self, target: u64, max_cycle: u64) -> bool {
        self.run_until(|n| n.payloads_delivered >= target, max_cycle)
    }
    fn run_until_stream_tails(&mut self, target: u64, max_cycle: u64) -> bool {
        self.run_until(|n| n.stream_tails_ejected >= target, max_cycle)
    }
    fn run_until_idle(&mut self, max_cycle: u64) -> bool {
        Network::run_until_idle(self, max_cycle)
    }
    fn stats(&self) -> &NetStats {
        &self.stats
    }
    fn cycle(&self) -> u64 {
        self.cycle
    }
    fn payloads_delivered(&self) -> u64 {
        self.payloads_delivered
    }
    fn stream_tails_ejected(&self) -> u64 {
        self.stream_tails_ejected
    }
    fn buffered_flits(&self) -> usize {
        self.total_buffered_flits()
    }
    fn payloads_in_flight(&self) -> u64 {
        Network::payloads_in_flight(self)
    }
}

impl SimKernel for ReferenceNetwork {
    fn post_result(&mut self, at: u64, node: Coord, payloads: u32) {
        ReferenceNetwork::post_result(self, at, node, payloads);
    }
    fn post_operand_stream(&mut self, at: u64, edge: StreamEdge, words: u64) {
        ReferenceNetwork::post_operand_stream(self, at, edge, words);
    }
    fn run_until_delivered(&mut self, target: u64, max_cycle: u64) -> bool {
        self.run_until(|n| n.payloads_delivered >= target, max_cycle)
    }
    fn run_until_stream_tails(&mut self, target: u64, max_cycle: u64) -> bool {
        self.run_until(|n| n.stream_tails_ejected >= target, max_cycle)
    }
    fn run_until_idle(&mut self, max_cycle: u64) -> bool {
        ReferenceNetwork::run_until_idle(self, max_cycle)
    }
    fn stats(&self) -> &NetStats {
        &self.stats
    }
    fn cycle(&self) -> u64 {
        self.cycle
    }
    fn payloads_delivered(&self) -> u64 {
        self.payloads_delivered
    }
    fn stream_tails_ejected(&self) -> u64 {
        self.stream_tails_ejected
    }
    fn buffered_flits(&self) -> usize {
        self.total_buffered_flits()
    }
    fn payloads_in_flight(&self) -> u64 {
        ReferenceNetwork::payloads_in_flight(self)
    }
}

// ---------------------------------------------------------------------
// The frozen pre-refactor kernel. Everything below is the original
// `noc::network` implementation, renamed; comments are trimmed to the
// load-bearing ones (the production module carries the full docs).
// ---------------------------------------------------------------------

#[derive(Debug)]
struct Arrival {
    router: usize,
    port: Port,
    vc: usize,
    flit: Flit,
}

#[derive(Debug)]
struct InjEntry {
    desc: PacketDesc,
    from_ni: bool,
    not_before: u64,
}

#[derive(Debug, Default)]
struct Injector {
    queue: VecDeque<InjEntry>,
    cur: Option<(PacketDesc, u32, usize)>,
}

#[derive(Debug, Clone, Copy)]
struct NiPost {
    node: usize,
    payloads: u32,
    dst: Coord,
    space: u64,
}

/// The pre-refactor simulator (see module docs).
pub struct ReferenceNetwork {
    pub cfg: SimConfig,
    pub collection: Collection,
    alg: Algorithm,
    cols: usize,
    rows: usize,
    vcs: usize,
    routers: Vec<RouterState>,
    ni: Vec<NiState>,
    injectors: Vec<Injector>,
    arrivals: VecDeque<Vec<Arrival>>,
    credit_refunds: Vec<(usize, usize, usize)>,
    credit_scratch: Vec<(usize, usize, usize)>,
    ni_posts: BTreeMap<u64, Vec<NiPost>>,
    stream_posts: BTreeMap<u64, Vec<(usize, Port, PacketDesc)>>,
    pub stats: NetStats,
    pub cycle: u64,
    flits_active: u64,
    pub payloads_delivered: u64,
    pub stream_tails_ejected: u64,
    pub gather_packets_ejected: u64,
    pub result_packets_ejected: u64,
    pub last_eject_cycle: u64,
    backlogged_nodes: usize,
    occupancy: Vec<u32>,
    next_pid: PacketId,
}

const PORTS: usize = Port::COUNT;

impl ReferenceNetwork {
    pub fn new(cfg: &SimConfig, collection: Collection) -> Self {
        cfg.validate().expect("invalid SimConfig");
        let (cols, rows, vcs) = (cfg.mesh_cols, cfg.mesh_rows, cfg.vcs);
        let mut routers = Vec::with_capacity(cols * rows);
        for y in 0..rows {
            for x in 0..cols {
                let mut nb = [false; PORTS];
                nb[Port::North.index()] = y > 0;
                nb[Port::South.index()] = y + 1 < rows;
                nb[Port::East.index()] = x + 1 < cols;
                nb[Port::West.index()] = x > 0;
                nb[Port::Local.index()] = false;
                routers.push(RouterState::new(
                    Coord::new(x as u16, y as u16),
                    vcs,
                    cfg.buffer_depth,
                    &nb,
                ));
            }
        }
        let mut ni: Vec<NiState> = (0..cols * rows).map(|_| NiState::new()).collect();
        for y in 0..rows {
            ni[y * cols].is_initiator = true;
        }
        let link_window = (cfg.link_latency + 2) as usize;
        ReferenceNetwork {
            cfg: cfg.clone(),
            collection,
            alg: Algorithm::Xy,
            cols,
            rows,
            vcs,
            routers,
            ni,
            injectors: (0..cols * rows * PORTS).map(|_| Injector::default()).collect(),
            arrivals: (0..link_window).map(|_| Vec::new()).collect(),
            credit_refunds: Vec::new(),
            credit_scratch: Vec::new(),
            ni_posts: BTreeMap::new(),
            stream_posts: BTreeMap::new(),
            stats: NetStats::default(),
            cycle: 0,
            flits_active: 0,
            payloads_delivered: 0,
            stream_tails_ejected: 0,
            gather_packets_ejected: 0,
            result_packets_ejected: 0,
            last_eject_cycle: 0,
            backlogged_nodes: 0,
            occupancy: vec![0; cols * rows],
            next_pid: 1,
        }
    }

    #[inline]
    fn node_idx(&self, c: Coord) -> usize {
        c.y as usize * self.cols + c.x as usize
    }

    pub fn memory_of_row(&self, y: usize) -> Coord {
        Coord::new(self.cols as u16, y as u16)
    }

    fn alloc_pid(&mut self) -> PacketId {
        let id = self.next_pid;
        self.next_pid += 1;
        id
    }

    pub fn post_result(&mut self, at: u64, node: Coord, payloads: u32) {
        assert!(at >= self.cycle, "cannot post results in the past");
        let dst = self.memory_of_row(node.y as usize);
        let idx = self.node_idx(node);
        self.ni_posts
            .entry(at)
            .or_default()
            .push(NiPost { node: idx, payloads, dst, space: at });
    }

    pub fn post_operand_stream(&mut self, at: u64, edge: StreamEdge, words: u64) {
        assert!(at >= self.cycle, "cannot post streams in the past");
        let ppf = self.cfg.payloads_per_flit() as u64;
        let body = words.div_ceil(ppf).max(1);
        let (router, port, dst) = match edge {
            StreamEdge::Row(y) => (
                self.node_idx(Coord::new(0, y as u16)),
                Port::West,
                Coord::new(self.cols as u16 - 1, y as u16),
            ),
            StreamEdge::Col(x) => (
                self.node_idx(Coord::new(x as u16, 0)),
                Port::North,
                Coord::new(x as u16, self.rows as u16 - 1),
            ),
        };
        let src = match edge {
            StreamEdge::Row(y) => Coord::new(0, y as u16),
            StreamEdge::Col(x) => Coord::new(x as u16, 0),
        };
        let desc = PacketDesc {
            id: self.alloc_pid(),
            ptype: PacketType::Multicast,
            src,
            dst,
            len_flits: (1 + body) as u32,
            aspace: 0,
            space: 0,
            inject_cycle: at,
            deliver_along_path: true,
            carried_payloads: 0,
        };
        self.stream_posts.entry(at).or_default().push((router, port, desc));
    }

    pub fn next_event_cycle(&self) -> Option<u64> {
        let mut next: Option<u64> = None;
        let mut consider = |c: u64| {
            next = Some(next.map_or(c, |n: u64| n.min(c)));
        };
        if let Some((&c, _)) = self.ni_posts.iter().next() {
            consider(c);
        }
        if let Some((&c, _)) = self.stream_posts.iter().next() {
            consider(c);
        }
        for ni in &self.ni {
            if ni.armed && ni.pending > 0 {
                consider(ni.deadline.saturating_sub(self.cfg.kappa()).max(self.cycle + 1));
            }
        }
        next
    }

    pub fn quiescent(&self) -> bool {
        self.flits_active == 0
            && self.backlogged_nodes == 0
            && self.injectors.iter().all(|i| i.queue.is_empty() && i.cur.is_none())
    }

    pub fn run_until(
        &mut self,
        mut pred: impl FnMut(&ReferenceNetwork) -> bool,
        max_cycle: u64,
    ) -> bool {
        while self.cycle < max_cycle {
            if pred(self) {
                return true;
            }
            if self.quiescent() {
                match self.next_event_cycle() {
                    Some(c) if c > self.cycle => self.cycle = c,
                    Some(_) => {}
                    None => return pred(self),
                }
            }
            self.step();
        }
        pred(self)
    }

    pub fn run_until_idle(&mut self, max_cycle: u64) -> bool {
        self.run_until(
            |n| {
                n.quiescent()
                    && n.ni_posts.is_empty()
                    && n.stream_posts.is_empty()
                    && n.ni.iter().all(|s| !(s.armed && s.pending > 0))
            },
            max_cycle,
        )
    }

    pub fn step(&mut self) {
        self.apply_credit_refunds();
        self.deliver_arrivals();
        self.apply_posts();
        self.vc_allocate();
        self.switch_allocate();
        self.feed_injectors();
        self.gather_timeouts();
        self.drain_backlogs();
        self.cycle += 1;
        self.stats.cycles_simulated = self.cycle;
    }

    fn apply_credit_refunds(&mut self) {
        std::mem::swap(&mut self.credit_refunds, &mut self.credit_scratch);
        for &(router, out_port, vc) in &self.credit_scratch {
            if let Some(ct) = self.routers[router].out_credits[out_port].as_mut() {
                ct.refund(vc, self.cfg.buffer_depth);
            }
        }
        self.credit_scratch.clear();
    }

    fn deliver_arrivals(&mut self) {
        let mut batch = self.arrivals.pop_front().expect("arrival ring underflow");
        for Arrival { router, port, vc, mut flit } in batch.drain(..) {
            flit.arrival = self.cycle;
            if flit.ptype == PacketType::Gather
                && flit.is_head()
                && self.routers[router].coord != flit.src
            {
                let ni = &mut self.ni[router];
                match try_board(&mut flit, ni) {
                    BoardOutcome::BoardedAll(k) => {
                        self.stats.gather_boards += k as u64;
                    }
                    BoardOutcome::BoardedPartial(k) => {
                        self.stats.gather_boards += k as u64;
                        self.stage_own_gather(router);
                    }
                    BoardOutcome::Full => {
                        self.stage_own_gather(router);
                    }
                    BoardOutcome::NotApplicable => {}
                }
            } else if flit.ptype == PacketType::Ina
                && flit.is_head()
                && self.routers[router].coord != flit.src
            {
                let ni = &mut self.ni[router];
                if let BoardOutcome::BoardedAll(k) =
                    try_board_mode(&mut flit, ni, BoardMode::Accumulate)
                {
                    self.stats.ina_folds += k as u64;
                    self.stats.ina_adds += k as u64;
                }
            }
            self.write_flit(router, port, vc, flit);
        }
        self.arrivals.push_back(batch);
    }

    fn stage_own_gather(&mut self, node: usize) {
        let ni = &self.ni[node];
        if ni.staged || ni.pending == 0 {
            return;
        }
        let (ptype, len_flits, space) = match self.collection {
            Collection::Gather => (PacketType::Gather, self.cfg.gather_packet_flits as u32, 0),
            Collection::Ina => {
                (PacketType::Ina, self.cfg.ina_packet_flits(ni.pending), ni.space)
            }
            Collection::RepetitiveUnicast => unreachable!("RU never stages NI packets"),
        };
        let desc = PacketDesc {
            id: 0,
            ptype,
            src: self.routers[node].coord,
            dst: ni.dst,
            len_flits,
            aspace: 0,
            space,
            inject_cycle: self.cycle,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        self.injectors[node * PORTS + Port::Local.index()].queue.push_back(InjEntry {
            desc,
            from_ni: true,
            not_before: self.cycle + 1,
        });
        let ni = &mut self.ni[node];
        ni.staged = true;
        ni.armed = false;
    }

    fn write_flit(&mut self, router: usize, port: Port, vc: usize, flit: Flit) {
        let vcs = self.vcs;
        let r = &mut self.routers[router];
        let idx = port.index() * vcs + vc;
        let was_empty = r.inputs[idx].is_empty();
        if flit.is_head() {
            r.meta[idx].head_arrival = self.cycle;
        }
        r.inputs[idx].push(flit);
        r.nonempty_mask |= 1 << idx;
        self.occupancy[router] += 1;
        self.stats.buffer_writes += 1;
        if was_empty && r.inputs[idx].state == VcState::Idle {
            r.inputs[idx].state =
                refresh_vc_state(&r.inputs[idx], &mut r.meta[idx], self.cycle, self.cfg.kappa());
        }
    }

    fn apply_posts(&mut self) {
        while let Some((&c, _)) = self.stream_posts.iter().next() {
            if c > self.cycle {
                break;
            }
            let (_, entries) = self.stream_posts.pop_first().unwrap();
            for (router, port, desc) in entries {
                self.stats.packets_injected += 1;
                self.injectors[router * PORTS + port.index()]
                    .queue
                    .push_back(InjEntry { desc, from_ni: false, not_before: self.cycle });
            }
        }
        while let Some((&c, _)) = self.ni_posts.iter().next() {
            if c > self.cycle {
                break;
            }
            let (_, posts) = self.ni_posts.pop_first().unwrap();
            for post in posts {
                self.apply_ni_post(post);
            }
        }
    }

    fn apply_ni_post(&mut self, post: NiPost) {
        self.ni[post.node].dst = post.dst;
        if self.ni_busy(post.node) {
            self.ni[post.node].backlog.push_back((post.payloads, post.space));
            self.backlogged_nodes += 1;
        } else {
            self.activate_round(post.node, post.payloads, post.space);
        }
    }

    fn ni_busy(&self, node: usize) -> bool {
        let inj = &self.injectors[node * PORTS + Port::Local.index()];
        self.ni[node].pending > 0 || !inj.queue.is_empty() || inj.cur.is_some()
    }

    fn activate_round(&mut self, node: usize, payloads: u32, space: u64) {
        match self.collection {
            Collection::RepetitiveUnicast => {
                let per_pkt = if self.cfg.ru_pack_payloads {
                    (self.cfg.unicast_packet_flits as u32 - 1) * self.cfg.payloads_per_flit()
                } else {
                    1
                };
                let src = self.routers[node].coord;
                let dst = self.ni[node].dst;
                let mut remaining = payloads;
                while remaining > 0 {
                    let carried = remaining.min(per_pkt);
                    remaining -= carried;
                    let desc = PacketDesc {
                        id: self.alloc_pid(),
                        ptype: PacketType::Unicast,
                        src,
                        dst,
                        len_flits: self.cfg.unicast_packet_flits as u32,
                        aspace: 0,
                        space: 0,
                        inject_cycle: self.cycle,
                        deliver_along_path: false,
                        carried_payloads: carried,
                    };
                    self.stats.packets_injected += 1;
                    self.injectors[node * PORTS + Port::Local.index()]
                        .queue
                        .push_back(InjEntry { desc, from_ni: false, not_before: self.cycle });
                }
            }
            Collection::Gather => {
                let x = self.routers[node].coord.x;
                let ni = &mut self.ni[node];
                ni.pending += payloads;
                if ni.is_initiator {
                    ni.armed = true;
                    ni.deadline = self.cycle;
                } else if !ni.armed {
                    ni.armed = true;
                    ni.deadline =
                        self.cycle.saturating_add(effective_delta(self.cfg.delta, x));
                }
            }
            Collection::Ina => {
                let x = self.routers[node].coord.x;
                let ni = &mut self.ni[node];
                debug_assert_eq!(ni.pending, 0, "INA NI activates one round at a time");
                ni.pending += payloads;
                ni.space = space;
                ni.armed = true;
                ni.deadline = if ni.is_initiator {
                    self.cycle
                } else {
                    self.cycle.saturating_add(effective_delta(self.cfg.delta, x))
                };
            }
        }
    }

    fn drain_backlogs(&mut self) {
        if self.backlogged_nodes == 0 {
            return;
        }
        for node in 0..self.ni.len() {
            if self.ni[node].backlog.is_empty() || self.ni_busy(node) {
                continue;
            }
            let (payloads, space) = self.ni[node].backlog.pop_front().unwrap();
            self.backlogged_nodes -= 1;
            self.activate_round(node, payloads, space);
        }
    }

    fn vc_allocate(&mut self) {
        let vcs = self.vcs;
        for ridx in 0..self.routers.len() {
            let mut mask = self.routers[ridx].nonempty_mask;
            while mask != 0 {
                let idx = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                let dst = {
                    let r = &self.routers[ridx];
                    match (r.inputs[idx].state, r.inputs[idx].front()) {
                        (VcState::Routing { sa_ready_cycle }, Some(f))
                            if self.cycle + 1 >= sa_ready_cycle =>
                        {
                            f.dst
                        }
                        _ => continue,
                    }
                };
                let here = self.routers[ridx].coord;
                let out_port = route(self.alg, here, dst);
                let in_port = idx / vcs;
                let in_vc = idx % vcs;
                let granted =
                    self.routers[ridx].allocate_out_vc(out_port, vcs, (in_port, in_vc));
                if let Some(out_vc) = granted {
                    self.stats.vc_allocs += 1;
                    self.routers[ridx].inputs[idx].state = VcState::Active {
                        out_port: out_port.index(),
                        out_vc,
                    };
                }
            }
        }
    }

    fn switch_allocate(&mut self) {
        let vcs = self.vcs;
        let n = PORTS * vcs;
        for ridx in 0..self.routers.len() {
            if self.routers[ridx].nonempty_mask == 0 {
                continue;
            }
            let mut reqs = [[usize::MAX; 16]; PORTS];
            let mut counts = [0usize; PORTS];
            {
                let r = &self.routers[ridx];
                let mut mask = r.nonempty_mask;
                while mask != 0 {
                    let idx = mask.trailing_zeros() as usize;
                    mask &= mask - 1;
                    let buf = &r.inputs[idx];
                    let (op, ovc) = match buf.state {
                        VcState::Active { out_port, out_vc } => (out_port, out_vc),
                        _ => continue,
                    };
                    let Some(front) = buf.front() else { continue };
                    if front.arrival >= self.cycle {
                        continue;
                    }
                    if front.is_head() {
                        let head_ready = r.meta[idx].head_arrival + self.cfg.kappa() - 1;
                        let ready = head_ready.max(r.meta[idx].front_since + 1);
                        if self.cycle < ready {
                            continue;
                        }
                    }
                    if let Some(ct) = &r.out_credits[op] {
                        if !ct.available(ovc) {
                            continue;
                        }
                    }
                    reqs[op][counts[op]] = idx;
                    counts[op] += 1;
                }
            }
            if self.collection == Collection::Ina {
                self.merge_ina_requests(ridx, &mut reqs, &mut counts);
            }
            let mut in_port_used = [false; PORTS];
            for out_port_i in 0..PORTS {
                if counts[out_port_i] == 0 {
                    continue;
                }
                let rr = self.routers[ridx].sa_rr[out_port_i];
                let mut winner: Option<(usize, usize)> = None;
                for &idx in &reqs[out_port_i][..counts[out_port_i]] {
                    if in_port_used[idx / vcs] {
                        continue;
                    }
                    let dist = (idx + n - rr) % n;
                    if winner.map_or(true, |(d, _)| dist < d) {
                        winner = Some((dist, idx));
                    }
                }
                let Some((_, idx)) = winner else { continue };
                self.grant(ridx, idx, out_port_i);
                in_port_used[idx / vcs] = true;
                self.routers[ridx].sa_rr[out_port_i] = (idx + 1) % n;
            }
        }
    }

    fn grant(&mut self, ridx: usize, idx: usize, out_port_i: usize) {
        let vcs = self.vcs;
        let out_port = Port::from_index(out_port_i);
        let kappa = self.cfg.kappa();

        let out_vc = match self.routers[ridx].inputs[idx].state {
            VcState::Active { out_port: op, out_vc } => {
                debug_assert_eq!(op, out_port_i);
                out_vc
            }
            s => panic!("SA granted from non-active VC state {s:?}"),
        };

        let flit = self.routers[ridx].inputs[idx].pop().expect("SA granted an empty VC");
        if self.routers[ridx].inputs[idx].is_empty() {
            self.routers[ridx].nonempty_mask &= !(1 << idx);
        }
        self.occupancy[ridx] -= 1;
        self.stats.buffer_reads += 1;
        self.stats.sa_grants += 1;
        self.stats.crossbar_traversals += 1;
        self.stats.flit_hops += 1;

        if flit.deliver_along_path {
            self.stats.stream_deliveries += 1;
        }

        let in_port = Port::from_index(idx / vcs);
        let in_vc = idx % vcs;
        if in_port != Port::Local {
            let here = self.routers[ridx].coord;
            if let Some(up) = self.neighbour(here, in_port) {
                let up_idx = self.node_idx(up);
                self.credit_refunds.push((up_idx, in_port.opposite().index(), in_vc));
            }
        }

        if flit.is_tail() || flit.packet_len == 1 {
            self.routers[ridx].release_out_vc(out_port, out_vc, vcs);
            let r = &mut self.routers[ridx];
            r.inputs[idx].state = VcState::Idle;
            if !r.inputs[idx].is_empty() {
                r.inputs[idx].state =
                    refresh_vc_state(&r.inputs[idx], &mut r.meta[idx], self.cycle, kappa);
            }
        }

        let here = self.routers[ridx].coord;
        let ejecting = out_port == Port::Local
            || (out_port == Port::East
                && here.x as usize + 1 == self.cols
                && flit.dst.x as usize >= self.cols);
        if ejecting {
            self.eject(flit);
            self.flits_active -= 1;
        } else {
            if let Some(ct) = self.routers[ridx].out_credits[out_port_i].as_mut() {
                ct.consume(out_vc);
            }
            let nb = self
                .neighbour(here, out_port)
                .expect("routed toward a missing neighbour");
            let nb_idx = self.node_idx(nb);
            self.stats.link_traversals += 1;
            let delay = (1 + self.cfg.link_latency) as usize;
            self.arrivals[delay - 1].push(Arrival {
                router: nb_idx,
                port: out_port.opposite(),
                vc: out_vc,
                flit,
            });
        }
    }

    fn merge_ina_requests(
        &mut self,
        ridx: usize,
        reqs: &mut [[usize; 16]; PORTS],
        counts: &mut [usize; PORTS],
    ) {
        for op in 0..PORTS {
            if counts[op] < 2 {
                continue;
            }
            let mut i = 0;
            while i < counts[op] {
                let survivor = reqs[op][i];
                let Some(key) = self.ina_complete_head(ridx, survivor) else {
                    i += 1;
                    continue;
                };
                let mut j = i + 1;
                while j < counts[op] {
                    let candidate = reqs[op][j];
                    if self.ina_complete_head(ridx, candidate) == Some(key) {
                        self.absorb_ina_packet(ridx, candidate, survivor);
                        for k in j..counts[op] - 1 {
                            reqs[op][k] = reqs[op][k + 1];
                        }
                        counts[op] -= 1;
                    } else {
                        j += 1;
                    }
                }
                i += 1;
            }
        }
    }

    fn ina_complete_head(&self, ridx: usize, idx: usize) -> Option<(u64, Coord)> {
        let buf = &self.routers[ridx].inputs[idx];
        let head = buf.front()?;
        if head.ptype != PacketType::Ina || !head.is_head() {
            return None;
        }
        let len = head.packet_len as usize;
        let tail = buf.get(len - 1)?;
        if tail.packet_id != head.packet_id {
            return None;
        }
        if len > 1 && !tail.is_tail() {
            return None;
        }
        Some((head.space, head.dst))
    }

    fn absorb_ina_packet(&mut self, ridx: usize, absorbed: usize, survivor: usize) {
        let vcs = self.vcs;
        let kappa = self.cfg.kappa();
        let (pid, len, carried, words) = {
            let f = self.routers[ridx].inputs[absorbed].front().expect("absorbed VC empty");
            (f.packet_id, f.packet_len as usize, f.carried_payloads, f.aspace)
        };
        match self.routers[ridx].inputs[absorbed].state {
            VcState::Active { out_port, out_vc } => {
                self.routers[ridx].release_out_vc(Port::from_index(out_port), out_vc, vcs);
            }
            s => panic!("INA merge on non-active VC state {s:?}"),
        }
        for _ in 0..len {
            let f = self.routers[ridx].inputs[absorbed].pop().expect("absorbed packet truncated");
            debug_assert_eq!(f.packet_id, pid, "absorbed a foreign flit");
        }
        self.occupancy[ridx] -= len as u32;
        self.flits_active -= len as u64;
        self.stats.buffer_reads += len as u64;
        self.stats.ina_merges += 1;
        self.stats.ina_adds += words as u64;
        let in_port = Port::from_index(absorbed / vcs);
        if in_port != Port::Local {
            let here = self.routers[ridx].coord;
            if let Some(up) = self.neighbour(here, in_port) {
                let up_idx = self.node_idx(up);
                for _ in 0..len {
                    self.credit_refunds.push((up_idx, in_port.opposite().index(), absorbed % vcs));
                }
            }
        }
        {
            let r = &mut self.routers[ridx];
            r.inputs[absorbed].state = VcState::Idle;
            if r.inputs[absorbed].is_empty() {
                r.nonempty_mask &= !(1 << absorbed);
            } else {
                r.inputs[absorbed].state = refresh_vc_state(
                    &r.inputs[absorbed],
                    &mut r.meta[absorbed],
                    self.cycle,
                    kappa,
                );
            }
        }
        let head = self.routers[ridx].inputs[survivor]
            .front_mut()
            .expect("survivor VC empty");
        debug_assert!(head.is_head() && head.ptype == PacketType::Ina);
        head.carried_payloads += carried;
        head.aspace = head.aspace.max(words);
    }

    fn eject(&mut self, flit: Flit) {
        self.stats.flits_ejected += 1;
        if flit.is_head() && flit.dst.x as usize >= self.cols {
            self.payloads_delivered += flit.carried_payloads as u64;
            if flit.ptype == PacketType::Gather {
                self.gather_packets_ejected += 1;
            }
        }
        if flit.is_tail() || flit.packet_len == 1 {
            self.stats.packets_ejected += 1;
            let lat = self.cycle.saturating_sub(flit.inject_cycle);
            self.stats.total_packet_latency += lat;
            self.stats.max_packet_latency = self.stats.max_packet_latency.max(lat);
            self.last_eject_cycle = self.cycle;
            if flit.deliver_along_path {
                self.stream_tails_ejected += 1;
            }
            if flit.dst.x as usize >= self.cols {
                self.result_packets_ejected += 1;
            }
        }
    }

    fn neighbour(&self, c: Coord, p: Port) -> Option<Coord> {
        match p {
            Port::North => (c.y > 0).then(|| Coord::new(c.x, c.y - 1)),
            Port::South => ((c.y as usize + 1) < self.rows).then(|| Coord::new(c.x, c.y + 1)),
            Port::East => ((c.x as usize + 1) < self.cols).then(|| Coord::new(c.x + 1, c.y)),
            Port::West => (c.x > 0).then(|| Coord::new(c.x - 1, c.y)),
            Port::Local => None,
        }
    }

    fn feed_injectors(&mut self) {
        for ridx in 0..self.routers.len() {
            for port_i in 0..PORTS {
                let ii = ridx * PORTS + port_i;
                if self.injectors[ii].cur.is_none() && self.injectors[ii].queue.is_empty() {
                    continue;
                }
                self.feed_one_injector(ridx, Port::from_index(port_i));
            }
        }
    }

    fn feed_one_injector(&mut self, ridx: usize, port: Port) {
        let ii = ridx * PORTS + port.index();
        if self.injectors[ii].cur.is_none() {
            let ready = match self.injectors[ii].queue.front() {
                Some(e) => e.not_before <= self.cycle,
                None => return,
            };
            if !ready {
                return;
            }
            let entry = self.injectors[ii].queue.pop_front().unwrap();
            let mut desc = entry.desc;
            if entry.from_ni {
                let cap = self.cfg.gather_capacity();
                let x = self.routers[ridx].coord.x;
                let collection = self.collection;
                let delta = self.cfg.delta;
                let cycle = self.cycle;
                let ni = &mut self.ni[ridx];
                ni.staged = false;
                if ni.pending == 0 {
                    return;
                }
                let carried = match collection {
                    Collection::Gather => ni.pending.min(cap),
                    Collection::Ina => ni.pending,
                    Collection::RepetitiveUnicast => {
                        unreachable!("RU never stages NI packets")
                    }
                };
                ni.pending -= carried;
                if ni.pending == 0 {
                    ni.armed = false;
                } else {
                    ni.armed = true;
                    ni.deadline = cycle.saturating_add(effective_delta(delta, x));
                }
                desc.carried_payloads = carried;
                desc.aspace = match collection {
                    Collection::Gather => cap - carried,
                    _ => carried,
                };
                desc.id = self.alloc_pid();
                desc.inject_cycle = self.cycle;
                self.stats.packets_injected += 1;
            }
            self.injectors[ii].cur = Some((desc, 0, usize::MAX));
        }
        let vcs = self.vcs;
        let Some((desc, seq, vc_slot)) = self.injectors[ii].cur.take() else { return };
        let mut vc = vc_slot;
        if seq == 0 {
            let r = &self.routers[ridx];
            let base = port.index() * vcs;
            vc = (0..vcs)
                .max_by_key(|&v| self.cfg.buffer_depth - r.inputs[base + v].len())
                .unwrap();
        }
        let idx = port.index() * vcs + vc;
        if self.routers[ridx].inputs[idx].has_space() {
            let flit = {
                let mut f = desc.flit(seq);
                f.arrival = self.cycle;
                f
            };
            self.write_flit(ridx, port, vc, flit);
            self.flits_active += 1;
            let next = seq + 1;
            if next < desc.len_flits {
                self.injectors[ii].cur = Some((desc, next, vc));
            }
        } else {
            self.injectors[ii].cur = Some((desc, seq, vc));
        }
    }

    fn gather_timeouts(&mut self) {
        if self.collection == Collection::RepetitiveUnicast {
            return;
        }
        for ridx in 0..self.ni.len() {
            let ni = &self.ni[ridx];
            if !(ni.armed && ni.pending > 0 && !ni.staged) {
                continue;
            }
            if self.cycle < ni.deadline {
                continue;
            }
            let is_initiator = ni.is_initiator;
            self.stage_own_gather(ridx);
            if !is_initiator {
                self.stats.delta_expiries += 1;
            }
        }
    }

    pub fn total_buffered_flits(&self) -> usize {
        self.routers.iter().map(|r| r.occupancy()).sum()
    }

    pub fn payloads_in_flight(&self) -> u64 {
        let mut total = 0u64;
        for posts in self.ni_posts.values() {
            total += posts.iter().map(|p| p.payloads as u64).sum::<u64>();
        }
        for ni in &self.ni {
            total += ni.pending as u64;
            total += ni.backlog.iter().map(|&(p, _)| p as u64).sum::<u64>();
        }
        for inj in &self.injectors {
            for e in &inj.queue {
                if !e.from_ni {
                    total += e.desc.carried_payloads as u64;
                }
            }
            if let Some((desc, seq, _)) = &inj.cur {
                if *seq == 0 {
                    total += desc.carried_payloads as u64;
                }
            }
        }
        for r in &self.routers {
            for buf in &r.inputs {
                total += buf
                    .iter()
                    .filter(|f| f.is_head())
                    .map(|f| f.carried_payloads as u64)
                    .sum::<u64>();
            }
        }
        for batch in &self.arrivals {
            total += batch
                .iter()
                .filter(|a| a.flit.is_head())
                .map(|a| a.flit.carried_payloads as u64)
                .sum::<u64>();
        }
        total
    }
}
