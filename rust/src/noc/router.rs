//! Per-router state: input VC buffers, credit trackers toward each
//! neighbour, output-VC allocation state and switch-allocation arbitration
//! pointers.
//!
//! The pipeline (Fig. 7) is modelled at flit granularity:
//!
//! * A **head** flit written into a VC buffer at cycle `t` finishes route
//!   computation + VC allocation no earlier than `t + κ − 2` and may compete
//!   for the switch from `t + κ − 1`; switch traversal takes one more cycle,
//!   so an uncontended head leaves the router `κ` cycles after arrival —
//!   matching Table 1's "router: 4 cycles".
//! * **Body/tail** flits inherit the route/VC of their head and only use the
//!   SA/ST stages; the otherwise idle RC/VA slots are what the gather
//!   support uses to fill payloads (Fig. 7, "Modified router pipeline") —
//!   which is why gather boarding adds zero latency in [`super::network`].

use super::buffer::{CreditTracker, VcBuffer, VcState};
use super::flit::{Coord, Flit, FlitLike};
use super::routing::Port;

/// Per-VC pipeline bookkeeping (parallel array to the VC buffers).
#[derive(Debug, Clone, Copy)]
pub struct VcMeta {
    /// Cycle the current head flit was written into this buffer.
    pub head_arrival: u64,
    /// Cycle the current front flit became the front of the FIFO.
    pub front_since: u64,
}

impl Default for VcMeta {
    fn default() -> Self {
        VcMeta { head_arrival: 0, front_since: 0 }
    }
}

/// One router's complete state, generic over the buffered flit
/// representation exactly like [`VcBuffer`] (the wide [`Flit`] default
/// keeps the frozen reference kernel compiling unchanged).
#[derive(Debug)]
pub struct RouterState<F = Flit> {
    pub coord: Coord,
    /// Input VC buffers, indexed `port * vcs + vc`.
    pub inputs: Vec<VcBuffer<F>>,
    /// Pipeline metadata parallel to `inputs`.
    pub meta: Vec<VcMeta>,
    /// Credits we hold toward the downstream input port behind each of our
    /// output ports. `None` for ports with no consumer (mesh edge) and for
    /// ejection ports, which sink flits unconditionally.
    pub out_credits: Vec<Option<CreditTracker>>,
    /// Which input VC currently holds each output VC, indexed
    /// `port * vcs + vc`. An output VC is held from head VA grant to tail
    /// switch traversal (wormhole).
    pub out_vc_holder: Vec<Option<(usize, usize)>>,
    /// Round-robin arbitration pointer per output port (over the flattened
    /// input-VC index space).
    pub sa_rr: Vec<usize>,
    /// Bit per input VC (bit `port*vcs+vc`): set while that buffer holds
    /// any flit. Lets the VA/SA stages walk only occupied VCs instead of
    /// scanning all ports×VCs (EXPERIMENTS.md §Perf).
    pub nonempty_mask: u32,
}

impl<F> RouterState<F> {
    pub fn new(coord: Coord, vcs: usize, depth: usize, neighbour_ports: &[bool; Port::COUNT]) -> Self {
        let n_in = Port::COUNT * vcs;
        RouterState {
            coord,
            inputs: (0..n_in).map(|_| VcBuffer::new(depth)).collect(),
            meta: vec![VcMeta::default(); n_in],
            out_credits: (0..Port::COUNT)
                .map(|p| neighbour_ports[p].then(|| CreditTracker::new(vcs, depth)))
                .collect(),
            out_vc_holder: vec![None; n_in],
            sa_rr: vec![0; Port::COUNT],
            nonempty_mask: 0,
        }
    }

    /// Flattened input index.
    #[inline]
    pub fn ivc(&self, port: Port, vc: usize, vcs: usize) -> usize {
        port.index() * vcs + vc
    }

    /// Number of flits buffered in this router (all ports, all VCs).
    pub fn occupancy(&self) -> usize {
        self.inputs.iter().map(|b| b.len()).sum()
    }

    /// Try to allocate a free output VC on `out_port`. Returns the granted
    /// VC index. Prefers the VC with the most downstream credits so long
    /// packets pick the least-congested lane.
    pub fn allocate_out_vc(&mut self, out_port: Port, vcs: usize, holder: (usize, usize)) -> Option<usize> {
        self.allocate_out_vc_range(out_port, 0, vcs, vcs, holder)
    }

    /// [`RouterState::allocate_out_vc`] restricted to the VC index range
    /// `lo..hi` — the topology dateline rule confines a packet to one VC
    /// class per link (see [`super::topology::Topology::vc_class`]); the
    /// unrestricted call is the full range, so mesh behavior is untouched.
    pub fn allocate_out_vc_range(
        &mut self,
        out_port: Port,
        lo: usize,
        hi: usize,
        vcs: usize,
        holder: (usize, usize),
    ) -> Option<usize> {
        let base = out_port.index() * vcs;
        let mut best: Option<(usize, u32)> = None;
        for vc in lo..hi.min(vcs) {
            if self.out_vc_holder[base + vc].is_none() {
                let credits = match &self.out_credits[out_port.index()] {
                    Some(ct) => ct.count(vc),
                    None => u32::MAX, // ejection port: always free
                };
                if best.map_or(true, |(_, c)| credits > c) {
                    best = Some((vc, credits));
                }
            }
        }
        let (vc, _) = best?;
        self.out_vc_holder[base + vc] = Some(holder);
        Some(vc)
    }

    /// Release an output VC after the tail flit traversed the switch.
    pub fn release_out_vc(&mut self, out_port: Port, vc: usize, vcs: usize) {
        let slot = &mut self.out_vc_holder[out_port.index() * vcs + vc];
        debug_assert!(slot.is_some(), "releasing an unheld output VC");
        *slot = None;
    }
}

/// State transitions of an input VC when its front flit changes.
/// Returns the new state given the (possibly new) front flit.
pub fn refresh_vc_state<F: FlitLike>(
    buf: &VcBuffer<F>,
    meta: &mut VcMeta,
    cycle: u64,
    kappa: u64,
) -> VcState {
    match buf.front() {
        None => VcState::Idle,
        Some(f) if f.is_head() => {
            meta.front_since = cycle;
            // RC+VA occupy κ−2 cycles from buffer write; SA may start at
            // κ−1. A head that waited blocked at the front re-enters with
            // only a single-cycle re-arbitration penalty.
            let sa_ready = (meta.head_arrival + kappa - 1).max(cycle + 1);
            VcState::Routing { sa_ready_cycle: sa_ready }
        }
        Some(_) => {
            // Body/tail at the front with no head: the packet's head already
            // departed, VC remains Active — the caller must not have reset
            // the state. Reaching here is a protocol bug.
            unreachable!("body/tail flit at VC front without an active packet state")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::flit::{PacketDesc, PacketType};

    fn router() -> RouterState {
        RouterState::new(Coord::new(1, 1), 2, 4, &[true, true, true, true, false])
    }

    #[test]
    fn out_vc_allocation_prefers_most_credits() {
        let mut r = router();
        // Consume 2 credits on East vc0 so vc1 has more.
        if let Some(ct) = r.out_credits[Port::East.index()].as_mut() {
            ct.consume(0);
            ct.consume(0);
        }
        let vc = r.allocate_out_vc(Port::East, 2, (0, 0)).unwrap();
        assert_eq!(vc, 1);
        // Next allocation must take the remaining VC.
        let vc2 = r.allocate_out_vc(Port::East, 2, (0, 1)).unwrap();
        assert_eq!(vc2, 0);
        // All VCs held: no grant.
        assert!(r.allocate_out_vc(Port::East, 2, (1, 0)).is_none());
        r.release_out_vc(Port::East, 1, 2);
        assert!(r.allocate_out_vc(Port::East, 2, (1, 0)).is_some());
    }

    #[test]
    fn range_allocation_confines_the_vc_class() {
        let mut r = router();
        // Class 1 on 2 VCs = index range 1..2 only.
        let vc = r.allocate_out_vc_range(Port::East, 1, 2, 2, (0, 0)).unwrap();
        assert_eq!(vc, 1);
        // Class 1 exhausted even though VC0 is free.
        assert!(r.allocate_out_vc_range(Port::East, 1, 2, 2, (1, 0)).is_none());
        // Class 0 still allocates.
        assert_eq!(r.allocate_out_vc_range(Port::East, 0, 1, 2, (1, 0)), Some(0));
    }

    #[test]
    fn head_sa_ready_respects_pipeline_depth() {
        let mut buf = VcBuffer::new(4);
        let d = PacketDesc {
            id: 1,
            ptype: PacketType::Unicast,
            src: Coord::new(0, 0),
            dst: Coord::new(3, 0),
            len_flits: 2,
            aspace: 0,
            space: 0,
            inject_cycle: 10,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        buf.push(d.flit(0));
        let mut meta = VcMeta { head_arrival: 10, front_since: 10 };
        let st = refresh_vc_state(&buf, &mut meta, 10, 4);
        match st {
            VcState::Routing { sa_ready_cycle } => assert_eq!(sa_ready_cycle, 13), // t + κ − 1
            _ => panic!("expected Routing"),
        }
    }

    #[test]
    fn blocked_head_pays_single_rearbitration_cycle() {
        let mut buf = VcBuffer::new(4);
        let d = PacketDesc {
            id: 1,
            ptype: PacketType::Unicast,
            src: Coord::new(0, 0),
            dst: Coord::new(3, 0),
            len_flits: 2,
            aspace: 0,
            space: 0,
            inject_cycle: 10,
            deliver_along_path: false,
            carried_payloads: 0,
        };
        buf.push(d.flit(0));
        // Head arrived long ago but only reached the FIFO front now (cycle 50).
        let mut meta = VcMeta { head_arrival: 10, front_since: 50 };
        match refresh_vc_state(&buf, &mut meta, 50, 4) {
            VcState::Routing { sa_ready_cycle } => assert_eq!(sa_ready_cycle, 51),
            _ => panic!("expected Routing"),
        }
    }
}
