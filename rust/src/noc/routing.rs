//! Route computation. The paper uses deadlock-free XY (dimension-order)
//! routing for all packet types, including gather packets (§4.1).

use super::flit::Coord;

/// Router ports. `Local` is the NI/PE side; `Eject` is the east-edge memory
/// element port (only wired on the memory column).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Port {
    North = 0,
    South = 1,
    East = 2,
    West = 3,
    Local = 4,
}

impl Port {
    pub const COUNT: usize = 5;

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Port {
        match i {
            0 => Port::North,
            1 => Port::South,
            2 => Port::East,
            3 => Port::West,
            4 => Port::Local,
            _ => panic!("invalid port index {i}"),
        }
    }

    /// One-letter label for compact link names in probe reports
    /// (`(6,2)->E(7,2)`).
    pub fn letter(self) -> char {
        match self {
            Port::North => 'N',
            Port::South => 'S',
            Port::East => 'E',
            Port::West => 'W',
            Port::Local => 'L',
        }
    }

    /// The port on the neighbouring router that receives what we emit from
    /// this output port (links connect opposite ports).
    pub fn opposite(self) -> Port {
        match self {
            Port::North => Port::South,
            Port::South => Port::North,
            Port::East => Port::West,
            Port::West => Port::East,
            Port::Local => Port::Local,
        }
    }
}

/// Routing algorithm selector. XY is the paper's choice; YX exists to
/// exercise the router model independently of the algorithm in tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Xy,
    Yx,
}

/// Compute the output port at router `here` for a packet headed to `dst`.
/// Returns `Port::Local` when the packet has arrived.
pub fn route(alg: Algorithm, here: Coord, dst: Coord) -> Port {
    match alg {
        Algorithm::Xy => {
            if dst.x > here.x {
                Port::East
            } else if dst.x < here.x {
                Port::West
            } else if dst.y > here.y {
                Port::South
            } else if dst.y < here.y {
                Port::North
            } else {
                Port::Local
            }
        }
        Algorithm::Yx => {
            if dst.y > here.y {
                Port::South
            } else if dst.y < here.y {
                Port::North
            } else if dst.x > here.x {
                Port::East
            } else if dst.x < here.x {
                Port::West
            } else {
                Port::Local
            }
        }
    }
}

/// The full XY path from `src` to `dst`, inclusive of both endpoints.
/// Used by tests and by the gather bookkeeping to reason about which
/// routers a packet visits.
pub fn xy_path(src: Coord, dst: Coord) -> Vec<Coord> {
    let mut path = vec![src];
    let mut cur = src;
    while cur != dst {
        let p = route(Algorithm::Xy, cur, dst);
        cur = match p {
            Port::East => Coord::new(cur.x + 1, cur.y),
            Port::West => Coord::new(cur.x - 1, cur.y),
            Port::South => Coord::new(cur.x, cur.y + 1),
            Port::North => Coord::new(cur.x, cur.y - 1),
            Port::Local => unreachable!("route() returned Local before arrival"),
        };
        path.push(cur);
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xy_routes_x_first() {
        let here = Coord::new(2, 2);
        assert_eq!(route(Algorithm::Xy, here, Coord::new(5, 0)), Port::East);
        assert_eq!(route(Algorithm::Xy, here, Coord::new(0, 5)), Port::West);
        assert_eq!(route(Algorithm::Xy, here, Coord::new(2, 5)), Port::South);
        assert_eq!(route(Algorithm::Xy, here, Coord::new(2, 0)), Port::North);
        assert_eq!(route(Algorithm::Xy, here, here), Port::Local);
    }

    #[test]
    fn yx_routes_y_first() {
        let here = Coord::new(2, 2);
        assert_eq!(route(Algorithm::Yx, here, Coord::new(5, 0)), Port::North);
        assert_eq!(route(Algorithm::Yx, here, Coord::new(5, 2)), Port::East);
    }

    #[test]
    fn xy_path_length_is_manhattan_plus_one() {
        let s = Coord::new(1, 6);
        let d = Coord::new(6, 2);
        let p = xy_path(s, d);
        assert_eq!(p.len() as u64, s.manhattan(&d) + 1);
        assert_eq!(p[0], s);
        assert_eq!(*p.last().unwrap(), d);
        // X-first: all X movement happens before any Y movement.
        let turn = p.iter().position(|c| c.x == d.x).unwrap();
        for w in p[..=turn].windows(2) {
            assert_eq!(w[0].y, w[1].y, "moved in Y before finishing X");
        }
    }

    #[test]
    fn opposite_ports() {
        assert_eq!(Port::East.opposite(), Port::West);
        assert_eq!(Port::North.opposite(), Port::South);
        assert_eq!(Port::from_index(Port::East.index()), Port::East);
    }
}
