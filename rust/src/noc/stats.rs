//! Simulation statistics: latency, hop, flit and energy-event counters.
//!
//! Energy is accounted as *event counts* here; `crate::power` converts the
//! counts into joules with the 45 nm constants. Keeping raw counts in the
//! simulator makes the power model swappable and the counters testable.


/// Raw event counters produced by one simulation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetStats {
    /// Packets injected into the mesh.
    pub packets_injected: u64,
    /// Packets fully ejected at their destination.
    pub packets_ejected: u64,
    /// Flits ejected.
    pub flits_ejected: u64,
    /// Sum over ejected packets of (eject cycle − inject cycle).
    pub total_packet_latency: u64,
    /// Max single-packet latency observed.
    pub max_packet_latency: u64,
    /// Total flit-hops (a flit crossing one router counts one).
    pub flit_hops: u64,
    /// Buffer write events (flit enters a VC buffer).
    pub buffer_writes: u64,
    /// Buffer read events (flit leaves a VC buffer).
    pub buffer_reads: u64,
    /// Crossbar traversal events.
    pub crossbar_traversals: u64,
    /// VC allocation events (head flits).
    pub vc_allocs: u64,
    /// Switch allocation grants.
    pub sa_grants: u64,
    /// Link traversal events (flit crosses an inter-router link).
    pub link_traversals: u64,
    /// Gather payloads that boarded a passing gather packet.
    pub gather_boards: u64,
    /// Partial-sum accumulate operations performed at the NIs before
    /// collection (Weight-Stationary register-file spill; see
    /// `crate::dataflow::ws`). Reported by the round driver from the
    /// mapping's `PsumCollection`, charged by `crate::power`.
    pub ni_accumulations: u64,
    /// INA (`Collection::Ina`): payloads folded into a passing INA packet
    /// at the NI boarding point of a transit router (the accumulate
    /// analogue of `gather_boards` — adds instead of slot fills).
    pub ina_folds: u64,
    /// INA: whole packets absorbed into a same-space packet during switch
    /// allocation (the router merge point; the absorbed packet's flits
    /// never traverse the crossbar).
    pub ina_merges: u64,
    /// INA: router ALU add operations (one per psum word folded at an NI
    /// or merged from an absorbed packet); priced by `crate::power`.
    pub ina_adds: u64,
    /// Gather packets initiated after a δ timeout expiry (not counting the
    /// hardwired leftmost initiator).
    pub delta_expiries: u64,
    /// Operand words delivered to router-local NIs by mesh multicast
    /// streams (`deliver_along_path` flits), one count per flit per router
    /// traversed.
    pub stream_deliveries: u64,
    /// Words delivered over the streaming buses (per-row/column counters are
    /// in `BusStats`).
    pub cycles_simulated: u64,
    /// Fault injection (`SimConfig::faults`): delivery attempts that
    /// failed the corruption roll at a link's receiver.
    pub flits_corrupted: u64,
    /// Fault injection: replays performed from link retransmission slots.
    pub retransmissions: u64,
    /// Fault injection: head flits whose retry budget ran out (their
    /// packet is dropped whole).
    pub retries_exhausted: u64,
    /// Fault injection: flits discarded (poisoned packets, arrivals on
    /// dead links/routers).
    pub flits_dropped: u64,
    /// Fault injection: packets dropped whole after retry exhaustion or a
    /// dead-link arrival.
    pub packets_dropped: u64,
    /// Fault-aware routing: hops taken off the fabric's fault-free route
    /// while steering around the fault region.
    pub detour_hops: u64,
}

impl NetStats {
    pub fn avg_packet_latency(&self) -> f64 {
        if self.packets_ejected == 0 {
            0.0
        } else {
            self.total_packet_latency as f64 / self.packets_ejected as f64
        }
    }

    /// Merge counters from another run segment (used by the round
    /// extrapolation to combine warmup + measured segments).
    pub fn merge(&mut self, other: &NetStats) {
        self.packets_injected += other.packets_injected;
        self.packets_ejected += other.packets_ejected;
        self.flits_ejected += other.flits_ejected;
        self.total_packet_latency += other.total_packet_latency;
        self.max_packet_latency = self.max_packet_latency.max(other.max_packet_latency);
        self.flit_hops += other.flit_hops;
        self.buffer_writes += other.buffer_writes;
        self.buffer_reads += other.buffer_reads;
        self.crossbar_traversals += other.crossbar_traversals;
        self.vc_allocs += other.vc_allocs;
        self.sa_grants += other.sa_grants;
        self.link_traversals += other.link_traversals;
        self.gather_boards += other.gather_boards;
        self.ni_accumulations += other.ni_accumulations;
        self.ina_folds += other.ina_folds;
        self.ina_merges += other.ina_merges;
        self.ina_adds += other.ina_adds;
        self.delta_expiries += other.delta_expiries;
        self.stream_deliveries += other.stream_deliveries;
        self.cycles_simulated = self.cycles_simulated.max(other.cycles_simulated);
        self.flits_corrupted += other.flits_corrupted;
        self.retransmissions += other.retransmissions;
        self.retries_exhausted += other.retries_exhausted;
        self.flits_dropped += other.flits_dropped;
        self.packets_dropped += other.packets_dropped;
        self.detour_hops += other.detour_hops;
    }

    /// Scale all additive counters by `k` (round extrapolation).
    pub fn scaled(&self, k: f64) -> NetStats {
        let s = |v: u64| (v as f64 * k).round() as u64;
        NetStats {
            packets_injected: s(self.packets_injected),
            packets_ejected: s(self.packets_ejected),
            flits_ejected: s(self.flits_ejected),
            total_packet_latency: s(self.total_packet_latency),
            max_packet_latency: self.max_packet_latency,
            flit_hops: s(self.flit_hops),
            buffer_writes: s(self.buffer_writes),
            buffer_reads: s(self.buffer_reads),
            crossbar_traversals: s(self.crossbar_traversals),
            vc_allocs: s(self.vc_allocs),
            sa_grants: s(self.sa_grants),
            link_traversals: s(self.link_traversals),
            gather_boards: s(self.gather_boards),
            ni_accumulations: s(self.ni_accumulations),
            ina_folds: s(self.ina_folds),
            ina_merges: s(self.ina_merges),
            ina_adds: s(self.ina_adds),
            delta_expiries: s(self.delta_expiries),
            stream_deliveries: s(self.stream_deliveries),
            cycles_simulated: self.cycles_simulated,
            flits_corrupted: s(self.flits_corrupted),
            retransmissions: s(self.retransmissions),
            retries_exhausted: s(self.retries_exhausted),
            flits_dropped: s(self.flits_dropped),
            packets_dropped: s(self.packets_dropped),
            detour_hops: s(self.detour_hops),
        }
    }
}

/// Streaming-bus event counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BusStats {
    /// Words driven on row (input activation) buses.
    pub row_words: u64,
    /// Words driven on column (weight) buses.
    pub col_words: u64,
    /// Cycles any bus was active.
    pub active_cycles: u64,
}

impl BusStats {
    pub fn merge(&mut self, other: &BusStats) {
        self.row_words += other.row_words;
        self.col_words += other.col_words;
        self.active_cycles += other.active_cycles;
    }

    pub fn scaled(&self, k: f64) -> BusStats {
        let s = |v: u64| (v as f64 * k).round() as u64;
        BusStats {
            row_words: s(self.row_words),
            col_words: s(self.col_words),
            active_cycles: s(self.active_cycles),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_latency_handles_zero_packets() {
        assert_eq!(NetStats::default().avg_packet_latency(), 0.0);
    }

    #[test]
    fn merge_adds_counters() {
        let mut a = NetStats { packets_ejected: 2, total_packet_latency: 100, ..Default::default() };
        let b = NetStats { packets_ejected: 3, total_packet_latency: 50, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.packets_ejected, 5);
        assert_eq!(a.avg_packet_latency(), 30.0);
    }

    #[test]
    fn scaled_multiplies_additive_counters() {
        let a = NetStats { flit_hops: 10, max_packet_latency: 7, ..Default::default() };
        let b = a.scaled(2.5);
        assert_eq!(b.flit_hops, 25);
        assert_eq!(b.max_packet_latency, 7); // max is not additive
    }
}
