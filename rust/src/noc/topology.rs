//! Route-aware topology abstraction over the router fabric.
//!
//! The cycle-accurate kernel ([`super::network::Network`]) moves flits
//! between routers; *which* routers exist, how they are linked, and which
//! output port a packet takes are questions this module answers through
//! the [`Topology`] trait. Three fabrics implement it:
//!
//! * [`Mesh2D`] — the paper's plain mesh, bit-identical to the hardwired
//!   geometry the kernel shipped with (the frozen reference kernel in
//!   [`super::reference`] keeps that geometry inline; the golden
//!   equivalence suite pins `Mesh2D` against it).
//! * [`Torus2D`] — mesh plus wraparound links. Collection-semantic
//!   traffic (gather/INA row walks, operand multicast streams) keeps the
//!   mesh's dimension-ordered paths, so Algorithm 1 still visits every
//!   NI of a row; unicast result traffic takes ring-minimal routes and a
//!   **dateline VC rule** keeps them deadlock-free (see below).
//! * [`ConcentratedMesh`] — `c` PEs share one router via the existing
//!   `pes_per_router` machinery, halving the router radix per dimension;
//!   routing is plain XY on the smaller grid.
//!
//! ## Determinism and deadlock freedom
//!
//! Every implementation's [`Topology::route`] is a *deterministic*
//! function of `(packet type, here, dst)` — no adaptivity, no RNG — so
//! simulations stay bit-reproducible. Deadlock freedom per fabric:
//!
//! * `Mesh2D` / `ConcentratedMesh`: dimension-ordered XY — the canonical
//!   turn-free order (X settles before Y; no cyclic channel dependency).
//! * `Torus2D`: gather/INA/multicast packets use the mesh's XY order and
//!   never cross a wraparound link. Unicast packets route ring-minimal
//!   per dimension (X then Y, ties break away from the wrap) and obey the
//!   dateline rule: the VC space is split into two classes; a packet
//!   occupies class-0 VCs until its path crosses the dimension's dateline
//!   (the wrap link), class-1 VCs from the wrap hop on
//!   ([`Topology::vc_class`]). Any cycle around a ring would need the
//!   wrap link in class 0 — which the rule forbids — so the channel
//!   dependency graph stays acyclic. This is why
//!   [`crate::config::SimConfig::validate`] demands `vcs >= 2` on a
//!   torus.
//!
//! ## Memory elements
//!
//! All fabrics keep the paper's memory placement: the row-`y` global
//! memory is the virtual node `(cols, y)` behind the east edge, reached
//! by ejecting east at column `cols − 1`. On the torus the *physical*
//! wrap link between columns `cols − 1` and `0` lets westbound unicasts
//! shortcut to the memory column ([`Topology::result_hops`] shrinks from
//! `cols − x` to `min(cols − x, x + 2)`), which is the fabric's latency
//! win for the repetitive-unicast baseline.

use std::fmt;
use std::sync::Arc;

use super::flit::{Coord, PacketType};
use super::routing::{route as dimension_route, Algorithm, Port};
use crate::config::{SimConfig, TopologyKind};

/// Streaming-unit placement for the bus fabrics of `crate::streaming`:
/// how many row/column buses exist and how many NIs each drives. Derived
/// from the router grid — concentration shrinks the bus count along with
/// the radix (each NI then feeds `c` PEs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BusAttachments {
    /// Input-activation streaming units (one per router row). Drives the
    /// word accounting of `streaming::per_round_bus_stats`.
    pub row_buses: usize,
    /// Weight streaming units (one per router column). Also consumed by
    /// the bus word accounting.
    pub col_buses: usize,
    /// NIs attached to each row bus (placement metadata: the §4.4
    /// all-have-space gate spans this many NIs).
    pub nis_per_row_bus: usize,
    /// NIs attached to each col bus (placement metadata).
    pub nis_per_col_bus: usize,
}

/// A router fabric: geometry (dims/links) plus deterministic routing.
///
/// Implementations must uphold:
///
/// * **route/neighbor consistency** — whenever `route` returns a
///   non-ejection port, `neighbor(here, port)` is `Some` and repeated
///   application reaches `dst` (progress);
/// * **no self-loops** — `neighbor(c, p) != Some(c)`;
/// * **determinism** — `route` depends only on its arguments;
/// * **documented deadlock freedom** (see the module docs per impl).
///
/// These laws are pinned by `tests/topology_laws.rs`.
pub trait Topology: fmt::Debug + Send + Sync {
    /// Which config key builds this fabric.
    fn kind(&self) -> TopologyKind;

    /// Router grid as `(cols, rows)`.
    fn dims(&self) -> (usize, usize);

    /// PEs concentrated behind each router (1 unless the fabric itself
    /// concentrates). Metadata for reports/tests — the kernel's per-NI
    /// behavior is always driven by `SimConfig::pes_per_router`, which
    /// the [`crate::api::ScenarioBuilder`] keeps in sync with this value
    /// when it derives a concentrated mesh.
    fn concentration(&self) -> usize {
        1
    }

    /// The router reached from `node` through output port `port`
    /// (`None` for fabric edges and for `Port::Local`).
    fn neighbor(&self, node: Coord, port: Port) -> Option<Coord>;

    /// Output port at `here` for a packet of `ptype` headed to `dst`.
    /// `dst.x >= cols` addresses the row memory element (eject east at
    /// the edge column). Deterministic and deadlock-free per impl.
    fn route(&self, ptype: PacketType, here: Coord, dst: Coord) -> Port;

    /// VC-class restriction for the hop leaving `here` through `out`
    /// toward `dst` (packet injected at `src`). `None` = unrestricted
    /// (the mesh behavior); `Some(0)`/`Some(1)` confine VC allocation to
    /// the lower/upper half of the VC space (the torus dateline rule).
    fn vc_class(
        &self,
        ptype: PacketType,
        src: Coord,
        here: Coord,
        dst: Coord,
        out: Port,
    ) -> Option<usize> {
        let _ = (ptype, src, here, dst, out);
        None
    }

    /// Ordered routers a row-collection (gather/INA) packet traverses for
    /// `row` — initiator first, ejecting router last.
    ///
    /// **Descriptive, not prescriptive**: the kernel steers gather/INA
    /// packets through [`Topology::route`] hop by hop, so this method
    /// must equal the walk `route` induces for gather packets — it is
    /// the queryable form of that walk for tests, analytics and NI
    /// placement, and `tests/topology_laws.rs` pins the agreement. A
    /// fabric that wants a different collection path must change
    /// `route`'s gather arm (and this view with it), not just this
    /// method.
    fn gather_path(&self, row: usize) -> Vec<Coord> {
        let (cols, _) = self.dims();
        (0..cols).map(|x| Coord::new(x as u16, row as u16)).collect()
    }

    /// Streaming-unit placement for the bus architectures.
    fn bus_attachments(&self) -> BusAttachments {
        let (cols, rows) = self.dims();
        BusAttachments {
            row_buses: rows,
            col_buses: cols,
            nis_per_row_bus: cols,
            nis_per_col_bus: rows,
        }
    }

    /// Routers a unicast result packet from `node` traverses to its row
    /// memory element, inclusive of the ejecting router.
    fn result_hops(&self, node: Coord) -> u64;

    /// Worst-case [`Topology::result_hops`] over a row — the head-latency
    /// term of the analytic RU closed form (Eq. (3) uses `M` on the
    /// mesh).
    fn worst_result_hops(&self) -> u64 {
        let (cols, _) = self.dims();
        (0..cols)
            .map(|x| self.result_hops(Coord::new(x as u16, 0)))
            .max()
            .unwrap_or(0)
    }
}

/// Build the fabric selected by `cfg.topology` over the config's router
/// grid. This is the single construction seam the kernel, the analytic
/// forms and the streaming model share.
pub fn build(cfg: &SimConfig) -> Arc<dyn Topology> {
    match cfg.topology {
        TopologyKind::Mesh => Arc::new(Mesh2D::new(cfg.mesh_cols, cfg.mesh_rows)),
        TopologyKind::Torus => Arc::new(Torus2D::new(cfg.mesh_cols, cfg.mesh_rows)),
        TopologyKind::CMesh => {
            Arc::new(ConcentratedMesh::new(cfg.mesh_cols, cfg.mesh_rows, cfg.pes_per_router))
        }
    }
}

/// Run `f` against the config's fabric **on the stack** — no `Arc`, no
/// heap allocation. For the closed-form consumers on hot paths (the
/// analytic forms inside the plan search, the per-run bus accounting),
/// where [`build`]'s boxed fabric per call would be pure overhead.
pub fn with_fabric<T>(cfg: &SimConfig, f: impl FnOnce(&dyn Topology) -> T) -> T {
    match cfg.topology {
        TopologyKind::Mesh => f(&Mesh2D::new(cfg.mesh_cols, cfg.mesh_rows)),
        TopologyKind::Torus => f(&Torus2D::new(cfg.mesh_cols, cfg.mesh_rows)),
        TopologyKind::CMesh => {
            f(&ConcentratedMesh::new(cfg.mesh_cols, cfg.mesh_rows, cfg.pes_per_router))
        }
    }
}

/// [`Topology::worst_result_hops`] of the config's fabric, without
/// constructing a boxed trait object (plan-search hot path).
pub fn worst_result_hops(cfg: &SimConfig) -> u64 {
    with_fabric(cfg, |t| t.worst_result_hops())
}

/// Enum-dispatched fabric for the kernel's per-flit hot path.
///
/// The VA stage calls `route` + `vc_class` for every occupied VC every
/// cycle; through `Arc<dyn Topology>` those are two virtual calls per VC
/// per cycle. `Fabric` closes the set to the three built-in fabrics so
/// the match arms inline into the cycle phases. The `Arc<dyn Topology>`
/// stays authoritative at construction and reporting surfaces — the
/// kernel builds its `Fabric` from the same `SimConfig` the boxed fabric
/// came from, so the two can never disagree on geometry.
#[derive(Debug, Clone, Copy)]
pub enum Fabric {
    Mesh(Mesh2D),
    Torus(Torus2D),
    CMesh(ConcentratedMesh),
}

impl Fabric {
    /// The config's fabric as a stack value (same selection as [`build`]).
    pub fn from_config(cfg: &SimConfig) -> Fabric {
        match cfg.topology {
            TopologyKind::Mesh => Fabric::Mesh(Mesh2D::new(cfg.mesh_cols, cfg.mesh_rows)),
            TopologyKind::Torus => Fabric::Torus(Torus2D::new(cfg.mesh_cols, cfg.mesh_rows)),
            TopologyKind::CMesh => Fabric::CMesh(ConcentratedMesh::new(
                cfg.mesh_cols,
                cfg.mesh_rows,
                cfg.pes_per_router,
            )),
        }
    }

    /// [`Topology::route`], statically dispatched.
    #[inline]
    pub fn route(&self, ptype: PacketType, here: Coord, dst: Coord) -> Port {
        match self {
            Fabric::Mesh(t) => t.route(ptype, here, dst),
            Fabric::Torus(t) => t.route(ptype, here, dst),
            Fabric::CMesh(t) => t.route(ptype, here, dst),
        }
    }

    /// [`Topology::vc_class`], statically dispatched.
    #[inline]
    pub fn vc_class(
        &self,
        ptype: PacketType,
        src: Coord,
        here: Coord,
        dst: Coord,
        out: Port,
    ) -> Option<usize> {
        match self {
            Fabric::Mesh(t) => t.vc_class(ptype, src, here, dst, out),
            Fabric::Torus(t) => t.vc_class(ptype, src, here, dst, out),
            Fabric::CMesh(t) => t.vc_class(ptype, src, here, dst, out),
        }
    }

    /// [`Topology::neighbor`], statically dispatched.
    #[inline]
    pub fn neighbor(&self, node: Coord, port: Port) -> Option<Coord> {
        match self {
            Fabric::Mesh(t) => t.neighbor(node, port),
            Fabric::Torus(t) => t.neighbor(node, port),
            Fabric::CMesh(t) => t.neighbor(node, port),
        }
    }
}

/// [`Topology::bus_attachments`] of the config's fabric, allocation-free.
pub fn bus_attachments(cfg: &SimConfig) -> BusAttachments {
    with_fabric(cfg, |t| t.bus_attachments())
}

// ---------------------------------------------------------------------
// Mesh2D
// ---------------------------------------------------------------------

/// The paper's plain 2D mesh: XY routing, no wraparound, memory off the
/// east edge. Reproduces the kernel's original hardwired geometry
/// bit-identically (routing delegates to the same
/// [`super::routing::route`] the pre-topology kernel called).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh2D {
    cols: usize,
    rows: usize,
}

impl Mesh2D {
    pub fn new(cols: usize, rows: usize) -> Mesh2D {
        Mesh2D { cols, rows }
    }
}

impl Topology for Mesh2D {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Mesh
    }

    fn dims(&self) -> (usize, usize) {
        (self.cols, self.rows)
    }

    fn neighbor(&self, c: Coord, p: Port) -> Option<Coord> {
        match p {
            Port::North => (c.y > 0).then(|| Coord::new(c.x, c.y - 1)),
            Port::South => ((c.y as usize + 1) < self.rows).then(|| Coord::new(c.x, c.y + 1)),
            Port::East => ((c.x as usize + 1) < self.cols).then(|| Coord::new(c.x + 1, c.y)),
            Port::West => (c.x > 0).then(|| Coord::new(c.x - 1, c.y)),
            Port::Local => None,
        }
    }

    fn route(&self, _ptype: PacketType, here: Coord, dst: Coord) -> Port {
        // Deadlock-free order: X settles fully before Y (XY dimension
        // order), identical for every packet type.
        dimension_route(Algorithm::Xy, here, dst)
    }

    fn result_hops(&self, node: Coord) -> u64 {
        self.cols as u64 - node.x as u64
    }
}

// ---------------------------------------------------------------------
// Torus2D
// ---------------------------------------------------------------------

/// Ring distances: (hops moving +1 mod dim, hops moving −1 mod dim).
fn ring_delta(from: u16, to: u16, dim: u16) -> (u16, u16) {
    let fwd = (to + dim - from) % dim;
    (fwd, (dim - fwd) % dim)
}

/// 2D torus: the mesh plus wraparound links in both dimensions.
///
/// Routing order (documented deadlock-free order of this impl):
///
/// * gather / INA / multicast packets: the mesh's XY walk — these packets
///   *are* their path (a gather packet must pass every NI of its row, an
///   operand stream must deliver to every router it covers), so the wrap
///   links are off-limits to them;
/// * unicast packets: ring-minimal X, then ring-minimal Y (ties break to
///   the positive direction), under the dateline VC rule of
///   [`Topology::vc_class`]. Memory destinations (`dst.x >= cols`) route
///   to the edge column ring-minimally — westbound wraps are exactly the
///   shortcut that makes RU collection cheaper on this fabric — and
///   eject east there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Torus2D {
    cols: usize,
    rows: usize,
}

impl Torus2D {
    pub fn new(cols: usize, rows: usize) -> Torus2D {
        Torus2D { cols, rows }
    }

    /// X-dimension target column: memory destinations clamp to the east
    /// edge column (where ejection happens).
    fn target_x(&self, dst: Coord) -> u16 {
        if dst.x as usize >= self.cols {
            self.cols as u16 - 1
        } else {
            dst.x
        }
    }

    /// Class of the downstream buffer for a hop moving `positive`ly (+1
    /// mod dim) or negatively from `here`, on a dimension of size `dim`,
    /// for the deterministic ring-minimal path `src → t`:
    /// 0 before the dateline (the wrap link), 1 from the wrap hop on.
    fn dim_class(src: u16, here: u16, t: u16, dim: u16, positive: bool) -> usize {
        if positive {
            // Path src, src+1, …, t (mod dim); wraps iff t < src.
            if t >= src {
                0
            } else if here == dim - 1 || here < src {
                1
            } else {
                0
            }
        } else {
            // Path src, src−1, …, t (mod dim); wraps iff t > src.
            if t <= src {
                0
            } else if here == 0 || here > src {
                1
            } else {
                0
            }
        }
    }
}

impl Topology for Torus2D {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Torus
    }

    fn dims(&self) -> (usize, usize) {
        (self.cols, self.rows)
    }

    fn neighbor(&self, c: Coord, p: Port) -> Option<Coord> {
        let (cols, rows) = (self.cols as u16, self.rows as u16);
        match p {
            Port::North => Some(Coord::new(c.x, (c.y + rows - 1) % rows)),
            Port::South => Some(Coord::new(c.x, (c.y + 1) % rows)),
            Port::East => Some(Coord::new((c.x + 1) % cols, c.y)),
            Port::West => Some(Coord::new((c.x + cols - 1) % cols, c.y)),
            Port::Local => None,
        }
    }

    fn route(&self, ptype: PacketType, here: Coord, dst: Coord) -> Port {
        if ptype != PacketType::Unicast {
            // Collection/stream semantics pin the mesh walk (see above).
            return dimension_route(Algorithm::Xy, here, dst);
        }
        let (cols, rows) = (self.cols as u16, self.rows as u16);
        let tx = self.target_x(dst);
        if here.x != tx {
            let (east, west) = ring_delta(here.x, tx, cols);
            return if east <= west { Port::East } else { Port::West };
        }
        if here.y != dst.y {
            let (south, north) = ring_delta(here.y, dst.y, rows);
            return if south <= north { Port::South } else { Port::North };
        }
        if dst.x as usize >= self.cols {
            Port::East // eject to the row memory element
        } else {
            Port::Local
        }
    }

    fn vc_class(
        &self,
        ptype: PacketType,
        src: Coord,
        here: Coord,
        dst: Coord,
        out: Port,
    ) -> Option<usize> {
        if ptype != PacketType::Unicast {
            return None; // XY walks never wrap: unrestricted, as on the mesh
        }
        let (cols, rows) = (self.cols as u16, self.rows as u16);
        match out {
            Port::East => {
                Some(Self::dim_class(src.x, here.x, self.target_x(dst), cols, true))
            }
            Port::West => {
                Some(Self::dim_class(src.x, here.x, self.target_x(dst), cols, false))
            }
            Port::South => Some(Self::dim_class(src.y, here.y, dst.y, rows, true)),
            Port::North => Some(Self::dim_class(src.y, here.y, dst.y, rows, false)),
            Port::Local => None,
        }
    }

    fn result_hops(&self, node: Coord) -> u64 {
        // East: routers node.x ..= cols−1 (cols − x of them).
        // West: node.x + 1 routers down to column 0, the wrap hop to the
        // edge column, then eject there — x + 2 total.
        let east = self.cols as u64 - node.x as u64;
        let west = node.x as u64 + 2;
        east.min(west)
    }
}

// ---------------------------------------------------------------------
// ConcentratedMesh
// ---------------------------------------------------------------------

/// Concentrated mesh: `c` PEs share each router, halving the router
/// radix per dimension relative to the PE array. The fabric itself is a
/// plain XY mesh over the smaller grid — concentration lives in the NI
/// (`SimConfig::pes_per_router` and [`crate::config::PeGrouping`] decide
/// how the co-located PEs share streams), so every routing/deadlock
/// property of [`Mesh2D`] carries over verbatim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConcentratedMesh {
    mesh: Mesh2D,
    concentration: usize,
}

impl ConcentratedMesh {
    pub fn new(cols: usize, rows: usize, concentration: usize) -> ConcentratedMesh {
        ConcentratedMesh { mesh: Mesh2D::new(cols, rows), concentration }
    }
}

impl Topology for ConcentratedMesh {
    fn kind(&self) -> TopologyKind {
        TopologyKind::CMesh
    }

    fn dims(&self) -> (usize, usize) {
        self.mesh.dims()
    }

    fn concentration(&self) -> usize {
        self.concentration
    }

    fn neighbor(&self, c: Coord, p: Port) -> Option<Coord> {
        self.mesh.neighbor(c, p)
    }

    fn route(&self, ptype: PacketType, here: Coord, dst: Coord) -> Port {
        self.mesh.route(ptype, here, dst)
    }

    fn result_hops(&self, node: Coord) -> u64 {
        self.mesh.result_hops(node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn walk_unicast(t: &dyn Topology, src: Coord, dst: Coord, max: usize) -> Vec<Coord> {
        let mut path = vec![src];
        let mut here = src;
        while here != dst {
            assert!(path.len() <= max, "route from {src:?} to {dst:?} did not converge");
            let p = t.route(PacketType::Unicast, here, dst);
            here = t.neighbor(here, p).expect("routed into a missing link");
            path.push(here);
        }
        path
    }

    #[test]
    fn mesh_matches_the_kernel_geometry() {
        let m = Mesh2D::new(8, 8);
        assert_eq!(m.dims(), (8, 8));
        assert_eq!(m.neighbor(Coord::new(0, 0), Port::West), None);
        assert_eq!(m.neighbor(Coord::new(7, 3), Port::East), None);
        assert_eq!(m.neighbor(Coord::new(3, 3), Port::East), Some(Coord::new(4, 3)));
        // Memory-bound routing ejects east at the edge.
        assert_eq!(
            m.route(PacketType::Gather, Coord::new(7, 2), Coord::new(8, 2)),
            Port::East
        );
        assert_eq!(m.result_hops(Coord::new(0, 0)), 8);
        assert_eq!(m.worst_result_hops(), 8);
    }

    #[test]
    fn torus_wraps_every_edge_without_self_loops() {
        let t = Torus2D::new(8, 4);
        assert_eq!(t.neighbor(Coord::new(0, 0), Port::West), Some(Coord::new(7, 0)));
        assert_eq!(t.neighbor(Coord::new(7, 0), Port::East), Some(Coord::new(0, 0)));
        assert_eq!(t.neighbor(Coord::new(0, 0), Port::North), Some(Coord::new(0, 3)));
        assert_eq!(t.neighbor(Coord::new(0, 3), Port::South), Some(Coord::new(0, 0)));
        for y in 0..4u16 {
            for x in 0..8u16 {
                for p in [Port::North, Port::South, Port::East, Port::West] {
                    let n = t.neighbor(Coord::new(x, y), p).unwrap();
                    assert_ne!(n, Coord::new(x, y), "self-loop at ({x},{y}) {p:?}");
                }
            }
        }
    }

    #[test]
    fn torus_unicast_takes_ring_minimal_paths() {
        let t = Torus2D::new(8, 8);
        // 6 → 1 eastward is 3 wrapped hops, not 5 westward.
        let p = walk_unicast(&t, Coord::new(6, 0), Coord::new(1, 0), 16);
        assert_eq!(p.len() - 1, 3);
        // Worst case per dimension is ⌈dim/2⌉.
        for sx in 0..8u16 {
            for dx in 0..8u16 {
                let hops = walk_unicast(&t, Coord::new(sx, 2), Coord::new(dx, 5), 32).len() - 1;
                assert!(hops as u64 <= 4 + 4, "({sx}→{dx}) took {hops} hops");
            }
        }
    }

    #[test]
    fn torus_memory_shortcut_beats_the_mesh_for_westside_nodes() {
        let t = Torus2D::new(8, 8);
        let m = Mesh2D::new(8, 8);
        assert_eq!(t.result_hops(Coord::new(0, 0)), 2); // wrap + eject
        assert_eq!(m.result_hops(Coord::new(0, 0)), 8);
        assert!(t.worst_result_hops() < m.worst_result_hops());
        // Eastside nodes keep the direct path.
        assert_eq!(t.result_hops(Coord::new(7, 0)), 1);
    }

    #[test]
    fn torus_gather_and_streams_never_wrap() {
        let t = Torus2D::new(8, 8);
        // A gather packet at the initiator column routes east along the
        // row (the XY walk), not backwards over the wrap link.
        assert_eq!(
            t.route(PacketType::Gather, Coord::new(0, 3), Coord::new(8, 3)),
            Port::East
        );
        assert_eq!(
            t.route(PacketType::Multicast, Coord::new(0, 3), Coord::new(7, 3)),
            Port::East
        );
        assert_eq!(t.gather_path(3).len(), 8);
        assert_eq!(t.gather_path(3)[0], Coord::new(0, 3));
    }

    #[test]
    fn dateline_classes_flip_exactly_at_the_wrap() {
        let t = Torus2D::new(8, 8);
        let src = Coord::new(6, 0);
        let dst = Coord::new(1, 0); // eastward wrapped path 6,7,0,1
        for (here, want) in [(6u16, 0usize), (7, 1), (0, 1)] {
            assert_eq!(
                t.vc_class(PacketType::Unicast, src, Coord::new(here, 0), dst, Port::East),
                Some(want),
                "east hop at x={here}"
            );
        }
        // Westbound memory shortcut from column 1: path 1, 0, wrap→7.
        let mem = Coord::new(8, 0);
        let src = Coord::new(1, 0);
        assert_eq!(
            t.vc_class(PacketType::Unicast, src, Coord::new(1, 0), mem, Port::West),
            Some(0)
        );
        assert_eq!(
            t.vc_class(PacketType::Unicast, src, Coord::new(0, 0), mem, Port::West),
            Some(1)
        );
        // Non-unicast packets are never class-restricted.
        assert_eq!(t.vc_class(PacketType::Gather, src, src, mem, Port::East), None);
        // Unwrapped paths stay in class 0 end to end.
        let m2 = Mesh2D::new(8, 8);
        assert_eq!(m2.vc_class(PacketType::Unicast, src, src, mem, Port::East), None);
        assert_eq!(
            t.vc_class(PacketType::Unicast, Coord::new(2, 0), Coord::new(5, 0), mem, Port::East),
            Some(0)
        );
    }

    #[test]
    fn cmesh_is_a_smaller_mesh_with_concentration() {
        let c = ConcentratedMesh::new(4, 4, 8);
        assert_eq!(c.kind(), TopologyKind::CMesh);
        assert_eq!(c.dims(), (4, 4));
        assert_eq!(c.concentration(), 8);
        assert_eq!(c.neighbor(Coord::new(0, 0), Port::West), None);
        assert_eq!(c.worst_result_hops(), 4);
        let b = c.bus_attachments();
        assert_eq!((b.row_buses, b.nis_per_row_bus), (4, 4));
    }

    #[test]
    fn stack_fabric_helpers_agree_with_build() {
        for kind in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::CMesh] {
            let mut cfg = SimConfig::table1_8x8(2);
            cfg.topology = kind;
            let boxed = build(&cfg);
            assert_eq!(worst_result_hops(&cfg), boxed.worst_result_hops(), "{kind:?}");
            assert_eq!(bus_attachments(&cfg), boxed.bus_attachments(), "{kind:?}");
            assert_eq!(with_fabric(&cfg, |t| t.kind()), kind);
        }
    }

    #[test]
    fn fabric_enum_agrees_with_the_boxed_fabric() {
        for kind in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::CMesh] {
            let mut cfg = SimConfig::table1_8x8(2);
            cfg.topology = kind;
            let boxed = build(&cfg);
            let fabric = Fabric::from_config(&cfg);
            let mem = Coord::new(cfg.mesh_cols as u16, 0);
            for ptype in [PacketType::Unicast, PacketType::Gather, PacketType::Multicast] {
                for sx in 0..cfg.mesh_cols as u16 {
                    for hx in 0..cfg.mesh_cols as u16 {
                        let (src, here) = (Coord::new(sx, 1), Coord::new(hx, 1));
                        for dst in [Coord::new(2, 5), mem] {
                            let p = boxed.route(ptype, here, dst);
                            assert_eq!(fabric.route(ptype, here, dst), p, "{kind:?}");
                            assert_eq!(
                                fabric.vc_class(ptype, src, here, dst, p),
                                boxed.vc_class(ptype, src, here, dst, p),
                                "{kind:?}"
                            );
                            assert_eq!(fabric.neighbor(here, p), boxed.neighbor(here, p));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn build_follows_the_config_key() {
        let mut cfg = SimConfig::table1_8x8(2);
        assert_eq!(build(&cfg).kind(), TopologyKind::Mesh);
        cfg.topology = TopologyKind::Torus;
        assert_eq!(build(&cfg).kind(), TopologyKind::Torus);
        cfg.topology = TopologyKind::CMesh;
        let t = build(&cfg);
        assert_eq!(t.kind(), TopologyKind::CMesh);
        assert_eq!(t.dims(), (8, 8)); // dims are always the literal router grid
        assert_eq!(t.concentration(), 2);
    }
}
