//! Processing-element and network-interface timing models (§4.4, Fig. 9).
//!
//! PEs are the simple MAC-pipeline elements of [36]: each PE performs one
//! MAC per cycle on streamed operands and applies its activation function
//! with a fixed, predictable pipeline depth (`T_MAC` in Table 1), so rows
//! and columns stay synchronized without handshake overhead.
//!
//! The NI (Fig. 9) aggregates `n` PEs behind one router: it disassembles
//! incoming stream words to the right PE register files and assembles
//! outgoing partial sums into packets (gather payload queue / packet format
//! unit). Its timing contribution is folded into the per-round schedule
//! computed here; its *gather* behaviour (payload queue, δ counter) lives
//! in `crate::noc::gather` because it is clocked with the router.

use crate::config::{SimConfig, Streaming};

/// Compute the per-round operand streaming time for a bus architecture.
/// This is the OS instantiation of the dataflow-generic
/// [`crate::dataflow::Dataflow::stream_cycles`] contract (the round
/// period the driver gates on is `stream_cycles + T_MAC`); the WS
/// broadcast phase lives in [`crate::dataflow::ws`].
///
/// `macs_per_pe` is `C·R·R` — one operand word pair is consumed per MAC, so
/// the stream for one PE is `C·R·R` words; `n` PEs per router multiply it
/// (§4.4: n input sets share the NI). The two-way architecture streams
/// inputs and weights on separate buses in parallel; the one-way
/// architecture interleaves both on a shared bus, doubling the occupancy
/// (Fig. 10(b)).
pub fn bus_stream_cycles(cfg: &SimConfig, streaming: Streaming, macs_per_pe: u64) -> u64 {
    let words = macs_per_pe * cfg.pes_per_router as u64;
    let per_bus = words.div_ceil(cfg.bus_words_per_cycle as u64);
    match streaming {
        Streaming::TwoWay => per_bus,
        Streaming::OneWay => 2 * per_bus,
        Streaming::Mesh => {
            unreachable!("mesh streaming time is simulated, not closed-form")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_way_streams_in_parallel() {
        let mut cfg = SimConfig::table1_8x8(2);
        cfg.bus_words_per_cycle = 1;
        // C·R·R = 27 MACs, n = 2 → 54 words on each bus.
        assert_eq!(bus_stream_cycles(&cfg, Streaming::TwoWay, 27), 54);
        assert_eq!(bus_stream_cycles(&cfg, Streaming::OneWay, 27), 108);
    }

    #[test]
    fn wider_bus_divides_stream_time() {
        let mut cfg = SimConfig::table1_8x8(1);
        cfg.bus_words_per_cycle = 4; // Table-1 default: flit-wide bus
        assert_eq!(bus_stream_cycles(&cfg, Streaming::TwoWay, 100), 25);
    }

}
