//! Per-layer execution policies for whole-network runs.
//!
//! The paper's whole-model numbers (Figs. 13–16) pick *one* architecture
//! and apply it to every layer, but communication behaviour shifts
//! layer-to-layer — early layers are streaming-bound (huge feature maps,
//! shallow reductions), late layers collection-bound (deep reductions,
//! many filters) — so the best (streaming × collection × dataflow) triple
//! is a per-layer decision. This module makes that decision a value:
//!
//! * [`LayerPolicy`] — one layer's (streaming, collection, dataflow)
//!   triple, JSON round-trippable.
//! * [`NetworkPlan`] — one policy per layer of a
//!   [`crate::models::Network`], with [`NetworkPlan::uniform`] for the
//!   paper's single-architecture convention and custom plans loadable
//!   from JSON (`noc-dnn model --plan <file.json>`). The sim-verified
//!   argmin plan is built by
//!   [`crate::coordinator::executor::best_plan`].
//! * [`reload_cycles`] — the inter-layer boundary charge: layer ℓ's
//!   output feature map is layer ℓ+1's input traffic and must cross the
//!   memory edge before the layer's rounds start. Charged identically by
//!   the executor and by [`crate::analytic::network_latency`], and a
//!   function of the *consuming* layer's policy only, so per-layer argmin
//!   composes to the whole-model optimum.
//!
//! With `SimConfig::probes` on, the search's sim-verified evaluations
//! also carry the measured per-link contention signal
//! ([`crate::noc::probes::ProbeReport`]): `best_plan` reports gain a
//! `max_link_util` diagnostic column, and exact total-cycle ties break
//! toward the candidate with more link headroom.

use crate::config::{Collection, ConfigError, DataflowKind, SimConfig, Streaming};
use crate::models::Network;
use crate::noc::stats::NetStats;
use crate::util::json::{self, Json};

/// The (streaming × collection × dataflow) triple assigned to one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerPolicy {
    pub streaming: Streaming,
    pub collection: Collection,
    pub dataflow: DataflowKind,
}

impl LayerPolicy {
    /// The paper's proposed architecture under the OS dataflow:
    /// two-way streaming + gather collection.
    pub fn proposed() -> LayerPolicy {
        LayerPolicy {
            streaming: Streaming::TwoWay,
            collection: Collection::Gather,
            dataflow: DataflowKind::OutputStationary,
        }
    }

    /// Compact display/JSON-free spelling, e.g. `two-way/gather/os`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}",
            self.streaming.key(),
            self.collection.label(),
            self.dataflow.label()
        )
    }

    /// The per-layer `SimConfig`: the base config with this policy's
    /// dataflow/collection selectors applied (streaming is passed to the
    /// driver explicitly).
    pub fn apply(&self, base: &SimConfig) -> SimConfig {
        let mut cfg = base.clone();
        cfg.dataflow = self.dataflow;
        cfg.collection = self.collection;
        cfg
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("streaming", Json::Str(self.streaming.key().to_string()))
            .set("collection", Json::Str(self.collection.label().to_string()))
            .set("dataflow", Json::Str(self.dataflow.label().to_string()));
        o
    }

    /// Parse one policy object. Missing fields fall back to the paper's
    /// proposed triple, so sparse plan files stay readable. Unknown
    /// keyword spellings are typed [`ConfigError`]s.
    pub fn from_json(j: &Json) -> Result<LayerPolicy, ConfigError> {
        let d = LayerPolicy::proposed();
        Ok(LayerPolicy {
            streaming: match j.get("streaming").and_then(Json::as_str) {
                Some(s) => Streaming::parse(s)?,
                None => d.streaming,
            },
            collection: match j.get("collection").and_then(Json::as_str) {
                Some(s) => Collection::parse(s)?,
                None => d.collection,
            },
            dataflow: match j.get("dataflow").and_then(Json::as_str) {
                Some(s) => DataflowKind::parse(s)?,
                None => d.dataflow,
            },
        })
    }
}

/// One policy per layer of a [`Network`], in layer order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetworkPlan {
    pub name: String,
    pub policies: Vec<LayerPolicy>,
}

impl NetworkPlan {
    /// The paper's convention: the same policy for every layer.
    pub fn uniform(policy: LayerPolicy, layers: usize) -> NetworkPlan {
        NetworkPlan {
            name: format!("uniform-{}", policy.label()),
            policies: vec![policy; layers],
        }
    }

    /// Policy of layer `i`.
    pub fn policy(&self, i: usize) -> LayerPolicy {
        self.policies[i]
    }

    /// A plan is valid for a model when it names exactly one policy per
    /// layer.
    pub fn validate(&self, model: &Network) -> Result<(), ConfigError> {
        if self.policies.len() != model.len() {
            return Err(ConfigError::invalid(
                "plan",
                format!(
                    "plan '{}' has {} policies but model '{}' has {} layers",
                    self.name,
                    self.policies.len(),
                    model.name,
                    model.len()
                ),
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone())).set(
            "policies",
            Json::Arr(self.policies.iter().map(LayerPolicy::to_json).collect()),
        );
        o
    }

    /// Parse a plan document: `{"name": ..., "policies": [{...}, ...]}`.
    /// Every failure — parser errors, missing structure, unknown policy
    /// keywords — is a typed [`ConfigError`], end to end.
    pub fn from_json(s: &str) -> Result<NetworkPlan, ConfigError> {
        let j = json::parse(s)
            .map_err(|e| ConfigError::Json { what: "plan", reason: e.to_string() })?;
        let policies = j
            .get("policies")
            .and_then(Json::as_arr)
            .ok_or_else(|| ConfigError::Json {
                what: "plan",
                reason: "needs a 'policies' array".to_string(),
            })?
            .iter()
            .map(LayerPolicy::from_json)
            .collect::<Result<Vec<_>, ConfigError>>()?;
        if policies.is_empty() {
            return Err(ConfigError::Json {
                what: "plan",
                reason: "plan has no policies".to_string(),
            });
        }
        Ok(NetworkPlan {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("custom")
                .to_string(),
            policies,
        })
    }
}

/// The bus-streaming policy grid: {two-way, one-way} × {gather, INA, RU}
/// × {OS, WS} — the 12 combinations the analytic closed forms cover. The
/// order is the deterministic tie-break preference of the plan search
/// (the paper's proposed two-way/gather/OS first).
pub fn bus_policy_grid() -> Vec<LayerPolicy> {
    let mut grid = Vec::new();
    for streaming in [Streaming::TwoWay, Streaming::OneWay] {
        for dataflow in [DataflowKind::OutputStationary, DataflowKind::WeightStationary] {
            for collection in [Collection::Gather, Collection::Ina, Collection::RepetitiveUnicast]
            {
                grid.push(LayerPolicy { streaming, collection, dataflow });
            }
        }
    }
    grid
}

/// The mesh-streaming (gather-only fabric) policies: 3 collections × 2
/// dataflows. No closed form exists for mesh operand delivery, so these
/// are evaluated by simulation only.
pub fn mesh_policy_grid() -> Vec<LayerPolicy> {
    let mut grid = Vec::new();
    for dataflow in [DataflowKind::OutputStationary, DataflowKind::WeightStationary] {
        for collection in [Collection::Gather, Collection::Ina, Collection::RepetitiveUnicast] {
            grid.push(LayerPolicy { streaming: Streaming::Mesh, collection, dataflow });
        }
    }
    grid
}

/// The full 3×3×2 (streaming × collection × dataflow) grid.
pub fn policy_grid() -> Vec<LayerPolicy> {
    let mut grid = bus_policy_grid();
    grid.extend(mesh_policy_grid());
    grid
}

/// Inter-layer boundary charge: cycles to move `words` operand words from
/// the global memory edge into the streaming sources before a layer's
/// rounds begin (layer ℓ's output volume is layer ℓ+1's input traffic;
/// §5.1 finishes each feature map before the next layer starts).
///
/// * Bus streaming: the `N` row buses refill in parallel at
///   `bus_words_per_cycle` each — `⌈words / (N·f_l)⌉` (identical for
///   one-way and two-way: input activations ride the row buses in both).
/// * Mesh streaming: the words enter as row wormhole streams, one
///   flit per row per cycle, plus the pipeline fill of the row walk.
///
/// The charge depends only on the *consuming* layer's streaming mode and
/// the (fixed) volume, never on the producing layer's policy — which is
/// what keeps whole-network latency separable per layer and lets the
/// per-layer argmin of `best_plan` compose to the model optimum.
pub fn reload_cycles(cfg: &SimConfig, streaming: Streaming, words: u64) -> u64 {
    let rows = cfg.mesh_rows as u64;
    match streaming {
        Streaming::OneWay | Streaming::TwoWay => {
            words.div_ceil(rows * cfg.bus_words_per_cycle as u64)
        }
        Streaming::Mesh => {
            let flits = words.div_ceil(cfg.payloads_per_flit() as u64);
            flits.div_ceil(rows)
                + cfg.mesh_cols as u64 * (cfg.kappa() + cfg.link_latency)
        }
    }
}

/// Router events of the reload traffic under **mesh** streaming, in
/// closed form: the boundary refill enters as one wormhole stream per
/// row, delivering words along its path — every flit is written, read,
/// switched and granted at each of the `M` routers it traverses and
/// crosses `M − 1` links. Charged by the executor's power roll-up so a
/// mesh policy does not move its input feature map for free energy-wise
/// (the same accounting `Dataflow::setup_net_stats` applies to WS weight
/// loads). Bus streaming charges reload words to the row buses instead;
/// zero here.
///
/// Closed-form, never simulated — so these `link_traversals` exist only
/// in the merged/priced aggregates, never in the per-link probe counters
/// ([`crate::noc::probes`]), which record simulated traffic exclusively.
/// Probe conservation tests therefore reconcile against the raw
/// `measured_net`, not against merged stats.
pub fn reload_net_stats(cfg: &SimConfig, streaming: Streaming, words: u64) -> NetStats {
    if streaming != Streaming::Mesh || words == 0 {
        return NetStats::default();
    }
    let rows = cfg.mesh_rows as u64;
    let cols = cfg.mesh_cols as u64;
    let words_per_row = words.div_ceil(rows);
    let flits_per_stream = 1 + words_per_row.div_ceil(cfg.payloads_per_flit() as u64).max(1);
    let per_router_events = rows * flits_per_stream * cols;
    NetStats {
        packets_injected: rows,
        packets_ejected: rows,
        flits_ejected: rows * flits_per_stream,
        buffer_writes: per_router_events,
        buffer_reads: per_router_events,
        crossbar_traversals: per_router_events,
        sa_grants: per_router_events,
        link_traversals: rows * flits_per_stream * (cols - 1),
        flit_hops: per_router_events,
        stream_deliveries: per_router_events,
        ..NetStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_label_is_compact_and_stable() {
        assert_eq!(LayerPolicy::proposed().label(), "two-way/gather/os");
        let p = LayerPolicy {
            streaming: Streaming::Mesh,
            collection: Collection::Ina,
            dataflow: DataflowKind::WeightStationary,
        };
        assert_eq!(p.label(), "mesh/INA/ws");
    }

    #[test]
    fn policy_grids_cover_the_full_cross_product() {
        assert_eq!(bus_policy_grid().len(), 12);
        assert_eq!(mesh_policy_grid().len(), 6);
        let grid = policy_grid();
        assert_eq!(grid.len(), 18);
        // All distinct.
        for (i, a) in grid.iter().enumerate() {
            for b in &grid[i + 1..] {
                assert_ne!(a, b);
            }
        }
        // The tie-break preference leads the grid.
        assert_eq!(grid[0], LayerPolicy::proposed());
    }

    #[test]
    fn policy_json_roundtrips() {
        for p in policy_grid() {
            let back = LayerPolicy::from_json(&p.to_json()).unwrap();
            assert_eq!(back, p);
        }
        // Sparse policy objects default to the proposed triple.
        let sparse = LayerPolicy::from_json(&json::parse(r#"{"dataflow":"ws"}"#).unwrap()).unwrap();
        assert_eq!(sparse.streaming, Streaming::TwoWay);
        assert_eq!(sparse.collection, Collection::Gather);
        assert_eq!(sparse.dataflow, DataflowKind::WeightStationary);
    }

    #[test]
    fn plan_json_roundtrips_and_validates() {
        let model = Network::alexnet();
        let mut plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
        plan.policies[2].collection = Collection::Ina;
        plan.policies[4].dataflow = DataflowKind::WeightStationary;
        let back = NetworkPlan::from_json(&plan.to_json().to_pretty()).unwrap();
        assert_eq!(back, plan);
        plan.validate(&model).unwrap();
        // Wrong layer count is rejected.
        let short = NetworkPlan::uniform(LayerPolicy::proposed(), 3);
        assert!(short.validate(&model).is_err());
        // Garbage documents are rejected with typed errors, end to end.
        assert!(matches!(
            NetworkPlan::from_json("{}"),
            Err(ConfigError::Json { what: "plan", .. })
        ));
        assert!(matches!(
            NetworkPlan::from_json(r#"{"policies":[{"collection":"x"}]}"#),
            Err(ConfigError::UnknownKeyword { what: "collection", .. })
        ));
        assert!(matches!(
            NetworkPlan::from_json("not json at all"),
            Err(ConfigError::Json { what: "plan", .. })
        ));
    }

    #[test]
    fn reload_charge_tracks_volume_and_mode() {
        let cfg = SimConfig::table1_8x8(4);
        // 8 row buses × 4 words/cycle = 32 words/cycle aggregate.
        assert_eq!(reload_cycles(&cfg, Streaming::TwoWay, 3200), 100);
        assert_eq!(
            reload_cycles(&cfg, Streaming::OneWay, 3200),
            reload_cycles(&cfg, Streaming::TwoWay, 3200),
            "input reload rides the row buses in both bus architectures"
        );
        // Mesh refill is strictly slower than the dedicated buses for any
        // non-trivial volume.
        assert!(reload_cycles(&cfg, Streaming::Mesh, 3200) > 100);
        assert_eq!(reload_cycles(&cfg, Streaming::TwoWay, 0), 0);
    }

    #[test]
    fn mesh_reload_is_charged_router_events_buses_are_not() {
        let cfg = SimConfig::table1_8x8(4);
        let s = reload_net_stats(&cfg, Streaming::Mesh, 3200);
        // One refill stream per row: 3200/8 = 400 words → 100 body flits
        // + head, events at each of the 8 routers crossed.
        assert_eq!(s.packets_injected, 8);
        assert_eq!(s.flits_ejected, 8 * 101);
        assert_eq!(s.buffer_writes, 8 * 101 * 8);
        assert_eq!(s.buffer_writes, s.buffer_reads);
        assert_eq!(s.flit_hops, s.crossbar_traversals);
        assert_eq!(s.link_traversals, 8 * 101 * 7);
        // Bus reload rides the buses (charged as bus words by the
        // executor), not the routers.
        assert_eq!(reload_net_stats(&cfg, Streaming::TwoWay, 3200), NetStats::default());
        assert_eq!(reload_net_stats(&cfg, Streaming::Mesh, 0), NetStats::default());
    }
}
