//! §5.4 hardware-overhead roll-up: reproduces the paper's Synopsys DC /
//! DSENT comparison of the baseline vs gather-supported router.

use super::router::{RouterArea, RouterEnergy};

/// One §5.4 table row.
#[derive(Debug, Clone, PartialEq)]
pub struct OverheadReport {
    pub baseline_power_mw: f64,
    pub proposed_power_mw: f64,
    pub power_overhead_pct: f64,
    pub baseline_area_um2: f64,
    pub proposed_area_um2: f64,
    pub area_overhead_pct: f64,
}

/// Compute the §5.4 overhead table for a 1 GHz router.
///
/// The proposed router's extra power is the gather logic exercised on the
/// same saturation traffic: one Load-generation per head flit per port plus
/// one payload fill per cycle (conservative — the upper bound of the
/// modified pipeline of Fig. 7).
pub fn overhead_report(clock_hz: f64) -> OverheadReport {
    let e = RouterEnergy::forty_five_nm();
    let a = RouterArea::forty_five_nm();
    let base_w = e.saturation_power(clock_hz);
    // Gather adders at saturation: 5 ports' heads checked (5 × logic) and
    // one payload fill per cycle, plus the payload queue's static power
    // (~0.45 mW, proportional to its share of buffer area).
    let queue_static_w = e.static_w * (a.gather_payload_q_um2 + a.gather_load_gen_um2)
        / a.baseline()
        * 2.5; // queue is flop-based: leakier per µm² than SRAM buffers
    let gather_dyn_w = (4.0 * e.gather_logic_j + e.gather_payload_j) * clock_hz;
    let prop_w = base_w + gather_dyn_w + queue_static_w;
    OverheadReport {
        baseline_power_mw: base_w * 1e3,
        proposed_power_mw: prop_w * 1e3,
        power_overhead_pct: (prop_w / base_w - 1.0) * 100.0,
        baseline_area_um2: a.baseline(),
        proposed_area_um2: a.proposed(),
        area_overhead_pct: (a.proposed() / a.baseline() - 1.0) * 100.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_section_5_4() {
        // Paper: 26.3 mW → 27.87 mW (~6%), 72106 µm² → 74950 µm² (~4%).
        let r = overhead_report(1.0e9);
        assert!((r.baseline_power_mw - 26.3).abs() < 0.5, "{r:?}");
        assert!((r.proposed_power_mw - 27.87).abs() < 0.8, "{r:?}");
        assert!(r.power_overhead_pct > 4.5 && r.power_overhead_pct < 7.5, "{r:?}");
        assert!(r.area_overhead_pct > 3.0 && r.area_overhead_pct < 5.0, "{r:?}");
    }
}
