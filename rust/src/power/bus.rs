//! DSENT-style streaming-bus wire model [40].
//!
//! DSENT models an on-chip bus as a repeated global wire: energy per bit
//! per millimetre from wire + repeater capacitance (≈0.20 pJ/bit/mm at
//! 45 nm, 1.0 V), plus repeater leakage per millimetre. The streaming bus
//! of Fig. 10 spans its full row/column (one tile pitch per hop), and a
//! broadcast drives the whole span every cycle it is active.

use crate::config::SimConfig;
use crate::noc::stats::BusStats;

#[derive(Debug, Clone, PartialEq)]
pub struct BusEnergy {
    /// Switching energy, joules per bit per millimetre.
    pub j_per_bit_mm: f64,
    /// Repeater/driver leakage, watts per millimetre of bus.
    pub leak_w_per_mm: f64,
    /// Tile pitch, millimetres (bus length = pitch × nodes spanned).
    pub tile_pitch_mm: f64,
    /// Signalling activity factor (fraction of bits toggling).
    pub activity: f64,
}

impl BusEnergy {
    pub fn forty_five_nm() -> Self {
        BusEnergy {
            j_per_bit_mm: 0.20e-12,
            leak_w_per_mm: 12.0e-6,
            tile_pitch_mm: 1.0,
            activity: 0.5,
        }
    }

    /// Length of one row bus (west memory to east-most PE column).
    pub fn row_bus_mm(&self, cfg: &SimConfig) -> f64 {
        cfg.mesh_cols as f64 * self.tile_pitch_mm
    }

    /// Length of one column bus.
    pub fn col_bus_mm(&self, cfg: &SimConfig) -> f64 {
        cfg.mesh_rows as f64 * self.tile_pitch_mm
    }

    /// Dynamic switching energy for the recorded bus traffic, joules.
    /// Every word drives the full bus span (broadcast).
    pub fn dynamic_j(&self, cfg: &SimConfig, bus: &BusStats) -> f64 {
        let word_bits = cfg.gather_payload_bits as f64;
        let row_j =
            bus.row_words as f64 * word_bits * self.activity * self.j_per_bit_mm * self.row_bus_mm(cfg);
        let col_j =
            bus.col_words as f64 * word_bits * self.activity * self.j_per_bit_mm * self.col_bus_mm(cfg);
        row_j + col_j
    }

    /// Leakage over `cycles` for the full bus fabric (joules). One-way
    /// architectures instantiate only the row buses — callers pass
    /// `col_buses = 0`.
    pub fn leakage_j(
        &self,
        cfg: &SimConfig,
        row_buses: usize,
        col_buses: usize,
        cycles: u64,
    ) -> f64 {
        let total_mm = row_buses as f64 * self.row_bus_mm(cfg)
            + col_buses as f64 * self.col_bus_mm(cfg);
        total_mm * self.leak_w_per_mm * cycles as f64 / cfg.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_energy_scales_with_words_and_span() {
        let cfg8 = SimConfig::table1_8x8(1);
        let cfg16 = SimConfig::table1_16x16(1);
        let e = BusEnergy::forty_five_nm();
        let bus = BusStats { row_words: 1000, col_words: 0, active_cycles: 0 };
        let j8 = e.dynamic_j(&cfg8, &bus);
        let j16 = e.dynamic_j(&cfg16, &bus);
        assert!(j16 > 1.9 * j8, "longer bus costs proportionally more");
        let bus2 = BusStats { row_words: 2000, col_words: 0, active_cycles: 0 };
        assert!((e.dynamic_j(&cfg8, &bus2) / j8 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn leakage_scales_with_time() {
        let cfg = SimConfig::table1_8x8(1);
        let e = BusEnergy::forty_five_nm();
        let a = e.leakage_j(&cfg, 8, 8, 1_000);
        let b = e.leakage_j(&cfg, 8, 8, 2_000);
        assert!((b / a - 2.0).abs() < 1e-9);
        assert!(e.leakage_j(&cfg, 8, 0, 1_000) < a);
    }
}
