//! Power models: [`router`] (Orion-3.0-style per-event router energy),
//! [`bus`] (DSENT-style streaming-bus wires) and [`area`] (§5.4 overhead
//! roll-up), plus the whole-run roll-up [`PowerReport`].

pub mod area;
pub mod bus;
pub mod router;

use crate::config::{Collection, SimConfig, Streaming};
use crate::noc::stats::{BusStats, NetStats};
use bus::BusEnergy;
use router::RouterEnergy;

/// Energy breakdown of one simulated run (joules), and derived power.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerReport {
    pub router_dynamic_j: f64,
    pub router_static_j: f64,
    pub bus_dynamic_j: f64,
    pub bus_static_j: f64,
    pub total_j: f64,
    /// Average network power over the run, watts.
    pub avg_power_w: f64,
    pub cycles: u64,
}

/// Convert event counts into the §5.x power numbers.
///
/// `total_cycles` is the (extrapolated) runtime the energy is spread over;
/// static power accrues for the whole runtime on every router and every
/// instantiated bus.
pub fn power_report(
    cfg: &SimConfig,
    streaming: Streaming,
    collection: Collection,
    net: &NetStats,
    bus_stats: &BusStats,
    total_cycles: u64,
) -> PowerReport {
    let re = RouterEnergy::forty_five_nm();
    let be = BusEnergy::forty_five_nm();

    let mut dyn_j = net.buffer_writes as f64 * re.buffer_write_j
        + net.buffer_reads as f64 * re.buffer_read_j
        + net.crossbar_traversals as f64 * re.crossbar_j
        + (net.vc_allocs + net.sa_grants) as f64 * re.arbiter_j
        + net.link_traversals as f64 * re.link_j;
    match collection {
        Collection::Gather => {
            // Load generation fires on every gather head passing a router;
            // we approximate heads by packets × average hops = flit_hops /
            // flits, but the exact count is the boards + the checks that
            // failed — charging every board plus one check per hop of
            // gather heads.
            dyn_j += net.gather_boards as f64 * (re.gather_payload_j + re.gather_logic_j);
        }
        Collection::Ina => {
            // NI folds reuse the gather boarding hardware (load generator +
            // payload-queue read) and every folded or merged psum word
            // costs one router ALU add (Table-2-style INA overhead).
            dyn_j += net.ina_folds as f64 * (re.gather_payload_j + re.gather_logic_j);
            dyn_j += net.ina_adds as f64 * re.ina_add_j;
        }
        Collection::RepetitiveUnicast => {}
    }
    // NI partial-sum accumulation (WS register-file spill): one adder pass
    // + payload-register write per fold, independent of collection scheme.
    dyn_j += net.ni_accumulations as f64 * re.gather_payload_j;
    // Fault-injection retransmissions: each replay re-drives the link and
    // re-writes the receiver's input buffer (the retransmission slot is a
    // sender-side register, charged as one buffer write on replay).
    dyn_j += net.retransmissions as f64 * (re.link_j + re.buffer_write_j);

    let seconds = total_cycles as f64 / cfg.clock_hz;
    let routers = (cfg.mesh_rows * cfg.mesh_cols) as f64;
    let router_static_j = routers * re.static_w * seconds;

    let (bus_dynamic_j, bus_static_j) = match streaming {
        Streaming::Mesh => (0.0, 0.0),
        Streaming::OneWay => (
            be.dynamic_j(cfg, bus_stats),
            be.leakage_j(cfg, cfg.mesh_rows, 0, total_cycles),
        ),
        Streaming::TwoWay => (
            be.dynamic_j(cfg, bus_stats),
            be.leakage_j(cfg, cfg.mesh_rows, cfg.mesh_cols, total_cycles),
        ),
    };

    let total_j = dyn_j + router_static_j + bus_dynamic_j + bus_static_j;
    PowerReport {
        router_dynamic_j: dyn_j,
        router_static_j,
        bus_dynamic_j,
        bus_static_j,
        total_j,
        avg_power_w: if seconds > 0.0 { total_j / seconds } else { 0.0 },
        cycles: total_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(flits: u64) -> NetStats {
        NetStats {
            buffer_writes: flits,
            buffer_reads: flits,
            crossbar_traversals: flits,
            sa_grants: flits,
            link_traversals: flits,
            ..Default::default()
        }
    }

    #[test]
    fn more_traffic_more_energy() {
        let cfg = SimConfig::table1_8x8(1);
        let a = power_report(&cfg, Streaming::TwoWay, Collection::Gather, &stats(1000), &BusStats::default(), 10_000);
        let b = power_report(&cfg, Streaming::TwoWay, Collection::Gather, &stats(2000), &BusStats::default(), 10_000);
        assert!(b.total_j > a.total_j);
        assert!(b.router_dynamic_j > 1.9 * a.router_dynamic_j);
    }

    #[test]
    fn static_energy_scales_with_runtime() {
        let cfg = SimConfig::table1_8x8(1);
        let a = power_report(&cfg, Streaming::TwoWay, Collection::Gather, &stats(0), &BusStats::default(), 10_000);
        let b = power_report(&cfg, Streaming::TwoWay, Collection::Gather, &stats(0), &BusStats::default(), 20_000);
        assert!((b.router_static_j / a.router_static_j - 2.0).abs() < 1e-9);
    }

    #[test]
    fn one_way_has_less_bus_leakage_than_two_way() {
        let cfg = SimConfig::table1_8x8(1);
        let bus = BusStats { row_words: 100, col_words: 0, active_cycles: 100 };
        let one = power_report(&cfg, Streaming::OneWay, Collection::Gather, &stats(0), &bus, 10_000);
        let two = power_report(&cfg, Streaming::TwoWay, Collection::Gather, &stats(0), &bus, 10_000);
        assert!(one.bus_static_j < two.bus_static_j);
    }

    #[test]
    fn ina_adds_are_priced_only_under_ina_collection() {
        let cfg = SimConfig::table1_8x8(1);
        let net = NetStats { ina_folds: 100, ina_adds: 150, ..stats(0) };
        let ina =
            power_report(&cfg, Streaming::TwoWay, Collection::Ina, &net, &BusStats::default(), 1_000);
        let ru = power_report(
            &cfg,
            Streaming::TwoWay,
            Collection::RepetitiveUnicast,
            &net,
            &BusStats::default(),
            1_000,
        );
        assert!(ina.router_dynamic_j > ru.router_dynamic_j, "ALU adds must cost energy");
        // Same counters under gather collection price boards, not adds.
        let g_net = NetStats { gather_boards: 100, ..stats(0) };
        let g = power_report(
            &cfg,
            Streaming::TwoWay,
            Collection::Gather,
            &g_net,
            &BusStats::default(),
            1_000,
        );
        assert!(g.router_dynamic_j > 0.0);
        assert!(
            ina.router_dynamic_j > g.router_dynamic_j,
            "INA folds reuse the boarding hardware and add the ALU cost on top"
        );
    }

    #[test]
    fn retransmissions_cost_link_and_buffer_energy() {
        let cfg = SimConfig::table1_8x8(1);
        let clean = stats(1000);
        let faulty = NetStats { retransmissions: 200, ..stats(1000) };
        let a = power_report(&cfg, Streaming::TwoWay, Collection::Gather, &clean, &BusStats::default(), 10_000);
        let b = power_report(&cfg, Streaming::TwoWay, Collection::Gather, &faulty, &BusStats::default(), 10_000);
        let re = router::RouterEnergy::forty_five_nm();
        let delta = b.router_dynamic_j - a.router_dynamic_j;
        assert!((delta - 200.0 * (re.link_j + re.buffer_write_j)).abs() < 1e-18, "delta {delta}");
    }

    #[test]
    fn mesh_streaming_has_no_bus_energy() {
        let cfg = SimConfig::table1_8x8(1);
        let r = power_report(&cfg, Streaming::Mesh, Collection::Gather, &stats(10), &BusStats::default(), 1_000);
        assert_eq!(r.bus_dynamic_j + r.bus_static_j, 0.0);
    }
}
