//! Orion-3.0-style router energy/area model [39], 45 nm.
//!
//! Orion estimates router power from per-event energies (buffer read/write,
//! crossbar traversal, arbitration) plus leakage. We use the same
//! decomposition with constants calibrated so the Table-1 router
//! (5 ports, 2 VCs, 4-flit × 128-bit buffers) dissipates ≈26.3 mW at 1 GHz
//! under saturation load — the DSENT figure the paper reports in §5.4 —
//! with a ~40% leakage share, typical for 45 nm SRAM-dominated routers.
//!
//! Absolute joules are calibration anchors, not measurements; every result
//! the paper reports (and we reproduce) is a *ratio* between two runs of
//! the same model, which depends only on relative event counts.

/// Per-event energies (joules) and static power (watts) for one router.
#[derive(Debug, Clone, PartialEq)]
pub struct RouterEnergy {
    pub buffer_write_j: f64,
    pub buffer_read_j: f64,
    pub crossbar_j: f64,
    /// One allocation decision (VC or SA grant).
    pub arbiter_j: f64,
    /// Inter-router link traversal, one flit.
    pub link_j: f64,
    /// Gather support: Load-signal generation + ASpace update on a passing
    /// gather head (the Fig. 8 "Gather Load Generator").
    pub gather_logic_j: f64,
    /// Gather support: enqueue/fill of one payload from the NI queue.
    pub gather_payload_j: f64,
    /// In-network accumulation: one 32-bit ALU add folding a psum word
    /// into a passing packet (the Table-2-style INA router overhead of
    /// arXiv:2209.10056 — adder + operand mux on the datapath).
    pub ina_add_j: f64,
    /// Static (leakage + clock) power per router, watts.
    pub static_w: f64,
}

impl RouterEnergy {
    /// 45 nm constants for the Table-1 router at 1.0 V.
    ///
    /// Derivation of the calibration: at saturation one flit enters and
    /// leaves every port each cycle (5 writes, 5 reads, 5 crossbar
    /// traversals, ~5 grants, 4 link traversals), giving
    /// `5·(0.85+0.65+1.25+0.18) + 4·0.45 pJ ≈ 16.5 pJ/cycle = 16.5 mW`
    /// dynamic at 1 GHz; with 9.8 mW static the total is ≈26.3 mW (§5.4).
    pub fn forty_five_nm() -> Self {
        RouterEnergy {
            buffer_write_j: 0.85e-12,
            buffer_read_j: 0.65e-12,
            crossbar_j: 1.25e-12,
            arbiter_j: 0.18e-12,
            link_j: 0.45e-12,
            // §5.4: the proposed router adds ~6% power; the adders are the
            // load generator (comparator + subtractor on the head) and the
            // payload queue fill (one 32-bit register file write).
            gather_logic_j: 0.12e-12,
            gather_payload_j: 0.22e-12,
            // A 32-bit ripple/carry-select add at 45 nm is cheaper than an
            // SRAM access; ~0.1 pJ sits between the arbiter and the
            // payload-queue write, matching the "small ALU per router"
            // overhead the INA follow-up reports.
            ina_add_j: 0.10e-12,
            static_w: 9.8e-3,
        }
    }

    /// Dynamic power at saturation load, watts at `clock_hz` (calibration
    /// check; see unit test).
    pub fn saturation_power(&self, clock_hz: f64) -> f64 {
        let per_cycle = 5.0 * (self.buffer_write_j + self.buffer_read_j + self.crossbar_j)
            + 5.0 * self.arbiter_j
            + 4.0 * self.link_j;
        self.static_w + per_cycle * clock_hz
    }
}

/// Area model (µm², 45 nm), component roll-up in the style of the Orion /
/// DSENT area reports. Calibrated to the paper's §5.4 figures:
/// baseline 72 106 µm², proposed (gather-supported) 74 950 µm² (+3.9%).
#[derive(Debug, Clone, PartialEq)]
pub struct RouterArea {
    pub buffers_um2: f64,
    pub crossbar_um2: f64,
    pub allocators_um2: f64,
    pub other_um2: f64,
    /// Gather Load Generator (comparators, ASpace subtractor) — Fig. 8.
    pub gather_load_gen_um2: f64,
    /// Gather payload queue + status signalling — Fig. 8.
    pub gather_payload_q_um2: f64,
}

impl RouterArea {
    pub fn forty_five_nm() -> Self {
        // Input buffers dominate (5 ports × 2 VCs × 4 × 128 b ≈ 5 Kb SRAM).
        RouterArea {
            buffers_um2: 39_000.0,
            crossbar_um2: 17_500.0,
            allocators_um2: 6_600.0,
            other_um2: 9_006.0,
            gather_load_gen_um2: 780.0,
            gather_payload_q_um2: 2_064.0,
        }
    }

    /// Baseline (unmodified) router area.
    pub fn baseline(&self) -> f64 {
        self.buffers_um2 + self.crossbar_um2 + self.allocators_um2 + self.other_um2
    }

    /// Gather-supported router area (Fig. 8).
    pub fn proposed(&self) -> f64 {
        self.baseline() + self.gather_load_gen_um2 + self.gather_payload_q_um2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturation_power_matches_the_papers_dsent_figure() {
        // §5.4: 26.3 mW at 1 GHz for the Table-1 router.
        let e = RouterEnergy::forty_five_nm();
        let p = e.saturation_power(1.0e9);
        assert!((p - 26.3e-3).abs() < 0.5e-3, "saturation power {p}");
    }

    #[test]
    fn area_matches_the_papers_synthesis_report() {
        // §5.4: 72106 µm² baseline, 74950 µm² proposed.
        let a = RouterArea::forty_five_nm();
        assert!((a.baseline() - 72_106.0).abs() < 110.0, "baseline {}", a.baseline());
        assert!((a.proposed() - 74_950.0).abs() < 110.0, "proposed {}", a.proposed());
        let overhead = a.proposed() / a.baseline() - 1.0;
        assert!(overhead > 0.03 && overhead < 0.05, "area overhead {overhead}");
    }

    #[test]
    fn leakage_share_is_plausible_for_45nm() {
        let e = RouterEnergy::forty_five_nm();
        let share = e.static_w / e.saturation_power(1.0e9);
        assert!(share > 0.3 && share < 0.5, "leakage share {share}");
    }
}
