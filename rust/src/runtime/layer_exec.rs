//! Convolution-layer execution through the AOT artifacts.
//!
//! Artifacts are per-layer-shape HLO modules produced by
//! `python/compile/aot.py` (the L2 JAX model calling the L1 Pallas
//! OS-matmul kernel). `LayerExecutor` resolves the artifact for a layer,
//! compiles it once, and executes it with concrete tensors — the numeric
//! half of the accelerator that the NoC simulator provides the timing for.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::{LoadedModel, Runtime, Tensor};
use crate::models::ConvLayer;

/// Artifact file name for a layer shape (mirrors `aot.py::artifact_name`).
pub fn artifact_name(c: usize, h: usize, r: usize, stride: usize, pad: usize, q: usize) -> String {
    format!("conv_c{c}_h{h}_r{r}_s{stride}_p{pad}_q{q}.hlo.txt")
}

/// Per-process executor: one PJRT client, one compiled executable per
/// distinct layer shape (compile-once, execute-many).
pub struct LayerExecutor {
    runtime: Runtime,
    artifacts_dir: PathBuf,
    cache: HashMap<String, LoadedModel>,
}

impl LayerExecutor {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<LayerExecutor> {
        Ok(LayerExecutor {
            runtime: Runtime::cpu()?,
            artifacts_dir: artifacts_dir.into(),
            cache: HashMap::new(),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    fn ensure_loaded(&mut self, layer: &ConvLayer) -> Result<String> {
        let name =
            artifact_name(layer.c, layer.h_in, layer.r, layer.stride, layer.pad, layer.q);
        if !self.cache.contains_key(&name) {
            let path = self.artifacts_dir.join(&name);
            anyhow::ensure!(
                path.exists(),
                "artifact {} not found — run `make artifacts` (layer {})",
                path.display(),
                layer.name
            );
            let model = self.runtime.load_hlo_text(&path)?;
            self.cache.insert(name.clone(), model);
        }
        Ok(name)
    }

    /// Execute the layer forward: `input [1,C,H,H]`, `weights [Q,C,R,R]`
    /// → `[1,Q,Ho,Ho]`.
    pub fn forward(&mut self, layer: &ConvLayer, input: &Tensor, weights: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(
            input.shape == vec![1, layer.c, layer.h_in, layer.h_in],
            "input shape {:?} does not match layer {}",
            input.shape,
            layer.name
        );
        anyhow::ensure!(
            weights.shape == vec![layer.q, layer.c, layer.r, layer.r],
            "weight shape {:?} does not match layer {}",
            weights.shape,
            layer.name
        );
        let h_out = layer.h_out();
        let key = self.ensure_loaded(layer)?;
        let model = &self.cache[&key];
        let outputs = self
            .runtime
            .exec_f32(model, &[input.clone(), weights.clone()])
            .with_context(|| format!("executing artifact for layer {}", layer.name))?;
        anyhow::ensure!(outputs.len() == 1, "expected a single output tensor");
        let data = outputs.into_iter().next().unwrap();
        anyhow::ensure!(
            data.len() == layer.q * h_out * h_out,
            "output size {} does not match [1,{},{h_out},{h_out}]",
            data.len(),
            layer.q
        );
        Ok(Tensor::new(vec![1, layer.q, h_out, h_out], data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_are_shape_keyed() {
        assert_eq!(artifact_name(3, 32, 3, 1, 1, 16), "conv_c3_h32_r3_s1_p1_q16.hlo.txt");
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let mut ex = LayerExecutor::new("/nonexistent-artifacts").unwrap();
        let layer = ConvLayer { name: "t", c: 3, h_in: 8, r: 3, stride: 1, pad: 1, q: 4 };
        let input = Tensor::zeros(vec![1, 3, 8, 8]);
        let weights = Tensor::zeros(vec![4, 3, 3, 3]);
        let err = ex.forward(&layer, &input, &weights).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
