//! PJRT runtime bridge — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them from rust. Python is never on this path.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`).
//!
//! ## Feature gating
//!
//! The PJRT client requires the `xla` crate, which the offline build image
//! does not carry. The real implementation is compiled only with the
//! `pjrt` cargo feature (which additionally requires adding the `xla`
//! dependency to `Cargo.toml`); the default build ships an API-compatible
//! stub whose `load_hlo_text`/`exec_f32` fail with a clear message. The
//! pure-rust [`reference`] numerics, [`Tensor`], and everything the NoC
//! timing simulation needs are always available.

pub mod layer_exec;
pub mod reference;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::Tensor;
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A PJRT CPU client plus the executables loaded on it.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// One compiled model artifact.
    pub struct LoadedModel {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path is not UTF-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            let name = path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "model".to_string());
            Ok(LoadedModel { exe, name })
        }

        /// Execute with f32 tensor inputs; returns every output of the result
        /// tuple, flattened (artifacts are lowered with `return_tuple=True`).
        pub fn exec_f32(&self, model: &LoadedModel, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for t in inputs {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping input to {:?}", t.shape))?;
                literals.push(lit);
            }
            let result = model.exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = result.to_tuple().context("decomposing result tuple")?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f32>().context("converting output to f32")?);
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use super::Tensor;
    use anyhow::Result;
    use std::path::Path;

    /// Stub PJRT client (built without the `pjrt` feature). Construction
    /// succeeds so callers can probe artifact availability first; loading
    /// or executing an artifact fails with a clear message.
    pub struct Runtime {
        _private: (),
    }

    /// Stub handle for a compiled model artifact.
    pub struct LoadedModel {
        pub name: String,
    }

    impl Runtime {
        /// Create the stub client (always succeeds).
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime { _private: () })
        }

        pub fn platform(&self) -> String {
            "stub (built without the `pjrt` feature)".to_string()
        }

        /// Always fails: PJRT support is not compiled in.
        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
            anyhow::bail!(
                "cannot load {}: built without the `pjrt` feature (add the `xla` \
                 dependency and rebuild with `--features pjrt`)",
                path.display()
            )
        }

        /// Always fails: PJRT support is not compiled in.
        pub fn exec_f32(&self, model: &LoadedModel, _inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!(
                "cannot execute {}: built without the `pjrt` feature (add the `xla` \
                 dependency and rebuild with `--features pjrt`)",
                model.name
            )
        }
    }
}

pub use pjrt_impl::{LoadedModel, Runtime};

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor for tests/examples.
    pub fn random(shape: Vec<usize>, seed: u64) -> Tensor {
        let mut rng = crate::util::rng::Rng::new(seed);
        let n = shape.iter().product();
        let data = (0..n).map(|_| (rng.unit() as f32 - 0.5) * 2.0).collect();
        Tensor { shape, data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Max absolute difference between two equally-shaped buffers.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "comparing buffers of different sizes");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_rejects_bad_shape() {
        Tensor::new(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn random_tensor_is_deterministic() {
        let a = Tensor::random(vec![8], 7);
        let b = Tensor::random(vec![8], 7);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn max_abs_diff_finds_the_worst_element() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_loudly_on_load() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().contains("stub"));
        let err = rt.load_hlo_text(std::path::Path::new("x.hlo.txt")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
