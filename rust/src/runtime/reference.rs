//! Pure-rust reference convolution (naive direct form). This is the
//! third corner of the correctness triangle:
//!
//! * `python/compile/kernels/ref.py` — jnp oracle checked against the
//!   Pallas kernel at build time;
//! * the AOT artifact executed through PJRT at run time;
//! * this function, checked against the artifact output in integration
//!   tests and the end-to-end example — proving the whole
//!   python-AOT → rust-runtime pipeline preserves numerics.

use super::Tensor;

/// Direct NCHW convolution. `input` is `[1, C, H, H]`, `weights` is
/// `[Q, C, R, R]`; returns `[1, Q, Ho, Ho]` with the given stride/padding.
pub fn conv2d(input: &Tensor, weights: &Tensor, stride: usize, pad: usize) -> Tensor {
    assert_eq!(input.shape.len(), 4, "input must be NCHW");
    assert_eq!(weights.shape.len(), 4, "weights must be QCRR");
    let (nb, c, h, w) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    let (q, cw, r, r2) = (weights.shape[0], weights.shape[1], weights.shape[2], weights.shape[3]);
    assert_eq!(nb, 1, "reference supports batch 1");
    assert_eq!(c, cw, "channel mismatch");
    assert_eq!(r, r2, "kernels are square");
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - r) / stride + 1;
    let mut out = Tensor::zeros(vec![1, q, ho, wo]);
    for oc in 0..q {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = 0.0f32;
                for ic in 0..c {
                    for ky in 0..r {
                        for kx in 0..r {
                            let iy = oy * stride + ky;
                            let ix = ox * stride + kx;
                            if iy < pad || ix < pad {
                                continue;
                            }
                            let (iy, ix) = (iy - pad, ix - pad);
                            if iy >= h || ix >= w {
                                continue;
                            }
                            let iv = input.data[(ic * h + iy) * w + ix];
                            let wv = weights.data[((oc * c + ic) * r + ky) * r + kx];
                            acc += iv * wv;
                        }
                    }
                }
                out.data[(oc * ho + oy) * wo + ox] = acc;
            }
        }
    }
    out
}

/// im2col patch extraction matching the L2 JAX model's layout: returns
/// `[P, C·R·R]` where `P = Ho·Wo` — the exact operand stream each PE row
/// receives in the OS dataflow (Fig. 4).
pub fn im2col(input: &Tensor, r: usize, stride: usize, pad: usize) -> Tensor {
    let (c, h, w) = (input.shape[1], input.shape[2], input.shape[3]);
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - r) / stride + 1;
    let k = c * r * r;
    let mut out = Tensor::zeros(vec![ho * wo, k]);
    for oy in 0..ho {
        for ox in 0..wo {
            let p = oy * wo + ox;
            for ic in 0..c {
                for ky in 0..r {
                    for kx in 0..r {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        let v = if iy < pad || ix < pad || iy - pad >= h || ix - pad >= w {
                            0.0
                        } else {
                            input.data[(ic * h + (iy - pad)) * w + (ix - pad)]
                        };
                        out.data[p * k + (ic * r + ky) * r + kx] = v;
                    }
                }
            }
        }
    }
    out
}

/// Matmul `[m,k] × [k,n] → [m,n]` (row-major). The OS dataflow computes
/// exactly `im2col(input) × weightsᵀ`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "inner dimension mismatch");
    let mut out = Tensor::zeros(vec![m, n]);
    for i in 0..m {
        for l in 0..k {
            let av = a.data[i * k + l];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                out.data[i * n + j] += av * b.data[l * n + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::max_abs_diff;

    #[test]
    fn identity_kernel_preserves_input() {
        // 1x1 kernel of 1.0 on a single channel is identity.
        let input = Tensor::random(vec![1, 1, 5, 5], 3);
        let weights = Tensor::new(vec![1, 1, 1, 1], vec![1.0]);
        let out = conv2d(&input, &weights, 1, 0);
        assert_eq!(out.shape, vec![1, 1, 5, 5]);
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn conv_matches_im2col_matmul() {
        // The OS dataflow identity: conv = im2col × Wᵀ, reshaped.
        let input = Tensor::random(vec![1, 3, 8, 8], 11);
        let weights = Tensor::random(vec![4, 3, 3, 3], 12);
        let direct = conv2d(&input, &weights, 1, 1);

        let patches = im2col(&input, 3, 1, 1); // [64, 27]
        let wt = {
            // [Q, C·R·R] -> transpose to [C·R·R, Q]
            let k = 27;
            let q = 4;
            let mut t = Tensor::zeros(vec![k, q]);
            for qq in 0..q {
                for kk in 0..k {
                    t.data[kk * q + qq] = weights.data[qq * k + kk];
                }
            }
            t
        };
        let mm = matmul(&patches, &wt); // [64, 4] = [P, Q]
        // direct is [1, Q, 8, 8]; mm is [P, Q] with P = 64.
        for p in 0..64 {
            for q in 0..4 {
                let d = direct.data[q * 64 + p];
                let m = mm.data[p * 4 + q];
                assert!((d - m).abs() < 1e-4, "p={p} q={q}: {d} vs {m}");
            }
        }
    }

    #[test]
    fn stride_and_padding_geometry() {
        let input = Tensor::random(vec![1, 2, 9, 9], 5);
        let weights = Tensor::random(vec![3, 2, 3, 3], 6);
        let out = conv2d(&input, &weights, 2, 1);
        assert_eq!(out.shape, vec![1, 3, 5, 5]);
    }

    #[test]
    fn matmul_small_known_case() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = matmul(&a, &b);
        assert_eq!(max_abs_diff(&c.data, &[3.0, 3.0, 7.0, 7.0]), 0.0);
    }
}
