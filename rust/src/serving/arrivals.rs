//! Seeded stochastic arrival processes for inference requests.
//!
//! Open-loop modes (Poisson, deterministic uniform) push a fixed offered
//! load regardless of how the fabric keeps up — the right model for
//! shared front-ends and the one that exposes the saturation knee.
//! Closed-loop mode models a bounded client population: each client has
//! at most one request outstanding and thinks for a fixed time between
//! completion and reissue, so offered load self-throttles to service
//! capacity (the mode the drain-to-zero conservation test exercises).
//!
//! All randomness comes from one [SplitMix64](Rng) stream seeded from
//! [`ServingConfig::seed`](super::ServingConfig::seed): same seed, same
//! arrival ledger, bit for bit.

use crate::config::ConfigError;
use crate::util::rng::Rng;

/// How requests enter the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Open loop, exponential inter-arrival gaps (memoryless traffic).
    Poisson,
    /// Open loop, constant inter-arrival gap `1e6 / rate` — a
    /// deterministic pace clock, useful for pinning exact latencies.
    Uniform,
    /// Closed loop: `clients` issuers, one outstanding request each,
    /// fixed think time between completion and reissue.
    ClosedLoop,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Result<ArrivalKind, ConfigError> {
        match s {
            "poisson" => Ok(ArrivalKind::Poisson),
            "uniform" => Ok(ArrivalKind::Uniform),
            "closed" | "closed-loop" => Ok(ArrivalKind::ClosedLoop),
            other => Err(ConfigError::UnknownKeyword {
                what: "arrival",
                got: other.to_string(),
                expected: "poisson | uniform | closed",
            }),
        }
    }

    pub fn key(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Uniform => "uniform",
            ArrivalKind::ClosedLoop => "closed",
        }
    }
}

/// One inference request: a single image against the served model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Mint order, 0-based — doubles as the ledger key.
    pub id: u64,
    /// Owning tenant, `id % tenants` (round-robin across tenants keeps
    /// per-tenant load balanced without a second RNG stream).
    pub tenant: usize,
    /// Closed-loop issuer index; 0 for open-loop traffic.
    pub client: usize,
    /// Cycle the request entered the system.
    pub arrival: u64,
}

/// Mints [`Request`]s and, for open-loop modes, draws inter-arrival gaps.
#[derive(Debug, Clone)]
pub struct ArrivalProcess {
    kind: ArrivalKind,
    rng: Rng,
    /// Mean inter-arrival gap in cycles (`1e6 / rate_per_mcycle`).
    mean_gap: f64,
    tenants: usize,
    next_id: u64,
}

impl ArrivalProcess {
    /// `rate_per_mcycle` is only meaningful for open-loop kinds; pass
    /// anything (it is unused) for [`ArrivalKind::ClosedLoop`].
    pub fn new(
        kind: ArrivalKind,
        rate_per_mcycle: f64,
        tenants: usize,
        seed: u64,
    ) -> ArrivalProcess {
        let mean_gap = if rate_per_mcycle > 0.0 {
            1.0e6 / rate_per_mcycle
        } else {
            0.0
        };
        ArrivalProcess {
            kind,
            rng: Rng::new(seed),
            mean_gap,
            tenants: tenants.max(1),
            next_id: 0,
        }
    }

    /// Cycles until the next open-loop arrival; always at least 1 so the
    /// event clock advances. Poisson draws an exponential via inverse
    /// transform; uniform is the rounded mean.
    pub fn gap(&mut self) -> u64 {
        let cycles = match self.kind {
            ArrivalKind::Poisson => {
                // u in [0,1) so 1-u in (0,1] and the log is finite.
                let u = self.rng.unit();
                -(1.0 - u).ln() * self.mean_gap
            }
            ArrivalKind::Uniform | ArrivalKind::ClosedLoop => self.mean_gap,
        };
        (cycles.round() as u64).max(1)
    }

    /// Mint the next request; ids are dense and tenant assignment is
    /// round-robin by id.
    pub fn mint(&mut self, arrival: u64, client: usize) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        Request {
            id,
            tenant: (id % self.tenants as u64) as usize,
            client,
            arrival,
        }
    }

    /// Requests minted so far.
    pub fn minted(&self) -> u64 {
        self.next_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_gap_sequence() {
        let mut a = ArrivalProcess::new(ArrivalKind::Poisson, 5.0, 1, 42);
        let mut b = ArrivalProcess::new(ArrivalKind::Poisson, 5.0, 1, 42);
        for _ in 0..1000 {
            assert_eq!(a.gap(), b.gap());
        }
    }

    #[test]
    fn poisson_mean_gap_tracks_the_rate() {
        // rate 10/Mcycle -> mean gap 100k cycles; the empirical mean over
        // 20k draws should land within a few percent.
        let mut p = ArrivalProcess::new(ArrivalKind::Poisson, 10.0, 1, 7);
        let n = 20_000u64;
        let total: u64 = (0..n).map(|_| p.gap()).sum();
        let mean = total as f64 / n as f64;
        assert!(
            (mean - 100_000.0).abs() < 5_000.0,
            "empirical mean gap {mean} too far from 100k"
        );
    }

    #[test]
    fn uniform_gap_is_constant_and_rounded() {
        let mut u = ArrivalProcess::new(ArrivalKind::Uniform, 4.0, 1, 1);
        for _ in 0..10 {
            assert_eq!(u.gap(), 250_000);
        }
        // Gaps never collapse to zero even at absurd rates.
        let mut fast = ArrivalProcess::new(ArrivalKind::Uniform, 1.0e9, 1, 1);
        assert_eq!(fast.gap(), 1);
    }

    #[test]
    fn minting_is_dense_and_round_robin_across_tenants() {
        let mut p = ArrivalProcess::new(ArrivalKind::Uniform, 1.0, 3, 9);
        let reqs: Vec<Request> = (0..7).map(|i| p.mint(i * 10, 0)).collect();
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tenant, i % 3);
            assert_eq!(r.arrival, i as u64 * 10);
        }
        assert_eq!(p.minted(), 7);
    }
}
