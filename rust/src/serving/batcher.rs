//! Batch formation and admission control.
//!
//! Requests wait in bounded queues until a batch is formed — either a
//! full one (`batch` images ready) or a partial one forced out when the
//! queue head has aged past the batch timeout (so a lone request is
//! never parked forever behind an unreachable fill target). Admission is
//! capacity-checked here: a request that arrives with `queue_cap`
//! requests already waiting is rejected and counted, which is what makes
//! offered-vs-accepted load a meaningful pair of numbers in the report.
//!
//! Scheduling is FIFO (one shared queue) or per-tenant priority: each
//! tenant gets its own queue, lower tenant ids strictly win ties, and a
//! batch carries the virtual-channel class its tenant maps to
//! (`tenant % vc_classes`). The VC tag rides along as pass metadata —
//! the profile-based serving executor time-shares the fabric at layer
//! granularity rather than re-simulating per-flit VC arbitration, but
//! the tag keeps the tenant→VC mapping visible in ledgers and reports
//! (and gives a cycle-accurate multi-pass NoC a ready-made handle).
//!
//! Everything here is integer state machines over [`VecDeque`]s: batch
//! formation order is a pure function of (arrival order, clock), so the
//! batcher contributes nothing nondeterministic to a seeded run.

use std::collections::VecDeque;

use super::arrivals::Request;
use super::ServingConfig;
use crate::config::ConfigError;

/// Queue discipline for batch formation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    /// One shared queue, strict arrival order.
    Fifo,
    /// Per-tenant queues; the lowest-id tenant with a full batch wins,
    /// then the most-overdue timed-out head.
    Priority,
}

impl SchedKind {
    pub fn parse(s: &str) -> Result<SchedKind, ConfigError> {
        match s {
            "fifo" => Ok(SchedKind::Fifo),
            "priority" => Ok(SchedKind::Priority),
            other => Err(ConfigError::UnknownKeyword {
                what: "sched",
                got: other.to_string(),
                expected: "fifo | priority",
            }),
        }
    }

    pub fn key(&self) -> &'static str {
        match self {
            SchedKind::Fifo => "fifo",
            SchedKind::Priority => "priority",
        }
    }
}

/// A formed batch, ready to be admitted as an in-flight pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// Source queue: tenant id under priority scheduling, 0 under FIFO.
    pub tenant: usize,
    /// Virtual-channel class the batch's traffic is tagged with.
    pub vc: usize,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Bounded queues + batch formation. See the module docs for the rules.
#[derive(Debug, Clone)]
pub struct Batcher {
    sched: SchedKind,
    batch: usize,
    timeout: u64,
    queue_cap: usize,
    vc_classes: usize,
    /// One queue under FIFO, `tenants` queues under priority.
    queues: Vec<VecDeque<Request>>,
    queued: usize,
    /// Requests admitted into a queue.
    pub accepted: u64,
    /// Requests turned away at capacity.
    pub rejected: u64,
}

impl Batcher {
    /// `timeout` is the resolved batch timeout in cycles (the executor
    /// resolves the config's `0 = auto` before building the batcher).
    pub fn new(cfg: &ServingConfig, timeout: u64, vc_classes: usize) -> Batcher {
        let lanes = match cfg.sched {
            SchedKind::Fifo => 1,
            SchedKind::Priority => cfg.tenants.max(1),
        };
        Batcher {
            sched: cfg.sched,
            batch: cfg.batch.max(1),
            timeout: timeout.max(1),
            queue_cap: cfg.queue_cap.max(1),
            vc_classes: vc_classes.max(1),
            queues: (0..lanes).map(|_| VecDeque::new()).collect(),
            queued: 0,
            accepted: 0,
            rejected: 0,
        }
    }

    /// Admit or reject one arrival. Returns whether it was queued.
    pub fn offer(&mut self, req: Request) -> bool {
        if self.queued >= self.queue_cap {
            self.rejected += 1;
            return false;
        }
        let lane = match self.sched {
            SchedKind::Fifo => 0,
            SchedKind::Priority => req.tenant % self.queues.len(),
        };
        self.queues[lane].push_back(req);
        self.queued += 1;
        self.accepted += 1;
        true
    }

    /// Requests currently waiting across all queues.
    pub fn depth(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Earliest cycle at which some queue head times out, if any request
    /// is waiting. The executor uses this only as a sanity bound; the
    /// event loop schedules an explicit timeout event per admission.
    pub fn next_deadline(&self) -> Option<u64> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|r| r.arrival + self.timeout))
            .min()
    }

    /// Form the next batch at cycle `now`, or `None` if no queue has
    /// either a full batch or a timed-out head. Deterministic: full
    /// batches beat timeouts, lower tenant ids break every tie.
    pub fn pop_batch(&mut self, now: u64) -> Option<Batch> {
        // Pass 1: lowest-id lane with a full batch.
        let full = (0..self.queues.len()).find(|&i| self.queues[i].len() >= self.batch);
        // Pass 2: among timed-out heads, the most overdue (oldest head
        // arrival); ties fall to the lower lane via strict `<`.
        let lane = full.or_else(|| {
            let mut best: Option<(u64, usize)> = None;
            for (i, q) in self.queues.iter().enumerate() {
                if let Some(head) = q.front() {
                    if head.arrival + self.timeout <= now
                        && best.map_or(true, |(a, _)| head.arrival < a)
                    {
                        best = Some((head.arrival, i));
                    }
                }
            }
            best.map(|(_, i)| i)
        })?;
        let take = self.queues[lane].len().min(self.batch);
        let requests: Vec<Request> =
            self.queues[lane].drain(..take).collect();
        self.queued -= take;
        let tenant = match self.sched {
            SchedKind::Fifo => 0,
            SchedKind::Priority => lane,
        };
        Some(Batch {
            requests,
            tenant,
            vc: tenant % self.vc_classes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::arrivals::{ArrivalKind, ArrivalProcess};
    use super::*;

    fn cfg(sched: SchedKind, batch: usize, tenants: usize, cap: usize) -> ServingConfig {
        ServingConfig {
            rate_per_mcycle: 1.0,
            sched,
            batch,
            tenants,
            queue_cap: cap,
            ..ServingConfig::default()
        }
    }

    fn mint(n: usize, tenants: usize) -> Vec<Request> {
        let mut p = ArrivalProcess::new(ArrivalKind::Uniform, 1.0, tenants, 1);
        (0..n).map(|i| p.mint(i as u64 * 10, 0)).collect()
    }

    #[test]
    fn fifo_forms_full_batches_in_arrival_order() {
        let mut b = Batcher::new(&cfg(SchedKind::Fifo, 3, 1, 64), 1000, 1);
        for r in mint(7, 1) {
            assert!(b.offer(r));
        }
        let first = b.pop_batch(60).expect("full batch available");
        assert_eq!(
            first.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        let second = b.pop_batch(60).expect("second full batch");
        assert_eq!(
            second.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![3, 4, 5]
        );
        // One request left: below the fill target and not yet timed out.
        assert!(b.pop_batch(60).is_none());
        assert_eq!(b.depth(), 1);
        // Past its deadline (arrival 60 + timeout 1000) it flushes alone.
        let flush = b.pop_batch(1060).expect("timeout flush");
        assert_eq!(flush.len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn capacity_rejections_are_counted() {
        let mut b = Batcher::new(&cfg(SchedKind::Fifo, 4, 1, 3), 1000, 1);
        let reqs = mint(5, 1);
        let admitted: Vec<bool> = reqs.into_iter().map(|r| b.offer(r)).collect();
        assert_eq!(admitted, vec![true, true, true, false, false]);
        assert_eq!((b.accepted, b.rejected), (3, 2));
        assert_eq!(b.depth(), 3);
    }

    #[test]
    fn priority_prefers_the_lowest_tenant_with_a_full_batch() {
        let mut b = Batcher::new(&cfg(SchedKind::Priority, 2, 3, 64), 1000, 4);
        // Round-robin tenants: ids 0..6 -> tenants 0,1,2,0,1,2.
        for r in mint(6, 3) {
            assert!(b.offer(r));
        }
        let batch = b.pop_batch(60).expect("tenant 0 is full");
        assert_eq!(batch.tenant, 0);
        assert_eq!(batch.vc, 0);
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 3]
        );
        // Next full lane by id order: tenant 1.
        assert_eq!(b.pop_batch(60).expect("tenant 1").tenant, 1);
        assert_eq!(b.pop_batch(60).expect("tenant 2").tenant, 2);
        assert!(b.is_empty());
    }

    #[test]
    fn priority_timeout_picks_the_most_overdue_head() {
        let mut b = Batcher::new(&cfg(SchedKind::Priority, 4, 2, 64), 100, 2);
        let mut p = ArrivalProcess::new(ArrivalKind::Uniform, 1.0, 2, 1);
        // id 0 -> tenant 0 at cycle 0; id 1 -> tenant 1 at cycle 5.
        let a = p.mint(0, 0);
        let b1 = p.mint(5, 0);
        b.offer(a);
        b.offer(b1);
        // Neither lane is full; at cycle 150 both heads are overdue and
        // tenant 0's (arrival 0) is older.
        let first = b.pop_batch(150).expect("overdue head");
        assert_eq!(first.tenant, 0);
        assert_eq!(first.vc, 0);
        let second = b.pop_batch(150).expect("remaining overdue head");
        assert_eq!(second.tenant, 1);
        assert_eq!(second.vc, 1);
    }

    #[test]
    fn deadline_tracks_the_oldest_head() {
        let mut b = Batcher::new(&cfg(SchedKind::Fifo, 8, 1, 64), 500, 1);
        assert_eq!(b.next_deadline(), None);
        for r in mint(2, 1) {
            b.offer(r);
        }
        assert_eq!(b.next_deadline(), Some(500));
    }
}
