//! Multi-pass fabric-sharing executor and the serving metrics layer.
//!
//! ## The fabric-sharing model
//!
//! A whole-network pass occupies the mesh one layer at a time — the
//! executor's own convention (each layer's output feature map completes
//! before the next layer starts) means the NoC is a **serial resource at
//! layer granularity**. The serving executor exploits that: it measures
//! each layer once through the real per-flit simulator (via
//! [`ServiceProfile::from_run`]) and then time-shares the fabric across
//! concurrent in-flight passes by granting it to one pass per layer
//! slice from a FIFO ready ring. A pass that finishes a layer re-enters
//! the back of the ring, so `max_inflight` passes interleave
//! round-robin at layer granularity — the same policy a cycle-accurate
//! multi-pass fabric would approach with fair arbitration, at event
//! cost instead of per-flit cost.
//!
//! Batching scales each layer slice: a batch of `B` images pays the
//! layer's setup once and its streaming/compute/reload terms per image
//! (`setup + B x (per_image + reload)`), which is exactly why batching
//! buys throughput at the cost of per-request latency.
//!
//! ## Determinism
//!
//! The event loop is single-threaded over the
//! [`Calendar`](crate::noc::calendar::Calendar) queue; the only
//! randomness is the seeded arrival RNG. Executor parallelism knobs
//! (`threads`, `intra_workers`) affect the *profile measurement* only,
//! and those runs are bit-identical by the network executor's own
//! guarantee — so the request ledger, percentiles, and every counter
//! here are bit-identical for a given seed. `tests/serving.rs` pins it.
//!
//! ## Conservation
//!
//! At every event cycle the loop audits
//! `offered == completed + rejected + queued + in_flight` and counts
//! violations (always zero unless the scheduler leaks a request); the
//! count is part of the report so CI can assert on it.

use std::collections::VecDeque;

use super::arrivals::{ArrivalKind, ArrivalProcess};
use super::batcher::{Batch, Batcher};
use super::ServingConfig;
use crate::coordinator::executor::NetworkRunReport;
use crate::noc::calendar::Calendar;
use crate::noc::faults::DegradationReport;
use crate::noc::probes::{Bottleneck, ProbeReport};
use crate::util::histogram::Histogram;
use crate::util::json::Json;

/// p99 multiplier (vs. the lowest swept rate) past which a sweep point
/// no longer counts as pre-knee.
pub const KNEE_BLOWUP: f64 = 5.0;

/// Latency histogram geometry: bucket width is one 64th of a full-batch
/// pass, so the tail resolves to ~1.5% of a pass and 8192 buckets cover
/// 128 queued pass-times before overflow (overflow reports the max).
const LAT_BUCKETS: usize = 8192;

/// Hard ceiling on processed events — a liveness backstop far above any
/// real run (arrivals are >= 1 cycle apart and passes retire requests).
const EVENT_CAP: u64 = 200_000_000;

/// What one layer of the served model costs, measured once by the
/// network executor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerCost {
    pub name: String,
    /// Paid once per batch: pipeline fill / drain and control overhead.
    pub setup_cycles: u64,
    /// Paid per image: the layer's streaming + compute + collection term.
    pub per_image_cycles: u64,
    /// Paid per image: refilling the layer's input feature map between
    /// passes (the executor's inter-layer reload charge).
    pub reload_cycles: u64,
}

/// Per-layer service costs plus the load-attribution artifacts carried
/// over from the measuring run: the hottest layer's link probes (for
/// "which link saturates first under load") and the summed degradation
/// ledger when the profile was measured on a faulty fabric.
#[derive(Debug, Clone)]
pub struct ServiceProfile {
    pub model: String,
    pub layers: Vec<LayerCost>,
    /// Virtual-channel classes tenants map onto (the fabric's VC count).
    pub vc_classes: usize,
    /// Link probes of the most expensive layer (per-image + reload) —
    /// the layer that bounds service rate, hence the saturation story.
    pub probes: Option<ProbeReport<'static>>,
    /// Field-wise sum of the measuring run's per-layer degradation.
    pub degraded: Option<DegradationReport>,
}

impl ServiceProfile {
    /// Distill a [`NetworkRunReport`] into per-layer costs. The driver's
    /// `total_cycles` splits into the setup prefix and a per-image
    /// remainder; `reload_cycles` is the executor's boundary charge.
    pub fn from_run(run: &NetworkRunReport) -> ServiceProfile {
        let mut layers = Vec::with_capacity(run.layers.len());
        let mut hot: Option<(u64, usize)> = None;
        for (i, l) in run.layers.iter().enumerate() {
            let total = l.report.run.total_cycles;
            let setup = l.report.run.setup_cycles.min(total);
            let per_image = (total - setup).max(1);
            layers.push(LayerCost {
                name: l.report.layer.clone(),
                setup_cycles: setup,
                per_image_cycles: per_image,
                reload_cycles: l.reload_cycles,
            });
            // Strict `>` keeps the first of equals — deterministic.
            let weight = per_image + l.reload_cycles;
            if hot.map_or(true, |(w, _)| weight > w) {
                hot = Some((weight, i));
            }
        }
        let probes = hot.and_then(|(_, i)| run.layers[i].report.run.probes.clone());
        let mut acc = DegradationReport::default();
        let mut any = false;
        for l in &run.layers {
            if let Some(d) = &l.report.run.degraded {
                any = true;
                acc.missing_contributors += d.missing_contributors;
                acc.payloads_dropped += d.payloads_dropped;
                acc.packets_dropped += d.packets_dropped;
                acc.flits_dropped += d.flits_dropped;
                acc.flits_corrupted += d.flits_corrupted;
                acc.retransmissions += d.retransmissions;
                acc.retries_exhausted += d.retries_exhausted;
                acc.detour_hops += d.detour_hops;
                acc.streams_truncated += d.streams_truncated;
                acc.streams_dropped += d.streams_dropped;
            }
        }
        ServiceProfile {
            model: run.model.clone(),
            layers,
            vc_classes: run.cfg.vcs.max(1),
            probes,
            degraded: any.then_some(acc),
        }
    }

    /// A hand-built profile for tests and benches — no fabric run needed.
    pub fn synthetic(model: &str, layers: Vec<LayerCost>) -> ServiceProfile {
        ServiceProfile {
            model: model.to_string(),
            layers,
            vc_classes: 2,
            probes: None,
            degraded: None,
        }
    }

    /// Cycles layer `i` occupies the fabric for a batch of `batch` images.
    pub fn layer_cycles(&self, i: usize, batch: u64) -> u64 {
        let l = &self.layers[i];
        l.setup_cycles
            .saturating_add(batch.saturating_mul(l.per_image_cycles + l.reload_cycles))
    }

    /// Cycles one whole pass of `batch` images occupies the fabric.
    pub fn pass_cycles(&self, batch: u64) -> u64 {
        (0..self.layers.len())
            .map(|i| self.layer_cycles(i, batch))
            .sum()
    }

    /// Upper bound on sustainable throughput at this batch size,
    /// requests per Mcycle — the fabric is serial, so it is simply
    /// `batch / pass_cycles`. Sweeps use this to place rates around the
    /// knee.
    pub fn capacity_per_mcycle(&self, batch: u64) -> f64 {
        batch as f64 * 1.0e6 / self.pass_cycles(batch).max(1) as f64
    }

    /// The link that bounds this profile's hottest layer, if the
    /// measuring run carried probes.
    pub fn bottleneck(&self) -> Option<Bottleneck> {
        self.probes.as_ref().and_then(|p| p.bottleneck())
    }
}

/// One retired request in the ledger (the bit-identity witness).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompletedRequest {
    pub id: u64,
    pub tenant: usize,
    pub client: usize,
    pub arrival: u64,
    pub completion: u64,
}

/// Everything a seeded serving run produced.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub model: String,
    pub cfg: ServingConfig,
    /// Resolved batch timeout (after `0 = auto`).
    pub batch_timeout: u64,
    /// Resolved arrival window (after `0 = auto`).
    pub duration: u64,
    /// Cycle the last event retired (arrival window + drain).
    pub total_cycles: u64,
    pub offered: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub fabric_busy_cycles: u64,
    /// `fabric_busy_cycles / total_cycles` — approaches 1 at the knee.
    pub utilization: f64,
    pub latency: Histogram,
    pub queue_depth_max: u64,
    pub queue_depth_mean: f64,
    pub throughput_per_mcycle: f64,
    /// Sample points where `offered != completed + rejected + queued +
    /// in_flight` — always 0 unless the scheduler leaks a request.
    pub conservation_violations: u64,
    pub queued_at_end: u64,
    pub inflight_at_end: u64,
    /// The link that saturates first under load (from the profile).
    pub bottleneck: Option<Bottleneck>,
    /// Degradation carried by the profile's measuring run, if faulty.
    pub degraded: Option<DegradationReport>,
    /// Per-request completions in retirement order.
    pub ledger: Vec<CompletedRequest>,
}

impl ServingReport {
    pub fn p50(&self) -> u64 {
        self.latency.percentile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.latency.percentile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.latency.percentile(0.999)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("model", Json::Str(self.model.clone()))
            .set("serving", self.cfg.to_json())
            .set("batch_timeout", Json::Num(self.batch_timeout as f64))
            .set("duration", Json::Num(self.duration as f64))
            .set("total_cycles", Json::Num(self.total_cycles as f64))
            .set("offered", Json::Num(self.offered as f64))
            .set("accepted", Json::Num(self.accepted as f64))
            .set("rejected", Json::Num(self.rejected as f64))
            .set("completed", Json::Num(self.completed as f64))
            .set("batches", Json::Num(self.batches as f64))
            .set("mean_batch_fill", Json::Num(self.mean_batch_fill))
            .set("fabric_busy_cycles", Json::Num(self.fabric_busy_cycles as f64))
            .set("utilization", Json::Num(self.utilization))
            .set(
                "throughput_per_mcycle",
                Json::Num(self.throughput_per_mcycle),
            )
            .set(
                "conservation_violations",
                Json::Num(self.conservation_violations as f64),
            )
            .set("queued_at_end", Json::Num(self.queued_at_end as f64))
            .set("inflight_at_end", Json::Num(self.inflight_at_end as f64))
            .set("latency", self.latency.to_json());
        let mut q = Json::obj();
        q.set("mean", Json::Num(self.queue_depth_mean))
            .set("max", Json::Num(self.queue_depth_max as f64));
        j.set("queue_depth", q);
        // Same bottleneck object shape as ProbeReport::to_json, so
        // downstream tooling parses both.
        if let Some(b) = &self.bottleneck {
            let mut o = Json::obj();
            o.set("link", Json::Str(b.label()))
                .set("port", Json::Str(b.port.letter().to_string()))
                .set("utilization", Json::Num(b.utilization))
                .set("flits", Json::Num(b.flits as f64))
                .set("vc", Json::Num(b.vc as f64))
                .set("blocked_cycles", Json::Num(b.blocked_cycles as f64))
                .set("stage", Json::Str(b.stage.label().to_string()));
            j.set("bottleneck", o);
        } else {
            j.set("bottleneck", Json::Null);
        }
        match &self.degraded {
            Some(d) => j.set("degraded", d.to_json()),
            None => j.set("degraded", Json::Null),
        };
        j
    }
}

/// Everything the event loop schedules.
enum Event {
    /// Next open-loop arrival (self-rescheduling until the window ends).
    Arrival,
    /// A closed-loop client issues (or retries) its request.
    ClientArrival(usize),
    /// A queue head may have aged out; purely a dispatch trigger, stale
    /// ones are no-ops.
    BatchTimeout,
    /// The fabric finished the current layer slice of pass `slot`.
    LayerDone(usize),
}

/// An admitted batch working through the model's layers.
struct Pass {
    batch: Batch,
    next_layer: usize,
}

/// Run one seeded serving simulation against a measured profile.
pub fn serve(profile: &ServiceProfile, cfg: &ServingConfig) -> crate::Result<ServingReport> {
    cfg.validate()?;
    anyhow::ensure!(
        !profile.layers.is_empty(),
        "service profile has no layers to serve"
    );
    let batch_images = cfg.batch as u64;
    let full_pass = profile.pass_cycles(batch_images).max(1);
    let timeout = if cfg.batch_timeout == 0 {
        (full_pass / 2).max(1)
    } else {
        cfg.batch_timeout
    };
    let duration = if cfg.duration == 0 {
        full_pass.saturating_mul(32).max(1_000_000)
    } else {
        cfg.duration
    };

    let mut arrivals =
        ArrivalProcess::new(cfg.arrival, cfg.rate_per_mcycle, cfg.tenants, cfg.seed);
    let mut batcher = Batcher::new(cfg, timeout, profile.vc_classes);
    let mut events: Calendar<Event> = Calendar::new();
    let mut latency = Histogram::new((full_pass / 64).max(1), LAT_BUCKETS);

    let mut passes: Vec<Option<Pass>> = Vec::new();
    let mut ready: VecDeque<usize> = VecDeque::new();
    let mut fabric_busy = false;
    let mut inflight_passes = 0usize;
    let mut inflight_requests = 0u64;
    let mut completed = 0u64;
    let mut batches = 0u64;
    let mut fill_sum = 0u64;
    let mut busy_cycles = 0u64;
    let mut ledger: Vec<CompletedRequest> = Vec::new();
    let mut conservation_violations = 0u64;
    let (mut depth_sum, mut depth_max, mut depth_samples) = (0u64, 0u64, 0u64);
    let mut clock = 0u64;
    let mut processed = 0u64;

    match cfg.arrival {
        ArrivalKind::ClosedLoop => {
            // Stagger the population by one cycle each so issue order is
            // well-defined without a tie-break rule.
            for c in 0..cfg.clients {
                events.push(1 + c as u64, Event::ClientArrival(c));
            }
        }
        ArrivalKind::Poisson | ArrivalKind::Uniform => {
            let first = arrivals.gap();
            if first <= duration {
                events.push(first, Event::Arrival);
            }
        }
    }

    let mut scratch: Vec<Event> = Vec::new();
    while let Some(cycle) = events.next_cycle() {
        clock = cycle;
        scratch.clear();
        events.drain_up_to(cycle, &mut scratch);
        for ev in scratch.drain(..) {
            processed += 1;
            match ev {
                Event::Arrival => {
                    let req = arrivals.mint(clock, 0);
                    if batcher.offer(req) {
                        events.push(clock + timeout, Event::BatchTimeout);
                    }
                    let next = clock + arrivals.gap();
                    if next <= duration {
                        events.push(next, Event::Arrival);
                    }
                }
                Event::ClientArrival(c) => {
                    let req = arrivals.mint(clock, c);
                    if batcher.offer(req) {
                        events.push(clock + timeout, Event::BatchTimeout);
                    } else {
                        // The client population is fixed: a rejected
                        // client thinks and retries rather than vanishing.
                        let retry = clock + cfg.think_cycles.max(1);
                        if retry <= duration {
                            events.push(retry, Event::ClientArrival(c));
                        }
                    }
                }
                Event::BatchTimeout => {}
                Event::LayerDone(slot) => {
                    fabric_busy = false;
                    let finished = {
                        let pass = passes[slot].as_mut().expect("pass slot is live");
                        pass.next_layer += 1;
                        pass.next_layer >= profile.layers.len()
                    };
                    if finished {
                        let pass = passes[slot].take().expect("pass slot is live");
                        inflight_passes -= 1;
                        inflight_requests -= pass.batch.len() as u64;
                        completed += pass.batch.len() as u64;
                        for r in &pass.batch.requests {
                            latency.record(clock - r.arrival);
                            ledger.push(CompletedRequest {
                                id: r.id,
                                tenant: r.tenant,
                                client: r.client,
                                arrival: r.arrival,
                                completion: clock,
                            });
                            if cfg.arrival == ArrivalKind::ClosedLoop {
                                let next = clock + cfg.think_cycles.max(1);
                                if next <= duration {
                                    events.push(next, Event::ClientArrival(r.client));
                                }
                            }
                        }
                    } else {
                        // Round-robin: back of the ready ring.
                        ready.push_back(slot);
                    }
                }
            }
        }
        anyhow::ensure!(
            processed <= EVENT_CAP,
            "serving run wedged: {processed} events without draining (cycle {clock})"
        );

        // Admit every batch the scheduler can form while in-flight slots
        // are free.
        while inflight_passes < cfg.max_inflight {
            let Some(batch) = batcher.pop_batch(clock) else {
                break;
            };
            batches += 1;
            fill_sum += batch.len() as u64;
            inflight_requests += batch.len() as u64;
            inflight_passes += 1;
            let slot = passes.len();
            passes.push(Some(Pass {
                batch,
                next_layer: 0,
            }));
            ready.push_back(slot);
        }
        // Grant the serial fabric to the next ready pass.
        if !fabric_busy {
            if let Some(slot) = ready.pop_front() {
                let pass = passes[slot].as_ref().expect("pass slot is live");
                let cycles = profile
                    .layer_cycles(pass.next_layer, pass.batch.len() as u64)
                    .max(1);
                busy_cycles += cycles;
                fabric_busy = true;
                events.push(clock + cycles, Event::LayerDone(slot));
            }
        }
        // Conservation audit + queue-depth sample at every event cycle.
        let queued = batcher.depth() as u64;
        if arrivals.minted() != completed + batcher.rejected + queued + inflight_requests {
            conservation_violations += 1;
        }
        depth_samples += 1;
        depth_sum += queued;
        depth_max = depth_max.max(queued);
    }

    let total_cycles = clock.max(1);
    Ok(ServingReport {
        model: profile.model.clone(),
        cfg: cfg.clone(),
        batch_timeout: timeout,
        duration,
        total_cycles,
        offered: arrivals.minted(),
        accepted: batcher.accepted,
        rejected: batcher.rejected,
        completed,
        batches,
        mean_batch_fill: if batches == 0 {
            0.0
        } else {
            fill_sum as f64 / batches as f64
        },
        fabric_busy_cycles: busy_cycles,
        utilization: busy_cycles as f64 / total_cycles as f64,
        latency,
        queue_depth_max: depth_max,
        queue_depth_mean: if depth_samples == 0 {
            0.0
        } else {
            depth_sum as f64 / depth_samples as f64
        },
        throughput_per_mcycle: completed as f64 * 1.0e6 / total_cycles as f64,
        conservation_violations,
        queued_at_end: batcher.depth() as u64,
        inflight_at_end: inflight_requests,
        bottleneck: profile.bottleneck(),
        degraded: profile.degraded.clone(),
        ledger,
    })
}

/// One swept arrival rate and its full report.
#[derive(Debug, Clone)]
pub struct RatePoint {
    pub rate: f64,
    pub report: ServingReport,
}

/// An ascending arrival-rate sweep with the located saturation knee.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub points: Vec<RatePoint>,
    /// Index of the highest pre-knee rate: the last point, scanning from
    /// the lowest rate, with zero rejections and p99 within
    /// [`KNEE_BLOWUP`] x the lowest rate's p99. `None` if even the first
    /// rate saturates.
    pub knee: Option<usize>,
}

impl SweepReport {
    pub fn knee_rate(&self) -> Option<f64> {
        self.knee.map(|i| self.points[i].rate)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let model = self
            .points
            .first()
            .map(|p| p.report.model.clone())
            .unwrap_or_default();
        j.set("model", Json::Str(model));
        match self.knee_rate() {
            Some(r) => j.set("knee_rate_per_mcycle", Json::Num(r)),
            None => j.set("knee_rate_per_mcycle", Json::Null),
        };
        let points = self
            .points
            .iter()
            .map(|p| {
                let r = &p.report;
                let mut o = Json::obj();
                o.set("rate_per_mcycle", Json::Num(p.rate))
                    .set("offered", Json::Num(r.offered as f64))
                    .set("rejected", Json::Num(r.rejected as f64))
                    .set("completed", Json::Num(r.completed as f64))
                    .set("throughput_per_mcycle", Json::Num(r.throughput_per_mcycle))
                    .set("utilization", Json::Num(r.utilization))
                    .set("p50", Json::Num(r.p50() as f64))
                    .set("p99", Json::Num(r.p99() as f64))
                    .set("p999", Json::Num(r.p999() as f64));
                o
            })
            .collect();
        j.set("points", Json::Arr(points));
        j
    }
}

/// Serve the profile at each rate in ascending order and locate the
/// saturation knee. Open-loop modes only — a closed loop self-throttles
/// and has no offered-rate axis to sweep.
pub fn sweep(
    profile: &ServiceProfile,
    base: &ServingConfig,
    rates: &[f64],
) -> crate::Result<SweepReport> {
    anyhow::ensure!(!rates.is_empty(), "rate sweep needs at least one rate");
    anyhow::ensure!(
        rates.windows(2).all(|w| w[0] < w[1]),
        "sweep rates must be strictly increasing"
    );
    anyhow::ensure!(
        base.arrival != ArrivalKind::ClosedLoop,
        "rate sweep needs an open-loop arrival mode (poisson | uniform)"
    );
    let mut points = Vec::with_capacity(rates.len());
    for &rate in rates {
        let mut cfg = base.clone();
        cfg.rate_per_mcycle = rate;
        let report = serve(profile, &cfg)?;
        points.push(RatePoint { rate, report });
    }
    let base_p99 = points[0].report.p99().max(1) as f64;
    let mut knee = None;
    for (i, p) in points.iter().enumerate() {
        let r = &p.report;
        if r.rejected == 0 && (r.p99() as f64) <= base_p99 * KNEE_BLOWUP {
            knee = Some(i);
        } else {
            break;
        }
    }
    Ok(SweepReport { points, knee })
}

#[cfg(test)]
mod tests {
    use super::super::SchedKind;
    use super::*;

    fn flat_profile(layers: usize, per_image: u64) -> ServiceProfile {
        ServiceProfile::synthetic(
            "synthetic",
            (0..layers)
                .map(|i| LayerCost {
                    name: format!("l{i}"),
                    setup_cycles: 0,
                    per_image_cycles: per_image,
                    reload_cycles: 0,
                })
                .collect(),
        )
    }

    #[test]
    fn unloaded_uniform_arrivals_pin_the_exact_latency() {
        // One layer of 100 cycles/image, batch 1, one arrival per 10k
        // cycles: no queueing ever, so every latency is exactly 100.
        let profile = flat_profile(1, 100);
        let cfg = ServingConfig {
            arrival: ArrivalKind::Uniform,
            rate_per_mcycle: 100.0,
            batch: 1,
            max_inflight: 1,
            duration: 1_000_000,
            ..ServingConfig::default()
        };
        let r = serve(&profile, &cfg).unwrap();
        assert_eq!(r.offered, 100);
        assert_eq!(r.completed, 100);
        assert_eq!(r.rejected, 0);
        assert_eq!(r.conservation_violations, 0);
        assert_eq!((r.p50(), r.p99(), r.p999()), (100, 100, 100));
        assert_eq!(r.latency.max(), 100);
        assert_eq!(r.queued_at_end, 0);
        assert_eq!(r.inflight_at_end, 0);
    }

    #[test]
    fn batch_slices_pay_setup_once_and_per_image_per_image() {
        let profile = ServiceProfile::synthetic(
            "synthetic",
            vec![LayerCost {
                name: "l0".into(),
                setup_cycles: 50,
                per_image_cycles: 100,
                reload_cycles: 10,
            }],
        );
        assert_eq!(profile.layer_cycles(0, 1), 160);
        assert_eq!(profile.layer_cycles(0, 4), 490);
        assert_eq!(profile.pass_cycles(4), 490);
        let cap = profile.capacity_per_mcycle(4);
        assert!((cap - 4.0e6 / 490.0).abs() < 1e-9);
    }

    #[test]
    fn overload_rejects_and_conserves() {
        // Capacity is 1 req / 1000 cycles; offer 10x that into a short
        // queue: rejections must appear and the audit must stay clean.
        let profile = flat_profile(2, 500);
        let cfg = ServingConfig {
            arrival: ArrivalKind::Uniform,
            rate_per_mcycle: 10_000.0,
            batch: 1,
            queue_cap: 8,
            max_inflight: 2,
            duration: 400_000,
            ..ServingConfig::default()
        };
        let r = serve(&profile, &cfg).unwrap();
        assert!(r.rejected > 0, "10x overload must reject");
        assert_eq!(r.offered, r.accepted + r.rejected);
        assert_eq!(r.accepted, r.completed, "the run drains fully");
        assert_eq!(r.conservation_violations, 0);
        assert_eq!(r.ledger.len() as u64, r.completed);
        assert!(r.utilization > 0.9, "overloaded fabric is ~saturated");
    }

    #[test]
    fn priority_ledger_orders_tenant_zero_first() {
        // Two tenants, both queues fill while the fabric is busy; the
        // priority scheduler must retire tenant 0's batch first.
        let profile = flat_profile(1, 1000);
        let cfg = ServingConfig {
            arrival: ArrivalKind::Uniform,
            rate_per_mcycle: 4000.0, // 4x capacity
            batch: 2,
            tenants: 2,
            sched: SchedKind::Priority,
            queue_cap: 32,
            max_inflight: 1,
            duration: 100_000,
            ..ServingConfig::default()
        };
        let r = serve(&profile, &cfg).unwrap();
        assert!(r.completed >= 4);
        assert_eq!(r.conservation_violations, 0);
        let first_batch: Vec<usize> = r.ledger[..2].iter().map(|c| c.tenant).collect();
        assert_eq!(first_batch, vec![0, 0], "tenant 0 retires first");
    }

    #[test]
    fn sweep_finds_a_knee_and_p99_blows_up_past_it() {
        let profile = flat_profile(4, 250); // 1000 cycles/image
        let base = ServingConfig {
            arrival: ArrivalKind::Poisson,
            batch: 1,
            queue_cap: 32,
            max_inflight: 1,
            duration: 2_000_000,
            ..ServingConfig::default()
        };
        // Capacity is 1000 req/Mcycle; sweep through it.
        let rates = [100.0, 400.0, 800.0, 1500.0, 3000.0];
        let sw = sweep(&profile, &base, &rates).unwrap();
        let knee = sw.knee.expect("low rates are pre-knee");
        assert!(knee < rates.len() - 1, "3x overload cannot be pre-knee");
        let last = &sw.points[rates.len() - 1].report;
        let at_knee = &sw.points[knee].report;
        assert!(
            last.p99() > at_knee.p99(),
            "p99 must blow up past the knee: {} vs {}",
            last.p99(),
            at_knee.p99()
        );
        assert!(last.rejected > 0 || last.p99() as f64 > KNEE_BLOWUP * at_knee.p99() as f64);
    }

    #[test]
    fn sweep_rejects_unordered_rates_and_closed_loops() {
        let profile = flat_profile(1, 100);
        let base = ServingConfig {
            batch: 1,
            ..ServingConfig::default()
        };
        assert!(sweep(&profile, &base, &[]).is_err());
        assert!(sweep(&profile, &base, &[5.0, 2.0]).is_err());
        let closed = ServingConfig {
            arrival: ArrivalKind::ClosedLoop,
            ..ServingConfig::default()
        };
        assert!(sweep(&profile, &closed, &[1.0, 2.0]).is_err());
    }
}
