//! Serving-scale traffic: request streams, batch scheduling, and
//! tail-latency metrics.
//!
//! The rest of the crate answers "how many cycles does one layer (or one
//! whole-network pass) cost on this fabric?" — the paper's question. A
//! capacity planner asks a different one: *at what offered load does the
//! fabric saturate, and what does the p99 latency look like near that
//! knee?* This module answers it by composing four pieces:
//!
//! ```text
//!   arrivals ──▶ batcher ──▶ multi-pass executor ──▶ metrics
//!   (seeded       (size/       (time-shares the        (throughput,
//!    Poisson /     timeout /    NoC across in-flight     queue depths,
//!    closed        per-tenant   passes at layer          p50/p99/p999)
//!    loop)         priority)    granularity)
//! ```
//!
//! * [`arrivals`] — a seeded stochastic arrival process (Poisson,
//!   deterministic-uniform, or closed-loop clients) minting
//!   [`Request`]s against a chosen model.
//! * [`batcher`] — admission control: bounded per-tenant queues, batch
//!   formation by size or timeout, FIFO or priority scheduling with
//!   tenants mapped onto virtual-channel classes.
//! * [`executor`] — the multi-pass fabric-sharing executor. It reuses
//!   the network executor's per-layer results as a [`ServiceProfile`]
//!   (per-layer setup / per-image / reload costs, plus the hot layer's
//!   [`ProbeReport`](crate::noc::probes::ProbeReport) bottleneck and the
//!   summed [`DegradationReport`](crate::noc::faults::DegradationReport)),
//!   and time-shares the NoC across concurrent passes through the
//!   [`Calendar`](crate::noc::calendar::Calendar) event core.
//!
//! ## Determinism
//!
//! Everything is seeded and single-threaded at the serving level: the
//! arrival RNG is [SplitMix64](crate::util::rng::Rng), the calendar
//! drains events in (cycle, insertion-order) order, the fabric is a
//! serial resource granted to passes from a FIFO ready ring, and the
//! latency tail is a fixed-bucket integer
//! [`Histogram`](crate::util::histogram::Histogram). Executor-level
//! parallelism (`threads`, `intra_workers`) only affects how the
//! *profile* is measured, and those runs are bit-identical by the
//! executor's own determinism guarantee — so a seeded serving run is
//! bit-identical across every parallelism knob. `tests/serving.rs` pins
//! this.

pub mod arrivals;
pub mod batcher;
pub mod executor;

pub use arrivals::{ArrivalKind, ArrivalProcess, Request};
pub use batcher::{Batch, Batcher, SchedKind};
pub use executor::{
    serve, sweep, CompletedRequest, LayerCost, RatePoint, ServiceProfile,
    ServingReport, SweepReport, KNEE_BLOWUP,
};

use crate::config::ConfigError;
use crate::util::json::Json;

/// Knobs for one serving run. Everything is in cycles or requests —
/// rates are expressed per **million cycles** (`Mcycle`) because a
/// whole-network pass on the 8x8 mesh costs millions of cycles, so
/// per-cycle rates would be unreadably small.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// Arrival mode (open-loop Poisson/uniform or closed-loop clients).
    pub arrival: ArrivalKind,
    /// Open-loop offered load, requests per million cycles. Ignored by
    /// closed-loop mode.
    pub rate_per_mcycle: f64,
    /// Closed-loop population size. Ignored by open-loop modes.
    pub clients: usize,
    /// Closed-loop think time between a completion and the client's next
    /// request.
    pub think_cycles: u64,
    /// Max images per admitted batch.
    pub batch: usize,
    /// Cycles a queue head may age before a partial batch is forced out.
    /// 0 = auto: half a full-batch pass time, derived from the profile.
    pub batch_timeout: u64,
    /// Number of tenants; arrivals are round-robin across tenants.
    pub tenants: usize,
    /// FIFO (single queue) or per-tenant priority queues mapped to VCs.
    pub sched: SchedKind,
    /// Total queued-request capacity; arrivals beyond it are rejected.
    pub queue_cap: usize,
    /// Max concurrent in-flight passes time-sharing the fabric.
    pub max_inflight: usize,
    /// Cycles during which new arrivals are generated; the run then
    /// drains to completion. 0 = auto: 32 full-batch pass times.
    pub duration: u64,
    /// Arrival-process RNG seed.
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> ServingConfig {
        ServingConfig {
            arrival: ArrivalKind::Poisson,
            rate_per_mcycle: 0.0,
            clients: 4,
            think_cycles: 0,
            batch: 4,
            batch_timeout: 0,
            tenants: 1,
            sched: SchedKind::Fifo,
            queue_cap: 64,
            max_inflight: 2,
            duration: 0,
            seed: 1,
        }
    }
}

impl ServingConfig {
    /// Typed validation, same contract as
    /// [`SimConfig::validate`](crate::config::SimConfig::validate): every
    /// rejection is a [`ConfigError`] naming the serving knob that broke.
    pub fn validate(&self) -> Result<(), ConfigError> {
        fn check(cond: bool, reason: &str) -> Result<(), ConfigError> {
            if cond {
                Ok(())
            } else {
                Err(ConfigError::invalid("serving", reason))
            }
        }
        match self.arrival {
            ArrivalKind::Poisson | ArrivalKind::Uniform => {
                check(
                    self.rate_per_mcycle.is_finite() && self.rate_per_mcycle > 0.0,
                    "arrival rate must be a positive, finite number of \
                     requests per Mcycle (--arrival-rate)",
                )?;
            }
            ArrivalKind::ClosedLoop => {
                check(self.clients >= 1, "closed-loop mode needs at least one client")?;
            }
        }
        check(self.batch >= 1, "batch size must be at least 1 image")?;
        check(self.tenants >= 1, "tenant count must be at least 1")?;
        check(self.queue_cap >= 1, "queue capacity must be at least 1")?;
        check(self.max_inflight >= 1, "max in-flight passes must be at least 1")?;
        Ok(())
    }

    /// Config echo embedded in the serving report.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("arrival", Json::Str(self.arrival.key().to_string()))
            .set("rate_per_mcycle", Json::Num(self.rate_per_mcycle))
            .set("clients", Json::Num(self.clients as f64))
            .set("think_cycles", Json::Num(self.think_cycles as f64))
            .set("batch", Json::Num(self.batch as f64))
            .set("batch_timeout", Json::Num(self.batch_timeout as f64))
            .set("tenants", Json::Num(self.tenants as f64))
            .set("sched", Json::Str(self.sched.key().to_string()))
            .set("queue_cap", Json::Num(self.queue_cap as f64))
            .set("max_inflight", Json::Num(self.max_inflight as f64))
            .set("duration", Json::Num(self.duration as f64))
            .set("seed", Json::Num(self.seed as f64));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_names_the_serving_knob() {
        let cfg = ServingConfig {
            rate_per_mcycle: 2.0,
            ..ServingConfig::default()
        };
        assert!(cfg.validate().is_ok());

        let zero_rate = ServingConfig::default();
        let err = zero_rate.validate().unwrap_err();
        assert!(err.to_string().contains("serving"), "{err}");
        assert!(err.to_string().contains("arrival rate"), "{err}");

        let bad_batch = ServingConfig {
            rate_per_mcycle: 2.0,
            batch: 0,
            ..ServingConfig::default()
        };
        let err = bad_batch.validate().unwrap_err();
        assert!(err.to_string().contains("serving"), "{err}");
        assert!(err.to_string().contains("batch"), "{err}");

        // Closed loop ignores the rate but insists on a population.
        let closed = ServingConfig {
            arrival: ArrivalKind::ClosedLoop,
            clients: 0,
            ..ServingConfig::default()
        };
        assert!(closed.validate().is_err());
        let closed_ok = ServingConfig {
            arrival: ArrivalKind::ClosedLoop,
            ..ServingConfig::default()
        };
        assert!(closed_ok.validate().is_ok());
    }

    #[test]
    fn keyword_parses_reject_unknown_modes() {
        assert!(ArrivalKind::parse("poisson").is_ok());
        assert!(ArrivalKind::parse("uniform").is_ok());
        assert!(ArrivalKind::parse("closed").is_ok());
        let err = ArrivalKind::parse("bursty").unwrap_err();
        assert!(err.to_string().contains("arrival"), "{err}");
        assert!(SchedKind::parse("fifo").is_ok());
        assert!(SchedKind::parse("priority").is_ok());
        assert!(SchedKind::parse("wfq").is_err());
    }

    #[test]
    fn config_json_echo_is_complete() {
        let cfg = ServingConfig {
            rate_per_mcycle: 3.5,
            ..ServingConfig::default()
        };
        let j = cfg.to_json();
        for key in [
            "arrival",
            "rate_per_mcycle",
            "batch",
            "batch_timeout",
            "tenants",
            "sched",
            "queue_cap",
            "max_inflight",
            "duration",
            "seed",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
