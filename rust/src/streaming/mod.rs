//! The streaming-bus architecture of §4.3/Fig. 10.
//!
//! Dedicated buses carry operands from the memory elements straight to the
//! PE rows/columns, eliminating per-hop router traversal for one-to-many
//! traffic:
//!
//! * **Two-way** (Fig. 10(a)): one input-activation streaming unit per row
//!   and one weight streaming unit per column, operating in parallel.
//! * **One-way** (Fig. 10(b)): a single per-row link shared by inputs and
//!   weights, interleaved through a multiplexor — half the wires, twice the
//!   occupancy.
//!
//! Flow control (§4.4): the global buffer tracks per-NI credits and a
//! stream unit only drives a word when *all* NIs in its row/column have
//! space, guaranteeing single-cycle delivery. The PEs of [36] consume one
//! word per cycle deterministically, so in steady state the gate never
//! closes; [`StreamUnit`] still models the gate so failure injection tests
//! can exercise stalls.

use crate::config::{SimConfig, Streaming};
use crate::dataflow::Dataflow;
use crate::noc::stats::BusStats;

/// One streaming unit driving one row (inputs) or column (weights).
#[derive(Debug, Clone)]
pub struct StreamUnit {
    /// Words still to stream this round.
    pub remaining: u64,
    /// Words deliverable per cycle (bus width, `f_l`).
    pub words_per_cycle: u32,
    /// Per-NI free-space credits along the bus (global-buffer view).
    pub credits: Vec<u32>,
    /// Total words driven (power accounting).
    pub words_driven: u64,
    /// Cycles the bus was active.
    pub active_cycles: u64,
}

impl StreamUnit {
    pub fn new(words: u64, words_per_cycle: u32, nis: usize, ni_queue_depth: u32) -> Self {
        StreamUnit {
            remaining: words,
            words_per_cycle,
            credits: vec![ni_queue_depth; nis],
            words_driven: 0,
            active_cycles: 0,
        }
    }

    /// §4.4: "The streaming unit will only perform the streaming if all the
    /// nodes have free space to hold the data."
    pub fn can_stream(&self) -> bool {
        self.remaining > 0 && self.credits.iter().all(|&c| c > 0)
    }

    /// Advance one cycle: drive up to `words_per_cycle` words (broadcast to
    /// every NI on the bus), consuming one credit per NI per word. Returns
    /// words driven.
    pub fn step(&mut self) -> u64 {
        if !self.can_stream() {
            return 0;
        }
        let burst = (self.words_per_cycle as u64)
            .min(self.remaining)
            .min(self.credits.iter().copied().min().unwrap_or(0) as u64);
        if burst == 0 {
            return 0;
        }
        for c in self.credits.iter_mut() {
            *c -= burst as u32;
        }
        self.remaining -= burst;
        self.words_driven += burst;
        self.active_cycles += 1;
        burst
    }

    /// An NI consumed `k` words (PE register file accepted them).
    pub fn refund(&mut self, ni: usize, k: u32) {
        self.credits[ni] += k;
    }

    pub fn done(&self) -> bool {
        self.remaining == 0
    }
}

/// Streaming-bus activity for ONE round of a dataflow's schedule (power
/// accounting input). Word demand and the active window both come from the
/// [`Dataflow`] mapping; the bus *count* comes from the topology's
/// [`crate::noc::topology::Topology::bus_attachments`] (one unit per
/// router row/column — a concentrated mesh therefore runs half the buses,
/// each feeding NIs that serve `c` PEs), so OS and WS and every fabric
/// account through the same code path. Mesh streaming has no buses.
pub fn per_round_bus_stats(
    cfg: &SimConfig,
    streaming: Streaming,
    mapping: &dyn Dataflow,
) -> BusStats {
    let att = crate::noc::topology::bus_attachments(cfg);
    let w = mapping.stream_words();
    match streaming {
        Streaming::TwoWay => BusStats {
            row_words: att.row_buses as u64 * w.row,
            col_words: att.col_buses as u64 * w.col,
            active_cycles: mapping.stream_cycles(cfg, streaming),
        },
        Streaming::OneWay => BusStats {
            // The shared per-row link carries inputs and weights interleaved
            // (Fig. 10(b)); weight words ride the row bus.
            row_words: att.row_buses as u64 * (w.row + w.col),
            col_words: 0,
            active_cycles: mapping.stream_cycles(cfg, streaming),
        },
        Streaming::Mesh => BusStats::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::os::OsMapping;
    use crate::dataflow::ws::WsMapping;
    use crate::models::ConvLayer;

    #[test]
    fn unit_streams_all_words_when_credits_flow() {
        let mut u = StreamUnit::new(10, 1, 4, 2);
        let mut cycles = 0;
        while !u.done() {
            let w = u.step();
            // Consume immediately (deterministic PEs).
            for ni in 0..4 {
                u.refund(ni, w as u32);
            }
            cycles += 1;
            assert!(cycles < 100, "livelock");
        }
        assert_eq!(u.words_driven, 10);
        assert_eq!(cycles, 10);
    }

    #[test]
    fn gate_closes_when_any_ni_backs_up() {
        let mut u = StreamUnit::new(10, 1, 4, 1);
        assert_eq!(u.step(), 1);
        // No refunds: all NIs full now.
        assert!(!u.can_stream());
        assert_eq!(u.step(), 0);
        u.refund(0, 1);
        // NI 0 has space but NIs 1-3 are full: §4.4 all-or-nothing gate.
        assert!(!u.can_stream());
        for ni in 1..4 {
            u.refund(ni, 1);
        }
        assert_eq!(u.step(), 1);
    }

    #[test]
    fn one_way_carries_weights_on_the_row_bus() {
        let cfg = SimConfig::table1_8x8(2);
        let layer = ConvLayer { name: "t", c: 3, h_in: 8, r: 3, stride: 1, pad: 1, q: 8 };
        let m = OsMapping::new(&cfg, &layer);
        let two = per_round_bus_stats(&cfg, Streaming::TwoWay, &m);
        let one = per_round_bus_stats(&cfg, Streaming::OneWay, &m);
        assert!(two.col_words > 0);
        assert_eq!(one.col_words, 0);
        assert!(one.row_words > two.row_words);
        assert_eq!(one.active_cycles, 2 * two.active_cycles);
    }

    #[test]
    fn ws_keeps_column_buses_dark_in_steady_state() {
        let cfg = SimConfig::table1_8x8(4);
        let layer = ConvLayer { name: "t", c: 3, h_in: 8, r: 3, stride: 1, pad: 1, q: 8 };
        let ws = WsMapping::new(&cfg, &layer);
        let two = per_round_bus_stats(&cfg, Streaming::TwoWay, &ws);
        assert_eq!(two.col_words, 0, "pinned weights stream nothing per round");
        assert_eq!(two.row_words, cfg.mesh_rows as u64 * layer.macs_per_output());
        // The broadcast patch costs the same on the shared one-way bus.
        let one = per_round_bus_stats(&cfg, Streaming::OneWay, &ws);
        assert_eq!(one.row_words, two.row_words);
        assert_eq!(one.active_cycles, two.active_cycles);
    }
}
