//! Tiny wall-clock bench harness (offline build: no `criterion`).
//!
//! Every `benches/*.rs` target is a `harness = false` binary that uses
//! [`time_it`] for simulator hot-path timing and prints the paper-figure
//! series alongside. Reported numbers: median, mean, min over `reps`.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median_ns: u128,
    pub mean_ns: u128,
    pub min_ns: u128,
    pub reps: usize,
}

impl Timing {
    pub fn per_iter(&self, iters_per_rep: u64) -> f64 {
        self.median_ns as f64 / iters_per_rep as f64
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {} mean {} min {} ({} reps)",
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            self.reps
        )
    }
}

pub fn fmt_ns(ns: u128) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Time `f` `reps` times (after one untimed warmup) and summarize.
/// The closure's return value is black-boxed to keep the work alive.
pub fn time_it<T>(reps: usize, mut f: impl FnMut() -> T) -> Timing {
    assert!(reps >= 1);
    std::hint::black_box(f()); // warmup
    let mut samples: Vec<u128> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos());
    }
    samples.sort_unstable();
    Timing {
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<u128>() / samples.len() as u128,
        min_ns: samples[0],
        reps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders_hold() {
        let t = time_it(5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(t.min_ns <= t.median_ns);
        assert_eq!(t.reps, 5);
    }

    #[test]
    fn formats_scale() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.500µs");
        assert_eq!(fmt_ns(2_000_000), "2.000ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.000s");
    }
}
