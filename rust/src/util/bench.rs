//! Tiny wall-clock bench harness (offline build: no `criterion`).
//!
//! Every `benches/*.rs` target is a `harness = false` binary that uses
//! [`time_it`] for simulator hot-path timing and prints the paper-figure
//! series alongside. Reported numbers: median, mean, min over `reps`.
//!
//! Benches additionally emit a machine-readable [`BenchReport`]
//! (`BENCH_<name>.json`) when invoked with `--json <path>` — the perf
//! trajectory CI tracks (uploaded as an artifact, gated against the
//! committed baseline by `scripts/check_bench_regression.py`). The
//! shared `--quick` flag selects the reduced CI matrix.

use std::time::Instant;

use super::json::Json;

#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median_ns: u128,
    pub mean_ns: u128,
    pub min_ns: u128,
    pub reps: usize,
}

impl Timing {
    pub fn per_iter(&self, iters_per_rep: u64) -> f64 {
        self.median_ns as f64 / iters_per_rep as f64
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {} mean {} min {} ({} reps)",
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            self.reps
        )
    }
}

pub fn fmt_ns(ns: u128) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Time `f` `reps` times (after one untimed warmup) and summarize.
/// The closure's return value is black-boxed to keep the work alive.
pub fn time_it<T>(reps: usize, mut f: impl FnMut() -> T) -> Timing {
    assert!(reps >= 1);
    std::hint::black_box(f()); // warmup
    let mut samples: Vec<u128> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos());
    }
    samples.sort_unstable();
    Timing {
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<u128>() / samples.len() as u128,
        min_ns: samples[0],
        reps,
    }
}

/// Flags shared by the bench binaries (`harness = false` mains):
/// `--quick` shrinks the matrix/reps for the CI smoke run, `--json PATH`
/// writes the [`BenchReport`] beside the human-readable stdout series.
#[derive(Debug, Default, Clone)]
pub struct BenchArgs {
    pub quick: bool,
    pub json: Option<String>,
}

/// Parse [`BenchArgs`] from the process arguments. Unknown flags panic
/// loudly — a typo silently running the full matrix in CI would be worse.
pub fn bench_args() -> BenchArgs {
    let mut out = BenchArgs::default();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--quick" => out.quick = true,
            "--json" => {
                out.json = Some(args.next().expect("--json requires a path"));
            }
            // Cargo unconditionally appends `--bench` when invoking a
            // bench target (even with `harness = false`); accept and
            // ignore it so plain `cargo bench` keeps working.
            "--bench" => {}
            other => panic!("unknown bench flag '{other}' (--quick | --json PATH)"),
        }
    }
    out
}

/// Machine-readable bench results: a flat list of measurement points,
/// each a JSON object of tags (`name`, `kernel`, …) and numeric metrics
/// (`cycles_per_sec`, `median_ns`, …). `measured` is always true for a
/// report produced by an actual run — the committed bootstrap baseline
/// carries `measured: false` until CI numbers are committed.
#[derive(Debug)]
pub struct BenchReport {
    name: String,
    quick: bool,
    points: Vec<Json>,
}

impl BenchReport {
    pub fn new(name: &str, quick: bool) -> BenchReport {
        BenchReport { name: name.to_string(), quick, points: Vec::new() }
    }

    /// Record one measurement point.
    pub fn add(&mut self, point: Json) {
        self.points.push(point);
    }

    /// Build a point from string tags and numeric metrics.
    pub fn point(tags: &[(&str, &str)], metrics: &[(&str, f64)]) -> Json {
        let mut j = Json::obj();
        for (k, v) in tags {
            j.set(k, Json::Str((*v).to_string()));
        }
        for (k, v) in metrics {
            j.set(k, Json::Num(*v));
        }
        j
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("bench", Json::Str(self.name.clone()))
            .set("measured", Json::Bool(true))
            .set("quick", Json::Bool(self.quick))
            .set("points", Json::Arr(self.points.clone()));
        j
    }

    /// Write the report; prints the destination so CI logs show where the
    /// artifact came from.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())?;
        println!("\nwrote {} point(s) to {path}", self.points.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders_hold() {
        let t = time_it(5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(t.min_ns <= t.median_ns);
        assert_eq!(t.reps, 5);
    }

    #[test]
    fn formats_scale() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.500µs");
        assert_eq!(fmt_ns(2_000_000), "2.000ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.000s");
    }

    #[test]
    fn bench_report_roundtrips_through_json() {
        let mut r = BenchReport::new("sim_hotpath", true);
        r.add(BenchReport::point(
            &[("name", "saturate"), ("kernel", "event")],
            &[("cycles_per_sec", 1.5e6), ("mesh", 16.0)],
        ));
        let parsed = crate::util::json::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("sim_hotpath"));
        assert_eq!(parsed.get("measured").and_then(Json::as_bool), Some(true));
        assert_eq!(parsed.get("quick").and_then(Json::as_bool), Some(true));
        let pts = parsed.get("points").and_then(Json::as_arr).unwrap();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].get("kernel").and_then(Json::as_str), Some("event"));
        assert_eq!(pts[0].get("cycles_per_sec").and_then(Json::as_f64), Some(1.5e6));
    }
}
