//! Minimal command-line flag parsing (offline build: no `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments and subcommands. Unknown flags are an error, so typos fail
//! loudly.
//!
//! The binary's flag vocabulary lives in `main.rs` (`VALUED` / `BOOLEAN`);
//! notable simulator selectors parsed through this module:
//!
//! * `--dataflow <os|ws>` — dataflow mapping for `run`/`config`/`compare`:
//!   `os` (Output-Stationary, the paper's default) or `ws`
//!   (Weight-Stationary; see [`crate::dataflow::ws`]). Long spellings
//!   `output-stationary` / `weight-stationary` are accepted by
//!   [`crate::config::DataflowKind::parse`].
//! * `--streaming <mesh|one-way|two-way>` and
//!   `--collection <ru|gather|ina>` — the architecture axes of the
//!   evaluation: the paper's repetitive-unicast baseline and gather
//!   packets, plus in-network accumulation (psums added at intermediate
//!   routers, arXiv:2209.10056; parsed by
//!   [`crate::config::Collection::parse`]).
//! * `--topology <mesh|torus|cmesh>` — the router fabric
//!   ([`crate::config::TopologyKind::parse`]); `main.rs` folds it through
//!   the [`crate::api::ScenarioBuilder`], so `cmesh` concentrates the
//!   `--mesh` PE array onto a half-radix router grid.
//!
//! Unknown spellings for any of these are typed
//! [`crate::config::ConfigError`]s: the binary prints them and exits
//! nonzero instead of unwinding.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]` given the set of flags that take a value.
    pub fn parse(
        argv: impl IntoIterator<Item = String>,
        valued: &[&str],
        boolean: &[&str],
    ) -> anyhow::Result<Args> {
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if boolean.contains(&name) {
                    anyhow::ensure!(inline.is_none(), "flag --{name} takes no value");
                    flags.insert(name.to_string(), "true".to_string());
                } else if valued.contains(&name) {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?,
                    };
                    flags.insert(name.to_string(), v);
                } else {
                    anyhow::bail!("unknown flag --{name}");
                }
            } else {
                positionals.push(arg);
            }
        }
        Ok(Args { flags, positionals })
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("invalid value for --{name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_valued_and_bool_flags() {
        let a = Args::parse(
            argv(&["run", "--mesh", "8", "--verbose", "--n=4"]),
            &["mesh", "n"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional(0), Some("run"));
        assert_eq!(a.get("mesh"), Some("8"));
        assert_eq!(a.get_parsed::<usize>("n", 1).unwrap(), 4);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn unknown_flag_fails() {
        assert!(Args::parse(argv(&["--wat"]), &[], &[]).is_err());
    }

    #[test]
    fn missing_value_fails() {
        assert!(Args::parse(argv(&["--mesh"]), &["mesh"], &[]).is_err());
    }

    #[test]
    fn default_applies_when_absent() {
        let a = Args::parse(argv(&[]), &["k"], &[]).unwrap();
        assert_eq!(a.get_parsed::<u64>("k", 9).unwrap(), 9);
    }

    #[test]
    fn dataflow_flag_round_trips_to_the_config_parser() {
        use crate::config::DataflowKind;
        let a = Args::parse(argv(&["run", "--dataflow", "ws"]), &["dataflow"], &[]).unwrap();
        let kind = DataflowKind::parse(a.get("dataflow").unwrap()).unwrap();
        assert_eq!(kind, DataflowKind::WeightStationary);
    }

    #[test]
    fn collection_flag_round_trips_to_the_config_parser() {
        use crate::config::Collection;
        for (spelling, want) in [
            ("ru", Collection::RepetitiveUnicast),
            ("gather", Collection::Gather),
            ("ina", Collection::Ina),
        ] {
            let a =
                Args::parse(argv(&["run", "--collection", spelling]), &["collection"], &[])
                    .unwrap();
            assert_eq!(Collection::parse(a.get("collection").unwrap()).unwrap(), want);
        }
    }
}
