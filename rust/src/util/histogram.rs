//! Deterministic fixed-bucket histogram for latency percentiles.
//!
//! The serving metrics layer reports p50/p99/p999 over hundreds of
//! thousands of per-request latencies. Sorting raw samples would be
//! exact but O(n log n) per report and memory-heavy; a quantile sketch
//! (t-digest and friends) would be compact but floating-point-ordering
//! dependent — two runs that interleave samples differently could
//! report different tails, which the serving determinism suite forbids.
//! A fixed-bucket integer histogram is both: exact counts per bucket,
//! order-insensitive by construction (addition of u64 counts commutes),
//! and O(buckets) per percentile query.
//!
//! ## Percentile convention
//!
//! Bucket `i` covers values `[i*w, (i+1)*w)` for width `w`; values at or
//! beyond the last bucket land in a single overflow bucket. The
//! `q`-quantile is defined by the **nearest-rank rule**: rank
//! `ceil(q * count)` (clamped to `[1, count]`), and the reported value is
//! the inclusive upper edge `(i+1)*w - 1` of the bucket holding that
//! rank, clamped to the true observed maximum (so a constant
//! distribution reports the constant, and `q = 1` reports the max).
//! With `w = 1` the rule is exact. Overflowed ranks report the observed
//! maximum. Every step is integer arithmetic over counts — no
//! floating-point accumulation order can change the answer.

use super::json::Json;

/// Fixed-bucket histogram over `u64` samples (see module docs for the
/// bucket and percentile conventions).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    bucket_width: u64,
    counts: Vec<u64>,
    /// Samples at or beyond `buckets * width`.
    overflow: u64,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Histogram {
    /// A histogram of `buckets` buckets of `bucket_width` values each.
    /// Zero widths or bucket counts have no meaningful geometry and are
    /// rejected loudly (a caller bug, not a data condition).
    pub fn new(bucket_width: u64, buckets: usize) -> Histogram {
        assert!(bucket_width > 0, "histogram bucket width must be positive");
        assert!(buckets > 0, "histogram needs at least one bucket");
        Histogram {
            bucket_width,
            counts: vec![0; buckets],
            overflow: 0,
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    pub fn bucket_width(&self) -> u64 {
        self.bucket_width
    }

    /// Record one sample.
    pub fn record(&mut self, value: u64) {
        let idx = (value / self.bucket_width) as usize;
        if idx < self.counts.len() {
            self.counts[idx] += 1;
        } else {
            self.overflow += 1;
        }
        self.total += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (the sum is kept as u128, so it never saturates on
    /// cycle-scale samples).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Nearest-rank percentile for `q` in `[0, 1]` — see the module docs
    /// for the exact deterministic rule. Returns 0 on an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        // rank = ceil(q * total), clamped to [1, total]; integer walk
        // from there on.
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cumulative = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cumulative += c;
            if cumulative >= rank {
                let upper = (i as u64 + 1) * self.bucket_width - 1;
                return upper.min(self.max);
            }
        }
        // The rank falls into the overflow bucket: the best deterministic
        // answer under fixed buckets is the observed maximum.
        self.max
    }

    /// Fold another histogram of identical geometry into this one
    /// (commutative — merge order cannot change any percentile).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            (self.bucket_width, self.counts.len()),
            (other.bucket_width, other.counts.len()),
            "histogram merge requires identical geometry"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The summary the serving report embeds: counts, extrema, mean and
    /// the p50/p99/p999 tail.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("bucket_width", Json::Num(self.bucket_width as f64))
            .set("count", Json::Num(self.total as f64))
            .set("overflow", Json::Num(self.overflow as f64))
            .set("min", Json::Num(self.min() as f64))
            .set("max", Json::Num(self.max() as f64))
            .set("mean", Json::Num(self.mean()))
            .set("p50", Json::Num(self.percentile(0.50) as f64))
            .set("p99", Json::Num(self.percentile(0.99) as f64))
            .set("p999", Json::Num(self.percentile(0.999) as f64));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pins_percentiles_on_a_known_uniform_distribution() {
        // 0..=999 with unit buckets: the rule is exact. Nearest rank for
        // q over 1000 samples is ceil(1000q), so p50 is the 500th
        // smallest (= 499), p99 the 990th (= 989), p999 the 999th (= 998).
        let mut h = Histogram::new(1, 1024);
        for v in 0..1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!((h.min(), h.max()), (0, 999));
        assert_eq!(h.percentile(0.50), 499);
        assert_eq!(h.percentile(0.99), 989);
        assert_eq!(h.percentile(0.999), 998);
        assert_eq!(h.percentile(1.0), 999);
        assert!((h.mean() - 499.5).abs() < 1e-9);
    }

    #[test]
    fn pins_percentiles_on_a_skewed_distribution() {
        // 990 fast samples at 10, 9 at 500, 1 at 9000: the classic
        // tail-latency shape. p50 sits in the body, p99 at the knee of
        // the slow band, p999 on the outlier.
        let mut h = Histogram::new(10, 128);
        for _ in 0..990 {
            h.record(10);
        }
        for _ in 0..9 {
            h.record(500);
        }
        h.record(9000);
        assert_eq!(h.count(), 1000);
        // Bucket [10,20) upper edge 19 — within one bucket width of the
        // true 10.
        assert_eq!(h.percentile(0.50), 19);
        assert_eq!(h.percentile(0.99), 509);
        // Rank 999 is the 9th slow sample (cumulative 999 at bucket 50).
        assert_eq!(h.percentile(0.999), 509);
        // 9000 lands beyond 128 buckets x 10 — overflow reports the max.
        assert_eq!(h.percentile(1.0), 9000);
    }

    #[test]
    fn constant_distribution_reports_the_constant() {
        let mut h = Histogram::new(64, 32);
        for _ in 0..17 {
            h.record(100);
        }
        // The bucket's upper edge (127) clamps to the observed max.
        for q in [0.5, 0.99, 0.999, 1.0] {
            assert_eq!(h.percentile(q), 100, "q={q}");
        }
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::new(8, 8);
        assert!(h.is_empty());
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!((h.min(), h.max()), (0, 0));
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_is_order_insensitive() {
        let mut a = Histogram::new(5, 64);
        let mut b = Histogram::new(5, 64);
        let mut ab = Histogram::new(5, 64);
        for v in [3u64, 77, 12, 300, 4, 4] {
            a.record(v);
            ab.record(v);
        }
        for v in [250u64, 1, 90] {
            b.record(v);
            ab.record(v);
        }
        let mut ba = b.clone();
        ba.merge(&a);
        a.merge(&b);
        assert_eq!(a, ba);
        assert_eq!(a, ab);
    }

    #[test]
    fn json_carries_the_tail() {
        let mut h = Histogram::new(1, 256);
        for v in 0..100u64 {
            h.record(v);
        }
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(100));
        assert_eq!(j.get("p50").and_then(Json::as_u64), Some(49));
        assert_eq!(j.get("p99").and_then(Json::as_u64), Some(98));
        assert_eq!(j.get("max").and_then(Json::as_u64), Some(99));
    }
}
