//! A minimal JSON reader/writer.
//!
//! The build environment is fully offline (no `serde`/`serde_json`), so the
//! crate carries this small, well-tested JSON implementation for config
//! round-trips and machine-readable experiment reports. It supports the
//! full JSON value grammar except exotic number forms; that is all the
//! project needs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)] // not worth a Display impl: JSON has two renderings
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad0 = "  ".repeat(indent);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad0);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad0);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.i == p.b.len(), "trailing characters at offset {}", p.i);
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(self.peek()? == c, "expected '{}' at offset {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "invalid literal at offset {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => anyhow::bail!("unexpected character '{}' at offset {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => anyhow::bail!("invalid escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    anyhow::ensure!(start + len <= self.b.len(), "truncated UTF-8");
                    s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("a", Json::Num(1.5))
            .set("b", Json::Str("x\"y".into()))
            .set("c", Json::Arr(vec![Json::Bool(true), Json::Null]));
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"k": {"inner": [1, 2, 3]}, "f": -2.5e3}"#).unwrap();
        assert_eq!(v.get("f").unwrap().as_f64(), Some(-2500.0));
        let inner = v.get("k").unwrap().get("inner").unwrap().as_arr().unwrap();
        assert_eq!(inner.len(), 3);
        assert_eq!(inner[2].as_u64(), Some(3));
    }

    #[test]
    fn integer_formatting_has_no_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("tab\tnl\nuni\u{2603}".into());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = parse(r#"{"a":[1,{"b":2}],"c":"s"}"#).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }
}
