//! In-tree utilities replacing unavailable third-party crates (the build
//! environment is offline): JSON ([`json`]), deterministic RNG and
//! property-check driver ([`rng`]), a wall-clock bench harness ([`bench`])
//! and CLI flag parsing ([`cli`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
