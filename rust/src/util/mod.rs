//! In-tree utilities replacing unavailable third-party crates (the build
//! environment is offline): JSON ([`json`]), deterministic RNG and
//! property-check driver ([`rng`]), a wall-clock bench harness ([`bench`]),
//! CLI flag parsing ([`cli`]) and the deterministic fixed-bucket
//! percentile histogram ([`histogram`]) the serving metrics layer reports
//! tail latencies through.

pub mod bench;
pub mod cli;
pub mod histogram;
pub mod json;
pub mod rng;
