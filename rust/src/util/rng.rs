//! Deterministic pseudo-random generation (SplitMix64) for workload
//! generation and in-tree property tests. Offline build: no `rand` crate.

/// SplitMix64 — tiny, fast, well-distributed; perfectly adequate for
/// test-case generation and traffic jitter.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }

    /// Uniform f64 in [0, 1).
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Run `f` against `iters` generated cases; on failure, panics with the
/// case number and seed so the case can be replayed. This is the crate's
/// lightweight substitute for `proptest` in the offline environment.
pub fn check_cases(seed: u64, iters: u64, mut f: impl FnMut(&mut Rng, u64)) {
    for case in 0..iters {
        let mut rng = Rng::new(seed ^ case.wrapping_mul(0x9e3779b97f4a7c15));
        f(&mut rng, case);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_hits_both_ends() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.range(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                v => assert!((5..=8).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn unit_is_uniform_enough() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.unit()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
