#!/usr/bin/env python3
"""Gate the simulator hot-path throughput against the committed baseline.

Usage:
    check_bench_regression.py [--allow-bootstrap] BASELINE.json FRESH.json
    check_bench_regression.py --promote BASELINE.json FRESH.json

* FRESH is the report a CI run just produced (``cargo bench --bench
  sim_hotpath -- --quick --json ...``).
* BASELINE is the committed ``BENCH_sim_hotpath.json``. While it carries
  ``"measured": false`` (bootstrap: the authoring environment had no Rust
  toolchain) the gate FAILS LOUDLY — a disarmed gate must never read as a
  passing one. ``--allow-bootstrap`` downgrades that failure to a note;
  the workflow passes it only on push-to-main runs, where the follow-up
  arm job promotes the fresh report and closes the bootstrap window.

``--promote`` arms the gate: if (and only if) the committed baseline is
still the bootstrap placeholder and FRESH carries ``"measured": true``
with event-kernel points, FRESH is copied over BASELINE and the script
exits 0 so the calling workflow can commit it. A *benign* refusal — the
baseline is already measured, or FRESH is not a promotable report —
exits 2 so the workflow can skip the commit; any other exit status
(missing file, malformed JSON) is an unexpected error the workflow must
fail on rather than silently never arming the gate.

If FRESH does not exist at the given path, both modes fall back to a
recursive glob for its basename — ``download-artifact`` has changed its
extraction layout (flat vs. per-artifact subdirectory) across major
versions, and a layout change must not read as "nothing to promote".

Fails (exit 1) when any event-kernel point's cycles/sec drops more than
REGRESSION_TOLERANCE below the baseline's matching point. Points are
matched on (name, kernel, collection, mesh, n); points present on only
one side are reported but never fail the gate (the matrix may grow).
"""

import glob
import json
import os
import shutil
import sys

REGRESSION_TOLERANCE = 0.20  # fail below 80% of baseline cycles/sec
EXIT_SKIP = 2  # benign --promote refusal: nothing to do, not an error


def key(p):
    return (
        p.get("name"),
        p.get("kernel"),
        p.get("collection"),
        p.get("mesh"),
        p.get("n"),
    )


def resolve(path):
    """Find the report file, tolerating artifact-extraction subdirectories."""
    if os.path.exists(path):
        return path
    hits = sorted(glob.glob(f"**/{os.path.basename(path)}", recursive=True))
    if len(hits) == 1:
        print(f"note: {path} not at the expected location, using {hits[0]}")
        return hits[0]
    if hits:
        sys.exit(f"ambiguous report location for {path}: {hits}")
    sys.exit(f"report {path} not found (and no {os.path.basename(path)} anywhere below .)")


def load(path):
    with open(resolve(path)) as f:
        return json.load(f)


def promote(baseline_path, fresh_path):
    """Replace a bootstrap baseline with the first measured report."""
    baseline, fresh = load(baseline_path), load(fresh_path)
    if baseline.get("measured", False):
        print(f"baseline {baseline_path} is already measured — nothing to promote")
        return EXIT_SKIP
    if not fresh.get("measured", False):
        print(f"fresh report {fresh_path} is not a measured run — refusing to promote")
        return EXIT_SKIP
    event_points = [
        p for p in fresh.get("points", [])
        if p.get("kernel") == "event" and "cycles_per_sec" in p
    ]
    if not event_points:
        print(f"fresh report {fresh_path} holds no event-kernel points — refusing to promote")
        return EXIT_SKIP
    shutil.copyfile(resolve(fresh_path), baseline_path)
    print(
        f"promoted {fresh_path} -> {baseline_path}: regression gate armed with "
        f"{len(event_points)} event-kernel point(s)"
    )
    return 0


def main():
    if sys.argv[1:2] == ["--promote"]:
        if len(sys.argv) != 4:
            sys.exit(__doc__)
        sys.exit(promote(sys.argv[2], sys.argv[3]))
    args = [a for a in sys.argv[1:] if a != "--allow-bootstrap"]
    allow_bootstrap = len(args) != len(sys.argv) - 1
    if len(args) != 2:
        sys.exit(__doc__)
    baseline, fresh = load(args[0]), load(args[1])

    fresh_points = {key(p): p for p in fresh.get("points", [])}
    speedups = [p for p in fresh.get("points", []) if p.get("name") == "speedup"]
    for p in speedups:
        print(
            f"event/reference speedup [{p.get('workload')} "
            f"{int(p.get('mesh', 0))}x{int(p.get('mesh', 0))} n={int(p.get('n', 0))} "
            f"{p.get('collection')}]: {p.get('event_over_reference', 0):.2f}x"
        )

    if not baseline.get("measured", False):
        msg = (
            f"baseline {args[0]} is a bootstrap placeholder "
            '("measured": false) — the regression gate is NOT armed.'
        )
        if allow_bootstrap:
            print(f"{msg} Tolerated (--allow-bootstrap): this is a push run "
                  "and the arm job will promote the fresh report.")
            return
        sys.exit(
            f"{msg} Failing loudly so a disarmed gate can never pass "
            "silently; the push-to-main arm job promotes the measured "
            "report (workflow passes --allow-bootstrap there)."
        )

    failures = []
    compared = 0
    for bp in baseline.get("points", []):
        if bp.get("kernel") != "event" or "cycles_per_sec" not in bp:
            continue
        fp = fresh_points.get(key(bp))
        if fp is None:
            print(f"note: baseline point {key(bp)} missing from fresh run")
            continue
        compared += 1
        old, new = bp["cycles_per_sec"], fp.get("cycles_per_sec", 0.0)
        ratio = new / old if old else float("inf")
        status = "OK" if ratio >= 1.0 - REGRESSION_TOLERANCE else "REGRESSED"
        print(f"{status}: {key(bp)} {old / 1e6:.2f}M -> {new / 1e6:.2f}M cyc/s ({ratio:.2f}x)")
        if status == "REGRESSED":
            failures.append(key(bp))

    if not compared:
        print("warning: measured baseline held no comparable event-kernel points")
    if failures:
        sys.exit(f"cycles/sec regressed >{REGRESSION_TOLERANCE:.0%} on {len(failures)} point(s): {failures}")
    print(f"gate passed: {compared} point(s) within tolerance")


if __name__ == "__main__":
    main()
