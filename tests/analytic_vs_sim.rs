//! Cross-validation of the closed-form latency models (Eqs. 3–4, §4.5)
//! against the cycle-accurate simulation in the uncongested regime, where
//! Δ_R = Δ_G = 0 and the two must agree.

use noc_dnn::analytic;
use noc_dnn::config::{Collection, DataflowKind, SimConfig, Streaming};
use noc_dnn::dataflow::run_layer;
use noc_dnn::models::{alexnet, ConvLayer};

fn quiet_layer() -> ConvLayer {
    // Large C·R·R => long compute period => the network is never
    // congested and the analytic zero-Δ forms should match simulation.
    ConvLayer { name: "quiet", c: 64, h_in: 16, r: 3, stride: 1, pad: 1, q: 32 }
}

fn rel_err(a: u64, b: u64) -> f64 {
    (a as f64 - b as f64).abs() / (b as f64)
}

#[test]
fn gather_simulation_matches_eq4_when_uncongested() {
    for n in [1usize, 4] {
        let cfg = SimConfig::table1_8x8(n);
        let layer = quiet_layer();
        let sim = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
        let model = analytic::latency_gather(&cfg, Streaming::TwoWay, &layer);
        let err = rel_err(sim.total_cycles, model);
        assert!(
            err < 0.05,
            "n={n}: sim {} vs Eq.(4) {model} ({:.1}% off)",
            sim.total_cycles,
            err * 100.0
        );
    }
}

#[test]
fn ru_simulation_matches_eq3_when_uncongested() {
    for n in [1usize, 4] {
        let cfg = SimConfig::table1_8x8(n);
        let layer = quiet_layer();
        let sim = run_layer(&cfg, Streaming::TwoWay, Collection::RepetitiveUnicast, &layer);
        let model = analytic::latency_ru(&cfg, Streaming::TwoWay, &layer);
        let err = rel_err(sim.total_cycles, model);
        assert!(
            err < 0.05,
            "n={n}: sim {} vs Eq.(3) {model} ({:.1}% off)",
            sim.total_cycles,
            err * 100.0
        );
    }
}

#[test]
fn ina_simulation_matches_the_generalized_closed_form_when_uncongested() {
    // INA's zero-load form: compute + M·(κ+link) + (L_ina − 1). Folds at
    // transit NIs add zero latency (they ride the RC slot exactly like
    // gather boarding), so the uncongested simulation must match within
    // the same tolerance as Eqs. (3)/(4).
    for n in [1usize, 4] {
        let cfg = SimConfig::table1_8x8(n);
        let layer = quiet_layer();
        let sim = run_layer(&cfg, Streaming::TwoWay, Collection::Ina, &layer);
        let model = analytic::latency_ina(&cfg, Streaming::TwoWay, &layer);
        let err = rel_err(sim.total_cycles, model);
        assert!(
            err < 0.05,
            "n={n}: INA sim {} vs closed form {model} ({:.1}% off)",
            sim.total_cycles,
            err * 100.0
        );
    }
}

#[test]
fn ws_ina_simulation_matches_the_generalized_closed_form() {
    // The WS mapping drives INA through the same generalized form (its
    // packet carries n/spread pre-accumulated words).
    for idx in [2usize, 3] {
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.dataflow = DataflowKind::WeightStationary;
        let layer = alexnet::conv_layers()[idx].clone();
        let sim = run_layer(&cfg, Streaming::TwoWay, Collection::Ina, &layer);
        let model = analytic::latency_ina(&cfg, Streaming::TwoWay, &layer);
        let err = rel_err(sim.total_cycles, model);
        assert!(
            err < 0.05,
            "{} WS/INA sim {} vs closed form {model} ({:.1}% off)",
            layer.name,
            sim.total_cycles,
            err * 100.0
        );
    }
}

#[test]
fn congestion_terms_are_nonnegative() {
    // Δ = sim − analytic must be ≥ (slightly below) 0: the closed forms
    // are zero-load lower bounds.
    let mut cfg = SimConfig::table1_8x8(8);
    cfg.trace_driven = true; // network-bound: Δ_R should be large
    let layer = ConvLayer { name: "hot", c: 4, h_in: 16, r: 3, stride: 1, pad: 1, q: 64 };
    let sim_ru = run_layer(&cfg, Streaming::TwoWay, Collection::RepetitiveUnicast, &layer);
    let sim_g = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
    // In the trace-driven regime the compute term is hidden, so compare
    // the two simulations directly: Δ_R > Δ_G manifests as RU slower.
    assert!(
        sim_ru.total_cycles > sim_g.total_cycles,
        "RU ({}) must exceed gather ({}) under congestion",
        sim_ru.total_cycles,
        sim_g.total_cycles
    );
}

#[test]
fn ws_simulation_matches_generalized_eq4_on_alexnet_layers() {
    // The WS instantiation of the generalized Eq. (4): broadcast-patch
    // stream period, wave setup cost, and a collection tail driven by
    // n/spread payloads per node. conv3 fits the register file
    // (spread = 1); conv4's 3456-word filters split across two PEs
    // (spread = 2, NI accumulation) — both must match simulation in the
    // uncongested regime.
    for idx in [2usize, 3] {
        for n in [1usize, 4] {
            let mut cfg = SimConfig::table1_8x8(n);
            cfg.dataflow = DataflowKind::WeightStationary;
            let layer = alexnet::conv_layers()[idx].clone();
            let sim = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
            let model = analytic::latency_gather(&cfg, Streaming::TwoWay, &layer);
            let err = rel_err(sim.total_cycles, model);
            assert!(
                err < 0.05,
                "{} n={n}: WS sim {} vs generalized Eq.(4) {model} ({:.1}% off)",
                layer.name,
                sim.total_cycles,
                err * 100.0
            );
        }
    }
}

#[test]
fn ws_ru_simulation_matches_generalized_eq3() {
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.dataflow = DataflowKind::WeightStationary;
    let layer = alexnet::conv_layers()[2].clone();
    let sim = run_layer(&cfg, Streaming::TwoWay, Collection::RepetitiveUnicast, &layer);
    let model = analytic::latency_ru(&cfg, Streaming::TwoWay, &layer);
    let err = rel_err(sim.total_cycles, model);
    assert!(err < 0.05, "WS/RU sim {} vs Eq.(3) {model}", sim.total_cycles);
}

#[test]
fn extrapolation_is_cap_insensitive() {
    // DESIGN.md: the round-extrapolated totals must be stable in the
    // simulated-prefix length (steady-state rounds are identical).
    let layer = quiet_layer();
    let mut totals = Vec::new();
    for cap in [4usize, 8, 16] {
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.sim_rounds_cap = cap;
        let r = run_layer(&cfg, Streaming::TwoWay, Collection::Gather, &layer);
        totals.push(r.total_cycles);
    }
    let spread = (*totals.iter().max().unwrap() - *totals.iter().min().unwrap()) as f64
        / *totals.iter().min().unwrap() as f64;
    assert!(spread < 0.02, "cap sensitivity too high: {totals:?}");
}
