//! The dataflow-trait refactor contract:
//!
//! 1. the OS mapping viewed through [`Dataflow`] is a faithful restatement
//!    of the concrete `OsMapping` (every trait method equals the field it
//!    abstracts);
//! 2. running a layer through the config-selected boxed trait object is
//!    cycle-identical to running it with the concrete mapping — across
//!    random configurations, streaming modes and collection schemes;
//! 3. the refactored driver still follows the pre-refactor OS round
//!    schedule exactly: in the uncongested bus regime the steady-state
//!    period is `C·R·R·n/f_l + T_MAC`, the Eq. (3)/(4) compute period.

use noc_dnn::config::{Collection, DataflowKind, SimConfig, Streaming};
use noc_dnn::dataflow::{run_layer, run_layer_mapped, Dataflow, OsMapping, WsMapping};
use noc_dnn::models::{alexnet, ConvLayer};
use noc_dnn::util::rng::{check_cases, Rng};

fn random_layer(rng: &mut Rng) -> ConvLayer {
    ConvLayer {
        name: "prop",
        c: rng.range(1, 16) as usize,
        h_in: rng.range(6, 14) as usize,
        r: *rng.choose(&[1usize, 3, 5]),
        stride: rng.range(1, 2) as usize,
        pad: rng.range(0, 2) as usize,
        q: rng.range(4, 48) as usize,
    }
}

#[test]
fn os_trait_view_restates_the_struct_fields() {
    for n in [1usize, 2, 4, 8] {
        let cfg = SimConfig::table1_8x8(n);
        for layer in alexnet::conv_layers() {
            let m = OsMapping::new(&cfg, &layer);
            let d: &dyn Dataflow = &m;
            assert_eq!(d.kind(), DataflowKind::OutputStationary);
            assert_eq!(d.rounds(), m.rounds);
            assert_eq!(d.macs_per_pe(), m.macs_per_pe);
            assert_eq!(d.stream_words().row, m.row_stream_words);
            assert_eq!(d.stream_words().col, m.col_stream_words);
            assert_eq!(d.psum_collection().payloads_per_node, m.payloads_per_node);
            assert!(!d.psum_collection().in_network_accumulation);
            assert_eq!(d.setup_cycles(&cfg, Streaming::TwoWay), 0, "OS has no setup phase");
            assert_eq!(d.traffic_per_round(&cfg).payloads, m.payloads_per_round(&cfg));
            assert_eq!(d.useful_outputs(&layer), m.useful_outputs(&layer));
        }
    }
}

#[test]
fn prop_os_via_trait_is_cycle_identical_to_concrete_mapping() {
    check_cases(0xD47AF10, 25, |rng, case| {
        let n = *rng.choose(&[1usize, 2, 4]);
        let mut cfg = SimConfig::table1_8x8(n);
        cfg.sim_rounds_cap = 4;
        cfg.trace_driven = rng.chance(0.3);
        let layer = random_layer(rng);
        let streaming = *rng.choose(&[Streaming::TwoWay, Streaming::OneWay, Streaming::Mesh]);
        let collection = if rng.chance(0.5) {
            Collection::Gather
        } else {
            Collection::RepetitiveUnicast
        };
        // Config-selected (boxed trait object) vs explicit concrete mapping.
        let via_cfg = run_layer(&cfg, streaming, collection, &layer);
        let concrete = OsMapping::new(&cfg, &layer);
        let via_concrete = run_layer_mapped(&cfg, streaming, collection, &layer, &concrete);
        assert_eq!(
            via_cfg.total_cycles, via_concrete.total_cycles,
            "case {case}: trait-object and concrete OS runs diverged"
        );
        assert_eq!(via_cfg.simulated_cycles, via_concrete.simulated_cycles, "case {case}");
        assert_eq!(via_cfg.steady_period, via_concrete.steady_period, "case {case}");
        assert_eq!(via_cfg.net, via_concrete.net, "case {case}: stats diverged");
        assert_eq!(via_cfg.bus, via_concrete.bus, "case {case}: bus stats diverged");
        assert_eq!(via_cfg.setup_cycles, 0, "case {case}: OS grew a setup phase");
    });
}

#[test]
fn prop_os_steady_period_matches_the_pre_refactor_schedule() {
    // The pre-refactor driver gated bus rounds at exactly
    // `bus_stream_cycles + T_MAC`. Compute-heavy layers are uncongested,
    // so the measured steady period must equal that closed form — cycle
    // for cycle — through the trait-driven driver too.
    check_cases(0x05C4ED, 15, |rng, case| {
        let n = *rng.choose(&[1usize, 2, 4]);
        let cfg = SimConfig::table1_8x8(n);
        let mut layer = random_layer(rng);
        layer.c = rng.range(48, 96) as usize; // long compute period
        layer.r = 3;
        layer.q = 64; // ≥ 8 filter rounds: guarantees ≥ 2 simulated rounds
        for streaming in [Streaming::TwoWay, Streaming::OneWay] {
            let mapping = OsMapping::new(&cfg, &layer);
            let expected = noc_dnn::pe::bus_stream_cycles(&cfg, streaming, mapping.macs_per_pe)
                + cfg.t_mac;
            let r = run_layer(&cfg, streaming, Collection::Gather, &layer);
            assert_eq!(
                r.steady_period, expected as f64,
                "case {case} ({streaming:?}): schedule drifted from Eq. (3)/(4) period"
            );
        }
    });
}

#[test]
fn ws_trait_object_runs_identically_to_concrete_ws() {
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.dataflow = DataflowKind::WeightStationary;
    let layer = ConvLayer { name: "t", c: 8, h_in: 12, r: 3, stride: 1, pad: 1, q: 32 };
    for streaming in [Streaming::TwoWay, Streaming::OneWay, Streaming::Mesh] {
        let via_cfg = run_layer(&cfg, streaming, Collection::Gather, &layer);
        let concrete = WsMapping::new(&cfg, &layer);
        let explicit = run_layer_mapped(&cfg, streaming, Collection::Gather, &layer, &concrete);
        assert_eq!(via_cfg.total_cycles, explicit.total_cycles);
        assert_eq!(via_cfg.net, explicit.net);
        assert_eq!(via_cfg.dataflow, "ws");
    }
}

#[test]
fn dataflows_disagree_only_where_they_should() {
    // Same layer, same fabric: OS and WS must both deliver every payload
    // they post, and their traffic shapes must differ in the documented
    // ways (WS broadcasts: row words independent of n; OS scales with n).
    let layer = &alexnet::conv_layers()[2];
    for n in [2usize, 8] {
        let cfg = SimConfig::table1_8x8(n);
        let os = OsMapping::new(&cfg, layer);
        let ws = WsMapping::new(&cfg, layer);
        assert_eq!(os.stream_words().row, n as u64 * layer.macs_per_output());
        assert_eq!(ws.stream_words().row, layer.macs_per_output());
        assert!(os.stream_words().col > 0);
        assert_eq!(ws.stream_words().col, 0);
        // Both cover the layer.
        assert!(os.rounds * os.payloads_per_round(&cfg) >= os.useful_outputs(layer));
        let ws_d: &dyn Dataflow = &ws;
        assert!(ws_d.rounds() * ws_d.traffic_per_round(&cfg).payloads >= ws_d.useful_outputs(layer));
    }
}
