//! Determinism regression: the simulator must be a pure function of
//! (`SimConfig`, collection scheme, posting schedule). Two runs with the
//! same RNG seed driving the same randomized workload must produce
//! bit-identical `NetStats` — this guards against nondeterministic state
//! (hash-map iteration, wall-clock coupling, or a future `util::rng` use
//! inside `Network::step`) silently entering the cycle-accurate core.

use noc_dnn::config::{Collection, SimConfig, Streaming};
use noc_dnn::coordinator::executor::NetworkExecutor;
use noc_dnn::dataflow::run_layer;
use noc_dnn::models::ConvLayer;
use noc_dnn::noc::network::Network;
use noc_dnn::noc::stats::NetStats;
use noc_dnn::noc::{Coord, ProbeReport};
use noc_dnn::plan::{LayerPolicy, NetworkPlan};
use noc_dnn::util::rng::Rng;

/// Intra-layer worker count from the `NOC_INTRA_WORKERS` CI matrix axis
/// (default 1 = sequential kernel): the whole determinism surface must
/// hold under the band-parallel kernel too.
fn intra_workers_from_env() -> usize {
    match std::env::var("NOC_INTRA_WORKERS") {
        Ok(s) => s.parse().expect("NOC_INTRA_WORKERS must be a worker count"),
        Err(_) => 1,
    }
}

/// Drive one randomized-but-seeded workload to completion, optionally
/// with the per-link probes on (the returned report is `None` iff
/// `probes` is false).
fn run_once(
    seed: u64,
    collection: Collection,
    probes: bool,
) -> (NetStats, u64, u64, Option<ProbeReport<'static>>) {
    run_once_with(seed, collection, probes, intra_workers_from_env())
}

/// [`run_once`] with an explicit intra-layer worker count.
fn run_once_with(
    seed: u64,
    collection: Collection,
    probes: bool,
    intra_workers: usize,
) -> (NetStats, u64, u64, Option<ProbeReport<'static>>) {
    let mut rng = Rng::new(seed);
    let n = *rng.choose(&[1usize, 2, 4, 8]);
    let mut cfg = SimConfig::table1_8x8(n);
    cfg.delta = rng.range(0, 2 * cfg.delta);
    cfg.probes = probes;
    cfg.intra_workers = intra_workers;
    let mut net = Network::new(&cfg, collection);
    let mut posted = 0u64;
    for round in 0..rng.range(2, 4) {
        for y in 0..cfg.mesh_rows {
            for x in 0..cfg.mesh_cols {
                if rng.chance(0.8) {
                    let p = rng.range(1, n as u64) as u32;
                    net.post_result(round * rng.range(10, 60), Coord::new(x as u16, y as u16), p);
                    posted += p as u64;
                }
            }
        }
    }
    let ok = net.run_until_idle(2_000_000);
    assert!(ok, "workload failed to drain");
    assert_eq!(net.payloads_delivered, posted);
    (net.stats.clone(), net.payloads_delivered, net.cycle, net.probe_report().map(|p| p.into_owned()))
}

#[test]
fn same_seed_same_collection_is_bit_identical() {
    for collection in
        [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
    {
        for seed in [42u64, 0xDECAF, 7_777_777] {
            let a = run_once(seed, collection, false);
            let b = run_once(seed, collection, false);
            assert_eq!(
                a, b,
                "{collection:?} seed {seed}: two identical runs diverged — \
                 nondeterminism in Network::step"
            );
        }
    }
}

#[test]
fn probes_do_not_perturb_the_simulation() {
    // `SimConfig::probes` is strictly observational: the probe-on run
    // must produce the same NetStats, delivery count and final cycle as
    // its probe-off twin, for every collection scheme. A probe that
    // influenced allocation, routing or timing diverges here.
    for collection in
        [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
    {
        for seed in [42u64, 0xDECAF] {
            let (stats_on, delivered_on, cycle_on, probes) =
                run_once(seed, collection, true);
            let (stats_off, delivered_off, cycle_off, none) =
                run_once(seed, collection, false);
            assert!(none.is_none(), "probe-off run carried probe state");
            assert_eq!(
                stats_on, stats_off,
                "{collection:?} seed {seed}: probes changed the statistics"
            );
            assert_eq!(delivered_on, delivered_off);
            assert_eq!(
                cycle_on, cycle_off,
                "{collection:?} seed {seed}: probes changed the timing"
            );
            let p = probes.expect("probe-on run must surface a report");
            assert_eq!(
                p.total_flits, stats_on.link_traversals,
                "{collection:?} seed {seed}: probe totals diverged"
            );
        }
    }
}

#[test]
fn probe_report_is_bit_identical_across_repeated_runs() {
    // The report itself — every per-link, per-VC and per-bucket counter —
    // is part of the simulator's deterministic output surface.
    for collection in
        [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
    {
        for seed in [7u64, 0xBAD_5EED] {
            let a = run_once(seed, collection, true);
            let b = run_once(seed, collection, true);
            assert_eq!(
                a.3, b.3,
                "{collection:?} seed {seed}: ProbeReport diverged between \
                 two identical runs"
            );
        }
    }
}

#[test]
fn intra_worker_count_is_invisible_in_every_observable() {
    // The band-parallel kernel is an implementation detail: for every
    // collection scheme, running the same seeded workload at workers
    // 2/4/8 must reproduce the workers=1 tuple bit for bit — NetStats,
    // delivered payloads, final cycle AND the full ProbeReport.
    for collection in
        [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
    {
        for seed in [42u64, 0xDECAF] {
            let base = run_once_with(seed, collection, true, 1);
            for workers in [2usize, 4, 8] {
                let par = run_once_with(seed, collection, true, workers);
                assert_eq!(
                    par, base,
                    "{collection:?} seed {seed}: intra_workers={workers} \
                     changed an observable vs the sequential kernel"
                );
            }
        }
    }
}

#[test]
fn network_executor_is_bit_identical_and_thread_invariant() {
    // Model scope: two runs of the same (model, plan, config) must agree
    // bit for bit at threads = 1, and the totals must not move with the
    // worker count — each layer simulation is a pure function, and the
    // leader/worker fan-out preserves layer order.
    let model = noc_dnn::models::Network::alexnet();
    let mut plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
    plan.policies[2].collection = Collection::Ina;
    let run_with = |threads: usize| {
        let mut cfg = SimConfig::table1_8x8(2);
        cfg.sim_rounds_cap = 2;
        cfg.threads = threads;
        // Probes on: the per-link reports are part of the surface that
        // must not move with the worker count.
        cfg.probes = true;
        NetworkExecutor::new(cfg).run(&model, &plan).unwrap()
    };
    let a = run_with(1);
    let b = run_with(1);
    assert_eq!(a.total_cycles, b.total_cycles, "executor diverged at threads=1");
    assert_eq!(a.total_energy_j, b.total_energy_j);
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.report.run.net, y.report.run.net, "layer {} stats diverged", x.index);
        assert_eq!(x.total_cycles, y.total_cycles);
        assert_eq!(
            x.report.run.probes, y.report.run.probes,
            "layer {} probe report diverged at threads=1",
            x.index
        );
        assert!(x.report.run.probes.is_some(), "probes on but layer {} lost it", x.index);
    }
    for threads in [2usize, 4] {
        let c = run_with(threads);
        assert_eq!(a.total_cycles, c.total_cycles, "totals moved at threads={threads}");
        assert_eq!(a.total_energy_j, c.total_energy_j);
        for (x, z) in a.layers.iter().zip(&c.layers) {
            assert_eq!(
                x.report.run.probes, z.report.run.probes,
                "layer {} probe report moved at threads={threads}",
                x.index
            );
        }
    }
}

#[test]
fn layer_driver_is_deterministic_end_to_end() {
    // The round driver (extrapolation included) on top of the network:
    // identical inputs ⇒ identical cycle counts and event counters.
    let layer = ConvLayer { name: "det", c: 8, h_in: 10, r: 3, stride: 1, pad: 1, q: 24 };
    for collection in
        [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
    {
        for streaming in [Streaming::TwoWay, Streaming::Mesh] {
            let cfg = SimConfig::table1_8x8(4);
            let a = run_layer(&cfg, streaming, collection, &layer);
            let b = run_layer(&cfg, streaming, collection, &layer);
            assert_eq!(a.total_cycles, b.total_cycles, "{collection:?}/{streaming:?}");
            assert_eq!(a.net, b.net, "{collection:?}/{streaming:?}: stats diverged");
            assert_eq!(a.steady_period, b.steady_period, "{collection:?}/{streaming:?}");
        }
    }
}
