//! Fault-injection suite: the robustness contract of `noc::faults`.
//!
//! Four pillars:
//! * **off means off** — with `SimConfig::faults` unset the simulator is
//!   bit-identical to the fault-free kernel for every collection scheme,
//!   every fabric and every intra-layer worker count (NetStats, final
//!   cycle, delivered/dropped counters AND the full ProbeReport);
//! * **conservation under fire** — a seeded fault storm (random permanent
//!   link faults + per-flit corruption) never loses a payload: at every
//!   sampled cycle boundary and after the drain,
//!   `posted == delivered + dropped + in flight`, and packet accounting
//!   closes (`injected == ejected + merged + dropped`);
//! * **determinism** — faulted runs are a pure function of (config, fault
//!   spec, posting schedule): repeated seeds and workers 1/2/4 produce
//!   identical NetStats and identical `DegradationReport`s;
//! * **typed failure outcomes** — a hand-wedged network trips the
//!   quiescence watchdog with a `RunOutcome::Stalled` report naming the
//!   credit-blocked link, and `SimConfig::max_cycles` trips
//!   `RunOutcome::CycleCapExceeded` instead of spinning.

use noc_dnn::config::{Collection, SimConfig, TopologyKind};
use noc_dnn::noc::stats::NetStats;
use noc_dnn::noc::{
    Coord, DegradationReport, FaultsConfig, Network, Port, ProbeReport, RunOutcome,
};
use noc_dnn::util::rng::Rng;

const COLLECTIONS: [Collection; 3] =
    [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina];

/// Everything a run can observe: stats, delivered, dropped, final cycle,
/// the per-link probe report and the degradation summary.
type Observed = (
    NetStats,
    u64,
    u64,
    u64,
    Option<ProbeReport<'static>>,
    Option<DegradationReport>,
);

/// Drive one seeded randomized workload to drain and return the full
/// observable surface. `faults` is an optional `FaultsConfig::parse` spec;
/// `mesh` picks the grid edge (8 or 16).
fn run_seeded(
    topology: TopologyKind,
    collection: Collection,
    faults: Option<&str>,
    mesh: usize,
    seed: u64,
    intra_workers: usize,
) -> Observed {
    let mut rng = Rng::new(seed);
    let mut cfg = SimConfig::table1(mesh, 4);
    cfg.topology = topology;
    cfg.probes = true;
    cfg.intra_workers = intra_workers;
    cfg.delta = rng.range(0, 2 * cfg.delta);
    if let Some(spec) = faults {
        cfg.faults = Some(FaultsConfig::parse(spec).expect("fault spec must parse"));
    }
    cfg.validate().unwrap();
    let mut net = Network::new(&cfg, collection);
    let mut posted = 0u64;
    for round in 0..3u64 {
        let at = round * rng.range(20, 90);
        for y in 0..cfg.mesh_rows {
            for x in 0..cfg.mesh_cols {
                if rng.chance(0.8) {
                    let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                    net.post_result(at, Coord::new(x as u16, y as u16), p);
                    posted += p as u64;
                }
            }
        }
    }
    let outcome = net.run_until_idle_outcome(8_000_000);
    assert!(
        outcome == RunOutcome::Satisfied,
        "{topology:?}/{collection:?} seed {seed} w{intra_workers}: drain failed ({})",
        outcome.describe()
    );
    assert_eq!(
        net.payloads_delivered + net.payloads_dropped,
        posted,
        "{topology:?}/{collection:?} seed {seed}: payload accounting open after drain"
    );
    (
        net.stats.clone(),
        net.payloads_delivered,
        net.payloads_dropped,
        net.cycle,
        net.probe_report().map(|p| p.into_owned()),
        net.degradation_report(),
    )
}

#[test]
fn faults_unset_is_bit_identical_across_fabrics_and_worker_counts() {
    // The subsystem must be invisible when off: `faults: None` runs carry
    // no degradation report, spend nothing on fault bookkeeping, and stay
    // bit-identical across repeated runs and across the band-parallel
    // worker matrix — per collection scheme, per fabric.
    for topology in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::CMesh] {
        for collection in COLLECTIONS {
            let base = run_seeded(topology, collection, None, 8, 41, 1);
            assert!(base.5.is_none(), "faults unset but a DegradationReport was issued");
            assert!(base.1 > 0, "{topology:?}/{collection:?}: nothing delivered");
            assert_eq!(base.2, 0, "{topology:?}/{collection:?}: fault-free run dropped payloads");
            assert_eq!(base.0.flits_corrupted, 0);
            assert_eq!(base.0.retransmissions, 0);
            assert_eq!(base.0.detour_hops, 0);
            let again = run_seeded(topology, collection, None, 8, 41, 1);
            assert_eq!(again, base, "{topology:?}/{collection:?}: repeat run diverged");
            for workers in [2usize, 4, 8] {
                let par = run_seeded(topology, collection, None, 8, 41, workers);
                assert_eq!(
                    par, base,
                    "{topology:?}/{collection:?}: intra_workers={workers} changed an \
                     observable with faults unset"
                );
            }
        }
    }
}

/// The 16×16 storm used by the conservation and determinism pillars:
/// random permanent link faults, per-flit corruption, a tight retry
/// budget — everything at once.
const STORM: &str = "seed=61455,rate=0.04,corrupt=0.02,retries=3,holdoff=6";

#[test]
fn fault_storm_conserves_payloads_and_packets() {
    // Extended conservation on a 16×16 mesh under the storm: mid-flight,
    // `posted == delivered + dropped + in flight` at every sampled cycle
    // boundary (retransmission slots and census exclusions included);
    // after the drain, nothing is resident and the packet ledger closes
    // with drops as a first-class column.
    for collection in COLLECTIONS {
        let mut rng = Rng::new(0x57011);
        let mut cfg = SimConfig::table1_16x16(4);
        cfg.probes = true;
        cfg.faults = Some(FaultsConfig::parse(STORM).unwrap());
        cfg.validate().unwrap();
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        for round in 0..3u64 {
            for y in 0..cfg.mesh_rows {
                for x in 0..cfg.mesh_cols {
                    if rng.chance(0.7) {
                        let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                        net.post_result(round * 60, Coord::new(x as u16, y as u16), p);
                        posted += p as u64;
                    }
                }
            }
        }
        // Sample the invariant while the storm is raging...
        net.run_until(
            |n| {
                assert_eq!(
                    posted,
                    n.payloads_delivered + n.payloads_dropped + n.payloads_in_flight(),
                    "{collection:?}: payload leak at cycle {} under faults",
                    n.cycle
                );
                false
            },
            rng.range(300, 3_000),
        );
        // ...and close the books after the drain.
        let outcome = net.run_until_idle_outcome(8_000_000);
        assert!(
            outcome == RunOutcome::Satisfied,
            "{collection:?}: storm run failed to drain ({})",
            outcome.describe()
        );
        assert_eq!(
            net.payloads_delivered + net.payloads_dropped,
            posted,
            "{collection:?}: payload ledger open after drain"
        );
        assert_eq!(net.payloads_in_flight(), 0, "{collection:?}: residue after drain");
        assert_eq!(net.total_buffered_flits(), 0, "{collection:?}: flits stuck");
        assert_eq!(
            net.stats.packets_injected,
            net.stats.packets_ejected + net.stats.ina_merges + net.stats.packets_dropped,
            "{collection:?}: packet ledger open (merges and drops must cover the gap)"
        );
        assert!(net.payloads_delivered > 0, "{collection:?}: storm delivered nothing");
        // The probe partition survives the storm: retransmission traffic
        // is its own plane, so link totals still equal the traversal count.
        let p = net.probe_report().expect("probes were on");
        assert_eq!(p.total_flits, net.stats.link_traversals, "{collection:?}: probe split broke");
        assert_eq!(
            p.total_retransmissions, net.stats.retransmissions,
            "{collection:?}: probe retransmission plane diverged from NetStats"
        );
        // The degradation report mirrors the stats it summarizes.
        let d = net.degradation_report().expect("faults on ⇒ report present");
        assert_eq!(d.flits_corrupted, net.stats.flits_corrupted);
        assert_eq!(d.retransmissions, net.stats.retransmissions);
        assert_eq!(d.retries_exhausted, net.stats.retries_exhausted);
        assert_eq!(d.packets_dropped, net.stats.packets_dropped);
        assert_eq!(d.payloads_dropped, net.payloads_dropped);
        assert!(
            !d.is_clean(),
            "{collection:?}: a 4% link-fault storm left no trace — injection inert?"
        );
    }
}

#[test]
fn faulted_runs_are_deterministic_and_worker_invariant() {
    // A faulted run is still a pure function of its inputs: repeated runs
    // agree bit for bit — including the DegradationReport — and the
    // band-parallel kernel at workers 2 and 4 reproduces the sequential
    // tuple exactly (the fault filter runs on the owner thread before the
    // band partition, so worker count must be invisible).
    for collection in COLLECTIONS {
        for seed in [42u64, 0xDECAF] {
            let base = run_seeded(TopologyKind::Mesh, collection, Some(STORM), 16, seed, 1);
            assert!(base.5.is_some(), "faults on but no DegradationReport");
            let again = run_seeded(TopologyKind::Mesh, collection, Some(STORM), 16, seed, 1);
            assert_eq!(
                again, base,
                "{collection:?} seed {seed}: two identical faulted runs diverged"
            );
            for workers in [2usize, 4] {
                let par =
                    run_seeded(TopologyKind::Mesh, collection, Some(STORM), 16, seed, workers);
                assert_eq!(
                    par, base,
                    "{collection:?} seed {seed}: intra_workers={workers} changed a \
                     faulted observable"
                );
            }
        }
    }
}

#[test]
fn dead_router_contributors_are_excluded_not_wedged() {
    // Graceful degradation: a hard-faulted router's contributors leave
    // the round census (counted, not silently lost), everyone else routes
    // around the hole, and the run drains to a typed clean completion.
    for collection in COLLECTIONS {
        let mut cfg = SimConfig::table1_8x8(4);
        cfg.faults = Some(FaultsConfig::parse("seed=3,routers=3:3").unwrap());
        cfg.validate().unwrap();
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        let mut posted_at_dead = 0u64;
        for round in 0..2u64 {
            for y in 0..8u16 {
                for x in 0..8u16 {
                    net.post_result(round * 50, Coord::new(x, y), 4);
                    posted += 4;
                    if (x, y) == (3, 3) {
                        posted_at_dead += 4;
                    }
                }
            }
        }
        let outcome = net.run_until_idle_outcome(8_000_000);
        assert!(
            outcome == RunOutcome::Satisfied,
            "{collection:?}: dead-router run wedged ({})",
            outcome.describe()
        );
        let d = net.degradation_report().expect("faults on ⇒ report present");
        assert!(
            d.missing_contributors >= 2,
            "{collection:?}: the dead router's two rounds were not excluded \
             from the census ({})",
            d.summary()
        );
        assert!(
            net.payloads_dropped >= posted_at_dead,
            "{collection:?}: census exclusion must account the dead router's payloads"
        );
        assert_eq!(
            net.payloads_delivered + net.payloads_dropped,
            posted,
            "{collection:?}: accounting open after degradation"
        );
        assert!(
            net.payloads_delivered > 0,
            "{collection:?}: healthy routers delivered nothing"
        );
    }
}

#[test]
fn corruption_is_retransmitted_within_budget_and_priced_by_probes() {
    // Corruption-only spec (no permanent faults): every corrupted flit is
    // held and replayed from its retransmission slot, the replays appear
    // in NetStats and in the probes' dedicated per-link plane, and with a
    // generous retry budget the workload still delivers everything it
    // does not explicitly drop.
    let (stats, delivered, dropped, _, probes, degraded) = run_seeded(
        TopologyKind::Mesh,
        Collection::Gather,
        Some("seed=9,corrupt=0.02,retries=6,holdoff=5"),
        8,
        7,
        1,
    );
    assert!(delivered > 0);
    assert!(stats.flits_corrupted > 0, "2% corruption left no corrupted flit");
    assert!(stats.retransmissions > 0, "corrupted flits were never replayed");
    assert!(
        stats.retransmissions <= stats.flits_corrupted,
        "more replays than corruption events"
    );
    // No permanent fault ⇒ no rerouting, no census exclusion.
    assert_eq!(stats.detour_hops, 0, "corruption-only spec must not reroute");
    let d = degraded.expect("faults on ⇒ report present");
    assert_eq!(d.missing_contributors, 0);
    assert_eq!(d.retransmissions, stats.retransmissions);
    let p = probes.expect("probes were on");
    assert_eq!(p.total_retransmissions, stats.retransmissions);
    assert_eq!(p.total_flits, stats.link_traversals);
    assert_eq!(dropped, d.payloads_dropped);
}

#[test]
fn watchdog_names_the_credit_blocked_link() {
    // Hand-built wedge: drain every credit router (4,3) holds toward its
    // east neighbor — modelling a downstream that stopped refunding —
    // then post a result whose XY path crosses that link. The head gets
    // VC allocation, switch allocation blocks forever, nothing is
    // scheduled: the watchdog must stop stepping and name the link
    // instead of spinning to the bound.
    let cfg = SimConfig::table1_8x8(1);
    cfg.validate().unwrap();
    let mut net = Network::new(&cfg, Collection::RepetitiveUnicast);
    net.drain_credits_for_test(Coord::new(4, 3), Port::East);
    net.post_result(0, Coord::new(2, 3), 1);
    let outcome = net.run_until_idle_outcome(2_000_000);
    match outcome {
        RunOutcome::Stalled(r) => {
            assert!(r.stuck_flits > 0, "stall report saw no stuck flits");
            assert!(
                r.blocking_links
                    .iter()
                    .any(|&(x, y, p, _)| (x, y, p) == (4, 3, Port::East)),
                "stall report failed to name the drained link: {}",
                r.describe()
            );
            assert!(
                r.cycle < 2_000_000,
                "watchdog fired only at the bound — it spun instead of detecting"
            );
        }
        other => panic!("expected RunOutcome::Stalled, got {}", other.describe()),
    }
    // The boolean wrapper folds the stall to a plain failure.
    let mut twin = Network::new(&cfg, Collection::RepetitiveUnicast);
    twin.drain_credits_for_test(Coord::new(4, 3), Port::East);
    twin.post_result(0, Coord::new(2, 3), 1);
    assert!(!twin.run_until_idle(2_000_000), "wrapper must report the wedge as failure");
}

#[test]
fn cycle_cap_trips_as_a_typed_outcome() {
    // `SimConfig::max_cycles` is the CI-hang guard: posts scheduled past
    // the cap leave the drain predicate unmet when the capped bound is
    // reached, and the kernel reports the cap — not a bare `false`, not
    // an exhausted caller bound.
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.max_cycles = 2_500;
    cfg.validate().unwrap();
    let mut net = Network::new(&cfg, Collection::Gather);
    for round in 0..10u64 {
        for x in 0..8u16 {
            net.post_result(round * 1_000, Coord::new(x, 0), 4);
        }
    }
    let outcome = net.run_until_idle_outcome(1_000_000);
    assert_eq!(
        outcome,
        RunOutcome::CycleCapExceeded { cap: 2_500 },
        "capped run must surface the cap (got {})",
        outcome.describe()
    );
}
