//! Golden-value regression pinning the paper's headline result: on an
//! AlexNet conv layer under the paper's trace-driven methodology (§5.1),
//! gather collection beats repetitive unicast on the two-way streaming
//! fabric in both runtime latency and network power — by a ratio inside a
//! tolerance band, so future refactors can neither quietly *lose* the
//! reproduction (ratio sinking to 1.0) nor quietly inflate it (ratio
//! blowing past what the paper reports).
//!
//! Bands: latency improvement in (1.0, 1.8], network-power improvement in
//! (1.0, 1.7] — the upper bounds sit just above the paper's Fig. 15
//! maxima for this configuration class.

use noc_dnn::config::SimConfig;
use noc_dnn::coordinator::{latency_improvement, power_improvement, Experiment};
use noc_dnn::models::alexnet;

#[test]
fn alexnet_gather_vs_ru_headline_stays_in_band() {
    // 8×8 mesh, 4 PEs/router, conv3: the configuration the packet-size
    // study (Fig. 13) and the AlexNet sweep (Fig. 15) share, in the
    // network-bound trace-driven regime where Δ_R vs Δ_G is visible.
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.trace_driven = true;
    let layer = &alexnet::conv_layers()[2];
    let ru = Experiment::baseline_ru(cfg.clone()).run_layer(layer);
    let gather = Experiment::proposed(cfg).run_layer(layer);

    let lat = latency_improvement(&ru, &gather);
    assert!(
        lat > 1.0,
        "gather must strictly improve runtime latency over RU (got {lat:.3}x) — \
         the paper's headline has regressed to parity"
    );
    assert!(
        lat <= 1.8,
        "latency improvement {lat:.3}x exceeds the paper's band — \
         RU is being simulated unfairly slow (or gather unfairly fast)"
    );

    let pow = power_improvement(&ru, &gather);
    assert!(
        pow > 1.0,
        "gather must strictly improve network power over RU (got {pow:.3}x)"
    );
    assert!(
        pow <= 1.7,
        "power improvement {pow:.3}x exceeds the paper's band"
    );

    // The mechanism behind the ratios, pinned alongside them: gather
    // consolidates the same payloads into far fewer packets and hops.
    // (No exact injected==ejected accounting here: the driver measures at
    // head-eject time, with the last packets' tails legitimately still in
    // flight; the property suite pins accounting after a full drain.)
    assert!(gather.run.net.packets_injected < ru.run.net.packets_injected);
    assert!(gather.run.net.flit_hops < ru.run.net.flit_hops);
}
