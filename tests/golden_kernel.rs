//! Golden kernel-equivalence suite: the event-driven simulation core
//! (`noc::network::Network`) must be **bit-identical** to the frozen
//! pre-refactor kernel (`noc::reference::ReferenceNetwork`) in every
//! observable — full `NetStats`, final cycle count, delivered payloads —
//! across the seed matrix of 3 collection schemes × 2 dataflows × 3
//! streaming fabrics on AlexNet conv3, plus the 16×16 two-packet regime
//! and a fast-forward-heavy sparse schedule.
//!
//! The golden values are not hardcoded constants: the reference kernel
//! *is* the recording — both kernels are driven through the identical
//! schedule (a compact replica of the round driver's bus/mesh loops) in
//! the same process, so every CI run re-records and re-checks the whole
//! matrix. A divergence in any counter fails with the offending matrix
//! point in the message.

use noc_dnn::config::{Collection, DataflowKind, SimConfig, Streaming, TopologyKind};
use noc_dnn::dataflow::build;
use noc_dnn::models::alexnet;
use noc_dnn::noc::network::Network;
use noc_dnn::noc::reference::{ReferenceNetwork, SimKernel};
use noc_dnn::noc::{Coord, NetStats, ProbeReport, StreamEdge};

const SIM_ROUNDS: u64 = 3;

/// Everything the equivalence assertions compare.
#[derive(Debug, PartialEq)]
struct Observed {
    stats: NetStats,
    cycle: u64,
    delivered: u64,
    stream_tails: u64,
}

fn observe<K: SimKernel>(net: &K) -> Observed {
    Observed {
        stats: net.stats().clone(),
        cycle: net.cycle(),
        delivered: net.payloads_delivered(),
        stream_tails: net.stream_tails_ejected(),
    }
}

fn post_round<K: SimKernel>(net: &mut K, cfg: &SimConfig, at: u64, payloads: u32) {
    for y in 0..cfg.mesh_rows {
        for x in 0..cfg.mesh_cols {
            net.post_result(at, Coord::new(x as u16, y as u16), payloads);
        }
    }
}

/// Compact replica of the round driver's bus-streaming schedule
/// (`dataflow::driver::run_bus_layer`): rounds gated by the closed-form
/// stream period, collection overlapping the next round's streaming.
fn drive_bus<K: SimKernel>(
    net: &mut K,
    cfg: &SimConfig,
    streaming: Streaming,
    layer: &noc_dnn::models::ConvLayer,
) {
    let mapping = build(cfg, layer);
    let period = (mapping.stream_cycles(cfg, streaming) + cfg.t_mac).max(1);
    let rounds = mapping.rounds().min(SIM_ROUNDS);
    let per_round = mapping.traffic_per_round(cfg).payloads;
    let ppn = mapping.psum_collection().payloads_per_node;
    let bound = (rounds + 2) * period
        + 40 * per_round * (cfg.mesh_cols as u64 + cfg.gather_packet_flits as u64)
        + 200_000;
    let mut ready = period;
    for r in 0..rounds {
        post_round(net, cfg, ready, ppn);
        let ok = net.run_until_delivered((r + 1) * per_round, bound);
        assert!(ok, "round {r} stalled ({streaming:?})");
        ready = (ready + period).max(net.cycle() + cfg.t_mac);
    }
    assert!(net.run_until_idle(bound), "drain stalled ({streaming:?})");
}

/// Compact replica of the mesh-streaming schedule
/// (`dataflow::driver::run_mesh_layer`): operand multicasts over the mesh
/// itself, next round's streams chasing this round's collection.
fn drive_mesh<K: SimKernel>(net: &mut K, cfg: &SimConfig, layer: &noc_dnn::models::ConvLayer) {
    let mapping = build(cfg, layer);
    let rounds = mapping.rounds().min(SIM_ROUNDS);
    let traffic = mapping.traffic_per_round(cfg);
    let per_round = traffic.payloads;
    let ppn = mapping.psum_collection().payloads_per_node;
    let words = mapping.stream_words();
    let row_streams = if words.row > 0 { cfg.mesh_rows as u64 } else { 0 };
    let col_streams = if words.col > 0 { cfg.mesh_cols as u64 } else { 0 };
    let streams_per_round = row_streams + col_streams;
    let bound = (rounds + 2) * (traffic.stream_flits * 8 + 100_000);

    let post_streams = |net: &mut K, at: u64| {
        if words.row > 0 {
            for y in 0..cfg.mesh_rows {
                net.post_operand_stream(at, StreamEdge::Row(y), words.row);
            }
        }
        if words.col > 0 {
            for x in 0..cfg.mesh_cols {
                net.post_operand_stream(at, StreamEdge::Col(x), words.col);
            }
        }
    };
    post_streams(net, 0);
    for r in 0..rounds {
        let ok = net.run_until_stream_tails((r + 1) * streams_per_round, bound);
        assert!(ok, "round {r}: operand streams stalled");
        let stream_end = net.cycle();
        if r + 1 < rounds {
            post_streams(net, stream_end);
        }
        post_round(net, cfg, stream_end + cfg.t_mac, ppn);
        let ok = net.run_until_delivered((r + 1) * per_round, bound);
        assert!(ok, "round {r}: collection stalled");
    }
    assert!(net.run_until_idle(bound), "mesh drain stalled");
}

fn assert_equivalent(cfg: &SimConfig, streaming: Streaming, collection: Collection, tag: &str) {
    // The reference kernel is frozen mesh-only; golden equivalence is
    // asserted on Mesh2D (the other fabrics are covered by
    // tests/topology_laws.rs conservation and law suites).
    assert_eq!(cfg.topology, noc_dnn::config::TopologyKind::Mesh);
    let layer = &alexnet::conv_layers()[2];
    let mut event = Network::new(cfg, collection);
    let mut reference = ReferenceNetwork::new(cfg, collection);
    match streaming {
        Streaming::Mesh => {
            drive_mesh(&mut event, cfg, layer);
            drive_mesh(&mut reference, cfg, layer);
        }
        _ => {
            drive_bus(&mut event, cfg, streaming, layer);
            drive_bus(&mut reference, cfg, streaming, layer);
        }
    }
    let (a, b) = (observe(&event), observe(&reference));
    assert_eq!(
        a, b,
        "{tag}: event-driven kernel diverged from the reference kernel \
         ({streaming:?}/{collection:?}/{:?})",
        cfg.dataflow
    );
    // Both kernels must end fully drained — conservation, not just parity.
    assert_eq!(event.buffered_flits(), 0, "{tag}: event kernel left flits buffered");
    assert_eq!(reference.buffered_flits(), 0, "{tag}: reference kernel left flits buffered");
    assert_eq!(event.payloads_in_flight(), 0, "{tag}: event kernel owes payloads");
    assert_eq!(reference.payloads_in_flight(), 0, "{tag}: reference kernel owes payloads");
    assert!(a.delivered > 0, "{tag}: workload delivered nothing");
    println!(
        "{tag}: OK — cycle {} hops {} packets {}",
        a.cycle, a.stats.flit_hops, a.stats.packets_injected
    );
}

#[test]
fn event_kernel_matches_reference_across_the_seed_matrix() {
    // The full 3 collections × 2 dataflows × 3 fabrics grid on 8×8 n=2
    // (AlexNet conv3 — the layer the golden headline test also pins).
    for dataflow in [DataflowKind::OutputStationary, DataflowKind::WeightStationary] {
        for streaming in [Streaming::TwoWay, Streaming::OneWay, Streaming::Mesh] {
            for collection in
                [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
            {
                let mut cfg = SimConfig::table1_8x8(2);
                cfg.dataflow = dataflow;
                let tag = format!(
                    "{}/{}/{}",
                    dataflow.label(),
                    streaming.key(),
                    collection.label()
                );
                assert_equivalent(&cfg, streaming, collection, &tag);
            }
        }
    }
}

#[test]
fn event_kernel_matches_reference_on_16x16_two_packet_regime() {
    // 16×16 n=8: two gather packets per row (§5.2), the INA merge point
    // under real contention, and the largest active set.
    for collection in [Collection::Gather, Collection::Ina] {
        let cfg = SimConfig::table1_16x16(8);
        let tag = format!("16x16/{}", collection.label());
        assert_equivalent(&cfg, Streaming::TwoWay, collection, &tag);
    }
}

/// Drive one burst schedule (row-wide posts every `gap` cycles) on a
/// network built with `intra_workers` band workers, and return the full
/// observable surface — stats, final cycle, delivery counters and the
/// per-link probe report.
fn run_banded(
    topology: TopologyKind,
    collection: Collection,
    mesh: usize,
    intra_workers: usize,
    gap: u64,
) -> (Observed, Option<ProbeReport<'static>>) {
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.mesh_cols = mesh;
    cfg.mesh_rows = mesh;
    cfg.topology = topology;
    cfg.probes = true;
    cfg.intra_workers = intra_workers;
    cfg.validate().unwrap();
    let mut net = Network::new(&cfg, collection);
    for burst in 0..5u64 {
        let at = burst * gap + 3;
        let y = (burst % mesh as u64) as u16;
        for x in 0..mesh as u16 {
            net.post_result(at, Coord::new(x, y), cfg.pes_per_router as u32);
        }
    }
    assert!(
        net.run_until_idle(20_000_000),
        "{topology:?}/{collection:?} w{intra_workers}: workload stalled"
    );
    (observe(&net), net.probe_report().map(|p| p.into_owned()))
}

#[test]
fn parallel_kernel_matches_sequential_across_the_worker_matrix() {
    // The intra-layer parallel kernel (noc::parallel) against its own
    // sequential twin: mesh/torus/cmesh × ru/gather/ina at workers
    // 2/4/8 vs workers 1 — full NetStats, final cycle AND ProbeReport
    // must be bit-identical. This is the end-to-end check of the
    // ascending-band merge-order argument.
    for topology in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::CMesh] {
        for collection in
            [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
        {
            let base = run_banded(topology, collection, 8, 1, 37);
            assert!(
                base.0.delivered > 0,
                "{topology:?}/{collection:?}: workload delivered nothing"
            );
            for w in [2usize, 4, 8] {
                let par = run_banded(topology, collection, 8, w, 37);
                assert_eq!(
                    par, base,
                    "{topology:?}/{collection:?}: parallel kernel (workers {w}) \
                     diverged from the sequential kernel"
                );
            }
        }
    }
}

#[test]
fn parallel_kernel_handles_ragged_bands_and_fast_forward_gaps() {
    // 8 rows over 3 workers leaves a ragged 2-row last band; the prime
    // burst spacing (7919 cycles, far past the series bucket width)
    // forces calendar fast-forward jumps between bursts while the
    // parallel kernel is active.
    for collection in [Collection::Gather, Collection::Ina] {
        let base = run_banded(TopologyKind::Mesh, collection, 8, 1, 7_919);
        for w in [3usize, 8] {
            let par = run_banded(TopologyKind::Mesh, collection, 8, w, 7_919);
            assert_eq!(
                par, base,
                "{collection:?} workers {w}: ragged band / fast-forward run \
                 diverged from the sequential kernel"
            );
        }
    }
    // Row count not divisible by the worker count: 7 rows at 2 workers
    // (bands of 4 and 3) and at 4 workers (2/2/2/1).
    for w in [2usize, 4] {
        let base = run_banded(TopologyKind::Mesh, Collection::Gather, 7, 1, 37);
        let par = run_banded(TopologyKind::Mesh, Collection::Gather, 7, w, 37);
        assert_eq!(par, base, "7x7 workers {w}: ragged last band diverged");
    }
}

#[test]
fn event_kernel_matches_reference_across_fast_forward_gaps() {
    // Sparse bursts separated by long quiescent stretches: both kernels
    // must take identical clock jumps (same next_event_cycle semantics)
    // and land on identical stats. Exercises the calendar-queue window
    // hops over multi-thousand-cycle gaps.
    for collection in
        [Collection::Gather, Collection::RepetitiveUnicast, Collection::Ina]
    {
        let cfg = SimConfig::table1_8x8(4);
        let mut event = Network::new(&cfg, collection);
        let mut reference = ReferenceNetwork::new(&cfg, collection);
        let schedule = |net: &mut dyn FnMut(u64, Coord, u32)| {
            for burst in 0..6u64 {
                let at = burst * 7_919 + 3; // prime-spaced, far beyond the wheel
                let y = (burst % 8) as u16;
                for x in 0..8u16 {
                    net(at, Coord::new(x, y), cfg.pes_per_router as u32);
                }
            }
        };
        schedule(&mut |at, c, p| event.post_result(at, c, p));
        schedule(&mut |at, c, p| SimKernel::post_result(&mut reference, at, c, p));
        assert!(event.run_until_idle(10_000_000), "event kernel stalled");
        assert!(reference.run_until_idle(10_000_000), "reference kernel stalled");
        let (a, b) = (observe(&event), observe(&reference));
        assert_eq!(a, b, "{collection:?}: kernels diverged across fast-forward gaps");
        assert!(
            a.cycle >= 5 * 7_919,
            "{collection:?}: clock never reached the last burst (cycle {})",
            a.cycle
        );
    }
}
