//! Behavioural tests of `Collection::Ina` — in-network accumulation
//! (arXiv:2209.10056) on the cycle-accurate mesh: one small packet per
//! row per round, zero-latency folds at transit NIs, accumulation-space
//! isolation across rounds, closed-form hop-weighted traffic, and
//! conservation under contention.

use noc_dnn::analytic;
use noc_dnn::config::{Collection, SimConfig};
use noc_dnn::noc::network::{Network, StreamEdge};
use noc_dnn::noc::Coord;

#[test]
fn single_small_packet_collects_a_whole_row() {
    // The INA headline: where gather needs a row-sized packet (9 flits
    // for n=4 on 8×8), INA crosses the row with a 2-flit packet and adds
    // everything into it en route.
    let cfg = SimConfig::table1_8x8(4);
    let mut net = Network::new(&cfg, Collection::Ina);
    for x in 0..8 {
        net.post_result(0, Coord::new(x, 2), 4);
    }
    // Drain fully before reading hop counters: payloads are credited when
    // the *head* ejects, while the tail still needs its final grants.
    assert!(net.run_until_idle(100_000), "INA row collection stalled");
    assert_eq!(net.payloads_delivered, 32);
    assert_eq!(net.stats.packets_injected, 1, "one small packet must suffice");
    assert_eq!(net.stats.ina_folds, 28, "7 transit nodes x 4 psums folded");
    assert_eq!(net.stats.ina_adds, 28, "one ALU add per folded word");
    assert_eq!(net.stats.ina_merges, 0, "no same-space packet ever co-resides here");
    assert_eq!(net.stats.delta_expiries, 0);
    // 2 flits × 8 hops, against gather's 9 × 8.
    assert_eq!(net.stats.flit_hops, 16);
}

#[test]
fn every_row_collects_independently() {
    let cfg = SimConfig::table1_8x8(8);
    let mut net = Network::new(&cfg, Collection::Ina);
    for y in 0..8 {
        for x in 0..8 {
            net.post_result(0, Coord::new(x, y), 8);
        }
    }
    let ok = net.run_until(|n| n.payloads_delivered >= 8 * 64, 200_000);
    assert!(ok);
    assert_eq!(net.stats.packets_injected, 8, "one packet per row");
    assert_eq!(net.stats.ina_folds, 8 * 7 * 8);
    assert!(net.run_until_idle(100_000));
    assert_eq!(net.stats.packets_ejected + net.stats.ina_merges, net.stats.packets_injected);
}

#[test]
fn rounds_never_accumulate_across_spaces() {
    // Two staggered rounds on one row: each must travel in its own packet
    // (psums of different rounds are different outputs — a cross-round
    // add would corrupt results). The space tag enforces this.
    let cfg = SimConfig::table1_8x8(4);
    let mut net = Network::new(&cfg, Collection::Ina);
    for x in 0..8 {
        net.post_result(0, Coord::new(x, 0), 4);
    }
    for x in 0..8 {
        net.post_result(5, Coord::new(x, 0), 4);
    }
    let ok = net.run_until(|n| n.payloads_delivered >= 64, 200_000);
    assert!(ok, "two-round INA collection stalled");
    assert!(net.run_until_idle(100_000));
    assert_eq!(net.payloads_delivered, 64);
    assert_eq!(
        net.stats.packets_injected, 2,
        "one packet per round — a shared packet would mean a cross-round add"
    );
    assert_eq!(net.stats.ina_merges, 0);
    assert_eq!(net.stats.ina_folds, 2 * 7 * 4, "each round folds its own row");
}

#[test]
fn hop_weighted_traffic_matches_the_closed_form() {
    // The analytic `row_collection_flit_hops` closed form against the
    // simulator, for all three collection schemes across Table-1 points.
    // (Fully drained — `single_row_collection` snapshots at head-eject
    // time, before the trailing flits finish their hops, so it is not
    // usable for exact hop equality.)
    for (mesh, n) in [(8usize, 1usize), (8, 4), (8, 8), (16, 1), (16, 8)] {
        let cfg = SimConfig::table1(mesh, n);
        for coll in [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina] {
            let mut net = Network::new(&cfg, coll);
            for x in 0..cfg.mesh_cols {
                net.post_result(0, Coord::new(x as u16, 0), n as u32);
            }
            assert!(net.run_until_idle(2_000_000), "{coll:?} on {mesh}x{mesh} stalled");
            assert_eq!(net.payloads_delivered, (mesh * n) as u64);
            let expect = analytic::row_collection_flit_hops(&cfg, coll, n as u32);
            assert_eq!(
                net.stats.flit_hops, expect,
                "{coll:?} on {mesh}x{mesh}, n={n}: simulated hops diverge from closed form"
            );
        }
    }
}

#[test]
fn ina_survives_stream_contention_and_space_skew_with_conservation() {
    // δ<κ degenerate INA under a long same-row operand stream plus a
    // partially-posted second round (some nodes skip it, so activation
    // times skew): packets bunch behind the stream and same-space heads
    // may co-reside, exercising the switch-allocation merge path — while
    // the post-cycle-derived space tags keep the two rounds unmergeable.
    // Whatever folds/merges fire, payload and packet accounting must
    // close exactly.
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.delta = 0;
    let mut net = Network::new(&cfg, Collection::Ina);
    net.post_operand_stream(0, StreamEdge::Row(0), 256);
    for x in 0..8u16 {
        net.post_result(30, Coord::new(x, 0), 4);
    }
    for x in [0u16, 2, 3, 5, 7] {
        net.post_result(90, Coord::new(x, 0), 4);
    }
    let total = 32 + 20;
    let ok = net.run_until(
        |n| n.payloads_delivered >= total && n.stream_tails_ejected >= 1,
        1_000_000,
    );
    assert!(ok, "contended INA run stalled: {}/{total}", net.payloads_delivered);
    assert!(net.run_until_idle(1_000_000));
    assert_eq!(net.payloads_delivered, total);
    assert_eq!(net.payloads_in_flight(), 0);
    assert_eq!(net.total_buffered_flits(), 0);
    assert_eq!(
        net.stats.packets_injected,
        net.stats.packets_ejected + net.stats.ina_merges,
        "absorbed packets must be the only injected-vs-ejected gap"
    );
    // Every fold is one add per word; merges only add on top of that
    // (the absorbed packet's physical words), and each merge moves at
    // least one word.
    assert!(net.stats.ina_adds >= net.stats.ina_folds + net.stats.ina_merges);
}
