//! Detailed behavioural tests of the cycle-accurate network: zero-load
//! latency arithmetic, wormhole serialization, credit backpressure,
//! gather packet emergence (1 packet on 8×8, 2 on 16×16), multicast
//! stream delivery, and the δ<κ degeneration.

use noc_dnn::config::{Collection, SimConfig};
use noc_dnn::noc::network::{Network, StreamEdge};
use noc_dnn::noc::Coord;

fn drain(net: &mut Network, payloads: u64) -> u64 {
    let ok = net.run_until(|n| n.payloads_delivered >= payloads, 1_000_000);
    assert!(ok, "network stalled at {}/{payloads}", net.payloads_delivered);
    net.cycle
}

#[test]
fn zero_load_unicast_latency_matches_pipeline_model() {
    // One unicast packet from (0,y) to the row memory: the head pays
    // κ+link per hop over (cols) routers + injection overhead; the tail
    // (2-flit packet) follows one cycle behind.
    let cfg = SimConfig::table1_8x8(1);
    let mut net = Network::new(&cfg, Collection::RepetitiveUnicast);
    net.post_result(0, Coord::new(0, 3), 1);
    let done = drain(&mut net, 1);
    // Analytic: injection pipeline (~3) + 8 hops x (kappa+link) - final
    // link reabsorbed at ejection; measured 39. Pin with +/-3 slack so
    // timing regressions surface.
    assert!((36..=42).contains(&done), "zero-load latency {done}");
    assert!(net.run_until_idle(10_000));
    assert_eq!(net.stats.packets_injected, 1);
    assert_eq!(net.stats.packets_ejected, 1);
}

#[test]
fn zero_load_latency_scales_with_distance() {
    let cfg = SimConfig::table1_8x8(1);
    let mut t = Vec::new();
    for x in [0u16, 4, 7] {
        let mut net = Network::new(&cfg, Collection::RepetitiveUnicast);
        net.post_result(0, Coord::new(x, 0), 1);
        t.push(drain(&mut net, 1));
    }
    assert!(t[0] > t[1] && t[1] > t[2], "farther sources must take longer: {t:?}");
    // Per-hop delta = kappa + link = 5.
    assert_eq!(t[1] - t[2], 3 * 5);
    assert_eq!(t[0] - t[1], 4 * 5);
}

#[test]
fn gather_single_packet_collects_whole_8x8_row() {
    let cfg = SimConfig::table1_8x8(4);
    let mut net = Network::new(&cfg, Collection::Gather);
    for x in 0..8 {
        net.post_result(0, Coord::new(x, 2), 4);
    }
    drain(&mut net, 32);
    assert_eq!(net.stats.packets_injected, 1, "one packet must suffice");
    assert_eq!(net.stats.gather_boards, 28, "7 transit nodes x 4 payloads");
    assert_eq!(net.gather_packets_ejected, 1);
}

#[test]
fn sixteen_mesh_emerges_exactly_two_gather_packets() {
    // §5.2: capacity covers half the row; the starved node initiates the
    // second packet immediately on seeing the full first one.
    for n in [1usize, 2, 4, 8] {
        let cfg = SimConfig::table1_16x16(n);
        let mut net = Network::new(&cfg, Collection::Gather);
        for x in 0..16 {
            net.post_result(0, Coord::new(x, 5), n as u32);
        }
        drain(&mut net, 16 * n as u64);
        assert_eq!(
            net.stats.packets_injected, 2,
            "n={n}: expected exactly 2 gather packets, got {}",
            net.stats.packets_injected
        );
    }
}

#[test]
fn tiny_delta_degenerates_to_per_node_packets_with_higher_cost() {
    let mut small = SimConfig::table1_8x8(8);
    small.delta = 0;
    let mut net_small = Network::new(&small, Collection::Gather);
    let big = SimConfig::table1_8x8(8);
    let mut net_big = Network::new(&big, Collection::Gather);
    for x in 0..8 {
        net_small.post_result(0, Coord::new(x, 0), 8);
        net_big.post_result(0, Coord::new(x, 0), 8);
    }
    let t_small = drain(&mut net_small, 64);
    let t_big = drain(&mut net_big, 64);
    assert!(net_small.stats.packets_injected > net_big.stats.packets_injected);
    assert!(net_small.stats.flit_hops > net_big.stats.flit_hops);
    assert!(t_small >= t_big, "congested delta<kappa must not be faster");
}

#[test]
fn wormhole_packets_do_not_interleave_on_a_vc() {
    // Two nodes on the same row send long gather packets; payload and
    // packet conservation under VC competition.
    let mut cfg = SimConfig::table1_8x8(8);
    cfg.delta = 0; // force both to self-inject 17-flit packets
    let mut net = Network::new(&cfg, Collection::Gather);
    net.post_result(0, Coord::new(2, 1), 8);
    net.post_result(0, Coord::new(3, 1), 8);
    drain(&mut net, 16);
    assert!(net.run_until_idle(100_000));
    assert_eq!(net.stats.packets_ejected, net.stats.packets_injected);
    assert_eq!(net.total_buffered_flits(), 0);
}

#[test]
fn credit_backpressure_bounds_buffer_occupancy() {
    // Flood one row from many sources; buffers must never exceed depth
    // (enforced by an assert inside VcBuffer::push — this test exercises
    // it under the heaviest contention we can generate).
    let mut cfg = SimConfig::table1_8x8(8);
    cfg.delta = 0;
    let mut net = Network::new(&cfg, Collection::RepetitiveUnicast);
    for r in 0..4u64 {
        for x in 0..8 {
            net.post_result(r, Coord::new(x, 0), 8);
        }
    }
    drain(&mut net, 4 * 64);
    assert!(net.run_until_idle(100_000));
    assert_eq!(net.total_buffered_flits(), 0);
}

#[test]
fn operand_streams_deliver_along_rows_and_columns() {
    let cfg = SimConfig::table1_8x8(1);
    let mut net = Network::new(&cfg, Collection::Gather);
    net.post_operand_stream(0, StreamEdge::Row(3), 64); // 16 body flits
    net.post_operand_stream(0, StreamEdge::Col(5), 32);
    let ok = net.run_until(|n| n.stream_tails_ejected >= 2, 100_000);
    assert!(ok, "streams stalled");
    // Row stream: 17 flits x 8 routers; col stream: 9 flits x 8 routers.
    assert_eq!(net.stats.stream_deliveries, 17 * 8 + 9 * 8);
}

#[test]
fn crossing_streams_use_disjoint_crossbar_paths() {
    // Row streams (West->East) and column streams (North->South) use
    // different input AND output ports — a non-blocking 5x5 crossbar
    // passes them concurrently. (The gather-only architecture's real
    // contention is stream-vs-collection, tested below.)
    let cfg = SimConfig::table1_8x8(1);
    let mut solo = Network::new(&cfg, Collection::Gather);
    solo.post_operand_stream(0, StreamEdge::Row(4), 256);
    assert!(solo.run_until(|n| n.stream_tails_ejected >= 1, 100_000));
    let t_solo = solo.cycle;
    let mut cross = Network::new(&cfg, Collection::Gather);
    cross.post_operand_stream(0, StreamEdge::Row(4), 256);
    for x in 0..8 {
        cross.post_operand_stream(0, StreamEdge::Col(x), 256);
    }
    assert!(cross.run_until(|n| n.stream_tails_ejected >= 9, 400_000));
    assert!(cross.cycle <= t_solo + 8, "orthogonal streams should not serialize");
}

#[test]
fn collection_contends_with_same_row_operand_stream() {
    // Operand streams and result collection both head East on the same
    // row: they share output ports, so the gather-only architecture pays
    // real contention — the mechanism behind Fig. 14's streaming-bus win.
    // (The inverse direction — collection delaying a lone small gather
    // packet — is mostly absorbed by the credit-loop bubbles, so we
    // assert on the stream side, where the interference is unavoidable.)
    let cfg = SimConfig::table1_8x8(8);
    let stream_words = 512u64;
    let mut solo = Network::new(&cfg, Collection::RepetitiveUnicast);
    solo.post_operand_stream(0, StreamEdge::Row(4), stream_words);
    assert!(solo.run_until(|n| n.stream_tails_ejected >= 1, 100_000));
    let t_solo = solo.cycle;
    let mut busy = Network::new(&cfg, Collection::RepetitiveUnicast);
    busy.post_operand_stream(0, StreamEdge::Row(4), stream_words);
    for x in 0..8 {
        busy.post_result(0, Coord::new(x, 4), 8); // 8 unicast pkts per node
    }
    assert!(busy.run_until(|n| n.stream_tails_ejected >= 1, 400_000));
    let t_busy = busy.cycle;
    assert!(
        t_busy > t_solo,
        "stream sharing the row with collection must slow down ({t_busy} vs {t_solo})"
    );
}

#[test]
fn rows_drain_independently_in_parallel() {
    // Same per-row load on 1 vs 8 rows: makespans should be close
    // (rows share nothing but the sink column).
    let cfg = SimConfig::table1_8x8(4);
    let mut one = Network::new(&cfg, Collection::Gather);
    for x in 0..8 {
        one.post_result(0, Coord::new(x, 0), 4);
    }
    let t1 = drain(&mut one, 32);
    let mut all = Network::new(&cfg, Collection::Gather);
    for y in 0..8 {
        for x in 0..8 {
            all.post_result(0, Coord::new(x, y), 4);
        }
    }
    let t8 = drain(&mut all, 8 * 32);
    assert!(t8 <= t1 + 10, "rows must drain in parallel: 1-row {t1}, 8-row {t8}");
}

#[test]
fn payloads_delivered_counts_each_exactly_once() {
    let cfg = SimConfig::table1_16x16(2);
    let mut net = Network::new(&cfg, Collection::Gather);
    let mut expect = 0u64;
    for y in 0..16 {
        for x in 0..16 {
            net.post_result(0, Coord::new(x, y), 2);
            expect += 2;
        }
    }
    drain(&mut net, expect);
    assert!(net.run_until_idle(1_000_000));
    assert_eq!(net.payloads_delivered, expect, "no duplicates after full drain");
}
